"""The paper's technique as a data-pipeline operator: near-duplicate removal,
then the deduped corpus served as an index for incoming documents.

Stage 1 (self-join): documents are sketched into a 6-D embedding (hashed
bigram counts + random projection -- exactly the low-dimensionality regime
the paper targets) and the distance-similarity self-join finds all
near-duplicate pairs; union-find keeps one representative per duplicate
cluster.

Stage 2 (external-query join, DESIGN.md S5): the deduped corpus becomes the
INDEXED set; a later batch of incoming documents is screened against it with
``core.query_join.epsilon_join`` -- counts say which incoming docs duplicate
the corpus, pairs say WHICH corpus doc each one duplicates -- without ever
re-joining the corpus against itself. This is the index-once/query-many
serving regime (launch/serve.py runs it as a persistent service).
"""
import numpy as np

from repro.data.dedup import dedup_batch, embed_ngrams
from repro.core.query_join import epsilon_join
from repro.core.selfjoin import self_join

rng = np.random.default_rng(0)
N_DIMS = 6     # sketch dimensionality (the paper's <= 6-D regime)
EPS = 0.1      # near-dup radius: above 1-2 token edits, below distinct docs

# a batch of 64 "documents": 48 unique + 8 exact dups + 8 near-dups
unique = rng.integers(0, 5000, (48, 256))
dups = unique[:8].copy()
near = unique[8:16].copy()
near[:, ::128] += 1         # light token noise (2 of 256 tokens)
batch = np.concatenate([unique, dups, near])

emb = embed_ngrams(batch, n_dims=N_DIMS)
pairs = self_join(emb, EPS, unicomp=True)
keep = dedup_batch(batch, eps=EPS, n_dims=N_DIMS)

print(f"documents           : {batch.shape[0]}")
print(f"duplicate pairs     : {pairs.shape[0] // 2} (unordered)")
print(f"kept after dedup    : {int(keep.sum())}")
assert keep.sum() == 48, keep.sum()
assert keep[:48].all() and not keep[48:].any()
print("dedup kept exactly the 48 unique documents")

# --- stage 2: screen an incoming stream against the kept corpus ----------
corpus = batch[keep]
corpus_emb = embed_ngrams(corpus, n_dims=N_DIMS)
incoming = np.concatenate([
    unique[20:24],                      # 4 near-dups of corpus docs
    rng.integers(0, 5000, (4, 256)),    # 4 genuinely new docs
])
incoming[:4, ::128] += 1                # light noise on the dup half
res = epsilon_join(embed_ngrams(incoming, n_dims=N_DIMS), corpus_emb, EPS)
is_dup = res.counts > 0
print(f"incoming screened   : {incoming.shape[0]} "
      f"({int(is_dup.sum())} duplicate the corpus)")
for qi, doc_id in res.pairs:
    print(f"  incoming[{qi}] duplicates corpus doc {doc_id}")
assert is_dup[:4].all() and not is_dup[4:].any(), is_dup
# the pairs name the exact corpus representatives (unique[20:24] kept
# their original positions 20..23 in the deduped corpus)
assert np.array_equal(res.pairs[:, 1], np.arange(20, 24)), res.pairs
print("external-query join flagged exactly the 4 incoming duplicates")
