"""The paper's technique as a data-pipeline operator: embedding-based
near-duplicate removal on COSINE similarity, then the deduped corpus
served as an index for incoming documents.

Stage 1 (cosine self-join, DESIGN.md S12): documents are sketched into a
6-D embedding (hashed bigram counts + random projection -- exactly the
low-dimensionality regime the paper targets) and deduped on cosine
similarity >= MIN_COS via the metric-trait join path: unit-normalize,
grid self-join at the equivalent chord radius, union-find keeps one
representative per duplicate cluster. Cosine is the right dedup metric
for embeddings -- a doc concatenated with itself doubles its sketch
norm but keeps its direction, so L2 would miss it while cosine pins it
at similarity 1.

The pipeline also survives encoder failures: all-zero and NaN embedding
rows (a timeout / overflow in a real encoder) are quarantined by the
zero-vector guard instead of crashing cosine canonicalization, and kept
for re-encoding.

Stage 2 (external-query join, DESIGN.md S5): the deduped corpus becomes
the INDEXED set; a later batch of incoming documents is screened against
it with ``core.query_join.epsilon_join(metric='cosine')`` -- counts say
which incoming docs duplicate the corpus, pairs say WHICH corpus doc
each one duplicates -- without ever re-joining the corpus against
itself. This is the index-once/query-many serving regime
(launch/serve.py runs it as a persistent service).
"""
import numpy as np

from repro.data.dedup import dedup_embeddings, embed_ngrams, guard_embeddings
from repro.core.query_join import epsilon_join

rng = np.random.default_rng(0)
N_DIMS = 6      # sketch dimensionality (the paper's <= 6-D regime)
MIN_COS = 0.997  # near-dup threshold: above the densest unrelated pair
                 # (cos 0.995 on this seed), below the lightest near-dup
                 # (cos 0.9988 -- 2 of 256 tokens edited)

# a batch of 66 "documents": 48 unique + 8 exact dups + 8 near-dups,
# plus 2 rows whose encoder "failed" (zero vector / NaN)
unique = rng.integers(0, 5000, (48, 256))
dups = unique[:8].copy()
near = unique[8:16].copy()
near[:, ::128] += 1         # light token noise (2 of 256 tokens)
batch = np.concatenate([unique, dups, near])

emb = embed_ngrams(batch, n_dims=N_DIMS)
emb = np.concatenate([emb, np.zeros((1, N_DIMS)),          # encoder timeout
                      np.full((1, N_DIMS), np.nan)])       # encoder overflow
keep, valid = dedup_embeddings(emb, min_cos=MIN_COS)

print(f"documents           : {emb.shape[0]}")
print(f"quarantined encodes : {int((~valid).sum())} (kept, not joined)")
print(f"kept after dedup    : {int(keep.sum())}")
assert not valid[64:].any() and valid[:64].all(), valid
assert keep[64:].all(), "guarded rows must be kept for re-encoding"
assert keep[:64].sum() == 48, keep[:64].sum()
assert keep[:48].all() and not keep[48:64].any()
print("cosine dedup kept the 48 unique documents + 2 quarantined rows")

# --- stage 2: screen an incoming stream against the kept corpus ----------
corpus_emb = emb[keep & valid]
incoming = np.concatenate([
    unique[20:24],                      # 4 near-dups of corpus docs
    rng.integers(0, 5000, (4, 256)),    # 4 genuinely new docs
])
incoming[:4, ::128] += 1                # light noise on the dup half
inc_emb = embed_ngrams(incoming, n_dims=N_DIMS)
assert guard_embeddings(inc_emb).all()  # real encodes pass the guard
res = epsilon_join(inc_emb, corpus_emb, MIN_COS, metric="cosine")
is_dup = res.counts > 0
print(f"incoming screened   : {incoming.shape[0]} "
      f"({int(is_dup.sum())} duplicate the corpus)")
for qi, doc_id in res.pairs:
    print(f"  incoming[{qi}] duplicates corpus doc {doc_id}")
assert is_dup[:4].all() and not is_dup[4:].any(), is_dup
# the pairs name the exact corpus representatives (unique[20:24] kept
# their original positions 20..23 in the deduped corpus)
assert np.array_equal(res.pairs[:, 1], np.arange(20, 24)), res.pairs
print("cosine external-query join flagged exactly the 4 incoming duplicates")
