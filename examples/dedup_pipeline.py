"""The paper's technique as a data-pipeline operator: near-duplicate removal.

Documents are sketched into a 4-D embedding (hashed bigram counts + random
projection -- exactly the low-dimensionality regime the paper targets) and
the distance-similarity self-join finds all near-duplicate pairs; union-find
keeps one representative per duplicate cluster.
"""
import numpy as np

from repro.data.dedup import dedup_batch, embed_ngrams
from repro.core.selfjoin import self_join

rng = np.random.default_rng(0)

# a batch of 64 "documents": 48 unique + 8 exact dups + 8 near-dups
unique = rng.integers(0, 5000, (48, 256))
dups = unique[:8].copy()
near = unique[8:16].copy()
near[:, ::17] += 1          # light token noise
batch = np.concatenate([unique, dups, near])

emb = embed_ngrams(batch, n_dims=4)
pairs = self_join(emb, 0.05, unicomp=True)
keep = dedup_batch(batch, eps=0.05)

print(f"documents           : {batch.shape[0]}")
print(f"duplicate pairs     : {pairs.shape[0] // 2} (unordered)")
print(f"kept after dedup    : {int(keep.sum())}")
assert keep.sum() == 48, keep.sum()
assert keep[:48].all() and not keep[48:].any()
print("dedup kept exactly the 48 unique documents")
