"""Epsilon-join serving: index once, answer batched external-query requests.

The index-once/query-many regime (DESIGN.md S5): launch.serve.JoinService
builds the grid index over the dataset at startup, warms the request
bucket's executables off the request path, and answers every request batch
of EXTERNAL query points through the fused query-join (core/query_join.py)
at steady-state execution cost -- no per-request trace/compile (asserted;
the driver fails if a steady-state request recompiles).

Run:  python examples/serve_join.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "selfjoin", "--points", "50000", "--dims", "4",
          "--eps", "2.5", "--requests", "10", "--request-batch", "512"])
