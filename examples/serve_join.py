"""Self-join serving: index once, answer batched epsilon-range requests.

The DBSCAN-style usage the paper cites (SII): the grid index is built once
over the dataset; request batches of query points are answered with the
bounded adjacent-cell search. Run:  python examples/serve_join.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "selfjoin", "--points", "50000", "--dims", "4",
          "--eps", "2.5", "--requests", "10", "--request-batch", "512"])
