"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the framework's full stack -- config registry, LMModel, AdamW with fp32
master, deterministic token pipeline with the paper's self-join dedup
operator, async checkpointing, straggler monitor -- via launch/train.py.

Default sizing is CPU-friendly; pass --full100m for the true 100M model
(12L x d768, GPT-2-small class) and more steps, as you would on a TPU host.
"""
import argparse
import sys

import repro  # noqa: F401  (enables x64, registers configs)
from repro.launch.train import main as train_main
from repro.models.config import ModelConfig

# a real ~124M config, selectable below
GPT_100M = ModelConfig(
    name="gpt-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, d_ff=3072, vocab=32000, attn_chunk=256,
)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full100m", action="store_true",
                    help="train the real 124M model (slow on CPU)")
    ap.add_argument("--steps", type=int, default=None)
    args, rest = ap.parse_known_args()

    if args.full100m:
        # register the 100M config under a temporary name
        import repro.configs as cfgs

        class _Mod:
            CONFIG = GPT_100M
            REDUCED = GPT_100M

        sys.modules["repro.configs.gpt_100m"] = _Mod
        cfgs.ALIASES["gpt-100m"] = "gpt_100m"
        steps = args.steps or 300
        argv = ["--arch", "gpt-100m", "--steps", str(steps),
                "--batch", "8", "--seq", "512", "--dedup",
                "--ckpt-dir", "/tmp/gpt100m_ckpt", "--ckpt-every", "100"]
    else:
        steps = args.steps or 200
        argv = ["--arch", "smoke-lm", "--reduced", "--steps", str(steps),
                "--batch", "8", "--seq", "128", "--dedup",
                "--ckpt-dir", "/tmp/lm_ckpt", "--ckpt-every", "100",
                "--log-every", "20"]
    train_main(argv + rest)
