"""Quickstart: the paper's distance-similarity self-join in five lines.

Builds the epsilon-grid index over a synthetic 4-D dataset (the paper's Syn-
regime), runs GPU-SJ with UNICOMP and the batching scheme, and validates the
result against the brute-force oracle -- the same consistency check the
paper used across its implementations.
"""
import numpy as np

from repro.core import (brute_force_count, self_join_batched,
                        self_join_count)

rng = np.random.default_rng(42)
D = rng.uniform(0, 100, size=(20_000, 4))   # |D|=20k points in 4-D
eps = 4.0

# the self-join: all ordered pairs within eps (grid index + UNICOMP +
# >=3 result batches, paper SIV-SV; fused gather-refine kernel, DESIGN.md S4)
pairs = self_join_batched(D, eps, unicomp=True, n_batches=3,
                          distance_impl="fused")
stats = self_join_count(D, eps, unicomp=True)

print(f"|D|={D.shape[0]} n=4 eps={eps}")
print(f"pairs found        : {pairs.shape[0]}")
print(f"cells visited      : {stats.cells_visited}")
print(f"candidates checked : {stats.candidates_checked}")
print(f"stencil offsets    : {stats.offsets} (UNICOMP: (3^n+1)/2)")

# validate against the O(N^2) oracle
expect = brute_force_count(D, eps)
assert pairs.shape[0] == expect, (pairs.shape[0], expect)
print(f"validated against brute force: {expect} pairs")
