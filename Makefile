# Developer entry points. `make verify` is the pre-merge gate: tier-1
# tests plus the serving-path no-retrace smoke (scripts/ci.sh).
.PHONY: verify test lint serve-smoke bench bench-serve bench-smoke

verify:
	bash scripts/ci.sh

test:
	PYTHONPATH=src python -m pytest -x -q

# static gate: contract prover + retrace/dtype linter vs the committed
# baseline (scripts/analysis_baseline.json), then the mutation check
# that proves the gate still has teeth.
lint:
	PYTHONPATH=src python -m repro.analysis
	PYTHONPATH=src python scripts/mutation_check.py

serve-smoke:
	PYTHONPATH=src python -m repro.launch.serve --arch selfjoin --requests 4

bench:
	PYTHONPATH=src python benchmarks/bench_selfjoin.py

bench-serve:
	PYTHONPATH=src python benchmarks/bench_selfjoin.py --mode serve

# one tiny workload, seconds: bench harness + BENCH schema rot gate (CI)
bench-smoke:
	PYTHONPATH=src python benchmarks/bench_selfjoin.py --smoke
