#!/usr/bin/env python
"""Mutation test for the analysis gate (run by scripts/ci.sh).

Proves the gate has teeth, per ISSUE 7's acceptance criteria: seeding
(a) an undersized window cap, (b) an int64 key literal on the int32 key
path, (c) a per-call ``jax.jit`` closure, (d) an int32-keyed index
whose volume leaves no device-probe headroom below the padding sentinel,
(e) a cell-run plan whose corrupted run length merges two cells into
one run (overlapping runs, DESIGN.md S11), and (f) a refine site that
inlines the eps-squared predicate instead of going through the metric
trait (DESIGN.md S12) must each produce a NEW failing finding, while
the unmutated tree produces zero new findings against the committed
baseline. Mutations are in-memory -- a tampered
``BucketPlan`` or ``run_ord`` injected through the prover's ``plan=`` /
``run_ord=`` seams, source text mutated before ``lint_source``, a forged
``GridIndex`` via ``dataclasses.replace`` -- so the working tree is
never touched.
"""
from __future__ import annotations

import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

import numpy as np  # noqa: E402

from repro.analysis import contracts, lint  # noqa: E402
from repro.analysis import findings as F  # noqa: E402
from repro.analysis.__main__ import DEFAULT_BASELINE, collect_findings  # noqa: E402

_FAILED = []


def check(name: str, ok: bool, detail: str = ""):
    print(f"  {'PASS' if ok else 'FAIL'}  {name}" + (f": {detail}" if detail and not ok else ""))
    if not ok:
        _FAILED.append(name)


def main() -> int:
    baseline = F.load_baseline(DEFAULT_BASELINE)

    # -- unmutated tree: zero new findings --------------------------------
    fresh = F.new_findings(collect_findings(), baseline)
    check("clean tree produces zero new findings", not fresh,
          "; ".join(f.key for f in fresh))

    # -- (a) undersized window cap ----------------------------------------
    from repro.core.grid import BucketPlan, build_grid_host, occupancy_plan

    rng = np.random.default_rng(3)
    centers = rng.uniform(0.0, 1.0, (4, 3))
    pts = centers[rng.integers(0, 4, 300)] + rng.normal(0.0, 0.03, (300, 3))
    index = build_grid_host(pts, 0.1)
    exact = contracts.recompute_cell_caps(index, merged=True)
    assert exact.max() > 8, "mutation fixture too sparse to undersize"
    plan = occupancy_plan(index, merged=True)
    tampered = BucketPlan(caps=(8,), sel=(None,),
                          cap_global=plan.cap_global,
                          hist={8: index.num_points})
    found = contracts.check_window_caps(index, merged=True, plan=tampered,
                                        tag="mutated")
    check("(a) undersized window cap is caught",
          any(f.rule == "cap-coverage" for f in found),
          "no cap-coverage finding")

    # -- (b) int64 key literal on the int32 path --------------------------
    grid_path = os.path.join(_REPO, "src", "repro", "core", "grid.py")
    with open(grid_path) as fh:
        text = fh.read()
    old = "    pad = jnp.asarray(pad_key_for(kd), kd)"
    assert old in text, "grid._pad_probe changed; update the mutation"
    mutated = text.replace(old, "    pad = jnp.asarray(PAD_KEY, kd)")
    found = lint.lint_source(mutated, "src/repro/core/grid.py")
    key = "lint:int64-key-literal:src/repro/core/grid.py::_pad_probe"
    check("(b) int64 key literal in _pad_probe is caught",
          any(f.key == key for f in F.new_findings(found, baseline)),
          "no new int64-key-literal finding at _pad_probe")

    # -- (c) per-call jax.jit closure -------------------------------------
    sj_path = os.path.join(_REPO, "src", "repro", "core", "selfjoin.py")
    with open(sj_path) as fh:
        text = fh.read()
    mutated = text + (
        "\n\ndef _mutated_range_query(points, eps):\n"
        "    @jax.jit\n"
        "    def run(x):\n"
        "        return x\n"
        "    return run(points)\n")
    found = lint.lint_source(mutated, "src/repro/core/selfjoin.py")
    key = ("lint:per-call-jit:src/repro/core/selfjoin.py"
           "::_mutated_range_query")
    check("(c) per-call jax.jit closure is caught",
          any(f.key == key for f in F.new_findings(found, baseline)),
          "no new per-call-jit finding")

    # -- (d) int32 keys with no probe headroom below the pad sentinel -----
    import dataclasses

    import jax.numpy as jnp

    # volume 2 * (2^30 - 1) = 2^31 - 2: key_dtype_for still says int32
    # (C4 stays clean) but the sentinel margin collapses to 2 -- the
    # device planners' key+2 probe would reach the padding sentinel
    forged = dataclasses.replace(
        index,
        dims=jnp.asarray([2, 2**30 - 1], jnp.int64),
        cell_keys=index.cell_keys.astype(jnp.int32))
    found = contracts.check_device_sentinel(forged, tag="mutated")
    check("(d) collapsed device-probe sentinel margin is caught",
          any(f.rule == "device-sentinel" for f in found),
          "no device-sentinel finding")
    clean = contracts.check_device_sentinel(index, tag="clean")
    check("(d) healthy index passes the device-sentinel contract",
          not clean, "; ".join(f.key for f in clean))

    # -- (e) corrupted run length: two cells merged into one run ----------
    from repro.core.grid import cell_run_plan, round_up

    tq = 128
    rank = np.asarray(index.point_cell_rank)
    qp = round_up(index.num_points, tq)
    pos = np.minimum(np.arange(qp), index.num_points - 1)
    plan_e = cell_run_plan(rank[pos], tq)
    healthy = contracts.check_run_plan(index, run_ord=plan_e.run_ord,
                                       tq=tq, tag="clean")
    check("(e) healthy run plan passes the run-partition contract",
          not healthy, "; ".join(f.key for f in healthy))
    ro = plan_e.run_ord.reshape(-1, tq).copy()
    tiles_multi = np.flatnonzero(ro.max(axis=1) > 0)
    assert tiles_multi.size, "mutation fixture has one run per tile"
    t = int(tiles_multi[0])
    ro[t][ro[t] >= 1] -= 1   # first run swallows the next cell's rows
    found = contracts.check_run_plan(index, run_ord=ro.reshape(-1),
                                     tq=tq, tag="mutated")
    check("(e) overlapping-run corruption is caught",
          any(f.rule == "run-partition" for f in found),
          "no run-partition finding")

    # -- (f) inlined eps-squared predicate outside core/metric.py ---------
    brute_path = os.path.join(_REPO, "src", "repro", "core", "brute.py")
    with open(brute_path) as fh:
        text = fh.read()
    mutated = text + (
        "\n\ndef _mutated_refine(d2, eps):\n"
        "    return d2 <= eps * eps\n")
    found = lint.lint_source(mutated, "src/repro/core/brute.py")
    key = ("lint:eps-squared-predicate:src/repro/core/brute.py"
           "::_mutated_refine")
    check("(f) inlined eps-squared predicate is caught",
          any(f.key == key for f in F.new_findings(found, baseline)),
          "no new eps-squared-predicate finding")
    # the owner module itself must stay exempt (it DEFINES the predicate)
    metric_path = os.path.join(_REPO, "src", "repro", "core", "metric.py")
    with open(metric_path) as fh:
        found = lint.lint_source(fh.read(), "src/repro/core/metric.py")
    owner = [f for f in found if f.rule == "eps-squared-predicate"]
    check("(f) core/metric.py is exempt from the predicate rule",
          not owner, "; ".join(f.key for f in owner))

    if _FAILED:
        print(f"mutation check: FAIL ({len(_FAILED)} of 10)", file=sys.stderr)
        return 1
    print("mutation check: OK (10/10)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
