#!/usr/bin/env bash
# CI entry point (`make verify`): tier-1 tests + the serving-path smoke.
#
# The smoke drives the real serve driver end-to-end; JoinService's
# no-retrace assertion (launch/serve.py) makes it a hard failure if any
# steady-state request traces or compiles, so the serving path can never
# silently regress to per-request compilation again (ISSUE 2).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "[ci] static analysis gate (contract prover + retrace/dtype linter vs baseline)"
timeout 300 python -m repro.analysis

echo "[ci] analysis mutation check (seeded bugs must each produce a new finding)"
timeout 300 python scripts/mutation_check.py

echo "[ci] tier-1: pytest"
python -m pytest -x -q

echo "[ci] serve smoke (steady state must not retrace)"
timeout 120 python -m repro.launch.serve --arch selfjoin --requests 4

echo "[ci] batching serve smoke (admission queue + coalesced launches)"
timeout 180 python -m repro.launch.serve --arch selfjoin --requests 8 \
  --batching --request-batch 64 --max-batch 512

echo "[ci] load smoke (fixed offered load: p99 must hold the recorded SLO, coalesce factor must be > 1)"
timeout 300 python benchmarks/bench_selfjoin.py --mode load --smoke

echo "[ci] bench smoke, merged-range sweep (harness + BENCH schema + merged-vs-unmerged AND run-loop-vs-row-loop pair-set parity + dma_windows_issued decrease on the clustered workload)"
timeout 300 python benchmarks/bench_selfjoin.py --smoke

echo "[ci] bench smoke, per-cell sweep oracle (--no-merge; parity asserted again)"
timeout 300 python benchmarks/bench_selfjoin.py --smoke --no-merge

echo "[ci] bench smoke under REPRO_SANITIZE=1 (sanitized kernel mode: invariant checks must stay clean)"
REPRO_SANITIZE=1 timeout 300 python benchmarks/bench_selfjoin.py --smoke --no-assert-floor

echo "[ci] distributed bench smoke (2 slabs: pair-set parity vs single-device fused join)"
XLA_FLAGS="--xla_force_host_platform_device_count=2" \
  timeout 300 python benchmarks/bench_selfjoin.py --mode distributed --smoke

echo "[ci] index bench smoke (device build bit-identical to host, downstream pairs identical)"
timeout 300 python benchmarks/bench_selfjoin.py --mode index --smoke

echo "[ci] metrics bench smoke (cosine + jaccard pair-set parity vs brute oracles)"
timeout 300 python benchmarks/bench_selfjoin.py --mode metrics --smoke

echo "[ci] reindex smoke (mid-load snapshot swap must not trip the no-retrace watchdog)"
timeout 180 python -m repro.launch.serve --arch selfjoin --requests 8 --reindex

echo "[ci] OK"
