"""repro — TPU-native distance-similarity self-join framework.

Reproduction of Gowanlock & Karsin (2018), "GPU Accelerated Self-join for the
Distance Similarity Metric", adapted to TPU/JAX per DESIGN.md, plus the
multi-arch LM substrate (configs/, models/, launch/).

x64 is enabled globally by default: the paper's GPU-SJ uses 64-bit floats
throughout, and grids whose key space exceeds 2^31 cells need int64 keys.
Setting the ``REPRO_NO_X64`` environment variable (to anything non-empty)
skips the global enable: small grids (prod(dims) < 2^31) then run entirely
on the int32 key fast path (core/grid.py ``key_dtype_for``) with float32
coordinates, while a build that genuinely needs int64 keys raises a clear
error instead of silently aliasing cells. All model/LM code passes explicit
dtypes (bf16/f32) and is unaffected either way.
"""
import os

import jax

if not os.environ.get("REPRO_NO_X64"):
    jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
