"""repro — TPU-native distance-similarity self-join framework.

Reproduction of Gowanlock & Karsin (2018), "GPU Accelerated Self-join for the
Distance Similarity Metric", adapted to TPU/JAX per DESIGN.md, plus the
multi-arch LM substrate (configs/, models/, launch/).

x64 is enabled globally: the paper's GPU-SJ uses 64-bit floats throughout, and
the grid's linearized cell keys need int64 in >=4-D. All model/LM code passes
explicit dtypes (bf16/f32) and is unaffected.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
