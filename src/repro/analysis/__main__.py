"""CLI: run the contract prover + linter against the committed baseline.

    PYTHONPATH=src python -m repro.analysis                # gate (CI)
    PYTHONPATH=src python -m repro.analysis --write-baseline
    PYTHONPATH=src python -m repro.analysis --json report.json

The gate proves the bounded-search contracts on canned small geometries
(uniform 2-D, clustered 3-D, tiny 6-D -- one per key-dtype/skew regime),
checks the static no-retrace model for a canned request mix, and lints
``src/``. Findings are diffed against ``scripts/analysis_baseline.json``
by (analyzer, rule, site) key: accepted findings (e.g. the legitimate
``PAD_KEY`` declaration sites) pass, any NEW finding exits nonzero and
fails the build (scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

from repro.analysis import contracts, lint
from repro.analysis import findings as F

_SRC = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
_REPO = os.path.dirname(_SRC)
DEFAULT_BASELINE = os.path.join(_REPO, "scripts", "analysis_baseline.json")


def canned_datasets():
    """Small deterministic geometries covering the planner regimes:
    uniform (single capacity class), clustered (skew -> bucketed plan),
    and 6-D (largest stencil, int32/int64 key boundary pressure)."""
    rng = np.random.default_rng(7)
    out = [("uniform-2d", rng.uniform(0.0, 1.0, (400, 2)), 0.08)]
    centers = rng.uniform(0.0, 1.0, (6, 3))
    pts = centers[rng.integers(0, 6, 300)] + rng.normal(0.0, 0.02, (300, 3))
    out.append(("clustered-3d", pts, 0.05))
    out.append(("tiny-6d", rng.uniform(0.0, 1.0, (64, 6)), 0.3))
    return out


def collect_findings(src_root: str = _SRC) -> list:
    from repro.core.grid import build_grid_host
    from repro.core.query_join import prepare

    found = []
    for tag, pts, eps in canned_datasets():
        index = build_grid_host(pts, float(eps))
        found += contracts.prove_index_contracts(index, tag=f"index:{tag}")
        found += contracts.prove_halo_contracts(
            pts, float(eps), n_slabs=4, tag=f"halo:{tag}")
        found += lint.check_no_retrace(
            prepare(index), max_batch=256,
            request_sizes=(1, 3, 32, 128, 200), tag=f"retrace:{tag}")
    found += lint.lint_tree(src_root)
    return found


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="static contract prover + retrace/dtype linter")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed findings baseline (JSON)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept the current findings as the baseline")
    ap.add_argument("--json", default=None,
                    help="also write the full findings report to this path")
    ap.add_argument("--src", default=_SRC,
                    help="source root to lint (default: this checkout)")
    args = ap.parse_args(argv)

    found = collect_findings(args.src)
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(F.report_json(found))
    if args.write_baseline:
        F.save_baseline(found, args.baseline)
        print(f"wrote {len(F.baseline_keys(found))} accepted keys to "
              f"{args.baseline}")
        return 0
    baseline = (F.load_baseline(args.baseline)
                if os.path.exists(args.baseline) else set())
    fresh = F.new_findings(found, baseline)
    accepted = len(found) - len(fresh)
    print(f"analysis: {len(found)} finding(s), {accepted} accepted by "
          f"baseline, {len(fresh)} new")
    for f in fresh:
        print("  NEW " + f.render())
    if fresh:
        print("analysis: FAIL (new findings; fix them or re-run with "
              "--write-baseline to accept)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
