"""Contract prover for the bounded-search invariants (DESIGN.md S9).

Every capacity and shape bound the fused engine relies on is re-derived
here from first principles -- coordinate-space stencil enumeration over
the decoded cell keys, brute-force boolean-mask parcel counts -- with
algorithms deliberately DIFFERENT from the planners in ``core.grid`` and
``core.distributed`` (which use linear-key arithmetic and searchsorted).
A planner bug that undercounts a capacity therefore cannot hide: the
prover's exact bound exceeds the planner's and a finding is emitted.

Contracts proved per index (all host-side, no kernel launches):

  C1 cap-coverage      every cell's worst-case (merged-)window fits the
                       capacity class its query rows are bucketed into,
                       and the global cap dominates all cells
  C2 plan-partition    the occupancy plan is a true partition: each row
                       in exactly one bucket, caps ascending + aligned
  C3 external-cap      ``external_range_cap`` dominates every window an
                       external query can form (any integer base key)
  C4 key-sentinel      the pad sentinel can never alias a real cell key
                       (and the key dtype matches ``key_dtype_for``)
  C5 slot-base-range   the kernel's int32 per-tile exclusive scan and
                       per-query counts cannot overflow at any
                       (class, tile) the plan can launch
  C6 vmem-budget       per-(class, tile) kernel VMEM footprint fits the
                       ``launch/roofline.py`` budget
  C9 device-sentinel   the device build/planners' probe headroom: every
                       probe key (up to 2 above the largest real key) and
                       a padded build's out-of-set sentinel cell stay
                       strictly below the dtype-max padding sentinel
  C10 run-partition    every cell-run plan the fused drivers can launch
                       (DESIGN.md S11) is a true partition of its rows
                       into per-tile runs of ONE cell each: ordinals
                       reset at tile starts, advance by at most one, and
                       never merge two cells into one run (which would
                       evaluate the second cell's queries against the
                       first cell's resident window)

plus, for a slab partition (C7/C8): k-hop halo reach covers every
eps-close slab pair, and ``exact_halo_capacity`` covers the brute-force
parcel counts (with named worst parcels -- the capacity plan the
distributed drivers' overflow raise reports).
"""
from __future__ import annotations

import itertools
from typing import Optional

import numpy as np

from repro.analysis.findings import SEV_WARNING, Finding

_AN = "contracts"


# ---------------------------------------------------------------------------
# independent re-derivations
# ---------------------------------------------------------------------------

def recompute_cell_caps(index, merged: bool) -> np.ndarray:
    """Exact per-cell worst-case window length, derived in COORDINATE
    space: decode every present cell key to its multi-index
    (``np.unravel_index``), enumerate the stencil as coordinate offsets,
    and drop any neighbor that leaves the grid box -- the arithmetic
    ``grid.cell_window_caps`` does in linear-key space (where an
    off-grid probe can alias a real cell across a row boundary and only
    ever OVERcounts). The planner's caps must dominate these."""
    dims = np.asarray(index.dims).astype(np.int64)
    n = dims.size
    ncells = int(index.num_cells)
    if ncells == 0:
        return np.zeros(0, np.int64)
    keys = np.asarray(index.cell_keys[:ncells]).astype(np.int64)
    counts = np.asarray(index.cell_count[:ncells]).astype(np.int64)
    coords = np.stack(np.unravel_index(keys, dims), axis=1)   # (ncells, n)
    starts = np.concatenate(
        [np.asarray(index.cell_start[:ncells]),
         [int(index.num_points)]]).astype(np.int64)
    caps = np.zeros(ncells, np.int64)
    if not merged:
        for off in itertools.product((-1, 0, 1), repeat=n):
            tgt = coords + np.asarray(off, np.int64)
            ok = np.all((tgt >= 0) & (tgt < dims), axis=1)
            tkey = np.ravel_multi_index(
                np.clip(tgt, 0, dims - 1).T, dims)
            pos = np.minimum(np.searchsorted(keys, tkey), ncells - 1)
            live = ok & (keys[pos] == tkey)
            caps = np.maximum(caps, np.where(live, counts[pos], 0))
        return caps
    dim_last = int(dims[-1])
    for off in itertools.product((-1, 0, 1), repeat=max(n - 1, 0)):
        base = coords.copy()
        if n > 1:
            base[:, : n - 1] += np.asarray(off, np.int64)
            ok = np.all((base[:, : n - 1] >= 0)
                        & (base[:, : n - 1] < dims[: n - 1]), axis=1)
        else:
            ok = np.ones(ncells, bool)
        lo = base.copy()
        hi = base.copy()
        lo[:, -1] = np.maximum(lo[:, -1] - 1, 0)
        hi[:, -1] = np.minimum(hi[:, -1] + 1, dim_last - 1)
        lo_key = np.ravel_multi_index(np.clip(lo, 0, dims - 1).T, dims)
        hi_key = np.ravel_multi_index(np.clip(hi, 0, dims - 1).T, dims)
        lo_rank = np.searchsorted(keys, lo_key, side="left")
        hi_rank = np.searchsorted(keys, hi_key, side="right")
        span = starts[hi_rank] - starts[lo_rank]
        caps = np.maximum(caps, np.where(ok & (hi_rank > lo_rank), span, 0))
    return caps


def recompute_external_cap(index) -> int:
    """Exact maximum window ANY external query base key can form.

    A window spans keys [b-1, b+1] for an arbitrary integer base b; a
    nonempty window's smallest present key k lies in that range, so
    b in {k-1, k, k+1} anchored at each present key k enumerates every
    distinct nonempty window. Brute force over those 3*ncells bases."""
    ncells = int(index.num_cells)
    if ncells == 0:
        return 0
    keys = np.asarray(index.cell_keys[:ncells]).astype(np.int64)
    starts = np.concatenate(
        [np.asarray(index.cell_start[:ncells]),
         [int(index.num_points)]]).astype(np.int64)
    best = 0
    for shift in (-1, 0, 1):
        base = keys + shift
        lo_rank = np.searchsorted(keys, base - 1, side="left")
        hi_rank = np.searchsorted(keys, base + 1, side="right")
        span = starts[hi_rank] - starts[lo_rank]
        if span.size:
            best = max(best, int(span.max()))
    return best


# ---------------------------------------------------------------------------
# per-index contracts
# ---------------------------------------------------------------------------

def _plan_cell_caps(index, plan) -> np.ndarray:
    """Per-cell capacity the plan actually grants: the cap of the class
    each cell's rows land in (min over the cell's rows when tampering
    split a cell -- the prover must still catch it)."""
    npts = int(index.num_points)
    rank = np.asarray(index.point_cell_rank)
    ncells = int(index.num_cells)
    granted = np.full(npts, -1, np.int64)
    for cap, sel in zip(plan.caps, plan.sel):
        rows = np.arange(npts) if sel is None else np.asarray(sel)
        granted[rows] = cap
    # init far above any real capacity (not a key sentinel -- and written
    # without iinfo(int64) so the linter's int64-key-literal rule, which
    # scans this package too, has nothing to flag here)
    cell_granted = np.full(ncells, 1 << 62, np.int64)
    for cell in range(ncells):
        rows = np.flatnonzero(rank == cell)
        if rows.size:
            cell_granted[cell] = granted[rows].min()
    return cell_granted


def check_window_caps(index, *, merged: bool, plan=None,
                      tag: str = "index") -> list:
    """C1 + C2: plan/cap coverage of the exact worst-case windows."""
    from repro.core.grid import (CAP_ALIGN, cell_window_caps, global_window_cap,
                                 occupancy_plan)

    out = []
    site = f"{tag}:merged={merged}"
    exact = recompute_cell_caps(index, merged)
    planner = np.asarray(cell_window_caps(index, merged=merged),
                         np.int64)
    if exact.size and np.any(planner < exact):
        i = int(np.argmax(exact - planner))
        out.append(Finding(_AN, "cap-coverage", site,
                           f"cell_window_caps undercounts cell {i}: planner "
                           f"{int(planner[i])} < exact {int(exact[i])}"))
    cap_global = int(global_window_cap(index, merged=merged))
    if exact.size and cap_global < int(exact.max()):
        out.append(Finding(_AN, "cap-coverage", site + ":global",
                           f"global_window_cap {cap_global} < exact max "
                           f"window {int(exact.max())}"))
    if plan is None:
        plan = occupancy_plan(index, merged=merged)
    # C2: partition + ladder shape
    npts = int(index.num_points)
    covered = np.zeros(npts, np.int64)
    for sel in plan.sel:
        if sel is None:
            covered += 1
        else:
            np.add.at(covered, np.asarray(sel), 1)
    if npts and not np.all(covered == 1):
        bad = int(np.flatnonzero(covered != 1)[0])
        out.append(Finding(_AN, "plan-partition", site,
                           f"occupancy plan covers row {bad} "
                           f"{int(covered[bad])} times (want exactly 1)"))
    caps = [int(c) for c in plan.caps]
    if any(c % CAP_ALIGN for c in caps):
        out.append(Finding(_AN, "plan-partition", site + ":align",
                           f"bucket caps {caps} not {CAP_ALIGN}-aligned"))
    if caps != sorted(caps):
        out.append(Finding(_AN, "plan-partition", site + ":order",
                           f"bucket caps {caps} not ascending"))
    if caps and max(caps) > int(plan.cap_global):
        out.append(Finding(_AN, "plan-partition", site + ":ceiling",
                           f"bucket cap {max(caps)} exceeds cap_global "
                           f"{plan.cap_global}"))
    # C1 against the plan: the capacity each cell's rows are GRANTED must
    # dominate that cell's exact worst-case window
    if exact.size:
        granted = _plan_cell_caps(index, plan)
        short = granted < exact
        if np.any(short):
            i = int(np.flatnonzero(short)[0])
            out.append(Finding(
                _AN, "cap-coverage", site + ":bucket",
                f"cell {i} granted capacity {int(granted[i])} < exact "
                f"worst-case window {int(exact[i])}: the fused kernel "
                f"would silently truncate its candidate window"))
    return out


def check_external_cap(index, tag: str = "index") -> list:
    """C3: the serving-path capacity dominates every possible query."""
    from repro.core.grid import external_range_cap

    exact = recompute_external_cap(index)
    cap = int(external_range_cap(index))
    if cap < exact:
        return [Finding(_AN, "external-cap", tag,
                        f"external_range_cap {cap} < exact worst external "
                        f"window {exact}")]
    return []


def check_key_sentinel(index, tag: str = "index") -> list:
    """C4: dtype route + sentinel aliasing, exact python-int arithmetic."""
    from repro.core.grid import key_dtype_for, sentinel_margin

    out = []
    dims = np.asarray(index.dims).astype(np.int64)
    volume = 1
    for d in dims.ravel():
        volume *= int(d)
    want = key_dtype_for(dims)
    have = np.dtype(index.key_dtype)
    if have != want:
        out.append(Finding(_AN, "key-sentinel", f"{tag}:dtype",
                           f"index key dtype {have} != key_dtype_for "
                           f"{want} for volume {volume}"))
    margin = sentinel_margin(dims, have)
    sentinel = margin + volume - 1
    if margin <= 0:
        out.append(Finding(_AN, "key-sentinel", f"{tag}:alias",
                           f"max real key {volume - 1} >= pad sentinel "
                           f"{sentinel}: padding slots alias real cells"))
    elif volume == sentinel:
        out.append(Finding(
            _AN, "key-sentinel", f"{tag}:edge", severity=SEV_WARNING,
            message=f"volume {volume} equals the pad sentinel: a padded "
                    f"build's out-of-grid sentinel cell (key == volume) "
                    f"aliases padding slots"))
    if dims.size and int(dims.min()) < 3:
        out.append(Finding(
            _AN, "key-sentinel", f"{tag}:interior", severity=SEV_WARNING,
            message=f"grid has a dimension with {int(dims.min())} < 3 "
                    f"cells: the interior-coordinate guarantee (probe keys "
                    f"stay in [0, volume)) does not hold for self-join "
                    f"descriptors on this geometry"))
    return out


def check_device_sentinel(index, tag: str = "index") -> list:
    """C9: device-planner probe headroom, exact python-int arithmetic.

    The device build pads B with the dtype-max sentinel; the device
    planners probe up to 2 above the largest real key (the external-span
    sweep probes [k, k+2]; the merged hi-probe reaches key+1 plus a
    stencil delta inside the volume) and a padded build stores the
    out-of-set sentinel cell at key == volume. All of these must stay
    strictly BELOW the padding sentinel, or a probe ranks into the padding
    tail as a false hit and window capacities silently shift: require
    ``sentinel_margin > 2``. ``device_key_dtype`` widens padded builds
    that would violate this, so a violation here means the index was
    built with a forced key dtype on a volume within 2 of the dtype max.
    """
    from repro.core.grid import sentinel_margin

    dims = np.asarray(index.dims).astype(np.int64)
    kd = np.dtype(index.key_dtype)
    margin = sentinel_margin(dims, kd)
    if margin <= 2:
        return [Finding(_AN, "device-sentinel", f"{tag}:margin",
                        f"sentinel margin {margin} <= 2 for key dtype "
                        f"{kd}: a device probe key (up to max real key "
                        f"+ 2) or a padded build's sentinel cell reaches "
                        f"the padding sentinel and aliases padding slots")]
    return []


def _plan_tiles(index, plan, metric: str = "l2") -> dict:
    from repro.kernels import autotune

    return {int(cap): autotune.fused_tile(index.n_dims, int(cap),
                                          metric=metric)
            for cap in plan.caps}


def check_slot_base(index, *, merged: bool, plan=None, tiles=None,
                    metric: str = "l2", tag: str = "index") -> list:
    """C5: int32 range of the kernel's counts and per-tile scan.

    Per query: count <= n_off * c. Per tile of tq rows: the exclusive
    scan's last base <= (tq - 1) * n_off * c. Both live in int32 inside
    the kernel; prove they cannot wrap for any (class, tile) launch.
    The bound is metric-independent (every metric's refine emits at most
    one hit per candidate slot), but ``metric`` keys the tile lookup --
    a jaccard table row may launch a different tq."""
    from repro.core.grid import occupancy_plan

    out = []
    if plan is None:
        plan = occupancy_plan(index, merged=merged)
    if tiles is None:
        tiles = _plan_tiles(index, plan, metric)
    n = index.n_dims
    n_off = 3 ** (n - 1) if merged else 3 ** n   # full stencil bounds UNICOMP
    lim = 2 ** 31 - 1
    for cap in plan.caps:
        cap = int(cap)
        tq = int(tiles[cap])
        per_query = n_off * cap
        scan_top = (tq - 1) * per_query
        if per_query > lim:
            out.append(Finding(
                _AN, "slot-base-range", f"{tag}:c{cap}",
                f"per-query hit count bound n_off*c = {per_query} "
                f"overflows int32"))
        elif scan_top > lim:
            out.append(Finding(
                _AN, "slot-base-range", f"{tag}:c{cap}:t{tq}",
                f"per-tile slot-base bound (tq-1)*n_off*c = {scan_top} "
                f"overflows the kernel's int32 exclusive scan "
                f"(tq={tq}, n_off={n_off}, c={cap})"))
    return out


def check_vmem(index, *, merged: bool, plan=None, tiles=None,
               metric: str = "l2", n_feat: int = 0,
               tag: str = "index") -> list:
    """C6: per-(class, tile) kernel VMEM footprint vs the roofline budget.

    Metric-aware (DESIGN.md S12): feature lanes (jaccard token bitmaps)
    widen every padded row past the featureless NP_PAD, so the proof
    re-derives the actual lane width with the same ``pad_width`` rule the
    drivers use -- coordinates + feature lanes + the merged-sweep
    coordinate lane -- and feeds it through the roofline's ``np_pad``.
    An l2/cosine index (n_feat == 0) reproduces the old NP_PAD=8 bound
    exactly."""
    from repro.core.grid import occupancy_plan
    from repro.kernels.fused_join import pad_width
    from repro.launch.roofline import VMEM_BYTES, fused_join_vmem_bytes

    out = []
    if plan is None:
        plan = occupancy_plan(index, merged=merged)
    if tiles is None:
        tiles = _plan_tiles(index, plan, metric)
    lanes = index.n_dims + int(n_feat) + (1 if merged else 0)
    np_pad = pad_width(lanes)
    for cap in plan.caps:
        cap = int(cap)
        tq = int(tiles[cap])
        need = fused_join_vmem_bytes(c=cap, tq=tq, np_pad=np_pad)
        if need > VMEM_BYTES:
            out.append(Finding(
                _AN, "vmem-budget", f"{tag}:c{cap}:t{tq}",
                f"fused kernel footprint {need} B exceeds the VMEM "
                f"budget {VMEM_BYTES} B at (c={cap}, tq={tq}, "
                f"np_pad={np_pad}); shrink the tile or split the "
                f"capacity class"))
    return out


def _oracle_cell_of_row(index) -> np.ndarray:
    """Independent A-order row -> cell rank map: derived from the CSR
    ``cell_start`` boundaries by binary search, NOT from the stored
    ``point_cell_rank`` (whose consistency is exactly what C10 proves)."""
    ncells = int(index.num_cells)
    starts = np.asarray(index.cell_start[:ncells]).astype(np.int64)
    rows = np.arange(int(index.num_points), dtype=np.int64)
    return np.searchsorted(starts, rows, side="right") - 1


def _validate_run_ord(run_ord: np.ndarray, cells: np.ndarray, tq: int,
                      site: str) -> list:
    """Core C10 validation of ONE launch's run_ord against the oracle
    per-row cell ids (same length, pad rows already carry their clamped
    row's cell)."""
    out = []
    ro = np.asarray(run_ord).astype(np.int64)
    if tq <= 0 or ro.size % tq:
        return [Finding(_AN, "run-partition", site,
                        f"run plan length {ro.size} is not a multiple of "
                        f"the query tile tq={tq}")]
    o = ro.reshape(-1, tq)
    c = np.asarray(cells).astype(np.int64).reshape(-1, tq)
    if o.size and np.any(o[:, 0] != 0):
        t = int(np.flatnonzero(o[:, 0] != 0)[0])
        out.append(Finding(
            _AN, "run-partition", f"{site}:tile{t}",
            f"run ordinal does not reset at tile {t} start (got "
            f"{int(o[t, 0])}): the kernel's slot phase would leak across "
            f"the tile boundary"))
    d = np.diff(o, axis=1)
    if np.any((d < 0) | (d > 1)):
        t, r = [int(x[0]) for x in np.nonzero((d < 0) | (d > 1))]
        out.append(Finding(
            _AN, "run-partition", f"{site}:tile{t}:row{r + 1}",
            f"run ordinal steps by {int(d[t, r])} at tile {t} row "
            f"{r + 1} (must be 0 or 1): rows would skip or rewind the "
            f"double-buffered window slots"))
        return out   # step checks below assume sane ordinals
    changed = c[:, 1:] != c[:, :-1]
    merged_runs = (d == 0) & changed
    if np.any(merged_runs):
        t, r = [int(x[0]) for x in np.nonzero(merged_runs)]
        out.append(Finding(
            _AN, "run-partition", f"{site}:tile{t}:row{r + 1}",
            f"rows of cells {int(c[t, r])} and {int(c[t, r + 1])} share "
            f"run {int(o[t, r])} in tile {t}: the second cell's queries "
            f"would be refined against the first cell's resident window "
            f"(overlapping runs)"))
    split_cell = (d == 1) & ~changed
    if np.any(split_cell):
        t, r = [int(x[0]) for x in np.nonzero(split_cell)]
        out.append(Finding(
            _AN, "run-partition", f"{site}:tile{t}:row{r + 1}",
            severity=SEV_WARNING,
            message=f"cell {int(c[t, r])} is split across runs "
                    f"{int(o[t, r])} and {int(o[t, r + 1])} inside tile "
                    f"{t}: correct but re-gathers a resident window "
                    f"(run maximality)"))
    return out


def check_run_plan(index, *, merged: bool = True, plan=None, tiles=None,
                   run_ord=None, tq: Optional[int] = None,
                   metric: str = "l2", tag: str = "index") -> list:
    """C10: cell-run plans are exact partitions (DESIGN.md S11).

    Default mode rebuilds every run plan the fused self-join drivers can
    launch -- the whole-range launch plus each occupancy bucket's
    composed plan -- through ``grid.cell_run_plan`` on the stored
    ``point_cell_rank``, then validates each against cell ids re-derived
    INDEPENDENTLY from the CSR boundaries (``_oracle_cell_of_row``), so
    a bug in either the rank array or the run planner is caught.
    ``run_ord``/``tq`` inject one tampered plan through the same seam
    the mutation harness uses (validated over A-order rows, pad rows
    clamped to the last row -- the drivers' padding convention).
    """
    from repro.core.grid import cell_run_plan, occupancy_plan, round_up

    npts = int(index.num_points)
    if npts == 0:
        return []
    oracle = _oracle_cell_of_row(index)
    if run_ord is not None:
        if tq is None:
            raise ValueError("check_run_plan(run_ord=...) needs tq")
        pos = np.minimum(np.arange(np.asarray(run_ord).size), npts - 1)
        return _validate_run_ord(run_ord, oracle[pos], int(tq),
                                 f"{tag}:injected")
    rank = np.asarray(index.point_cell_rank).astype(np.int64)
    if plan is None:
        plan = occupancy_plan(index, merged=merged)
    if tiles is None:
        tiles = _plan_tiles(index, plan, metric)
    out = []
    for cap, sel in zip(plan.caps, plan.sel):
        t = int(tiles[int(cap)])
        if sel is None:
            qp = round_up(npts, t)
            pos = np.minimum(np.arange(qp), npts - 1)
            site = f"{tag}:merged={merged}:all:c{int(cap)}"
        else:
            sel = np.asarray(sel)
            if not sel.size:
                continue
            qp = round_up(sel.size, t)
            pos = np.zeros(qp, np.int64)
            pos[: sel.size] = sel   # pad rows group with row 0's cell,
            pos[sel.size:] = 0      # matching the driver (their windows
                                    # are zeroed, so the grouping is inert)
        if sel is not None:
            site = f"{tag}:merged={merged}:bucket:c{int(cap)}"
        ro = cell_run_plan(rank[pos], t).run_ord
        out += _validate_run_ord(ro, oracle[pos], t, site)
    return out


def prove_index_contracts(index, *, merged: Optional[bool] = None,
                          plan=None, tiles=None, metric: str = "l2",
                          n_feat: int = 0, tag: str = "index") -> list:
    """All per-index contracts (C1-C6, C9, C10). ``merged=None`` proves both
    sweep modes; ``plan``/``tiles`` override the planner outputs (the
    mutation harness injects tampered plans through exactly this seam).
    ``metric``/``n_feat`` describe the refine layout the index serves
    (DESIGN.md S12): they key the autotuned tile lookups and widen the C6
    VMEM proof by the metric's feature lanes. A jaccard index never runs
    a merged sweep, so its merged-mode proof is skipped."""
    modes = (False, True) if merged is None else (bool(merged),)
    if metric == "jaccard":
        modes = tuple(m for m in modes if not m) or (False,)
    out = check_key_sentinel(index, tag)
    out += check_device_sentinel(index, tag)
    out += check_external_cap(index, tag)
    for m in modes:
        out += check_window_caps(index, merged=m, plan=plan, tag=tag)
        out += check_slot_base(index, merged=m, plan=plan, tiles=tiles,
                               metric=metric, tag=tag)
        out += check_vmem(index, merged=m, plan=plan, tiles=tiles,
                          metric=metric, n_feat=n_feat, tag=tag)
        out += check_run_plan(index, merged=m, plan=plan, tiles=tiles,
                              metric=metric, tag=tag)
    return out


# ---------------------------------------------------------------------------
# halo contracts (C7/C8)
# ---------------------------------------------------------------------------

def prove_halo_contracts(points: np.ndarray, eps: float, n_slabs: int,
                         *, k_hops: Optional[int] = None,
                         halo_capacity: Optional[int] = None,
                         tag: str = "halo") -> list:
    """C7 reach + C8 parcel coverage for a slab partition.

    Parcels are recounted with direct boolean masks over each slab's
    owned dim-0 coordinates (the planner uses searchsorted over the
    sorted slab); ``exact_halo_capacity`` must dominate every parcel,
    and a user-supplied ``halo_capacity`` must dominate the plan."""
    from repro.core.distributed import (exact_halo_capacity,
                                        halo_capacity_plan, halo_reach,
                                        partition_points_host, slab_extents)

    out = []
    pts = np.asarray(points)
    if pts.shape[0] == 0:
        return out
    coords, gids, _ = partition_points_host(pts, n_slabs)
    mins, maxs = slab_extents(coords, gids)
    k_auto = halo_reach(mins, maxs, eps)
    if k_hops is None:
        k_hops = k_auto
    # C7: every eps-close slab pair within k hops
    for i in range(n_slabs):
        if not np.isfinite(maxs[i]):
            continue
        for j in range(i + 1, n_slabs):
            if not np.isfinite(mins[j]):
                continue
            if mins[j] <= maxs[i] + eps and j - i > k_hops:
                out.append(Finding(
                    _AN, "halo-reach", f"{tag}:{i}->{j}",
                    f"slabs {i} and {j} are eps-close along dim 0 "
                    f"(gap {mins[j] - maxs[i]:.4g} <= eps {eps}) but "
                    f"{j - i} hops > k_hops {k_hops}: their pairs are "
                    f"silently dropped"))
    # C8: brute-force parcel recount vs the searchsorted plan
    plan = halo_capacity_plan(coords, gids, mins, maxs, eps, k_hops)
    cap_exact = exact_halo_capacity(coords, gids, mins, maxs, eps, k_hops)
    for j in range(n_slabs):
        own = gids[j] >= 0
        x0 = coords[j, own, 0]
        if not x0.size:
            continue
        for h in range(1, k_hops + 1):
            checks = []
            if j - h >= 0 and np.isfinite(maxs[j - h]):
                checks.append((-1, int((x0 <= maxs[j - h] + eps).sum())))
            if j + h < n_slabs and np.isfinite(mins[j + h]):
                checks.append((+1, int((x0 >= mins[j + h] - eps).sum())))
            for direction, need in checks:
                if need > cap_exact:
                    out.append(Finding(
                        _AN, "halo-parcel", f"{tag}:{j}:{h}:{direction:+d}",
                        f"parcel slab {j} -> {j + direction * h} needs "
                        f"{need} rows > exact_halo_capacity {cap_exact}"))
    if halo_capacity is not None and plan:
        worst = max(plan, key=lambda p: p.need)
        if halo_capacity < worst.need:
            out.append(Finding(
                _AN, "halo-parcel", f"{tag}:capacity",
                f"halo_capacity {halo_capacity} < required {worst.need} "
                f"(worst parcel: slab {worst.slab} -> "
                f"{worst.slab + worst.direction * worst.hop}, hop "
                f"{worst.hop}); pass halo_capacity >= {worst.need}"))
    return out
