"""Opt-in sanitized kernel mode (``REPRO_SANITIZE=1``).

When enabled, every fused-sweep launch is accompanied by a jitted
device-side *error-code reduction* (``kernels.fused_join
.sanitize_errcodes``) over the same window descriptors and outputs the
kernel consumed/produced. The reduction stays async: per-launch codes
are queued here and only forced at the driver's existing sync points
(``PendingJoin.result``, the count->fill finish loops), so sanitize mode
adds launches but no extra host round-trips mid-pipeline.

Checked invariants (bitmask):

  E_OOB_GATHER     a window descriptor slot would gather outside the
                   padded points buffer (corrupted window start/count).
  E_CAP_OVERFLOW   a per-query candidate count exceeds the granted
                   window capacity (undersized ``cell_window_caps``).
  E_SCAN_MISMATCH  the exclusive-scan slot bases are not disjoint or
                   don't telescope to the total hit count (a slot-write
                   collision on the emit path).
  E_NONFINITE      NaN/Inf in a gathered candidate or computed distance
                   (metric mode: the check covers GEOMETRY lanes only --
                   jaccard bitmap operands are packed integer words, not
                   coordinates, and are skipped).
  E_COUNT_RANGE    a hit count outside [0, window rows] (corrupted
                   counts buffer).
  E_UNNORMALIZED   (cosine metric) a nonzero input row reached the kernel
                   with a squared norm off unity by more than
                   ``core.metric.NORM_TOL`` -- raw, un-canonicalized
                   embeddings bypassed ``metric.canonicalize``.

Trust boundary: the sanitizer recomputes with plain jnp ops (gathers,
segment sums), NOT the Pallas kernel, so a miscompiled kernel and its
checker cannot share a bug.
"""
from __future__ import annotations

import os
from typing import List, Tuple

E_OOB_GATHER = 1
E_CAP_OVERFLOW = 2
E_SCAN_MISMATCH = 4
E_NONFINITE = 8
E_COUNT_RANGE = 16
E_UNNORMALIZED = 32

_NAMES = {
    E_OOB_GATHER: "oob-gather",
    E_CAP_OVERFLOW: "cap-overflow",
    E_SCAN_MISMATCH: "scan-mismatch",
    E_NONFINITE: "nonfinite",
    E_COUNT_RANGE: "count-range",
    E_UNNORMALIZED: "unnormalized-cosine",
}

_FORCED = None              # tests: set_enabled(True/False); None -> env
_PENDING: List[Tuple[str, object]] = []


class SanitizerError(RuntimeError):
    """A sanitized launch reported a violated kernel invariant."""


def enabled() -> bool:
    if _FORCED is not None:
        return _FORCED
    return os.environ.get("REPRO_SANITIZE", "0") not in ("", "0")


def set_enabled(value) -> None:
    """Force sanitize mode on/off for tests; ``None`` restores the env."""
    global _FORCED
    _FORCED = value


def decode(code: int) -> list:
    """Bit names set in an error code, e.g. ``['oob-gather']``."""
    return [name for bit, name in sorted(_NAMES.items()) if code & bit]


def record(label: str, code) -> None:
    """Queue a launch's (still-async) error-code scalar for later raise."""
    _PENDING.append((label, code))


def pending() -> int:
    return len(_PENDING)


def clear() -> None:
    del _PENDING[:]


def raise_pending() -> None:
    """Force all queued error codes; raise on the first nonzero one.

    Called at driver sync points -- the device work is already being
    awaited there, so this adds no extra blocking in the clean case.
    """
    if not _PENDING:
        return
    queued, _PENDING[:] = _PENDING[:], []
    for label, code in queued:
        val = int(code)
        if val:
            raise SanitizerError(
                f"sanitizer: {label}: kernel invariant violated "
                f"({'+'.join(decode(val))}, code {val})")
