"""Machine-readable findings + the committed-baseline diff protocol.

A finding is one violated (or suspicious) contract instance. Its
``key`` deliberately excludes line numbers and message text: baselines
are keyed on (analyzer, rule, site) where ``site`` is a file-qualified
function name or a geometry tag, so unrelated edits that shift lines do
not churn the baseline, while a NEW occurrence of a banned pattern in a
new function is always a new key (the CI gate: new findings fail the
build, scripts/ci.sh).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Optional

SEV_ERROR = "error"
SEV_WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Finding:
    analyzer: str        # "contracts" | "lint"
    rule: str            # e.g. "cap-coverage", "per-call-jit"
    site: str            # "src/repro/core/grid.py::_pad_probe" or "index:uniform-2d"
    message: str
    severity: str = SEV_ERROR
    line: Optional[int] = None   # informational; NOT part of the key

    @property
    def key(self) -> str:
        return f"{self.analyzer}:{self.rule}:{self.site}"

    def render(self) -> str:
        loc = f"{self.site}:{self.line}" if self.line else self.site
        return f"[{self.severity}] {self.analyzer}/{self.rule} {loc}: {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def baseline_keys(findings: Iterable[Finding]) -> list:
    """Sorted unique keys -- the committed-baseline payload."""
    return sorted({f.key for f in findings})


def save_baseline(findings: Iterable[Finding], path: str) -> None:
    with open(path, "w") as fh:
        json.dump({"version": 1, "accepted": baseline_keys(findings)},
                  fh, indent=1)
        fh.write("\n")


def load_baseline(path: str) -> set:
    with open(path) as fh:
        payload = json.load(fh)
    return set(payload.get("accepted", []))


def new_findings(findings: Iterable[Finding], baseline: set) -> list:
    """Findings whose key is not accepted by the baseline."""
    return [f for f in findings if f.key not in baseline]


def report_json(findings: Iterable[Finding]) -> str:
    return json.dumps({"findings": [f.to_dict() for f in findings]}, indent=1)
