"""Static analysis for the fused join engine (DESIGN.md S9).

Three layers, all runnable without launching a single kernel:

  * ``analysis.contracts`` -- a contract prover that re-derives the
    bounded-search invariants (window capacities, slot-base arithmetic,
    halo parcels, key sentinels, VMEM footprints) from an index's
    geometry with INDEPENDENT algorithms and checks the engine's
    planners against them.
  * ``analysis.lint`` -- an AST linter over ``src/`` for the retrace and
    dtype bug classes that bit this repo historically (per-call
    ``jax.jit`` closures, host syncs under jit, hardcoded int64 key
    sentinels), plus a static no-retrace check that enumerates the
    launch shapes a request mix can produce and proves them a subset of
    ``PreparedJoin.warm``'s compiled set.
  * ``analysis.sanitize`` -- the opt-in ``REPRO_SANITIZE=1`` kernel mode:
    every fused launch is accompanied by a device-side error-code
    reduction (gather bounds, count<=capacity, exclusive-scan/slot
    disjointness, NaN/Inf) that the count->fill drivers raise on.

``python -m repro.analysis`` runs the prover + linter against the
committed findings baseline (scripts/analysis_baseline.json); CI fails
on any NEW finding.
"""
from repro.analysis.findings import (Finding, baseline_keys, load_baseline,
                                     new_findings, save_baseline)

__all__ = [
    "Finding",
    "baseline_keys",
    "load_baseline",
    "new_findings",
    "save_baseline",
]
