"""Retrace/dtype linter: AST rules + a static no-retrace shape model.

AST rules over ``src/`` (the bug classes this repo actually shipped):

  per-call-jit        a ``jax.jit`` (bare, called, or via ``partial``)
                      created INSIDE a function body. Every call of the
                      enclosing function builds a fresh jitted callable
                      whose trace cache starts empty -- the PR 2
                      ``range_query`` bug (~245 ms/request until fixed).
                      Module-level jits and decorators are fine.
  host-sync-in-jit    ``.item()`` / ``np.asarray`` (errors) and
                      ``float()``/``int()`` of a non-literal (warnings)
                      inside a jit-decorated function or its nested
                      defs: on traced values these force a blocking
                      device sync (or a tracer error at runtime).
  int64-key-literal   hardcoded int64 sentinels -- ``PAD_KEY`` reads,
                      ``iinfo(int64)`` probes, or the bare 2^63-1
                      literal. On the ``REPRO_NO_X64`` int32 key path
                      these overflow or silently never match (the PR 3
                      key-aliasing class); key code must go through
                      ``grid.pad_key_for``/``grid.key_dtype_for``.
                      Legitimate declaration sites live in the committed
                      baseline; any NEW site fails CI.
  eps-squared-predicate  a hardcoded eps-squared comparison (the radius
                      multiplied by itself, or raised to the power 2)
                      outside ``core/metric.py``. Since the
                      metric trait (DESIGN.md S12) the refine predicate
                      is owned by ``core.metric`` alone -- an inlined
                      eps-squared comparison silently reverts that site
                      to L2 for every metric (a cosine or jaccard join
                      routed through it returns L2 answers). Use
                      ``metric_lib.eps_squared`` / ``l2_sq_hits`` /
                      ``tile_refine_hits`` instead.

Static no-retrace check (``check_no_retrace``): enumerates, by pure
``bucket_rows``/capacity-class arithmetic, every fused-launch executable
a canned request mix can demand and proves it a subset of what
``PreparedJoin.warm`` compiles for the warmed size ladder -- the
compile-time complement of ``serve.assert_no_retrace`` (which can only
catch a retrace after it already happened in production).
"""
from __future__ import annotations

import ast
import os
import re
from typing import Iterable, Optional

from repro.analysis.findings import SEV_WARNING, Finding

_AN = "lint"
RULE_JIT = "per-call-jit"
RULE_SYNC = "host-sync-in-jit"
RULE_I64 = "int64-key-literal"
RULE_EPS = "eps-squared-predicate"

# the one module allowed to spell the squared-threshold arithmetic: the
# metric trait that owns every refine predicate (DESIGN.md S12)
_EPS_OWNER = "core/metric.py"

_I64_MAX = (1 << 63) - 1          # spelled as a shift so we don't self-flag
_NP_NAMES = ("np", "numpy", "jnp")


def _is_jit_ref(node) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "jit":
        return True
    return isinstance(node, ast.Name) and node.id == "jit"


def _is_partial_ref(node) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "partial":
        return True
    return isinstance(node, ast.Name) and node.id == "partial"


def _is_jit_maker(node) -> bool:
    """A Call expression that creates a jitted callable."""
    if not isinstance(node, ast.Call):
        return False
    if _is_jit_ref(node.func):
        return True
    return (_is_partial_ref(node.func)
            and any(_is_jit_ref(a) for a in node.args))


def _decorator_is_jit(dec) -> bool:
    return _is_jit_ref(dec) or _is_jit_maker(dec)


def _is_int64_ref(node) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "int64":
        return True
    return isinstance(node, ast.Name) and node.id == "int64"


_EPS_IDENT = re.compile(r"(?:^|_)eps")   # eps, eps_geom, metric_eps; NOT steps


def _is_eps_ref(node) -> bool:
    """A Name/Attribute whose terminal identifier is an epsilon: 'eps',
    'eps_geom', 'self.eps', 'index.metric_eps', ... The 'eps' token must
    start the identifier or a ``_``-separated word of it, so 'steps' and
    'depth_steps' do not flag."""
    if isinstance(node, ast.Attribute):
        return bool(_EPS_IDENT.search(node.attr.lower()))
    return isinstance(node, ast.Name) and bool(_EPS_IDENT.search(node.id.lower()))


def _is_eps_square(node) -> bool:
    """The banned squaring shapes: an eps reference multiplied by the
    SAME eps reference, or an eps reference raised to the power 2."""
    if not isinstance(node, ast.BinOp):
        return False
    if isinstance(node.op, ast.Mult):
        return (_is_eps_ref(node.left) and _is_eps_ref(node.right)
                and ast.dump(node.left) == ast.dump(node.right))
    if isinstance(node.op, ast.Pow):
        return (_is_eps_ref(node.left)
                and isinstance(node.right, ast.Constant)
                and node.right.value == 2)
    return False


class _Linter(ast.NodeVisitor):
    def __init__(self, relpath: str):
        self.relpath = relpath
        self.stack: list = []        # enclosing class/function names
        self.func_depth = 0
        self.jit_depth = 0           # > 0: inside a jit-decorated def
        self.skip: set = set()       # decorator node ids (not per-call jits)
        self.findings: list = []

    def _qual(self) -> str:
        return ".".join(self.stack) if self.stack else "<module>"

    def _site(self) -> str:
        return f"{self.relpath}::{self._qual()}"

    def _add(self, rule: str, message: str, node, severity: str = "error"):
        self.findings.append(Finding(
            _AN, rule, self._site(), message, severity=severity,
            line=getattr(node, "lineno", None)))

    # -- scopes -------------------------------------------------------------

    def visit_ClassDef(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def _visit_func(self, node):
        jitted = any(_decorator_is_jit(d) for d in node.decorator_list)
        for d in node.decorator_list:
            for sub in ast.walk(d):
                self.skip.add(id(sub))
        if self.func_depth > 0 and jitted:
            self._add(RULE_JIT,
                      f"per-call @jax.jit: '{node.name}' is traced and "
                      f"compiled fresh on every call of "
                      f"'{self._qual()}' (hoist to module level or cache "
                      f"the jitted callable)", node)
        self.stack.append(node.name)
        self.func_depth += 1
        self.jit_depth += 1 if (jitted or self.jit_depth) else 0
        # decorators were evaluated in the ENCLOSING scope; still walk them
        # for int64 literals etc.
        for d in node.decorator_list:
            self.visit(d)
        for item in node.body:
            self.visit(item)
        if jitted or self.jit_depth:
            self.jit_depth -= 1 if self.jit_depth else 0
        self.func_depth -= 1
        self.stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    # -- rules --------------------------------------------------------------

    def visit_Call(self, node):
        if (self.func_depth > 0 and id(node) not in self.skip
                and _is_jit_maker(node)):
            self._add(RULE_JIT,
                      "jax.jit called inside a function body: the "
                      "resulting callable's trace cache is rebuilt per "
                      "call (hoist to module level or cache it)", node)
        if self.jit_depth > 0:
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr == "item":
                self._add(RULE_SYNC,
                          ".item() inside a jitted function blocks on the "
                          "device (or fails on a tracer)", node)
            elif (isinstance(f, ast.Attribute) and f.attr == "asarray"
                  and isinstance(f.value, ast.Name)
                  and f.value.id in ("np", "numpy")):
                self._add(RULE_SYNC,
                          "np.asarray inside a jitted function forces a "
                          "host sync of a traced value", node)
            elif (isinstance(f, ast.Name) and f.id in ("float", "int")
                  and node.args
                  and not isinstance(node.args[0], ast.Constant)):
                self._add(RULE_SYNC,
                          f"{f.id}() of a non-literal inside a jitted "
                          f"function syncs if the value is traced",
                          node, severity=SEV_WARNING)
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr == "iinfo"
                and any(_is_int64_ref(a) for a in node.args)):
            self._add(RULE_I64,
                      "iinfo(int64) sentinel: breaks the int32 key fast "
                      "path (REPRO_NO_X64); derive sentinels via "
                      "grid.pad_key_for(index.key_dtype)", node)
        self.generic_visit(node)

    def visit_Name(self, node):
        if node.id == "PAD_KEY" and isinstance(node.ctx, ast.Load):
            self._add(RULE_I64,
                      "PAD_KEY is the int64-max sentinel: on int32-keyed "
                      "grids it overflows/never matches; use "
                      "grid.pad_key_for(index.key_dtype)", node)
        self.generic_visit(node)

    def visit_Constant(self, node):
        if node.value == _I64_MAX and isinstance(node.value, int):
            self._add(RULE_I64,
                      "bare 2^63-1 literal used as a key sentinel", node)
        self.generic_visit(node)

    def visit_BinOp(self, node):
        if (_is_eps_square(node)
                and not self.relpath.endswith(_EPS_OWNER)):
            self._add(RULE_EPS,
                      "hardcoded eps-squared predicate outside "
                      "core/metric.py: the refine threshold is owned by "
                      "the metric trait (metric_lib.eps_squared / "
                      "l2_sq_hits / tile_refine_hits); an inlined square "
                      "silently evaluates L2 for every metric", node)
        self.generic_visit(node)


def lint_source(text: str, relpath: str) -> list:
    """Lint one module's source text; findings carry ``relpath`` sites."""
    tree = ast.parse(text, filename=relpath)
    linter = _Linter(relpath)
    linter.visit(tree)
    return linter.findings


def lint_paths(paths: Iterable[str], root: Optional[str] = None) -> list:
    out = []
    for path in paths:
        rel = os.path.relpath(path, root) if root else path
        with open(path) as fh:
            out.extend(lint_source(fh.read(), rel.replace(os.sep, "/")))
    return out


def lint_tree(root: str = "src") -> list:
    """Lint every ``.py`` under ``root`` (sites relative to its parent)."""
    paths = []
    for dirpath, _, files in os.walk(root):
        for name in sorted(files):
            if name.endswith(".py"):
                paths.append(os.path.join(dirpath, name))
    base = os.path.dirname(os.path.abspath(root))
    return lint_paths(sorted(paths), root=base)


# ---------------------------------------------------------------------------
# static no-retrace check (shape-space model of PreparedJoin.warm)
# ---------------------------------------------------------------------------

def fused_launch_keys(pj, size: int, keep: bool) -> set:
    """Every fused-sweep executable key a request of ``size`` queries can
    demand from ``pj``: (capacity, tile, padded rows, keep_hits). On a
    bucketed index the per-class row split is data-dependent, but its
    SHAPE space is the pow2 tile ladder bounded by the request bucket --
    the same enumeration ``PreparedJoin.warm``'s ladder loop compiles."""
    from repro.core.query_join import bucket_rows

    qp = bucket_rows(size)
    keys = set()
    if not pj.bucketed:
        tile = pj.tiles[pj.c]
        keys.add((pj.c, tile, qp, keep))
        return keys
    for cb in pj.classes:
        tile = pj.tiles[cb]
        s = tile
        while s <= bucket_rows(qp, tile):
            keys.add((cb, tile, s, keep))
            s *= 2
    return keys


def warmed_launch_keys(pj, warm_sizes: Iterable[int],
                       keep_variants=(True, False)) -> set:
    """The executable set ``PreparedJoin.warm(n)`` compiles for each
    warmed size: the request-bucket launch (single-class indexes) plus
    the full (class, pow2-size) ladder (bucketed indexes)."""
    keys = set()
    for n in warm_sizes:
        for keep in keep_variants:
            keys |= fused_launch_keys(pj, int(n), keep)
    return keys


def check_no_retrace(pj, *, max_batch: int, request_sizes: Iterable[int],
                     warm_sizes: Optional[Iterable[int]] = None,
                     keep_variants=(True, False),
                     tag: str = "prepared") -> list:
    """Prove a canned request mix cannot out-trace the warm set.

    ``warm_sizes=None`` models the batching service's full pow2 ladder up
    to ``max_batch`` (launch/serve.py ``BatchingJoinService.warmup``); an
    explicit list models a fixed-size ``JoinService.warmup``. Findings
    name every executable the mix demands that warm never compiled --
    each one is a steady-state trace+compile on the request path."""
    from repro.core.query_join import bucket_rows

    if warm_sizes is None:
        warm_sizes, s = [], bucket_rows(1)
        while s <= bucket_rows(max_batch):
            warm_sizes.append(s)
            s *= 2
    warmed = warmed_launch_keys(pj, warm_sizes, keep_variants)
    out = []
    for m in request_sizes:
        for keep in keep_variants:
            missing = sorted(fused_launch_keys(pj, int(m), keep) - warmed)
            if missing:
                out.append(Finding(
                    _AN, "static-retrace", f"{tag}:q{int(m)}:keep={keep}",
                    f"request of {int(m)} queries demands un-warmed "
                    f"executables {missing}: each is a steady-state "
                    f"trace+compile (warm sizes {sorted(warm_sizes)})"))
    return out


def count_distinct_lowerings(pj, sizes: Iterable[int],
                             keep_variants=(True, False)) -> int:
    """Distinct fused-sweep lowerings a request mix compiles in total --
    the number ``executable_cache_stats`` would report for the sweep."""
    keys = set()
    for m in sizes:
        for keep in keep_variants:
            keys |= fused_launch_keys(pj, int(m), keep)
    return len(keys)
