"""Fault-tolerant checkpointing: sharded npz + atomic manifest + elastic.

Layout:  <dir>/step_<N>/
             manifest.json      tree structure, leaf -> file map, shapes
             leaf_<i>.npy       one file per leaf (streams well at scale)
         <dir>/step_<N>.tmp/    staging; atomically renamed on completion

Guarantees:
  * atomicity -- a step directory either fully exists (rename is atomic on
    POSIX) or is garbage-collected staging; readers only trust renamed dirs
    with a manifest whose 'complete' flag is set;
  * elastic restore -- leaves are stored as full logical arrays and re-placed
    with jax.device_put against the *current* mesh/spec, so a job restarted
    on a different device count resumes bit-exact (tests/test_ckpt.py);
  * async -- save() optionally snapshots to host (blocking only on D2H) and
    writes on a background thread; wait() joins before the next save.
  * retention -- keep_last_k garbage collection.

On real multi-host TPU, each host writes only the shards it owns
(process-local addressable shards); on this single-process container that
degenerates to host 0 writing everything, which is the same code path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, jax.tree.structure(tree)


def save_checkpoint(directory: str, step: int, tree: Any, *,
                    extra: Optional[dict] = None) -> str:
    """Blocking save. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names, leaves, _ = _tree_paths(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {},
                "complete": False}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {"name": name, "file": fn, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    manifest["complete"] = True
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            man = os.path.join(directory, d, "manifest.json")
            if os.path.exists(man):
                try:
                    with open(man) as f:
                        if json.load(f).get("complete"):
                            steps.append(int(d.split("_")[1]))
                except (ValueError, json.JSONDecodeError):
                    continue
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any, *,
                       mesh=None, specs: Any = None) -> Any:
    """Restore into the structure of ``like``; reshard onto ``mesh``/specs.

    ``like`` may be a pytree of arrays or ShapeDtypeStructs. When mesh+specs
    are given, every leaf is device_put with NamedSharding -- this is the
    elastic path: the stored arrays are logical (unsharded), so any mesh
    shape works as long as the specs divide.
    """
    from jax.sharding import NamedSharding

    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["complete"], f"incomplete checkpoint at {path}"
    names, like_leaves, treedef = _tree_paths(like)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    out = []
    if specs is not None:
        spec_leaves = treedef.flatten_up_to(specs)
    else:
        spec_leaves = [None] * len(like_leaves)
    for name, leaf, spec in zip(names, like_leaves, spec_leaves):
        e = by_name[name]
        arr = np.load(os.path.join(path, e["file"]))
        if arr.dtype.kind == "V":
            # np.save round-trips ml_dtypes (bf16 etc.) as raw void bytes;
            # reinterpret using the dtype recorded in the manifest.
            arr = arr.view(_np_dtype(e["dtype"]))
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        if arr.dtype != want_dtype:
            arr = np.asarray(jax.numpy.asarray(arr).astype(want_dtype))
        if mesh is not None and spec is not None:
            out.append(jax.device_put(arr, NamedSharding(mesh, spec)))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Async saves + retention. One in-flight save at a time."""

    def __init__(self, directory: str, keep_last_k: int = 3):
        self.directory = directory
        self.keep = keep_last_k
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        # snapshot on the caller thread (D2H), write on the background thread
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra=extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
