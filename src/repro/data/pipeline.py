"""Deterministic synthetic token pipeline.

Every (step, host) pair maps to an independent Philox stream, so:
  * restarts resume mid-epoch exactly (the step index is the only state),
  * elastic re-sharding keeps per-example streams stable (examples are keyed
    by global example id, not by host),
  * no host reads another host's shard (scales to any host count).

Optionally applies the paper's self-join near-duplicate filter per batch
(data/dedup.py): duplicates are *replaced* by fresh samples drawn from a
reserve stream so the global batch size stays static for jit.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int            # global batch (examples per step)
    seq: int
    seed: int = 0
    dedup: bool = False
    dedup_eps: float = 0.05
    input_kind: str = "tokens"
    d_model: int = 0      # for embeddings input_kind

    def _rng(self, step: int, salt: int = 0):
        key = (self.seed << 32) ^ (salt << 16) ^ 0xD5
        return np.random.Generator(np.random.Philox(key=key, counter=step))

    def batch_at(self, step: int) -> dict:
        """Global batch for ``step`` (host-sliced by the caller if needed)."""
        rng = self._rng(step)
        if self.input_kind == "embeddings":
            emb = rng.normal(size=(self.batch, self.seq, self.d_model))
            labels = rng.integers(0, self.vocab, (self.batch, self.seq))
            return {"embeds": emb.astype(np.float32),
                    "labels": labels.astype(np.int32)}
        # zipfian-ish marginals make the loss non-degenerate
        z = rng.zipf(1.3, size=(self.batch, self.seq))
        tokens = (z % self.vocab).astype(np.int32)
        if self.dedup:
            tokens = self._dedup(tokens, step)
        labels = np.roll(tokens, -1, axis=1).astype(np.int32)
        labels[:, -1] = -1  # masked
        return {"tokens": tokens, "labels": labels}

    def _dedup(self, tokens: np.ndarray, step: int) -> np.ndarray:
        from repro.data.dedup import dedup_batch

        keep = dedup_batch(tokens, eps=self.dedup_eps)
        n_dup = int((~keep).sum())
        if n_dup:
            reserve = self._rng(step, salt=1)
            z = reserve.zipf(1.3, size=(n_dup, self.seq))
            tokens = tokens.copy()
            tokens[~keep] = (z % self.vocab).astype(np.int32)
        return tokens

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
