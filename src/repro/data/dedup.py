"""Near-duplicate removal via the paper's epsilon self-join.

This is the framework's first-class integration of the paper's technique
(DESIGN.md SArch-applicability): documents are embedded into a *low
dimensional* space (n-gram count sketch -> random projection to 2-6 D,
exactly the dimensionality regime the paper targets), then a distance
similarity self-join with radius eps finds all near-duplicate pairs, and one
element of every pair is dropped (lowest-id survivor, union-find over join
pairs so duplicate *clusters* keep exactly one representative).

The join is the GPU-SJ algorithm: grid index + UNICOMP + batched result
(core/selfjoin.py), i.e. the data pipeline literally runs the paper's
contribution on every batch.
"""
from __future__ import annotations

import numpy as np

from repro.core.selfjoin import self_join


def embed_ngrams(tokens: np.ndarray, n_dims: int = 4, n: int = 2,
                 n_hash: int = 64, seed: int = 1234) -> np.ndarray:
    """(B, S) int tokens -> (B, n_dims) float64 document sketch.

    Hashed n-gram counts (n_hash buckets, L2-normalized) followed by a fixed
    Gaussian random projection to n_dims. Near-identical documents land
    within a small epsilon of each other; unrelated ones do not.
    """
    B, S = tokens.shape
    t = tokens.astype(np.int64)
    grams = t[:, : S - n + 1].copy()
    for k in range(1, n):
        grams = grams * 1000003 + t[:, k : S - n + 1 + k]
    buckets = (grams % n_hash).astype(np.int64)
    counts = np.zeros((B, n_hash), np.float64)
    rows = np.repeat(np.arange(B), buckets.shape[1])
    np.add.at(counts, (rows, buckets.reshape(-1)), 1.0)
    norms = np.linalg.norm(counts, axis=1, keepdims=True)
    counts /= np.maximum(norms, 1e-12)
    proj = np.random.Generator(np.random.Philox(key=seed)).normal(
        size=(n_hash, n_dims)) / np.sqrt(n_dims)
    return counts @ proj


def _keep_from_pairs(n: int, pairs: np.ndarray) -> np.ndarray:
    """Union-find over join pairs -> keep-mask: each duplicate cluster
    keeps its lowest-id representative (chains a~b~c keep exactly one)."""
    keep = np.ones(n, bool)
    if pairs.shape[0] == 0:
        return keep
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in pairs:
        ra, rb = find(int(a)), find(int(b))
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)
    for i in range(n):
        if find(i) != i:
            keep[i] = False
    return keep


def dedup_batch(tokens: np.ndarray, *, eps: float = 0.05, n_dims: int = 4,
                unicomp: bool = True) -> np.ndarray:
    """Boolean keep-mask over the batch; duplicate clusters keep one doc."""
    emb = embed_ngrams(tokens, n_dims=n_dims)
    pairs = self_join(emb, eps, unicomp=unicomp)
    return _keep_from_pairs(tokens.shape[0], pairs)


def guard_embeddings(emb: np.ndarray) -> np.ndarray:
    """Boolean mask of rows safe to canonicalize for the cosine join:
    finite in every lane AND nonzero norm. A failed encoder emits exactly
    these rows (all-zero on a timeout, NaN on an overflow), and
    ``metric.canonicalize(..., metric='cosine')`` rejects them by design
    -- cosine similarity is undefined at the origin. The pipeline
    quarantines them instead of crashing the batch."""
    emb = np.asarray(emb)
    finite = np.isfinite(emb).all(axis=1)
    norms = np.where(finite, np.abs(emb).sum(axis=1), 0.0)
    return finite & (norms > 0.0)


def dedup_embeddings(emb: np.ndarray, *, min_cos: float = 0.98,
                     unicomp: bool = True):
    """Cosine near-duplicate removal over raw embedding rows.

    Returns ``(keep, valid)`` boolean masks: ``valid`` marks rows the
    zero-vector/NaN guard admitted to the join; invalid rows are KEPT
    (their similarity is unknowable, dropping data on an encoder glitch
    is worse) but quarantined from the join and flagged ``valid=False``
    so the caller can retry their encode. Among valid rows, every
    cluster with pairwise cosine similarity >= ``min_cos`` keeps its
    lowest-id representative -- the join runs the metric-trait cosine
    path (DESIGN.md S12): unit-normalize, then the paper's grid
    self-join at the equivalent chord radius."""
    emb = np.asarray(emb, np.float64)
    valid = guard_embeddings(emb)
    keep = np.ones(emb.shape[0], bool)
    idx = np.flatnonzero(valid)
    if idx.size:
        pairs = self_join(emb[idx], float(min_cos), unicomp=unicomp,
                          metric="cosine")
        keep_valid = _keep_from_pairs(idx.size, pairs)
        keep[idx] = keep_valid
    return keep, valid
