"""Data substrate: synthetic token pipeline + self-join dedup operator."""
from repro.data.pipeline import TokenPipeline
from repro.data.dedup import dedup_batch, embed_ngrams

__all__ = ["TokenPipeline", "dedup_batch", "embed_ngrams"]
