"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel body
runs as traced jnp ops); on a TPU backend they compile to Mosaic. The
``interpret`` decision is made once at import from the default backend, and
f64 inputs (the paper's precision, unsupported by the MXU) are computed in
f32 on TPU -- documented hardware adaptation, validated in tests against the
f64 oracle with f32 tolerances.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis import sanitize as _sanitize
from repro.kernels import cell_join as _cell_join
from repro.kernels import distance_tile as _distance_tile
from repro.kernels import fused_join as _fused_join

_INTERPRET = jax.default_backend() != "tpu"


def _kernel_dtype(dtype):
    if not _INTERPRET and dtype == jnp.float64:
        return jnp.float32  # TPU has no f64; paper precision kept on CPU path
    return dtype


def distance_tile_hits(q, pts, eps):
    """Brute-force tile: (TQ,n) x (N,n) -> (TQ,N) bool epsilon-hits."""
    dt = _kernel_dtype(q.dtype)
    return _distance_tile.distance_tile_hits(
        q.astype(dt), pts.astype(dt), eps, interpret=_INTERPRET
    )


def distance_tile_counts(pts, eps, *, tq: int = 256, tc: int = 256):
    """Fused brute-force per-point neighbor counts (excl. self)."""
    dt = _kernel_dtype(pts.dtype)
    return _distance_tile.distance_tile_counts(
        pts.astype(dt), eps, tq=tq, tc=tc, interpret=_INTERPRET
    )


def cell_join_hits(q, cand, valid, eps):
    """Grid-cell refine: (B,n) x (B,C,n) x (B,C) -> (B,C) bool."""
    dt = _kernel_dtype(q.dtype)
    return _cell_join.cell_join_hits(
        q.astype(dt), cand.astype(dt), valid, eps, interpret=_INTERPRET
    )


def fused_join_hits(points_pad, q_batch, win_start, win_count, is_zero,
                    q_pos, eps, *, c, n_real, unicomp, external=False,
                    merged=False, gid_pairs=False,
                    tq=_fused_join.TQ_DEFAULT, keep_hits=True,
                    run_ord=None, run_loop=False, method=None,
                    metric="l2", n_feat=0):
    """Fused gather-refine sweep (all offsets, one launch) -> hits/counts.

    ``q_pos`` is the (Q_pad,) per-row sorted-position array (zeros for
    external queries). method=None dispatches the Mosaic kernel on TPU and
    the identical reference lowering elsewhere; tests force method='kernel'
    to exercise the Pallas path through the interpreter. ``external=True``
    serves queries that are not members of the indexed set
    (core/query_join.py). ``merged=True`` consumes merged last-dimension
    range windows (DESIGN.md S7; lane ``n_real`` carries cell coordinates
    -- exact small integers, so the TPU f32 downcast is lossless).
    ``gid_pairs=True`` rides GLOBAL point ids in the next pad lane and
    masks pairs by gid instead of sorted position (distributed slab join,
    DESIGN.md S3; ids < 2^24, exact in f32). ``run_loop=True`` with a
    ``run_ord`` plan (grid.cell_run_plan) enables the cell-run DMA dedup
    (DESIGN.md S11): one window gather per run of co-located query rows.
    ``metric``/``n_feat`` (DESIGN.md S12) select the static refine
    predicate (core/metric.py) and the feature-lane layout.
    """
    dt = _kernel_dtype(points_pad.dtype)
    pts, qb = points_pad.astype(dt), q_batch.astype(dt)
    out = _fused_join.fused_join_hits(
        pts, qb, win_start, win_count,
        is_zero, q_pos, eps, c=c, n_real=n_real, unicomp=unicomp,
        external=external, merged=merged, gid_pairs=gid_pairs, tq=tq,
        keep_hits=keep_hits, run_ord=run_ord, run_loop=run_loop,
        method=method, interpret=_INTERPRET, metric=metric, n_feat=n_feat,
    )
    if _sanitize.enabled():
        hits, counts, base = out
        code = _fused_join.sanitize_errcodes(
            pts, qb, jnp.asarray(win_start, jnp.int32),
            jnp.asarray(win_count, jnp.int32), counts, base, hits,
            c=c, tq=tq, check_hits=keep_hits, metric=metric, n_real=n_real)
        _sanitize.record(
            f"fused_join[c={c},tq={tq},merged={merged},ext={external},"
            f"metric={metric}]",
            code)
    return out


def fused_window_hits(points_sorted, q, cand_pos, valid, eps):
    """Gather-free refine for the compacted sweep: positions, not coords."""
    dt = _kernel_dtype(q.dtype)
    return _fused_join.fused_window_hits(
        points_sorted.astype(dt), q.astype(dt), cand_pos, valid, eps
    )
