"""Pallas TPU kernels for the join's compute hot-spots.

distance_tile.py -- brute-force / refine tile (MXU formulation), count+hits
cell_join.py     -- per-cell gathered-candidate refine (VPU formulation)
fused_join.py    -- fused gather-refine sweep (scalar-prefetch windows,
                    in-kernel HBM->VMEM gather, count + fill slot scan)
ops.py           -- jit'd wrappers (interpret on CPU, Mosaic on TPU)
ref.py           -- pure-jnp oracles (tests assert allclose against these)
"""
from repro.kernels.ops import (
    cell_join_hits,
    distance_tile_counts,
    distance_tile_hits,
    fused_join_hits,
    fused_window_hits,
)

__all__ = [
    "cell_join_hits",
    "distance_tile_counts",
    "distance_tile_hits",
    "fused_join_hits",
    "fused_window_hits",
]
