"""Measured tile + route autotuning for the fused join (DESIGN.md S6).

Two hard-coded decisions of the pre-S6 code are replaced by a measured,
persisted table:

  * the fused kernel's query tile ``TQ`` was a global constant (128). The
    right tile depends on the backend, the dimensionality, and -- with
    occupancy bucketing -- the bucket's window capacity ``C`` (a C=64
    bucket holds 8x the VMEM per row of a C=8 bucket). ``fused_tile``
    returns the tile for a (backend, n_dims, C) class, timing the
    candidate tiles ONCE on a synthetic descriptor workload when
    measurement is enabled, and caching the winner.
  * ``self_join_count``'s dense-vs-compact routing was a TPU-gated density
    heuristic. ``count_route`` folds it into a single table: a cached
    measured winner per workload class when available, a measured pass
    over the live candidates when tuning is enabled, and the (extended)
    occupancy heuristic otherwise. Candidate routes now include 'sparse'
    (the probe-compacted counter for the empty-neighbor regime) and 'jnp'
    (the reference dense counter), so routing can never be forced into a
    fused plan that measures slower than the baseline: the chosen route is
    logged in ``JoinStats.route``. Since the merged-range sweep
    (DESIGN.md S7) the SWEEP is a routed axis too: merged classes admit
    'dense-flat'/'sparse-flat' candidates (the per-cell 3^n sweep, which
    can beat merging on heavily co-occupied low-dimensional data), and
    the pair-emitting join follows a cached 'dense-flat' verdict (the
    one candidate pair that measures its own sweep).

The cache is a small JSON file. Resolution order: ``$REPRO_AUTOTUNE_CACHE``
if set, else ``autotune_cache.json`` next to this module (a pre-measured
table for this container's backend ships with the repo). Measurement is
enabled by ``$REPRO_AUTOTUNE=1`` (benchmarks/bench_selfjoin.py sets it) or
an explicit ``measure=True``; without it, cache misses fall back to
deterministic defaults so tests and production paths never pay a timing
pass they did not ask for. Writes are atomic and best-effort (a read-only
install keeps the table in memory only).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Optional

import numpy as np

DEFAULT_TQ = 128
TQ_CANDIDATES = (64, 128, 256)
_ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
_ENV_MEASURE = "REPRO_AUTOTUNE"
# Cache schema version, stored under "__schema__" in the JSON file. Bump
# when the meaning of a key class changes so stale measurements invalidate
# wholesale instead of silently steering new code. v2: merged-range sweep
# (DESIGN.md S7) -- tile entries are keyed on MERGED window capacities and
# route entries carry the sweep mode, so every v1 entry (per-cell
# capacities/offset counts) is stale. v3: cell-run DMA dedup (DESIGN.md
# S11) adds the 'dense-run' candidate to the measured route table; v2
# winners never raced it, so they must be re-measured.
SCHEMA_VERSION = 3


def cache_path() -> str:
    return os.environ.get(_ENV_CACHE) or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "autotune_cache.json")


def measure_enabled() -> bool:
    return os.environ.get(_ENV_MEASURE, "").lower() in ("1", "true", "yes")


class _Cache:
    """Lazy-loaded JSON key -> entry store with best-effort persistence."""

    def __init__(self):
        self._data: Optional[dict] = None
        self._path: Optional[str] = None

    def _load(self) -> dict:
        path = cache_path()
        if self._data is None or path != self._path:
            self._path = path
            try:
                with open(path) as f:
                    self._data = json.load(f)
            except (OSError, ValueError):
                self._data = {}
            if self._data.get("__schema__") != SCHEMA_VERSION:
                # stale schema: discard every entry (measurements made
                # against a different key semantics must not steer)
                self._data = {"__schema__": SCHEMA_VERSION}
        return self._data

    def get(self, key: str):
        return self._load().get(key)

    def put(self, key: str, entry: dict) -> None:
        data = self._load()
        data["__schema__"] = SCHEMA_VERSION
        data[key] = entry
        try:
            tmp = self._path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(data, f, indent=1, sort_keys=True)
            os.replace(tmp, self._path)
        except OSError:
            pass  # read-only install: keep the entry in memory only

    def reset(self) -> None:  # test hook
        self._data = None


_CACHE = _Cache()


def _backend(backend: Optional[str]) -> str:
    if backend is not None:
        return backend
    import jax

    return jax.default_backend()


def _pow2_class(x: float) -> int:
    """Coarse pow2 bucketing for cache keys (1, 2, 4, ...; min 1)."""
    v = 1
    while v < x:
        v *= 2
    return v


# ---------------------------------------------------------------------------
# Query-tile (TQ) selection
# ---------------------------------------------------------------------------

def metric_class(metric: str) -> str:
    """The metric's autotune table class (DESIGN.md S12). Cosine ALIASES
    the l2 rows: its traced computation is exactly the L2 one (the static
    tag only keys executables), so l2 measurements steer it correctly.
    Jaccard's popcount predicate has different arithmetic intensity and
    extra feature-lane traffic, so it keys its own rows."""
    return "l2" if metric in ("l2", "cosine") else metric


def tile_key(backend: str, n_dims: int, c: int, metric: str = "l2") -> str:
    mc = metric_class(metric)
    suffix = "" if mc == "l2" else f"/{mc}"
    return f"tile/{backend}/{n_dims}d/c{c}{suffix}"


def fused_tile(n_dims: int, c: int, *, backend: Optional[str] = None,
               measure: Optional[bool] = None, metric: str = "l2") -> int:
    """Query tile for a fused launch of window capacity ``c``.

    Cached measurement per (backend, n_dims, c, metric class);
    ``DEFAULT_TQ`` on a cache miss with measurement disabled. Jaccard
    classes never measure here (the synthetic workload below exercises the
    L2 predicate, which would mislabel a jaccard row): they return a cache
    hit or the default.
    """
    backend = _backend(backend)
    key = tile_key(backend, int(n_dims), int(c), metric)
    entry = _CACHE.get(key)
    if entry is not None:
        return int(entry["tq"])
    if measure is None:
        measure = measure_enabled()
    if not measure or metric_class(metric) == "jaccard":
        return DEFAULT_TQ
    tq, timings = _measure_fused_tile(n_dims, int(c))
    _CACHE.put(key, {"tq": tq, "ms": timings})
    return tq


def _measure_fused_tile(n_dims: int, c: int, *, qp: int = 1024,
                        npts: int = 4096, trials: int = 3):
    """Time the candidate tiles on a synthetic descriptor workload.

    Windows and queries are random but FIXED across candidates, so the
    comparison isolates the tile; keep_hits=False keeps the measurement on
    the count path (the fill pass is dominated by the same sweep).
    """
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.fused_join import NP_PAD

    n_off = min(3 ** n_dims, 27)
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.uniform(0, 1, (npts + c, NP_PAD)))
    qb = pts[:qp]
    ws = jnp.asarray(rng.integers(0, npts, (n_off, qp)), jnp.int32)
    wc = jnp.asarray(rng.integers(0, c + 1, (n_off, qp)), jnp.int32)
    iz = np.zeros(n_off, np.int32)
    iz[0] = 1
    iz = jnp.asarray(iz)
    qpos = jnp.arange(qp, dtype=jnp.int32)
    timings = {}
    for tq in TQ_CANDIDATES:
        if qp % tq:
            continue

        def run(tq=tq):
            _, counts, _ = ops.fused_join_hits(
                pts, qb, ws, wc, iz, qpos, 0.05, c=c, n_real=n_dims,
                unicomp=True, tq=tq, keep_hits=False)
            return np.asarray(counts)

        run()  # compile, excluded
        best = min(_timed(run) for _ in range(trials))
        timings[str(tq)] = 1000 * best
    winner = min(timings, key=timings.get)
    return int(winner), timings


def _timed(fn: Callable) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# ---------------------------------------------------------------------------
# Count-route table
# ---------------------------------------------------------------------------

def route_key(backend: str, n_dims: int, n_off: int, c_class: int,
              live_class: int, merged: bool = False,
              metric: str = "l2") -> str:
    sweep = "merged" if merged else "flat"
    mc = metric_class(metric)
    suffix = "" if mc == "l2" else f"/{mc}"
    return (f"route/{backend}/{n_dims}d/off{n_off}/c{c_class}"
            f"/live{live_class}/{sweep}{suffix}")


def route_heuristic(backend: str, n_dims: int, n_off: int, c: int,
                    occupancy: float, live_frac: float,
                    merged: bool = False) -> str:
    """The deterministic fallback when no measurement is cached.

    TPU keeps the PR-2 rule (window-DMA traffic binds -> compact in the
    empty-neighbor regime). Off-TPU the per-offset packing sort made
    'compact' lose everywhere (EXPERIMENTS.md SServe note); the
    probe-compacted 'sparse' counter replaces it there: one flat
    compaction over the whole (offset, query) plane, worth it only when
    nearly all dense window slots are padding.

    ``merged``: ``n_off`` is the reduced 3^(n-1) count while ``c`` and
    ``live_frac`` remain per-cell workload features, so the dense-slot-
    volume products scale n_off back up by the 3 merged cells -- the
    regime boundaries describe the DATA and must not move with the sweep.
    """
    vol = n_off * (3 if merged else 1)
    if backend == "tpu":
        if vol * occupancy < 3.0 and vol * c >= 256:
            return "compact"
        return "dense"
    if live_frac < 0.06 and vol * c >= 512:
        return "sparse"
    return "dense"


def count_route(*, n_dims: int, n_off: int, c: int, occupancy: float,
                live_frac: float, backend: Optional[str] = None,
                merged: bool = False, candidates: Optional[dict] = None,
                measure: Optional[bool] = None,
                metric: str = "l2") -> tuple:
    """Route for ``self_join_count(distance_impl='fused')``.

    Returns ``(route, source)`` with source in {'cache', 'measured',
    'heuristic', 'forced'}. ``candidates`` maps route name -> zero-arg
    callable running that counter on the live workload; when measurement
    is enabled they are each warmed once and timed (best of 2), and the
    winner is cached under the workload's class key -- the "measured
    routing table" that replaces the density heuristic wherever it has
    been populated. ``merged`` marks (and keys) the merged-range sweep:
    its candidates run merged counters, so its measurements live in
    separate table rows.

    ``metric`` keys the table per ``metric_class``: cosine rides the l2
    rows (same traced computation), while jaccard is FORCED onto the
    fused dense sweep -- the compact/sparse/jnp counters evaluate the L2
    predicate and cannot race a bitmap workload.
    """
    backend = _backend(backend)
    if metric_class(metric) == "jaccard":
        return "dense", "forced"
    key = route_key(backend, int(n_dims), int(n_off),
                    _pow2_class(c), _pow2_class(live_frac * n_off),
                    merged, metric)
    entry = _CACHE.get(key)
    if entry is not None:
        return str(entry["route"]), "cache"
    if measure is None:
        measure = measure_enabled()
    if measure and candidates:
        timings = {}
        for name, fn in candidates.items():
            fn()  # warm: compile time must not decide the route
            timings[name] = 1000 * min(_timed(fn), _timed(fn))
        winner = min(timings, key=timings.get)
        _CACHE.put(key, {"route": winner, "ms": timings})
        return winner, "measured"
    return route_heuristic(backend, n_dims, n_off, c, occupancy,
                           live_frac, merged), "heuristic"
