"""Fused gather-refine Pallas TPU kernel (DESIGN.md S4).

The unfused offset sweep (core/selfjoin.py history, kernels/cell_join.py)
materializes a ``(B, C, n)`` gathered-candidate tensor in HBM per stencil
offset and then evaluates distances over it -- the dominant cost is HBM
traffic the paper's shared-memory refine never pays. This kernel removes the
intermediate: the *positions* of each query's candidate window (``win_start``
/ ``win_count`` from ``core.grid.window_descriptors``) arrive via scalar
prefetch (``pltpu.PrefetchScalarGridSpec``), and the kernel performs the
HBM->VMEM candidate gather itself with a dynamic slice of ``points_sorted``,
immediately consuming the window for the distance + epsilon threshold. The
candidate coordinates live only in VMEM.

One ``pallas_call`` sweeps the whole stencil: the grid is

    (query tiles, stencil offsets)       -- offsets innermost

so the query tile block (index map depends on the tile index only) stays
VMEM-resident across all offsets of the sweep -- the locality
kernels/cell_join.py's docstring promises but the per-offset dispatch of the
unfused path could not deliver.

Per grid step the kernel fuses, per query row:

    gather window -> squared distance -> eps threshold -> UNICOMP/self mask
    -> per-query hit count (accumulated across offsets)

and on the final offset computes the per-tile exclusive scan of the hit
counts (``slot_base``) -- the slot assignment the fill phase uses, so count
and fill share ONE distance evaluation per candidate: the driver
(core/selfjoin.py) sizes the result buffer from ``counts`` and scatters pairs
from the returned ``hits`` mask without ever recomputing a distance.

Outputs (for a query batch of Q_pad rows, C-slot windows, n_off offsets):

    hits      (n_off, Q_pad, C) int8 -- fully masked epsilon-hits
    counts    (Q_pad,)          int32 -- per-query hit totals over all offsets
    slot_base (Q_pad,)          int32 -- per-tile exclusive scan of counts

A ``reference`` lowering with identical semantics runs on backends without
Mosaic (this container): it ``lax.scan``s the stencil offsets (mirroring the
kernel's innermost offset axis) and evaluates each offset's full
``(Q_pad, C)`` window plane at once -- squared distances accumulate in place
over per-coordinate column gathers, so the reference path never materializes
a ``(B, C, n)`` candidate tensor either, and UNICOMP/merged/gid masking is
the shared ``_mask_hits``. The Pallas kernel is validated against it in
tests/test_fused_join.py.

Cell-run DMA dedup (DESIGN.md S11, ``run_loop=True``): a scalar-prefetched
run-ordinal array (``grid.cell_run_plan``) groups each tile's rows into RUNS
sharing a grid cell; since same-cell rows have identical windows for every
offset, the window DMA advances once per run (slot = ordinal mod 2, still
two slots / two semaphores; the current run's last row issues the next run's
copy, the head row waits, interior rows reuse the resident slot) -- the
paper's duplicate-search removal (SIV-C) applied to the gather stream. The
reference lowering accepts and ignores the ordinals: evaluating every row
against its OWN descriptors is exactly the run-loop's semantics whenever the
run plan satisfies the shared-window contract (proven by
``analysis.contracts.check_run_plan``), so bit-parity is structural.

Merged-range sweeps (DESIGN.md S7): with ``merged=True`` the windows are
last-dimension RANGE spans (up to three adjacent cells' contiguous points,
``grid.range_window_descriptors``), the sweep runs 3^(n-1) reduced offsets,
and the kernel applies the last-dimension boundary mask
|cand_last - q_last| <= 1 from the cell coordinates riding lane ``n_real``
of the padded point/query arrays (exact integers in float, never derived
from float positions).

Hardware adaptation notes (honest limits of this port):
  * each row's window is fetched with an explicit ``pltpu.make_async_copy``
    (HBM -> VMEM scratch) inside a ``fori_loop``, the Mosaic-lowerable
    form, DOUBLE-BUFFERED across rows: two VMEM window slots, the copy for
    row r+1 issued before row r's compute (pallas_guide.md "Patterns:
    Double Buffering"). Off-TPU the copies run through the interpreter.
  * scalar-prefetch arrays are (n_off, Q_pad) int32; at serving scale these
    are sharded with the query batch (launch/mesh.py 'slab' axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import metric as metric_lib

NP_PAD = 8     # minimum lane padding of the coordinate axis (cell_join.py)
TQ_DEFAULT = 128  # query tile rows


def pad_width(n_lanes: int) -> int:
    """Padded lane count for ``n_lanes`` occupied lanes: at least NP_PAD,
    rounded up to the 8-lane unit. Metrics with feature payloads (jaccard
    bitmaps) widen the points array past NP_PAD; the kernel reads the
    width back off the array shapes, so L2/cosine layouts are unchanged."""
    return max(NP_PAD, -(-int(n_lanes) // 8) * 8)


def resolve_merge_last_dim(n_dims: int,
                           merge_last_dim: bool | None,
                           extra_lanes: int = 0) -> bool:
    """THE merge-resolution rule, shared by the self-join drivers and the
    external-query service: merged-range sweeps default ON and fall back
    to the per-cell sweep when there is no free pad lane to carry the
    boundary-mask coordinates (n_dims >= NP_PAD). ``extra_lanes`` reserves
    additional pad lanes the caller needs besides the coordinates -- the
    distributed slab join rides the global point id in one (DESIGN.md S3),
    so its merged sweep needs TWO free lanes."""
    if merge_last_dim is None:
        merge_last_dim = True
    return bool(merge_last_dim) and n_dims + extra_lanes < NP_PAD


def pad_points(points_sorted: jax.Array, tail: int,
               last_coord: jax.Array | None = None,
               gid: jax.Array | None = None,
               feats: jax.Array | None = None) -> jax.Array:
    """(N, n) -> (N + tail, L) zero-padded copy for in-kernel gathers,
    with L = ``pad_width`` of the occupied lanes (NP_PAD unless feature
    lanes widen it).

    ``tail`` >= C guarantees every C-slot window read is in bounds
    (win_start + C <= N + tail, see grid.window_descriptors); zero pad rows
    are never hits because their window slots are masked by win_count.

    ``feats`` (metric feature payload, DESIGN.md S12): per-point non-
    geometric lanes -- the jaccard metric's packed 16-bit token words as
    exact small-integer floats -- stored in lanes [n, n + n_feat)
    immediately after the coordinates, BEFORE the merged/gid lanes, so
    the refine predicate addresses them at a metric-static offset.

    ``last_coord`` (merged-range sweeps, DESIGN.md S7): per-point
    last-dimension CELL coordinate, stored in the first lane after the
    coordinate+feature lanes as an exactly-representable float so the
    kernel's boundary mask reads it with the same gather as the
    coordinates. Requires a free lane below NP_PAD in the featureless
    layout; the lane is excluded from the distance sum by the kernel's
    static ``n_real``.

    ``gid`` (distributed slab joins, DESIGN.md S3): per-point GLOBAL id,
    stored in the lane after the coordinates (and after ``feats`` /
    ``last_coord`` when they ride). The kernel's ``gid_pairs`` masks
    compare these instead of sorted positions, making the UNICOMP
    intra-cell tie-break device-independent. Ids are small integers
    (< 2^24), exact in f32, so the TPU downcast never reorders them; tail
    rows carry -1.
    """
    n = points_sorted.shape[1]
    n_feat = 0 if feats is None else feats.shape[1]
    lanes = (n + n_feat + (0 if last_coord is None else 1)
             + (0 if gid is None else 1))
    np_pad = pad_width(lanes)
    out = jnp.pad(points_sorted, ((0, tail), (0, np_pad - n)))
    lane = n
    if feats is not None:
        fp = jnp.pad(feats.astype(points_sorted.dtype), ((0, tail), (0, 0)))
        out = jax.lax.dynamic_update_slice(out, fp, (0, lane))
        lane += n_feat
    if last_coord is not None:
        lc = jnp.pad(last_coord.astype(points_sorted.dtype), (0, tail))
        out = out.at[:, lane].set(lc)
        lane += 1
    if gid is not None:
        g = jnp.pad(gid.astype(points_sorted.dtype), (0, tail),
                    constant_values=-1)
        out = out.at[:, lane].set(g)
    return out


def _mask_hits(hit, cand_pos, q_pos, zero, unicomp: bool,
               external: bool = False, gq=None, gc=None, ldiff=None):
    """UNICOMP triangle / full-stencil self mask (same rule as the drivers).

    ``external`` queries are not members of the indexed set: there is no
    self-pair to drop and no triangle rule to apply (every epsilon-hit is a
    result), so the mask is the identity. The self-join is the special case
    ``external=False`` with the query batch sliced out of ``points_sorted``.

    Under the merged-range sweep the UNICOMP rule is unchanged: the zero
    REDUCED offset's window spans the own cell plus the key+1 cell, and
    ``cand_pos > q_pos`` is exact for both (own cell: the triangle; key+1
    cell: every candidate sits at a later sorted position than any
    own-cell query).

    ``gq``/``gc`` (distributed slab joins): GLOBAL ids of query/candidate
    replace sorted positions in the tie-break, so every slab resolves an
    intra-cell pair the same way regardless of its local sort (DESIGN.md
    S3 ownership rule). Merged sweeps must then split the zero reduced
    offset's window by ``ldiff`` (last-dim cell delta): the key+1 cell's
    candidates are NON-zero-offset pairs and all count, only the own-cell
    part applies the gid triangle -- local positions got this for free
    (own-cell rows always precede key+1 rows in A-order), global ids do
    not.
    """
    if external:
        return hit
    if gq is not None:
        if unicomp:
            tri = gc > gq
            if ldiff is not None:
                tri = (ldiff > 0) | ((ldiff == 0) & tri)
            return hit & jnp.where(zero != 0, tri, True)
        return hit & (gc != gq)
    if unicomp:
        return hit & jnp.where(zero != 0, cand_pos > q_pos, True)
    return hit & (cand_pos != q_pos)


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

def _fused_kernel(ws_ref, wc_ref, iz_ref, qpos_ref, ord_ref, scal_ref, q_ref,
                  pts_ref, hits_ref, counts_ref, base_ref, win_ref, sem_ref,
                  *, c, tq, n_real, unicomp, external, merged, gid_pairs,
                  run_loop, metric, n_feat):
    i = pl.program_id(0)           # query tile
    j = pl.program_id(1)           # stencil offset (innermost: q tile resident)
    n_off = pl.num_programs(1)
    scal = scal_ref[0, 0]          # metric refine scalar (core.metric)
    zero = iz_ref[j]

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    # Double-buffered row DMA: two VMEM window slots; the copy for row
    # r + 1 is issued before row r's compute, so the gather of the next
    # window overlaps the current distance evaluation (pallas_guide.md
    # "Patterns: Double Buffering"). The merged sweep's windows are up to
    # 3 cells long, which is what makes the overlap worth having.
    def win_dma(r, slot):
        return pltpu.make_async_copy(
            pts_ref.at[pl.ds(ws_ref[j, i * tq + r], c), :],
            win_ref.at[slot], sem_ref.at[slot])

    win_dma(0, 0).start()

    def row(r, _):
        if run_loop:
            # Cell-run DMA (DESIGN.md S11): rows with equal run ordinals
            # share their window for every offset (grid.cell_run_plan
            # contract), so the gather advances per RUN. slot = ordinal
            # mod 2 alternates run to run; the run's LAST row issues the
            # next run's copy (overlapping the remaining compute), the
            # HEAD row waits, interior rows reuse the resident slot.
            o = ord_ref[i * tq + r]
            two = jnp.asarray(2, o.dtype)
            slot = jax.lax.rem(o, two)
            nxt = ord_ref[i * tq + jnp.minimum(r + 1, tq - 1)]
            prev = ord_ref[i * tq + jnp.maximum(r - 1, 0)]

            @pl.when((r + 1 < tq) & (nxt != o))
            def _prefetch():
                win_dma(r + 1, jax.lax.rem(o + 1, two)).start()

            @pl.when((r == 0) | (o != prev))
            def _wait():
                win_dma(r, slot).wait()
        else:
            slot = jax.lax.rem(r, 2)

            @pl.when(r + 1 < tq)
            def _prefetch():
                win_dma(r + 1, jax.lax.rem(r + 1, 2)).start()

            win_dma(r, slot).wait()
        qg = i * tq + r                       # row in the query batch
        q_pos = qpos_ref[qg]                  # global sorted position
        start = ws_ref[j, qg]
        cnt = wc_ref[j, qg]
        window = win_ref[slot]                            # (C, NP)
        qrow = q_ref[pl.ds(r, 1), :]                      # (1, NP)
        # metric refine (core.metric, DESIGN.md S12): the predicate skips
        # pad lanes by the static (n_real, n_feat) layout -- with the
        # merged sweep, lane n_real + n_feat carries the last-dimension
        # cell coordinate, not a zero
        hit = metric_lib.tile_refine_hits(metric, qrow, window, scal,
                                          n_real=n_real, n_feat=n_feat)
        slots = jax.lax.broadcasted_iota(jnp.int32, (c, 1), 0)[:, 0]
        cand_pos = start + slots
        hit = hit & (slots < cnt)
        ldiff = None
        if merged:
            # last-dimension boundary mask (DESIGN.md S7): a candidate
            # whose last-dim cell coordinate wrapped across a grid row is
            # not a stencil neighbor; coordinates ride the lane after the
            # coordinate+feature lanes as exact integers, so the float
            # compare is exact
            ml = n_real + n_feat
            ldiff = window[:, ml] - qrow[0, ml]
            hit = hit & (jnp.abs(ldiff) <= 1)
        gq = gc = None
        if gid_pairs:
            # global ids ride the lane after the coordinates/features (and
            # after the merged coordinate lane); exact small ints in float
            gl = n_real + n_feat + (1 if merged else 0)
            gq, gc = qrow[0, gl], window[:, gl]
        hit = _mask_hits(hit, cand_pos, q_pos, zero, unicomp, external,
                         gq, gc, ldiff if gid_pairs else None)
        hits_ref[0, r, :] = hit.astype(jnp.int8)
        counts_ref[r, 0] = counts_ref[r, 0] + jnp.sum(hit).astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, tq, row, 0)

    @pl.when(j == n_off - 1)
    def _scan():
        # In-kernel exclusive scan: per-tile fill slot assignment.
        ctile = counts_ref[...]
        base_ref[...] = jnp.cumsum(ctile, axis=0) - ctile


@functools.partial(
    jax.jit, static_argnames=("c", "tq", "n_real", "unicomp", "external",
                              "merged", "gid_pairs", "keep_hits", "run_loop",
                              "interpret", "metric", "n_feat"))
def _fused_join_hits_pallas(points_pad, q_batch, win_start, win_count,
                            is_zero, q_pos, run_ord, scal, *, c, tq, n_real,
                            unicomp, external=False, merged=False,
                            gid_pairs=False, keep_hits=True, run_loop=False,
                            interpret=True, metric="l2", n_feat=0):
    n_off, qp = win_start.shape
    np_pad = points_pad.shape[1]   # pad_width: NP_PAD unless feats widen it
    if keep_hits:
        hits_shape, hits_map = (n_off, qp, c), (lambda i, j, *_: (j, i, 0))
    else:
        # count-only launch: one revisited (1, tq, c) block per tile serves
        # as scratch, so no O(n_off * Q * C) buffer is ever allocated.
        hits_shape, hits_map = (1, qp, c), (lambda i, j, *_: (0, i, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(qp // tq, n_off),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, *_: (0, 0)),
            pl.BlockSpec((tq, np_pad), lambda i, j, *_: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, tq, c), hits_map),
            pl.BlockSpec((tq, 1), lambda i, j, *_: (i, 0)),
            pl.BlockSpec((tq, 1), lambda i, j, *_: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, c, np_pad), points_pad.dtype),  # double-buffered
            pltpu.SemaphoreType.DMA((2,)),                 # window DMA slots
        ],
    )
    hits, counts, base = pl.pallas_call(
        functools.partial(_fused_kernel, c=c, tq=tq, n_real=n_real,
                          unicomp=unicomp, external=external, merged=merged,
                          gid_pairs=gid_pairs, run_loop=run_loop,
                          metric=metric, n_feat=n_feat),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(hits_shape, jnp.int8),
            jax.ShapeDtypeStruct((qp, 1), jnp.int32),
            jax.ShapeDtypeStruct((qp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(win_start, win_count, is_zero, q_pos, run_ord, scal, q_batch,
      points_pad)
    return hits, counts[:, 0], base[:, 0]


# ---------------------------------------------------------------------------
# Reference lowering (identical semantics, no Mosaic required)
# ---------------------------------------------------------------------------

def _offset_hits(points_pad, q_batch, ws, wc, zero, q_pos, scal, *,
                 c, n_real, unicomp, external=False, merged=False,
                 gid_pairs=False, metric="l2", n_feat=0):
    """Masked hits of every query against one offset's windows.

    The metric refine accumulates lane-by-lane over (Q, C) column gathers
    (``metric.plane_refine_hits``), so no (Q, C, n) candidate tensor
    exists on this path either.
    """
    slots = jnp.arange(c, dtype=jnp.int32)
    cand_pos = ws[:, None] + slots[None, :]               # (Q, C)
    hit = metric_lib.plane_refine_hits(metric, points_pad, q_batch,
                                       cand_pos, scal, n_real=n_real,
                                       n_feat=n_feat)
    hit = hit & (slots[None, :] < wc[:, None])
    ldiff = None
    if merged:
        # last-dimension boundary mask, identical to the kernel's: cell
        # coordinates ride the lane after the coordinate+feature lanes of
        # points_pad / q_batch as exact integers (grid.point_last_coords)
        ml = n_real + n_feat
        ldiff = (jnp.take(points_pad[:, ml], cand_pos)
                 - q_batch[:, ml][:, None])
        hit = hit & (jnp.abs(ldiff) <= 1)
    gq = gc = None
    if gid_pairs:
        gl = n_real + n_feat + (1 if merged else 0)
        gq = q_batch[:, gl][:, None]
        gc = jnp.take(points_pad[:, gl], cand_pos)
    return _mask_hits(hit, cand_pos, q_pos[:, None], zero, unicomp, external,
                      gq, gc, ldiff if gid_pairs else None)


@functools.partial(
    jax.jit, static_argnames=("c", "tq", "n_real", "unicomp", "external",
                              "merged", "gid_pairs", "keep_hits", "metric",
                              "n_feat"))
def _fused_join_hits_reference(points_pad, q_batch, win_start, win_count,
                               is_zero, q_pos, run_ord, scal, *, c, tq,
                               n_real, unicomp, external=False, merged=False,
                               gid_pairs=False, keep_hits=True, metric="l2",
                               n_feat=0):
    # ``run_ord`` is accepted for arity parity with the kernel and IGNORED:
    # evaluating each row against its own descriptors is the run-loop's
    # semantics whenever the plan satisfies the shared-window contract
    # (module docstring), so the reference is the oracle for both modes.
    del run_ord
    n_off, qp = win_start.shape
    scals = scal[0, 0]

    def per_offset(counts, xs):
        ws, wc, zero = xs
        hit = _offset_hits(points_pad, q_batch, ws, wc, zero, q_pos, scals,
                           c=c, n_real=n_real, unicomp=unicomp,
                           external=external, merged=merged,
                           gid_pairs=gid_pairs, metric=metric,
                           n_feat=n_feat)
        counts = counts + hit.sum(axis=1, dtype=jnp.int32)
        out = hit.astype(jnp.int8) if keep_hits else jnp.zeros((), jnp.int8)
        return counts, out

    counts0 = jnp.zeros((qp,), jnp.int32)
    counts, hits = jax.lax.scan(
        per_offset, counts0, (win_start, win_count, is_zero))
    if not keep_hits:
        hits = jnp.zeros((1, qp, c), jnp.int8)
    ctile = counts.reshape(-1, tq)
    base = (jnp.cumsum(ctile, axis=1) - ctile).reshape(-1)
    return hits, counts, base


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------

def fused_join_hits(points_pad, q_batch, win_start, win_count, is_zero,
                    q_pos, eps, *, c, n_real, unicomp, external=False,
                    merged=False, gid_pairs=False, tq=TQ_DEFAULT,
                    keep_hits=True, run_ord=None, run_loop=False,
                    method=None, interpret=True, metric="l2", n_feat=0):
    """Fused gather-refine sweep over all stencil offsets in one launch.

    Args:
      points_pad: (N + tail, NP_PAD) ``pad_points`` output, tail >= c.
      q_batch:    (Q_pad, NP_PAD) query coordinates, Q_pad % tq == 0. For the
                  self-join these are rows of ``points_pad`` at sorted
                  positions ``q_pos`` -- a contiguous batch OR an
                  occupancy-bucket selection (DESIGN.md S6); with
                  ``external`` it is ANY query set (zero-padded pad
                  rows/lanes), and the window descriptors come from the
                  queries' own cell coordinates
                  (``grid.external_window_descriptors``).
      win_start / win_count: (n_off, Q_pad) int32 from
                  ``grid.window_descriptors`` / ``window_descriptors_at``
                  (self-join) or ``grid.external_window_descriptors``
                  (external queries); count 0 for padding queries /
                  out-of-grid probes.
      is_zero:    (n_off,) int32, 1 for the o = 0 offset (UNICOMP triangle).
      q_pos:      (Q_pad,) int32 global sorted position of every query row,
                  prefetched as a scalar array (self-join masking only;
                  pass zeros with ``external``). Padding rows may carry any
                  in-range value -- their windows are count-0.
      eps:        scalar refine threshold in the metric's UNsquared form:
                  the geometry radius for l2/cosine (squared once by
                  ``metric.device_refine_scalar``), the Jaccard similarity
                  threshold t for jaccard. Traced, so a mix of radii per
                  metric shares one executable.
      c:          static window capacity (the launch's bucket capacity; the
                  global ``max_per_cell`` rounded up in the unbucketed case).
      n_real:     static true dimensionality (reference path skips pad lanes).
      unicomp:    static; triangle rule on o = 0 vs. full-stencil self mask.
      external:   static; True disables BOTH masks (queries are not members
                  of the indexed set -- every epsilon-hit is a result).
      merged:     static; True = windows are MERGED last-dimension range
                  spans (DESIGN.md S7): lane ``n_real`` of points_pad and
                  q_batch carries last-dim cell coordinates
                  (``pad_points(..., last_coord=...)``) and the kernel
                  applies the boundary mask |cand_last - q_last| <= 1.
      gid_pairs:  static; True = the lane after the coordinates (and after
                  the merged coordinate lane) carries GLOBAL point ids
                  (``pad_points(..., gid=...)``) and the UNICOMP/self
                  masks compare those instead of sorted positions -- the
                  device-independent tie-break of the distributed slab
                  join (DESIGN.md S3).
      keep_hits:  static; False = count-only (no O(n_off*Q*C) hits buffer).
      run_ord:    (Q_pad,) int32 per-tile run ordinals from
                  ``grid.cell_run_plan(...).run_ord`` -- required when
                  ``run_loop`` is True, otherwise optional (prefetched but
                  unused; pass zeros to keep launch shapes identical).
      run_loop:   static; True = cell-run DMA dedup (module docstring): the
                  kernel gathers one window per RUN of equal ordinals. The
                  caller owns the contract that equal ordinals imply equal
                  (win_start, win_count) columns for all offsets
                  (``analysis.contracts.check_run_plan``).
      method:     'kernel' | 'reference' | None (auto: kernel on TPU).
      metric:     static metric tag ('l2' | 'cosine' | 'jaccard'): selects
                  the refine predicate (core.metric) and keys a SEPARATE
                  executable per metric -- no traced branch.
      n_feat:     static count of metric feature lanes riding points_pad /
                  q_batch at lanes [n_real, n_real + n_feat) (jaccard
                  bitmap words; 0 otherwise).

    Returns (hits, counts, slot_base); hits is (1, Q_pad, c) scratch when
    ``keep_hits`` is False.
    """
    if method is None:
        method = "kernel" if jax.default_backend() == "tpu" else "reference"
    metric_lib.check_metric(metric)
    q_pos = jnp.asarray(q_pos, jnp.int32)
    if run_ord is None:
        if run_loop:
            raise ValueError("run_loop=True requires a run_ord plan "
                             "(grid.cell_run_plan)")
        run_ord = jnp.zeros((win_start.shape[1],), jnp.int32)
    run_ord = jnp.asarray(run_ord, jnp.int32)
    scal = metric_lib.device_refine_scalar(metric, eps, points_pad.dtype)
    if method == "kernel":
        return _fused_join_hits_pallas(
            points_pad, q_batch, win_start, win_count, is_zero, q_pos,
            run_ord, scal, c=c, tq=tq, n_real=n_real, unicomp=unicomp,
            external=external, merged=merged, gid_pairs=gid_pairs,
            keep_hits=keep_hits, run_loop=run_loop, interpret=interpret,
            metric=metric, n_feat=n_feat)
    if method == "reference":
        return _fused_join_hits_reference(
            points_pad, q_batch, win_start, win_count, is_zero, q_pos,
            run_ord, scal, c=c, tq=tq, n_real=n_real, unicomp=unicomp,
            external=external, merged=merged, gid_pairs=gid_pairs,
            keep_hits=keep_hits, metric=metric, n_feat=n_feat)
    raise ValueError(f"unknown fused_join method {method!r}")


@functools.partial(jax.jit, static_argnames=("c", "tq", "check_hits",
                                             "metric", "n_real"))
def sanitize_errcodes(points_pad, q_batch, win_start, win_count, counts,
                      base, hits, *, c, tq, check_hits=False, metric="l2",
                      n_real=None):
    """Device-side invariant reduction for one fused launch -> int32 bitmask.

    The sanitized-mode checker (``REPRO_SANITIZE=1``, analysis/sanitize.py):
    recomputes the launch's safety conditions with plain jnp ops over the
    SAME descriptors and outputs the kernel consumed/produced, so the kernel
    and its checker cannot share a miscompile. Stays async -- the caller
    queues the scalar and the driver forces it at its existing sync points.

    Bits (constants in analysis/sanitize.py):
      oob-gather     a live window's [start, start + c) gather would leave
                     the padded points buffer (corrupted descriptor).
      cap-overflow   win_count > c: the granted capacity silently truncates
                     the window (undersized ``cell_window_caps``).
      scan-mismatch  slot_base is not the per-tile exclusive scan of counts
                     (or, with ``check_hits``, counts disagree with the hits
                     mask) -- the emit path's slot writes would collide.
      nonfinite      NaN/Inf in the points or query coordinates. With
                     ``metric='jaccard'`` the check covers the GEOMETRY
                     lanes [0, n_real) only: the bitmap feature lanes are
                     packed integer words, not coordinates.
      count-range    negative window counts, or per-query totals outside
                     [0, n_off * c].
      unnormalized   (``metric='cosine'`` only) a NONZERO point or query
                     row whose coordinate-lane squared norm is off unity by
                     more than ``metric.NORM_TOL``: raw embeddings reached
                     the kernel without canonicalization. All-zero rows are
                     padding, not input (canonicalize rejects zero rows).
    """
    from repro.analysis import sanitize as _san

    np_total = points_pad.shape[0]
    n_off, _ = win_start.shape
    live = win_count > 0
    oob = live & ((win_start < 0) | (win_start + c > np_total))
    code = jnp.where(jnp.any(oob), _san.E_OOB_GATHER, 0)
    code = code | jnp.where(jnp.any(win_count > c), _san.E_CAP_OVERFLOW, 0)
    bad_range = ((win_count < 0).any() | (counts < 0).any()
                 | (counts > n_off * c).any())
    code = code | jnp.where(bad_range, _san.E_COUNT_RANGE, 0)
    ctile = counts.reshape(-1, tq)
    scan_bad = jnp.any(
        ((jnp.cumsum(ctile, axis=1) - ctile).reshape(-1)) != base)
    if check_hits:
        scan_bad = scan_bad | jnp.any(
            hits.astype(jnp.int32).sum(axis=(0, 2)) != counts)
    code = code | jnp.where(scan_bad, _san.E_SCAN_MISMATCH, 0)
    n_chk = points_pad.shape[1] if (metric != "jaccard" or n_real is None) \
        else n_real
    finite = (jnp.all(jnp.isfinite(points_pad[:, :n_chk]))
              & jnp.all(jnp.isfinite(q_batch[:, :n_chk])))
    code = code | jnp.where(~finite, _san.E_NONFINITE, 0)
    if metric == "cosine" and n_real is not None:
        def off_unit(rows):
            n2 = jnp.sum(rows[:, :n_real] * rows[:, :n_real], axis=1)
            return jnp.any((n2 > 0) & (jnp.abs(n2 - 1) > metric_lib.NORM_TOL))
        code = code | jnp.where(off_unit(points_pad) | off_unit(q_batch),
                                _san.E_UNNORMALIZED, 0)
    return code.astype(jnp.int32)


def fused_window_hits(points_sorted, q, cand_pos, valid, eps):
    """Positional drop-in for selfjoin._distance_hits_jnp without the gather.

    (B, n) queries x (B, C) candidate *positions* -> (B, C) bool hits; the
    compacted sweep (selfjoin._count_compact) uses this so distance_impl=
    'fused' never materializes the (B, C, n) candidate tensor there either.
    """
    d2 = jnp.zeros(cand_pos.shape, q.dtype)
    for dim in range(q.shape[1]):
        cd = jnp.take(points_sorted[:, dim], cand_pos)
        d2 = d2 + (q[:, dim][:, None] - cd) ** 2
    return metric_lib.l2_sq_hits(d2, eps) & valid
