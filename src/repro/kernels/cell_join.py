"""Pallas TPU kernel: grid-cell candidate refine (Alg. 1 lines 14-17).

Consumes what the offset sweep gathers: a tile of query points and, per
query, its padded candidate window from one adjacent cell. Computes masked
squared distances and the epsilon threshold entirely in VMEM.

Layout: queries (TB, NP), candidates (TB, C, NP), validity (TB, C). The
candidate window C is small (max points per cell, rounded to 8), so this is
VPU elementwise work: the subtract-square-reduce over NP lanes. The MXU
formulation is deliberately NOT used here: each query row contracts against
its *own* candidate set (a batched matvec, M=1), which cannot fill the
128x128 systolic array; the VPU form also avoids the catastrophic
cancellation of ||a||^2+||b||^2-2ab for nearby points, which matters since
cell windows contain exactly the nearby points. (The brute-force kernel can
use the MXU because its query tile shares one global candidate tile.)

The query tile (TB, NP) stays resident in VMEM across all stencil offsets of
one sweep step -- the TPU analogue of the L1 temporal locality the paper
measures for UNICOMP (Table II); see EXPERIMENTS.md SPerf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NP_PAD = 8


def _cell_join_kernel(eps2_ref, q_ref, cand_ref, valid_ref, out_ref):
    q = q_ref[...]                    # (TB, NP)
    c = cand_ref[...]                 # (TB, C, NP)
    v = valid_ref[...]                # (TB, C) int8
    d = q[:, None, :] - c
    d2 = jnp.sum(d * d, axis=-1)      # (TB, C)
    hit = (d2 <= eps2_ref[0, 0]) & (v != 0)
    out_ref[...] = hit.astype(jnp.int8)


def _ceil_to(x, m):
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def cell_join_hits(q, cand, valid, eps, *, tb: int = 512, interpret: bool = True):
    """(B,n) x (B,C,n) x (B,C) bool -> (B,C) bool epsilon-hits.

    Drop-in for selfjoin._distance_hits_jnp (``distance_impl='pallas'``).
    """
    b, n = q.shape
    c = cand.shape[1]
    b_p = _ceil_to(max(b, 1), tb)
    pad_b = b_p - b
    if n < NP_PAD:
        q = jnp.pad(q, ((0, 0), (0, NP_PAD - n)))
        cand = jnp.pad(cand, ((0, 0), (0, 0), (0, NP_PAD - n)))
    if pad_b:
        q = jnp.pad(q, ((0, pad_b), (0, 0)))
        cand = jnp.pad(cand, ((0, pad_b), (0, 0), (0, 0)))
        valid = jnp.pad(valid, ((0, pad_b), (0, 0)))
    eps2 = jnp.asarray(eps, q.dtype).reshape(1, 1) ** 2

    out = pl.pallas_call(
        _cell_join_kernel,
        grid=(b_p // tb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((tb, NP_PAD), lambda i: (i, 0)),
            pl.BlockSpec((tb, c, NP_PAD), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_p, c), jnp.int8),
        interpret=interpret,
    )(eps2, q, cand.astype(q.dtype), valid.astype(jnp.int8))
    return out[:b].astype(bool)
