"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the exact mathematical specification its kernel is tested
against (tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
The epsilon predicate itself is owned by core/metric.py (DESIGN.md S12);
these oracles only compute squared distances and delegate the compare.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import metric as metric_lib


def distance_tile_hits_ref(q, pts, eps):
    """(TQ,n) x (N,n) -> (TQ,N) bool: ||q_i - p_j||^2 <= eps^2."""
    d2 = jnp.sum((q[:, None, :] - pts[None, :, :]) ** 2, axis=-1)
    return metric_lib.l2_sq_hits(d2, jnp.asarray(eps, q.dtype))


def distance_tile_counts_ref(pts, eps):
    """(N,n) -> (N,) int32: per-point epsilon-neighbor count, excl. self."""
    d2 = jnp.sum((pts[:, None, :] - pts[None, :, :]) ** 2, axis=-1)
    hits = metric_lib.l2_sq_hits(d2, jnp.asarray(eps, pts.dtype))
    n = pts.shape[0]
    hits = hits & ~jnp.eye(n, dtype=bool)
    return hits.sum(axis=1).astype(jnp.int32)


def cell_join_hits_ref(q, cand, valid, eps):
    """(B,n) x (B,C,n) x (B,C) -> (B,C) bool masked epsilon-hits."""
    d2 = jnp.sum((q[:, None, :] - cand) ** 2, axis=-1)
    return metric_lib.l2_sq_hits(d2, jnp.asarray(eps, q.dtype)) & valid
