"""Pallas TPU kernel: tiled epsilon-distance join (the refine hot-spot).

This is the compute core of both the brute-force baseline (paper SVI-B) and
the batched refine stage of GPU-SJ. The CUDA original evaluates one scalar
Euclidean distance per thread (Alg. 1 lines 14-16); the TPU formulation
computes a (TQ x TC) block of squared distances at once on the MXU:

    ||q - p||^2 = ||q||^2 + ||p||^2 - 2 q . p

The cross term is a (TQ, NP) x (TC, NP) dot_general, i.e. a systolic-array
matmul with the point dimensionality NP as the contraction. NP is tiny (2-6,
zero-padded to 8); the MXU zero-pads the contraction internally, and the
norms are rank-1 VPU terms -- the kernel is deliberately memory-streaming
(candidates flow HBM->VMEM once per query tile) because at n <= 6 the join is
intrinsically bandwidth-bound (see EXPERIMENTS.md roofline).

Two entry points:
  * hits kernel  -- emits the (TQ, TC) boolean block (drop-in for the jnp
    reference; used by the fill phase which needs the mask).
  * count kernel -- fused threshold+popcount accumulated over candidate
    tiles; per-query counts never leave VMEM until the final (TQ,) write.
    This is the paper's "count phase" with zero result-buffer traffic.

VMEM working set (defaults TQ=TC=256, NP=8, f32): q 8 KiB + p 8 KiB +
out 64 KiB (hits) or 1 KiB (counts) -- far under the ~16 MiB/core budget, so
the grid can be swept with full double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NP_PAD = 8  # point dimensionality padded to the f32 sublane count


def _acc_dtype(dtype):
    # MXU accumulates bf16 x bf16 natively in f32; keep f64 for the paper-
    # precision interpret path.
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def _hits_kernel(eps2_ref, q_ref, p_ref, out_ref):
    q = q_ref[...]                      # (TQ, NP)
    p = p_ref[...]                      # (TC, NP)
    acc = _acc_dtype(q.dtype)
    eps2 = eps2_ref[0, 0].astype(acc)
    qf = q.astype(acc)
    pf = p.astype(acc)
    qn = jnp.sum(qf * qf, axis=1, keepdims=True)        # (TQ, 1)
    pn = jnp.sum(pf * pf, axis=1, keepdims=True).T      # (1, TC)
    cross = jax.lax.dot_general(
        q, p, (((1,), (1,)), ((), ())),
        preferred_element_type=acc,
    )                                                   # MXU: (TQ, TC)
    d2 = qn + pn - 2.0 * cross
    out_ref[...] = (d2 <= eps2).astype(jnp.int8)


def _count_kernel(eps2_ref, npts_ref, q_ref, p_ref, out_ref, *, tq, tc):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[...]
    p = p_ref[...]
    acc = _acc_dtype(q.dtype)
    eps2 = eps2_ref[0, 0].astype(acc)
    npts = npts_ref[0, 0]
    qf = q.astype(acc)
    pf = p.astype(acc)
    qn = jnp.sum(qf * qf, axis=1, keepdims=True)
    pn = jnp.sum(pf * pf, axis=1, keepdims=True).T
    cross = jax.lax.dot_general(
        q, p, (((1,), (1,)), ((), ())), preferred_element_type=acc
    )
    d2 = qn + pn - 2.0 * cross
    row = i * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tc), 0)
    col = j * tc + jax.lax.broadcasted_iota(jnp.int32, (tq, tc), 1)
    ok = (row < npts) & (col < npts) & (row != col)
    hits = (d2 <= eps2) & ok
    out_ref[0, :] += hits.sum(axis=1).astype(jnp.int32)


def _pad_points(x, np_pad):
    n = x.shape[-1]
    if n < np_pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, np_pad - n)])
    return x


def _ceil_to(x, m):
    return -(-x // m) * m


@functools.partial(
    jax.jit, static_argnames=("tq", "tc", "interpret")
)
def distance_tile_hits(q, pts, eps, *, tq: int = 256, tc: int = 256,
                       interpret: bool = True):
    """(TQ_total,n) x (N,n) -> (TQ_total,N) bool epsilon-hit block."""
    nq, n = q.shape
    npts = pts.shape[0]
    dtype = q.dtype
    nq_p, nc_p = _ceil_to(nq, tq), _ceil_to(npts, tc)
    qp = _pad_points(jnp.pad(q, ((0, nq_p - nq), (0, 0))), NP_PAD)
    # pad candidates far away so padded slots can never hit (1e9 keeps
    # ||p||^2 ~ 1e18, far below overflow even in bf16/f32, and >> eps^2)
    pp = _pad_points(jnp.pad(pts, ((0, nc_p - npts), (0, 0)), constant_values=1e9),
                     NP_PAD)
    eps2 = jnp.asarray(eps, dtype).reshape(1, 1) ** 2

    out = pl.pallas_call(
        _hits_kernel,
        grid=(nq_p // tq, nc_p // tc),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((tq, NP_PAD), lambda i, j: (i, 0)),
            pl.BlockSpec((tc, NP_PAD), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tq, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq_p, nc_p), jnp.int8),
        interpret=interpret,
    )(eps2, qp, pp)
    return out[:nq, :npts].astype(bool)


@functools.partial(
    jax.jit, static_argnames=("tq", "tc", "interpret")
)
def distance_tile_counts(pts, eps, *, tq: int = 256, tc: int = 256,
                         interpret: bool = True):
    """(N,n) -> (N,) int32 per-point epsilon-neighbor counts (excl. self).

    Fused brute-force count: the full O(N^2) distance evaluation with only an
    O(N) output -- the TPU version of the paper's count phase.
    """
    npts, n = pts.shape
    dtype = pts.dtype
    n_p = _ceil_to(npts, max(tq, tc))
    pp = _pad_points(jnp.pad(pts, ((0, n_p - npts), (0, 0))), NP_PAD)
    eps2 = jnp.asarray(eps, dtype).reshape(1, 1) ** 2
    npts_a = jnp.asarray(npts, jnp.int32).reshape(1, 1)

    kernel = functools.partial(_count_kernel, tq=tq, tc=tc)
    out = pl.pallas_call(
        kernel,
        grid=(n_p // tq, n_p // tc),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((tq, NP_PAD), lambda i, j: (i, 0)),
            pl.BlockSpec((tc, NP_PAD), lambda i, j: (j, 0)),
        ],
        # counts live as (1, tq) rows so the accumulator stays 2-D (TPU
        # vector layout wants a lane dimension)
        out_specs=pl.BlockSpec((1, tq), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_p // tq, tq), jnp.int32),
        interpret=interpret,
    )(eps2, npts_a, pp, pp)
    return out.reshape(-1)[:npts]
