"""Distributed self-join: spatial slab decomposition with eps-halo exchange.

The paper is single-GPU; this module is the scale-out design of DESIGN.md S3
(the slab + halo shape of Gowanlock's multi-GPU follow-on work and Karsin's
multi-GPU join pipelines, PAPERS.md).

Decomposition
-------------
Points are partitioned into contiguous slabs along dimension 0 (equal-count
quantile boundaries, computed on the host: ``partition_points_host``; empty
slabs are legal and handled). Each slab:

  1. exchanges a k-hop eps-halo with its slab neighbors via
     ``lax.ppermute`` -- exactly the points within eps (in dim 0) of the
     shared boundary, which is all another slab can ever need
     (``_assemble_candidates``; ``halo_reach`` derives k, parcels are
     capacity-bounded with overflow *detected*, never silent),
  2. builds its local grid over (local + halo) candidates against the
     GLOBAL grid geometry, so cell coordinates -- and the UNICOMP
     cell-pair ownership rule -- are consistent across slabs, and
  3. joins only pairs whose *query* point it owns.

Two join paths share that decomposition:

``distributed_self_join`` -- the fused pair join: per slab, the SAME fast
path as the single-device join (merged-range sweep, occupancy buckets,
single-pass count -> fill; ``selfjoin._self_join_fused``) restricted to
owned query rows, with GLOBAL point ids riding a kernel pad lane
(``gid_pairs``) so the UNICOMP intra-cell tie-break is device-independent.
Emits (K, 2) global-id pairs bit-identical to
``self_join(distance_impl='fused')`` after the lexsort;
``return_pairs=False`` runs the count-only launches.

``distributed_self_join_count`` -- the legacy jnp offset-sweep counter,
retained for the 'model'-axis offset parallelism: the stencil offset table
is sharded over the second mesh axis and partial counts are psum-reduced,
matching how the LM stack uses the same axis for tensor parallelism.

Correctness of single counting: with globally consistent cell coordinates the
UNICOMP half-stencil assigns each unordered adjacent-cell pair to exactly one
directed evaluation; the device owning the query endpoint of that evaluation
is unique, and (since qualifying pairs are within eps in dim 0) its candidate
set is guaranteed to contain the other endpoint. Intra-cell pairs use the
global-id total order as the tie-break, which is device-independent.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import grid as grid_lib
from repro.core.grid import (build_grid_with_geometry,
                             build_grid_with_geometry_jit, device_key_dtype,
                             host_grid_geometry, row_major_strides)
from repro.core.selfjoin import _distance_hits_jnp, _gather_batch, _neighbor_ranks_for_delta
from repro.core.stencil import stencil_offsets


@dataclasses.dataclass(frozen=True)
class DistJoinConfig:
    pts_per_device: int          # P: local slab size (padded)
    n_dims: int
    halo_capacity: int           # H: slots per direction per hop
    max_per_cell: int            # C: candidate window per cell
    unicomp: bool = True
    slab_axis: str = "slab"
    model_axis: Optional[str] = "model"   # None -> no offset-parallelism
    distance_impl: str = "jnp"
    # halo reach: a slab narrower than eps (equal-count partition of skewed
    # data at high slab counts) needs points from k>1 slabs away. The driver
    # auto-computes k from the partition boundaries.
    k_hops: int = 1
    # static cell-key dtype name for the padded device build: the driver
    # fixes it host-side from the global geometry (device_key_dtype with
    # padded=True -- the slab grids carry the out-of-set sentinel cell), so
    # small grids ride the int32 fast path and work under REPRO_NO_X64.
    # A string keeps the config hashable for the step cache.
    key_dtype: str = "int64"


def partition_points_host(points: np.ndarray, n_slabs: int):
    """Equal-count slab partition along dim 0 (host side).

    Returns (coords (n_slabs, P, n), gids (n_slabs, P) int32 with -1 padding).
    Equal-count boundaries keep devices load-balanced under skew -- the
    distributed analogue of the paper's non-empty-cell index (DESIGN.md S3).
    """
    pts = np.asarray(points)
    npts, n = pts.shape
    order = np.argsort(pts[:, 0], kind="stable")
    slabs = np.array_split(order, n_slabs)
    pcap = max(len(s) for s in slabs)
    coords = np.zeros((n_slabs, pcap, n), dtype=pts.dtype)
    gids = np.full((n_slabs, pcap), -1, dtype=np.int32)
    for k, s in enumerate(slabs):
        coords[k, : len(s)] = pts[s]
        gids[k, : len(s)] = s
        if len(s):
            coords[k, len(s):] = pts[s[0]]  # harmless filler (masked by gid)
    widths = [pts[s, 0].max() - pts[s, 0].min() for s in slabs if len(s) > 1]
    return coords, gids, min(widths) if widths else 0.0


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def slab_extents(coords: np.ndarray, gids: np.ndarray):
    """Per-slab [min, max] extent along dim 0; empty slabs (possible when
    ``n_slabs`` approaches the point count, or under heavy skew) carry the
    neutral (+inf, -inf) pair instead of raising on an empty reduction."""
    n_slabs = coords.shape[0]
    mins = np.full(n_slabs, np.inf)
    maxs = np.full(n_slabs, -np.inf)
    for i in range(n_slabs):
        own = gids[i] >= 0
        if own.any():
            mins[i] = coords[i, own, 0].min()
            maxs[i] = coords[i, own, 0].max()
    return mins, maxs


def halo_reach(mins: np.ndarray, maxs: np.ndarray, eps: float) -> int:
    """Hop count k such that every slab's eps-neighborhood along dim 0 is
    covered by its k-hop slab neighbors (skewed data -> narrow slabs ->
    k > 1). Empty slabs sit at the END of the sorted partition
    (``np.array_split`` of the x0-sorted order only under-fills trailing
    slabs), so an empty slab's +inf min terminates the inner scan exactly
    where a too-far real slab would."""
    n_slabs = mins.shape[0]
    k_hops = 1
    for i in range(n_slabs):
        if not np.isfinite(maxs[i]):
            continue
        for h in range(1, n_slabs - i):
            if mins[i + h] <= maxs[i] + eps:
                k_hops = max(k_hops, h)
            else:
                break
    return k_hops


def _halo_exchange(x, valid, axis, n_dev, direction, hops: int = 1):
    """Shift (x, valid) ``hops`` steps along ``axis``. direction=+1 sends
    right (device i's value lands on device i+hops)."""
    idx = jax.lax.axis_index(axis)
    if direction > 0:
        perm = [(i, i + hops) for i in range(n_dev - hops)]
    else:
        perm = [(i, i - hops) for i in range(hops, n_dev)]
    rx = jax.lax.ppermute(x, axis, perm)
    rv = jax.lax.ppermute(valid, axis, perm)
    # devices with no sending neighbor receive zeros; zero validity is False.
    edge = (idx < hops) if direction > 0 else (idx >= n_dev - hops)
    rv = jnp.where(edge, False, rv)
    return rx, rv


def _pack_mask(coords, gids, mask, capacity):
    """Select masked rows into ``capacity`` slots (validity-flagged)."""
    order = jnp.argsort(~mask, stable=True)             # masked rows first
    take = order[:capacity]
    sent = jnp.take(mask, take)
    overflow = mask.sum() > capacity
    return coords[take], gids[take], sent, overflow


def _assemble_candidates(coords, gids, eps, *, cfg: "DistJoinConfig",
                         n_slab: int):
    """Device-side candidate assembly: local slab + k-hop eps-halo parcels.

    The shared first phase of BOTH distributed paths (the legacy count
    step and the fused pair join): each slab learns its h-hop neighbors'
    dim-0 boundaries, selects exactly the points those neighbors need
    (within eps of the boundary), and ships the parcels via
    ``lax.ppermute``. Returns

        (cand_coords (P + 2*H*k, n), cand_gids, cand_valid, cand_owned,
         owned (P,), halo_overflow ())

    where the first P rows are the local slab (owned) and the rest the
    received parcels (validity-flagged; overflow against the H-slot parcel
    capacity is detected, never silent). Invalid parcel slots carry the
    slab's anchor coordinate -- harmless for consumers that mask validity;
    the pair path overwrites them host-side with out-of-volume sentinels
    before building its grid.
    """
    slab = cfg.slab_axis
    P_loc, H = cfg.pts_per_device, cfg.halo_capacity
    coords = coords.reshape(P_loc, cfg.n_dims)
    gids = gids.reshape(P_loc)
    owned = gids >= 0
    big = jnp.asarray(jnp.finfo(coords.dtype).max / 4, coords.dtype)

    # Receiver r needs every point p with |p.x0 - slab_r| <= eps; when
    # equal-count slabs are narrower than eps (skew), that spans k > 1
    # neighbors. For each hop h: learn the h-hop neighbor's boundary,
    # select exactly what it needs, ship the parcel h hops.
    my_min0 = jnp.where(owned, coords[:, 0], big).min()
    my_max0 = jnp.where(owned, coords[:, 0], -big).max()
    parcels_c, parcels_g, parcels_v = [], [], []
    halo_overflow = jnp.array(False)
    for h in range(1, cfg.k_hops + 1):
        left_max, lm_ok = _halo_exchange(
            my_max0, jnp.array(True), slab, n_slab, +1, hops=h)
        right_min, rm_ok = _halo_exchange(
            my_min0, jnp.array(True), slab, n_slab, -1, hops=h)
        left_max = jnp.where(lm_ok, left_max, -big)
        right_min = jnp.where(rm_ok, right_min, big)
        send_left = owned & (coords[:, 0] <= left_max + eps)
        send_right = owned & (coords[:, 0] >= right_min - eps)
        cl, gl, vl, ofl = _pack_mask(coords, gids, send_left, H)
        cr, gr, vr, ofr = _pack_mask(coords, gids, send_right, H)
        # ship h hops: sending "left" means device i -> i-h, i.e. I
        # receive my h-hop RIGHT neighbor's left edge, and vice versa.
        hcl, hvl = _halo_exchange(cl, vl, slab, n_slab, -1, hops=h)
        hgl, _ = _halo_exchange(gl, vl, slab, n_slab, -1, hops=h)
        hcr, hvr = _halo_exchange(cr, vr, slab, n_slab, +1, hops=h)
        hgr, _ = _halo_exchange(gr, vr, slab, n_slab, +1, hops=h)
        parcels_c += [hcl, hcr]
        parcels_g += [hgl, hgr]
        parcels_v += [hvl, hvr]
        halo_overflow = halo_overflow | ofl | ofr
    halo_coords = jnp.concatenate(parcels_c, axis=0)
    halo_gids = jnp.concatenate(parcels_g, axis=0)
    halo_valid = jnp.concatenate(parcels_v, axis=0)

    n_halo = 2 * H * cfg.k_hops
    anchor = coords[0]
    cand_coords = jnp.concatenate(
        [coords, jnp.where(halo_valid[:, None], halo_coords, anchor)], axis=0
    )
    cand_gids = jnp.concatenate([gids, jnp.where(halo_valid, halo_gids, -1)])
    cand_valid = jnp.concatenate([owned, halo_valid])
    cand_owned = jnp.concatenate([owned, jnp.zeros(n_halo, bool)])
    return cand_coords, cand_gids, cand_valid, cand_owned, owned, \
        halo_overflow


def make_distributed_count_step(mesh: Mesh, cfg: DistJoinConfig):
    """Build the jitted distributed count step for ``mesh``.

    Returns (step, in_shardings): ``step(coords, gids, eps)`` with
    coords (S*P, n) sharded over the slab axis, gids (S*P,) likewise;
    returns (ordered_pair_count, halo_overflow, cell_overflow) replicated.
    """
    slab = cfg.slab_axis
    n_slab = mesh.shape[slab]
    axes = (slab,) if cfg.model_axis is None else (slab, cfg.model_axis)
    n_model = 1 if cfg.model_axis is None else mesh.shape[cfg.model_axis]

    offs = stencil_offsets(cfg.n_dims, cfg.unicomp)      # (n_off, n)
    n_off = offs.shape[0]
    n_off_pad = -(-n_off // n_model) * n_model
    offs_pad = np.zeros((n_off_pad, cfg.n_dims), np.int64)
    offs_pad[:n_off] = offs
    off_valid = np.arange(n_off_pad) < n_off
    off_zero = np.zeros(n_off_pad, bool)
    off_zero[:n_off] = np.all(offs == 0, axis=1)

    P_loc, H, C = cfg.pts_per_device, cfg.halo_capacity, cfg.max_per_cell

    def local_fn(coords, gids, eps, offsets, ovalid, ozero):
        cand_coords, cand_gids, cand_valid, cand_owned, owned, \
            halo_overflow = _assemble_candidates(
                coords, gids, eps, cfg=cfg, n_slab=n_slab)
        coords = cand_coords[:P_loc]

        # -- global geometry (consistent cell coords across devices) --------
        big = jnp.asarray(jnp.finfo(coords.dtype).max / 4, coords.dtype)
        lo = jnp.where(owned[:, None], coords, big).min(axis=0)
        hi = jnp.where(owned[:, None], coords, -big).max(axis=0)
        gmin = jax.lax.pmin(lo, slab) - eps
        gmax = jax.lax.pmax(hi, slab) + eps
        dims = jnp.ceil((gmax - gmin) / eps).astype(jnp.int64) + 1
        n_halo = 2 * H * cfg.k_hops

        # -- local grid over candidates, global geometry ---------------------
        # invalid padding slots get the sentinel cell: unreachable as
        # candidates and excluded from the max_per_cell bound.
        index = build_grid_with_geometry(cand_coords, eps, gmin, dims,
                                         valid=cand_valid,
                                         key_dtype=np.dtype(cfg.key_dtype))
        valid_sorted = cand_valid[index.order]
        owned_sorted = cand_owned[index.order]
        gid_sorted = cand_gids[index.order]
        cell_overflow = index.max_per_cell > C

        deltas = offsets @ row_major_strides(dims)
        n_cand = P_loc + n_halo

        def body(total, xs):
            delta, o_ok, o_zero = xs
            nbr_cells = _neighbor_ranks_for_delta(index, delta)
            q, cand, cand_pos, vmask, q_pos, _ = _gather_batch(
                index, nbr_cells, jnp.asarray(0, jnp.int32), n_cand, C
            )
            hits = _distance_hits_jnp(q, cand, vmask, eps)
            hits = hits & valid_sorted[cand_pos] & owned_sorted[q_pos][:, None]
            hits = hits & o_ok
            gq = gid_sorted[q_pos][:, None]
            gc = gid_sorted[cand_pos]
            if cfg.unicomp:
                hits = hits & jnp.where(o_zero, gc > gq, gc != gq)
                inc = 2 * hits.sum()  # every unicomp hit is one unordered pair
            else:
                hits = hits & (gc != gq)
                inc = hits.sum()
            return total + inc.astype(jnp.int64), None

        total, _ = jax.lax.scan(
            body, jnp.zeros((), jnp.int64), (deltas, jnp.asarray(ovalid), jnp.asarray(ozero))
        )
        total = jax.lax.psum(total, axes)
        halo_overflow = jax.lax.pmax(halo_overflow.astype(jnp.int32), axes)
        cell_overflow = jax.lax.pmax(cell_overflow.astype(jnp.int32), axes)
        return total, halo_overflow, cell_overflow

    off_spec = P(cfg.model_axis) if cfg.model_axis else P()
    from repro.compat import shard_map

    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(slab), P(slab), P(), off_spec, off_spec, off_spec),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )

    offsets_dev = jnp.asarray(offs_pad)
    ovalid_dev = jnp.asarray(off_valid)
    ozero_dev = jnp.asarray(off_zero)

    @jax.jit
    def step(coords, gids, eps):
        return fn(coords, gids, eps, offsets_dev, ovalid_dev, ozero_dev)

    in_shardings = (
        NamedSharding(mesh, P(slab)),
        NamedSharding(mesh, P(slab)),
    )
    return step, in_shardings


def distributed_self_join_count(
    points: np.ndarray,
    eps: float,
    mesh: Mesh,
    *,
    unicomp: bool = True,
    halo_capacity: Optional[int] = None,
    max_per_cell: Optional[int] = None,
    model_axis: Optional[str] = None,
    metric: str = "l2",
) -> int:
    """Host-facing driver: partition, shard, count. Raises on overflow.

    ``metric="cosine"`` canonicalizes at entry (unit rows, reduced L2
    threshold, DESIGN.md S12); the slab pipeline then runs unchanged.
    Jaccard is not distributed (its bitmap lanes do not ride the halo
    exchange yet)."""
    points, eps = _canonicalize_for_slabs(points, eps, metric)
    pts = np.asarray(points)
    slab_axis = mesh.axis_names[0]
    n_slabs = mesh.shape[slab_axis]
    if pts.shape[0] == 0:
        return 0
    coords, gids, min_width = partition_points_host(pts, n_slabs)
    mins, maxs = slab_extents(coords, gids)
    k_hops = halo_reach(mins, maxs, eps)
    if halo_capacity is None:
        halo_capacity = coords.shape[1]          # worst case: whole slab
    if max_per_cell is None:
        from repro.core.grid import build_grid_host

        max_per_cell = int(build_grid_host(pts, eps).max_per_cell)
    # the step derives gmin/dims on-device with the same arithmetic; the key
    # dtype must be STATIC, so fix it here from the host geometry
    _, dims_h = host_grid_geometry(pts, eps)
    cfg = DistJoinConfig(
        pts_per_device=coords.shape[1],
        n_dims=pts.shape[1],
        halo_capacity=halo_capacity,
        max_per_cell=max(8, -(-max_per_cell // 8) * 8),
        unicomp=unicomp,
        slab_axis=slab_axis,
        model_axis=model_axis,
        k_hops=k_hops,
        key_dtype=device_key_dtype(dims_h, padded=True).name,
    )
    step, in_sh = make_distributed_count_step(mesh, cfg)
    coords_flat = coords.reshape(-1, pts.shape[1])
    gids_flat = gids.reshape(-1)
    coords_dev = jax.device_put(coords_flat, in_sh[0])
    gids_dev = jax.device_put(gids_flat, in_sh[1])
    total, halo_of, cell_of = step(coords_dev, gids_dev, jnp.asarray(eps, pts.dtype))
    if int(halo_of):
        raise _halo_overflow_error(
            cfg.halo_capacity,
            halo_capacity_plan(coords, gids, mins, maxs, eps, k_hops))
    if int(cell_of):
        raise RuntimeError("max_per_cell overflow")
    return int(total)


# ---------------------------------------------------------------------------
# Fused slab join (DESIGN.md S3): pairs with global ids, built on the
# PR 1-4 fast path -- merged-range sweep, occupancy buckets, single-pass
# count -> fill -- run per slab over the (local + halo) candidate set.
# ---------------------------------------------------------------------------

# Per-slab grid builds against the global geometry go through THE shared
# jitted device builder (grid.build_grid_with_geometry_jit): one executable
# per (slab shape, key dtype); slab blocks share one shape by construction,
# and the serving build path reuses the same executable.

_HALO_STEPS: dict = {}


def make_halo_step(mesh: Mesh, cfg: DistJoinConfig):
    """Build the jitted halo-assembly step: the shard_map phase of the
    fused slab join. ``step(coords, gids, eps)`` with coords (S*P, n) /
    gids (S*P,) sharded over the slab axis returns the per-slab candidate
    blocks (coords, gids, valid, owned), each (S*(P + 2*H*k), ...) sharded
    over slab, plus the replicated halo-overflow flag.

    Steps are cached per (mesh, cfg) -- both hashable -- so repeated joins
    of same-shaped workloads (the bench loop, a recurring pipeline) reuse
    one traced executable instead of paying a fresh shard_map trace per
    call (the re-tracing failure mode ISSUE 2 banned from the serve path).
    """
    key = (mesh, cfg)
    cached = _HALO_STEPS.get(key)
    if cached is not None:
        return cached
    slab = cfg.slab_axis
    n_slab = mesh.shape[slab]

    def halo_fn(coords, gids, eps):
        cand_coords, cand_gids, cand_valid, cand_owned, _, halo_of = \
            _assemble_candidates(coords, gids, eps, cfg=cfg, n_slab=n_slab)
        halo_of = jax.lax.pmax(halo_of.astype(jnp.int32), slab)
        return cand_coords, cand_gids, cand_valid, cand_owned, halo_of

    from repro.compat import shard_map

    fn = shard_map(
        halo_fn,
        mesh=mesh,
        in_specs=(P(slab), P(slab), P()),
        out_specs=(P(slab), P(slab), P(slab), P(slab), P()),
        check_vma=False,
    )
    step = jax.jit(fn)
    in_shardings = (
        NamedSharding(mesh, P(slab)),
        NamedSharding(mesh, P(slab)),
    )
    _HALO_STEPS[key] = (step, in_shardings)
    return step, in_shardings


@dataclasses.dataclass(frozen=True)
class HaloParcel:
    """One (shipping slab, hop, direction) halo parcel and its exact size."""
    slab: int          # slab shipping the parcel
    hop: int           # 1..k_hops
    direction: int     # -1 toward lower slabs, +1 toward higher
    need: int          # rows the parcel must carry

    @property
    def dest(self) -> int:
        return self.slab + self.direction * self.hop

    def describe(self) -> str:
        return (f"slab {self.slab} -> slab {self.dest} (hop {self.hop}, "
                f"direction {self.direction:+d}) ships {self.need} rows")


def halo_capacity_plan(coords: np.ndarray, gids: np.ndarray,
                       mins: np.ndarray, maxs: np.ndarray, eps: float,
                       k_hops: int) -> list:
    """Every halo parcel the exchange ships, with exact sizes.

    Slabs hold x0-sorted points, so each parcel count is one
    ``searchsorted`` against the receiving slab's boundary. This is the
    full per-parcel capacity plan behind ``exact_halo_capacity`` -- the
    overflow raises report its worst parcel so an under-capacity failure
    names the slab/hop/direction to act on."""
    n_slabs = coords.shape[0]
    plan = []
    for j in range(n_slabs):
        x0 = coords[j, gids[j] >= 0, 0]          # sorted ascending
        if not x0.size:
            continue
        for h in range(1, k_hops + 1):
            if j - h >= 0 and np.isfinite(maxs[j - h]):
                # parcel j -> j-h: points with x0 <= maxs[j-h] + eps
                need = int(np.searchsorted(x0, maxs[j - h] + eps,
                                           side="right"))
                plan.append(HaloParcel(j, h, -1, need))
            if j + h < n_slabs and np.isfinite(mins[j + h]):
                # parcel j -> j+h: points with x0 >= mins[j+h] - eps
                need = int(x0.size - np.searchsorted(
                    x0, mins[j + h] - eps, side="left"))
                plan.append(HaloParcel(j, h, +1, need))
    return plan


def worst_halo_parcel(plan) -> Optional[HaloParcel]:
    return max(plan, key=lambda p: p.need) if plan else None


def exact_halo_capacity(coords: np.ndarray, gids: np.ndarray,
                        mins: np.ndarray, maxs: np.ndarray, eps: float,
                        k_hops: int) -> int:
    """Largest parcel any (slab, hop, direction) ship needs -- the max of
    ``halo_capacity_plan``. This is the per-slab capacity plan of the fused
    path: the default ``halo_capacity`` that makes overflow impossible, and
    the bound user-supplied capacities are checked against on-device."""
    worst = worst_halo_parcel(
        halo_capacity_plan(coords, gids, mins, maxs, eps, k_hops))
    return worst.need if worst is not None else 1


def _halo_overflow_error(capacity: int, plan) -> RuntimeError:
    """Actionable under-capacity report: worst parcel + minimal fix."""
    worst = worst_halo_parcel(plan)
    if worst is None:
        return RuntimeError(f"halo capacity overflow: capacity {capacity}")
    over = [p for p in plan if p.need > capacity]
    return RuntimeError(
        f"halo capacity overflow: capacity {capacity} < required "
        f"{worst.need}; {len(over)} parcel(s) exceed it, worst: "
        f"{worst.describe()}. Pass halo_capacity >= {worst.need}, or "
        f"omit it for the exact default.")


def _canonicalize_for_slabs(points, eps, metric: str):
    """Metric entry gate for the distributed drivers: cosine reduces to L2
    on canonical geometry (exact, DESIGN.md S12) so the whole slab + halo
    pipeline runs unchanged; jaccard's packed bitmap lanes do not ride the
    halo exchange yet, so it is rejected loudly rather than mis-joined."""
    from repro.core import metric as metric_lib

    metric_lib.check_metric(metric)
    if metric == "jaccard":
        raise NotImplementedError(
            "distributed jaccard join: bitmap feature lanes do not ride "
            "the slab halo exchange yet; use the single-device fused path "
            "(core.selfjoin.self_join(metric='jaccard'))")
    if metric == "cosine":
        canon = metric_lib.canonicalize(points, eps, metric="cosine")
        return np.asarray(canon.geom), float(canon.eps_geom)
    return points, eps


def distributed_self_join(
    points: np.ndarray,
    eps: float,
    mesh: Mesh,
    *,
    unicomp: bool = True,
    merge_last_dim: Optional[bool] = None,
    bucketed: Optional[bool] = None,
    sort_result: bool = True,
    halo_capacity: Optional[int] = None,
    method: Optional[str] = None,
    emit: Optional[str] = None,
    return_pairs: bool = True,
    metric: str = "l2",
):
    """Distributed self-join returning globally-consistent PAIRS.

    The fused slab join of DESIGN.md S3: points partition into equal-count
    dim-0 slabs (one per device on the mesh's first axis), the eps-halo
    exchange runs on-device via ``shard_map`` + ``ppermute``
    (``make_halo_step``), and each slab then runs the SAME fused fast path
    as the single-device join -- merged-range sweep, occupancy buckets
    restricted to the rows the slab owns, single-pass count -> fill --
    over its (local + halo) candidate set, against the global grid
    geometry.

    Pair ownership (single emission of every pair): the fused kernel's
    UNICOMP/self masks compare GLOBAL ids riding a pad lane
    (``gid_pairs``), so the intra-cell tie-break is device-independent,
    and only rows a slab OWNS launch as queries -- each unordered pair is
    emitted by exactly the slab owning its designated query endpoint,
    whose candidate set provably contains the other endpoint (points
    within eps are within eps in dim 0, hence inside the k-hop halo).

    The result is the same (K, 2) int32 ordered-pair array as
    ``self_join(distance_impl='fused')`` -- bit-identical after the
    ``sort_result`` lexsort (asserted across device counts, UNICOMP and
    sweep modes in tests/test_distributed.py and the CI bench smoke).
    ``return_pairs=False`` runs the count-only fused sweep (no hit
    buffers) and returns the total ordered-pair count.

    ``halo_capacity`` defaults to the exact per-slab requirement
    (``exact_halo_capacity``), making overflow impossible; a smaller
    explicit capacity is CHECKED on-device and raises instead of silently
    dropping candidates.
    """
    from repro.core.selfjoin import (_self_join_count_fused,
                                     _self_join_fused)
    from repro.kernels.fused_join import NP_PAD, resolve_merge_last_dim

    # cosine canonicalizes at entry (unit rows + reduced L2 threshold,
    # DESIGN.md S12); jaccard is rejected -- its bitmap lanes do not ride
    # the halo exchange
    points, eps = _canonicalize_for_slabs(points, eps, metric)
    pts = np.asarray(points)
    npts, n = pts.shape
    if n >= NP_PAD:
        raise ValueError(
            f"distributed pairs need a free global-id pad lane: n_dims={n} "
            f">= NP_PAD={NP_PAD}")
    if npts >= 1 << 24:
        # the gid lane is compared as float; TPU kernels run f32, where
        # ids >= 2^24 collapse and the gid masks silently mis-pair
        raise ValueError(
            f"distributed pairs carry global ids in a float pad lane, "
            f"exact only below 2^24: npts={npts}")
    empty = np.empty((0, 2), np.int32)
    if npts == 0:
        return empty if return_pairs else 0
    # the merged sweep additionally rides the last-dim cell coordinate:
    # two free lanes or fall back to the per-cell stencil
    merged = resolve_merge_last_dim(n, merge_last_dim, extra_lanes=1)
    slab_axis = mesh.axis_names[0]
    n_slabs = mesh.shape[slab_axis]
    coords, gids, _ = partition_points_host(pts, n_slabs)
    mins, maxs = slab_extents(coords, gids)
    k_hops = halo_reach(mins, maxs, eps)
    h_need = exact_halo_capacity(coords, gids, mins, maxs, eps, k_hops)
    # default capacity rounds up to a power of two (capped at the slab
    # size): the halo step is cached per (mesh, cfg), and the exact
    # requirement is data-dependent -- same-shaped workloads with fresh
    # data would otherwise miss the cache and re-trace every call (and
    # leak one executable per distinct capacity)
    h_default = min(_next_pow2(h_need), coords.shape[1])
    cfg = DistJoinConfig(
        pts_per_device=coords.shape[1],
        n_dims=n,
        halo_capacity=(h_default if halo_capacity is None
                       else int(halo_capacity)),
        max_per_cell=0,                  # per-slab grids: no global C bound
        unicomp=unicomp,
        slab_axis=slab_axis,
        model_axis=None,
        k_hops=k_hops,
    )
    step, in_sh = make_halo_step(mesh, cfg)
    coords_dev = jax.device_put(coords.reshape(-1, n), in_sh[0])
    gids_dev = jax.device_put(gids.reshape(-1), in_sh[1])
    cand_c, cand_g, cand_v, cand_o, halo_of = step(
        coords_dev, gids_dev, jnp.asarray(eps, pts.dtype))
    if int(halo_of):
        raise _halo_overflow_error(
            cfg.halo_capacity,
            halo_capacity_plan(coords, gids, mins, maxs, eps, k_hops))
    pc = cfg.pts_per_device + 2 * cfg.halo_capacity * k_hops
    cand_c = np.asarray(cand_c).reshape(n_slabs, pc, n)
    cand_g = np.asarray(cand_g).reshape(n_slabs, pc)
    cand_v = np.asarray(cand_v).reshape(n_slabs, pc)
    cand_o = np.asarray(cand_o).reshape(n_slabs, pc)

    # global geometry, EXACTLY as build_grid_host derives it (the one shared
    # numpy copy): cell coords (and the UNICOMP cell-pair ownership) agree
    # across slabs AND with the single-device join
    gmin, dims = host_grid_geometry(pts, eps)
    gmax = pts.max(axis=0) + eps
    # padded slab builds carry the out-of-set sentinel cell -> static key
    # dtype via device_key_dtype (int32 fast path on small grids)
    slab_kd = device_key_dtype(dims, padded=True)
    # invalid candidate slots: coordinates far outside the volume, so a
    # window that reaches the sentinel cell (a top-corner stencil probe can
    # alias its key) evaluates no spurious hits
    far = gmax + 4.0 * max(float(eps), 1.0)
    gmin_dev = jnp.asarray(gmin)
    dims_dev = jnp.asarray(dims)
    eps_dev = jnp.asarray(eps, pts.dtype)

    chunks = []
    total = 0
    for k in range(n_slabs):
        v = cand_v[k]
        o = cand_o[k] & v
        if not o.any():
            continue
        cc = cand_c[k].copy()
        cc[~v] = far
        index = build_grid_with_geometry_jit(
            jnp.asarray(cc), eps_dev, gmin_dev, dims_dev, jnp.asarray(v),
            key_dtype=slab_kd)
        order = np.asarray(index.order)
        gid_sorted = cand_g[k][order]
        owned_sorted = o[order]
        if return_pairs:
            chunks.append(_self_join_fused(
                index, unicomp=unicomp, sort_result=False, method=method,
                emit=emit, bucketed=bucketed, merged=merged,
                row_ok=owned_sorted, ids=gid_sorted, gid_pairs=True))
        else:
            total += _self_join_count_fused(
                index, unicomp=unicomp, method=method, bucketed=bucketed,
                merged=merged, row_ok=owned_sorted, ids=gid_sorted,
                gid_pairs=True).total_pairs
    if not return_pairs:
        return total
    out = np.concatenate(chunks, axis=0) if chunks else empty
    if sort_result:
        out = out[np.lexsort((out[:, 1], out[:, 0]))]
    return out
