"""Distributed self-join: spatial slab decomposition with eps-halo exchange.

The paper is single-GPU; this module is the scale-out design of DESIGN.md S3.

Decomposition
-------------
Points are partitioned into contiguous slabs along dimension 0 (equal-count
quantile boundaries, computed on the host: ``partition_points_host``). Each
device:

  1. computes the *global* grid geometry (pmin/pmax over the slab axis) so
     cell coordinates are consistent across devices,
  2. exchanges an eps-halo with its left/right slab neighbors via
     ``lax.ppermute`` -- exactly the points within eps (in dim 0) of the
     shared boundary, which is all another slab can ever need,
  3. builds its local grid over (local + halo) candidates and runs the same
     offset-sweep join as the single-device path, counting only pairs whose
     *query* point it owns.

Correctness of single counting: with globally consistent cell coordinates the
UNICOMP half-stencil assigns each unordered adjacent-cell pair to exactly one
directed evaluation; the device owning the query endpoint of that evaluation
is unique, and (since qualifying pairs are within eps in dim 0) its candidate
set is guaranteed to contain the other endpoint. Intra-cell pairs use a
global-id total order as the tie-break, which is device-independent.

The second mesh axis ('model') parallelizes the sweep across *stencil
offsets*: the offset table is sharded over 'model' and partial counts are
psum-reduced -- work-parallelism inside a slab, matching how the LM stack
uses the same axis for tensor parallelism.

Requirements: slab width >= eps (the partitioner warns otherwise; a k-hop
halo generalization is a straightforward extension and is noted in
EXPERIMENTS.md). Halo buffers and cells are capacity-bounded; overflow is
*detected* and reported (never silent).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import grid as grid_lib
from repro.core.grid import build_grid_with_geometry, row_major_strides
from repro.core.selfjoin import _distance_hits_jnp, _gather_batch, _neighbor_ranks_for_delta
from repro.core.stencil import stencil_offsets


@dataclasses.dataclass(frozen=True)
class DistJoinConfig:
    pts_per_device: int          # P: local slab size (padded)
    n_dims: int
    halo_capacity: int           # H: slots per direction per hop
    max_per_cell: int            # C: candidate window per cell
    unicomp: bool = True
    slab_axis: str = "slab"
    model_axis: Optional[str] = "model"   # None -> no offset-parallelism
    distance_impl: str = "jnp"
    # halo reach: a slab narrower than eps (equal-count partition of skewed
    # data at high slab counts) needs points from k>1 slabs away. The driver
    # auto-computes k from the partition boundaries.
    k_hops: int = 1


def partition_points_host(points: np.ndarray, n_slabs: int):
    """Equal-count slab partition along dim 0 (host side).

    Returns (coords (n_slabs, P, n), gids (n_slabs, P) int32 with -1 padding).
    Equal-count boundaries keep devices load-balanced under skew -- the
    distributed analogue of the paper's non-empty-cell index (DESIGN.md S3).
    """
    pts = np.asarray(points)
    npts, n = pts.shape
    order = np.argsort(pts[:, 0], kind="stable")
    slabs = np.array_split(order, n_slabs)
    pcap = max(len(s) for s in slabs)
    coords = np.zeros((n_slabs, pcap, n), dtype=pts.dtype)
    gids = np.full((n_slabs, pcap), -1, dtype=np.int32)
    for k, s in enumerate(slabs):
        coords[k, : len(s)] = pts[s]
        gids[k, : len(s)] = s
        if len(s):
            coords[k, len(s):] = pts[s[0]]  # harmless filler (masked by gid)
    widths = [pts[s, 0].max() - pts[s, 0].min() for s in slabs if len(s) > 1]
    return coords, gids, min(widths) if widths else 0.0


def _halo_exchange(x, valid, axis, n_dev, direction, hops: int = 1):
    """Shift (x, valid) ``hops`` steps along ``axis``. direction=+1 sends
    right (device i's value lands on device i+hops)."""
    idx = jax.lax.axis_index(axis)
    if direction > 0:
        perm = [(i, i + hops) for i in range(n_dev - hops)]
    else:
        perm = [(i, i - hops) for i in range(hops, n_dev)]
    rx = jax.lax.ppermute(x, axis, perm)
    rv = jax.lax.ppermute(valid, axis, perm)
    # devices with no sending neighbor receive zeros; zero validity is False.
    edge = (idx < hops) if direction > 0 else (idx >= n_dev - hops)
    rv = jnp.where(edge, False, rv)
    return rx, rv


def _pack_mask(coords, gids, mask, capacity):
    """Select masked rows into ``capacity`` slots (validity-flagged)."""
    order = jnp.argsort(~mask, stable=True)             # masked rows first
    take = order[:capacity]
    sent = jnp.take(mask, take)
    overflow = mask.sum() > capacity
    return coords[take], gids[take], sent, overflow


def make_distributed_count_step(mesh: Mesh, cfg: DistJoinConfig):
    """Build the jitted distributed count step for ``mesh``.

    Returns (step, in_shardings): ``step(coords, gids, eps)`` with
    coords (S*P, n) sharded over the slab axis, gids (S*P,) likewise;
    returns (ordered_pair_count, halo_overflow, cell_overflow) replicated.
    """
    slab = cfg.slab_axis
    n_slab = mesh.shape[slab]
    axes = (slab,) if cfg.model_axis is None else (slab, cfg.model_axis)
    n_model = 1 if cfg.model_axis is None else mesh.shape[cfg.model_axis]

    offs = stencil_offsets(cfg.n_dims, cfg.unicomp)      # (n_off, n)
    n_off = offs.shape[0]
    n_off_pad = -(-n_off // n_model) * n_model
    offs_pad = np.zeros((n_off_pad, cfg.n_dims), np.int64)
    offs_pad[:n_off] = offs
    off_valid = np.arange(n_off_pad) < n_off
    off_zero = np.zeros(n_off_pad, bool)
    off_zero[:n_off] = np.all(offs == 0, axis=1)

    P_loc, H, C = cfg.pts_per_device, cfg.halo_capacity, cfg.max_per_cell

    def local_fn(coords, gids, eps, offsets, ovalid, ozero):
        coords = coords.reshape(P_loc, cfg.n_dims)
        gids = gids.reshape(P_loc)
        owned = gids >= 0

        # -- global geometry (consistent cell coords across devices) --------
        big = jnp.asarray(jnp.finfo(coords.dtype).max / 4, coords.dtype)
        lo = jnp.where(owned[:, None], coords, big).min(axis=0)
        hi = jnp.where(owned[:, None], coords, -big).max(axis=0)
        gmin = jax.lax.pmin(lo, slab) - eps
        gmax = jax.lax.pmax(hi, slab) + eps
        dims = jnp.ceil((gmax - gmin) / eps).astype(jnp.int64) + 1

        # -- eps-halo exchange with slab neighbors (k-hop) -------------------
        # Receiver r needs every point p with |p.x0 - slab_r| <= eps; when
        # equal-count slabs are narrower than eps (skew), that spans k > 1
        # neighbors. For each hop h: learn the h-hop neighbor's boundary,
        # select exactly what it needs, ship the parcel h hops.
        my_min0 = jnp.where(owned, coords[:, 0], big).min()
        my_max0 = jnp.where(owned, coords[:, 0], -big).max()
        parcels_c, parcels_g, parcels_v = [], [], []
        halo_overflow = jnp.array(False)
        for h in range(1, cfg.k_hops + 1):
            left_max, lm_ok = _halo_exchange(
                my_max0, jnp.array(True), slab, n_slab, +1, hops=h)
            right_min, rm_ok = _halo_exchange(
                my_min0, jnp.array(True), slab, n_slab, -1, hops=h)
            left_max = jnp.where(lm_ok, left_max, -big)
            right_min = jnp.where(rm_ok, right_min, big)
            send_left = owned & (coords[:, 0] <= left_max + eps)
            send_right = owned & (coords[:, 0] >= right_min - eps)
            cl, gl, vl, ofl = _pack_mask(coords, gids, send_left, H)
            cr, gr, vr, ofr = _pack_mask(coords, gids, send_right, H)
            # ship h hops: sending "left" means device i -> i-h, i.e. I
            # receive my h-hop RIGHT neighbor's left edge, and vice versa.
            hcl, hvl = _halo_exchange(cl, vl, slab, n_slab, -1, hops=h)
            hgl, _ = _halo_exchange(gl, vl, slab, n_slab, -1, hops=h)
            hcr, hvr = _halo_exchange(cr, vr, slab, n_slab, +1, hops=h)
            hgr, _ = _halo_exchange(gr, vr, slab, n_slab, +1, hops=h)
            parcels_c += [hcl, hcr]
            parcels_g += [hgl, hgr]
            parcels_v += [hvl, hvr]
            halo_overflow = halo_overflow | ofl | ofr
        halo_coords = jnp.concatenate(parcels_c, axis=0)
        halo_gids = jnp.concatenate(parcels_g, axis=0)
        halo_valid = jnp.concatenate(parcels_v, axis=0)

        n_halo = 2 * H * cfg.k_hops
        anchor = coords[0]
        cand_coords = jnp.concatenate(
            [coords, jnp.where(halo_valid[:, None], halo_coords, anchor)], axis=0
        )
        cand_gids = jnp.concatenate([gids, jnp.where(halo_valid, halo_gids, -1)])
        cand_valid = jnp.concatenate([owned, halo_valid])
        cand_owned = jnp.concatenate([owned, jnp.zeros(n_halo, bool)])

        # -- local grid over candidates, global geometry ---------------------
        # invalid padding slots get the sentinel cell: unreachable as
        # candidates and excluded from the max_per_cell bound.
        index = build_grid_with_geometry(cand_coords, eps, gmin, dims, valid=cand_valid)
        valid_sorted = cand_valid[index.order]
        owned_sorted = cand_owned[index.order]
        gid_sorted = cand_gids[index.order]
        cell_overflow = index.max_per_cell > C

        deltas = offsets @ row_major_strides(dims)
        n_cand = P_loc + n_halo

        def body(total, xs):
            delta, o_ok, o_zero = xs
            nbr_cells = _neighbor_ranks_for_delta(index, delta)
            q, cand, cand_pos, vmask, q_pos, _ = _gather_batch(
                index, nbr_cells, jnp.asarray(0, jnp.int32), n_cand, C
            )
            hits = _distance_hits_jnp(q, cand, vmask, eps)
            hits = hits & valid_sorted[cand_pos] & owned_sorted[q_pos][:, None]
            hits = hits & o_ok
            gq = gid_sorted[q_pos][:, None]
            gc = gid_sorted[cand_pos]
            if cfg.unicomp:
                hits = hits & jnp.where(o_zero, gc > gq, gc != gq)
                inc = 2 * hits.sum()  # every unicomp hit is one unordered pair
            else:
                hits = hits & (gc != gq)
                inc = hits.sum()
            return total + inc.astype(jnp.int64), None

        total, _ = jax.lax.scan(
            body, jnp.zeros((), jnp.int64), (deltas, jnp.asarray(ovalid), jnp.asarray(ozero))
        )
        total = jax.lax.psum(total, axes)
        halo_overflow = jax.lax.pmax(halo_overflow.astype(jnp.int32), axes)
        cell_overflow = jax.lax.pmax(cell_overflow.astype(jnp.int32), axes)
        return total, halo_overflow, cell_overflow

    off_spec = P(cfg.model_axis) if cfg.model_axis else P()
    from repro.compat import shard_map

    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(slab), P(slab), P(), off_spec, off_spec, off_spec),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )

    offsets_dev = jnp.asarray(offs_pad)
    ovalid_dev = jnp.asarray(off_valid)
    ozero_dev = jnp.asarray(off_zero)

    @jax.jit
    def step(coords, gids, eps):
        return fn(coords, gids, eps, offsets_dev, ovalid_dev, ozero_dev)

    in_shardings = (
        NamedSharding(mesh, P(slab)),
        NamedSharding(mesh, P(slab)),
    )
    return step, in_shardings


def distributed_self_join_count(
    points: np.ndarray,
    eps: float,
    mesh: Mesh,
    *,
    unicomp: bool = True,
    halo_capacity: Optional[int] = None,
    max_per_cell: Optional[int] = None,
    model_axis: Optional[str] = None,
) -> int:
    """Host-facing driver: partition, shard, count. Raises on overflow."""
    pts = np.asarray(points)
    slab_axis = mesh.axis_names[0]
    n_slabs = mesh.shape[slab_axis]
    coords, gids, min_width = partition_points_host(pts, n_slabs)
    # halo reach: slab r needs points from any slab within eps along dim 0
    # (skewed data -> narrow slabs -> k > 1). Computed from the partition.
    mins = np.array([coords[i, gids[i] >= 0, 0].min() for i in range(n_slabs)])
    maxs = np.array([coords[i, gids[i] >= 0, 0].max() for i in range(n_slabs)])
    k_hops = 1
    for i in range(n_slabs):
        for h in range(1, n_slabs - i):
            if mins[i + h] <= maxs[i] + eps:
                k_hops = max(k_hops, h)
            else:
                break
    if halo_capacity is None:
        halo_capacity = coords.shape[1]          # worst case: whole slab
    if max_per_cell is None:
        from repro.core.grid import build_grid_host

        max_per_cell = int(build_grid_host(pts, eps).max_per_cell)
    cfg = DistJoinConfig(
        pts_per_device=coords.shape[1],
        n_dims=pts.shape[1],
        halo_capacity=halo_capacity,
        max_per_cell=max(8, -(-max_per_cell // 8) * 8),
        unicomp=unicomp,
        slab_axis=slab_axis,
        model_axis=model_axis,
        k_hops=k_hops,
    )
    step, in_sh = make_distributed_count_step(mesh, cfg)
    coords_flat = coords.reshape(-1, pts.shape[1])
    gids_flat = gids.reshape(-1)
    coords_dev = jax.device_put(coords_flat, in_sh[0])
    gids_dev = jax.device_put(gids_flat, in_sh[1])
    total, halo_of, cell_of = step(coords_dev, gids_dev, jnp.asarray(eps, pts.dtype))
    if int(halo_of):
        raise RuntimeError("halo capacity overflow")
    if int(cell_of):
        raise RuntimeError("max_per_cell overflow")
    return int(total)
