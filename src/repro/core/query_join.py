"""External-query epsilon joins against a prebuilt grid index (DESIGN.md S5).

The paper's self-join is the symmetric case of the operation a similarity
*service* actually runs: an index-once/query-many epsilon join, where the
indexed set D is built once (paper SIV) and request batches of EXTERNAL
query points -- not members of D, possibly outside its volume, possibly
duplicated -- are answered against it (the regime of Gowanlock's Hybrid
KNN-Join and GTS). This module generalizes the fused gather-refine path
(kernels/fused_join.py) to that workload:

  * window descriptors come from each query's OWN cell coordinates under
    D's grid geometry -- by default the MERGED-RANGE 3^(n-1) stencil
    (``grid.external_range_descriptors``, DESIGN.md S7; no UNICOMP,
    external queries have no self-pair or triangle rule), with the
    per-cell 3^n sweep (``grid.external_window_descriptors``) retained
    behind ``merge_last_dim=False`` as the parity oracle, and
  * the same single-pass count -> fill driver returns per-query neighbor
    COUNTS and neighbor PAIRS from one distance evaluation per candidate.

Serving without re-tracing (the bug this subsystem fixes): every jitted
function here is MODULE-LEVEL, so XLA executables are cached by input
shape, and request batches are padded to a small set of static bucket
shapes (``bucket_rows``: tile multiples growing by powers of two), so a
service sees O(log max_batch) compilations total -- not one per request,
which is what the old ``@jax.jit``-closure-per-call ``range_query`` paid.
``TRACE_EVENTS`` / ``executable_cache_stats`` make that property observable
(asserted by launch/serve.py's smoke and tests/test_query_join.py).

Cell-run batching (DESIGN.md S11): by default each request batch is
stably sorted by the query's clipped grid-cell coordinate TUPLE before
launch, so co-located queries form contiguous runs and the fused kernel
(``run_loop=True``) gathers each run's candidate window once instead of
once per row. The inverse permutation restores request row numbering on
the counts and the emitted pair query-ids, so answers are identical to
the unsorted launch (``prepare(index, run_loop=False)`` keeps the
row-loop path as the parity oracle).

Typical use:

    index = build_grid(points, eps)          # once (device build)
    pj = prepare(index)                      # once: pads, offset tables
    res = pj.join(queries)                   # per request: counts + pairs

``epsilon_join(queries, points, eps)`` is the one-shot convenience wrapper;
``core.selfjoin.range_query`` delegates here for backward compatibility.
"""
from __future__ import annotations

import collections
import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grid as grid_lib
from repro.core import metric as metric_lib
from repro.core.grid import (GridIndex, build_grid, cell_run_plan,
                             round_up as _round_up)
from repro.core.stencil import stencil_offsets

_TQ = 128      # query tile rows (kernel grid unit; bucket shapes are multiples)
_C_ALIGN = 8   # window capacity alignment (lane unit, matches selfjoin)
# Device-emit scatter capacity floor: result buffers round up to powers of
# two with this minimum, so a service compiles O(log max_result) emit
# executables over its lifetime instead of one per small result size.
_EMIT_CAP_MIN = 1024

# Trace-time event counters: the body of a jitted function executes only
# while TRACING, so these increments count compilations, not calls. The
# serve smoke and the no-retrace tests snapshot this dict across requests.
#
# Keys prefixed ``metric:`` are SERVING metrics, not compile events: the
# continuous-batching service (launch/serve.py) publishes its queue-depth
# and coalescing counters here so one observability surface carries both.
# They move on every steady-state request, so every no-retrace freeze/
# comparison must drop them (``metric_free`` below does).
TRACE_EVENTS: collections.Counter = collections.Counter()

METRIC_PREFIX = "metric:"


def _bump(name: str) -> None:
    TRACE_EVENTS[name] += 1


def note_metric(name: str, inc: int = 1) -> None:
    """Accumulate a serving metric (``metric:``-prefixed TRACE_EVENTS key)."""
    TRACE_EVENTS[METRIC_PREFIX + name] += int(inc)


def note_metric_peak(name: str, value: int) -> None:
    """Record the running peak of a serving metric (e.g. queue depth)."""
    key = METRIC_PREFIX + name
    TRACE_EVENTS[key] = max(TRACE_EVENTS[key], int(value))


def metric_free(trace_events: dict) -> dict:
    """Drop ``metric:`` keys: the compile-event view of TRACE_EVENTS that
    no-retrace comparisons must use (metrics move per request by design)."""
    return {k: v for k, v in trace_events.items()
            if not k.startswith(METRIC_PREFIX)}


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def bucket_rows(n_queries: int, tile: int = _TQ) -> int:
    """Static padded row count for a request of ``n_queries`` queries.

    Tile-multiple buckets growing by powers of two (128, 256, 512, ...), so
    a service compiles O(log max_batch) executables across all request
    sizes instead of one per distinct size. ``tile`` is the kernel grid
    unit the rows must divide (a capacity class's query tile for the
    occupancy buckets).
    """
    n = max(int(n_queries), 1)
    return tile * _next_pow2(-(-n // tile))


@jax.jit
def _external_windows(index: GridIndex, offsets: jax.Array,
                      queries_pad: jax.Array, q_limit: jax.Array):
    """Jitted descriptor computation; cached by (n_off, Q_pad) shape."""
    _bump("external_windows")
    n = index.grid_min.shape[0]
    return grid_lib.external_window_descriptors(
        index, offsets, queries_pad[:, :n], q_limit)


@jax.jit
def _external_range_windows(index: GridIndex, offsets: jax.Array,
                            lo_off: jax.Array, hi_off: jax.Array,
                            queries_pad: jax.Array, q_limit: jax.Array):
    """Merged-range descriptor computation (DESIGN.md S7); cached by
    (n_off, Q_pad) shape. Returns (win_start, win_count) -- the external
    join does not report per-cell work counters."""
    _bump("external_range_windows")
    n = index.grid_min.shape[0]
    ws, wc, _ = grid_lib.external_range_descriptors(
        index, offsets, lo_off, hi_off, queries_pad[:, :n], q_limit)
    return ws, wc


@jax.jit
def _window_caps(wc: jax.Array) -> jax.Array:
    """Per-query candidate capacity: max window length over all offsets.

    The occupancy-bucketing analogue of ``grid.cell_window_caps`` for
    EXTERNAL queries, whose capacity follows from their own neighborhoods
    rather than the index's cells."""
    _bump("window_caps")
    return wc.max(axis=0)


@jax.jit
def _bucket_select(ws: jax.Array, wc: jax.Array, q_pad: jax.Array,
                   sel: jax.Array, nsel: jax.Array):
    """Gather one capacity class's rows out of the request batch.

    ``sel`` is the class's (qp_b,) row selection (padded with 0); rows >=
    ``nsel`` get zeroed window counts so bucket padding never contributes
    candidates. Cached per (request, bucket) shape pair."""
    _bump("bucket_select")
    ok = jnp.arange(sel.shape[0], dtype=jnp.int32) < nsel
    ws_b = ws[:, sel]
    wc_b = jnp.where(ok[None, :], wc[:, sel], 0)
    return ws_b, wc_b, q_pad[sel]


@partial(jax.jit, static_argnames=("c", "tq", "capacity"))
def _emit_pairs_device(order, hits, counts, slot_base, win_start, *,
                       c: int, tq: int, capacity: int):
    """Device fill: scatter (query row, point id) pairs from the count
    pass's hit set -- no distances, same single-pass discipline as
    ``selfjoin._emit_from_hits`` minus the self-join masking. Query-major
    row order (per query: offsets in sweep order, slots in window order),
    identical to the host emit."""
    _bump("emit_pairs_device")
    n_off, qp, _ = hits.shape
    npts = order.shape[0]
    h = hits.astype(bool).transpose(1, 0, 2).reshape(qp, n_off * c)
    slots = jnp.arange(c, dtype=jnp.int32)
    cand = win_start[:, :, None] + slots[None, None, :]
    cp = jnp.minimum(cand.transpose(1, 0, 2).reshape(qp, n_off * c), npts - 1)
    rank = jnp.cumsum(h, axis=1) - 1              # within-query hit rank
    tile_tot = counts.reshape(-1, tq).sum(axis=1).astype(jnp.int64)
    tile_base = jnp.cumsum(tile_tot) - tile_tot
    qbase = jnp.repeat(tile_base, tq) + slot_base.astype(jnp.int64)
    pos = qbase[:, None] + rank
    qid = jnp.broadcast_to(jnp.arange(qp, dtype=jnp.int32)[:, None], h.shape)
    cid = order[cp]
    keys = jnp.full((capacity,), -1, jnp.int32)
    vals = jnp.full((capacity,), -1, jnp.int32)
    idx = jnp.where(h, pos, capacity)
    keys = keys.at[idx].set(qid, mode="drop")
    vals = vals.at[idx].set(cid, mode="drop")
    return keys, vals


def _emit_pairs_host(order_np: np.ndarray, hits, win_start,
                     npts: int) -> np.ndarray:
    """Host fill: one ``np.nonzero`` compaction of the hit bitmap (default
    off-TPU, same rationale as ``selfjoin._emit_from_hits_host``)."""
    h = np.asarray(hits).astype(bool).transpose(1, 0, 2)   # (Q, n_off, C)
    ws = np.asarray(win_start)                             # (n_off, Q)
    q, off, s = np.nonzero(h)
    cand = np.minimum(ws[off, q] + s, npts - 1)
    return np.stack([q.astype(np.int32), order_np[cand]], axis=1)


@dataclasses.dataclass(frozen=True)
class QueryJoinResult:
    """One request's answer: per-query neighbor counts and (optionally)
    the neighbor pairs as (query row, original point id) int32 rows."""

    counts: np.ndarray                 # (Q,) int32
    pairs: Optional[np.ndarray]        # (K, 2) int32, or None
    n_offsets: int                     # stencil cells probed per query
    bucket_rows: int                   # static padded batch shape used
    emit: Optional[str]                # 'host' | 'device' | None (counts-only)
    candidates_checked: Optional[int]  # total live window slots (with_stats)

    @property
    def total(self) -> int:
        return int(self.counts.sum())


def coalesce_requests(batches) -> tuple[np.ndarray, np.ndarray]:
    """Concatenate request query batches into ONE joint batch.

    The continuous-batching service (launch/serve.py BatchingJoinService)
    merges queued requests into a single fused launch; ``bounds`` records
    each request's row span so ``slice_result`` can hand every caller its
    own answer back. Empty requests are legal (zero-width spans).

    Returns (queries (sum Q_i, n), bounds (k+1,) int64) with request i
    owning joint rows [bounds[i], bounds[i+1]).
    """
    if not batches:
        raise ValueError("coalesce_requests needs at least one request")
    arrs = [np.asarray(b) for b in batches]
    n = arrs[0].shape[1] if arrs[0].ndim == 2 else -1
    for a in arrs:
        if a.ndim != 2 or a.shape[1] != n:
            raise ValueError(
                f"coalesced requests must share (Q_i, n) shape; got "
                f"{[tuple(x.shape) for x in arrs]}")
    sizes = np.asarray([a.shape[0] for a in arrs], np.int64)
    bounds = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
    return np.concatenate(arrs, axis=0), bounds


def slice_result(res: QueryJoinResult, lo: int, hi: int) -> QueryJoinResult:
    """One request's view of a coalesced result: rows [lo, hi).

    Counts slice directly; pairs require the coalesced result SORTED by
    query row (``sort_pairs=True``, the default) so each request's pairs
    are one contiguous span found by binary search, with query ids
    rebased to the request's own row numbering.
    """
    lo, hi = int(lo), int(hi)
    pairs = None
    if res.pairs is not None:
        if res.pairs.shape[0] and np.any(np.diff(res.pairs[:, 0]) < 0):
            raise ValueError(
                "slice_result needs the coalesced pairs sorted by query "
                "row (join with sort_pairs=True)")
        a = np.searchsorted(res.pairs[:, 0], lo, side="left")
        b = np.searchsorted(res.pairs[:, 0], hi, side="left")
        pairs = res.pairs[a:b].copy()
        pairs[:, 0] -= lo
    return QueryJoinResult(
        counts=res.counts[lo:hi], pairs=pairs, n_offsets=res.n_offsets,
        bucket_rows=res.bucket_rows, emit=res.emit, candidates_checked=None)


@dataclasses.dataclass
class _FusedLaunch:
    """One dispatched fused sweep: the request rows it serves, the device
    handles (counts / hit bitmap / slot bases), and the static shapes its
    pair emit needs. ``rows`` is None for a whole-batch (unbucketed)
    launch."""

    rows: Optional[np.ndarray]
    n_rows: int
    hits: Optional[jax.Array]
    counts: jax.Array
    base: jax.Array
    ws: jax.Array
    c: int
    tile: int


class PendingJoin:
    """An in-flight request: every device computation has been DISPATCHED
    but nothing is materialized on the host yet. ``result()`` blocks on
    the device values, emits pairs, and assembles the final
    ``QueryJoinResult``.

    This is the double-buffering seam of the batching service (DESIGN.md
    S8): on an asynchronous backend the host can assemble and dispatch
    batch k+1 between ``join_async(batch_k)`` and ``pending_k.result()``,
    overlapping host-side batch assembly with device execution. The
    split is also what lets a sharded service dispatch every slab's sweep
    before blocking on any of them."""

    def __init__(self, prepared: "PreparedJoin", launches: list, *,
                 wc, qp: int, n_queries: int, return_pairs: bool,
                 sort_pairs: bool, emit: Optional[str], with_stats: bool,
                 perm: Optional[np.ndarray] = None):
        self._pj = prepared
        self._launches = launches
        self._wc = wc
        self._qp = qp
        self._n_queries = n_queries
        # cell-sort permutation (DESIGN.md S11): launch row i served
        # request row perm[i]; None when the batch ran unsorted
        self._perm = perm
        self._return_pairs = return_pairs
        self._sort_pairs = sort_pairs
        self._emit = emit
        self._with_stats = with_stats
        self._result: Optional[QueryJoinResult] = None

    def ready(self) -> bool:
        """True once every launch's device values have landed, i.e.
        ``result()`` will not block on execution. Non-blocking; a backend
        whose arrays lack ``is_ready`` reports True (result() then blocks
        as usual)."""
        if self._result is not None:
            return True
        for ln in self._launches:
            for arr in (ln.counts, ln.hits, ln.base):
                if arr is not None and hasattr(arr, "is_ready"):
                    if not arr.is_ready():
                        return False
        return True

    def result(self) -> QueryJoinResult:
        """Block on the device work and assemble the answer (idempotent)."""
        if self._result is not None:
            return self._result
        from repro.analysis import sanitize
        sanitize.raise_pending()   # REPRO_SANITIZE: we block on devices here
        pj, n_queries = self._pj, self._n_queries
        counts_np = np.zeros(n_queries, np.int32)
        chunks = []
        perm = self._perm
        for ln in self._launches:
            counts_b = np.asarray(ln.counts)[: ln.n_rows]
            rows = (np.arange(ln.n_rows) if ln.rows is None else ln.rows)
            if perm is not None:
                rows = perm[rows]   # sorted-batch row -> request row
            counts_np[rows] = counts_b
            if self._return_pairs:
                p = pj._emit(self._emit, ln.hits, ln.counts, ln.base, ln.ws,
                             c=ln.c, tq=ln.tile, total=int(counts_b.sum()))
                if ln.rows is not None:
                    p[:, 0] = ln.rows[p[:, 0]]   # launch row -> batch row
                if perm is not None:
                    p[:, 0] = perm[p[:, 0]]      # batch row -> request row
                chunks.append(p)
        pairs = None
        if self._return_pairs:
            pairs = (chunks[0] if len(chunks) == 1
                     else np.concatenate(chunks, axis=0) if chunks
                     else np.empty((0, 2), np.int32))
            assert pairs.shape[0] == int(counts_np.sum())
            if self._sort_pairs:
                pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
        cands = (int(np.asarray(self._wc).sum())
                 if self._with_stats else None)
        self._result = QueryJoinResult(
            counts=counts_np, pairs=pairs, n_offsets=pj.n_offsets,
            bucket_rows=self._qp,
            emit=self._emit if self._return_pairs else None,
            candidates_checked=cands)
        self._launches = self._wc = None   # release device references
        return self._result


class PreparedJoin:
    """A grid index prepared for serving: offset tables, the padded points
    copy, and the occupancy capacity classes (DESIGN.md S6) are built ONCE;
    every per-request computation dispatches into module-level jitted
    functions cached per bucket shape.

    When the index is skewed (global window capacity above the smallest
    class), each request batch is partitioned by PER-QUERY candidate
    capacity (max window length over the stencil) and every class launches
    the fused sweep at ITS static capacity -- the serving-side inheritance
    of the self-join's occupancy bucketing. Rows with zero candidates are
    dropped before any launch. The class set and the pow2 ladder of bucket
    sizes are both known at prepare time, so ``warm()`` can compile every
    steady-state executable off the request path.

    ``canon`` (DESIGN.md S12) makes the prepared index METRIC-aware: it is
    the ``metric.Canonical`` the index was built from, and the index must
    be the grid over ``canon.geom`` at ``canon.eps_geom``. Requests then
    arrive in RAW metric form (embeddings for cosine; token-id sets or an
    (Q, V) binary matrix for jaccard) and are canonicalized per request
    against the index's normalization/vocabulary. The metric tag is a
    STATIC of the fused executable, so each metric warms its own ladder;
    per-request thresholds stay traced within a metric.
    """

    def __init__(self, index: GridIndex,
                 merge_last_dim: Optional[bool] = None,
                 run_loop: bool = True,
                 canon: Optional[metric_lib.Canonical] = None):
        from repro.core.grid import capacity_classes, external_range_cap
        from repro.core.stencil import merged_stencil_offsets
        from repro.kernels import autotune
        from repro.kernels.fused_join import (pad_points,
                                              resolve_merge_last_dim)

        self.index = index
        self.n_dims = index.n_dims
        self.eps = float(index.eps)
        self.canon = canon
        self.metric = "l2" if canon is None else canon.metric
        self.n_feat = 0 if canon is None else int(canon.n_feat)
        # metric-units build threshold (cos similarity / jaccard t); the
        # geometry eps above stays the radius the stencil covers
        self.metric_eps = self.eps if canon is None else float(canon.eps)
        # default kernel refine scalar (UNsquared form, see Canonical)
        self.refine = self.eps if canon is None else float(canon.refine)
        feats = None
        if canon is not None:
            metric_lib.check_metric(canon.metric)
            # index.eps round-trips through the geometry dtype (float32
            # for jaccard set sizes), so compare at float32 resolution
            if abs(self.eps - float(canon.eps_geom)) > 1e-5 * max(1.0,
                                                                  self.eps):
                raise ValueError(
                    f"index eps {self.eps} does not match the canonical "
                    f"geometry radius {canon.eps_geom}; build the grid "
                    f"over canon.geom at canon.eps_geom")
            if canon.feats is not None:
                # feature lanes ride sorted point order:
                # points_sorted[i] == points[order[i]]
                feats = jnp.asarray(
                    np.asarray(canon.feats)[np.asarray(index.order)])
        self.feats = feats
        # jaccard geometry is the 1-D set-size axis: the merged-range
        # reduction has nothing to merge there and the bitmap predicate
        # wants the plain per-cell sweep, so force it off
        if self.metric == "jaccard":
            merge_last_dim = False
        # merged-range sweep (DESIGN.md S7): 3^(n-1) reduced offsets, full
        # stencil (external queries have no UNICOMP)
        self.merged = resolve_merge_last_dim(self.n_dims, merge_last_dim)
        if self.merged:
            self.c = external_range_cap(index, _C_ALIGN)
            reduced, lo, hi = merged_stencil_offsets(self.n_dims,
                                                     unicomp=False)
            self.n_offsets = reduced.shape[0]
            self.offsets = jnp.asarray(reduced)              # (n_off, n)
            self.lo_off = jnp.asarray(lo)
            self.hi_off = jnp.asarray(hi)
            self.points_pad = pad_points(
                index.points_sorted, self.c,
                last_coord=grid_lib.point_last_coords(index), feats=feats)
        else:
            self.c = _round_up(max(int(index.max_per_cell), 1), _C_ALIGN)
            offs = stencil_offsets(self.n_dims, unicomp=False)  # full 3^n
            self.n_offsets = offs.shape[0]
            self.offsets = jnp.asarray(offs)                 # (n_off, n)
            self.points_pad = pad_points(index.points_sorted, self.c,
                                         feats=feats)
        self.is_zero = jnp.zeros((self.n_offsets,), jnp.int32)  # unused mask
        self.order_np = np.asarray(index.order)
        self.dtype = np.dtype(index.points_sorted.dtype)
        self.gmin_np = np.asarray(index.grid_min)
        self.classes = capacity_classes(self.c, _C_ALIGN)
        # Per-class query tile from the measured table, clamped to the
        # service's request-padding unit so bucket_rows stays the public
        # shape contract (multiples of _TQ).
        self.tiles = {cb: min(autotune.fused_tile(self.n_dims, cb,
                                                  metric=self.metric), _TQ)
                      for cb in self.classes}
        self.bucketed = len(self.classes) > 1
        # cell-run batching (DESIGN.md S11): sort request batches by grid
        # cell so the fused kernel gathers each run's window once
        self.run_loop = bool(run_loop)
        self.q_pos0: dict = {}   # zeros (qp,) per launch shape (external)

    def _pad_queries(self, q: np.ndarray,
                     feats: Optional[np.ndarray] = None
                     ) -> tuple[jax.Array, int]:
        # _TQ is always the request padding unit: class tiles are clamped
        # to _TQ at construction, so every launch divides it. Lane width
        # comes from the padded points copy, so queries and candidates
        # always agree (the kernel derives its statics the same way).
        qp = bucket_rows(q.shape[0])
        q_pad = np.zeros((qp, int(self.points_pad.shape[1])), self.dtype)
        q_pad[: q.shape[0], : self.n_dims] = q
        if feats is not None:
            q_pad[: q.shape[0],
                  self.n_dims: self.n_dims + self.n_feat] = feats
        if self.merged:
            # last-dim cell coordinate rides the first pad lane AFTER any
            # feature lanes (kernel boundary mask); same float computation
            # as grid.cell_coords, clipped -- any query whose raw
            # coordinate leaves the clip range has no live window, so the
            # clip never changes a mask
            qc = np.floor((q[:, -1] - self.gmin_np[-1]) / self.eps)
            q_pad[: q.shape[0], self.n_dims + self.n_feat] = np.clip(
                qc, -(1 << 24), 1 << 24)
        return jnp.asarray(q_pad), qp

    def _q_pos(self, qp: int) -> jax.Array:
        """External queries have no sorted position; the kernel's q_pos
        prefetch is a cached zeros array per launch shape."""
        z = self.q_pos0.get(qp)
        if z is None:
            z = jnp.zeros((qp,), jnp.int32)
            self.q_pos0[qp] = z
        return z

    def _launch_run_ord(self, gid: Optional[np.ndarray], qp_b: int,
                        tile: int) -> jax.Array:
        """run_ord scalar-prefetch for one launch: the launch rows' cell
        group ids padded to the launch shape with the edge id (pad rows
        join the LAST run -- inert, their window counts are zeroed by the
        q_limit / bucket masks). ``gid`` is None for an empty batch."""
        if gid is None or not gid.size:
            return self._q_pos(qp_b)   # zeros: one run per tile
        ids = np.full(qp_b, gid[-1], np.int64)
        ids[: gid.size] = gid
        return jnp.asarray(cell_run_plan(ids, tile).run_ord)

    def _emit(self, emit, hits, counts, base, ws, *, c: int, tq: int,
              total: int) -> np.ndarray:
        """One launch's fill: host bitmap compaction or device scatter."""
        if emit == "host":
            return _emit_pairs_host(
                self.order_np, hits, ws, self.index.num_points)
        if emit == "device":
            capacity = max(_next_pow2(total), _EMIT_CAP_MIN)
            keys, vals = _emit_pairs_device(
                self.index.order, hits, counts, base, ws,
                c=c, tq=tq, capacity=capacity)
            return np.stack(
                [np.asarray(keys)[:total], np.asarray(vals)[:total]], axis=1)
        raise ValueError(f"unknown emit backend {emit!r}")

    def join_async(self, queries, *, eps: Optional[float] = None,
                   return_pairs: bool = True, sort_pairs: bool = True,
                   emit: Optional[str] = None, method: Optional[str] = None,
                   with_stats: bool = False) -> PendingJoin:
        """Dispatch an epsilon join and return WITHOUT materializing.

        Runs the launch half of ``join`` -- query padding, window
        descriptors, every fused-sweep dispatch -- and hands back a
        ``PendingJoin`` whose ``result()`` blocks on the device values and
        assembles the ``QueryJoinResult``. The batching service overlaps
        host assembly of the next coalesced batch with the device
        execution of this one through exactly this seam (DESIGN.md S8);
        the occupancy partition of a skewed index still costs one small
        host sync here (the per-query capacity vector decides the launch
        shapes, so it cannot be deferred).
        """
        from repro.kernels import ops

        qf = None
        if self.metric == "l2":
            q = np.asarray(queries, self.dtype)
        elif isinstance(queries, tuple) and len(queries) == 2:
            # pre-canonicalized (geometry, features) pair: the batching
            # service canonicalizes once at admission so coalesced parts
            # and slab fan-outs do not re-normalize/re-pack per launch
            qg, qf = queries
            q = np.asarray(qg, self.dtype)
            qf = None if qf is None else np.asarray(qf)
        else:
            # raw metric input -> (geometry, features) under the INDEX's
            # canonical form (unit rows for cosine; sizes + packed bitmap
            # words against the index vocabulary for jaccard)
            qg, qf = metric_lib.canonicalize_queries(self.canon, queries)
            q = np.asarray(qg, self.dtype)
        if q.ndim != 2 or q.shape[1] != self.n_dims:
            raise ValueError(f"queries must be (Q, {self.n_dims}), "
                             f"got {q.shape}")
        if eps is None:
            eps = self.refine
        else:
            # per-request threshold in METRIC units -> kernel scalar,
            # validating the build-time stencil still covers it
            eps = metric_lib.request_scalar(
                self.metric, float(eps), index_eps=self.metric_eps,
                index_eps_geom=self.eps)
        n_queries = q.shape[0]
        perm = gid = None
        if self.run_loop and n_queries:
            # Cell-run batching (DESIGN.md S11): stable sort by the
            # clipped cell-coordinate TUPLE -- exact cell identity (a
            # linearized key could alias distinct out-of-grid cells) --
            # so co-located queries form contiguous runs. Out-of-grid
            # clip collisions are safe: such queries have no live window.
            qc = np.clip(np.floor((q - self.gmin_np[None, :]) / self.eps),
                         -(1 << 24), 1 << 24).astype(np.int64)
            perm = np.lexsort(qc.T)
            q, qc = q[perm], qc[perm]
            if qf is not None:
                qf = qf[perm]
            head = np.ones(n_queries, bool)
            head[1:] = np.any(qc[1:] != qc[:-1], axis=1)
            gid = np.cumsum(head) - 1      # per-row cell group id
        q_dev, qp = self._pad_queries(q, qf)
        if self.merged:
            ws, wc = _external_range_windows(
                self.index, self.offsets, self.lo_off, self.hi_off, q_dev,
                jnp.asarray(n_queries, jnp.int32))
        else:
            ws, wc = _external_windows(
                self.index, self.offsets, q_dev,
                jnp.asarray(n_queries, jnp.int32))
        if return_pairs and emit is None:
            emit = "device" if jax.default_backend() == "tpu" else "host"
        launches = []
        if not self.bucketed:
            tile = self.tiles[self.c]
            ro = (self._launch_run_ord(gid, qp, tile)
                  if self.run_loop else None)
            hits, counts, base = ops.fused_join_hits(
                self.points_pad, q_dev, ws, wc, self.is_zero,
                self._q_pos(qp), eps, c=self.c, n_real=self.n_dims,
                unicomp=False, external=True, merged=self.merged, tq=tile,
                keep_hits=return_pairs, run_ord=ro,
                run_loop=self.run_loop, method=method,
                metric=self.metric, n_feat=self.n_feat)
            launches.append(_FusedLaunch(
                rows=None, n_rows=n_queries, hits=hits, counts=counts,
                base=base, ws=ws, c=self.c, tile=tile))
        else:
            caps = np.asarray(_window_caps(wc))[:n_queries]
            caps_aligned = np.minimum(_round_up(caps, _C_ALIGN), self.c)
            cls = np.searchsorted(np.asarray(self.classes), caps_aligned)
            for k, cb in enumerate(self.classes):
                rows = np.flatnonzero((cls == k) & (caps > 0))
                if not rows.size:
                    continue   # empty class (or all-miss rows: counts stay 0)
                tile = self.tiles[cb]
                qp_b = bucket_rows(rows.size, tile)
                sel = np.zeros(qp_b, np.int32)
                sel[:rows.size] = rows
                ws_b, wc_b, q_b = _bucket_select(
                    ws, wc, q_dev, jnp.asarray(sel),
                    jnp.asarray(rows.size, jnp.int32))
                # rows ascend batch order, so equal-cell rows stay
                # contiguous within the class launch
                ro = (self._launch_run_ord(gid[rows], qp_b, tile)
                      if self.run_loop else None)
                hits, counts, base = ops.fused_join_hits(
                    self.points_pad, q_b, ws_b, wc_b, self.is_zero,
                    self._q_pos(qp_b), eps, c=cb, n_real=self.n_dims,
                    unicomp=False, external=True, merged=self.merged,
                    tq=tile, keep_hits=return_pairs, run_ord=ro,
                    run_loop=self.run_loop, method=method,
                    metric=self.metric, n_feat=self.n_feat)
                launches.append(_FusedLaunch(
                    rows=rows, n_rows=rows.size, hits=hits, counts=counts,
                    base=base, ws=ws_b, c=cb, tile=tile))
        return PendingJoin(
            self, launches, wc=wc, qp=qp, n_queries=n_queries,
            return_pairs=return_pairs, sort_pairs=sort_pairs, emit=emit,
            with_stats=with_stats, perm=perm)

    def join(self, queries, *, eps: Optional[float] = None,
             return_pairs: bool = True, sort_pairs: bool = True,
             emit: Optional[str] = None, method: Optional[str] = None,
             with_stats: bool = False) -> QueryJoinResult:
        """Epsilon join of a query batch against the prepared index.

        ``eps`` is in METRIC units and defaults to the index's build
        threshold; per-request overrides must stay within what the
        build-time stencil covers (smaller radii for l2, HIGHER similarity
        floors for cosine/jaccard -- ``metric.request_scalar`` validates).
        Counts include an indexed point that exactly coincides with a
        query (external queries have no self). The threshold is a traced
        operand of the fused sweep, so serving a MIX of thresholds within
        one metric hits one executable.

        On a skewed index the batch is served through the occupancy
        buckets: per-query capacities from the window descriptors, one
        fused launch per populated class at its own static capacity,
        counts scattered back to request rows and pair query-ids remapped.
        The pair SET matches the single-capacity launch bit-for-bit after
        sorting (row order across classes differs; ``sort_pairs``
        canonicalizes). ``join_async`` is the non-blocking half.
        """
        return self.join_async(
            queries, eps=eps, return_pairs=return_pairs,
            sort_pairs=sort_pairs, emit=emit, method=method,
            with_stats=with_stats).result()

    def counts(self, queries, *, eps: Optional[float] = None,
               method: Optional[str] = None) -> np.ndarray:
        """Counts-only fast path (no O(n_off * Q * C) hit buffer)."""
        return self.join(queries, eps=eps, return_pairs=False,
                         method=method).counts

    def _warm_queries(self, n: int):
        """A metric-VALID dummy batch of ``n`` raw queries: warm joins run
        through the same canonicalization as real requests, which rejects
        zero vectors under cosine and expects token sets under jaccard."""
        if self.metric == "cosine":
            raw = np.zeros((n, self.n_dims), self.dtype)
            raw[:, 0] = 1.0
            return raw
        if self.metric == "jaccard":
            return [() for _ in range(n)]   # empty token sets (size 0)
        return np.zeros((n, self.n_dims), self.dtype)

    def warm(self, batch_size: int, *, return_pairs: Optional[bool] = None
             ) -> int:
        """Compile every steady-state executable for requests of up to
        ``batch_size`` queries, OFF the request path.

        The request-level shapes are warmed by dummy joins; on a skewed
        index the per-class row partition of a future request is data-
        dependent, but its SHAPE space is not: each class's bucket size is
        a pow2 tile multiple bounded by the batch, so every (class, size)
        executable is compiled here and ``assert_no_retrace`` can hold
        over arbitrary steady-state request mixes. ``return_pairs=None``
        (default) warms BOTH the pair-serving and counts-only sweeps.
        Returns the request bucket's padded row count.
        """
        from repro.kernels import ops

        n = max(int(batch_size), 1)
        variants = ((True, False) if return_pairs is None
                    else (bool(return_pairs),))
        for keep in variants:
            self.join(self._warm_queries(n), return_pairs=keep)
        if self.bucketed:
            qp = bucket_rows(n)
            ws = jnp.zeros((self.n_offsets, qp), jnp.int32)
            wc = jnp.zeros((self.n_offsets, qp), jnp.int32)
            q_pad = jnp.zeros((qp, int(self.points_pad.shape[1])),
                              self.dtype)
            for cb in self.classes:
                tile = self.tiles[cb]
                s = tile
                # ladder bound: ANY request landing in this request bucket
                # (up to qp rows, not just n) may put all its rows in one
                # class, so warm class launches up to bucket_rows(qp, tile)
                while s <= bucket_rows(qp, tile):
                    ws_b, wc_b, q_b = _bucket_select(
                        ws, wc, q_pad, jnp.zeros((s,), jnp.int32),
                        jnp.asarray(0, jnp.int32))
                    for keep in variants:
                        # zeros run_ord (one run per tile) is a valid
                        # plan; only the run_loop STATIC flag must match
                        # steady state for the warm to cover it
                        _, counts, _ = ops.fused_join_hits(
                            self.points_pad, q_b, ws_b, wc_b, self.is_zero,
                            self._q_pos(s), self.refine, c=cb,
                            n_real=self.n_dims, unicomp=False,
                            external=True, merged=self.merged, tq=tile,
                            keep_hits=keep,
                            run_ord=(self._q_pos(s) if self.run_loop
                                     else None),
                            run_loop=self.run_loop,
                            metric=self.metric, n_feat=self.n_feat)
                        np.asarray(counts)   # block: compile now, not later
                    s *= 2
        # single-class requests pad with _TQ too (class tiles are clamped
        # to _TQ at construction, so _TQ is always the padding unit)
        return bucket_rows(n)


def prepare(index: GridIndex,
            merge_last_dim: Optional[bool] = None,
            run_loop: bool = True,
            canon: Optional[metric_lib.Canonical] = None) -> PreparedJoin:
    """Prepare a grid index for repeated external-query joins.

    ``merge_last_dim`` (default on) serves requests through the 3^(n-1)
    merged-range stencil (DESIGN.md S7); ``False`` keeps the per-cell
    3^n sweep as the parity oracle. ``run_loop`` (default on) cell-sorts
    request batches and shares each run's window gather (DESIGN.md S11);
    ``False`` keeps the unsorted row-loop launch as the parity oracle.
    ``canon`` attaches the metric the index was canonicalized for
    (DESIGN.md S12); requests then arrive in raw metric form."""
    return PreparedJoin(index, merge_last_dim=merge_last_dim,
                        run_loop=run_loop, canon=canon)


def epsilon_join(queries, points, eps: Optional[float] = None, *,
                 index: Optional[GridIndex] = None,
                 return_pairs: bool = True, sort_pairs: bool = True,
                 emit: Optional[str] = None, method: Optional[str] = None,
                 with_stats: bool = False,
                 merge_last_dim: Optional[bool] = None,
                 metric: str = "l2",
                 vocab: Optional[int] = None) -> QueryJoinResult:
    """One-shot external-query epsilon join: counts and pairs of all
    indexed points within ``eps`` of each query.

    Builds the grid over ``points`` unless ``index`` is supplied. Services
    answering many requests against one dataset should hold a
    ``prepare(index)`` object instead (launch/serve.py's JoinService does);
    the underlying executables are shared either way -- this wrapper only
    re-pays the cheap host-side preparation per call.

    ``metric`` selects the similarity (DESIGN.md S12): ``eps`` is then the
    metric-units threshold (minimum cosine similarity / minimum Jaccard
    similarity), ``points`` the raw dataset (or a pre-built
    ``metric.Canonical``), and ``queries`` raw metric input. ``vocab``
    fixes the jaccard packing vocabulary. ``index`` must be None for
    non-L2 metrics (the grid is built over the canonical geometry here).
    """
    metric_lib.check_metric(metric)
    if metric != "l2" or isinstance(points, metric_lib.Canonical):
        if index is not None:
            raise ValueError(
                "epsilon_join: pass raw points (or a Canonical), not a "
                "prebuilt index, for non-L2 metrics -- the grid must be "
                "built over the canonical geometry")
        canon = (points if isinstance(points, metric_lib.Canonical)
                 else metric_lib.canonicalize(points, eps, metric=metric,
                                              vocab=vocab))
        idx = build_grid(np.asarray(canon.geom), float(canon.eps_geom))
        return prepare(idx, merge_last_dim=merge_last_dim, canon=canon).join(
            queries, eps=None, return_pairs=return_pairs,
            sort_pairs=sort_pairs, emit=emit, method=method,
            with_stats=with_stats)
    if index is None:
        index = build_grid(np.asarray(points), float(eps))
    return prepare(index, merge_last_dim=merge_last_dim).join(
        queries, eps=eps, return_pairs=return_pairs, sort_pairs=sort_pairs,
        emit=emit, method=method, with_stats=with_stats)


def executable_cache_stats() -> dict:
    """Compilation-cache observability for the serving path.

    Returns per-function XLA executable-cache sizes plus the trace-event
    counters; a healthy steady-state service shows these CONSTANT across
    requests (asserted by launch/serve.py and tests/test_query_join.py).
    """
    from repro.kernels import fused_join as fj

    def size(f) -> int:
        try:
            return int(f._cache_size())
        except Exception:
            return -1

    return {
        "external_windows": size(_external_windows),
        "external_range_windows": size(_external_range_windows),
        "window_caps": size(_window_caps),
        "bucket_select": size(_bucket_select),
        "fused_reference": size(fj._fused_join_hits_reference),
        "fused_pallas": size(fj._fused_join_hits_pallas),
        "emit_pairs_device": size(_emit_pairs_device),
        # prepare-path builders/planners (DESIGN.md S10): these compile
        # during build/reindex, never per steady-state request, so the
        # serve watchdog exempts them (launch/serve.py assert_no_retrace).
        "grid_build": size(grid_lib.build_grid_with_geometry_jit),
        "grid_caps": size(grid_lib._cell_window_caps_device),
        "grid_extspan": size(grid_lib._external_span_device),
        "trace_events": dict(TRACE_EVENTS),
    }
