"""External-query epsilon joins against a prebuilt grid index (DESIGN.md S5).

The paper's self-join is the symmetric case of the operation a similarity
*service* actually runs: an index-once/query-many epsilon join, where the
indexed set D is built once (paper SIV) and request batches of EXTERNAL
query points -- not members of D, possibly outside its volume, possibly
duplicated -- are answered against it (the regime of Gowanlock's Hybrid
KNN-Join and GTS). This module generalizes the fused gather-refine path
(kernels/fused_join.py) to that workload:

  * window descriptors come from each query's OWN cell coordinates under
    D's grid geometry (``grid.external_window_descriptors``: coordinate-
    space bounds masking, full 3^n stencil -- no UNICOMP, external queries
    have no self-pair or triangle rule), and
  * the same single-pass count -> fill driver returns per-query neighbor
    COUNTS and neighbor PAIRS from one distance evaluation per candidate.

Serving without re-tracing (the bug this subsystem fixes): every jitted
function here is MODULE-LEVEL, so XLA executables are cached by input
shape, and request batches are padded to a small set of static bucket
shapes (``bucket_rows``: tile multiples growing by powers of two), so a
service sees O(log max_batch) compilations total -- not one per request,
which is what the old ``@jax.jit``-closure-per-call ``range_query`` paid.
``TRACE_EVENTS`` / ``executable_cache_stats`` make that property observable
(asserted by launch/serve.py's smoke and tests/test_query_join.py).

Typical use:

    index = build_grid_host(points, eps)     # once
    pj = prepare(index)                      # once: pads, offset tables
    res = pj.join(queries)                   # per request: counts + pairs

``epsilon_join(queries, points, eps)`` is the one-shot convenience wrapper;
``core.selfjoin.range_query`` delegates here for backward compatibility.
"""
from __future__ import annotations

import collections
import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grid as grid_lib
from repro.core.grid import GridIndex, build_grid_host
from repro.core.stencil import stencil_offsets

_TQ = 128      # query tile rows (kernel grid unit; bucket shapes are multiples)
_C_ALIGN = 8   # window capacity alignment (lane unit, matches selfjoin)
# Device-emit scatter capacity floor: result buffers round up to powers of
# two with this minimum, so a service compiles O(log max_result) emit
# executables over its lifetime instead of one per small result size.
_EMIT_CAP_MIN = 1024

# Trace-time event counters: the body of a jitted function executes only
# while TRACING, so these increments count compilations, not calls. The
# serve smoke and the no-retrace tests snapshot this dict across requests.
TRACE_EVENTS: collections.Counter = collections.Counter()


def _bump(name: str) -> None:
    TRACE_EVENTS[name] += 1


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def bucket_rows(n_queries: int) -> int:
    """Static padded row count for a request of ``n_queries`` queries.

    Tile-multiple buckets growing by powers of two (128, 256, 512, ...), so
    a service compiles O(log max_batch) executables across all request
    sizes instead of one per distinct size.
    """
    n = max(int(n_queries), 1)
    return _TQ * _next_pow2(-(-n // _TQ))


@jax.jit
def _external_windows(index: GridIndex, offsets: jax.Array,
                      queries_pad: jax.Array, q_limit: jax.Array):
    """Jitted descriptor computation; cached by (n_off, Q_pad) shape."""
    _bump("external_windows")
    n = index.grid_min.shape[0]
    return grid_lib.external_window_descriptors(
        index, offsets, queries_pad[:, :n], q_limit)


@partial(jax.jit, static_argnames=("c", "tq", "capacity"))
def _emit_pairs_device(order, hits, counts, slot_base, win_start, *,
                       c: int, tq: int, capacity: int):
    """Device fill: scatter (query row, point id) pairs from the count
    pass's hit set -- no distances, same single-pass discipline as
    ``selfjoin._emit_from_hits`` minus the self-join masking. Query-major
    row order (per query: offsets in sweep order, slots in window order),
    identical to the host emit."""
    _bump("emit_pairs_device")
    n_off, qp, _ = hits.shape
    npts = order.shape[0]
    h = hits.astype(bool).transpose(1, 0, 2).reshape(qp, n_off * c)
    slots = jnp.arange(c, dtype=jnp.int32)
    cand = win_start[:, :, None] + slots[None, None, :]
    cp = jnp.minimum(cand.transpose(1, 0, 2).reshape(qp, n_off * c), npts - 1)
    rank = jnp.cumsum(h, axis=1) - 1              # within-query hit rank
    tile_tot = counts.reshape(-1, tq).sum(axis=1).astype(jnp.int64)
    tile_base = jnp.cumsum(tile_tot) - tile_tot
    qbase = jnp.repeat(tile_base, tq) + slot_base.astype(jnp.int64)
    pos = qbase[:, None] + rank
    qid = jnp.broadcast_to(jnp.arange(qp, dtype=jnp.int32)[:, None], h.shape)
    cid = order[cp]
    keys = jnp.full((capacity,), -1, jnp.int32)
    vals = jnp.full((capacity,), -1, jnp.int32)
    idx = jnp.where(h, pos, capacity)
    keys = keys.at[idx].set(qid, mode="drop")
    vals = vals.at[idx].set(cid, mode="drop")
    return keys, vals


def _emit_pairs_host(order_np: np.ndarray, hits, win_start,
                     npts: int) -> np.ndarray:
    """Host fill: one ``np.nonzero`` compaction of the hit bitmap (default
    off-TPU, same rationale as ``selfjoin._emit_from_hits_host``)."""
    h = np.asarray(hits).astype(bool).transpose(1, 0, 2)   # (Q, n_off, C)
    ws = np.asarray(win_start)                             # (n_off, Q)
    q, off, s = np.nonzero(h)
    cand = np.minimum(ws[off, q] + s, npts - 1)
    return np.stack([q.astype(np.int32), order_np[cand]], axis=1)


@dataclasses.dataclass(frozen=True)
class QueryJoinResult:
    """One request's answer: per-query neighbor counts and (optionally)
    the neighbor pairs as (query row, original point id) int32 rows."""

    counts: np.ndarray                 # (Q,) int32
    pairs: Optional[np.ndarray]        # (K, 2) int32, or None
    n_offsets: int                     # stencil cells probed per query
    bucket_rows: int                   # static padded batch shape used
    emit: Optional[str]                # 'host' | 'device' | None (counts-only)
    candidates_checked: Optional[int]  # total live window slots (with_stats)

    @property
    def total(self) -> int:
        return int(self.counts.sum())


class PreparedJoin:
    """A grid index prepared for serving: offset tables and the padded
    points copy are built ONCE; every per-request computation dispatches
    into module-level jitted functions cached per bucket shape."""

    def __init__(self, index: GridIndex):
        from repro.kernels.fused_join import pad_points

        self.index = index
        self.n_dims = index.n_dims
        self.eps = float(index.eps)
        self.c = _round_up(max(int(index.max_per_cell), 1), _C_ALIGN)
        offs = stencil_offsets(self.n_dims, unicomp=False)   # full 3^n
        self.n_offsets = offs.shape[0]
        self.offsets = jnp.asarray(offs)                     # (n_off, n)
        self.is_zero = jnp.zeros((self.n_offsets,), jnp.int32)  # unused mask
        self.points_pad = pad_points(index.points_sorted, self.c)
        self.order_np = np.asarray(index.order)
        self.dtype = np.dtype(index.points_sorted.dtype)
        self.q_start0 = jnp.zeros((), jnp.int32)

    def _pad_queries(self, q: np.ndarray) -> tuple[jax.Array, int]:
        from repro.kernels.fused_join import NP_PAD

        qp = bucket_rows(q.shape[0])
        q_pad = np.zeros((qp, NP_PAD), self.dtype)
        q_pad[: q.shape[0], : self.n_dims] = q
        return jnp.asarray(q_pad), qp

    def join(self, queries, *, eps: Optional[float] = None,
             return_pairs: bool = True, sort_pairs: bool = True,
             emit: Optional[str] = None, method: Optional[str] = None,
             with_stats: bool = False) -> QueryJoinResult:
        """Epsilon join of a query batch against the prepared index.

        ``eps`` defaults to the index's build epsilon and may be smaller
        (the +/-1-cell stencil only covers the build radius; a larger
        radius needs a rebuilt grid). Counts include an indexed point that
        exactly coincides with a query (external queries have no self).
        """
        from repro.kernels import ops

        q = np.asarray(queries, self.dtype)
        if q.ndim != 2 or q.shape[1] != self.n_dims:
            raise ValueError(f"queries must be (Q, {self.n_dims}), "
                             f"got {q.shape}")
        if eps is None:
            eps = self.eps
        elif eps > self.eps * (1 + 1e-12):
            raise ValueError(
                f"query eps {eps} exceeds index build eps {self.eps}; the "
                f"adjacent-cell stencil only covers the build radius")
        n_queries = q.shape[0]
        q_dev, qp = self._pad_queries(q)
        ws, wc = _external_windows(
            self.index, self.offsets, q_dev,
            jnp.asarray(n_queries, jnp.int32))
        hits, counts, base = ops.fused_join_hits(
            self.points_pad, q_dev, ws, wc, self.is_zero, self.q_start0,
            eps, c=self.c, n_real=self.n_dims, unicomp=False, external=True,
            tq=_TQ, keep_hits=return_pairs, method=method)
        counts_np = np.asarray(counts)[:n_queries]
        pairs = None
        if return_pairs:
            if emit is None:
                emit = ("device" if jax.default_backend() == "tpu"
                        else "host")
            if emit == "host":
                pairs = _emit_pairs_host(
                    self.order_np, hits, ws, self.index.num_points)
            elif emit == "device":
                total = int(counts_np.sum())
                capacity = max(_next_pow2(total), _EMIT_CAP_MIN)
                keys, vals = _emit_pairs_device(
                    self.index.order, hits, counts, base, ws,
                    c=self.c, tq=_TQ, capacity=capacity)
                pairs = np.stack(
                    [np.asarray(keys)[:total], np.asarray(vals)[:total]],
                    axis=1)
            else:
                raise ValueError(f"unknown emit backend {emit!r}")
            assert pairs.shape[0] == int(counts_np.sum())
            if sort_pairs:
                pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
        cands = int(np.asarray(wc).sum()) if with_stats else None
        return QueryJoinResult(
            counts=counts_np, pairs=pairs, n_offsets=self.n_offsets,
            bucket_rows=qp, emit=emit if return_pairs else None,
            candidates_checked=cands)

    def counts(self, queries, *, eps: Optional[float] = None,
               method: Optional[str] = None) -> np.ndarray:
        """Counts-only fast path (no O(n_off * Q * C) hit buffer)."""
        return self.join(queries, eps=eps, return_pairs=False,
                         method=method).counts


def prepare(index: GridIndex) -> PreparedJoin:
    """Prepare a grid index for repeated external-query joins."""
    return PreparedJoin(index)


def epsilon_join(queries, points, eps: Optional[float] = None, *,
                 index: Optional[GridIndex] = None,
                 return_pairs: bool = True, sort_pairs: bool = True,
                 emit: Optional[str] = None, method: Optional[str] = None,
                 with_stats: bool = False) -> QueryJoinResult:
    """One-shot external-query epsilon join: counts and pairs of all
    indexed points within ``eps`` of each query.

    Builds the grid over ``points`` unless ``index`` is supplied. Services
    answering many requests against one dataset should hold a
    ``prepare(index)`` object instead (launch/serve.py's JoinService does);
    the underlying executables are shared either way -- this wrapper only
    re-pays the cheap host-side preparation per call.
    """
    if index is None:
        index = build_grid_host(np.asarray(points), float(eps))
    return prepare(index).join(
        queries, eps=eps, return_pairs=return_pairs, sort_pairs=sort_pairs,
        emit=emit, method=method, with_stats=with_stats)


def executable_cache_stats() -> dict:
    """Compilation-cache observability for the serving path.

    Returns per-function XLA executable-cache sizes plus the trace-event
    counters; a healthy steady-state service shows these CONSTANT across
    requests (asserted by launch/serve.py and tests/test_query_join.py).
    """
    from repro.kernels import fused_join as fj

    def size(f) -> int:
        try:
            return int(f._cache_size())
        except Exception:
            return -1

    return {
        "external_windows": size(_external_windows),
        "fused_reference": size(fj._fused_join_hits_reference),
        "fused_pallas": size(fj._fused_join_hits_pallas),
        "emit_pairs_device": size(_emit_pairs_device),
        "trace_events": dict(TRACE_EVENTS),
    }
