"""GPU brute-force baseline (paper SVI-B): O(|D|^2) nested-loop join.

The paper uses |D| threads, each comparing its point against all others, to
show that GPU-SJ's gains are not merely GPU throughput. Our TPU analogue is a
row-tiled sweep: each scan step evaluates a (tile x |D|) distance block --
this is also the shape the Pallas kernel (kernels/distance_tile.py) executes
on the MXU; ``distance_impl='pallas'`` routes the block computation there.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metric as metric_lib


def _block_hits_jnp(q, pts, eps):
    """(T,n) x (N,n) -> (T,N) bool: ||q - p||^2 <= eps^2."""
    d2 = jnp.sum((q[:, None, :] - pts[None, :, :]) ** 2, axis=-1)
    return metric_lib.l2_sq_hits(d2, eps)


def _get_impl(name):
    if name == "jnp":
        return _block_hits_jnp
    if name == "pallas":
        from repro.kernels.ops import distance_tile_hits

        return distance_tile_hits
    raise ValueError(f"unknown distance_impl {name!r}")


@partial(jax.jit, static_argnames=("tile", "distance_impl"))
def _count(points, eps, *, tile: int, distance_impl: str):
    npts, _ = points.shape
    n_tiles = -(-npts // tile)
    pad = n_tiles * tile - npts
    pts_pad = jnp.pad(points, ((0, pad), (0, 0)), constant_values=0.0)
    hits_fn = _get_impl(distance_impl)

    def body(total, t):
        q = jax.lax.dynamic_slice_in_dim(pts_pad, t * tile, tile)
        rows = t * tile + jnp.arange(tile)
        hits = hits_fn(q, points, eps)
        hits = hits & (rows[:, None] < npts)                  # query padding
        hits = hits & (rows[:, None] != jnp.arange(npts)[None, :])  # self
        return total + hits.sum(dtype=jnp.int64), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.int64), jnp.arange(n_tiles))
    return total


def brute_force_count(points, eps, *, tile: int = 256, distance_impl: str = "jnp") -> int:
    """Ordered-pair count (excl. self) by exhaustive comparison."""
    points = jnp.asarray(points)
    return int(_count(points, jnp.asarray(eps, points.dtype), tile=tile,
                      distance_impl=distance_impl))


@partial(jax.jit, static_argnames=("tile", "capacity", "distance_impl"))
def _fill(points, eps, *, tile: int, capacity: int, distance_impl: str):
    npts, _ = points.shape
    n_tiles = -(-npts // tile)
    pad = n_tiles * tile - npts
    pts_pad = jnp.pad(points, ((0, pad), (0, 0)), constant_values=0.0)
    hits_fn = _get_impl(distance_impl)

    def body(carry, t):
        cursor, keys, vals = carry
        q = jax.lax.dynamic_slice_in_dim(pts_pad, t * tile, tile)
        rows = t * tile + jnp.arange(tile)
        hits = hits_fn(q, points, eps)
        hits = hits & (rows[:, None] < npts)
        hits = hits & (rows[:, None] != jnp.arange(npts)[None, :])
        flat = hits.reshape(-1)
        rel = jnp.cumsum(flat.astype(jnp.int64)) - 1
        n_hits = rel[-1] + 1
        qid = jnp.broadcast_to(rows[:, None], hits.shape).reshape(-1)
        cid = jnp.broadcast_to(jnp.arange(npts)[None, :], hits.shape).reshape(-1)
        idx = jnp.where(flat, cursor + rel, capacity)
        keys = keys.at[idx].set(qid.astype(jnp.int32), mode="drop")
        vals = vals.at[idx].set(cid.astype(jnp.int32), mode="drop")
        return (cursor + n_hits, keys, vals), None

    keys0 = jnp.full((capacity,), -1, jnp.int32)
    vals0 = jnp.full((capacity,), -1, jnp.int32)
    (count, keys, vals), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.int64), keys0, vals0), jnp.arange(n_tiles)
    )
    return keys, vals, count


def brute_force_join(points, eps, *, tile: int = 256, distance_impl: str = "jnp"):
    """All ordered pairs (K,2) by exhaustive comparison (sorted by key)."""
    points = jnp.asarray(points)
    eps = jnp.asarray(eps, points.dtype)
    total = int(_count(points, eps, tile=tile, distance_impl=distance_impl))
    keys, vals, count = _fill(
        points, eps, tile=tile, capacity=max(total, 1), distance_impl=distance_impl
    )
    assert int(count) == total
    pairs = np.stack([np.asarray(keys), np.asarray(vals)], axis=1)[:total]
    return pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
