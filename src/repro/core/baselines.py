"""CPU baselines the paper compares against (SVI-B).

* ``rtree_join``  -- CPU-RTREE: the sequential search-and-refine reference.
  An STR bulk-loaded R-tree (Kamel & Faloutsos style packing; the paper sorts
  data into unit bins before insertion for the same locality effect), then a
  per-point range search + refine. Pure numpy, single-threaded by design (the
  paper's reference is 1 thread).

* ``ego_join``    -- Super-EGO-style epsilon-grid-order join (Kalashnikov
  2013): EGO-sort the points by their eps-grid cell coordinate, then a
  recursive block join in which a pair of blocks is pruned when their cell
  bounding ranges are farther than one cell apart in some dimension. This
  reproduces the algorithmic structure (EGO-sort + EGO-join + pruning); the
  original's dimension-reordering heuristic is noted in benchmarks where the
  paper's claim depends on it (uniform data defeats reordering, paper SVI-C).

Both return ordered-pair counts and (optionally) pair lists consistent with
``core.selfjoin.self_join``; consistency is asserted in tests the same way
the paper validated implementations "by comparing the total number of
neighbors within eps".
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import metric as metric_lib

# ---------------------------------------------------------------------------
# CPU-RTREE (search-and-refine reference)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _RTree:
    # level arrays, root last. boxes[l]: (n_nodes_l, 2, n); children[l]:
    # (n_nodes_l, 2) int ranges into level l-1 nodes (or into points for l=0).
    boxes: list
    children: list
    point_order: np.ndarray
    points: np.ndarray
    leaf_size: int


def build_rtree(points: np.ndarray, leaf_size: int = 32, fanout: int = 8) -> _RTree:
    """Sort-Tile-Recursive bulk load.

    Points are recursively sorted and partitioned one dimension at a time into
    ~equal slices (the STR packing); leaves hold ``leaf_size`` points. This
    mirrors the paper's 'sort into unit bins so internal nodes do not span
    empty space' preparation for its R-tree reference.
    """
    pts = np.asarray(points)
    npts, ndim = pts.shape

    def str_pack(idx: np.ndarray, dim: int) -> np.ndarray:
        """Recursive STR: sort by dim, split into ~equal slabs, recurse."""
        if idx.shape[0] <= leaf_size:
            return idx
        srt = idx[np.argsort(pts[idx, dim], kind="stable")]
        n_slabs = min(fanout, -(-srt.shape[0] // leaf_size))
        return np.concatenate(
            [str_pack(s, (dim + 1) % ndim) for s in np.array_split(srt, n_slabs)]
        )

    order = str_pack(np.arange(npts), 0)

    pts_sorted = pts[order]
    # leaves
    leaf_ranges = [
        (i, min(i + leaf_size, npts)) for i in range(0, npts, leaf_size)
    ]
    boxes = []
    children = []
    lvl_boxes = np.empty((len(leaf_ranges), 2, ndim))
    lvl_child = np.empty((len(leaf_ranges), 2), dtype=np.int64)
    for k, (a, b) in enumerate(leaf_ranges):
        lvl_boxes[k, 0] = pts_sorted[a:b].min(axis=0)
        lvl_boxes[k, 1] = pts_sorted[a:b].max(axis=0)
        lvl_child[k] = (a, b)
    boxes.append(lvl_boxes)
    children.append(lvl_child)
    while boxes[-1].shape[0] > 1:
        prev = boxes[-1]
        m = prev.shape[0]
        groups = [(i, min(i + fanout, m)) for i in range(0, m, fanout)]
        nb = np.empty((len(groups), 2, ndim))
        nc = np.empty((len(groups), 2), dtype=np.int64)
        for k, (a, b) in enumerate(groups):
            nb[k, 0] = prev[a:b, 0].min(axis=0)
            nb[k, 1] = prev[a:b, 1].max(axis=0)
            nc[k] = (a, b)
        boxes.append(nb)
        children.append(nc)
    return _RTree(boxes, children, order, pts_sorted, leaf_size)


def _rtree_query(tree: _RTree, q: np.ndarray, eps: float) -> np.ndarray:
    """Ids (original numbering) of points within eps of q (search-and-refine)."""
    lo, hi = q - eps, q + eps
    top = len(tree.boxes) - 1
    nodes = np.array([0], dtype=np.int64)
    for level in range(top, 0, -1):  # descend to leaf level
        bx = tree.boxes[level][nodes]
        ok = np.all(bx[:, 0] <= hi, axis=1) & np.all(bx[:, 1] >= lo, axis=1)
        rng = tree.children[level][nodes[ok]]
        if rng.shape[0] == 0:
            return np.empty(0, dtype=np.int64)
        nodes = np.concatenate([np.arange(a, b) for a, b in rng])
    bx = tree.boxes[0][nodes]
    ok = np.all(bx[:, 0] <= hi, axis=1) & np.all(bx[:, 1] >= lo, axis=1)
    rng = tree.children[0][nodes[ok]]
    if rng.shape[0] == 0:
        return np.empty(0, dtype=np.int64)
    cand = np.concatenate([np.arange(a, b) for a, b in rng])
    # refine
    d2 = ((tree.points[cand] - q) ** 2).sum(axis=1)
    return tree.point_order[cand[metric_lib.l2_sq_hits(d2, eps)]]


def rtree_join(points: np.ndarray, eps: float, *, return_pairs: bool = False,
               leaf_size: int = 32):
    """Sequential search-and-refine self-join (ordered pairs, excl. self)."""
    pts = np.asarray(points)
    tree = build_rtree(pts, leaf_size=leaf_size)
    total = 0
    pairs = [] if return_pairs else None
    for i in range(pts.shape[0]):
        nbrs = _rtree_query(tree, pts[i], eps)
        nbrs = nbrs[nbrs != i]
        total += nbrs.shape[0]
        if return_pairs:
            pairs.append(np.stack([np.full_like(nbrs, i), nbrs], axis=1))
    if return_pairs:
        out = (np.concatenate(pairs) if pairs else np.empty((0, 2), np.int64))
        out = out[np.lexsort((out[:, 1], out[:, 0]))]
        return total, out
    return total


# ---------------------------------------------------------------------------
# Super-EGO-style epsilon grid order join
# ---------------------------------------------------------------------------


def _ego_sort(points: np.ndarray, eps: float):
    gmin = points.min(axis=0)
    cells = np.floor((points - gmin) / eps).astype(np.int64)
    order = np.lexsort(tuple(cells[:, j] for j in range(cells.shape[1] - 1, -1, -1)))
    return points[order], cells[order], order


def ego_join(points: np.ndarray, eps: float, *, block: int = 64,
             return_pairs: bool = False):
    """EGO-sort + recursive block join with cell-distance pruning.

    Prune rule (epsilon grid order, Boehm et al. 2001): two EGO-sorted blocks
    cannot contain a qualifying pair if, in the first dimension where their
    cell ranges are disjoint, the gap exceeds one cell. Counts ordered pairs.
    """
    pts = np.asarray(points)
    npts = pts.shape[0]
    if npts == 0:
        return (0, np.empty((0, 2), np.int64)) if return_pairs else 0
    P, C, order = _ego_sort(pts, eps)
    eps2 = metric_lib.eps_squared(eps)
    blocks = [(i, min(i + block, npts)) for i in range(0, npts, block)]
    blo = np.array([C[a:b].min(axis=0) for a, b in blocks])
    bhi = np.array([C[a:b].max(axis=0) for a, b in blocks])
    nb = len(blocks)
    total = 0
    pairs = [] if return_pairs else None
    for bi in range(nb):
        a0, a1 = blocks[bi]
        for bj in range(bi, nb):
            # prune on cell ranges: gap > 1 cell in any dim -> no pairs.
            gap_lo = blo[bj] - bhi[bi]
            gap_hi = blo[bi] - bhi[bj]
            if np.any(np.maximum(gap_lo, gap_hi) > 1):
                # EGO order is lexicographic: once dim-0 gap exceeds 1 for bj,
                # it does for all later bj too.
                if gap_lo[0] > 1:
                    break
                continue
            b0, b1 = blocks[bj]
            d2 = ((P[a0:a1, None, :] - P[None, b0:b1, :]) ** 2).sum(axis=2)
            hit = d2 <= eps2
            if bi == bj:
                np.fill_diagonal(hit, False)
                total += int(hit.sum())
            else:
                total += 2 * int(hit.sum())
            if return_pairs:
                ii, jj = np.nonzero(hit)
                gi, gj = order[a0 + ii], order[b0 + jj]
                pairs.append(np.stack([gi, gj], axis=1))
                if bi != bj:
                    pairs.append(np.stack([gj, gi], axis=1))
    if return_pairs:
        out = (np.concatenate(pairs) if pairs else np.empty((0, 2), np.int64))
        out = out[np.lexsort((out[:, 1], out[:, 0]))]
        return total, out
    return total
