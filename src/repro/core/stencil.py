"""Adjacent-cell stencils and the UNICOMP work-halving (paper SV-B).

The search for neighbors of a point in cell c is bounded to the 3^n adjacent
cells c + o, o in {-1,0,+1}^n (paper SIV-D). UNICOMP ("uni-directional
comparison") evaluates each unordered *pair of cells* exactly once and emits
both orders of every found pair, halving cell evaluations and distance
calculations.

The paper formulates UNICOMP with an odd/even cell-coordinate rule (Alg. 2):
a cell with odd coordinate in dimension j evaluates the neighbors differing
in dimension j. Observe what that rule computes: for every unordered pair of
adjacent cells (a, b), exactly one of a, b evaluates the other. Our TPU
formulation achieves the same single-evaluation property directly with a
*lexicographically positive* half-stencil:

    keep offset o  iff  o = 0  or  the first nonzero coordinate of o is +1

(3^n - 1)/2 + 1 offsets survive instead of 3^n. o = 0 (the cell itself) is
handled with an intra-cell upper-triangle mask. Equivalence to the paper's
odd/even rule is checked in tests/test_selfjoin.py: both evaluate each
unordered adjacent cell pair exactly once, so the produced pair sets are
identical; the half-stencil is branch-free and offset-static, which suits a
vector machine (DESIGN.md S2).
"""
from __future__ import annotations

import itertools

import numpy as np


def stencil_offsets(n: int, unicomp: bool) -> np.ndarray:
    """All 3^n adjacent-cell offsets, or the UNICOMP half-stencil.

    Returns (n_offsets, n) int64. The zero offset is always first.
    """
    offs = np.array(list(itertools.product((-1, 0, 1), repeat=n)), dtype=np.int64)
    if unicomp:
        keep = []
        for o in offs:
            nz = np.nonzero(o)[0]
            if nz.size == 0 or o[nz[0]] > 0:
                keep.append(o)
        offs = np.stack(keep)
    # zero offset first (intra-cell pass)
    zkey = np.all(offs == 0, axis=1)
    offs = np.concatenate([offs[zkey], offs[~zkey]], axis=0)
    return offs


def unicomp_paper_visits(coord: np.ndarray, n: int) -> list[tuple]:
    """The paper's Alg. 2 odd/even rule, as offsets visited by cell ``coord``.

    Reference-only (used by tests to prove pair-coverage equivalence with the
    half-stencil). Alg. 2's pass for dimension j visits offsets o with
    o[j] != 0, o[k] = 0 for k > j, and o[k] free for k < j -- i.e. the pass
    that owns offset o is its *last* nonzero dimension. The pass runs iff
    coord[j] is odd. Since adjacent cells differ by 1 in that dimension,
    exactly one endpoint of every unordered adjacent-cell pair is odd there,
    so each pair is evaluated exactly once -- the same invariant as our
    lexicographic half-stencil.
    """
    visits = []
    for o in itertools.product((-1, 0, 1), repeat=n):
        o = np.array(o, dtype=np.int64)
        nz = np.nonzero(o)[0]
        if nz.size == 0:
            continue
        j = nz[-1]  # the paper pass that owns this offset
        if coord[j] % 2 == 1:
            visits.append(tuple(o))
        # even coordinate in dim j: the *neighbor* cell owns the pair; its
        # coordinate in dim j is coord[j] +- 1, which is odd.
    return visits


def merged_stencil_offsets(
    n: int, unicomp: bool
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The 3^(n-1) merged-range stencil (DESIGN.md S7).

    Under row-major linearized keys the last dimension has stride 1, so the
    three adjacent cells that differ only in the last coordinate by
    {-1, 0, +1} occupy ADJACENT KEY RANKS in B -- their point windows are
    one contiguous span of ``points_sorted`` (Gowanlock & Karsin,
    arXiv:1809.09930). The per-cell triple therefore collapses into a
    single range probe: this returns

        reduced (n_off, n) int64 -- offset vectors with last coordinate 0,
            one per distinct first-(n-1)-coordinate offset; zero first.
        lo / hi (n_off,) int64   -- the last-dimension span each reduced
            offset covers, as key deltas relative to the reduced target.

    Full stencil: 3^(n-1) reduced offsets, each spanning [-1, +1]. UNICOMP
    keeps a reduced offset iff its (n-1)-vector is zero or lexicographically
    positive -- (3^(n-1) - 1)/2 + 1 offsets. The zero reduced offset spans
    [0, +1] only (the lone-last-dim offset (0..0,-1) has first nonzero -1
    and is dropped by the half-stencil rule); applying the o = 0 triangle
    rule ``cand_pos > q_pos`` across that WHOLE merged window is exact:
    own-cell candidates get the triangle, and every candidate from the
    key+1 cell sits at a later sorted position than any own-cell query, so
    the same predicate admits all of them. Equivalence with the unmerged
    half-stencil is asserted in tests/test_merged_sweep.py.
    """
    offs = np.array(list(itertools.product((-1, 0, 1), repeat=n - 1)),
                    dtype=np.int64)
    if unicomp:
        keep = []
        for o in offs:
            nz = np.nonzero(o)[0]
            if nz.size == 0 or o[nz[0]] > 0:
                keep.append(o)
        offs = np.stack(keep)
    zkey = np.all(offs == 0, axis=1)
    offs = np.concatenate([offs[zkey], offs[~zkey]], axis=0)
    reduced = np.concatenate(
        [offs, np.zeros((offs.shape[0], 1), np.int64)], axis=1)
    lo = np.full(offs.shape[0], -1, np.int64)
    hi = np.full(offs.shape[0], 1, np.int64)
    if unicomp:
        lo[0] = 0  # zero reduced offset: own cell + the key+1 cell only
    return reduced, lo, hi


def offsets_array(n: int, unicomp: bool):
    """stencil_offsets as a device-ready array (import-light helper)."""
    import jax.numpy as jnp

    return jnp.asarray(stencil_offsets(n, unicomp))
