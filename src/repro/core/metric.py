"""Compile-time metric trait for the search-and-refine pipeline (DESIGN.md S12).

The paper's pipeline is metric-agnostic in principle: the grid PRUNES in a
geometry space, the refine predicate DECIDES in metric space. This module is
the one place that knows both halves for every supported metric; everything
else (kernels, drivers, services, benchmarks) threads an opaque static
``metric=`` string through to the helpers here.

Each metric provides three things:

  * **canonicalization** (``canonicalize``): map raw input points onto the
    (geometry, features) pair the grid and kernel consume.

      - ``l2``: identity. Geometry IS the point; no feature lanes.
      - ``cosine``: unit-normalize rows (zero-norm / nonfinite input is a
        hard error). On the unit sphere ``cos(a,b) >= t`` is EXACTLY
        ``||a-b||^2 <= 2 - 2t``, so the cosine join reduces to an L2 join
        at threshold ``sqrt(2 - 2t)`` and the whole existing machinery
        (grid, merged-range sweep, cell-run plan, occupancy planner) works
        unchanged. The static ``metric="cosine"`` tag only keys the
        executable; the traced computation is the L2 one.
      - ``jaccard``: token sets become packed bitmaps riding the pad-lane
        mechanism (``TOKEN_BITS`` tokens per lane as exact small-integer
        float words), and the GEOMETRY is the 1-D set-size coordinate:
        ``J(a,b) >= t`` with ``|b| >= |a|`` implies ``|b| - |a| <=
        (1-t)|b| <= (1-t)S_max``, so a 1-D grid over sizes with cell width
        ``max((1-t) * S_max, 1)`` is a sound prune.

  * a **refine predicate** (``tile_refine_hits`` for the fused kernel's
    per-row window form, ``plane_refine_hits`` for the reference lowering's
    column-gather form) evaluated under the same descriptor/count->fill
    contract for every metric, plus the scalar it consumes
    (``device_refine_scalar``: eps^2 for l2/cosine, the raw Jaccard
    threshold t for jaccard).

  * a **brute-force oracle** (``brute_force_join_metric``) built from the
    SAME float expressions as the kernel predicate, so pair-set parity with
    the fused path is structural rather than approximate.

Predicate ownership: ``eps_squared`` / ``l2_sq_hits`` below are the ONLY
place the repo derives a squared-epsilon threshold; ``analysis/lint.py``
(rule ``eps-predicate``) flags any ``d2 <= eps*eps``-shaped comparison that
reappears outside this module.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

METRICS = ("l2", "cosine", "jaccard")

# Jaccard bitmap packing: tokens per feature lane. Lanes are stored in the
# points array's float dtype, so the packed word must be EXACT in float32;
# 16-bit words (max 65535 < 2^24) are, 32-bit words are not.
TOKEN_BITS = 16

# |1 - ||x||^2| tolerance for "canonical cosine input" (sanitize check):
# float32 normalization of well-scaled vectors lands well inside this.
NORM_TOL = 1e-3

_POPCOUNT16: Optional[np.ndarray] = None


def check_metric(metric: str) -> str:
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}; expected one of "
                         f"{METRICS}")
    return metric


def metric_feat_lanes(metric: str, n_feat: int) -> int:
    """Feature lanes a metric rides in the padded points array (0 unless
    the metric carries non-geometric payload; jaccard carries bitmaps)."""
    return int(n_feat) if metric == "jaccard" else 0


# ---------------------------------------------------------------------------
# The refine predicate (single owner of the squared-threshold form)
# ---------------------------------------------------------------------------

def eps_squared(eps):
    """THE squared-threshold derivation. Works on python floats, numpy and
    jax arrays alike (pure operators); every other module must obtain its
    squared epsilon from here so the linter can hold the grep gate."""
    return eps * eps


def l2_sq_hits(d2, eps):
    """``d2 <= eps^2``: the L2 refine predicate against an UNsquared
    threshold (host-side / oracle form)."""
    return d2 <= eps_squared(eps)


def l2_sq_hits_presquared(d2, eps2):
    """``d2 <= eps2`` against an already-squared threshold (kernel form:
    the squaring happened once in ``device_refine_scalar``)."""
    return d2 <= eps2


def device_refine_scalar(metric: str, eps, dtype) -> jax.Array:
    """The (1, 1) scalar operand the fused kernel refines against.

    l2/cosine consume the SQUARED geometry threshold (the kernel compares
    squared distances); jaccard consumes the similarity threshold ``t``
    verbatim (the kernel compares ``inter >= t * union``). The threshold
    stays a TRACED operand for every metric, so serving a mix of radii
    hits one executable per metric.
    """
    s = jnp.asarray(eps, dtype)
    if metric != "jaccard":
        s = eps_squared(s)
    return jnp.reshape(s, (1, 1))


def tile_refine_hits(metric: str, qrow, window, scalar, *, n_real: int,
                     n_feat: int):
    """Fused-kernel refine: one query row against its candidate window.

    ``qrow`` is (1, L), ``window`` is (C, L) with L the padded lane count
    (geometry lanes [0, n_real), feature lanes [n_real, n_real+n_feat)),
    ``scalar`` the ``device_refine_scalar`` value. Returns a (C,) bool.
    """
    if metric == "jaccard":
        # Sizes from the geometry lane, NOT bitmap popcounts: a query
        # packed against a smaller index vocabulary keeps its TRUE size
        # (out-of-vocabulary tokens can never intersect indexed sets, so
        # the intersection is exact and the union needs the true size).
        sq = qrow[0, 0]
        sc = window[:, 0]
        inter = jnp.zeros(window.shape[:1], jnp.int32)
        for k in range(n_feat):
            qw = qrow[0, n_real + k].astype(jnp.int32)
            cw = window[:, n_real + k].astype(jnp.int32)
            inter = inter + jax.lax.population_count(qw & cw)
        inter = inter.astype(window.dtype)
        union = sq + sc - inter
        return (union > 0) & (inter >= scalar * union)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, window.shape[1]), 1)
    lane_w = (lane < n_real).astype(window.dtype)
    d = (window - qrow) * lane_w
    return l2_sq_hits_presquared(jnp.sum(d * d, axis=-1), scalar)


def plane_refine_hits(metric: str, points_pad, q_batch, cand_pos, scalar, *,
                      n_real: int, n_feat: int):
    """Reference-lowering refine: per-lane COLUMN gathers, no (Q, C, L)
    tensor (matches the fused kernel's arithmetic lane by lane).

    ``q_batch`` is (Q, L), ``cand_pos`` is (Q, C) gather positions into
    ``points_pad`` rows. Returns (Q, C) bool.
    """
    if metric == "jaccard":
        sq = q_batch[:, 0][:, None]
        sc = jnp.take(points_pad[:, 0], cand_pos)
        inter = jnp.zeros(cand_pos.shape, jnp.int32)
        for k in range(n_feat):
            qw = q_batch[:, n_real + k].astype(jnp.int32)[:, None]
            cw = jnp.take(points_pad[:, n_real + k],
                          cand_pos).astype(jnp.int32)
            inter = inter + jax.lax.population_count(qw & cw)
        inter = inter.astype(points_pad.dtype)
        union = sq + sc - inter
        return (union > 0) & (inter >= scalar * union)
    d2 = jnp.zeros(cand_pos.shape, points_pad.dtype)
    for dim in range(n_real):
        cd = jnp.take(points_pad[:, dim], cand_pos)
        d2 = d2 + (q_batch[:, dim][:, None] - cd) ** 2
    return l2_sq_hits_presquared(d2, scalar)


# ---------------------------------------------------------------------------
# Canonicalization
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Canonical:
    """A dataset canonicalized for one metric.

    ``geom`` is what the grid indexes (the points themselves for l2, unit
    rows for cosine, (N, 1) set sizes for jaccard); ``feats`` is the
    non-geometric payload riding the pad lanes (packed token words for
    jaccard, None otherwise). ``eps`` is the threshold in METRIC units as
    given; ``eps_geom`` is the grid cell width / L2 prune radius derived
    from it; ``refine`` is the scalar the fused kernel consumes.
    """

    metric: str
    geom: np.ndarray                  # (N, n_geom)
    feats: Optional[np.ndarray]       # (N, n_feat) packed words, or None
    n_feat: int
    eps: float                        # metric-units threshold
    eps_geom: float                   # grid cell width (geometry space)
    vocab: int = 0                    # jaccard: packed vocabulary size

    @property
    def refine(self) -> float:
        """Kernel scalar in UNsquared form: the geometry radius for
        l2/cosine (the kernel squares it once), the threshold t for
        jaccard (consumed verbatim)."""
        return self.eps if self.metric == "jaccard" else self.eps_geom


def cosine_eps_geom(eps: float) -> float:
    """The cosine -> L2 threshold reduction on the unit sphere:
    ``cos(a,b) >= eps  <=>  ||a-b||^2 = 2 - 2cos(a,b) <= 2 - 2eps``."""
    return float(np.sqrt(max(2.0 - 2.0 * float(eps), 0.0)))


def _unit_rows(points, *, what: str) -> np.ndarray:
    pts = np.asarray(points)
    if pts.ndim != 2:
        raise ValueError(f"{what} must be 2-D (N, d), got shape {pts.shape}")
    if not np.issubdtype(pts.dtype, np.floating):
        pts = pts.astype(np.float64)
    if not np.isfinite(pts).all():
        bad = np.flatnonzero(~np.isfinite(pts).all(axis=1))
        raise ValueError(
            f"cosine metric: {what} rows {bad[:8].tolist()} contain "
            f"non-finite values; clean the embeddings before joining")
    norms = np.linalg.norm(pts, axis=1)
    zero = np.flatnonzero(norms == 0)
    if zero.size:
        raise ValueError(
            f"cosine metric: {what} rows {zero[:8].tolist()} have zero "
            f"norm; direction is undefined for the zero vector")
    return pts / norms[:, None]


def pack_tokens(sets, *, vocab: Optional[int] = None
                ) -> tuple[np.ndarray, np.ndarray, int]:
    """Pack token sets into (sizes, words, vocab).

    ``sets`` is either a sequence of token-id iterables or an (N, V)
    binary membership matrix. Returns float32 ``sizes`` (N,) -- TRUE set
    sizes, counting every distinct token -- and float32 ``words``
    (N, ceil(vocab / TOKEN_BITS)) whose lanes hold exact 16-bit packed
    words. With an explicit ``vocab`` (query-side packing against a fixed
    index vocabulary), out-of-vocabulary tokens still count toward the
    size but set no bits: they cannot intersect any indexed set, so the
    intersection stays exact and the union uses the true size.
    """
    if isinstance(sets, np.ndarray) and sets.ndim == 2:
        mask = np.asarray(sets) != 0
        ind = [np.flatnonzero(row) for row in mask]
    else:
        ind = []
        for s in sets:
            toks = np.unique(np.asarray(list(s), dtype=np.int64))
            if toks.size and toks[0] < 0:
                raise ValueError("jaccard metric: token ids must be >= 0")
            ind.append(toks)
    sizes = np.asarray([t.size for t in ind], np.float32)
    max_tok = max((int(t[-1]) for t in ind if t.size), default=-1)
    if vocab is None:
        vocab = max_tok + 1
        clip = False
    else:
        vocab = int(vocab)
        clip = True
    n_words = max(-(-max(vocab, 1) // TOKEN_BITS), 1)
    words = np.zeros((len(ind), n_words), np.uint16)
    for i, toks in enumerate(ind):
        if clip:
            toks = toks[toks < vocab]
        if toks.size:
            np.bitwise_or.at(
                words[i], toks // TOKEN_BITS,
                (np.uint16(1) << (toks % TOKEN_BITS).astype(np.uint16)))
    return sizes, words.astype(np.float32), int(vocab)


def canonicalize(points, eps, *, metric: str = "l2",
                 vocab: Optional[int] = None) -> Canonical:
    """Canonicalize a dataset for one metric (index-build side)."""
    check_metric(metric)
    if metric == "l2":
        geom = np.asarray(points)
        if geom.ndim != 2:
            raise ValueError(f"points must be 2-D, got shape {geom.shape}")
        e = float(eps)
        return Canonical("l2", geom, None, 0, e, e)
    if metric == "cosine":
        e = float(eps)
        if not (-1.0 <= e < 1.0):
            raise ValueError(
                f"cosine threshold must lie in [-1, 1), got {e}; it is a "
                f"minimum cosine SIMILARITY, not a distance")
        geom = _unit_rows(points, what="points")
        return Canonical("cosine", geom, None, 0, e, cosine_eps_geom(e))
    # jaccard
    t = float(eps)
    if not (0.0 < t <= 1.0):
        raise ValueError(
            f"jaccard threshold must lie in (0, 1], got {t}; it is a "
            f"minimum Jaccard similarity")
    sizes, words, vocab = pack_tokens(points, vocab=vocab)
    s_max = float(sizes.max()) if sizes.size else 0.0
    # |b| >= |a| and J >= t  =>  |b| - |a| <= (1-t)|b| <= (1-t)S_max:
    # a 1-D grid over set sizes at this width is a sound prune. Floor at
    # 1 so t = 1 (exact duplicates) still yields a positive cell width.
    eps_geom = max((1.0 - t) * s_max, 1.0)
    geom = sizes[:, None]
    return Canonical("jaccard", geom, words, words.shape[1], t, eps_geom,
                     vocab)


def canonicalize_queries(canon: Canonical, queries
                         ) -> tuple[np.ndarray, Optional[np.ndarray]]:
    """Canonicalize an EXTERNAL query batch against an indexed dataset's
    canonical form. Returns (geometry rows, feature rows or None)."""
    if canon.metric == "l2":
        q = np.asarray(queries)
        return q, None
    if canon.metric == "cosine":
        return _unit_rows(queries, what="queries"), None
    sizes, words, _ = pack_tokens(queries, vocab=canon.vocab)
    return sizes[:, None].astype(canon.geom.dtype), words


def request_scalar(metric: str, eps: float, *, index_eps: float,
                   index_eps_geom: float) -> float:
    """Map a per-request threshold (METRIC units) onto the kernel scalar,
    validating the index's stencil still covers it.

    l2: smaller radii only. cosine: HIGHER similarity only (a lower
    similarity floor means a larger geometry radius than the grid was
    built for). jaccard: HIGHER thresholds only, and the scalar is t
    itself -- a stricter t shrinks the size-difference prune radius, so
    the build-time windows remain a superset of the candidates.
    """
    check_metric(metric)
    if metric == "l2":
        if eps > index_eps * (1 + 1e-12):
            raise ValueError(
                f"query eps {eps} exceeds index build eps {index_eps}; the "
                f"adjacent-cell stencil only covers the build radius")
        return float(eps)
    if metric == "cosine":
        if eps < index_eps - 1e-12:
            raise ValueError(
                f"query cosine threshold {eps} is below the index build "
                f"threshold {index_eps}; a lower similarity floor needs a "
                f"rebuilt grid")
        geom = cosine_eps_geom(eps)
        return float(min(geom, index_eps_geom))
    if eps < index_eps - 1e-12:
        raise ValueError(
            f"query jaccard threshold {eps} is below the index build "
            f"threshold {index_eps}; a looser threshold needs a rebuilt "
            f"grid")
    return float(eps)


# ---------------------------------------------------------------------------
# Brute-force oracles
# ---------------------------------------------------------------------------

def _popcount16_table() -> np.ndarray:
    global _POPCOUNT16
    if _POPCOUNT16 is None:
        bits = np.unpackbits(
            np.arange(65536, dtype=np.uint16).view(np.uint8).reshape(-1, 2),
            axis=1)
        _POPCOUNT16 = bits.sum(axis=1).astype(np.uint8)
    return _POPCOUNT16


def _jaccard_brute_hits(canon: Canonical, block: int = 512) -> np.ndarray:
    """(K, 2) ordered hit pairs (both directions, self excluded) by exact
    bitmap intersection, using the SAME float comparison as the kernel."""
    words = canon.feats.astype(np.uint16)
    sizes = canon.geom[:, 0].astype(canon.geom.dtype)
    t = canon.geom.dtype.type(canon.eps)
    table = _popcount16_table()
    n = words.shape[0]
    out = []
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        inter = table[words[lo:hi, None, :] & words[None, :, :]] \
            .sum(axis=-1, dtype=np.int64)
        inter_f = inter.astype(canon.geom.dtype)
        union = sizes[lo:hi, None] + sizes[None, :] - inter_f
        hit = (union > 0) & (inter_f >= t * union)
        hit[np.arange(lo, hi) - lo, np.arange(lo, hi)] = False
        a, b = np.nonzero(hit)
        out.append(np.stack([a + lo, b], axis=1).astype(np.int32))
    if not out:
        return np.empty((0, 2), np.int32)
    return np.concatenate(out, axis=0)


def brute_force_join_metric(canon: Canonical, *, tile: int = 256
                            ) -> np.ndarray:
    """Metric-generic brute-force oracle: lexsorted (K, 2) ordered pairs.

    l2/cosine delegate to the blocked L2 oracle on the canonical geometry
    at the reduced threshold; jaccard runs the exact bitmap intersection.
    Every comparison uses the same float expression as the fused kernel,
    so pair-set parity with the grid path is structural.
    """
    if canon.metric in ("l2", "cosine"):
        from repro.core import brute
        return brute.brute_force_join(canon.geom, canon.eps_geom, tile=tile)
    pairs = _jaccard_brute_hits(canon)
    if pairs.shape[0]:
        pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
    return pairs


def brute_force_count_metric(canon: Canonical, *, tile: int = 256) -> int:
    """Ordered-pair count under the metric's brute-force oracle."""
    if canon.metric in ("l2", "cosine"):
        from repro.core import brute
        return brute.brute_force_count(canon.geom, canon.eps_geom, tile=tile)
    return int(_jaccard_brute_hits(canon).shape[0])


def jaccard_similarity(a, b) -> float:
    """Exact Jaccard similarity of two token iterables (test helper)."""
    sa, sb = set(a), set(b)
    if not sa and not sb:
        return 0.0
    return len(sa & sb) / len(sa | sb)
