"""The self-join (paper Alg. 1 + SV optimizations), TPU-native formulation.

The paper's CUDA kernel is thread-per-point: each thread walks the 3^n
adjacent cells of its point, binary-searches B per cell, and appends result
pairs through a global atomic. On a TPU there are no per-lane scatters or
atomics, so we restructure the same computation as an **offset sweep**
(DESIGN.md S2):

    for each stencil offset o in {-1,0,1}^n (or the UNICOMP half-stencil):
        nbr[h]   = rank in B of (cell h + o)          -- one batched searchsorted
        for every query point i (vectorized):          -- regular, branch-free
            candidates = A[start[nbr[rank_i]] : +count]  (padded to C_max slots)
            hits       = ||q_i - cand||^2 <= eps^2       (masked)

The candidate distance evaluation is the compute hot-spot; it is pluggable
(``distance_impl``):

  'jnp'    -- reference: gather the (B, C, n) candidate tensor, evaluate.
  'pallas' -- kernels/cell_join.py refine over the same gathered tensor.
  'fused'  -- kernels/fused_join.py: the gather happens INSIDE the kernel
              (window descriptors via scalar prefetch, HBM->VMEM dynamic
              slice per window), all stencil offsets sweep in ONE launch
              with the query tile VMEM-resident throughout, and count+fill
              share a single distance evaluation per candidate: the kernel
              returns the masked hit set plus per-query counts and the
              per-tile exclusive-scan slot bases, so the fill phase only
              scatters (DESIGN.md S4). No (B, C, n) intermediate exists.

Result emission replaces the paper's atomics with a two-phase
count -> exclusive-scan -> scatter fill ('jnp'/'pallas'; every distance is
computed twice) or the fused single-pass count -> fill above. The paper
sorts the key/value result after the kernel, and we optionally do the same.
Batching over query points (paper SV-A) bounds both the result buffer and
the per-batch hit set; the driver ``self_join_batched`` uses >= 3 batches
like the paper and overlaps device compute with host transfers via JAX
async dispatch.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.grid import GridIndex, PAD_KEY, build_grid_host, neighbor_rank
from repro.core.stencil import stencil_offsets


@dataclasses.dataclass(frozen=True)
class JoinStats:
    """Work counters (paper Table II analogue: cells and distances checked)."""

    total_pairs: int          # ordered pairs with dist <= eps (excl. self)
    cells_visited: int        # non-empty adjacent cells evaluated
    candidates_checked: int   # candidate slots with a real point
    offsets: int              # stencil offsets swept
    route: str = "dense"      # sweep chosen: 'dense' | 'compact' (auto-routed)


def _strides(dims: jax.Array) -> jax.Array:
    """Row-major strides s_j = prod_{k>j} dims_k, so key(c+o)=key(c)+o.s."""
    rev = jnp.cumprod(dims[::-1])          # d_{n-1}, d_{n-1}d_{n-2}, ...
    return jnp.concatenate([rev[-2::-1], jnp.ones((1,), dims.dtype)])


def _offset_tables(index: GridIndex, unicomp: bool):
    """Static offset list -> (deltas (n_off,), is_zero (n_off,)) device arrays."""
    offs = stencil_offsets(index.n_dims, unicomp)          # (n_off, n) np
    deltas = jnp.asarray(offs) @ _strides(index.dims)      # (n_off,) int64
    is_zero = jnp.asarray(np.all(offs == 0, axis=1))
    return deltas, is_zero


def _neighbor_ranks_for_delta(index: GridIndex, delta: jax.Array) -> jax.Array:
    """Rank in B of (cell + offset) for every non-empty cell; -1 if absent.

    Padding cells resolve to padding slots whose cell_count is 0, so they
    contribute no candidates downstream.
    """
    valid = jnp.arange(index.num_points) < index.num_cells
    base = jnp.where(valid, index.cell_keys, 0)
    qk = jnp.where(valid, base + delta, PAD_KEY)
    return neighbor_rank(index, qk)


def _distance_hits_jnp(q, cand, valid, eps):
    """Reference candidate evaluation: (B,n) x (B,C,n) -> (B,C) bool hits."""
    d2 = jnp.sum((q[:, None, :] - cand) ** 2, axis=-1)
    return (d2 <= eps * eps) & valid


def _get_distance_impl(name: str):
    if name == "jnp":
        return _distance_hits_jnp
    if name == "pallas":
        from repro.kernels.ops import cell_join_hits

        return cell_join_hits
    raise ValueError(f"unknown distance_impl {name!r}")


def _gather_batch(index: GridIndex, nbr_rank_cells, q_start, q_size, max_per_cell):
    """Candidate window of each query in the batch under one stencil offset.

    Returns (q (q_size,n), cand (q_size,C,n), cand_pos (q_size,C) int32,
    valid (q_size,C) bool, q_pos (q_size,) int32 position in sorted order).
    """
    q_pos = q_start + jnp.arange(q_size, dtype=jnp.int32)
    q_ok = q_pos < index.num_points
    q_pos_c = jnp.minimum(q_pos, index.num_points - 1)
    q = index.points_sorted[q_pos_c]
    rank = index.point_cell_rank[q_pos_c]
    nbr = nbr_rank_cells[rank]                       # (q_size,) rank in B or -1
    nbr_c = jnp.maximum(nbr, 0)
    start = index.cell_start[nbr_c]
    count = jnp.where(nbr >= 0, index.cell_count[nbr_c], 0)
    slots = jnp.arange(max_per_cell, dtype=jnp.int32)
    cand_pos = start[:, None] + slots[None, :]       # (q_size, C)
    valid = (slots[None, :] < count[:, None]) & q_ok[:, None]
    cand_pos_c = jnp.minimum(cand_pos, index.num_points - 1)
    cand = index.points_sorted[cand_pos_c]
    return q, cand, cand_pos_c, valid, q_pos_c, q_ok


@partial(
    jax.jit,
    static_argnames=("q_size", "max_per_cell", "unicomp", "distance_impl"),
)
def _count_batch(
    index: GridIndex,
    deltas: jax.Array,
    is_zero: jax.Array,
    q_start: jax.Array,
    *,
    q_size: int,
    max_per_cell: int,
    unicomp: bool,
    distance_impl: str = "jnp",
):
    """Count phase: ordered-pair total + work counters for one query batch."""
    hits_fn = _get_distance_impl(distance_impl)
    eps = index.eps

    def body(carry, xs):
        total, cells, cands = carry
        delta, zero = xs
        nbr_cells = _neighbor_ranks_for_delta(index, delta)
        q, cand, cand_pos, valid, q_pos, q_ok = _gather_batch(
            index, nbr_cells, q_start, q_size, max_per_cell
        )
        hits = hits_fn(q, cand, valid, eps)
        if unicomp:
            # o = 0: strict upper triangle within the cell; o != 0: all pairs.
            # Every hit is an unordered pair -> contributes 2 ordered pairs.
            tri = cand_pos > q_pos[:, None]
            hits = hits & jnp.where(zero, tri, True)
            n_ordered = 2 * hits.sum()
        else:
            # full stencil: each ordered pair found exactly once; drop self.
            hits = hits & (cand_pos != q_pos[:, None])
            n_ordered = hits.sum()
        # work counters (paper Table II analogue)
        valid_rank = index.point_cell_rank[
            jnp.minimum(
                q_start + jnp.arange(q_size, dtype=jnp.int32), index.num_points - 1
            )
        ]
        visited = (nbr_cells[valid_rank] >= 0) & q_ok
        return (
            total + n_ordered,
            cells + visited.sum(),
            cands + valid.sum(),
        ), None

    init = (jnp.zeros((), jnp.int64),) * 3
    (total, cells, cands), _ = jax.lax.scan(body, init, (deltas, is_zero))
    return total, cells, cands


@partial(
    jax.jit,
    static_argnames=("q_size", "max_per_cell", "unicomp", "capacity", "distance_impl"),
)
def _fill_batch(
    index: GridIndex,
    deltas: jax.Array,
    is_zero: jax.Array,
    q_start: jax.Array,
    *,
    q_size: int,
    max_per_cell: int,
    unicomp: bool,
    capacity: int,
    distance_impl: str = "jnp",
):
    """Fill phase: emit ordered pairs (original point ids) into a flat buffer.

    The paper's kernel appends through a global atomic and sorts afterwards;
    we compute each hit's output slot with a cumulative sum (deterministic)
    and scatter. Returns (keys, vals, count); slots >= count are PAD (-1).
    """
    hits_fn = _get_distance_impl(distance_impl)
    eps = index.eps
    orig_id = index.order  # sorted position -> original point id

    def body(carry, xs):
        cursor, keys, vals = carry
        delta, zero = xs
        nbr_cells = _neighbor_ranks_for_delta(index, delta)
        q, cand, cand_pos, valid, q_pos, _ = _gather_batch(
            index, nbr_cells, q_start, q_size, max_per_cell
        )
        hits = hits_fn(q, cand, valid, eps)
        if unicomp:
            tri = cand_pos > q_pos[:, None]
            hits = hits & jnp.where(zero, tri, True)
        else:
            hits = hits & (cand_pos != q_pos[:, None])
        flat = hits.reshape(-1)
        rel = jnp.cumsum(flat.astype(jnp.int64)) - 1      # position among hits
        n_hits = jnp.where(flat.shape[0] > 0, rel[-1] + 1, 0)
        qid = jnp.broadcast_to(orig_id[q_pos][:, None], hits.shape).reshape(-1)
        cid = orig_id[cand_pos].reshape(-1)
        if unicomp:
            pos_fwd = cursor + 2 * rel
            pos_rev = pos_fwd + 1
            idx_fwd = jnp.where(flat, pos_fwd, capacity)
            idx_rev = jnp.where(flat, pos_rev, capacity)
            keys = keys.at[idx_fwd].set(qid, mode="drop")
            vals = vals.at[idx_fwd].set(cid, mode="drop")
            keys = keys.at[idx_rev].set(cid, mode="drop")
            vals = vals.at[idx_rev].set(qid, mode="drop")
            cursor = cursor + 2 * n_hits
        else:
            pos = cursor + rel
            idx = jnp.where(flat, pos, capacity)
            keys = keys.at[idx].set(qid, mode="drop")
            vals = vals.at[idx].set(cid, mode="drop")
            cursor = cursor + n_hits
        return (cursor, keys, vals), None

    keys0 = jnp.full((capacity,), -1, jnp.int32)
    vals0 = jnp.full((capacity,), -1, jnp.int32)
    (count, keys, vals), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.int64), keys0, vals0), (deltas, is_zero)
    )
    return keys, vals, count


def _resolve_index(points, eps, index: Optional[GridIndex]) -> GridIndex:
    if index is not None:
        return index
    return build_grid_host(np.asarray(points), float(eps))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Fused path (distance_impl='fused'): single-pass count -> fill around
# kernels/fused_join.py. One kernel launch sweeps every stencil offset; the
# fill reuses the count pass's hit set / per-tile totals, so each candidate
# distance is evaluated exactly once and the (B, C, n) gathered intermediate
# of the unfused sweep never exists (DESIGN.md S4).
# ---------------------------------------------------------------------------

_FUSED_TQ = 128  # query tile rows (kernel grid unit; batch sizes round up)


@partial(jax.jit, static_argnames=("qp", "q_limit"))
def _fused_prep(index: GridIndex, points_pad: jax.Array, deltas: jax.Array,
                q_start: jax.Array, *, qp: int, q_limit: int):
    """Window descriptors + contiguous query slice for one batch.

    Pure index arithmetic and a contiguous slice -- explicitly NOT a
    ``points_sorted[cand_pos]`` gather; candidate coordinates are only ever
    touched inside the fused kernel. ``q_limit`` < qp zeroes the windows of
    tile-padding query rows so batches rounded up to the tile unit never
    overlap the next batch's queries.
    """
    from repro.core.grid import window_descriptors
    from repro.kernels.fused_join import NP_PAD

    ws, wc = window_descriptors(index, deltas, q_start, qp)
    if q_limit < qp:
        wc = jnp.where(jnp.arange(qp, dtype=jnp.int32) < q_limit, wc, 0)
    q_batch = jax.lax.dynamic_slice(
        points_pad, (q_start, jnp.asarray(0, q_start.dtype)), (qp, NP_PAD))
    return ws, wc, q_batch


def _fused_pad(index: GridIndex, *, q_size: int, c: int,
               q_start_max: int = 0):
    """One padded-points copy shared by every batch of a sweep. The tail
    covers the C-slot window reads and the worst batch's rounded-up query
    slice (``q_start_max`` = largest batch origin), so the per-batch
    dynamic_slice never clamps."""
    from repro.kernels.fused_join import pad_points

    qp = _round_up(max(q_size, 1), _FUSED_TQ)
    tail = max(c, q_start_max + qp - index.num_points)
    return pad_points(index.points_sorted, tail), qp


def _fused_batch_run(index: GridIndex, points_pad, deltas, is_zero, q_start,
                     *, qp: int, q_size: int, c: int, unicomp: bool,
                     keep_hits: bool, method: Optional[str] = None):
    """One query batch through the fused kernel: descriptors -> sweep."""
    from repro.kernels import ops

    ws, wc, q_batch = _fused_prep(
        index, points_pad, deltas, jnp.asarray(q_start, jnp.int32), qp=qp,
        q_limit=max(q_size, 1))
    hits, counts, base = ops.fused_join_hits(
        points_pad, q_batch, ws, wc, is_zero.astype(jnp.int32),
        jnp.asarray(q_start, jnp.int32), index.eps,
        c=c, n_real=index.n_dims, unicomp=unicomp, tq=_FUSED_TQ,
        keep_hits=keep_hits, method=method)
    return ws, wc, hits, counts, base


@partial(jax.jit, static_argnames=("c", "tq", "unicomp", "capacity"))
def _emit_from_hits(index: GridIndex, hits, counts, slot_base, win_start,
                    q_start, *, c: int, tq: int, unicomp: bool,
                    capacity: int):
    """Fill phase of the fused path: scatter pairs from the count pass's hit
    set. No distances here -- positions come from the window descriptors and
    output slots from the kernel's per-tile exclusive scan (``slot_base``)
    offset by the exclusive scan of the per-tile totals."""
    n_off, qp, _ = hits.shape
    npts = index.num_points
    orig = index.order
    q_pos = jnp.asarray(q_start, jnp.int32) + jnp.arange(qp, dtype=jnp.int32)
    q_pos_c = jnp.minimum(q_pos, npts - 1)
    slots = jnp.arange(c, dtype=jnp.int32)
    cand_pos = win_start[:, :, None] + slots[None, None, :]
    # query-major flattening: a query's hits are contiguous in slot order
    h = hits.astype(bool).transpose(1, 0, 2).reshape(qp, n_off * c)
    cp = jnp.minimum(cand_pos.transpose(1, 0, 2).reshape(qp, n_off * c),
                     npts - 1)
    rank = jnp.cumsum(h, axis=1) - 1              # within-query hit rank
    tile_tot = counts.reshape(-1, tq).sum(axis=1).astype(jnp.int64)
    tile_base = jnp.cumsum(tile_tot) - tile_tot
    qbase = jnp.repeat(tile_base, tq) + slot_base.astype(jnp.int64)
    pos = qbase[:, None] + rank
    qid = jnp.broadcast_to(orig[q_pos_c][:, None], h.shape)
    cid = orig[cp]
    keys = jnp.full((capacity,), -1, jnp.int32)
    vals = jnp.full((capacity,), -1, jnp.int32)
    if unicomp:
        # every hit is an unordered pair -> two ordered result rows
        idx_fwd = jnp.where(h, 2 * pos, capacity)
        idx_rev = jnp.where(h, 2 * pos + 1, capacity)
        keys = keys.at[idx_fwd].set(qid, mode="drop")
        vals = vals.at[idx_fwd].set(cid, mode="drop")
        keys = keys.at[idx_rev].set(cid, mode="drop")
        vals = vals.at[idx_rev].set(qid, mode="drop")
        total = 2 * counts.sum(dtype=jnp.int64)
    else:
        idx = jnp.where(h, pos, capacity)
        keys = keys.at[idx].set(qid, mode="drop")
        vals = vals.at[idx].set(cid, mode="drop")
        total = counts.sum(dtype=jnp.int64)
    return keys, vals, total


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def _emit_from_hits_host(order: np.ndarray, hits, win_start, q_start: int,
                         npts: int, unicomp: bool) -> np.ndarray:
    """Host-side fill from the count pass's hit set (no distances, no device
    scatter). The result is host-bound anyway (the paper copies each batch
    off-device, SV-A), and compacting the (n_off, Q, C) hit bitmap with one
    ``np.nonzero`` beats an XLA scatter of mostly-dropped updates by orders
    of magnitude off-TPU; on TPU the device path ``_emit_from_hits`` keeps
    the scatter close to the data."""
    # query-major like the device emit, so both backends produce the SAME
    # row order (per query: offsets in sweep order, slots in window order)
    h = np.asarray(hits).astype(bool).transpose(1, 0, 2)   # (Q, n_off, C)
    ws = np.asarray(win_start)
    q, off, s = np.nonzero(h)
    cand_pos = ws[off, q] + s
    qid = order[np.minimum(q_start + q, npts - 1)]
    cid = order[cand_pos]
    if unicomp:
        out = np.empty((2 * qid.shape[0], 2), np.int32)
        out[0::2, 0] = qid
        out[0::2, 1] = cid
        out[1::2, 0] = cid
        out[1::2, 1] = qid
    else:
        out = np.stack([qid, cid], axis=1).astype(np.int32)
    return out


def _self_join_fused(index: GridIndex, *, unicomp: bool, sort_result: bool,
                     n_batches: int = 1, method: Optional[str] = None,
                     emit: Optional[str] = None):
    """Single-pass count -> fill driver for distance_impl='fused'.

    Per batch: one fused sweep produces the hit set + per-query counts; the
    exact result size follows from the counts (sync point), and the fill is
    a pure compaction/scatter over the same hit set -- no second distance
    pass. ``emit`` selects the fill backend: 'device' (scatter sized by the
    counts, with the kernel's per-tile slot bases; default on TPU) or 'host'
    (np.nonzero compaction of the hit bitmap; default elsewhere). Device
    capacities round to powers of two across batches so the emit scatter
    compiles O(log) times, not per batch.
    """
    if emit is None:
        emit = "device" if jax.default_backend() == "tpu" else "host"
    deltas, is_zero = _offset_tables(index, unicomp)
    c = _round_up(max(int(index.max_per_cell), 1), 8)
    npts = index.num_points
    order_np = np.asarray(index.order)
    n_batches = max(int(n_batches), 1)
    q_size = -(-npts // n_batches)  # ceil
    mult = 2 if unicomp else 1
    points_pad, qp = _fused_pad(index, q_size=q_size, c=c,
                                q_start_max=(n_batches - 1) * q_size)

    def finish(run):
        """Drain one batch: blocks on ITS buffers only, so the next batch's
        kernel (already dispatched, JAX async) overlaps the transfer --
        the paper's SV-A compute/copy overlap, kept on the fused path."""
        q_start, ws, hits, counts, base = run
        if emit == "host":
            pairs = _emit_from_hits_host(
                order_np, hits, ws, q_start, npts, unicomp)
            assert pairs.shape[0] == mult * int(counts.sum(dtype=jnp.int64))
            return pairs
        ordered = mult * int(counts.sum(dtype=jnp.int64))
        capacity = max(ordered if n_batches == 1 else _next_pow2(ordered), 1)
        keys, vals, cnt = _emit_from_hits(
            index, hits, counts, base, ws, jnp.asarray(q_start, jnp.int32),
            c=c, tq=_FUSED_TQ, unicomp=unicomp, capacity=capacity)
        assert int(cnt) == ordered, (int(cnt), ordered)
        return np.stack(
            [np.asarray(keys)[:ordered], np.asarray(vals)[:ordered]], axis=1)

    chunks = []
    prev = None
    for b in range(n_batches):
        q_start = b * q_size
        ws, _, hits, counts, base = _fused_batch_run(
            index, points_pad, deltas, is_zero, q_start, qp=qp,
            q_size=q_size, c=c, unicomp=unicomp, keep_hits=True,
            method=method)
        if prev is not None:
            chunks.append(finish(prev))
        prev = (q_start, ws, hits, counts, base)
    if prev is not None:
        chunks.append(finish(prev))
    out = (np.concatenate(chunks, axis=0) if chunks
           else np.empty((0, 2), np.int32))
    if sort_result:
        out = out[np.lexsort((out[:, 1], out[:, 0]))]
    return out


def _self_join_count_fused(index: GridIndex, *, unicomp: bool,
                           query_batch: Optional[int] = None,
                           method: Optional[str] = None) -> JoinStats:
    """Count-only fused sweep (keep_hits=False: no O(n_off*Q*C) buffer)."""
    deltas, is_zero = _offset_tables(index, unicomp)
    c = _round_up(max(int(index.max_per_cell), 1), 8)
    npts = index.num_points
    q_size = int(query_batch) if query_batch else npts
    mult = 2 if unicomp else 1
    points_pad, qp = _fused_pad(index, q_size=q_size, c=c,
                                q_start_max=((npts - 1) // q_size) * q_size)
    total = cells = cands = 0
    for q_start in range(0, npts, q_size):
        _, wc, _, counts, _ = _fused_batch_run(
            index, points_pad, deltas, is_zero, q_start, qp=qp,
            q_size=q_size, c=c, unicomp=unicomp, keep_hits=False,
            method=method)
        total += mult * int(counts.sum(dtype=jnp.int64))
        cells += int((wc > 0).sum())
        cands += int(wc.sum(dtype=jnp.int64))
    return JoinStats(
        total_pairs=total,
        cells_visited=cells,
        candidates_checked=cands,
        offsets=int(deltas.shape[0]),
        route="dense",
    )


def _fused_count_route(index: GridIndex, n_off: int,
                       backend: Optional[str] = None) -> str:
    """Density heuristic: dense fused sweep vs. empty-neighbor compaction.

    The dense sweep gathers a full C-slot window for every (query, offset)
    probe; in the empty-neighbor regime (high dimensionality, sparse grid)
    >90% of probes miss and that padding traffic makes fused count ~0.6x of
    jnp (EXPERIMENTS.md SPerf, uniform-6d). The compacted counter packs
    live queries before the gather, but pays an O(n_off * |D| log |D|)
    packing sort -- only worth it when the window DMA traffic it saves is
    the binding constraint, i.e. on the TPU kernel path. Off-TPU the
    reference lowering's dense sweep is cache-resident and the packing
    sort dominates instead: measured on the bench 6-D workloads, compact
    LOSES to dense everywhere (EXPERIMENTS.md SServe note), so auto-routing
    stays dense there and ``route='compact'`` remains an explicit override.

    On TPU, cheap proxies from the host grid:

      occupancy = num_cells / prod(dims)  ~ P(random adjacent cell is live)
      n_off * occupancy                   ~ expected live probes per query
      n_off * max_per_cell                ~ dense window slots per query

    Route compact when expected live probes are few (< 3) and the dense
    slot traffic is large enough (>= 256) to amortize the packing sort.
    """
    if backend is None:
        backend = jax.default_backend()
    if backend != "tpu":
        return "dense"
    ncells = max(int(index.num_cells), 1)
    # float prod: a fine 6-D grid overflows int64, and the heuristic only
    # needs a ratio
    volume = max(float(np.prod(np.asarray(index.dims, dtype=np.float64))), 1.0)
    occupancy = ncells / volume
    c = max(int(index.max_per_cell), 1)
    if n_off * occupancy < 3.0 and n_off * c >= 256:
        return "compact"
    return "dense"


@partial(
    jax.jit,
    static_argnames=("cap_q", "max_per_cell", "unicomp", "distance_impl"),
)
def _count_compact(
    index: GridIndex,
    deltas: jax.Array,          # o != 0 offsets only
    *,
    cap_q: int,
    max_per_cell: int,
    unicomp: bool,
    distance_impl: str = "jnp",
):
    """Compacted sweep over the non-zero stencil offsets.

    In high dimensionality most (query, offset) probes hit an EMPTY neighbor
    cell (uniform 6-D: >90% misses), yet the dense sweep still gathers a full
    max_per_cell window of padding for each -- the dominant HBM traffic term
    (EXPERIMENTS.md SPerf). Here queries with a live neighbor are packed into
    ``cap_q`` slots per offset BEFORE the gather, so traffic scales with
    *actual* candidate volume. ``cap_q`` is exact: the driver computes
    max-over-offsets of the live-query count from the host grid, so no
    overflow is possible. The o=0 (own cell) pass stays dense -- every query
    is live there.
    """
    fused = distance_impl == "fused"
    hits_fn = None if fused else _get_distance_impl(distance_impl)
    eps = index.eps
    npts = index.num_points

    def body(carry, delta):
        total, slots = carry
        nbr_cells = _neighbor_ranks_for_delta(index, delta)
        q_pos_all = jnp.arange(npts, dtype=jnp.int32)
        rank = index.point_cell_rank
        nbr_all = nbr_cells[rank]                     # (|D|,)
        live = nbr_all >= 0
        packed = jnp.argsort(~live)[:cap_q].astype(jnp.int32)
        p_live = live[packed]
        q_pos = packed
        nbr = nbr_all[packed]
        nbr_c = jnp.maximum(nbr, 0)
        start = index.cell_start[nbr_c]
        count = jnp.where(p_live, index.cell_count[nbr_c], 0)
        sl = jnp.arange(max_per_cell, dtype=jnp.int32)
        cand_pos = jnp.minimum(start[:, None] + sl[None, :], npts - 1)
        valid = sl[None, :] < count[:, None]
        q = index.points_sorted[q_pos]
        if fused:
            # gather-free refine: candidate POSITIONS go in, the per-dim
            # coordinate reads stay inside the op (kernels/fused_join.py)
            from repro.kernels.ops import fused_window_hits

            hits = fused_window_hits(index.points_sorted, q, cand_pos,
                                     valid, eps)
        else:
            cand = index.points_sorted[cand_pos]
            hits = hits_fn(q, cand, valid, eps)
        if unicomp:
            n = 2 * hits.sum()
        else:
            hits = hits & (cand_pos != q_pos[:, None])
            n = hits.sum()
        return (total + n.astype(jnp.int64),
                slots + valid.sum(dtype=jnp.int64)), None

    init = (jnp.zeros((), jnp.int64), jnp.zeros((), jnp.int64))
    (total, slots), _ = jax.lax.scan(body, init, deltas)
    return total, slots


def compact_cap(index: GridIndex, unicomp: bool) -> int:
    """Exact max live-query count over non-zero offsets (host side)."""
    ncells = int(index.num_cells)
    keys = np.asarray(index.cell_keys[:ncells])
    counts = np.asarray(index.cell_count[:ncells]).astype(np.int64)
    deltas = np.asarray(_offset_tables(index, unicomp)[0][1:])  # skip o=0
    cap = 1
    for delta in deltas:
        pos = np.searchsorted(keys, keys + delta)
        pos = np.minimum(pos, ncells - 1)
        live = keys[pos] == keys + delta
        cap = max(cap, int(counts[live].sum()))
    return cap


def self_join_count_compact(
    points,
    eps,
    *,
    unicomp: bool = True,
    index: Optional[GridIndex] = None,
    distance_impl: str = "jnp",
) -> JoinStats:
    """self_join_count with empty-neighbor compaction (beyond-paper opt)."""
    index = _resolve_index(points, eps, index)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)
    deltas, is_zero = _offset_tables(index, unicomp)
    cap_q = _round_up(compact_cap(index, unicomp), 128)
    # o = 0 dense pass (every query is live in its own cell)
    if distance_impl == "fused":
        points_pad, qp = _fused_pad(
            index, q_size=index.num_points, c=max_per_cell)
        _, wc0, _, counts0, _ = _fused_batch_run(
            index, points_pad, deltas[:1], is_zero[:1], 0, qp=qp,
            q_size=index.num_points, c=max_per_cell, unicomp=unicomp,
            keep_hits=False)
        t0 = (2 if unicomp else 1) * int(counts0.sum(dtype=jnp.int64))
        k0 = int(wc0.sum(dtype=jnp.int64))
    else:
        t0, _, k0 = _count_batch(
            index, deltas[:1], is_zero[:1], jnp.asarray(0, jnp.int32),
            q_size=index.num_points, max_per_cell=max_per_cell,
            unicomp=unicomp, distance_impl=distance_impl)
    tn, slots = _count_compact(
        index, deltas[1:], cap_q=min(cap_q, index.num_points),
        max_per_cell=max_per_cell, unicomp=unicomp,
        distance_impl=distance_impl)
    return JoinStats(
        total_pairs=int(t0) + int(tn),
        cells_visited=0,
        candidates_checked=int(k0) + int(slots),
        offsets=int(deltas.shape[0]),
        route="compact",
    )


def self_join_count(
    points,
    eps,
    *,
    unicomp: bool = True,
    index: Optional[GridIndex] = None,
    distance_impl: str = "jnp",
    query_batch: Optional[int] = None,
    route: Optional[str] = None,
) -> JoinStats:
    """Total ordered-pair count + work counters (no materialized result).

    With ``distance_impl='fused'`` the sweep is auto-routed: the dense
    fused sweep by default, the empty-neighbor compacted counter
    (``self_join_count_compact``) when the density heuristic
    ``_fused_count_route`` detects the sparse/high-dimensional regime
    where dense window gathers are mostly padding. The chosen path is
    logged in ``JoinStats.route``; pass ``route='dense'``/``'compact'`` to
    override. Compact reports no per-cell visit counter (cells_visited=0)
    and checks fewer candidate slots by construction.
    """
    if route not in (None, "dense", "compact"):
        raise ValueError(f"unknown route {route!r}; "
                         f"expected None, 'dense', or 'compact'")
    index = _resolve_index(points, eps, index)
    if distance_impl == "fused":
        if route is None:
            n_off = stencil_offsets(index.n_dims, unicomp).shape[0]
            route = ("dense" if query_batch is not None
                     else _fused_count_route(index, n_off))
        if route == "compact":
            return self_join_count_compact(
                points, eps, unicomp=unicomp, index=index,
                distance_impl="fused")
        return _self_join_count_fused(
            index, unicomp=unicomp, query_batch=query_batch)
    npts = index.num_points
    deltas, is_zero = _offset_tables(index, unicomp)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)
    q_size = int(query_batch) if query_batch else npts
    total = cells = cands = 0
    for q_start in range(0, npts, q_size):
        t, c, k = _count_batch(
            index,
            deltas,
            is_zero,
            jnp.asarray(q_start, jnp.int32),
            q_size=q_size,
            max_per_cell=max_per_cell,
            unicomp=unicomp,
            distance_impl=distance_impl,
        )
        total += int(t)
        cells += int(c)
        cands += int(k)
    return JoinStats(
        total_pairs=total,
        cells_visited=cells,
        candidates_checked=cands,
        offsets=int(deltas.shape[0]),
    )


def self_join(
    points,
    eps,
    *,
    unicomp: bool = True,
    index: Optional[GridIndex] = None,
    distance_impl: str = "jnp",
    sort_result: bool = True,
):
    """Single-batch self-join. Returns (pairs (K,2) int32 np.ndarray).

    Two-phase: exact count, then fill with exactly-sized capacity
    ('jnp'/'pallas'); single-pass count -> fill for 'fused'. For the
    incremental / overlapped execution the paper uses, see
    ``self_join_batched``.
    """
    index = _resolve_index(points, eps, index)
    if distance_impl == "fused":
        return _self_join_fused(
            index, unicomp=unicomp, sort_result=sort_result)
    stats = self_join_count(
        points, eps, unicomp=unicomp, index=index, distance_impl=distance_impl
    )
    capacity = max(stats.total_pairs, 1)
    deltas, is_zero = _offset_tables(index, unicomp)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)
    keys, vals, count = _fill_batch(
        index,
        deltas,
        is_zero,
        jnp.asarray(0, jnp.int32),
        q_size=index.num_points,
        max_per_cell=max_per_cell,
        unicomp=unicomp,
        capacity=capacity,
        distance_impl=distance_impl,
    )
    assert int(count) == stats.total_pairs, (int(count), stats.total_pairs)
    pairs = np.stack([np.asarray(keys), np.asarray(vals)], axis=1)[: int(count)]
    if sort_result:  # the paper sorts the key/value result after the kernel
        pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
    return pairs


def self_join_batched(
    points,
    eps,
    *,
    unicomp: bool = True,
    n_batches: int = 3,
    index: Optional[GridIndex] = None,
    distance_impl: str = "jnp",
    sort_result: bool = True,
):
    """The paper's batching scheme (SV-A): >= 3 query batches, each batch's
    result copied to the host while the next batch computes (JAX async
    dispatch provides the overlap; on TPU these run on separate streams).

    Memory high-water is O(|D|/n_batches * C_max) intermediates + one batch
    result, instead of the full result set -- this is what lets result sets
    larger than device memory complete (paper Fig. 1 regime).
    """
    index = _resolve_index(points, eps, index)
    if distance_impl == "fused":
        return _self_join_fused(
            index, unicomp=unicomp, sort_result=sort_result,
            n_batches=n_batches)
    npts = index.num_points
    n_batches = max(int(n_batches), 1)
    q_size = -(-npts // n_batches)  # ceil
    deltas, is_zero = _offset_tables(index, unicomp)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)

    # Phase 1: per-batch exact counts (cheap; no result materialization).
    counts = []
    for b in range(n_batches):
        t, _, _ = _count_batch(
            index,
            deltas,
            is_zero,
            jnp.asarray(b * q_size, jnp.int32),
            q_size=q_size,
            max_per_cell=max_per_cell,
            unicomp=unicomp,
            distance_impl=distance_impl,
        )
        counts.append(t)
    counts = [int(t) for t in counts]  # sync point
    capacity = max(max(counts), 1)     # one fill compilation reused per batch

    # Phase 2: fill batches; async dispatch overlaps batch b+1 compute with
    # batch b's D2H transfer (np.asarray blocks only on b's buffers).
    device_results = []
    for b in range(n_batches):
        keys, vals, cnt = _fill_batch(
            index,
            deltas,
            is_zero,
            jnp.asarray(b * q_size, jnp.int32),
            q_size=q_size,
            max_per_cell=max_per_cell,
            unicomp=unicomp,
            capacity=capacity,
            distance_impl=distance_impl,
        )
        device_results.append((keys, vals, cnt))

    out = np.empty((sum(counts), 2), dtype=np.int32)
    pos = 0
    for b, (keys, vals, cnt) in enumerate(device_results):
        k = counts[b]
        assert int(cnt) == k
        out[pos : pos + k, 0] = np.asarray(keys)[:k]
        out[pos : pos + k, 1] = np.asarray(vals)[:k]
        pos += k
    if sort_result:
        out = out[np.lexsort((out[:, 1], out[:, 0]))]
    return out


def range_query(
    queries,
    points,
    eps,
    *,
    index: Optional[GridIndex] = None,
    return_pairs: bool = False,
):
    """Epsilon-range counts for EXTERNAL query points against an indexed set.

    Thin compatibility wrapper over ``core.query_join`` (DESIGN.md S5),
    which this function's original implementation grew into. Two bugs of
    that implementation are fixed by the delegation:

      * it defined its ``@jax.jit`` closure per CALL, so every serve
        request paid a fresh trace + compile; the query-join path uses
        module-level jitted functions cached per static bucket shape, and
      * it clamped query cell coordinates with ``clip(qcoords, 1,
        dims - 2)``, whose bounds invert (hi < lo) on grids with < 3 cells
        in a dimension, silently redirecting every query to cell 0; the
        query-join descriptors mask out-of-grid probes exactly in
        coordinate space instead (``grid.external_window_descriptors``).

    Returns (Q,) int32 neighbor counts -- or ``(counts, pairs)`` with
    ``return_pairs`` -- for the DBSCAN-style use the paper cites (SII).
    Services answering sustained traffic should hold a
    ``query_join.prepare(index)`` / ``launch.serve.JoinService`` instead.
    """
    from repro.core.query_join import epsilon_join

    index = _resolve_index(points, eps, index)
    res = epsilon_join(queries, None, index=index, return_pairs=return_pairs)
    if return_pairs:
        return res.counts, res.pairs
    return res.counts


def per_point_neighbor_counts(
    points,
    eps,
    *,
    index: Optional[GridIndex] = None,
) -> np.ndarray:
    """|epsilon-neighborhood| of each point (excl. self) -- the range-query
    building block the paper cites for DBSCAN/OPTICS. Full-stencil sweep with
    a scatter-add on the query id."""
    index = _resolve_index(points, eps, index)
    deltas, is_zero = _offset_tables(index, unicomp=False)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)

    @jax.jit
    def run(index):
        def body(deg, xs):
            delta, _ = xs
            nbr_cells = _neighbor_ranks_for_delta(index, delta)
            q, cand, cand_pos, valid, q_pos, _ = _gather_batch(
                index, nbr_cells, jnp.asarray(0, jnp.int32),
                index.num_points, max_per_cell,
            )
            hits = _distance_hits_jnp(q, cand, valid, index.eps)
            hits = hits & (cand_pos != q_pos[:, None])
            deg = deg.at[index.order[q_pos]].add(hits.sum(axis=1).astype(jnp.int32))
            return deg, None

        deg0 = jnp.zeros((index.num_points,), jnp.int32)
        deg, _ = jax.lax.scan(body, deg0, (deltas, is_zero))
        return deg

    return np.asarray(run(index))
