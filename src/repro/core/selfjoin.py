"""The self-join (paper Alg. 1 + SV optimizations), TPU-native formulation.

The paper's CUDA kernel is thread-per-point: each thread walks the 3^n
adjacent cells of its point, binary-searches B per cell, and appends result
pairs through a global atomic. On a TPU there are no per-lane scatters or
atomics, so we restructure the same computation as an **offset sweep**
(DESIGN.md S2):

    for each stencil offset o in {-1,0,1}^n (or the UNICOMP half-stencil):
        nbr[h]   = rank in B of (cell h + o)          -- one batched searchsorted
        for every query point i (vectorized):          -- regular, branch-free
            candidates = A[start[nbr[rank_i]] : +count]  (padded to C_max slots)
            hits       = ||q_i - cand||^2 <= eps^2       (masked)

The candidate distance evaluation is the compute hot-spot; it is pluggable
(``distance_impl``): 'jnp' (reference) or 'pallas' (kernels/cell_join.py,
MXU formulation).

Result emission replaces the paper's atomics with a two-phase
count -> exclusive-scan -> scatter fill; the paper sorts the key/value result
after the kernel, and we optionally do the same. Batching over query points
(paper SV-A) bounds both the result buffer and the gathered-candidate
intermediate; the driver ``self_join_batched`` uses >= 3 batches like the
paper and overlaps device compute with host transfers via JAX async dispatch.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grid as grid_lib
from repro.core.grid import GridIndex, PAD_KEY, build_grid_host, neighbor_rank
from repro.core.stencil import stencil_offsets


@dataclasses.dataclass(frozen=True)
class JoinStats:
    """Work counters (paper Table II analogue: cells and distances checked)."""

    total_pairs: int          # ordered pairs with dist <= eps (excl. self)
    cells_visited: int        # non-empty adjacent cells evaluated
    candidates_checked: int   # candidate slots with a real point
    offsets: int              # stencil offsets swept


def _strides(dims: jax.Array) -> jax.Array:
    """Row-major strides s_j = prod_{k>j} dims_k, so key(c+o)=key(c)+o.s."""
    rev = jnp.cumprod(dims[::-1])          # d_{n-1}, d_{n-1}d_{n-2}, ...
    return jnp.concatenate([rev[-2::-1], jnp.ones((1,), dims.dtype)])


def _offset_tables(index: GridIndex, unicomp: bool):
    """Static offset list -> (deltas (n_off,), is_zero (n_off,)) device arrays."""
    offs = stencil_offsets(index.n_dims, unicomp)          # (n_off, n) np
    deltas = jnp.asarray(offs) @ _strides(index.dims)      # (n_off,) int64
    is_zero = jnp.asarray(np.all(offs == 0, axis=1))
    return deltas, is_zero


def _neighbor_ranks_for_delta(index: GridIndex, delta: jax.Array) -> jax.Array:
    """Rank in B of (cell + offset) for every non-empty cell; -1 if absent.

    Padding cells resolve to padding slots whose cell_count is 0, so they
    contribute no candidates downstream.
    """
    valid = jnp.arange(index.num_points) < index.num_cells
    base = jnp.where(valid, index.cell_keys, 0)
    qk = jnp.where(valid, base + delta, PAD_KEY)
    return neighbor_rank(index, qk)


def _distance_hits_jnp(q, cand, valid, eps):
    """Reference candidate evaluation: (B,n) x (B,C,n) -> (B,C) bool hits."""
    d2 = jnp.sum((q[:, None, :] - cand) ** 2, axis=-1)
    return (d2 <= eps * eps) & valid


def _get_distance_impl(name: str):
    if name == "jnp":
        return _distance_hits_jnp
    if name == "pallas":
        from repro.kernels.ops import cell_join_hits

        return cell_join_hits
    raise ValueError(f"unknown distance_impl {name!r}")


def _gather_batch(index: GridIndex, nbr_rank_cells, q_start, q_size, max_per_cell):
    """Candidate window of each query in the batch under one stencil offset.

    Returns (q (q_size,n), cand (q_size,C,n), cand_pos (q_size,C) int32,
    valid (q_size,C) bool, q_pos (q_size,) int32 position in sorted order).
    """
    q_pos = q_start + jnp.arange(q_size, dtype=jnp.int32)
    q_ok = q_pos < index.num_points
    q_pos_c = jnp.minimum(q_pos, index.num_points - 1)
    q = index.points_sorted[q_pos_c]
    rank = index.point_cell_rank[q_pos_c]
    nbr = nbr_rank_cells[rank]                       # (q_size,) rank in B or -1
    nbr_c = jnp.maximum(nbr, 0)
    start = index.cell_start[nbr_c]
    count = jnp.where(nbr >= 0, index.cell_count[nbr_c], 0)
    slots = jnp.arange(max_per_cell, dtype=jnp.int32)
    cand_pos = start[:, None] + slots[None, :]       # (q_size, C)
    valid = (slots[None, :] < count[:, None]) & q_ok[:, None]
    cand_pos_c = jnp.minimum(cand_pos, index.num_points - 1)
    cand = index.points_sorted[cand_pos_c]
    return q, cand, cand_pos_c, valid, q_pos_c, q_ok


@partial(
    jax.jit,
    static_argnames=("q_size", "max_per_cell", "unicomp", "distance_impl"),
)
def _count_batch(
    index: GridIndex,
    deltas: jax.Array,
    is_zero: jax.Array,
    q_start: jax.Array,
    *,
    q_size: int,
    max_per_cell: int,
    unicomp: bool,
    distance_impl: str = "jnp",
):
    """Count phase: ordered-pair total + work counters for one query batch."""
    hits_fn = _get_distance_impl(distance_impl)
    eps = index.eps

    def body(carry, xs):
        total, cells, cands = carry
        delta, zero = xs
        nbr_cells = _neighbor_ranks_for_delta(index, delta)
        q, cand, cand_pos, valid, q_pos, q_ok = _gather_batch(
            index, nbr_cells, q_start, q_size, max_per_cell
        )
        hits = hits_fn(q, cand, valid, eps)
        if unicomp:
            # o = 0: strict upper triangle within the cell; o != 0: all pairs.
            # Every hit is an unordered pair -> contributes 2 ordered pairs.
            tri = cand_pos > q_pos[:, None]
            hits = hits & jnp.where(zero, tri, True)
            n_ordered = 2 * hits.sum()
        else:
            # full stencil: each ordered pair found exactly once; drop self.
            hits = hits & (cand_pos != q_pos[:, None])
            n_ordered = hits.sum()
        # work counters (paper Table II analogue)
        valid_rank = index.point_cell_rank[
            jnp.minimum(
                q_start + jnp.arange(q_size, dtype=jnp.int32), index.num_points - 1
            )
        ]
        visited = (nbr_cells[valid_rank] >= 0) & q_ok
        return (
            total + n_ordered,
            cells + visited.sum(),
            cands + valid.sum(),
        ), None

    init = (jnp.zeros((), jnp.int64),) * 3
    (total, cells, cands), _ = jax.lax.scan(body, init, (deltas, is_zero))
    return total, cells, cands


@partial(
    jax.jit,
    static_argnames=("q_size", "max_per_cell", "unicomp", "capacity", "distance_impl"),
)
def _fill_batch(
    index: GridIndex,
    deltas: jax.Array,
    is_zero: jax.Array,
    q_start: jax.Array,
    *,
    q_size: int,
    max_per_cell: int,
    unicomp: bool,
    capacity: int,
    distance_impl: str = "jnp",
):
    """Fill phase: emit ordered pairs (original point ids) into a flat buffer.

    The paper's kernel appends through a global atomic and sorts afterwards;
    we compute each hit's output slot with a cumulative sum (deterministic)
    and scatter. Returns (keys, vals, count); slots >= count are PAD (-1).
    """
    hits_fn = _get_distance_impl(distance_impl)
    eps = index.eps
    orig_id = index.order  # sorted position -> original point id

    def body(carry, xs):
        cursor, keys, vals = carry
        delta, zero = xs
        nbr_cells = _neighbor_ranks_for_delta(index, delta)
        q, cand, cand_pos, valid, q_pos, _ = _gather_batch(
            index, nbr_cells, q_start, q_size, max_per_cell
        )
        hits = hits_fn(q, cand, valid, eps)
        if unicomp:
            tri = cand_pos > q_pos[:, None]
            hits = hits & jnp.where(zero, tri, True)
        else:
            hits = hits & (cand_pos != q_pos[:, None])
        flat = hits.reshape(-1)
        rel = jnp.cumsum(flat.astype(jnp.int64)) - 1      # position among hits
        n_hits = jnp.where(flat.shape[0] > 0, rel[-1] + 1, 0)
        qid = jnp.broadcast_to(orig_id[q_pos][:, None], hits.shape).reshape(-1)
        cid = orig_id[cand_pos].reshape(-1)
        if unicomp:
            pos_fwd = cursor + 2 * rel
            pos_rev = pos_fwd + 1
            idx_fwd = jnp.where(flat, pos_fwd, capacity)
            idx_rev = jnp.where(flat, pos_rev, capacity)
            keys = keys.at[idx_fwd].set(qid, mode="drop")
            vals = vals.at[idx_fwd].set(cid, mode="drop")
            keys = keys.at[idx_rev].set(cid, mode="drop")
            vals = vals.at[idx_rev].set(qid, mode="drop")
            cursor = cursor + 2 * n_hits
        else:
            pos = cursor + rel
            idx = jnp.where(flat, pos, capacity)
            keys = keys.at[idx].set(qid, mode="drop")
            vals = vals.at[idx].set(cid, mode="drop")
            cursor = cursor + n_hits
        return (cursor, keys, vals), None

    keys0 = jnp.full((capacity,), -1, jnp.int32)
    vals0 = jnp.full((capacity,), -1, jnp.int32)
    (count, keys, vals), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.int64), keys0, vals0), (deltas, is_zero)
    )
    return keys, vals, count


def _resolve_index(points, eps, index: Optional[GridIndex]) -> GridIndex:
    if index is not None:
        return index
    return build_grid_host(np.asarray(points), float(eps))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@partial(
    jax.jit,
    static_argnames=("cap_q", "max_per_cell", "unicomp", "distance_impl"),
)
def _count_compact(
    index: GridIndex,
    deltas: jax.Array,          # o != 0 offsets only
    *,
    cap_q: int,
    max_per_cell: int,
    unicomp: bool,
    distance_impl: str = "jnp",
):
    """Compacted sweep over the non-zero stencil offsets.

    In high dimensionality most (query, offset) probes hit an EMPTY neighbor
    cell (uniform 6-D: >90% misses), yet the dense sweep still gathers a full
    max_per_cell window of padding for each -- the dominant HBM traffic term
    (EXPERIMENTS.md SPerf). Here queries with a live neighbor are packed into
    ``cap_q`` slots per offset BEFORE the gather, so traffic scales with
    *actual* candidate volume. ``cap_q`` is exact: the driver computes
    max-over-offsets of the live-query count from the host grid, so no
    overflow is possible. The o=0 (own cell) pass stays dense -- every query
    is live there.
    """
    hits_fn = _get_distance_impl(distance_impl)
    eps = index.eps
    npts = index.num_points

    def body(carry, delta):
        total, slots = carry
        nbr_cells = _neighbor_ranks_for_delta(index, delta)
        q_pos_all = jnp.arange(npts, dtype=jnp.int32)
        rank = index.point_cell_rank
        nbr_all = nbr_cells[rank]                     # (|D|,)
        live = nbr_all >= 0
        packed = jnp.argsort(~live)[:cap_q].astype(jnp.int32)
        p_live = live[packed]
        q_pos = packed
        nbr = nbr_all[packed]
        nbr_c = jnp.maximum(nbr, 0)
        start = index.cell_start[nbr_c]
        count = jnp.where(p_live, index.cell_count[nbr_c], 0)
        sl = jnp.arange(max_per_cell, dtype=jnp.int32)
        cand_pos = jnp.minimum(start[:, None] + sl[None, :], npts - 1)
        valid = sl[None, :] < count[:, None]
        q = index.points_sorted[q_pos]
        cand = index.points_sorted[cand_pos]
        hits = hits_fn(q, cand, valid, eps)
        if unicomp:
            n = 2 * hits.sum()
        else:
            hits = hits & (cand_pos != q_pos[:, None])
            n = hits.sum()
        return (total + n.astype(jnp.int64),
                slots + valid.sum(dtype=jnp.int64)), None

    init = (jnp.zeros((), jnp.int64), jnp.zeros((), jnp.int64))
    (total, slots), _ = jax.lax.scan(body, init, deltas)
    return total, slots


def compact_cap(index: GridIndex, unicomp: bool) -> int:
    """Exact max live-query count over non-zero offsets (host side)."""
    ncells = int(index.num_cells)
    keys = np.asarray(index.cell_keys[:ncells])
    counts = np.asarray(index.cell_count[:ncells]).astype(np.int64)
    deltas = np.asarray(_offset_tables(index, unicomp)[0][1:])  # skip o=0
    cap = 1
    for delta in deltas:
        pos = np.searchsorted(keys, keys + delta)
        pos = np.minimum(pos, ncells - 1)
        live = keys[pos] == keys + delta
        cap = max(cap, int(counts[live].sum()))
    return cap


def self_join_count_compact(
    points,
    eps,
    *,
    unicomp: bool = True,
    index: Optional[GridIndex] = None,
    distance_impl: str = "jnp",
) -> JoinStats:
    """self_join_count with empty-neighbor compaction (beyond-paper opt)."""
    index = _resolve_index(points, eps, index)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)
    deltas, is_zero = _offset_tables(index, unicomp)
    cap_q = _round_up(compact_cap(index, unicomp), 128)
    # o = 0 dense pass (every query is live in its own cell)
    t0, _, k0 = _count_batch(
        index, deltas[:1], is_zero[:1], jnp.asarray(0, jnp.int32),
        q_size=index.num_points, max_per_cell=max_per_cell, unicomp=unicomp,
        distance_impl=distance_impl)
    tn, slots = _count_compact(
        index, deltas[1:], cap_q=min(cap_q, index.num_points),
        max_per_cell=max_per_cell, unicomp=unicomp,
        distance_impl=distance_impl)
    return JoinStats(
        total_pairs=int(t0) + int(tn),
        cells_visited=0,
        candidates_checked=int(k0) + int(slots),
        offsets=int(deltas.shape[0]),
    )


def self_join_count(
    points,
    eps,
    *,
    unicomp: bool = True,
    index: Optional[GridIndex] = None,
    distance_impl: str = "jnp",
    query_batch: Optional[int] = None,
) -> JoinStats:
    """Total ordered-pair count + work counters (no materialized result)."""
    index = _resolve_index(points, eps, index)
    npts = index.num_points
    deltas, is_zero = _offset_tables(index, unicomp)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)
    q_size = int(query_batch) if query_batch else npts
    total = cells = cands = 0
    for q_start in range(0, npts, q_size):
        t, c, k = _count_batch(
            index,
            deltas,
            is_zero,
            jnp.asarray(q_start, jnp.int32),
            q_size=q_size,
            max_per_cell=max_per_cell,
            unicomp=unicomp,
            distance_impl=distance_impl,
        )
        total += int(t)
        cells += int(c)
        cands += int(k)
    return JoinStats(
        total_pairs=total,
        cells_visited=cells,
        candidates_checked=cands,
        offsets=int(deltas.shape[0]),
    )


def self_join(
    points,
    eps,
    *,
    unicomp: bool = True,
    index: Optional[GridIndex] = None,
    distance_impl: str = "jnp",
    sort_result: bool = True,
):
    """Single-batch self-join. Returns (pairs (K,2) int32 np.ndarray).

    Two-phase: exact count, then fill with exactly-sized capacity. For the
    incremental / overlapped execution the paper uses, see
    ``self_join_batched``.
    """
    index = _resolve_index(points, eps, index)
    stats = self_join_count(
        points, eps, unicomp=unicomp, index=index, distance_impl=distance_impl
    )
    capacity = max(stats.total_pairs, 1)
    deltas, is_zero = _offset_tables(index, unicomp)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)
    keys, vals, count = _fill_batch(
        index,
        deltas,
        is_zero,
        jnp.asarray(0, jnp.int32),
        q_size=index.num_points,
        max_per_cell=max_per_cell,
        unicomp=unicomp,
        capacity=capacity,
        distance_impl=distance_impl,
    )
    assert int(count) == stats.total_pairs, (int(count), stats.total_pairs)
    pairs = np.stack([np.asarray(keys), np.asarray(vals)], axis=1)[: int(count)]
    if sort_result:  # the paper sorts the key/value result after the kernel
        pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
    return pairs


def self_join_batched(
    points,
    eps,
    *,
    unicomp: bool = True,
    n_batches: int = 3,
    index: Optional[GridIndex] = None,
    distance_impl: str = "jnp",
    sort_result: bool = True,
):
    """The paper's batching scheme (SV-A): >= 3 query batches, each batch's
    result copied to the host while the next batch computes (JAX async
    dispatch provides the overlap; on TPU these run on separate streams).

    Memory high-water is O(|D|/n_batches * C_max) intermediates + one batch
    result, instead of the full result set -- this is what lets result sets
    larger than device memory complete (paper Fig. 1 regime).
    """
    index = _resolve_index(points, eps, index)
    npts = index.num_points
    n_batches = max(int(n_batches), 1)
    q_size = -(-npts // n_batches)  # ceil
    deltas, is_zero = _offset_tables(index, unicomp)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)

    # Phase 1: per-batch exact counts (cheap; no result materialization).
    counts = []
    for b in range(n_batches):
        t, _, _ = _count_batch(
            index,
            deltas,
            is_zero,
            jnp.asarray(b * q_size, jnp.int32),
            q_size=q_size,
            max_per_cell=max_per_cell,
            unicomp=unicomp,
            distance_impl=distance_impl,
        )
        counts.append(t)
    counts = [int(t) for t in counts]  # sync point
    capacity = max(max(counts), 1)     # one fill compilation reused per batch

    # Phase 2: fill batches; async dispatch overlaps batch b+1 compute with
    # batch b's D2H transfer (np.asarray blocks only on b's buffers).
    device_results = []
    for b in range(n_batches):
        keys, vals, cnt = _fill_batch(
            index,
            deltas,
            is_zero,
            jnp.asarray(b * q_size, jnp.int32),
            q_size=q_size,
            max_per_cell=max_per_cell,
            unicomp=unicomp,
            capacity=capacity,
            distance_impl=distance_impl,
        )
        device_results.append((keys, vals, cnt))

    out = np.empty((sum(counts), 2), dtype=np.int32)
    pos = 0
    for b, (keys, vals, cnt) in enumerate(device_results):
        k = counts[b]
        assert int(cnt) == k
        out[pos : pos + k, 0] = np.asarray(keys)[:k]
        out[pos : pos + k, 1] = np.asarray(vals)[:k]
        pos += k
    if sort_result:
        out = out[np.lexsort((out[:, 1], out[:, 0]))]
    return out


def range_query(
    queries,
    points,
    eps,
    *,
    index: Optional[GridIndex] = None,
) -> np.ndarray:
    """Epsilon-range counts for EXTERNAL query points against an indexed set.

    The serving-side building block (launch/serve.py): the grid is built once
    over ``points``; each request batch of queries is answered by the same
    bounded adjacent-cell sweep, with the query's cell derived from its
    coordinates (queries need not belong to the dataset). Returns (Q,) int32
    neighbor counts; the DBSCAN-style use the paper cites (SII).
    """
    index = _resolve_index(points, eps, index)
    queries = jnp.asarray(queries)
    deltas, _ = _offset_tables(index, unicomp=False)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)

    @jax.jit
    def run(index, queries):
        # cell key of each query under the dataset's grid geometry
        qcoords = grid_lib.cell_coords(queries, index.grid_min, index.eps)
        # clamp into the grid (queries may fall outside the indexed volume)
        qcoords = jnp.clip(qcoords, 1, index.dims - 2)
        qkeys = grid_lib.linearize(qcoords, index.dims)
        eps2 = index.eps * index.eps

        def body(counts, delta):
            nbr = neighbor_rank(index, qkeys + delta)      # (Q,)
            nbr_c = jnp.maximum(nbr, 0)
            start = index.cell_start[nbr_c]
            count = jnp.where(nbr >= 0, index.cell_count[nbr_c], 0)
            slots = jnp.arange(max_per_cell, dtype=jnp.int32)
            pos = jnp.minimum(start[:, None] + slots[None, :],
                              index.num_points - 1)
            valid = slots[None, :] < count[:, None]
            cand = index.points_sorted[pos]
            d2 = jnp.sum((queries[:, None, :] - cand) ** 2, axis=-1)
            hits = (d2 <= eps2) & valid
            return counts + hits.sum(axis=1, dtype=jnp.int32), None

        counts0 = jnp.zeros((queries.shape[0],), jnp.int32)
        counts, _ = jax.lax.scan(body, counts0, deltas)
        return counts

    return np.asarray(run(index, queries))


def per_point_neighbor_counts(
    points,
    eps,
    *,
    index: Optional[GridIndex] = None,
) -> np.ndarray:
    """|epsilon-neighborhood| of each point (excl. self) -- the range-query
    building block the paper cites for DBSCAN/OPTICS. Full-stencil sweep with
    a scatter-add on the query id."""
    index = _resolve_index(points, eps, index)
    deltas, is_zero = _offset_tables(index, unicomp=False)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)

    @jax.jit
    def run(index):
        def body(deg, xs):
            delta, _ = xs
            nbr_cells = _neighbor_ranks_for_delta(index, delta)
            q, cand, cand_pos, valid, q_pos, _ = _gather_batch(
                index, nbr_cells, jnp.asarray(0, jnp.int32),
                index.num_points, max_per_cell,
            )
            hits = _distance_hits_jnp(q, cand, valid, index.eps)
            hits = hits & (cand_pos != q_pos[:, None])
            deg = deg.at[index.order[q_pos]].add(hits.sum(axis=1).astype(jnp.int32))
            return deg, None

        deg0 = jnp.zeros((index.num_points,), jnp.int32)
        deg, _ = jax.lax.scan(body, deg0, (deltas, is_zero))
        return deg

    return np.asarray(run(index))
