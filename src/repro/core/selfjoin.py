"""The self-join (paper Alg. 1 + SV optimizations), TPU-native formulation.

The paper's CUDA kernel is thread-per-point: each thread walks the 3^n
adjacent cells of its point, binary-searches B per cell, and appends result
pairs through a global atomic. On a TPU there are no per-lane scatters or
atomics, so we restructure the same computation as an **offset sweep**
(DESIGN.md S2):

    for each stencil offset o in {-1,0,1}^n (or the UNICOMP half-stencil):
        nbr[h]   = rank in B of (cell h + o)          -- one batched searchsorted
        for every query point i (vectorized):          -- regular, branch-free
            candidates = A[start[nbr[rank_i]] : +count]  (padded to C_max slots)
            hits       = ||q_i - cand||^2 <= eps^2       (masked)

The candidate distance evaluation is the compute hot-spot; it is pluggable
(``distance_impl``):

  'jnp'    -- reference: gather the (B, C, n) candidate tensor, evaluate.
  'pallas' -- kernels/cell_join.py refine over the same gathered tensor.
  'fused'  -- kernels/fused_join.py: the gather happens INSIDE the kernel
              (window descriptors via scalar prefetch, HBM->VMEM dynamic
              slice per window), all stencil offsets sweep in ONE launch
              with the query tile VMEM-resident throughout, and count+fill
              share a single distance evaluation per candidate: the kernel
              returns the masked hit set plus per-query counts and the
              per-tile exclusive-scan slot bases, so the fill phase only
              scatters (DESIGN.md S4). No (B, C, n) intermediate exists.
              Launches are occupancy-bucketed (DESIGN.md S6): query rows
              partition by candidate-capacity class (grid.occupancy_plan)
              and each bucket sweeps at ITS static window capacity, so
              skewed data stops paying the global max_per_cell per row;
              tiles and the count route come from the measured tables in
              kernels/autotune.py.

Result emission replaces the paper's atomics with a two-phase
count -> exclusive-scan -> scatter fill ('jnp'/'pallas'; every distance is
computed twice) or the fused single-pass count -> fill above. The paper
sorts the key/value result after the kernel, and we optionally do the same.
Batching over query points (paper SV-A) bounds both the result buffer and
the per-batch hit set; the driver ``self_join_batched`` uses >= 3 batches
like the paper and overlaps device compute with host transfers via JAX
async dispatch.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metric as metric_lib
from repro.core.grid import (GridIndex, build_grid,
                             neighbor_rank, round_up as _round_up)
from repro.core.stencil import stencil_offsets


@dataclasses.dataclass(frozen=True)
class JoinStats:
    """Work counters (paper Table II analogue: cells and distances checked)."""

    total_pairs: int          # ordered pairs with dist <= eps (excl. self)
    cells_visited: int        # non-empty adjacent cells evaluated
    candidates_checked: int   # candidate slots with a real point
    offsets: int              # stencil offsets swept
    # sweep chosen by the routing table (kernels/autotune.py):
    #   'dense'     occupancy-bucketed fused sweep (full window per probe)
    #   'dense-run' fused sweep with cell-run DMA dedup (DESIGN.md S11)
    #   'compact'   per-offset live-query packing before the gather (TPU)
    #   'sparse'    probe-compacted counter (empty-neighbor regime, off-TPU)
    #   'jnp'       reference dense counter (fused plan measured slower)
    route: str = "dense"
    # cell-run DMA accounting (DESIGN.md S11): window gathers the fused
    # sweep issued across all launches and offsets (n_off * runs with the
    # run loop, n_off * rows without), and the HBM->VMEM traffic the run
    # loop avoided vs one gather per row. Host-side analytic counters,
    # exact for the kernel's DMA schedule on any backend.
    dma_windows_issued: int = 0
    dma_bytes_saved: int = 0

    @property
    def n_offsets(self) -> int:
        """Stencil offsets swept: 3^n (full) / (3^n+1)/2 (UNICOMP) for the
        per-cell sweep; 3^(n-1) / (3^(n-1)+1)/2 for the merged-range sweep
        (DESIGN.md S7)."""
        return self.offsets


def _offset_tables(index: GridIndex, unicomp: bool):
    """Static offset list -> (deltas (n_off,), is_zero (n_off,)) device arrays."""
    from repro.core.grid import row_major_strides

    offs = stencil_offsets(index.n_dims, unicomp)          # (n_off, n) np
    deltas = jnp.asarray(offs) @ row_major_strides(index.dims)  # (n_off,)
    is_zero = jnp.asarray(np.all(offs == 0, axis=1))
    return deltas, is_zero


def _merged_offset_tables(index: GridIndex, unicomp: bool):
    """Merged-range sweep tables (DESIGN.md S7).

    Returns (dtab (3, n_off) int64, is_zero (n_off,)): row 0 the linearized
    reduced offsets (last coordinate 0), rows 1/2 the lo/hi last-dimension
    span deltas each reduced offset covers ({-1..+1}; the UNICOMP zero
    offset spans [0, +1]). Packed as one array so the jitted descriptor
    preps keep a single traced-operand signature for both sweep modes.
    """
    from repro.core.grid import row_major_strides
    from repro.core.stencil import merged_stencil_offsets

    reduced, lo, hi = merged_stencil_offsets(index.n_dims, unicomp)
    deltas = jnp.asarray(reduced) @ row_major_strides(index.dims)
    dtab = jnp.stack([deltas, jnp.asarray(lo), jnp.asarray(hi)])
    is_zero = jnp.asarray(np.all(reduced == 0, axis=1))
    return dtab, is_zero


def _resolve_merge(index: GridIndex, merge_last_dim: Optional[bool]) -> bool:
    """The shared merge-resolution rule applied to this index (see
    ``kernels.fused_join.resolve_merge_last_dim``)."""
    from repro.kernels.fused_join import resolve_merge_last_dim

    return resolve_merge_last_dim(index.n_dims, merge_last_dim)


def _neighbor_ranks_for_delta(index: GridIndex, delta: jax.Array) -> jax.Array:
    """Rank in B of (cell + offset) for every non-empty cell; -1 if absent.

    Padding cells resolve to padding slots whose cell_count is 0, so they
    contribute no candidates downstream.
    """
    from repro.core.grid import _pad_probe

    valid = jnp.arange(index.num_points) < index.num_cells
    base = jnp.where(valid, index.cell_keys, 0)
    qk = _pad_probe(base + delta, valid, index.cell_keys.dtype)
    return neighbor_rank(index, qk)


def _distance_hits_jnp(q, cand, valid, eps):
    """Reference candidate evaluation: (B,n) x (B,C,n) -> (B,C) bool hits."""
    d2 = jnp.sum((q[:, None, :] - cand) ** 2, axis=-1)
    return metric_lib.l2_sq_hits(d2, eps) & valid


def _get_distance_impl(name: str):
    if name == "jnp":
        return _distance_hits_jnp
    if name == "pallas":
        from repro.kernels.ops import cell_join_hits

        return cell_join_hits
    raise ValueError(f"unknown distance_impl {name!r}")


def _gather_batch(index: GridIndex, nbr_rank_cells, q_start, q_size, max_per_cell):
    """Candidate window of each query in the batch under one stencil offset.

    Returns (q (q_size,n), cand (q_size,C,n), cand_pos (q_size,C) int32,
    valid (q_size,C) bool, q_pos (q_size,) int32 position in sorted order).
    """
    q_pos = q_start + jnp.arange(q_size, dtype=jnp.int32)
    q_ok = q_pos < index.num_points
    q_pos_c = jnp.minimum(q_pos, index.num_points - 1)
    q = index.points_sorted[q_pos_c]
    rank = index.point_cell_rank[q_pos_c]
    nbr = nbr_rank_cells[rank]                       # (q_size,) rank in B or -1
    nbr_c = jnp.maximum(nbr, 0)
    start = index.cell_start[nbr_c]
    count = jnp.where(nbr >= 0, index.cell_count[nbr_c], 0)
    slots = jnp.arange(max_per_cell, dtype=jnp.int32)
    cand_pos = start[:, None] + slots[None, :]       # (q_size, C)
    valid = (slots[None, :] < count[:, None]) & q_ok[:, None]
    cand_pos_c = jnp.minimum(cand_pos, index.num_points - 1)
    cand = index.points_sorted[cand_pos_c]
    return q, cand, cand_pos_c, valid, q_pos_c, q_ok


@partial(
    jax.jit,
    static_argnames=("q_size", "max_per_cell", "unicomp", "distance_impl"),
)
def _count_batch(
    index: GridIndex,
    deltas: jax.Array,
    is_zero: jax.Array,
    q_start: jax.Array,
    *,
    q_size: int,
    max_per_cell: int,
    unicomp: bool,
    distance_impl: str = "jnp",
):
    """Count phase: ordered-pair total + work counters for one query batch."""
    hits_fn = _get_distance_impl(distance_impl)
    eps = index.eps

    def body(carry, xs):
        total, cells, cands = carry
        delta, zero = xs
        nbr_cells = _neighbor_ranks_for_delta(index, delta)
        q, cand, cand_pos, valid, q_pos, q_ok = _gather_batch(
            index, nbr_cells, q_start, q_size, max_per_cell
        )
        hits = hits_fn(q, cand, valid, eps)
        if unicomp:
            # o = 0: strict upper triangle within the cell; o != 0: all pairs.
            # Every hit is an unordered pair -> contributes 2 ordered pairs.
            tri = cand_pos > q_pos[:, None]
            hits = hits & jnp.where(zero, tri, True)
            n_ordered = 2 * hits.sum()
        else:
            # full stencil: each ordered pair found exactly once; drop self.
            hits = hits & (cand_pos != q_pos[:, None])
            n_ordered = hits.sum()
        # work counters (paper Table II analogue)
        valid_rank = index.point_cell_rank[
            jnp.minimum(
                q_start + jnp.arange(q_size, dtype=jnp.int32), index.num_points - 1
            )
        ]
        visited = (nbr_cells[valid_rank] >= 0) & q_ok
        return (
            total + n_ordered,
            cells + visited.sum(),
            cands + valid.sum(),
        ), None

    init = (jnp.zeros((), jnp.int64),) * 3
    (total, cells, cands), _ = jax.lax.scan(body, init, (deltas, is_zero))
    return total, cells, cands


@partial(
    jax.jit,
    static_argnames=("q_size", "max_per_cell", "unicomp", "capacity", "distance_impl"),
)
def _fill_batch(
    index: GridIndex,
    deltas: jax.Array,
    is_zero: jax.Array,
    q_start: jax.Array,
    *,
    q_size: int,
    max_per_cell: int,
    unicomp: bool,
    capacity: int,
    distance_impl: str = "jnp",
):
    """Fill phase: emit ordered pairs (original point ids) into a flat buffer.

    The paper's kernel appends through a global atomic and sorts afterwards;
    we compute each hit's output slot with a cumulative sum (deterministic)
    and scatter. Returns (keys, vals, count); slots >= count are PAD (-1).
    """
    hits_fn = _get_distance_impl(distance_impl)
    eps = index.eps
    orig_id = index.order  # sorted position -> original point id

    def body(carry, xs):
        cursor, keys, vals = carry
        delta, zero = xs
        nbr_cells = _neighbor_ranks_for_delta(index, delta)
        q, cand, cand_pos, valid, q_pos, _ = _gather_batch(
            index, nbr_cells, q_start, q_size, max_per_cell
        )
        hits = hits_fn(q, cand, valid, eps)
        if unicomp:
            tri = cand_pos > q_pos[:, None]
            hits = hits & jnp.where(zero, tri, True)
        else:
            hits = hits & (cand_pos != q_pos[:, None])
        flat = hits.reshape(-1)
        rel = jnp.cumsum(flat.astype(jnp.int64)) - 1      # position among hits
        n_hits = jnp.where(flat.shape[0] > 0, rel[-1] + 1, 0)
        qid = jnp.broadcast_to(orig_id[q_pos][:, None], hits.shape).reshape(-1)
        cid = orig_id[cand_pos].reshape(-1)
        if unicomp:
            pos_fwd = cursor + 2 * rel
            pos_rev = pos_fwd + 1
            idx_fwd = jnp.where(flat, pos_fwd, capacity)
            idx_rev = jnp.where(flat, pos_rev, capacity)
            keys = keys.at[idx_fwd].set(qid, mode="drop")
            vals = vals.at[idx_fwd].set(cid, mode="drop")
            keys = keys.at[idx_rev].set(cid, mode="drop")
            vals = vals.at[idx_rev].set(qid, mode="drop")
            cursor = cursor + 2 * n_hits
        else:
            pos = cursor + rel
            idx = jnp.where(flat, pos, capacity)
            keys = keys.at[idx].set(qid, mode="drop")
            vals = vals.at[idx].set(cid, mode="drop")
            cursor = cursor + n_hits
        return (cursor, keys, vals), None

    keys0 = jnp.full((capacity,), -1, jnp.int32)
    vals0 = jnp.full((capacity,), -1, jnp.int32)
    (count, keys, vals), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.int64), keys0, vals0), (deltas, is_zero)
    )
    return keys, vals, count


def _resolve_index(points, eps, index: Optional[GridIndex]) -> GridIndex:
    if index is not None:
        return index
    # device build (bit-identical to build_grid_host; DESIGN.md S10)
    return build_grid(np.asarray(points), float(eps))


# ---------------------------------------------------------------------------
# Fused path (distance_impl='fused'): single-pass count -> fill around
# kernels/fused_join.py. One kernel launch sweeps every stencil offset; the
# fill reuses the count pass's hit set / per-tile totals, so each candidate
# distance is evaluated exactly once and the (B, C, n) gathered intermediate
# of the unfused sweep never exists (DESIGN.md S4).
#
# Occupancy bucketing (DESIGN.md S6): instead of ONE launch padded to the
# global max_per_cell, query rows are partitioned by candidate-capacity
# class (grid.occupancy_plan) and each bucket launches with its own static
# window capacity -- on skewed data most rows live in the small classes, so
# the padding-lane distance evaluations of the single-capacity sweep
# disappear. Per-bucket counts/slot bases compose back into the same
# single-pass count -> fill contract; the query tile per (backend, n_dims,
# capacity) class comes from the measured table in kernels/autotune.py.
# ---------------------------------------------------------------------------

def _fused_tile(index: GridIndex, c: int) -> int:
    from repro.kernels import autotune

    return autotune.fused_tile(index.n_dims, c)


@partial(jax.jit, static_argnames=("qp", "q_limit", "merged"))
def _fused_prep(index: GridIndex, points_pad: jax.Array, deltas: jax.Array,
                q_start: jax.Array, *, qp: int, q_limit: int,
                merged: bool = False):
    """Window descriptors + contiguous query slice for one batch.

    Pure index arithmetic and a contiguous slice -- explicitly NOT a
    ``points_sorted[cand_pos]`` gather; candidate coordinates are only ever
    touched inside the fused kernel. ``q_limit`` < qp zeroes the windows of
    tile-padding query rows so batches rounded up to the tile unit never
    overlap the next batch's queries.

    ``merged``: ``deltas`` is the (3, n_off) merged table
    (``_merged_offset_tables``) and the descriptors are last-dimension
    range windows; the extra ``wcells`` return is the per-window non-empty
    cell count (1/0 for per-cell windows), keeping merged and unmerged
    work counters identical.
    """
    from repro.core.grid import (range_window_descriptors,
                                 window_descriptors)

    if merged:
        ws, wc, wcells = range_window_descriptors(
            index, deltas[0], deltas[1], deltas[2], q_start, qp)
    else:
        ws, wc = window_descriptors(index, deltas, q_start, qp)
        wcells = (wc > 0).astype(jnp.int32)
    if q_limit < qp:
        ok = jnp.arange(qp, dtype=jnp.int32) < q_limit
        wc = jnp.where(ok, wc, 0)
        wcells = jnp.where(ok, wcells, 0)
    q_batch = jax.lax.dynamic_slice(
        points_pad, (q_start, jnp.asarray(0, q_start.dtype)),
        (qp, points_pad.shape[1]))
    q_pos = jnp.asarray(q_start, jnp.int32) + jnp.arange(qp, dtype=jnp.int32)
    return ws, wc, wcells, q_batch, q_pos


@partial(jax.jit, static_argnames=("qp", "merged"))
def _fused_bucket_prep(index: GridIndex, points_pad: jax.Array,
                       deltas: jax.Array, sel: jax.Array, nsel: jax.Array,
                       *, qp: int, merged: bool = False):
    """Window descriptors + gathered query rows for one occupancy bucket.

    ``sel`` is the bucket's (qp,) sorted-position selection (ascending
    A-order, padded with any in-range value); rows >= ``nsel`` are padding
    and get zeroed windows. The candidate windows stay contiguous runs of
    ``points_sorted`` -- only the QUERY side is permuted.
    """
    from repro.core.grid import (range_window_descriptors_at,
                                 window_descriptors_at)

    q_ok = jnp.arange(qp, dtype=jnp.int32) < nsel
    q_pos = jnp.minimum(sel.astype(jnp.int32), index.num_points - 1)
    if merged:
        ws, wc, wcells = range_window_descriptors_at(
            index, deltas[0], deltas[1], deltas[2], q_pos, q_ok)
    else:
        ws, wc = window_descriptors_at(index, deltas, q_pos, q_ok)
        wcells = (wc > 0).astype(jnp.int32)
    q_batch = points_pad[q_pos]
    return ws, wc, wcells, q_batch, q_pos


def _fused_pad(index: GridIndex, *, q_size: int, c: int,
               q_start_max: int = 0, tq: int = 128, merged: bool = False,
               gid=None, feats=None):
    """One padded-points copy shared by every batch of a sweep. The tail
    covers the C-slot window reads and the worst batch's rounded-up query
    slice (``q_start_max`` = largest batch origin), so the per-batch
    dynamic_slice never clamps. Merged sweeps ride the per-point last-dim
    cell coordinate in the first pad lane (the kernel's boundary mask);
    query slices of this copy inherit it. ``gid`` (distributed slab join)
    rides the per-point global id in the next free lane. ``feats``
    (metric feature payload in SORTED point order, DESIGN.md S12) rides
    immediately after the coordinate lanes."""
    from repro.core.grid import point_last_coords
    from repro.kernels.fused_join import pad_points

    qp = _round_up(max(q_size, 1), tq)
    tail = max(c, q_start_max + qp - index.num_points)
    lc = point_last_coords(index) if merged else None
    return pad_points(index.points_sorted, tail, last_coord=lc,
                      gid=gid, feats=feats), qp


def _host_cell_ranks(index: GridIndex) -> np.ndarray:
    """Host copy of ``point_cell_rank``, cached per index -- run planning
    (DESIGN.md S11) happens on the host alongside the launch schedule."""
    from repro.core.grid import index_cached

    return index_cached(index, "rank_np",
                        lambda: np.asarray(index.point_cell_rank))


def _launch_run_plan(index: GridIndex, sel: Optional[np.ndarray],
                     q_start: int, *, qp: int, tile: int):
    """Cell-run plan of one fused launch (DESIGN.md S11).

    Row identities are the queries' cell RANKS at the same clamped
    positions the descriptor preps resolve windows from, so a row and its
    windows can never disagree about the cell. Padding rows group with
    whatever cell their clamped position lands in -- their window counts
    are zeroed by the preps, so any grouping of them is inert (the kernel
    masks every slot of a count-0 window).
    """
    from repro.core.grid import cell_run_plan

    rank = _host_cell_ranks(index)
    npts = index.num_points
    if sel is None:
        pos = int(q_start) + np.arange(qp)
    else:
        pos = np.zeros(qp, np.int64)
        pos[:sel.shape[0]] = sel
    return cell_run_plan(rank[np.minimum(pos, npts - 1)], tile)


@partial(jax.jit, static_argnames=("qp", "q_limit"))
def _fused_table_prep(index: GridIndex, points_pad: jax.Array, tab_ws,
                      tab_wc, tab_wcells, q_start: jax.Array, *, qp: int,
                      q_limit: int):
    """Run-mode descriptor prep for a contiguous batch: GATHER from the
    per-cell tables (``grid.cell_window_tables``) instead of re-running
    the searchsorted plane per launch -- the descriptor half of the
    paper's duplicate-search removal (SIV-C). Produces bit-identical
    hits/counts/work-counters to ``_fused_prep``: table columns replicate
    the per-row descriptor math per cell rank, and the only rows whose
    ``win_start`` can differ are dead ones (count forced to 0), which no
    consumer reads."""
    npts = index.num_points
    q_pos = jnp.asarray(q_start, jnp.int32) + jnp.arange(qp, dtype=jnp.int32)
    rank = index.point_cell_rank[jnp.minimum(q_pos, npts - 1)]
    ok = (q_pos < npts) & (jnp.arange(qp, dtype=jnp.int32) < q_limit)
    ws = tab_ws[:, rank]
    wc = jnp.where(ok[None, :], tab_wc[:, rank], 0)
    wcells = jnp.where(ok[None, :], tab_wcells[:, rank], 0)
    q_batch = jax.lax.dynamic_slice(
        points_pad, (q_start, jnp.asarray(0, q_start.dtype)),
        (qp, points_pad.shape[1]))
    return ws, wc, wcells, q_batch, q_pos


@partial(jax.jit, static_argnames=("qp",))
def _fused_table_bucket_prep(index: GridIndex, points_pad: jax.Array,
                             tab_ws, tab_wc, tab_wcells, sel: jax.Array,
                             nsel: jax.Array, *, qp: int):
    """Run-mode descriptor prep for an occupancy bucket (see
    ``_fused_table_prep``); mirrors ``_fused_bucket_prep`` row for row."""
    npts = index.num_points
    q_ok = jnp.arange(qp, dtype=jnp.int32) < nsel
    q_pos = jnp.minimum(sel.astype(jnp.int32), npts - 1)
    rank = index.point_cell_rank[q_pos]
    ws = tab_ws[:, rank]
    wc = jnp.where(q_ok[None, :], tab_wc[:, rank], 0)
    wcells = jnp.where(q_ok[None, :], tab_wcells[:, rank], 0)
    q_batch = points_pad[q_pos]
    return ws, wc, wcells, q_batch, q_pos


def _fused_batch_run(index: GridIndex, points_pad, deltas, is_zero, q_start,
                     *, qp: int, q_size: int, c: int, unicomp: bool,
                     keep_hits: bool, method: Optional[str] = None,
                     tq: int = 128, merged: bool = False,
                     gid_pairs: bool = False, run_plan=None,
                     metric: str = "l2", n_feat: int = 0,
                     refine_eps=None):
    """One contiguous query batch through the fused kernel.

    ``run_plan`` (a ``grid.RunPlan`` for THIS launch's rows) switches on
    the cell-run path (DESIGN.md S11): descriptors gather from the cached
    per-cell tables and the kernel DMAs one window per run.

    ``metric``/``n_feat`` (DESIGN.md S12) select the static refine
    predicate; ``refine_eps`` overrides the scalar the kernel refines
    against (``metric.Canonical.refine``) when the index's cell width is
    not it -- the jaccard grid prunes on set sizes at ``eps_geom`` while
    the kernel compares against the similarity threshold t.
    """
    from repro.core.grid import cell_window_tables
    from repro.kernels import ops

    if run_plan is not None:
        tab_ws, tab_wc, tab_wcells = cell_window_tables(
            index, deltas, merged=merged, tag=unicomp)
        ws, wc, wcells, q_batch, q_pos = _fused_table_prep(
            index, points_pad, tab_ws, tab_wc, tab_wcells,
            jnp.asarray(q_start, jnp.int32), qp=qp,
            q_limit=max(q_size, 1))
    else:
        ws, wc, wcells, q_batch, q_pos = _fused_prep(
            index, points_pad, deltas, jnp.asarray(q_start, jnp.int32),
            qp=qp, q_limit=max(q_size, 1), merged=merged)
    hits, counts, base = ops.fused_join_hits(
        points_pad, q_batch, ws, wc, is_zero.astype(jnp.int32), q_pos,
        index.eps if refine_eps is None else refine_eps,
        c=c, n_real=index.n_dims, unicomp=unicomp, tq=tq,
        merged=merged, gid_pairs=gid_pairs, keep_hits=keep_hits,
        run_ord=None if run_plan is None else jnp.asarray(run_plan.run_ord),
        run_loop=run_plan is not None, method=method, metric=metric,
        n_feat=n_feat)
    return ws, wc, wcells, hits, counts, base, q_pos


def _fused_bucket_launch(index: GridIndex, points_pad, deltas, is_zero,
                         sel: np.ndarray, *, qp: int, c: int, unicomp: bool,
                         keep_hits: bool, method: Optional[str] = None,
                         tq: int = 128, merged: bool = False,
                         gid_pairs: bool = False, run_plan=None,
                         metric: str = "l2", n_feat: int = 0,
                         refine_eps=None):
    """One occupancy bucket through the fused kernel at ITS capacity.
    ``run_plan`` / ``metric`` / ``n_feat`` / ``refine_eps`` as in
    ``_fused_batch_run`` (bucket selections keep cells contiguous: a
    cell's rows share window counts, hence a capacity class, and
    ``BucketPlan.sel`` is ascending A-order)."""
    from repro.core.grid import cell_window_tables
    from repro.kernels import ops

    nsel = sel.shape[0]
    sel_pad = np.zeros(qp, np.int32)
    sel_pad[:nsel] = sel
    if run_plan is not None:
        tab_ws, tab_wc, tab_wcells = cell_window_tables(
            index, deltas, merged=merged, tag=unicomp)
        ws, wc, wcells, q_batch, q_pos = _fused_table_bucket_prep(
            index, points_pad, tab_ws, tab_wc, tab_wcells,
            jnp.asarray(sel_pad), jnp.asarray(nsel, jnp.int32), qp=qp)
    else:
        ws, wc, wcells, q_batch, q_pos = _fused_bucket_prep(
            index, points_pad, deltas, jnp.asarray(sel_pad),
            jnp.asarray(nsel, jnp.int32), qp=qp, merged=merged)
    hits, counts, base = ops.fused_join_hits(
        points_pad, q_batch, ws, wc, is_zero.astype(jnp.int32), q_pos,
        index.eps if refine_eps is None else refine_eps,
        c=c, n_real=index.n_dims, unicomp=unicomp, tq=tq,
        merged=merged, gid_pairs=gid_pairs, keep_hits=keep_hits,
        run_ord=None if run_plan is None else jnp.asarray(run_plan.run_ord),
        run_loop=run_plan is not None, method=method, metric=metric,
        n_feat=n_feat)
    return ws, wc, wcells, hits, counts, base, q_pos


@partial(jax.jit, static_argnames=("c", "tq", "unicomp", "capacity"))
def _emit_from_hits(index: GridIndex, ids, hits, counts, slot_base,
                    win_start, q_pos, *, c: int, tq: int, unicomp: bool,
                    capacity: int):
    """Fill phase of the fused path: scatter pairs from the count pass's hit
    set. No distances here -- positions come from the window descriptors and
    output slots from the kernel's per-tile exclusive scan (``slot_base``)
    offset by the exclusive scan of the per-tile totals. ``q_pos`` is the
    launch's per-row sorted-position array (contiguous batch or occupancy
    bucket selection); ``ids`` maps sorted positions to emitted point ids
    (``index.order`` for the single-device join, the slab's GLOBAL id
    array for the distributed join)."""
    n_off, qp, _ = hits.shape
    npts = index.num_points
    orig = ids
    q_pos_c = jnp.minimum(q_pos, npts - 1)
    slots = jnp.arange(c, dtype=jnp.int32)
    cand_pos = win_start[:, :, None] + slots[None, None, :]
    # query-major flattening: a query's hits are contiguous in slot order
    h = hits.astype(bool).transpose(1, 0, 2).reshape(qp, n_off * c)
    cp = jnp.minimum(cand_pos.transpose(1, 0, 2).reshape(qp, n_off * c),
                     npts - 1)
    rank = jnp.cumsum(h, axis=1) - 1              # within-query hit rank
    tile_tot = counts.reshape(-1, tq).sum(axis=1).astype(jnp.int64)
    tile_base = jnp.cumsum(tile_tot) - tile_tot
    qbase = jnp.repeat(tile_base, tq) + slot_base.astype(jnp.int64)
    pos = qbase[:, None] + rank
    qid = jnp.broadcast_to(orig[q_pos_c][:, None], h.shape)
    cid = orig[cp]
    keys = jnp.full((capacity,), -1, jnp.int32)
    vals = jnp.full((capacity,), -1, jnp.int32)
    if unicomp:
        # every hit is an unordered pair -> two ordered result rows
        idx_fwd = jnp.where(h, 2 * pos, capacity)
        idx_rev = jnp.where(h, 2 * pos + 1, capacity)
        keys = keys.at[idx_fwd].set(qid, mode="drop")
        vals = vals.at[idx_fwd].set(cid, mode="drop")
        keys = keys.at[idx_rev].set(cid, mode="drop")
        vals = vals.at[idx_rev].set(qid, mode="drop")
        total = 2 * counts.sum(dtype=jnp.int64)
    else:
        idx = jnp.where(h, pos, capacity)
        keys = keys.at[idx].set(qid, mode="drop")
        vals = vals.at[idx].set(cid, mode="drop")
        total = counts.sum(dtype=jnp.int64)
    return keys, vals, total


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def _emit_from_hits_host(order: np.ndarray, hits, win_start,
                         q_pos: np.ndarray, npts: int,
                         unicomp: bool) -> np.ndarray:
    """Host-side fill from the count pass's hit set (no distances, no device
    scatter). The result is host-bound anyway (the paper copies each batch
    off-device, SV-A), and compacting the (n_off, Q, C) hit bitmap with one
    ``np.nonzero`` beats an XLA scatter of mostly-dropped updates by orders
    of magnitude off-TPU; on TPU the device path ``_emit_from_hits`` keeps
    the scatter close to the data. ``q_pos`` maps launch rows to sorted
    positions (contiguous batch or occupancy bucket selection)."""
    # query-major like the device emit, so both backends produce the SAME
    # row order (per query: offsets in sweep order, slots in window order)
    h = np.asarray(hits).astype(bool).transpose(1, 0, 2)   # (Q, n_off, C)
    ws = np.asarray(win_start)
    q, off, s = np.nonzero(h)
    cand_pos = ws[off, q] + s
    qid = order[np.minimum(q_pos[q], npts - 1)]
    cid = order[cand_pos]
    if unicomp:
        out = np.empty((2 * qid.shape[0], 2), np.int32)
        out[0::2, 0] = qid
        out[0::2, 1] = cid
        out[1::2, 0] = cid
        out[1::2, 1] = qid
    else:
        out = np.stack([qid, cid], axis=1).astype(np.int32)
    return out


def _fused_launches(index: GridIndex, *, n_batches: int,
                    bucketed: Optional[bool], merged: bool = False,
                    row_ok: Optional[np.ndarray] = None,
                    gid=None, feats=None):
    """The launch schedule of one fused sweep: occupancy buckets (each
    chunked to the batching bound), or contiguous batches when the plan is
    a single class. Returns (launches, points_pad, c_max) where every
    launch is (sel|None, q_start, q_size, qp, c, tile). ``merged``
    schedules against the merged range-window capacities (DESIGN.md S7)
    and pads the points copy with the boundary-mask coordinate lane.

    ``row_ok`` (distributed slab join, DESIGN.md S3) restricts query rows
    to a boolean mask over sorted positions (the slab's OWNED rows);
    every launch then becomes an explicit selection. ``gid`` rides the
    per-point global ids in a pad lane of the points copy.
    """
    from repro.core.grid import (BucketPlan, filter_plan_rows,
                                 global_window_cap, occupancy_plan)

    npts = index.num_points
    c_glob = global_window_cap(index, merged)
    n_batches = max(min(int(n_batches), max(npts, 1)), 1)
    batch_rows = -(-max(npts, 1) // n_batches)  # ceil
    if bucketed is None:
        bucketed = True
    plan = occupancy_plan(index, merged=merged) if bucketed else None
    if row_ok is not None:
        if plan is None:
            plan = BucketPlan(caps=(c_glob,), sel=(None,),
                              cap_global=c_glob, hist={c_glob: npts})
        plan = filter_plan_rows(plan, row_ok)
    launches = []
    if plan is None or plan.sel[0] is None:
        cap = c_glob if plan is None else plan.caps[0]
        tile = _fused_tile(index, cap)
        points_pad, qp = _fused_pad(
            index, q_size=batch_rows, c=c_glob, tq=tile,
            q_start_max=(n_batches - 1) * batch_rows, merged=merged,
            gid=gid, feats=feats)
        for b in range(n_batches):
            q_size = min(batch_rows, npts - b * batch_rows)
            launches.append((None, b * batch_rows, q_size, qp, cap, tile))
        return launches, points_pad, c_glob
    points_pad, _ = _fused_pad(index, q_size=1, c=c_glob, merged=merged,
                               gid=gid, feats=feats)
    for cap, sel in zip(plan.caps, plan.sel):
        tile = _fused_tile(index, cap)
        for i in range(0, sel.shape[0], batch_rows):
            piece = sel[i:i + batch_rows]
            qp = _round_up(piece.shape[0], tile)
            launches.append((piece, 0, piece.shape[0], qp, cap, tile))
    return launches, points_pad, c_glob


def _join_run_loop(index: GridIndex) -> bool:
    """Default run-loop decision for the pair-emitting fused join
    (DESIGN.md S11): sharing one window gather across a run only saves
    traffic when cells hold >= 2 queries on average -- below that, runs
    degenerate to rows and the run bookkeeping is pure overhead. The
    COUNT path instead races 'dense-run' as a measured autotune candidate;
    bit-parity with the row loop is guaranteed (and CI-gated) either way,
    so this is purely a performance choice.
    """
    return index.num_points >= 2 * max(int(index.num_cells), 1)


def _self_join_fused(index: GridIndex, *, unicomp: bool, sort_result: bool,
                     n_batches: int = 1, method: Optional[str] = None,
                     emit: Optional[str] = None,
                     bucketed: Optional[bool] = None,
                     merged: bool = True,
                     row_ok: Optional[np.ndarray] = None,
                     ids: Optional[np.ndarray] = None,
                     gid_pairs: bool = False,
                     run_loop: Optional[bool] = None,
                     metric: str = "l2", n_feat: int = 0,
                     feats=None, refine_eps=None):
    """Single-pass count -> fill driver for distance_impl='fused'.

    Per launch (an occupancy bucket chunk, or a contiguous batch when the
    capacity plan collapses to one class): one fused sweep produces the hit
    set + per-query counts; the exact result size follows from the counts
    (sync point), and the fill is a pure compaction/scatter over the same
    hit set -- no second distance pass. ``emit`` selects the fill backend:
    'device' (scatter sized by the counts, with the kernel's per-tile slot
    bases; default on TPU) or 'host' (np.nonzero compaction of the hit
    bitmap; default elsewhere). Device capacities round to powers of two
    across launches so the emit scatter compiles O(log) times, not per
    launch. Bucketed and single-capacity schedules emit the same pair SET
    (row order differs across buckets; ``sort_result`` canonicalizes).

    ``merged`` (default) sweeps the 3^(n-1) merged-range stencil
    (DESIGN.md S7); ``merged=False`` keeps the per-cell 3^n sweep as the
    parity oracle. Both emit the same pair set (asserted in tests and by
    the CI bench smoke) -- the fill machinery is shared unchanged because
    merged windows are still contiguous runs of ``points_sorted``.

    Per-shard reuse (the distributed slab join, DESIGN.md S3) supplies
    ``row_ok`` (query rows restricted to the slab's OWNED sorted
    positions), ``ids`` (sorted position -> GLOBAL point id, replacing
    ``index.order`` in the emit), and ``gid_pairs`` (the kernel's
    UNICOMP/self masks compare global ids riding a pad lane instead of
    local sorted positions). The single-device join is the special case
    row_ok=None, ids=index.order, gid_pairs=False.

    ``run_loop`` (DESIGN.md S11): True routes every launch through the
    cell-run DMA dedup (one window gather per run of co-located queries,
    per-cell descriptor tables); None (default) decides by mean cell
    occupancy (``_join_run_loop``). Pair sets are bit-identical either
    way -- the run plan only regroups when each window is fetched.

    ``metric`` / ``n_feat`` / ``feats`` / ``refine_eps`` (DESIGN.md S12):
    the static refine predicate, its feature payload (SORTED point
    order), and the kernel scalar when it differs from the index's cell
    width (jaccard). The fill machinery is metric-agnostic -- it only
    consumes the hit mask and window descriptors.
    """
    if emit is None:
        emit = "device" if jax.default_backend() == "tpu" else "host"
    if run_loop is None:
        run_loop = _join_run_loop(index)
    if merged:
        deltas, is_zero = _merged_offset_tables(index, unicomp)
    else:
        deltas, is_zero = _offset_tables(index, unicomp)
    npts = index.num_points
    order_np = np.asarray(index.order) if ids is None else np.asarray(ids)
    ids_dev = index.order if ids is None else jnp.asarray(
        np.asarray(ids).astype(np.int32))
    gid = jnp.asarray(order_np.astype(np.int32)) if gid_pairs else None
    mult = 2 if unicomp else 1
    launches, points_pad, _ = _fused_launches(
        index, n_batches=n_batches, bucketed=bucketed, merged=merged,
        row_ok=row_ok, gid=gid, feats=feats)
    single = len(launches) == 1

    def finish(run):
        """Drain one launch: blocks on ITS buffers only, so the next
        launch's kernel (already dispatched, JAX async) overlaps the
        transfer -- the paper's SV-A compute/copy overlap, kept on the
        fused path."""
        ws, hits, counts, base, q_pos, cap, tile = run
        if emit == "host":
            pairs = _emit_from_hits_host(
                order_np, hits, ws, np.asarray(q_pos), npts, unicomp)
            assert pairs.shape[0] == mult * int(counts.sum(dtype=jnp.int64))
            return pairs
        ordered = mult * int(counts.sum(dtype=jnp.int64))
        capacity = max(ordered if single else _next_pow2(ordered), 1)
        keys, vals, cnt = _emit_from_hits(
            index, ids_dev, hits, counts, base, ws, q_pos,
            c=cap, tq=tile, unicomp=unicomp, capacity=capacity)
        assert int(cnt) == ordered, (int(cnt), ordered)
        return np.stack(
            [np.asarray(keys)[:ordered], np.asarray(vals)[:ordered]], axis=1)

    chunks = []
    prev = None
    for sel, q_start, q_size, qp, cap, tile in launches:
        plan = (_launch_run_plan(index, sel, q_start, qp=qp, tile=tile)
                if run_loop else None)
        if sel is None:
            ws, _, _, hits, counts, base, q_pos = _fused_batch_run(
                index, points_pad, deltas, is_zero, q_start, qp=qp,
                q_size=q_size, c=cap, unicomp=unicomp, keep_hits=True,
                method=method, tq=tile, merged=merged, gid_pairs=gid_pairs,
                run_plan=plan, metric=metric, n_feat=n_feat,
                refine_eps=refine_eps)
        else:
            ws, _, _, hits, counts, base, q_pos = _fused_bucket_launch(
                index, points_pad, deltas, is_zero, sel, qp=qp, c=cap,
                unicomp=unicomp, keep_hits=True, method=method, tq=tile,
                merged=merged, gid_pairs=gid_pairs, run_plan=plan,
                metric=metric, n_feat=n_feat, refine_eps=refine_eps)
        if prev is not None:
            chunks.append(finish(prev))
        prev = (ws, hits, counts, base, q_pos, cap, tile)
    if prev is not None:
        chunks.append(finish(prev))
    from repro.analysis import sanitize
    sanitize.raise_pending()   # REPRO_SANITIZE: launches already drained
    out = (np.concatenate(chunks, axis=0) if chunks
           else np.empty((0, 2), np.int32))
    if sort_result:
        out = out[np.lexsort((out[:, 1], out[:, 0]))]
    return out


def _self_join_count_fused(index: GridIndex, *, unicomp: bool,
                           query_batch: Optional[int] = None,
                           method: Optional[str] = None,
                           bucketed: Optional[bool] = None,
                           merged: bool = True,
                           row_ok: Optional[np.ndarray] = None,
                           ids: Optional[np.ndarray] = None,
                           gid_pairs: bool = False,
                           run_loop: bool = False,
                           metric: str = "l2", n_feat: int = 0,
                           feats=None, refine_eps=None) -> JoinStats:
    """Count-only fused sweep (keep_hits=False: no O(n_off*Q*C) buffer).

    Occupancy-bucketed by default; each bucket launch counts at ITS window
    capacity and the per-launch totals/work counters sum to exactly the
    single-capacity sweep's (every query row is in exactly one bucket).
    An explicit ``query_batch`` keeps the contiguous batched sweep (the
    paper's SV-A memory bound) at the global capacity. The merged-range
    sweep reports the SAME cells_visited / candidates_checked as the
    per-cell sweep (a merged window's cell count and length are exactly
    the sum of its constituent per-cell windows'); only ``offsets``
    shrinks to 3^(n-1).

    ``run_loop`` (the 'dense-run' route, DESIGN.md S11) dedups the window
    DMA per cell run; totals and work counters are bit-identical to the
    row loop, and the DMA counters in the returned stats record the
    schedule actually issued (``dma_windows_issued``) plus the gather
    traffic avoided vs one window per row (``dma_bytes_saved``).
    """
    from repro.core.grid import global_window_cap
    from repro.kernels.ops import _kernel_dtype

    if merged:
        deltas, is_zero = _merged_offset_tables(index, unicomp)
        n_off = int(deltas.shape[1])
    else:
        deltas, is_zero = _offset_tables(index, unicomp)
        n_off = int(deltas.shape[0])
    npts = index.num_points
    mult = 2 if unicomp else 1
    gid = (jnp.asarray(np.asarray(ids).astype(np.int32))
           if gid_pairs else None)
    if query_batch:
        c = global_window_cap(index, merged)
        tile = _fused_tile(index, c)
        q_size = int(query_batch)
        points_pad, qp = _fused_pad(
            index, q_size=q_size, c=c, tq=tile,
            q_start_max=((npts - 1) // q_size) * q_size, merged=merged,
            gid=gid, feats=feats)
        launches = [(None, q_start, min(q_size, npts - q_start), qp, c, tile)
                    for q_start in range(0, npts, q_size)]
    else:
        launches, points_pad, _ = _fused_launches(
            index, n_batches=1, bucketed=bucketed, merged=merged,
            row_ok=row_ok, gid=gid, feats=feats)
    total = cells = cands = 0
    dma_windows = dma_saved = 0
    np_pad = int(points_pad.shape[1])
    dtype_bytes = np.dtype(_kernel_dtype(points_pad.dtype)).itemsize
    for sel, q_start, q_size, qp, cap, tile in launches:
        plan = (_launch_run_plan(index, sel, q_start, qp=qp, tile=tile)
                if run_loop else None)
        if plan is None:
            dma_windows += n_off * qp
        else:
            dma_windows += n_off * plan.n_runs
            dma_saved += (n_off * (qp - plan.n_runs)
                          * cap * np_pad * dtype_bytes)
        if sel is None:
            _, wc, wcells, _, counts, _, _ = _fused_batch_run(
                index, points_pad, deltas, is_zero, q_start, qp=qp,
                q_size=q_size, c=cap, unicomp=unicomp, keep_hits=False,
                method=method, tq=tile, merged=merged, gid_pairs=gid_pairs,
                run_plan=plan, metric=metric, n_feat=n_feat,
                refine_eps=refine_eps)
        else:
            _, wc, wcells, _, counts, _, _ = _fused_bucket_launch(
                index, points_pad, deltas, is_zero, sel, qp=qp, c=cap,
                unicomp=unicomp, keep_hits=False, method=method, tq=tile,
                merged=merged, gid_pairs=gid_pairs, run_plan=plan,
                metric=metric, n_feat=n_feat, refine_eps=refine_eps)
        total += mult * int(counts.sum(dtype=jnp.int64))
        cells += int(wcells.sum(dtype=jnp.int64))
        cands += int(wc.sum(dtype=jnp.int64))
    from repro.analysis import sanitize
    sanitize.raise_pending()   # REPRO_SANITIZE: counts already drained
    return JoinStats(
        total_pairs=total,
        cells_visited=cells,
        candidates_checked=cands,
        offsets=n_off,
        route="dense-run" if run_loop else "dense",
        dma_windows_issued=dma_windows,
        dma_bytes_saved=dma_saved,
    )


def dma_window_stats(index: GridIndex, *, unicomp: bool = True,
                     merged: bool = True,
                     bucketed: Optional[bool] = None) -> dict:
    """Analytic DMA-window accounting of one fused sweep's launch schedule
    (DESIGN.md S11) -- no kernels run. Reports the window gathers a
    row-loop sweep would issue (``n_off * rows``), the gathers the
    run-loop sweep issues (``n_off * runs``), the HBM->VMEM bytes the
    dedup avoids, the run-length histogram, and the mean cell occupancy
    the reduction should track. The bench writes this into
    BENCH_selfjoin.json's "dma" section and the CI smoke gates on it.
    """
    from repro.kernels.ops import _kernel_dtype

    if merged:
        deltas, _ = _merged_offset_tables(index, unicomp)
        n_off = int(deltas.shape[1])
    else:
        deltas, _ = _offset_tables(index, unicomp)
        n_off = int(deltas.shape[0])
    launches, points_pad, _ = _fused_launches(
        index, n_batches=1, bucketed=bucketed, merged=merged)
    np_pad = int(points_pad.shape[1])
    dtype_bytes = np.dtype(_kernel_dtype(points_pad.dtype)).itemsize
    rows = runs = saved = 0
    hist: dict = {}
    for sel, q_start, q_size, qp, cap, tile in launches:
        plan = _launch_run_plan(index, sel, q_start, qp=qp, tile=tile)
        rows += n_off * qp
        runs += n_off * plan.n_runs
        saved += n_off * (qp - plan.n_runs) * cap * np_pad * dtype_bytes
        lens, cnts = np.unique(plan.run_lengths, return_counts=True)
        for ln, cnt in zip(lens, cnts):
            hist[int(ln)] = hist.get(int(ln), 0) + int(cnt)
    return {
        "offsets": n_off,
        "dma_windows_row": int(rows),
        "dma_windows_run": int(runs),
        "dma_bytes_saved": int(saved),
        "reduction_factor": rows / max(runs, 1),
        "mean_cell_occupancy": (index.num_points
                                / max(int(index.num_cells), 1)),
        "run_length_hist": {str(k): v for k, v in sorted(hist.items())},
    }


@partial(jax.jit, static_argnames=("qp",))
def _rank_plane_search(keys, rank_arr, deltas, *, qp: int):
    """(n_off, qp) rank-in-B of every (query, offset) probe; -1 = miss.

    Searchsorted formulation (any key-space size): one batched binary
    search over the probe plane and NOTHING else -- window start/count
    gathers are deferred to the packed live probes, so the mostly-dead
    plane never materializes beyond one int32 rank array.
    """
    npts = keys.shape[0]
    q_pos = jnp.arange(qp, dtype=jnp.int32)
    q_ok = q_pos < npts
    own = keys[rank_arr[jnp.minimum(q_pos, npts - 1)]]
    qk = own[None, :] + deltas[:, None]
    pos = jnp.minimum(jnp.searchsorted(keys, qk).astype(jnp.int32), npts - 1)
    hit = (keys[pos] == qk) & q_ok[None, :]
    return jnp.where(hit, pos, -1)


@partial(jax.jit, static_argnames=("qp",))
def _rank_plane_table(table, cell_keys, rank_arr, deltas32, *, qp: int):
    """Rank plane via a dense key -> rank lookup table: a pure GATHER.

    The paper binary-searches B precisely to avoid O(prod(dims)) memory;
    when the key space is small (fine low-volume grids, the uniform-6d
    bench regime) a dense int32 table costs a few MB and replaces the
    probe plane's dominant cost -- 3.7M binary searches on uniform-6d --
    with one gather (measured ~80x faster on this container).
    """
    vol = table.shape[0]
    npts = rank_arr.shape[0]
    q_pos = jnp.arange(qp, dtype=jnp.int32)
    own = cell_keys[rank_arr[jnp.minimum(q_pos, npts - 1)]].astype(jnp.int32)
    own = jnp.where(q_pos < npts, own, -(1 << 30))
    qk = own[None, :] + deltas32[:, None]
    ok = (qk >= 0) & (qk < vol)
    return jnp.where(ok, table[jnp.clip(qk, 0, vol - 1)], -1)


@partial(jax.jit, static_argnames=("qp",))
def _range_plane_search(keys, rank_arr, deltas, lo_off, hi_off, dim_last,
                        *, qp: int):
    """(n_off, qp) merged-range rank spans: one searchsorted PAIR per
    reduced offset over the probe plane (DESIGN.md S7).

    Returns (lo_rank, hi_rank); a probe is live iff hi_rank > lo_rank.
    The last-dimension span clamps at the grid row exactly like
    ``grid.range_window_descriptors_at``.
    """
    npts = keys.shape[0]
    q_pos = jnp.arange(qp, dtype=jnp.int32)
    q_ok = q_pos < npts
    own = keys[rank_arr[jnp.minimum(q_pos, npts - 1)]]
    q_last = own % dim_last
    base = own[None, :] + deltas[:, None]
    lo = jnp.maximum(lo_off[:, None], -q_last[None, :])
    hi = jnp.minimum(hi_off[:, None], dim_last - 1 - q_last[None, :])
    lo_rank = jnp.searchsorted(keys, base + lo, side="left").astype(jnp.int32)
    hi_rank = jnp.searchsorted(keys, base + hi,
                               side="right").astype(jnp.int32)
    hi_rank = jnp.where(q_ok[None, :], hi_rank, lo_rank)   # pad rows dead
    return lo_rank, hi_rank


@partial(jax.jit, static_argnames=("qp",))
def _range_plane_table(table, cell_keys, rank_arr, deltas32, lo_off, hi_off,
                       dim_last, *, qp: int):
    """Merged-range rank spans via the dense key -> rank table: three plane
    GATHERS (one per last-dimension slot) instead of binary searches.

    Within a merged span the only possible keys are base + {-1, 0, +1}, so
    the span's rank range is [min present probed rank, max present probed
    rank + 1] -- contiguity of the span makes the min/max reconstruction
    exact.
    """
    vol = table.shape[0]
    npts = rank_arr.shape[0]
    q_pos = jnp.arange(qp, dtype=jnp.int32)
    own = cell_keys[rank_arr[jnp.minimum(q_pos, npts - 1)]].astype(jnp.int32)
    q_last = own % dim_last
    own = jnp.where(q_pos < npts, own, -(1 << 30))
    base = own[None, :] + deltas32[:, None]
    big = jnp.asarray(1 << 30, jnp.int32)
    lo_rank = jnp.full(base.shape, big, jnp.int32)
    hi_rank = jnp.full(base.shape, -1, jnp.int32)
    for d in (-1, 0, 1):
        qk = base + d
        in_span = ((d >= lo_off[:, None]) & (d <= hi_off[:, None])
                   & (q_last[None, :] + d >= 0)
                   & (q_last[None, :] + d < dim_last))
        ok = in_span & (qk >= 0) & (qk < vol)
        r = jnp.where(ok, table[jnp.clip(qk, 0, vol - 1)], -1)
        present = r >= 0
        lo_rank = jnp.where(present, jnp.minimum(lo_rank, r), lo_rank)
        hi_rank = jnp.where(present, jnp.maximum(hi_rank, r), hi_rank)
    live = hi_rank >= 0
    lo_rank = jnp.where(live, lo_rank, 0)
    hi_rank = jnp.where(live, hi_rank + 1, 0)
    return lo_rank, hi_rank


# Dense-lookup budget: prod(dims) at or below this many cells (x4 bytes)
# buys the table path; beyond it, binary search (the paper's trade) wins.
_LOOKUP_MAX_CELLS = 1 << 23   # 32 MB


def _sparse_lookup(index: GridIndex):
    """Cached per index: ('table', dense key->rank table) when the key
    space fits the budget, else ('keys', int32-or-int64 B).

    The int32 downcast of B applies when every probe key ``own + delta``
    fits (prod(dims) < 2^30): the PAD_KEY sentinel maps to int32 max,
    preserving sort order and never matching a probe; int32 halves the
    binary search's bandwidth.
    """
    from repro.core.grid import index_cached, pad_key_for

    def build():
        volume = float(np.prod(np.asarray(index.dims, dtype=np.float64)))
        ncells = int(index.num_cells)
        if volume <= _LOOKUP_MAX_CELLS:
            keys = np.asarray(index.cell_keys[:ncells])
            table = np.full(int(volume), -1, np.int32)
            # a padded build (build_grid_with_geometry valid=...) carries a
            # sentinel cell with key == prod(dims) (== table length), and
            # out-of-geometry points can produce keys outside [0, volume);
            # keep those cells out of the scatter -- probes to them miss,
            # and padding points were never reachable as candidates anyway
            ok = (keys >= 0) & (keys < int(volume))
            table[keys[ok]] = np.arange(ncells, dtype=np.int32)[ok]
            return ("table", jnp.asarray(table))
        if volume < float(1 << 30):
            k = np.asarray(index.cell_keys)
            if k.dtype == np.int32:
                # int32 key fast path: B already carries the right sentinel
                return ("keys", index.cell_keys)
            k = k.copy()
            k[k == pad_key_for(k.dtype)] = pad_key_for(np.dtype(np.int32))
            return ("keys", jnp.asarray(k.astype(np.int32)))
        return ("keys", index.cell_keys)

    return index_cached(index, "sparse_lookup", build)


@partial(jax.jit, static_argnames=("c", "unicomp"))
def _count_probes_span(points_sorted, eps, p_start, p_count, p_qpos, p_zero,
                       *, c: int, unicomp: bool):
    """Distance evaluation over PACKED probes carrying explicit point
    spans: window start / count arrive precomputed (single-cell windows
    on the per-cell sparse path, rank spans on the merged path), so the
    one probe evaluator serves both sweeps. Padding probes carry
    count 0."""
    npts = points_sorted.shape[0]
    slots = jnp.arange(c, dtype=jnp.int32)
    cand_pos = jnp.minimum(p_start[:, None] + slots[None, :], npts - 1)
    valid = slots[None, :] < p_count[:, None]
    q = points_sorted[jnp.minimum(p_qpos, npts - 1)]
    d2 = jnp.zeros(cand_pos.shape, points_sorted.dtype)
    for dim in range(points_sorted.shape[1]):
        cd = jnp.take(points_sorted[:, dim], cand_pos)
        d2 = d2 + (q[:, dim][:, None] - cd) ** 2
    hit = metric_lib.l2_sq_hits(d2, eps) & valid
    if unicomp:
        tri = cand_pos > p_qpos[:, None]
        hit = hit & jnp.where(p_zero[:, None] != 0, tri, True)
    else:
        hit = hit & (cand_pos != p_qpos[:, None])
    return hit.sum(dtype=jnp.int64)


def _self_join_count_sparse(index: GridIndex, *, unicomp: bool,
                            method: Optional[str] = None,
                            merged: bool = True) -> JoinStats:
    """Probe-compacted counter for the empty-neighbor regime (route
    'sparse').

    In high dimensionality >90% of (query, offset) probes hit an EMPTY
    neighbor cell, yet the dense sweep still evaluates a full capacity-C
    window of padding for each -- the uniform-6d regression (fused count
    0.67x of jnp before this route existed). Three moves make this route
    beat even the jnp scan there: the descriptor pass shrinks to a bare
    rank plane (a dense key->rank lookup table when prod(dims) fits the
    memory budget -- one gather instead of 3.7M binary searches -- else one
    batched searchsorted with int32 keys when they fit), the plane is
    compacted ONCE on the host (``np.nonzero`` -- the count is host-driven
    anyway), and distances + window gathers run only over the packed live
    probes, so eval work scales with actual candidate volume. Work
    counters match the dense sweep's by construction (same probe plane).
    Unlike 'compact' (per-offset argsort packing, a TPU-only win), the
    single flat compaction amortizes across the whole stencil.

    ``merged`` (default) compacts the 3^(n-1) merged-range plane
    (DESIGN.md S7): rank SPANS per probe (searchsorted pair, or three
    table gathers), each packed probe evaluating one contiguous point
    span. The plane shrinks 3x in the offset axis and probes get 3x
    likelier to be live, so the same candidate volume packs into far
    fewer, longer windows.
    """
    del method  # probe evaluation is a jnp op; no kernel variant yet
    from repro.core.grid import global_window_cap

    npts = index.num_points
    mult = 2 if unicomp else 1
    qp = _round_up(max(npts, 1), 128)
    kind, lookup = _sparse_lookup(index)
    if merged:
        dtab, is_zero = _merged_offset_tables(index, unicomp)
        n_off = int(dtab.shape[1])
        c = global_window_cap(index, merged=True)
        dim_last = int(np.asarray(index.dims)[-1])
        if kind == "table":
            lo_rank, hi_rank = _range_plane_table(
                lookup, index.cell_keys, index.point_cell_rank,
                dtab[0].astype(jnp.int32), dtab[1].astype(jnp.int32),
                dtab[2].astype(jnp.int32),
                jnp.asarray(dim_last, jnp.int32), qp=qp)
        else:
            dt = lookup.dtype
            lo_rank, hi_rank = _range_plane_search(
                lookup, index.point_cell_rank, dtab[0].astype(dt),
                dtab[1].astype(dt), dtab[2].astype(dt),
                jnp.asarray(dim_last, dt), qp=qp)
        from repro.core.grid import starts_ext

        lo_rank, hi_rank = np.asarray(lo_rank), np.asarray(hi_rank)
        ext = starts_ext(index)
        off, q = np.nonzero(hi_rank > lo_rank)
        n_live = off.shape[0]
        lo_l, hi_l = lo_rank[off, q], hi_rank[off, q]
        w_start = ext[lo_l]
        w_count = ext[hi_l] - w_start
        cells = int((hi_l - lo_l).sum(dtype=np.int64)) if n_live else 0
        total = 0
        cands = int(w_count.sum(dtype=np.int64)) if n_live else 0
        if n_live:
            from repro.core.grid import capacity_classes

            is_zero_np = np.asarray(is_zero).astype(np.int32)
            q_np, off_np = q, off
            # Merged spans vary 1..3 cells, so a single global capacity
            # would pad every probe to the worst ADJACENT-TRIPLE occupancy
            # (~3x the per-cell max on clustered data). Class the packed
            # probes by pow2 window length instead -- the sparse-route
            # analogue of the occupancy buckets: total padded slots stay
            # within 2x of the true candidate volume at O(log C) compiles.
            ladder = np.asarray(capacity_classes(c, 8))
            cls = np.searchsorted(
                ladder, np.minimum(_round_up(w_count, 8), int(ladder[-1])))
            chunk = 1 << 17
            for k, ccap in enumerate(ladder):
                rows = np.flatnonzero(cls == k)
                for i in range(0, rows.shape[0], chunk):
                    sel = rows[i:i + chunk]
                    m = sel.shape[0]
                    cap = min(chunk, max(_next_pow2(m), 128))
                    p_start = np.zeros(cap, np.int32)
                    p_count = np.zeros(cap, np.int32)
                    p_qpos = np.zeros(cap, np.int32)
                    p_zero = np.zeros(cap, np.int32)
                    p_start[:m] = w_start[sel]
                    p_count[:m] = w_count[sel]
                    p_qpos[:m] = q_np[sel]
                    p_zero[:m] = is_zero_np[off_np[sel]]
                    total += int(_count_probes_span(
                        index.points_sorted, index.eps,
                        jnp.asarray(p_start), jnp.asarray(p_count),
                        jnp.asarray(p_qpos), jnp.asarray(p_zero),
                        c=int(ccap), unicomp=unicomp))
        return JoinStats(
            total_pairs=mult * total,
            cells_visited=cells,
            candidates_checked=cands,
            offsets=n_off,
            route="sparse",
        )
    deltas, is_zero = _offset_tables(index, unicomp)
    c = _round_up(max(int(index.max_per_cell), 1), 8)
    if kind == "table":
        nbr = np.asarray(_rank_plane_table(
            lookup, index.cell_keys, index.point_cell_rank,
            deltas.astype(jnp.int32), qp=qp))
    else:
        nbr = np.asarray(_rank_plane_search(
            lookup, index.point_cell_rank, deltas.astype(lookup.dtype),
            qp=qp))
    off, q = np.nonzero(nbr >= 0)
    n_live = off.shape[0]
    cc_np = np.asarray(index.cell_count)
    cs_np = np.asarray(index.cell_start)
    total = 0
    cands = 0
    if n_live:
        is_zero_np = np.asarray(is_zero).astype(np.int32)
        chunk = 1 << 17   # bounds the (P, C) eval; pow2 pads bound compiles
        for i in range(0, n_live, chunk):
            o_c, q_c = off[i:i + chunk], q[i:i + chunk]
            m = o_c.shape[0]
            cap = min(chunk, max(_next_pow2(m), 128))
            p_start = np.zeros(cap, np.int32)
            p_count = np.zeros(cap, np.int32)
            p_qpos = np.zeros(cap, np.int32)
            p_zero = np.zeros(cap, np.int32)
            live_nbr = nbr[o_c, q_c]
            p_start[:m] = cs_np[live_nbr]
            p_count[:m] = cc_np[live_nbr]
            p_qpos[:m] = q_c
            p_zero[:m] = is_zero_np[o_c]
            cands += int(cc_np[live_nbr].sum(dtype=np.int64))
            total += int(_count_probes_span(
                index.points_sorted, index.eps, jnp.asarray(p_start),
                jnp.asarray(p_count), jnp.asarray(p_qpos),
                jnp.asarray(p_zero), c=c, unicomp=unicomp))
    return JoinStats(
        total_pairs=mult * total,
        cells_visited=n_live,
        candidates_checked=cands,
        offsets=int(deltas.shape[0]),
        route="sparse",
    )


def _route_features(index: GridIndex, deltas) -> dict:
    """Cheap host-side workload features for the routing table.

    ``occupancy`` is the global live-cell fraction (the PR-2 proxy, kept
    for the TPU rule); ``live_frac`` is the SAMPLED per-query live-probe
    fraction under the actual stencil -- occupancy is a poor estimator on
    clustered data, where a query's probes concentrate in its own (live)
    neighborhood.
    """
    ncells = max(int(index.num_cells), 1)
    # float prod: a fine 6-D grid overflows int64, and the heuristic only
    # needs a ratio
    volume = max(float(np.prod(np.asarray(index.dims, dtype=np.float64))), 1.0)
    occupancy = ncells / volume
    c = max(int(index.max_per_cell), 1)
    npts = index.num_points
    live_frac = 0.0
    if npts and ncells:
        keys = np.asarray(index.cell_keys[:ncells])
        rank = np.asarray(index.point_cell_rank)
        sample = rank[::-(-npts // 1024)][:1024]   # ceil stride: spans all
                                                   # of sorted key order
        probe = keys[sample][None, :] + np.asarray(deltas)[:, None]
        pos = np.minimum(np.searchsorted(keys, probe), ncells - 1)
        live_frac = float((keys[pos] == probe).mean())
    return {"occupancy": occupancy, "live_frac": live_frac, "c": c}


def _fused_count_route(index: GridIndex, n_off: int,
                       backend: Optional[str] = None, *,
                       unicomp: bool = True) -> str:
    """Heuristic route for the fused counter (no cache consulted).

    The measured routing table (kernels/autotune.py, consulted by
    ``self_join_count``) supersedes this wherever it has been populated;
    this function is the deterministic fallback and the unit-testable
    regime detector. See ``autotune.route_heuristic`` for the rules.
    """
    from repro.kernels import autotune

    deltas, _ = _offset_tables(index, unicomp)
    feats = _route_features(index, deltas)
    if backend is None:
        backend = jax.default_backend()
    return autotune.route_heuristic(
        backend, index.n_dims, n_off, feats["c"], feats["occupancy"],
        feats["live_frac"])


@partial(
    jax.jit,
    static_argnames=("cap_q", "max_per_cell", "unicomp", "distance_impl"),
)
def _count_compact(
    index: GridIndex,
    deltas: jax.Array,          # o != 0 offsets only
    *,
    cap_q: int,
    max_per_cell: int,
    unicomp: bool,
    distance_impl: str = "jnp",
):
    """Compacted sweep over the non-zero stencil offsets.

    In high dimensionality most (query, offset) probes hit an EMPTY neighbor
    cell (uniform 6-D: >90% misses), yet the dense sweep still gathers a full
    max_per_cell window of padding for each -- the dominant HBM traffic term
    (EXPERIMENTS.md SPerf). Here queries with a live neighbor are packed into
    ``cap_q`` slots per offset BEFORE the gather, so traffic scales with
    *actual* candidate volume. ``cap_q`` is exact: the driver computes
    max-over-offsets of the live-query count from the host grid, so no
    overflow is possible. The o=0 (own cell) pass stays dense -- every query
    is live there.
    """
    fused = distance_impl == "fused"
    hits_fn = None if fused else _get_distance_impl(distance_impl)
    eps = index.eps
    npts = index.num_points

    def body(carry, delta):
        total, slots = carry
        nbr_cells = _neighbor_ranks_for_delta(index, delta)
        q_pos_all = jnp.arange(npts, dtype=jnp.int32)
        rank = index.point_cell_rank
        nbr_all = nbr_cells[rank]                     # (|D|,)
        live = nbr_all >= 0
        packed = jnp.argsort(~live)[:cap_q].astype(jnp.int32)
        p_live = live[packed]
        q_pos = packed
        nbr = nbr_all[packed]
        nbr_c = jnp.maximum(nbr, 0)
        start = index.cell_start[nbr_c]
        count = jnp.where(p_live, index.cell_count[nbr_c], 0)
        sl = jnp.arange(max_per_cell, dtype=jnp.int32)
        cand_pos = jnp.minimum(start[:, None] + sl[None, :], npts - 1)
        valid = sl[None, :] < count[:, None]
        q = index.points_sorted[q_pos]
        if fused:
            # gather-free refine: candidate POSITIONS go in, the per-dim
            # coordinate reads stay inside the op (kernels/fused_join.py)
            from repro.kernels.ops import fused_window_hits

            hits = fused_window_hits(index.points_sorted, q, cand_pos,
                                     valid, eps)
        else:
            cand = index.points_sorted[cand_pos]
            hits = hits_fn(q, cand, valid, eps)
        if unicomp:
            n = 2 * hits.sum()
        else:
            hits = hits & (cand_pos != q_pos[:, None])
            n = hits.sum()
        return (total + n.astype(jnp.int64),
                slots + valid.sum(dtype=jnp.int64)), None

    init = (jnp.zeros((), jnp.int64), jnp.zeros((), jnp.int64))
    (total, slots), _ = jax.lax.scan(body, init, deltas)
    return total, slots


def compact_cap(index: GridIndex, unicomp: bool) -> int:
    """Exact max live-query count over non-zero offsets (host side)."""
    ncells = int(index.num_cells)
    keys = np.asarray(index.cell_keys[:ncells])
    counts = np.asarray(index.cell_count[:ncells]).astype(np.int64)
    deltas = np.asarray(_offset_tables(index, unicomp)[0][1:])  # skip o=0
    cap = 1
    for delta in deltas:
        pos = np.searchsorted(keys, keys + delta)
        pos = np.minimum(pos, ncells - 1)
        live = keys[pos] == keys + delta
        cap = max(cap, int(counts[live].sum()))
    return cap


def self_join_count_compact(
    points,
    eps,
    *,
    unicomp: bool = True,
    index: Optional[GridIndex] = None,
    distance_impl: str = "jnp",
) -> JoinStats:
    """self_join_count with empty-neighbor compaction (beyond-paper opt)."""
    index = _resolve_index(points, eps, index)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)
    deltas, is_zero = _offset_tables(index, unicomp)
    cap_q = _round_up(compact_cap(index, unicomp), 128)
    # o = 0 dense pass (every query is live in its own cell)
    if distance_impl == "fused":
        tile = _fused_tile(index, max_per_cell)
        points_pad, qp = _fused_pad(
            index, q_size=index.num_points, c=max_per_cell, tq=tile)
        _, wc0, _, _, counts0, _, _ = _fused_batch_run(
            index, points_pad, deltas[:1], is_zero[:1], 0, qp=qp,
            q_size=index.num_points, c=max_per_cell, unicomp=unicomp,
            keep_hits=False, tq=tile)
        t0 = (2 if unicomp else 1) * int(counts0.sum(dtype=jnp.int64))
        k0 = int(wc0.sum(dtype=jnp.int64))
    else:
        t0, _, k0 = _count_batch(
            index, deltas[:1], is_zero[:1], jnp.asarray(0, jnp.int32),
            q_size=index.num_points, max_per_cell=max_per_cell,
            unicomp=unicomp, distance_impl=distance_impl)
    tn, slots = _count_compact(
        index, deltas[1:], cap_q=min(cap_q, index.num_points),
        max_per_cell=max_per_cell, unicomp=unicomp,
        distance_impl=distance_impl)
    return JoinStats(
        total_pairs=int(t0) + int(tn),
        cells_visited=0,
        candidates_checked=int(k0) + int(slots),
        offsets=int(deltas.shape[0]),
        route="compact",
    )


def self_join_count(
    points,
    eps,
    *,
    unicomp: bool = True,
    index: Optional[GridIndex] = None,
    distance_impl: str = "jnp",
    query_batch: Optional[int] = None,
    route: Optional[str] = None,
    bucketed: Optional[bool] = None,
    merge_last_dim: Optional[bool] = None,
    metric: str = "l2",
    vocab: Optional[int] = None,
) -> JoinStats:
    """Total ordered-pair count + work counters (no materialized result).

    With ``distance_impl='fused'`` the sweep is auto-routed through the
    measured routing table (kernels/autotune.py): a cached measured winner
    for the workload class when one exists, a timed pass over the live
    candidates when tuning is enabled ($REPRO_AUTOTUNE=1), the occupancy
    heuristic otherwise. Routes: 'dense' (occupancy-bucketed fused sweep),
    'dense-run' (the same sweep with cell-run DMA dedup, DESIGN.md S11;
    measured-only -- the heuristic never picks it), 'compact' (per-offset
    live-query packing, TPU), 'sparse' (probe-compacted counter for the
    empty-neighbor regime), 'jnp' (reference dense counter -- the floor:
    routing can never pin a fused plan that
    measures slower than the baseline). The chosen path is logged in
    ``JoinStats.route``; pass ``route=`` to override. 'dense'/'sparse'/
    'jnp' report identical work counters; 'compact' reports no per-cell
    visit counter (cells_visited=0) and checks fewer candidate slots by
    construction. ``bucketed=False`` forces the single-capacity dense
    sweep (parity/debug knob).

    ``merge_last_dim`` (default on) runs the fused 'dense'/'sparse'
    routes over the 3^(n-1) merged-range stencil (DESIGN.md S7);
    ``merge_last_dim=False`` keeps the per-cell 3^n sweep as the parity
    oracle. Totals and cells/candidates counters are identical either
    way; only ``offsets`` changes. The measured routing table covers the
    SWEEP axis too: 'dense-flat' / 'sparse-flat' run the per-cell sweep
    when it measured faster for the workload class (clustered data in low
    dimensionality, where merged windows pay ~3x capacity padding for
    only a small offset saving); the heuristic fallback never picks them.
    'compact' (a TPU per-offset packing) and the 'jnp' reference always
    sweep per cell.

    ``metric`` / ``vocab`` as in ``self_join`` (DESIGN.md S12): cosine
    canonicalizes onto the unit sphere and counts with the full L2
    routing machinery; jaccard always runs the fused dense sweep over
    the 1-D size grid (the only route whose kernel carries the bitmap
    refine predicate).
    """
    routes = (None, "dense", "compact", "sparse", "jnp", "dense-flat",
              "sparse-flat", "dense-run")
    if route not in routes:
        raise ValueError(f"unknown route {route!r}; expected one of "
                         f"{routes[1:]}")
    metric_lib.check_metric(metric)
    if metric != "l2" or isinstance(points, metric_lib.Canonical):
        canon = _metric_canonical(points, eps, metric, vocab)
        if canon.metric == "jaccard":
            if route not in (None, "dense", "dense-run"):
                raise ValueError(
                    f"route {route!r} does not support metric='jaccard'; "
                    f"only the fused dense sweep carries the bitmap refine")
            idx = _metric_grid(canon)
            return _self_join_count_fused(
                idx, unicomp=unicomp, query_batch=query_batch,
                bucketed=bucketed, merged=False,
                run_loop=route == "dense-run", metric="jaccard",
                n_feat=canon.n_feat, feats=_metric_feats_sorted(canon, idx),
                refine_eps=canon.eps)
        if canon.metric == "cosine":
            index = _metric_grid(canon)
        points, eps = canon.geom, canon.eps_geom
    index = _resolve_index(points, eps, index)
    merged = _resolve_merge(index, merge_last_dim)
    route_label = "dense"
    if distance_impl == "fused":
        if route is None:
            if query_batch is not None:
                route = "dense"
            else:
                route = _auto_route(index, unicomp=unicomp,
                                    bucketed=bucketed, merged=merged)
        if route == "compact":
            return self_join_count_compact(
                points, eps, unicomp=unicomp, index=index,
                distance_impl="fused")
        if route in ("sparse", "sparse-flat"):
            return dataclasses.replace(
                _self_join_count_sparse(
                    index, unicomp=unicomp,
                    merged=merged and route == "sparse"),
                route=route)
        if route in ("dense", "dense-flat", "dense-run"):
            return dataclasses.replace(
                _self_join_count_fused(
                    index, unicomp=unicomp, query_batch=query_batch,
                    bucketed=bucketed,
                    merged=merged and route != "dense-flat",
                    run_loop=route == "dense-run"),
                route=route)
        # route == 'jnp': the fused plan measured slower than the reference
        # dense counter for this workload class -- run that, log the route.
        distance_impl = "jnp"
        route_label = "jnp"
    npts = index.num_points
    deltas, is_zero = _offset_tables(index, unicomp)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)
    q_size = int(query_batch) if query_batch else npts
    total = cells = cands = 0
    for q_start in range(0, npts, q_size):
        t, c, k = _count_batch(
            index,
            deltas,
            is_zero,
            jnp.asarray(q_start, jnp.int32),
            q_size=q_size,
            max_per_cell=max_per_cell,
            unicomp=unicomp,
            distance_impl=distance_impl,
        )
        total += int(t)
        cells += int(c)
        cands += int(k)
    return JoinStats(
        total_pairs=total,
        cells_visited=cells,
        candidates_checked=cands,
        offsets=int(deltas.shape[0]),
        route=route_label,
    )


def _join_sweep_merged(index: GridIndex, *, unicomp: bool,
                       bucketed: Optional[bool], merged: bool) -> bool:
    """Sweep choice for the pair-emitting join: follow the measured count
    route's verdict ONLY when it judged the join's own sweep. The join
    always runs the dense bucketed sweep, so a measured 'dense-flat'
    winner (per-cell dense beat merged dense for this workload class)
    transfers directly; a 'sparse-flat' winner is a verdict about the
    probe-compacted COUNTER's table-vs-span tradeoff and says nothing
    about the dense sweep -- the merged default stands there, as it does
    on the heuristic tier (which never returns '-flat'). Exact either way
    -- the S7 parity guarantee is what licenses the switch."""
    if not merged:
        return False
    route = _auto_route(index, unicomp=unicomp, bucketed=bucketed,
                        merged=True)
    return route != "dense-flat"


def _auto_route(index: GridIndex, *, unicomp: bool,
                bucketed: Optional[bool] = None,
                merged: bool = False) -> str:
    """Consult the routing table; measure the live candidates if tuning is
    enabled; fall back to the occupancy heuristic. The decision is a pure
    function of the index + sweep mode, so it is cached per index object:
    steady-state fused counts pay a dict lookup, not the sampled feature
    probe."""
    from repro.core.grid import index_cached

    return index_cached(
        index, f"route/{unicomp}/{bucketed}/{merged}",
        lambda: _auto_route_uncached(index, unicomp=unicomp,
                                     bucketed=bucketed, merged=merged))


def _auto_route_uncached(index: GridIndex, *, unicomp: bool,
                         bucketed: Optional[bool] = None,
                         merged: bool = False) -> str:
    from repro.kernels import autotune

    # workload features come from the per-cell stencil either way -- they
    # describe the data's neighbor regime, not the sweep; the MERGED
    # sweep's n_off keys a separate table row (its candidates run merged)
    deltas, _ = _offset_tables(index, unicomp)
    feats = _route_features(index, deltas)
    if merged:
        dtab, _ = _merged_offset_tables(index, unicomp)
        n_off = int(dtab.shape[1])
    else:
        n_off = int(deltas.shape[0])
    candidates = None
    if autotune.measure_enabled():
        candidates = {
            "dense": lambda: _self_join_count_fused(
                index, unicomp=unicomp, bucketed=bucketed, merged=merged),
            "sparse": lambda: _self_join_count_sparse(
                index, unicomp=unicomp, merged=merged),
            "jnp": lambda: self_join_count(
                index.points_sorted, index.eps, unicomp=unicomp,
                index=index, distance_impl="jnp"),
        }
        if merged:
            # the sweep itself is a measured axis: clustered data in low
            # dimensionality can pay more in merged-window capacity
            # padding than the 3x offset reduction saves, so the per-cell
            # sweep competes for the slot (pair sets are identical either
            # way -- the S7 parity guarantee is what makes the sweep a
            # pure routing decision)
            candidates["dense-flat"] = lambda: _self_join_count_fused(
                index, unicomp=unicomp, bucketed=bucketed, merged=False)
            candidates["sparse-flat"] = lambda: _self_join_count_sparse(
                index, unicomp=unicomp, merged=False)
            # cell-run DMA dedup (DESIGN.md S11) competes for the same
            # slot: totals are bit-identical to 'dense', so the run loop
            # is a pure measured tradeoff (run bookkeeping + per-cell
            # table gather vs one window DMA per query row)
            candidates["dense-run"] = lambda: _self_join_count_fused(
                index, unicomp=unicomp, bucketed=bucketed, merged=True,
                run_loop=True)
        if jax.default_backend() == "tpu":
            candidates["compact"] = lambda: self_join_count_compact(
                index.points_sorted, index.eps, unicomp=unicomp,
                index=index, distance_impl="fused")
    route, _src = autotune.count_route(
        n_dims=index.n_dims, n_off=n_off, c=feats["c"],
        occupancy=feats["occupancy"], live_frac=feats["live_frac"],
        merged=merged, candidates=candidates)
    return route


def _metric_canonical(points, eps, metric: str,
                      vocab=None) -> metric_lib.Canonical:
    """Resolve the (points, eps, metric) triple to a ``metric.Canonical``:
    pass-through for an already-canonicalized dataset (``eps`` must then
    be None or match), ``metric.canonicalize`` otherwise."""
    if isinstance(points, metric_lib.Canonical):
        canon = points
        if metric not in ("l2", canon.metric):
            raise ValueError(
                f"metric={metric!r} conflicts with the canonical dataset's "
                f"metric {canon.metric!r}")
        if eps is not None and float(eps) != canon.eps:
            raise ValueError(
                f"eps={eps} conflicts with the canonical dataset's "
                f"threshold {canon.eps}; canonicalize at the new threshold")
        return canon
    return metric_lib.canonicalize(points, eps, metric=metric, vocab=vocab)


def _metric_feats_sorted(canon: metric_lib.Canonical,
                         index: GridIndex):
    """Feature payload permuted into the index's sorted point order
    (``points_sorted[i] == points[order[i]]``), or None."""
    if canon.feats is None:
        return None
    return jnp.asarray(np.asarray(canon.feats)[np.asarray(index.order)])


def _metric_grid(canon: metric_lib.Canonical) -> GridIndex:
    """Grid over the canonical GEOMETRY at the derived prune radius: the
    points themselves for l2, unit rows for cosine (both exact L2 grids),
    the 1-D set-size coordinate for jaccard (DESIGN.md S12)."""
    return build_grid(np.asarray(canon.geom), float(canon.eps_geom))


def _metric_self_join(canon: metric_lib.Canonical, *, unicomp: bool,
                      sort_result: bool, bucketed: Optional[bool] = None,
                      index: Optional[GridIndex] = None) -> np.ndarray:
    """Pair-emitting fused join for a canonicalized non-L2 dataset.

    Cosine runs the full L2 machinery (merged sweep, occupancy buckets,
    run loop) on the unit-sphere geometry -- the metric tag keys the
    executable and the sanitize normalization check. Jaccard forces the
    per-cell sweep over the 1-D size grid (merged last-dim reduction is
    meaningless in 1-D) with the bitmap payload riding the feature lanes
    and the kernel refining against the similarity threshold t itself.
    """
    if index is None:
        index = _metric_grid(canon)
    if canon.metric == "jaccard":
        return _self_join_fused(
            index, unicomp=unicomp, sort_result=sort_result,
            bucketed=bucketed, merged=False, metric="jaccard",
            n_feat=canon.n_feat, feats=_metric_feats_sorted(canon, index),
            refine_eps=canon.eps)
    merged = _join_sweep_merged(
        index, unicomp=unicomp, bucketed=bucketed,
        merged=_resolve_merge(index, None))
    return _self_join_fused(
        index, unicomp=unicomp, sort_result=sort_result, bucketed=bucketed,
        merged=merged, metric=canon.metric)


def self_join(
    points,
    eps,
    *,
    unicomp: bool = True,
    index: Optional[GridIndex] = None,
    distance_impl: str = "jnp",
    sort_result: bool = True,
    bucketed: Optional[bool] = None,
    merge_last_dim: Optional[bool] = None,
    metric: str = "l2",
    vocab: Optional[int] = None,
):
    """Single-batch self-join. Returns (pairs (K,2) int32 np.ndarray).

    Two-phase: exact count, then fill with exactly-sized capacity
    ('jnp'/'pallas'); single-pass count -> fill for 'fused', occupancy-
    bucketed by default (``bucketed=False`` forces the single-capacity
    launch; both produce the same pair set) over the merged-range stencil
    (``merge_last_dim=False`` keeps the per-cell 3^n sweep as the parity
    oracle; DESIGN.md S7). For the incremental / overlapped execution the
    paper uses, see ``self_join_batched``.

    ``metric`` (DESIGN.md S12): 'l2' (default, ``eps`` is the radius),
    'cosine' (``points`` are raw embeddings, ``eps`` the minimum cosine
    similarity in [-1, 1)), or 'jaccard' (``points`` are token-id
    iterables or an (N, V) binary matrix, ``eps`` the minimum Jaccard
    similarity in (0, 1]; ``vocab`` optionally fixes the packed
    vocabulary). ``points`` may also be a pre-built ``metric.Canonical``
    (then pass ``eps=None``). Non-L2 metrics canonicalize, build their
    own geometry grid, and always run the fused path; ``index`` /
    ``distance_impl`` apply to 'l2' only.
    """
    metric_lib.check_metric(metric)
    if metric != "l2" or isinstance(points, metric_lib.Canonical):
        canon = _metric_canonical(points, eps, metric, vocab)
        if canon.metric == "l2":
            points, eps = canon.geom, canon.eps
        else:
            return _metric_self_join(
                canon, unicomp=unicomp, sort_result=sort_result,
                bucketed=bucketed)
    index = _resolve_index(points, eps, index)
    if distance_impl == "fused":
        merged = _join_sweep_merged(
            index, unicomp=unicomp, bucketed=bucketed,
            merged=_resolve_merge(index, merge_last_dim))
        return _self_join_fused(
            index, unicomp=unicomp, sort_result=sort_result,
            bucketed=bucketed, merged=merged)
    stats = self_join_count(
        points, eps, unicomp=unicomp, index=index, distance_impl=distance_impl
    )
    capacity = max(stats.total_pairs, 1)
    deltas, is_zero = _offset_tables(index, unicomp)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)
    keys, vals, count = _fill_batch(
        index,
        deltas,
        is_zero,
        jnp.asarray(0, jnp.int32),
        q_size=index.num_points,
        max_per_cell=max_per_cell,
        unicomp=unicomp,
        capacity=capacity,
        distance_impl=distance_impl,
    )
    assert int(count) == stats.total_pairs, (int(count), stats.total_pairs)
    pairs = np.stack([np.asarray(keys), np.asarray(vals)], axis=1)[: int(count)]
    if sort_result:  # the paper sorts the key/value result after the kernel
        pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
    return pairs


def self_join_batched(
    points,
    eps,
    *,
    unicomp: bool = True,
    n_batches: int = 3,
    index: Optional[GridIndex] = None,
    distance_impl: str = "jnp",
    sort_result: bool = True,
    bucketed: Optional[bool] = None,
    merge_last_dim: Optional[bool] = None,
):
    """The paper's batching scheme (SV-A): >= 3 query batches, each batch's
    result copied to the host while the next batch computes (JAX async
    dispatch provides the overlap; on TPU these run on separate streams).

    Memory high-water is O(|D|/n_batches * C_max) intermediates + one batch
    result, instead of the full result set -- this is what lets result sets
    larger than device memory complete (paper Fig. 1 regime).
    """
    index = _resolve_index(points, eps, index)
    if distance_impl == "fused":
        merged = _join_sweep_merged(
            index, unicomp=unicomp, bucketed=bucketed,
            merged=_resolve_merge(index, merge_last_dim))
        return _self_join_fused(
            index, unicomp=unicomp, sort_result=sort_result,
            n_batches=n_batches, bucketed=bucketed, merged=merged)
    npts = index.num_points
    # clamp: more batches than points would schedule empty trailing batches
    # whose rounded-up query slices cover pure padding rows (wasted
    # launches; one compile per distinct empty shape)
    n_batches = max(min(int(n_batches), max(npts, 1)), 1)
    q_size = -(-npts // n_batches)  # ceil
    deltas, is_zero = _offset_tables(index, unicomp)
    max_per_cell = _round_up(max(int(index.max_per_cell), 1), 8)

    # Phase 1: per-batch exact counts (cheap; no result materialization).
    counts = []
    for b in range(n_batches):
        t, _, _ = _count_batch(
            index,
            deltas,
            is_zero,
            jnp.asarray(b * q_size, jnp.int32),
            q_size=q_size,
            max_per_cell=max_per_cell,
            unicomp=unicomp,
            distance_impl=distance_impl,
        )
        counts.append(t)
    counts = [int(t) for t in counts]  # sync point
    capacity = max(max(counts), 1)     # one fill compilation reused per batch

    # Phase 2: fill batches; async dispatch overlaps batch b+1 compute with
    # batch b's D2H transfer (np.asarray blocks only on b's buffers).
    device_results = []
    for b in range(n_batches):
        keys, vals, cnt = _fill_batch(
            index,
            deltas,
            is_zero,
            jnp.asarray(b * q_size, jnp.int32),
            q_size=q_size,
            max_per_cell=max_per_cell,
            unicomp=unicomp,
            capacity=capacity,
            distance_impl=distance_impl,
        )
        device_results.append((keys, vals, cnt))

    out = np.empty((sum(counts), 2), dtype=np.int32)
    pos = 0
    for b, (keys, vals, cnt) in enumerate(device_results):
        k = counts[b]
        assert int(cnt) == k
        out[pos : pos + k, 0] = np.asarray(keys)[:k]
        out[pos : pos + k, 1] = np.asarray(vals)[:k]
        pos += k
    if sort_result:
        out = out[np.lexsort((out[:, 1], out[:, 0]))]
    return out


def range_query(
    queries,
    points,
    eps,
    *,
    index: Optional[GridIndex] = None,
    return_pairs: bool = False,
    merge_last_dim: Optional[bool] = None,
):
    """Epsilon-range counts for EXTERNAL query points against an indexed set.

    Thin compatibility wrapper over ``core.query_join`` (DESIGN.md S5),
    which this function's original implementation grew into. Two bugs of
    that implementation are fixed by the delegation:

      * it defined its ``@jax.jit`` closure per CALL, so every serve
        request paid a fresh trace + compile; the query-join path uses
        module-level jitted functions cached per static bucket shape, and
      * it clamped query cell coordinates with ``clip(qcoords, 1,
        dims - 2)``, whose bounds invert (hi < lo) on grids with < 3 cells
        in a dimension, silently redirecting every query to cell 0; the
        query-join descriptors mask out-of-grid probes exactly in
        coordinate space instead (``grid.external_window_descriptors``).

    Returns (Q,) int32 neighbor counts -- or ``(counts, pairs)`` with
    ``return_pairs`` -- for the DBSCAN-style use the paper cites (SII).
    Services answering sustained traffic should hold a
    ``query_join.prepare(index)`` / ``launch.serve.JoinService`` instead.
    """
    from repro.core.query_join import epsilon_join

    index = _resolve_index(points, eps, index)
    res = epsilon_join(queries, None, index=index, return_pairs=return_pairs,
                       merge_last_dim=merge_last_dim)
    if return_pairs:
        return res.counts, res.pairs
    return res.counts


# Module-level jits for per_point_neighbor_counts: these used to be defined
# inside the function body (the PR-2 per-call @jax.jit retrace pattern --
# every call re-traced from an empty cache; analysis/lint.py's per-call-jit
# rule now bans the shape). ``cap`` is the only closed-over value and rides
# as a static argname, so the executable cache is shared across calls.
@partial(jax.jit, static_argnames=("cap",))
def _neighbor_counts_merged(index, dtab, *, cap: int):
    from repro.core.grid import range_window_descriptors_at

    npts = index.num_points
    q_pos = jnp.arange(npts, dtype=jnp.int32)
    ws, wc, _ = range_window_descriptors_at(
        index, dtab[0], dtab[1], dtab[2], q_pos)
    q = index.points_sorted
    slots = jnp.arange(cap, dtype=jnp.int32)

    def body(deg, xs):
        ws_o, wc_o = xs
        cand_pos = jnp.minimum(
            ws_o[:, None] + slots[None, :], npts - 1)
        valid = slots[None, :] < wc_o[:, None]
        cand = index.points_sorted[cand_pos]
        hits = _distance_hits_jnp(q, cand, valid, index.eps)
        hits = hits & (cand_pos != q_pos[:, None])
        deg = deg.at[index.order].add(
            hits.sum(axis=1).astype(jnp.int32))
        return deg, None

    deg0 = jnp.zeros((npts,), jnp.int32)
    deg, _ = jax.lax.scan(body, deg0, (ws, wc))
    return deg


@partial(jax.jit, static_argnames=("cap",))
def _neighbor_counts_dense(index, deltas, is_zero, *, cap: int):
    def body(deg, xs):
        delta, _ = xs
        nbr_cells = _neighbor_ranks_for_delta(index, delta)
        q, cand, cand_pos, valid, q_pos, _ = _gather_batch(
            index, nbr_cells, jnp.asarray(0, jnp.int32),
            index.num_points, cap,
        )
        hits = _distance_hits_jnp(q, cand, valid, index.eps)
        hits = hits & (cand_pos != q_pos[:, None])
        deg = deg.at[index.order[q_pos]].add(hits.sum(axis=1).astype(jnp.int32))
        return deg, None

    deg0 = jnp.zeros((index.num_points,), jnp.int32)
    deg, _ = jax.lax.scan(body, deg0, (deltas, is_zero))
    return deg


def per_point_neighbor_counts(
    points,
    eps,
    *,
    index: Optional[GridIndex] = None,
    merge_last_dim: Optional[bool] = None,
) -> np.ndarray:
    """|epsilon-neighborhood| of each point (excl. self) -- the range-query
    building block the paper cites for DBSCAN/OPTICS. Sweeps the MERGED
    3^(n-1) range stencil by default (DESIGN.md S7) with a scatter-add on
    the query id; ``merge_last_dim=False`` keeps the per-cell 3^n sweep as
    the parity oracle."""
    index = _resolve_index(points, eps, index)
    merged = _resolve_merge(index, merge_last_dim)
    if merged:
        from repro.core.grid import global_window_cap
        dtab, _ = _merged_offset_tables(index, unicomp=False)
        cap = global_window_cap(index, merged=True)
        return np.asarray(_neighbor_counts_merged(index, dtab, cap=cap))
    deltas, is_zero = _offset_tables(index, unicomp=False)
    cap = _round_up(max(int(index.max_per_cell), 1), 8)
    return np.asarray(_neighbor_counts_dense(index, deltas, is_zero, cap=cap))
