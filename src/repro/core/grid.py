"""The epsilon-grid index of paper SIV.

The paper's index has four components (Fig. 2a):
    A   point-id lookup array, |A| = |D|, grouped by grid cell
    G   per non-empty cell, the [min, max] range into A
    B   sorted linearized ids of the non-empty cells (binary-searched)
    M_j per-dimension list of non-empty cell coordinates (range masking)

Only non-empty cells are stored, so space is O(|D|) independent of the
(hyper)volume (paper SIV-D). We provide two builders:

  * ``build_grid`` -- the PRIMARY builder (DESIGN.md S10): geometry and the
    static key dtype fixed on the host, then key computation + stable sort
    + segment detection inside one cached jitted executable
    (``build_grid_with_geometry``), shapes padded to |D| (the number of
    non-empty cells is at most |D|). Also usable inside shard_map / pjit
    where host round-trips are impossible (core/distributed.py).
  * ``build_grid_host`` -- exact, numpy, on the host; the reference the
    device build is bit-identical to. Mirrors the paper's CPU fallback
    ("inserting points into the grid requires far less work than
    constructing the R-tree", SVI-B).

Both produce the same ``GridIndex`` pytree -- field-for-field equal on the
same input -- and the joins in ``selfjoin.py`` consume either.

TPU adaptation note (DESIGN.md S2): the per-thread binary search of B in the
paper's kernel is replaced by vectorized ``searchsorted`` over all cells per
stencil offset at *search* time; the per-dimension masks M_j are kept for the
host path and subsumed by the searchsorted miss (-1) on the device path.
"""
from __future__ import annotations

import collections
import dataclasses
import weakref
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel linear key for padding slots in B. Must compare greater than any
# real key so searchsorted never matches it.
PAD_KEY = jnp.iinfo(jnp.int64).max


def key_dtype_for(dims) -> np.dtype:
    """Narrowest safe cell-key dtype for a grid of ``dims`` cells.

    int32 when ``prod(dims) < 2^31`` (every linear key, and every probe
    key a host-built grid's interior geometry can form, fits), else
    int64. The int32 fast path halves searchsorted bandwidth AND removes
    the ``jax_enable_x64`` requirement for small grids; exact python-int
    arithmetic so a 6-D grid just past the boundary cannot wrap into the
    int32 route (regression-tested in tests/test_grid_keys.py).
    """
    volume = 1
    for d in np.asarray(dims).ravel():
        volume *= int(d)
    return np.dtype(np.int32) if volume < 2**31 else np.dtype(np.int64)


def pad_key_for(dtype) -> int:
    """The padding/miss sentinel for a key array of ``dtype``: the dtype's
    max. Real keys are < prod(dims) <= sentinel - 1 by ``key_dtype_for``'s
    strict bound, so a sentinel probe can only land on padding slots --
    whose ``cell_count`` is 0 -- never on a real cell."""
    return int(np.iinfo(np.dtype(dtype)).max)


def sentinel_margin(dims, key_dtype=None) -> int:
    """``pad_key_for`` sentinel minus the largest possible real key, in
    exact python-int arithmetic (no numpy wrap-around on 6-D volumes).

    Positive margin proves the sentinel can never alias a real cell key;
    0 means the out-of-grid sentinel cell of a padded build (key ==
    prod(dims)) coincides with the padding sentinel. The contract prover
    (analysis/contracts.py C4) checks this for every index geometry."""
    if key_dtype is None:
        key_dtype = key_dtype_for(dims)
    volume = 1
    for d in np.asarray(dims).ravel():
        volume *= int(d)
    return pad_key_for(key_dtype) - (volume - 1)


def device_key_dtype(dims, padded: bool = False) -> np.dtype:
    """Static key dtype for a DEVICE build of known geometry.

    ``key_dtype_for`` widened to int64 when a padded build would need the
    out-of-set sentinel cell (key == prod(dims)) and that key would not
    clear the int32 padding sentinel: the sentinel cell key must both fit
    the dtype and stay strictly below ``pad_key_for`` (C9,
    analysis/contracts.py ``check_device_sentinel``). Exact python-int
    arithmetic throughout.
    """
    kd = key_dtype_for(dims)
    if padded and kd == np.int32 and sentinel_margin(dims, kd) < 2:
        kd = np.dtype(np.int64)
    return kd


def _pad_probe(arr: jax.Array, mask: jax.Array, key_dtype) -> jax.Array:
    """``arr`` cast to the index's key dtype with ``~mask`` lanes set to
    the dtype's miss sentinel (the dtype-aware form of
    ``jnp.where(mask, keys, PAD_KEY)``, which overflows when the keys are
    int32)."""
    kd = jnp.dtype(key_dtype)
    pad = jnp.asarray(pad_key_for(kd), kd)
    return jnp.where(mask, arr.astype(kd), pad)


def _require_int64_keys() -> None:
    """Refuse to build a grid whose keys would silently truncate to int32.

    With ``jax_enable_x64`` off, ``jnp.asarray`` of an int64 host array and
    every ``linearize`` result downcast to int32 without warning; on >=4-D
    grids the linear key space exceeds 2^31 and distinct cells ALIAS to the
    same key (and ``PAD_KEY`` wraps negative, so padding slots match real
    searches). Importing ``repro`` enables x64 globally; this guard catches
    grid builds that genuinely need 64-bit keys (``key_dtype_for``) from
    processes that disabled or bypassed that import.
    """
    if not jax.config.jax_enable_x64:
        raise RuntimeError(
            "epsilon-grid cell keys require int64, but jax_enable_x64 is "
            "off: linearized keys (grid.linearize) and PAD_KEY would "
            "silently truncate to int32 and alias distinct cells on "
            "high-dimensional grids. Enable it with "
            "jax.config.update('jax_enable_x64', True) -- importing the "
            "`repro` package does this for you (unless REPRO_NO_X64 is "
            "set, in which case only int32-keyed grids -- prod(dims) < "
            "2^31 -- can be built).")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GridIndex:
    """The paper's index (A/G/B + geometry), as a JAX pytree.

    Arrays are padded to static shapes: ``cell_keys``/``cell_start``/
    ``cell_count`` have length ``num_points`` with ``num_cells`` valid
    entries; padding keys are PAD_KEY.
    """

    # --- geometry (paper SIV-B) ---
    grid_min: jax.Array      # (n,) g_j^min = min x_j - eps
    eps: jax.Array           # () scalar
    dims: jax.Array          # (n,) |g_j| cells per dimension, int64
    # --- components (paper SIV-C) ---
    order: jax.Array         # (|D|,) int32 == A : point ids grouped by cell
    points_sorted: jax.Array # (|D|, n)  D[A] : coordinates in A-order
    cell_keys: jax.Array     # (|D|,) int64 == B : sorted linear ids (padded)
    cell_start: jax.Array    # (|D|,) int32 == G.min : offset into A
    cell_count: jax.Array    # (|D|,) int32 == G.max-G.min+1
    point_cell_rank: jax.Array  # (|D|,) int32: rank in B of each sorted point's cell
    num_cells: jax.Array     # () int32 |G| = |B|
    max_per_cell: jax.Array  # () int32 (exact on host path; reported on jit path)

    @property
    def n_dims(self) -> int:
        return self.points_sorted.shape[1]

    @property
    def num_points(self) -> int:
        return self.points_sorted.shape[0]

    @property
    def key_dtype(self):
        """Cell-key dtype: int32 on small grids (``key_dtype_for``),
        int64 otherwise. Probe keys must cast through ``_pad_probe`` so
        their miss sentinel matches this dtype."""
        return self.cell_keys.dtype


def cell_coords(points: jax.Array, grid_min: jax.Array, eps) -> jax.Array:
    """n-dimensional integer cell coordinates of each point (int64).

    The grid range is appended by eps on both sides (paper SIV-B), so every
    point's coordinate is >= 1 and adjacent-cell lookups never go negative.
    """
    return jnp.floor((points - grid_min) / eps).astype(jnp.int64)


def linearize(coords: jax.Array, dims: jax.Array) -> jax.Array:
    """Row-major linear cell id (paper Fig. 2b's lexicographic cell id).

    int64: for 6-D data the id space is prod |g_j| which overflows int32.
    """
    coords = coords.astype(jnp.int64)
    dims = dims.astype(jnp.int64)
    n = coords.shape[-1]
    key = coords[..., 0]
    for j in range(1, n):
        key = key * dims[j] + coords[..., j]
    return key


def row_major_strides(dims: jax.Array) -> jax.Array:
    """s_j = prod_{k>j} dims_k, the ``linearize`` convention -- so
    key(c + o) = key(c) + o @ s for any offset vector o.

    THE stride formula: the offset tables (selfjoin), the distributed slab
    join, and the host-side occupancy planner (``cell_window_caps``) must
    all agree with ``linearize`` bit-for-bit, or window capacities
    undercount and the kernel silently truncates candidates. jnp, usable
    under jit; host code converts with ``np.asarray``.
    """
    dims = jnp.asarray(dims).astype(jnp.int64)
    rev = jnp.cumprod(dims[::-1])
    return jnp.concatenate([rev[-2::-1], jnp.ones((1,), dims.dtype)])


def grid_geometry(points: jax.Array, eps) -> tuple[jax.Array, jax.Array]:
    """grid_min (g_j^min) and dims (|g_j|) per paper SIV-B.

    g_j^min = min_j - eps ; g_j^max = max_j + eps ; |g_j| = ceil(range/eps).
    """
    eps = jnp.asarray(eps, points.dtype)
    gmin = points.min(axis=0) - eps
    gmax = points.max(axis=0) + eps
    dims = jnp.ceil((gmax - gmin) / eps).astype(jnp.int64) + 1
    return gmin, dims


# ---------------------------------------------------------------------------
# Host (exact) build -- mirrors the paper's host-side index construction.
# ---------------------------------------------------------------------------

def host_grid_geometry(points: np.ndarray,
                       eps) -> tuple[np.ndarray, np.ndarray]:
    """Exact numpy grid geometry (paper SIV-B): THE one copy shared by
    ``build_grid_host`` and the device-build dispatcher (``build_grid``),
    so both builders derive bit-identical gmin/dims from the same IEEE
    operations and the resulting indexes can be compared field-for-field."""
    points = np.asarray(points)
    gmin = points.min(axis=0) - eps
    gmax = points.max(axis=0) + eps
    dims = (np.ceil((gmax - gmin) / eps)).astype(np.int64) + 1
    return gmin, dims


def build_grid_host(points: np.ndarray, eps: float) -> GridIndex:
    """Exact epsilon-grid build in numpy. Returns a device GridIndex.

    Keys are built in the narrowest safe dtype (``key_dtype_for``): int32
    when prod(dims) < 2^31 -- the natural eps-margin geometry keeps every
    point's coords in [1, dims-2], so every probe key the stencil can form
    stays inside [0, prod(dims)) and int32 is exact WITHOUT
    ``jax_enable_x64``. Larger grids keep int64 keys and the x64 guard.
    """
    points = np.asarray(points)
    npts, n = points.shape
    gmin, dims = host_grid_geometry(points, eps)
    key_dtype = key_dtype_for(dims)
    if key_dtype == np.int64:
        _require_int64_keys()

    coords = np.floor((points - gmin) / eps).astype(np.int64)
    keys = coords[:, 0]
    for j in range(1, n):
        keys = keys * dims[j] + coords[:, j]
    keys = keys.astype(key_dtype)

    order = np.argsort(keys, kind="stable").astype(np.int32)
    keys_sorted = keys[order]

    uniq, start, count = np.unique(keys_sorted, return_index=True, return_counts=True)
    ncells = uniq.shape[0]

    cell_keys = np.full(npts, np.iinfo(key_dtype).max, dtype=key_dtype)
    cell_keys[:ncells] = uniq
    cell_start = np.zeros(npts, dtype=np.int32)
    cell_start[:ncells] = start
    cell_count = np.zeros(npts, dtype=np.int32)
    cell_count[:ncells] = count

    rank = np.searchsorted(uniq, keys_sorted).astype(np.int32)

    return GridIndex(
        grid_min=jnp.asarray(gmin),
        eps=jnp.asarray(eps, dtype=points.dtype),
        dims=jnp.asarray(dims),
        order=jnp.asarray(order),
        points_sorted=jnp.asarray(points[order]),
        cell_keys=jnp.asarray(cell_keys),
        cell_start=jnp.asarray(cell_start),
        cell_count=jnp.asarray(cell_count),
        point_cell_rank=jnp.asarray(rank),
        num_cells=jnp.asarray(ncells, dtype=jnp.int32),
        max_per_cell=jnp.asarray(int(count.max()) if ncells else 0, dtype=jnp.int32),
    )


def masks_host(index: GridIndex) -> list[np.ndarray]:
    """The paper's per-dimension masking arrays M_j (SIV-C).

    M_j = sorted unique non-empty cell coordinates in dimension j. Used by the
    host reference search; the device path folds this pruning into the
    neighbor-table searchsorted (a miss there prunes the same cells and more).
    """
    keys = np.asarray(index.cell_keys[: int(index.num_cells)])
    dims = np.asarray(index.dims)
    n = dims.shape[0]
    coords = np.empty((keys.shape[0], n), dtype=np.int64)
    rem = keys.copy()
    for j in range(n - 1, 0, -1):
        coords[:, j] = rem % dims[j]
        rem //= dims[j]
    coords[:, 0] = rem
    return [np.unique(coords[:, j]) for j in range(n)]


# ---------------------------------------------------------------------------
# Device build -- key computation, stable sort and segment detection on the
# accelerator (the paper builds its index on the GPU; DESIGN.md S10).
# ---------------------------------------------------------------------------

def build_grid(points, eps, *, device: bool = True) -> GridIndex:
    """Primary epsilon-grid build: host geometry, DEVICE construction.

    Geometry (gmin/dims) is derived on the host with the exact numpy
    arithmetic of ``build_grid_host`` (``host_grid_geometry``), which also
    fixes the static key dtype; the O(|D| log |D|) work -- linearized key
    computation, stable sort, segment detection -- runs inside ONE cached
    jitted executable (``build_grid_with_geometry``). The result is
    bit-identical to ``build_grid_host`` field-for-field: same geometry
    ops, same key dtype and dtype-max padding, and stable sorts of equal
    key arrays produce equal permutations (property-tested in
    tests/test_device_build.py). ``device=False`` dispatches to the numpy
    builder unchanged.
    """
    pts_np = np.asarray(points)
    if not device:
        return build_grid_host(pts_np, float(eps))
    gmin, dims = host_grid_geometry(pts_np, eps)
    key_dtype = key_dtype_for(dims)
    if key_dtype == np.int64:
        _require_int64_keys()    # fail before tracing, same error as host
    return build_grid_with_geometry_jit(
        jnp.asarray(pts_np), eps, jnp.asarray(gmin), jnp.asarray(dims),
        key_dtype=key_dtype)


def build_grid_with_geometry(
    points: jax.Array, eps, gmin: jax.Array, dims: jax.Array,
    valid: Optional[jax.Array] = None, *, key_dtype=None,
) -> GridIndex:
    """Jittable grid build against externally supplied geometry.

    The one device builder: ``build_grid`` (primary path) and the
    distributed slab join (core/distributed.py) both dispatch here -- the
    latter builds every slab's local grid against the *global* gmin/dims
    so cell coordinates (and the UNICOMP cell-pair ownership rule) are
    consistent across devices (DESIGN.md S3).

    ``valid`` marks real points; invalid (padding) points are assigned the
    out-of-set sentinel cell key prod(dims), which sorts after every real
    cell and can never be produced by a real cell + stencil-offset lookup,
    so padding points are unreachable as candidates. ``max_per_cell``
    excludes the sentinel cell.

    ``key_dtype`` must be STATIC (dims are traced under jit, so the dtype
    cannot be derived here): callers with concrete geometry pass
    ``key_dtype_for(dims)`` (or ``device_key_dtype`` when ``valid`` is
    used) to ride the int32 fast path; ``None`` keeps the legacy int64
    route, which requires x64. Padding slots carry the dtype-max sentinel
    (``pad_key_for``), matching the host build.
    """
    if key_dtype is None:
        key_dtype = np.dtype(np.int64)
    key_dtype = np.dtype(key_dtype)
    if key_dtype == np.int64:
        _require_int64_keys()
    npts, _ = points.shape
    keys = linearize(cell_coords(points, gmin, eps), dims).astype(key_dtype)
    # out-of-set sentinel cell key == prod(dims): exact in the key dtype
    # (int32 route has volume < 2^31; device_key_dtype widens when the
    # sentinel would collide with the padding sentinel). Explicit dtype=
    # because jnp.prod promotes int32 to the default int otherwise.
    sentinel = jnp.prod(dims.astype(key_dtype), dtype=key_dtype)
    if valid is not None:
        keys = jnp.where(valid, keys, sentinel)

    order = jnp.argsort(keys, stable=True).astype(jnp.int32)
    keys_sorted = keys[order]

    # Segment boundaries of the sorted key array -> non-empty cells.
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), keys_sorted[1:] != keys_sorted[:-1]]
    )
    ncells = is_start.sum().astype(jnp.int32)
    # Rank of each sorted point's cell in B (0-based).
    rank = (jnp.cumsum(is_start) - 1).astype(jnp.int32)

    # Scatter segment starts into padded arrays. Valid slots: [0, ncells).
    seg_idx = jnp.where(is_start, rank, npts)  # pad writes -> dropped
    positions = jnp.arange(npts, dtype=jnp.int32)
    cell_start = jnp.zeros(npts, jnp.int32).at[seg_idx].set(positions, mode="drop")
    cell_keys = jnp.full(npts, pad_key_for(key_dtype), key_dtype)
    cell_keys = cell_keys.at[seg_idx].set(keys_sorted, mode="drop")
    # count[h] = start[h+1] - start[h]; for the last valid cell use npts.
    nxt = jnp.concatenate([cell_start[1:], jnp.zeros((1,), jnp.int32)])
    idx = jnp.arange(npts, dtype=jnp.int32)
    nxt = jnp.where(idx == ncells - 1, npts, nxt)
    cell_count = jnp.where(idx < ncells, nxt - cell_start, 0).astype(jnp.int32)

    real_count = jnp.where(cell_keys < sentinel, cell_count, 0)
    return GridIndex(
        grid_min=gmin,
        eps=jnp.asarray(eps, points.dtype),
        dims=dims,
        order=order,
        points_sorted=points[order],
        cell_keys=cell_keys,
        cell_start=cell_start,
        cell_count=cell_count,
        point_cell_rank=rank,
        num_cells=ncells,
        max_per_cell=real_count.max().astype(jnp.int32),
    )


# THE jitted device builder: one executable per (shape, key dtype), shared
# by build_grid and the distributed slab join (core/distributed.py).
build_grid_with_geometry_jit = jax.jit(
    build_grid_with_geometry, static_argnames=("key_dtype",))


def window_descriptors(
    index: GridIndex,
    deltas: jax.Array,
    q_start: jax.Array | int = 0,
    q_size: Optional[int] = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-(offset, query) candidate windows in kernel-friendly layout.

    For the query batch at sorted positions [q_start, q_start + q_size) and
    every stencil offset delta (linearized), returns

        win_start (n_off, q_size) int32 -- offset into ``points_sorted`` of
            the adjacent cell's candidate window, and
        win_count (n_off, q_size) int32 -- its length (0 when the adjacent
            cell is empty, absent from B, or the query slot is padding).

    This is pure index arithmetic: one batched ``searchsorted`` over B for
    the whole (offset x query) plane, no point-coordinate gather. The fused
    kernel (kernels/fused_join.py) prefetches these two arrays as scalars
    (pltpu.PrefetchScalarGridSpec) and performs the HBM->VMEM candidate
    gather itself, so the (B, C, n) gathered intermediate of the unfused
    sweep never exists (DESIGN.md S4).

    A window is always a contiguous run of ``points_sorted`` rows because a
    grid cell's points are contiguous in A-order (paper Fig. 2a), and
    ``win_start + win_count <= |D|`` always holds, so a kernel may read a
    fixed C-padded window anywhere as long as ``points_sorted`` carries C
    rows of tail padding.
    """
    npts = index.num_points
    if q_size is None:
        q_size = npts
    q_pos = jnp.asarray(q_start, jnp.int32) + jnp.arange(q_size, dtype=jnp.int32)
    return window_descriptors_at(index, deltas, q_pos, q_pos < npts)


def window_descriptors_at(
    index: GridIndex,
    deltas: jax.Array,
    q_pos: jax.Array,
    q_ok: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Candidate windows for EXPLICIT sorted positions (``q_pos``, (Q,)).

    The occupancy-bucketed launch loop (DESIGN.md S6) partitions query rows
    by candidate-capacity class, so a bucket's query rows are an ascending
    but non-contiguous subset of sorted order; this variant resolves each
    row's own cell from its position rather than a contiguous batch origin.
    ``q_ok`` masks padding slots (window count forced to 0); candidate
    windows themselves stay contiguous runs of ``points_sorted`` regardless
    of the query partition.
    """
    npts = index.num_points
    q_pos = q_pos.astype(jnp.int32)
    if q_ok is None:
        q_ok = q_pos < npts
    q_pos_c = jnp.minimum(q_pos, npts - 1)
    rank = index.point_cell_rank[q_pos_c]            # (Q,) rank of own cell
    own_key = index.cell_keys[rank]                  # (Q,) int64
    qk = own_key[None, :] + deltas[:, None]          # (n_off, Q) int64
    nbr = neighbor_rank(index, qk)                   # (n_off, Q), -1 = miss
    live = (nbr >= 0) & q_ok[None, :]
    nbr_c = jnp.maximum(nbr, 0)
    win_start = jnp.where(live, index.cell_start[nbr_c], 0).astype(jnp.int32)
    win_count = jnp.where(live, index.cell_count[nbr_c], 0).astype(jnp.int32)
    return win_start, win_count


def _rank_to_point(index: GridIndex, rank: jax.Array) -> jax.Array:
    """Sorted-point position of a cell RANK's window start; ranks >=
    ``num_cells`` map to ``num_points`` (the exclusive end of real points).

    The bridge between key-rank space and point space that makes merged
    range windows work: consecutive ranks in B own consecutive runs of
    ``points_sorted``, so the span of ranks [lo, hi) is exactly the point
    span [_rank_to_point(lo), _rank_to_point(hi)).
    """
    npts = index.num_points
    rank_c = jnp.minimum(rank, npts - 1)
    return jnp.where(rank < index.num_cells,
                     index.cell_start[rank_c], npts).astype(jnp.int32)


def range_window_descriptors_at(
    index: GridIndex,
    deltas: jax.Array,
    lo_off: jax.Array,
    hi_off: jax.Array,
    q_pos: jax.Array,
    q_ok: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """MERGED candidate windows for explicit sorted positions (DESIGN.md S7).

    For each reduced stencil offset (``deltas`` = linearized first-(n-1)-
    coordinate offsets, last coordinate 0) the three cells differing only
    in the last coordinate occupy adjacent key ranks, so their windows are
    ONE contiguous span of ``points_sorted``. Per (offset, query) this
    resolves the span [base + lo_off, base + hi_off] in key space with one
    searchsorted pair (left on the low key, right on the high key) and
    converts ranks to point positions via ``_rank_to_point``.

    The last-dimension span is clamped to the grid row: a query whose cell
    sits at last coordinate 0 (or dims-1) must not let the range probe
    wrap into the previous (next) row of the grid -- keys are dense across
    row boundaries, so an unclamped [base-1, base+1] would silently pull a
    wrapped cell's points into the window. Natural grid geometry keeps
    every point's coordinates in [1, dims-2] (paper SIV-B eps margins) so
    the clamp is a no-op there, but externally supplied geometry
    (``build_grid_with_geometry``) can place points on the row edge; the
    fused kernel's last-dimension boundary mask (kernels/fused_join.py)
    backstops the same hazard candidate-by-candidate.

    Returns (win_start, win_count, win_cells), each (n_off, Q) int32;
    ``win_cells`` is the number of non-empty cells inside each merged
    window -- the per-cell work counter the unmerged sweep reported as its
    live-probe count, preserved so merged and unmerged JoinStats match
    counter-for-counter.
    """
    npts = index.num_points
    q_pos = q_pos.astype(jnp.int32)
    if q_ok is None:
        q_ok = q_pos < npts
    q_pos_c = jnp.minimum(q_pos, npts - 1)
    rank = index.point_cell_rank[q_pos_c]            # (Q,) rank of own cell
    own_key = index.cell_keys[rank]                  # (Q,) int64
    dim_last = index.dims.astype(jnp.int64)[-1]
    q_last = own_key % dim_last                      # (Q,) last-dim coord
    base = own_key[None, :] + deltas[:, None]        # (n_off, Q) int64
    lo = jnp.maximum(lo_off[:, None], -q_last[None, :])
    hi = jnp.minimum(hi_off[:, None], dim_last - 1 - q_last[None, :])
    lo_rank = jnp.searchsorted(index.cell_keys, base + lo,
                               side="left").astype(jnp.int32)
    hi_rank = jnp.searchsorted(index.cell_keys, base + hi,
                               side="right").astype(jnp.int32)
    live = (hi_rank > lo_rank) & q_ok[None, :]
    start = _rank_to_point(index, lo_rank)
    end = _rank_to_point(index, hi_rank)
    win_start = jnp.where(live, start, 0).astype(jnp.int32)
    win_count = jnp.where(live, end - start, 0).astype(jnp.int32)
    win_cells = jnp.where(live, hi_rank - lo_rank, 0).astype(jnp.int32)
    return win_start, win_count, win_cells


def range_window_descriptors(
    index: GridIndex,
    deltas: jax.Array,
    lo_off: jax.Array,
    hi_off: jax.Array,
    q_start: jax.Array | int = 0,
    q_size: Optional[int] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Merged-range windows for a contiguous query batch (see
    ``range_window_descriptors_at``)."""
    npts = index.num_points
    if q_size is None:
        q_size = npts
    q_pos = (jnp.asarray(q_start, jnp.int32)
             + jnp.arange(q_size, dtype=jnp.int32))
    return range_window_descriptors_at(
        index, deltas, lo_off, hi_off, q_pos, q_pos < npts)


def external_range_descriptors(
    index: GridIndex,
    offsets: jax.Array,
    lo_off: jax.Array,
    hi_off: jax.Array,
    queries: jax.Array,
    q_limit: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Merged-range windows for EXTERNAL query points (DESIGN.md S7).

    The merged analogue of ``external_window_descriptors``: adjacency on
    the first n-1 coordinates is resolved in coordinate space with exact
    bounds masking (no key aliasing on tiny grids), and the last dimension
    becomes a per-query key-span [q_last + lo_off, q_last + hi_off]
    clamped to [0, dims-1] -- which also handles queries up to one cell
    OUTSIDE the volume in the last dimension (q_last = -1 probes row 0
    only; q_last = dims probes row dims-1 only; farther out the clamped
    span inverts and the probe is dead, the exact answer).

    Returns (win_start, win_count, win_cells), each (n_off, Q) int32.
    """
    qcoords = cell_coords(queries, index.grid_min, index.eps)   # (Q, n)
    dims = index.dims.astype(jnp.int64)
    n = qcoords.shape[1]
    row = qcoords[None, :, :-1] + offsets[:, None, :-1]   # (n_off, Q, n-1)
    row_ok = jnp.all((row >= 0) & (row < dims[:-1]), axis=-1) if n > 1 \
        else jnp.ones(row.shape[:2], bool)
    q_last = qcoords[:, -1]                               # (Q,) int64
    lo_last = jnp.maximum(q_last[None, :] + lo_off[:, None], 0)
    hi_last = jnp.minimum(q_last[None, :] + hi_off[:, None], dims[-1] - 1)
    live = row_ok & (lo_last <= hi_last)
    row_c = jnp.clip(row, 0, dims[:-1] - 1)               # safe linearize
    # append an explicit zero last coordinate: row_c is width n-1, which
    # is 0 for 1-D data, so zeros_like(row_c[..., :1]) would stay empty
    zero_last = jnp.zeros(row_c.shape[:-1] + (1,), row_c.dtype)
    base = linearize(jnp.concatenate([row_c, zero_last], axis=-1),
                     index.dims)
    kd = index.cell_keys.dtype
    # dead probes get an inverted sentinel span (lo > hi) in the INDEX
    # key dtype; `live` already masks them, the sentinel just keeps the
    # searchsorted inputs in range for int32-keyed grids
    lo_key = _pad_probe(base + lo_last, live, kd)
    hi_key = jnp.where(live, (base + hi_last).astype(kd),
                       jnp.asarray(pad_key_for(kd) - 1, kd))
    lo_rank = jnp.searchsorted(index.cell_keys, lo_key,
                               side="left").astype(jnp.int32)
    hi_rank = jnp.searchsorted(index.cell_keys, hi_key,
                               side="right").astype(jnp.int32)
    if q_limit is not None:
        q_ok = jnp.arange(queries.shape[0], dtype=jnp.int32) < q_limit
        live = live & q_ok[None, :]
    live = live & (hi_rank > lo_rank)
    start = _rank_to_point(index, lo_rank)
    end = _rank_to_point(index, hi_rank)
    win_start = jnp.where(live, start, 0).astype(jnp.int32)
    win_count = jnp.where(live, end - start, 0).astype(jnp.int32)
    win_cells = jnp.where(live, hi_rank - lo_rank, 0).astype(jnp.int32)
    return win_start, win_count, win_cells


def point_last_coords(index: GridIndex) -> jax.Array:
    """Last-dimension cell coordinate of every sorted point, int32.

    Derived EXACTLY from the int64 cell keys (key mod dims[-1]), never
    from float coordinates -- the fused kernel's merged boundary mask
    compares these as (exactly representable) floats, so a TPU f32
    downcast can never disagree with the build-time cell assignment.
    """
    keys = index.cell_keys[index.point_cell_rank]
    return (keys % index.dims.astype(jnp.int64)[-1]).astype(jnp.int32)


def external_window_descriptors(
    index: GridIndex,
    offsets: jax.Array,
    queries: jax.Array,
    q_limit: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Candidate windows for EXTERNAL query points (core/query_join.py).

    ``window_descriptors`` derives each query's cell from its position in
    ``points_sorted``; here the cell comes from the query's own coordinates
    under the dataset's grid geometry, so ``queries`` may be ANY point set --
    inside the indexed volume, outside it, or duplicated.

    Adjacency is resolved in COORDINATE space, not linearized-key space:
    ``target = cell_coords(q) + o`` per stencil offset ``o`` (the (n_off, n)
    int64 offset vectors, not their linearized deltas), masked where any
    dimension leaves [0, dims). This supersedes the historical
    ``clip(qcoords, 1, dims - 2)`` clamp, which inverted (hi < lo) on grids
    with < 3 cells in a dimension and silently redirected every query to
    cell 0; exact bounds masking has no such degenerate case, and it also
    prevents linearized keys of out-of-range coordinates from aliasing into
    other real cells (a double-count hazard the key-space probe has when a
    dimension has < 3 cells).

    A query farther than eps outside the volume has out-of-range coords in
    some dimension for every offset -> all probes masked -> zero candidates,
    which is the exact answer. A query within eps of the volume has coords
    in [0, dims), and its true neighbors' cells are covered by the masked
    stencil (real points occupy the interior band by construction).

    Returns (win_start, win_count), each (n_off, Q) int32, count 0 for
    masked probes, absent cells, and query rows >= ``q_limit`` (tile
    padding).
    """
    qcoords = cell_coords(queries, index.grid_min, index.eps)   # (Q, n)
    dims = index.dims.astype(jnp.int64)
    target = qcoords[None, :, :] + offsets[:, None, :]          # (n_off, Q, n)
    in_grid = jnp.all((target >= 0) & (target < dims), axis=-1)
    keys = _pad_probe(linearize(target, index.dims), in_grid,
                      index.cell_keys.dtype)
    nbr = neighbor_rank(index, keys)                            # (n_off, Q)
    live = nbr >= 0
    if q_limit is not None:
        q_ok = jnp.arange(queries.shape[0], dtype=jnp.int32) < q_limit
        live = live & q_ok[None, :]
    nbr_c = jnp.maximum(nbr, 0)
    win_start = jnp.where(live, index.cell_start[nbr_c], 0).astype(jnp.int32)
    win_count = jnp.where(live, index.cell_count[nbr_c], 0).astype(jnp.int32)
    return win_start, win_count


def neighbor_rank(index: GridIndex, query_keys: jax.Array) -> jax.Array:
    """Vectorized membership lookup in B: rank of each key, or -1 if absent.

    This is the TPU-native replacement for the paper's per-thread binary
    search (Alg. 1 line 11): one batched ``searchsorted`` over all queries.
    """
    pos = jnp.searchsorted(index.cell_keys, query_keys).astype(jnp.int32)
    pos = jnp.minimum(pos, index.num_points - 1)
    hit = index.cell_keys[pos] == query_keys
    return jnp.where(hit, pos, -1)


# ---------------------------------------------------------------------------
# Cell-run plans (DESIGN.md S11): queries sharing a grid cell have identical
# window descriptors for EVERY stencil offset (both descriptor families above
# derive (win_start, win_count) purely from the query's cell rank), so the
# fused kernel can gather each cell's candidate window once per RUN of
# co-located query rows instead of once per row -- the paper's duplicate-
# search-removal (SIV-C) applied to the DMA stream.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RunPlan:
    """Cell-run partition of one fused launch's query rows.

    ``run_ord[i]`` is row i's run ordinal WITHIN its tq-tile: it resets to 0
    at every tile boundary and increments by exactly 1 where the row's cell
    identity changes, so rows with equal ordinals inside a tile form one run
    and (by the descriptor purity argument above) share ``win_start`` /
    ``win_count`` columns for all offsets. The run-loop kernel derives its
    DMA schedule entirely from this array (head = ordinal change, slot =
    ordinal mod 2); ``n_runs`` / ``run_lengths`` are the host-side
    accounting behind ``JoinStats.dma_windows_issued`` and the bench
    run-length histogram.
    """

    run_ord: np.ndarray       # (qp,) int32 per-tile run ordinals
    n_runs: int               # total runs across all tiles
    run_lengths: np.ndarray   # (n_runs,) int32 rows per run


def cell_run_plan(cell_of_row: np.ndarray, tq: int) -> RunPlan:
    """Partition a launch's rows into maximal same-cell runs, per tile.

    ``cell_of_row`` is any per-row cell identity in launch order -- the
    self-join drivers use ``point_cell_rank`` at each row's sorted
    position, the external serving path the (sorted) query batch's cell
    coordinates collapsed to group ids. Rows are grouped while the
    identity repeats; runs additionally split at ``tq``-tile boundaries
    because the kernel's grid iterates tiles (per-tile DMA warm-up and
    outputs), which is why ``run_ord`` can reset per tile.

    The partition is exact: every row belongs to exactly one run, ordinals
    within a tile start at 0 and step by {0, 1}, and a step of 1 happens
    precisely where the cell identity changes. ``analysis.contracts.
    check_run_plan`` (C10) re-proves this against an independently derived
    cell-of-row oracle; tests/test_cell_runs.py fuzzes it.
    """
    ids = np.asarray(cell_of_row)
    qp = ids.shape[0]
    if tq <= 0 or qp % tq:
        raise ValueError(f"run plan rows {qp} must be a positive multiple "
                         f"of tq={tq}")
    head = np.ones(qp, bool)
    head[1:] = ids[1:] != ids[:-1]
    head[np.arange(0, qp, tq)] = True
    run_ord = (np.cumsum(head.reshape(-1, tq), axis=1, dtype=np.int64) - 1)
    starts = np.flatnonzero(head)
    lengths = np.diff(np.append(starts, qp)).astype(np.int32)
    return RunPlan(run_ord=run_ord.reshape(-1).astype(np.int32),
                   n_runs=int(starts.size),
                   run_lengths=lengths)


@partial(jax.jit, static_argnames=("merged",))
def _cell_window_table_device(index: GridIndex, deltas, *, merged: bool):
    """Per-CELL window descriptor tables, shape (n_off, num_points).

    Column r holds the (win_start, win_count, win_cells) triple of cell
    rank r -- the same arithmetic as ``window_descriptors_at`` /
    ``range_window_descriptors_at`` evaluated once per CELL instead of
    once per query row. Columns beyond ``num_cells`` are dead (count 0):
    they are only ever gathered through clamped padding rows, whose
    counts the preps re-zero anyway. Computing the table once per index
    and gathering per launch removes the per-launch searchsorted over
    (n_off x rows) -- the paper's duplicate-search removal (SIV-C) on the
    descriptor side, feeding the run-loop kernel's DMA-side dedup.
    """
    npts = index.num_points
    valid = jnp.arange(npts) < index.num_cells
    own_key = jnp.where(valid, index.cell_keys, 0)
    if merged:
        dtab, lo_off, hi_off = deltas
        dim_last = index.dims.astype(jnp.int64)[-1]
        q_last = own_key % dim_last
        base = own_key[None, :] + dtab[:, None]
        lo = jnp.maximum(lo_off[:, None], -q_last[None, :])
        hi = jnp.minimum(hi_off[:, None], dim_last - 1 - q_last[None, :])
        lo_rank = jnp.searchsorted(index.cell_keys, base + lo,
                                   side="left").astype(jnp.int32)
        hi_rank = jnp.searchsorted(index.cell_keys, base + hi,
                                   side="right").astype(jnp.int32)
        live = (hi_rank > lo_rank) & valid[None, :]
        start = _rank_to_point(index, lo_rank)
        end = _rank_to_point(index, hi_rank)
        ws = jnp.where(live, start, 0).astype(jnp.int32)
        wc = jnp.where(live, end - start, 0).astype(jnp.int32)
        wcells = jnp.where(live, hi_rank - lo_rank, 0).astype(jnp.int32)
        return ws, wc, wcells
    qk = own_key[None, :] + deltas[:, None]
    nbr = neighbor_rank(index, qk)
    live = (nbr >= 0) & valid[None, :]
    nbr_c = jnp.maximum(nbr, 0)
    ws = jnp.where(live, index.cell_start[nbr_c], 0).astype(jnp.int32)
    wc = jnp.where(live, index.cell_count[nbr_c], 0).astype(jnp.int32)
    wcells = (wc > 0).astype(jnp.int32)
    return ws, wc, wcells


def cell_window_tables(index: GridIndex, deltas, *, merged: bool, tag):
    """Cached per-cell descriptor tables (see ``_cell_window_table_device``).

    ``deltas`` is the linearized offset table (unmerged) or the
    ``(dtab, lo_off, hi_off)`` triple (merged); ``tag`` disambiguates
    offset tables that share ``merged`` (the drivers pass the unicomp
    flag). Cached per index via ``index_cached`` so repeated sweeps and
    the run-loop's steady state never recompute the searchsorted plane.
    """
    return index_cached(
        index, f"wintab/{bool(merged)}/{tag}",
        lambda: _cell_window_table_device(index, deltas, merged=merged))


# ---------------------------------------------------------------------------
# Occupancy bucketing (DESIGN.md S6): partition query rows into candidate-
# capacity classes so the fused kernel pads each window to its BUCKET's
# capacity instead of the global max_per_cell. On skewed data the global max
# is 5-10x the median cell, so a single-capacity launch spends most of its
# window lanes on padding; per-bucket static capacities keep kernel shapes
# static (one cached executable per class) while sizing the work to the data.
# ---------------------------------------------------------------------------

CAP_ALIGN = 8  # lane alignment of window capacities (matches the kernels)


def round_up(x, m: int):
    """Round up to a multiple of m (python ints and np arrays alike) --
    THE capacity/tile alignment helper (selfjoin and query_join alias it)."""
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static partition of sorted query rows into capacity classes.

    ``caps[k]`` is bucket k's window capacity (ascending, CAP_ALIGN-aligned,
    the last equals the global capacity); ``sel[k]`` holds the bucket's
    sorted positions in ascending A-order (``None`` for the single-bucket
    plan, meaning "all rows, contiguous"). ``hist`` maps each capacity class
    to its query count -- the window-length histogram that motivated the
    classes (EXPERIMENTS.md SBuckets).
    """

    caps: tuple
    sel: tuple
    cap_global: int
    hist: dict

    @property
    def n_buckets(self) -> int:
        return len(self.caps)


def capacity_classes(cap_global: int, align: int = CAP_ALIGN) -> tuple:
    """Pow2-growing capacity ladder (align, 2*align, ...) capped at
    ``cap_global`` (which is kept even when not a power of two)."""
    cap_global = max(int(cap_global), align)
    out = []
    v = align
    while v < cap_global:
        out.append(v)
        v *= 2
    out.append(cap_global)
    return tuple(out)


def starts_ext(index: GridIndex) -> np.ndarray:
    """Host-side rank -> point-span bridge: ``cell_start`` of each valid
    rank with ``num_points`` appended as the exclusive end, so the point
    span of ranks [lo, hi) is ``starts_ext[lo] : starts_ext[hi]``. THE one
    copy of that convention -- the merged capacity planners (here) and the
    sparse counter (core/selfjoin.py) must agree with
    ``_rank_to_point`` bit-for-bit or window capacities undercount."""
    ncells = int(index.num_cells)
    return np.concatenate(
        [np.asarray(index.cell_start[:ncells]),
         np.asarray([index.num_points])]).astype(np.int64)


def cell_window_caps_host(index: GridIndex, merged: bool = False) -> np.ndarray:
    """Numpy reference for ``cell_window_caps``: 3^(n-1) host searchsorted
    sweeps, one per stencil offset. Kept as the independent oracle the
    device planner is property-tested against (tests/test_device_build.py);
    the serving path uses the batched device planner below.
    """
    from repro.core.stencil import merged_stencil_offsets, stencil_offsets

    ncells = int(index.num_cells)
    keys = np.asarray(index.cell_keys[:ncells])
    counts = np.asarray(index.cell_count[:ncells]).astype(np.int64)
    strides = np.asarray(row_major_strides(index.dims))
    caps = np.zeros(ncells, np.int64)
    if not merged:
        deltas = stencil_offsets(index.n_dims, unicomp=False) @ strides
        for delta in deltas:
            probe = keys + delta
            pos = np.minimum(np.searchsorted(keys, probe), ncells - 1)
            live = keys[pos] == probe
            caps = np.maximum(caps, np.where(live, counts[pos], 0))
        return caps.astype(np.int32)
    reduced, _, _ = merged_stencil_offsets(index.n_dims, unicomp=False)
    deltas = reduced @ strides
    dim_last = int(np.asarray(index.dims)[-1])
    last = keys % dim_last
    lo = keys + np.maximum(-1, -last)
    hi = keys + np.minimum(1, dim_last - 1 - last)
    ext = starts_ext(index)
    for delta in deltas:
        lo_rank = np.searchsorted(keys, lo + delta, side="left")
        hi_rank = np.searchsorted(keys, hi + delta, side="right")
        span = ext[hi_rank] - ext[lo_rank]
        caps = np.maximum(caps, np.where(hi_rank > lo_rank, span, 0))
    return caps.astype(np.int32)


@partial(jax.jit, static_argnames=("merged",))
def _cell_window_caps_device(index: GridIndex, deltas: jax.Array,
                             merged: bool) -> jax.Array:
    """Batched device form of the per-cell capacity sweep: ONE searchsorted
    over the (offset x cell) plane per probe side instead of a host loop of
    3^(n-1) sweeps. Operates on the full padded key array; lanes at rank >=
    ``num_cells`` are dead (padding-sentinel probes land on zero-count
    padding slots). Returns the (npts,) int64 caps; the un-jitted wrapper
    slices the valid prefix -- the single host sync of the plan.

    Probe overflow note: on the int32 key route the host reference promotes
    ``keys + delta`` to int64 while the device add wraps, but a wrapped
    probe is strictly negative (|key|, |delta| < volume < 2^31) and ranks
    to 0 where it can never equal a real key -- the same dead answer the
    host's out-of-range int64 probe gets at rank ``ncells``. The only
    geometry where the merged hi-probe could wrap PAST the padding sentinel
    is volume within 2 of 2^31, which contract C9 rejects
    (analysis/contracts.py ``check_device_sentinel``).
    """
    keys = index.cell_keys                           # (npts,) pad-sentineled
    kd = keys.dtype
    n = keys.shape[0]
    is_cell = jnp.arange(n, dtype=jnp.int32) < index.num_cells
    counts = jnp.where(is_cell, index.cell_count, 0).astype(jnp.int64)
    deltas = deltas.astype(kd)[:, None]              # (n_off, 1)
    if not merged:
        probe = _pad_probe(keys[None, :] + deltas, is_cell[None, :], kd)
        pos = jnp.minimum(jnp.searchsorted(keys, probe), n - 1)
        live = keys[pos] == probe
        hit = jnp.where(live, counts[pos], 0)        # (n_off, npts)
        return jnp.max(hit, axis=0)
    dim_last = index.dims.astype(kd)[-1]
    last = keys % dim_last
    lo = keys + jnp.maximum(jnp.asarray(-1, kd), -last)
    hi = keys + jnp.minimum(jnp.asarray(1, kd), dim_last - 1 - last)
    # dead lanes: inverted sentinel span (lo=pad, hi=pad-1), the idiom of
    # ``external_range_descriptors`` -- both ranks land in the padding tail
    # and the hi_rank > lo_rank mask kills the lane
    lo_key = _pad_probe(lo[None, :] + deltas, is_cell[None, :], kd)
    hi_key = jnp.where(is_cell[None, :], hi[None, :] + deltas,
                       jnp.asarray(pad_key_for(kd) - 1, kd))
    lo_rank = jnp.searchsorted(keys, lo_key, side="left").astype(jnp.int32)
    hi_rank = jnp.searchsorted(keys, hi_key, side="right").astype(jnp.int32)
    span = (_rank_to_point(index, hi_rank)
            - _rank_to_point(index, lo_rank)).astype(jnp.int64)
    hit = jnp.where(hi_rank > lo_rank, span, 0)
    return jnp.max(hit, axis=0)


def cell_window_caps(index: GridIndex, merged: bool = False) -> np.ndarray:
    """Per non-empty cell: the largest candidate window any of its points
    can see. Pure index arithmetic; an upper bound for any sub-stencil
    (e.g. the UNICOMP half), so one plan serves both sweep modes.

    ``merged=False``: max over the FULL 3^n stencil of the single neighbor
    cell's count (own cell included). ``merged=True``: max over the
    3^(n-1) reduced stencil of the MERGED last-dimension range window
    (DESIGN.md S7) -- the contiguous span of up to three cells' points,
    clamped at the grid row like ``range_window_descriptors_at``.

    The sweep itself runs on the device (``_cell_window_caps_device``,
    batched over all reduced offsets at once); this wrapper materializes
    the offset table, launches the jitted planner, and performs the single
    host sync that fixes the static bucket-capacity classes. Bit-equal to
    ``cell_window_caps_host``.
    """
    from repro.core.stencil import merged_stencil_offsets, stencil_offsets

    strides = np.asarray(row_major_strides(index.dims))
    if merged:
        reduced, _, _ = merged_stencil_offsets(index.n_dims, unicomp=False)
        deltas = reduced @ strides
    else:
        deltas = stencil_offsets(index.n_dims, unicomp=False) @ strides
    kd = np.dtype(index.cell_keys.dtype)
    caps = _cell_window_caps_device(
        index, jnp.asarray(deltas.astype(kd)), merged=merged)
    ncells = int(index.num_cells)
    return np.asarray(caps)[:ncells].astype(np.int32)


@jax.jit
def _external_span_device(index: GridIndex) -> jax.Array:
    """Device form of the external range-cap sweep: point span of keys
    [k, k+2] for every present key k, batched ``searchsorted`` with
    side='right'. Padding lanes probe pad-1 and span zero."""
    keys = index.cell_keys
    kd = keys.dtype
    n = keys.shape[0]
    is_cell = jnp.arange(n, dtype=jnp.int32) < index.num_cells
    hi_key = jnp.where(is_cell, keys + jnp.asarray(2, kd),
                       jnp.asarray(pad_key_for(kd) - 1, kd))
    hi_rank = jnp.searchsorted(keys, hi_key, side="right").astype(jnp.int32)
    lo = _rank_to_point(index, jnp.arange(n, dtype=jnp.int32))
    span = (_rank_to_point(index, hi_rank) - lo).astype(jnp.int64)
    return jnp.where(is_cell, span, 0)


# Derived structures (bucket plans, lookup tables, route decisions) are
# pure functions of the (immutable) index; cache them per live GridIndex so
# repeated joins against the same index pay the planning work once. Keyed
# by (id, tag) with a weakref finalizer for eviction -- GridIndex holds jax
# arrays and is itself unhashable. Bounded LRU: a long-lived re-indexing
# service (launch/serve.py reindex) swaps snapshots indefinitely, and the
# finalizer alone only fires when the OLD index is garbage collected --
# anything still referencing a retired index would pin its plans forever.
# Entries are pure recomputable values (never executables), so eviction can
# only cost a rebuild, never a retrace.
_INDEX_CACHE_MAX = 64
_INDEX_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_MISSING = object()

INDEX_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0, "finalized": 0}


def index_cache_stats() -> dict:
    """Snapshot of the per-index plan cache counters plus current size."""
    out = dict(INDEX_CACHE_STATS)
    out["size"] = len(_INDEX_CACHE)
    return out


def _finalize_index_entry(key) -> None:
    # The entry may already be gone (LRU eviction raced the GC): pop with a
    # sentinel default so a late finalizer never raises or double-counts.
    if _INDEX_CACHE.pop(key, _MISSING) is not _MISSING:
        INDEX_CACHE_STATS["finalized"] += 1


def index_cached(index: GridIndex, tag: str, build):
    """Memoize ``build()`` on the index object under ``tag`` (bounded LRU)."""
    key = (id(index), tag)
    value = _INDEX_CACHE.get(key, _MISSING)
    if value is not _MISSING:
        INDEX_CACHE_STATS["hits"] += 1
        _INDEX_CACHE.move_to_end(key)
        return value
    INDEX_CACHE_STATS["misses"] += 1
    value = build()
    _INDEX_CACHE[key] = value
    weakref.finalize(index, _finalize_index_entry, key)
    while len(_INDEX_CACHE) > _INDEX_CACHE_MAX:
        _INDEX_CACHE.popitem(last=False)
        INDEX_CACHE_STATS["evictions"] += 1
    return value


def cell_window_caps_cached(index: GridIndex,
                            merged: bool = False) -> np.ndarray:
    """``cell_window_caps`` memoized per index object -- the merged caps
    feed both ``global_window_cap`` and the occupancy plan build, and a
    6-D pass is 3^(n-1) host searchsorted sweeps worth not repeating."""
    return index_cached(index, f"cellcaps/{merged}",
                        lambda: cell_window_caps(index, merged=merged))


def global_window_cap(index: GridIndex, merged: bool = False,
                      align: int = CAP_ALIGN) -> int:
    """Aligned global window capacity of one fused launch: the unbucketed
    static window size. Unmerged: the paper's max_per_cell. Merged: the
    largest merged range window any cell sees (<= 3 * max_per_cell,
    computed exactly; cached per index)."""
    if not merged:
        return round_up(max(int(index.max_per_cell), 1), align)

    def build():
        caps = cell_window_caps_cached(index, merged=True)
        top = int(caps.max()) if caps.size else 0
        return round_up(max(top, 1), align)

    return index_cached(index, f"capglobal/{align}/{merged}", build)


def external_range_cap(index: GridIndex, align: int = CAP_ALIGN) -> int:
    """Upper bound on ANY merged range window an external query can see.

    An external query's window spans keys [base-1, base+1]; its minimal
    present key k bounds the span by [k, k+2] -- so the max over present
    keys k of the point span of [k, k+2] dominates every possible query
    window, including windows whose center cell is absent from B (which
    per-cell caps cannot see). Sweep on the device
    (``_external_span_device``); cached per index.
    """
    def build():
        span = np.asarray(_external_span_device(index))
        top = int(span.max()) if span.size else 0
        return round_up(max(top, 1), align)

    return index_cached(index, f"extcap/{align}", build)


def occupancy_plan(index: GridIndex, align: int = CAP_ALIGN,
                   merged: bool = False) -> BucketPlan:
    """Window-length histogram -> capacity classes -> query-row partition.

    Rows keep ascending A-order inside every bucket (a cell's points share
    a class, so selections are runs of whole cells) and each row appears in
    exactly ONE bucket: per-bucket counts and slot bases compose back into
    the single-pass count -> fill contract by concatenation. ``merged``
    plans classes on the merged range-window capacities (DESIGN.md S7).
    """
    return index_cached(index, f"plan/{align}/{merged}",
                        lambda: _build_occupancy_plan(index, align, merged))


def filter_plan_rows(plan: BucketPlan, row_ok: np.ndarray) -> BucketPlan:
    """Restrict a BucketPlan to the sorted rows where ``row_ok`` is True.

    The distributed slab join (core/distributed.py) launches the fused
    sweep only over rows its slab OWNS -- halo rows are candidates, never
    queries -- so every bucket's selection is intersected with the
    ownership mask (ascending A-order preserved). Contiguous single-class
    plans (``sel`` None) become explicit selections; classes left empty
    are dropped; the capacity ladder and histogram keep the surviving
    rows' counts.
    """
    row_ok = np.asarray(row_ok, bool)
    caps, sels, hist = [], [], {}
    for cap, sel in zip(plan.caps, plan.sel):
        rows = (np.flatnonzero(row_ok).astype(np.int32) if sel is None
                else sel[row_ok[sel]])
        if rows.size:
            caps.append(cap)
            sels.append(rows)
            hist[int(cap)] = int(rows.size)
    if not caps:
        return BucketPlan(caps=(plan.cap_global,), sel=(np.zeros(0, np.int32),),
                          cap_global=plan.cap_global,
                          hist={plan.cap_global: 0})
    return BucketPlan(caps=tuple(caps), sel=tuple(sels),
                      cap_global=plan.cap_global, hist=hist)


def _build_occupancy_plan(index: GridIndex, align: int,
                          merged: bool = False) -> BucketPlan:
    npts = index.num_points
    cap_global = global_window_cap(index, merged, align)
    if cap_global <= align or npts == 0:
        return BucketPlan(caps=(cap_global,), sel=(None,),
                          cap_global=cap_global, hist={cap_global: npts})
    classes = capacity_classes(cap_global, align)
    caps = cell_window_caps_cached(index, merged=merged)  # (ncells,)
    caps_aligned = np.minimum(
        round_up(np.maximum(caps, 1), align), cap_global)
    cls_of_cell = np.searchsorted(np.asarray(classes), caps_aligned)
    rank = np.asarray(index.point_cell_rank)             # (npts,) cell of row
    cls_of_row = cls_of_cell[rank]
    hist, sels, kept = {}, [], []
    for k, cap in enumerate(classes):
        rows = np.flatnonzero(cls_of_row == k).astype(np.int32)
        if rows.size:
            hist[int(cap)] = int(rows.size)
            sels.append(rows)
            kept.append(int(cap))
    if len(kept) == 1:
        # one populated class: single contiguous launch at that capacity
        return BucketPlan(caps=(kept[0],), sel=(None,),
                          cap_global=cap_global, hist=hist)
    return BucketPlan(caps=tuple(kept), sel=tuple(sels),
                      cap_global=cap_global, hist=hist)

