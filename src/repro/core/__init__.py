"""Core self-join library: the paper's primary contribution in JAX.

Public API:
    build_grid_host / build_grid   -- the epsilon-grid index (paper SIV)
    self_join                      -- grid join, optional UNICOMP (paper SV-B)
    self_join_batched              -- result-set batching driver (paper SV-A)
    brute_force_join / brute_force_count  -- GPU brute-force baseline (paper SVI-B)
    rtree_join / ego_join          -- CPU baselines (paper SVI-B)
    distributed_self_join_count    -- shard_map slab decomposition (DESIGN S3)
"""
from repro.core.grid import GridIndex, build_grid, build_grid_host
from repro.core.stencil import stencil_offsets
from repro.core.selfjoin import (
    per_point_neighbor_counts,
    range_query,
    self_join,
    self_join_batched,
    self_join_count,
    self_join_count_compact,
)
from repro.core.brute import brute_force_count, brute_force_join
from repro.core.baselines import ego_join, rtree_join
from repro.core.distributed import distributed_self_join_count

__all__ = [
    "GridIndex",
    "build_grid",
    "build_grid_host",
    "stencil_offsets",
    "self_join",
    "self_join_count",
    "self_join_count_compact",
    "self_join_batched",
    "per_point_neighbor_counts",
    "range_query",
    "brute_force_count",
    "brute_force_join",
    "rtree_join",
    "ego_join",
    "distributed_self_join_count",
]
