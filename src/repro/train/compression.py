"""Cross-pod gradient compression: int8 all-gather with error feedback.

Within a pod, gradients synchronize over the fast ICI fabric (GSPMD inserts
the reduce inside backward). *Across* pods the link is DCN -- the slow, paid
link -- so the cross-pod exchange is made explicit and compressed:

  1. the batch is sharded over ('pod', 'data'); shard_map manual over 'pod'
     (auto over the rest) yields per-pod mean gradients;
  2. each tensor is quantized to int8 against a shared scale
     (pmax of per-pod absmax over 'pod');
  3. int8 payloads are all-gathered over 'pod' (1 byte/elem/pod on the wire
     vs 4 for an f32 ring all-reduce -> ~4x DCN traffic reduction, 2x vs
     bf16) and summed locally in int32;
  4. quantization error is fed back into the next step's gradient (error
     feedback keeps the scheme unbiased over time).

The error-feedback buffers live in the optimizer state pytree and shard like
the gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, scale):
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q.astype(jnp.int8)


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(grads, errors, axis: str, n_pods: int):
    """Per-tensor int8 all-gather mean over ``axis`` with error feedback.

    Call inside shard_map (manual over ``axis``). Returns
    (mean_grads, new_errors).
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        local_max = jnp.max(jnp.abs(g32))
        shared_max = jax.lax.pmax(local_max, axis)
        scale = jnp.maximum(shared_max, 1e-12) / 127.0
        q = quantize(g32, scale)
        new_e = g32 - dequantize(q, scale)            # error feedback
        gathered = jax.lax.all_gather(q, axis)        # int8 on the wire
        total = gathered.astype(jnp.int32).sum(axis=0)
        mean = dequantize(total, scale) / n_pods
        return mean.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def compressed_mean_gspmd(pod_grads, errors, n_pods: int):
    """The same int8 exchange as ``compressed_psum_mean``, expressed over
    EXPLICIT per-pod gradient operands inside one GSPMD program -- no
    shard_map.

    The jax 0.4.x line this container ships cannot lower the partial-manual
    shard_map composition the collective form needs (the SPMD partitioner
    hard-crashes on manual-subgroup operands; see ``repro.compat``), so the
    train step there materializes each pod's gradient explicitly and runs
    the identical quantize -> int32-sum -> dequantize pipeline as plain
    array math, leaving the cross-pod transfer placement to GSPMD. The
    wire-format claim is weaker than the collective form (XLA chooses what
    crosses the DCN), but the *numerics* are the same scheme: shared scale
    from the max per-pod absmax, int8 rounding per pod, error feedback
    carrying the MEAN residual (adding the shared residual to every pod's
    gradient feeds exactly one residual into the reconstructed mean, so the
    scheme stays unbiased over time like the per-pod form).

    ``pod_grads`` is a list of ``n_pods`` gradient pytrees; returns
    (mean_grads, new_errors) with ``new_errors`` shaped like ``errors``
    (one shared copy, matching ``init_error_state``).
    """
    flat_e, tdef = jax.tree.flatten(errors)
    flat_gs = [tdef.flatten_up_to(g) for g in pod_grads]

    def one(e, *gs):
        g32 = [g.astype(jnp.float32) + e for g in gs]
        smax = jnp.abs(g32[0]).max()
        for g in g32[1:]:
            smax = jnp.maximum(smax, jnp.abs(g).max())
        scale = jnp.maximum(smax, 1e-12) / 127.0
        qs = [quantize(g, scale) for g in g32]
        recon = dequantize(sum(q.astype(jnp.int32) for q in qs), scale)
        mean = recon / n_pods
        new_e = (sum(g32) - recon) / n_pods       # mean residual feedback
        return mean.astype(gs[0].dtype), new_e

    out = [one(e, *(fg[i] for fg in flat_gs)) for i, e in enumerate(flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
