"""Cross-pod gradient compression: int8 all-gather with error feedback.

Within a pod, gradients synchronize over the fast ICI fabric (GSPMD inserts
the reduce inside backward). *Across* pods the link is DCN -- the slow, paid
link -- so the cross-pod exchange is made explicit and compressed:

  1. the batch is sharded over ('pod', 'data'); shard_map manual over 'pod'
     (auto over the rest) yields per-pod mean gradients;
  2. each tensor is quantized to int8 against a shared scale
     (pmax of per-pod absmax over 'pod');
  3. int8 payloads are all-gathered over 'pod' (1 byte/elem/pod on the wire
     vs 4 for an f32 ring all-reduce -> ~4x DCN traffic reduction, 2x vs
     bf16) and summed locally in int32;
  4. quantization error is fed back into the next step's gradient (error
     feedback keeps the scheme unbiased over time).

The error-feedback buffers live in the optimizer state pytree and shard like
the gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, scale):
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q.astype(jnp.int8)


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(grads, errors, axis: str, n_pods: int):
    """Per-tensor int8 all-gather mean over ``axis`` with error feedback.

    Call inside shard_map (manual over ``axis``). Returns
    (mean_grads, new_errors).
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        local_max = jnp.max(jnp.abs(g32))
        shared_max = jax.lax.pmax(local_max, axis)
        scale = jnp.maximum(shared_max, 1e-12) / 127.0
        q = quantize(g32, scale)
        new_e = g32 - dequantize(q, scale)            # error feedback
        gathered = jax.lax.all_gather(q, axis)        # int8 on the wire
        total = gathered.astype(jnp.int32).sum(axis=0)
        mean = dequantize(total, scale) / n_pods
        return mean.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
