"""AdamW with fp32 master weights (bf16 compute params) + Adafactor option.

Optimizer state mirrors the parameter sharding specs exactly (master, m, v
each get the param's PartitionSpec), so FSDP-sharded parameters keep their
optimizer state sharded the same way -- 16 bytes/param spread over the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # factored second moment (Adafactor-style) for giant models: v is stored
    # as row+col factors for 2-D+ weights, ~halving optimizer bytes.
    factored: bool = False
    # storage dtype for the first moment (compute stays f32): 'bfloat16'
    # drops optimizer bytes 4->2 per param -- the 8-bit-Adam-style state
    # compression lever for the 300B+ MoEs (see EXPERIMENTS.md SDry-run).
    m_dtype: str = "float32"


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def adamw_init(params, cfg: Optional[AdamWConfig] = None):
    cfg = cfg or AdamWConfig()

    def v_like(p):
        if cfg.factored and p.ndim >= 2:
            return {
                "row": jnp.zeros(p.shape[:-1], jnp.float32),
                "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return jnp.zeros(p.shape, jnp.float32)

    m_dt = jnp.dtype(cfg.m_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, m_dt), params),
        "v": jax.tree.map(v_like, params),
    }


def opt_state_specs(param_specs, cfg: Optional[AdamWConfig] = None,
                    param_shapes=None):
    """Sharding specs for the optimizer state (mirrors param specs)."""
    from jax.sharding import PartitionSpec as P

    cfg = cfg or AdamWConfig()
    is_spec = lambda x: isinstance(x, P)

    def v_spec(sp, shape):
        if cfg.factored and shape is not None and len(shape.shape) >= 2:
            return {"row": P(*sp[:-1]), "col": P(*(sp[:-2] + sp[-1:]))}
        return sp

    if cfg.factored and param_shapes is not None:
        v = jax.tree.map(v_spec, param_specs, param_shapes, is_leaf=is_spec)
    else:
        v = param_specs
    return {
        "step": P(),
        "master": param_specs,
        "m": param_specs,
        "v": v,
    }


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: Optional[AdamWConfig] = None):
    """Returns (new_params, new_state, metrics)."""
    cfg = cfg or AdamWConfig()
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    m_dt = jnp.dtype(cfg.m_dtype)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        if isinstance(v, dict):  # factored second moment
            g2 = g * g
            v = {
                "row": cfg.b2 * v["row"] + (1 - cfg.b2) * g2.mean(axis=-1),
                "col": cfg.b2 * v["col"] + (1 - cfg.b2) * g2.mean(axis=-2),
            }
            r = v["row"] / jnp.maximum(v["row"].mean(axis=-1, keepdims=True), 1e-30)
            vhat = r[..., None] * v["col"][..., None, :]
        else:
            v = cfg.b2 * v + (1 - cfg.b2) * g * g
            vhat = v
        mh = m / b1c
        vh = vhat / b2c
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                    + cfg.weight_decay * master)
        return m.astype(m_dt), v, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_ma = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, v, ma) for g, m, v, ma in
           zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(
        lambda ma, p: ma.astype(p.dtype), new_master, params)
    new_state = {"step": step, "master": new_master, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
