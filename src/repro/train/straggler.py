"""Straggler detection and mitigation hooks.

At thousand-node scale the common failure smells are (a) a host whose steps
are consistently slow (bad HBM, thermal throttling, noisy neighbor) and (b) a
host that stops heartbeating entirely. This monitor implements the detection
side and exposes mitigation hooks the launcher wires up:

  * per-step wall time EWMA + variance; a step slower than
    ``threshold x EWMA`` increments a strike counter;
  * ``strikes >= patience`` -> ``should_rebalance()`` flips, and the train
    loop checkpoints + restarts on a smaller 'data' axis (elastic restore,
    ckpt/checkpoint.py) excluding the slow host;
  * heartbeat files (one per host) let any host detect a dead peer without
    a control plane -- missing heartbeat for ``dead_after`` seconds is
    treated like a failed step barrier.

On this single-process container the monitor is exercised by tests with
synthetic timings; the decision logic is identical at scale.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Optional


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0      # x EWMA that counts as a slow step
    patience: int = 3           # consecutive strikes before rebalance
    alpha: float = 0.1          # EWMA coefficient
    warmup_steps: int = 5       # ignore compile/jit steps
    dead_after: float = 300.0   # heartbeat staleness -> dead host

    ewma: Optional[float] = None
    strikes: int = 0
    steps: int = 0
    slow_steps: int = 0

    def record(self, step_time: float) -> bool:
        """Feed one step's wall time; returns True if it counted as slow."""
        self.steps += 1
        if self.steps <= self.warmup_steps:
            return False
        if self.ewma is None:
            self.ewma = step_time
            return False
        slow = step_time > self.threshold * self.ewma
        if slow:
            self.strikes += 1
            self.slow_steps += 1
        else:
            self.strikes = 0
            # only fold non-outlier steps into the EWMA
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
        return slow

    def should_rebalance(self) -> bool:
        return self.strikes >= self.patience

    def reset(self):
        self.strikes = 0

    # -- heartbeat files (cross-host liveness without a control plane) ------

    @staticmethod
    def heartbeat(directory: str, host_id: int, step: int):
        os.makedirs(directory, exist_ok=True)
        path = os.path.join(directory, f"host_{host_id}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time()}, f)
        os.replace(tmp, path)

    def dead_hosts(self, directory: str, now: Optional[float] = None) -> list:
        now = now or time.time()
        dead = []
        if not os.path.isdir(directory):
            return dead
        for fn in os.listdir(directory):
            if fn.startswith("host_") and fn.endswith(".json"):
                with open(os.path.join(directory, fn)) as f:
                    hb = json.load(f)
                if now - hb["time"] > self.dead_after:
                    dead.append(int(fn.split("_")[1].split(".")[0]))
        return sorted(dead)
