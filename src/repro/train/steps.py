"""Jitted train/serve step builders with explicit in/out shardings.

``make_train_step`` wires model.train_loss -> grads -> AdamW into one jitted,
donated step. With ``compress_pods=True`` on a multi-pod mesh, the step is
wrapped in a shard_map manual over 'pod' (auto over 'data'/'model'): each pod
computes its own gradient under GSPMD, and the cross-pod exchange goes
through train/compression.py (int8 all-gather + error feedback) instead of
the implicit f32 all-reduce -- the DCN link is the slow one at multi-pod
scale (DESIGN.md S6).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.train import compression as comp
from repro.train.optimizer import AdamWConfig, adamw_update


def make_train_step(model, opt_cfg: AdamWConfig, *, compress_pods: bool = False,
                    param_specs=None):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    The caller jits this with in/out shardings (launch/train.py, dryrun.py).

    ``param_specs`` pins the GRADIENT sharding to the parameter sharding.
    Without it, GSPMD is free to materialize replicated f32 gradients inside
    the layer scan and all-reduce them (measured on grok/arctic: ~20 GB
    all-reduces per layer, EXPERIMENTS.md SPerf); the constraint makes the
    backward emit reduce-scatters into the FSDP shards instead.
    """
    mesh = model.mesh
    has_pod = mesh is not None and "pod" in mesh.axis_names

    def loss_fn(p, batch):
        loss, aux = model.train_loss(p, batch)
        return loss, aux

    def constrain(grads):
        if param_specs is None or mesh is None:
            return grads
        from jax.sharding import PartitionSpec as P

        def one(sp, g):
            try:
                return jax.lax.with_sharding_constraint(g, sp)
            except (ValueError, RuntimeError):
                return g

        return jax.tree.map(one, param_specs, grads,
                            is_leaf=lambda x: isinstance(x, P))

    if not (compress_pods and has_pod):
        def step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            grads = constrain(grads)
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 opt_cfg)
            metrics = {"loss": loss, **aux, **om}
            return params, opt_state, metrics
        return step

    n_pods = mesh.shape["pod"]

    def pod_partials_shard_map(params, batch, errors):
        """Per-pod grads + exchange via shard_map manual over 'pod' only
        ('data'/'model' remain GSPMD-auto inside) -- the production form,
        partial-manual, available on new jax."""

        def per_pod(params, batch, errors):
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            grads, new_errors = comp.compressed_psum_mean(
                grads, errors, "pod", n_pods)
            loss = jax.lax.pmean(loss, "pod")
            aux = jax.tree.map(lambda a: jax.lax.pmean(a, "pod"), aux)
            return loss, aux, grads, new_errors

        pspecs = jax.tree.map(lambda _: P(), params)
        espisos = jax.tree.map(lambda _: P(), errors)
        batch_specs = jax.tree.map(lambda _: P("pod"), batch)
        from repro.compat import shard_map

        return shard_map(
            per_pod, mesh=mesh,
            in_specs=(pspecs, batch_specs, espisos),
            out_specs=(P(), jax.tree.map(lambda _: P(), aux_struct(model)),
                       pspecs, espisos),
            check_vma=False,
            axis_names={"pod"},
        )(params, batch, errors)

    def pod_partials_gspmd(params, batch, errors):
        """Per-pod grads + exchange as one explicit GSPMD program -- the
        jax 0.4.x composition (partial-manual shard_map crashes the 0.4.x
        SPMD partitioner; see repro.compat).

        Each pod's gradient comes from a FULL-shape backward whose loss
        masks the other pods' rows (labels -1 drop out of the token mask),
        not a sliced half-batch: the masked backward lowers to the same
        partitioned program as the plain step's, so the compressed step
        tracks the uncompressed trajectory to quantization error rather
        than diverging on reduction-order numerics -- bf16 models are
        sensitive enough that a differently-sharded backward drifts far
        beyond the compression error within a few steps. Costs n_pods
        backward passes; the shard_map form above is the scalable one.
        """
        rows = jax.tree_util.tree_leaves(batch)[0].shape[0]
        if rows % n_pods:
            # the shard_map form raises on a non-divisible pod shard; the
            # masked form must not silently drop the remainder rows
            raise ValueError(
                f"batch rows {rows} not divisible by n_pods {n_pods}")
        per = rows // n_pods
        losses, auxes, pod_grads = [], [], []
        for p in range(n_pods):
            keep = (jnp.arange(rows) // per) == p
            bp = dict(batch)
            bp["labels"] = jnp.where(keep[:, None], batch["labels"], -1)
            (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, bp)
            losses.append(l)
            auxes.append(aux)
            pod_grads.append(g)
        loss = sum(losses) / n_pods
        aux = jax.tree.map(lambda *xs: sum(xs) / n_pods, *auxes)
        grads, new_errors = comp.compressed_mean_gspmd(
            pod_grads, errors, n_pods)
        return loss, aux, grads, new_errors

    pod_partials = (pod_partials_shard_map if hasattr(jax, "shard_map")
                    else pod_partials_gspmd)

    def step(params, opt_state, batch):
        errors = opt_state["grad_error"]
        loss, aux, grads, new_errors = pod_partials(params, batch, errors)
        opt_state = dict(opt_state)
        opt_state["grad_error"] = new_errors
        inner = {k: opt_state[k] for k in ("step", "master", "m", "v")}
        params, inner, om = adamw_update(grads, inner, params, opt_cfg)
        opt_state.update(inner)
        metrics = {"loss": loss, **aux, **om}
        return params, opt_state, metrics

    return step


def aux_struct(model):
    return {"dropped_frac": 0.0}


def make_eval_step(model):
    def step(params, batch):
        loss, aux = model.train_loss(params, batch)
        return {"loss": loss, **aux}
    return step


def make_decode_step(model):
    def step(params, tokens, caches):
        return model.decode_step(params, tokens, caches)
    return step


def make_prefill_step(model):
    def step(params, batch, caches):
        return model.prefill(params, batch, caches)
    return step
