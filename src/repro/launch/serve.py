"""Serving driver: batched epsilon-range queries against a grid-indexed set,
or LM token decoding -- selected by --arch.

Self-join service (the paper's operator as a long-running service):
    python -m repro.launch.serve --arch selfjoin --points 20000 --dims 4 \
        --eps 1.0 --requests 8 --request-batch 256
The dataset is indexed ONCE (grid build, paper SIV); each request batch of
query points is answered with the bounded adjacent-cell sweep
(core.selfjoin.range_query). Batch latency is reported per request.

LM decode service:
    python -m repro.launch.serve --arch qwen1.5-0.5b --reduced --tokens 32
Prefills a prompt batch and decodes tokens autoregressively with the KV
cache, reporting per-token latency.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.models.lm import LMModel


def serve_selfjoin(args):
    from repro.core.grid import build_grid_host
    from repro.core.selfjoin import range_query

    rng = np.random.default_rng(args.seed)
    pts = rng.uniform(0, 100, size=(args.points, args.dims))
    t0 = time.time()
    index = build_grid_host(pts, args.eps)
    print(f"[serve] indexed {args.points} pts in {time.time()-t0:.3f}s "
          f"(|G|={int(index.num_cells)} non-empty cells)")
    lat = []
    total = 0
    for r in range(args.requests):
        q = rng.uniform(0, 100, size=(args.request_batch, args.dims))
        t0 = time.time()
        counts = range_query(q, pts, args.eps, index=index)
        lat.append(time.time() - t0)
        total += int(counts.sum())
    lat_ms = 1000 * np.asarray(lat)
    print(f"[serve] {args.requests} requests x {args.request_batch} queries: "
          f"p50 {np.percentile(lat_ms, 50):.1f}ms "
          f"p99 {np.percentile(lat_ms, 99):.1f}ms "
          f"({total} neighbors found)")
    return float(np.median(lat_ms))


def serve_lm(args):
    cfg = get_config(args.arch, reduced=args.reduced)
    model = LMModel(cfg, None)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    B, S = args.request_batch, args.prompt_len
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    caches = model.init_caches(B, S + args.tokens)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(2,))
    t0 = time.time()
    logits, caches = prefill(params, batch, caches)
    logits.block_until_ready()
    print(f"[serve] prefill {B}x{S} in {time.time()-t0:.3f}s")
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lat = []
    out = [tok]
    for _ in range(args.tokens):
        t0 = time.time()
        logits, caches = decode(params, tok, caches)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        tok.block_until_ready()
        lat.append(time.time() - t0)
        out.append(tok)
    lat_ms = 1000 * np.asarray(lat[1:])  # drop compile step
    print(f"[serve] decoded {args.tokens} tokens: "
          f"p50 {np.percentile(lat_ms, 50):.1f}ms/token")
    return float(np.median(lat_ms))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="selfjoin")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # selfjoin service
    ap.add_argument("--points", type=int, default=20000)
    ap.add_argument("--dims", type=int, default=4)
    ap.add_argument("--eps", type=float, default=2.0)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--request-batch", type=int, default=256)
    # lm service
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)
    if args.arch == "selfjoin":
        return serve_selfjoin(args)
    return serve_lm(args)


if __name__ == "__main__":
    main()
