"""Serving driver: a persistent external-query epsilon-join service over a
grid-indexed set, or LM token decoding -- selected by --arch.

Epsilon-join service (the paper's operator in the index-once/query-many
regime, DESIGN.md S5):
    python -m repro.launch.serve --arch selfjoin --points 20000 --dims 4 \
        --eps 1.0 --requests 8 --request-batch 256
``JoinService`` builds the grid index ONCE (paper SIV) and prepares the
fused external-query join path (core/query_join.py): offset tables and the
padded points copy are computed at startup, request batches are padded to
static bucket shapes, and every per-request computation dispatches into
module-level jitted functions whose XLA executables are cached per bucket --
so steady-state requests pay pure execution, never trace/compile (the bug
the original ``range_query``-per-request loop had). The driver warms the
request bucket, then reports p50/p99 latency and requests/sec over the
steady-state window, and FAILS (exit code) if any steady-state request
grew a compilation cache -- the no-retrace regression gate `make verify`
runs.

LM decode service:
    python -m repro.launch.serve --arch smoke-lm --reduced --tokens 32
Prefills a prompt batch and decodes tokens autoregressively with the KV
cache, reporting per-token latency.
"""
from __future__ import annotations

import argparse
import threading
import time
import warnings
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.models.lm import LMModel


class _JoinServiceBase:
    """Serving-side bookkeeping shared by the single-index, slab-sharded
    and batching services: steady-state latency percentiles that reflect
    execution rather than trace time, and a compilation-cache watchdog
    (``assert_no_retrace``) so a regression back to per-request tracing
    can never pass silently.

    Latency samples taken before ``mark_steady`` land in
    ``warmup_latencies_ms`` and are EXCLUDED from ``percentiles`` /
    ``requests_per_sec``; every ``warmup()`` implementation auto-marks
    steady (with a warning) so a caller that forgets ``mark_steady`` can
    no longer report warmup-tainted stats.
    """

    def __init__(self, return_pairs: bool = False):
        self.return_pairs = return_pairs
        self.latencies_ms: list[float] = []        # steady-state window
        self.warmup_latencies_ms: list[float] = []  # pre-steady samples
        self.total_neighbors = 0
        self.requests = 0
        self._steady = False
        self._warm_buckets: set[int] = set()
        self._cache_mark: Optional[dict] = None

    def _answer(self, queries: np.ndarray, eps: Optional[float]):
        raise NotImplementedError

    def mark_steady(self) -> None:
        """Snapshot compilation caches; later requests must not grow them,
        and later latency samples enter the steady-state window."""
        from repro.core.query_join import executable_cache_stats

        self._steady = True
        self._cache_mark = executable_cache_stats()

    def _auto_steady(self) -> None:
        """Called by ``warmup()``: enter steady state if the caller has
        not done so explicitly (warn -- forgetting ``mark_steady`` used to
        silently mix compile latencies into the report)."""
        if not self._steady:
            warnings.warn(
                "mark_steady() was never called; auto-marking steady "
                "after warmup() so reported stats exclude the warmup "
                "window", stacklevel=3)
            self.mark_steady()

    def query(self, queries: np.ndarray, *, eps: Optional[float] = None):
        """Answer one request; records the latency sample in the steady
        or warmup window depending on ``mark_steady``."""
        t0 = time.perf_counter()
        res = self._answer(queries, eps)
        dt_ms = 1000 * (time.perf_counter() - t0)
        (self.latencies_ms if self._steady
         else self.warmup_latencies_ms).append(dt_ms)
        self.requests += 1
        self.total_neighbors += res.total
        return res

    def _steady_window(self) -> list[float]:
        if self.latencies_ms:
            return self.latencies_ms
        if self.warmup_latencies_ms:
            warnings.warn(
                "no steady-state samples recorded (mark_steady/warmup "
                "never ran before queries); falling back to the warmup "
                "window -- stats include compile time", stacklevel=3)
            return self.warmup_latencies_ms
        return []

    def percentiles(self) -> tuple[float, float]:
        lat = np.asarray(self._steady_window())
        return (float(np.percentile(lat, 50)), float(np.percentile(lat, 99)))

    def requests_per_sec(self) -> float:
        win = self._steady_window()
        total_s = sum(win) / 1000
        return len(win) / total_s if total_s > 0 else float("inf")

    def assert_no_retrace(self) -> None:
        """Raise if any request since ``mark_steady`` traced or compiled.

        The device-emit scatter is exempt: its result-buffer capacity is a
        static shape bucketed to powers of two (with a floor), so a
        pair-serving service legitimately compiles O(log max_result) emit
        executables on demand as larger results first appear -- warmup
        cannot know result sizes in advance. Observability counters
        (``metric:`` trace events, e.g. the batching service's coalesce
        counters) are also exempt: they bump per launch without tracing.
        The prepare-path builders/planners (``grid_build``/``grid_caps``/
        ``grid_extspan``) are exempt too: they compile during index build
        and background ``reindex``, never per steady-state request.
        The request-path functions (window descriptors, fused sweep) must
        stay frozen; those are what the per-request re-tracing bug
        burned."""
        from repro.core.query_join import executable_cache_stats, metric_free

        def freeze(stats: dict) -> dict:
            out = {k: v for k, v in stats.items()
                   if k not in ("emit_pairs_device", "trace_events",
                                "grid_build", "grid_caps", "grid_extspan")}
            out["trace_events"] = {
                k: v for k, v in metric_free(stats["trace_events"]).items()
                if k != "emit_pairs_device"}
            return out

        now = executable_cache_stats()
        if (self._cache_mark is not None
                and freeze(now) != freeze(self._cache_mark)):
            raise RuntimeError(
                "serve path recompiled during steady state: "
                f"{freeze(self._cache_mark)} -> {freeze(now)}")


class JoinService(_JoinServiceBase):
    """Persistent epsilon-join service: index once, answer many requests.

    Wraps ``core.query_join.prepare`` with the serving-side bookkeeping of
    ``_JoinServiceBase`` plus bucket warmup (compile off the request
    path).

    The serving state is ONE snapshot tuple ``(index, prepared)``:
    ``reindex`` rebuilds both in a background thread (device build,
    DESIGN.md S10) and swaps them with a single reference assignment, so
    every request observes either the old snapshot or the new one, never a
    mix -- the first slice of the ROADMAP mutable-index item.
    """

    def __init__(self, points: np.ndarray, eps: float, *,
                 index=None, return_pairs: bool = False,
                 merge_last_dim: Optional[bool] = None,
                 metric: str = "l2", vocab: Optional[int] = None):
        from repro.core import metric as metric_lib
        from repro.core.grid import build_grid
        from repro.core.query_join import prepare

        super().__init__(return_pairs)
        metric_lib.check_metric(metric)
        self.metric = metric
        self.vocab = vocab
        self.eps = float(eps)          # METRIC-units threshold throughout
        self.merge_last_dim = merge_last_dim
        t0 = time.perf_counter()
        if metric != "l2":
            if index is not None:
                raise ValueError(
                    "JoinService: non-L2 metrics build their own index "
                    "over the canonical geometry; pass raw points")
            canon = metric_lib.canonicalize(points, eps, metric=metric,
                                            vocab=vocab)
            index = build_grid(np.asarray(canon.geom),
                               float(canon.eps_geom))
            prepared = prepare(index, merge_last_dim=merge_last_dim,
                               canon=canon)
        else:
            if index is None:
                index = build_grid(np.asarray(points), float(eps))
            prepared = prepare(index, merge_last_dim=merge_last_dim)
        self._snapshot = (index, prepared)
        self.build_s = time.perf_counter() - t0
        self.swaps = 0
        self.reindex_timings: Optional[dict] = None
        self._reindex_thread: Optional[threading.Thread] = None
        self._reindex_error: Optional[BaseException] = None

    @property
    def index(self):
        return self._snapshot[0]

    @property
    def prepared(self):
        return self._snapshot[1]

    def warmup(self, batch_size: int) -> int:
        """Compile the executables serving ``batch_size``-query requests
        (off the request path): the request bucket AND, on a skewed index,
        every (capacity class, bucket size) launch a steady-state request
        mix can need (``PreparedJoin.warm``). Returns the request bucket's
        padded row count."""
        from repro.core.query_join import bucket_rows

        qp = bucket_rows(batch_size)
        if qp not in self._warm_buckets:
            self.prepared.warm(batch_size, return_pairs=self.return_pairs)
            self._warm_buckets.add(qp)
        self._auto_steady()
        return qp

    def reindex(self, points: np.ndarray, *, wait: bool = True) -> None:
        """Rebuild the index over ``points`` and atomically swap the
        serving snapshot (DESIGN.md S10).

        Device build + planning + bucket warm-up all run in a background
        thread; requests keep being answered from the OLD snapshot until
        the single ``_snapshot`` assignment at the end. Executables are
        module-level and keyed by static shapes (bucket rows, capacity
        class, point count), so a new snapshot whose classes match the old
        one's reuses every warmed executable and the no-retrace watchdog
        stays green across the swap; a snapshot with genuinely new classes
        compiles here -- off the request path -- and the driver must
        ``mark_steady`` again. ``wait=False`` returns immediately; call
        ``join_reindex`` (or the next ``reindex``) to surface errors.
        """
        if self._reindex_thread is not None and self._reindex_thread.is_alive():
            raise RuntimeError("reindex already in progress")
        self.join_reindex()          # surface a previous failure, if any
        # non-L2 input may be ragged (token sets); canonicalize in-thread
        pts = np.asarray(points) if self.metric == "l2" else points

        def work():
            try:
                from repro.core import metric as metric_lib
                from repro.core.grid import build_grid
                from repro.core.query_join import prepare

                t0 = time.perf_counter()
                canon = None
                if self.metric != "l2":
                    canon = metric_lib.canonicalize(
                        pts, self.eps, metric=self.metric, vocab=self.vocab)
                    geom, eps_geom = np.asarray(canon.geom), canon.eps_geom
                else:
                    geom, eps_geom = pts, self.eps
                index = jax.block_until_ready(
                    build_grid(geom, float(eps_geom)))
                t1 = time.perf_counter()
                prepared = prepare(index,
                                   merge_last_dim=self.merge_last_dim,
                                   canon=canon)
                t2 = time.perf_counter()
                for qp in sorted(self._warm_buckets):
                    prepared.warm(qp, return_pairs=self.return_pairs)
                t3 = time.perf_counter()
                self._snapshot = (index, prepared)   # THE atomic swap
                self.swaps += 1
                self.reindex_timings = {
                    "build_s": t1 - t0, "plan_s": t2 - t1,
                    "warm_s": t3 - t2,
                    "swap_s": time.perf_counter() - t3}
            except BaseException as e:   # noqa: BLE001 -- surfaced in caller
                self._reindex_error = e

        th = threading.Thread(target=work, name="join-reindex", daemon=True)
        self._reindex_thread = th
        th.start()
        if wait:
            self.join_reindex()

    def join_reindex(self) -> None:
        """Block until any in-flight reindex has swapped; re-raise its
        error in the caller's thread if it failed."""
        th = self._reindex_thread
        if th is not None:
            th.join()
        if self._reindex_error is not None:
            err, self._reindex_error = self._reindex_error, None
            raise RuntimeError("background reindex failed") from err

    def _answer(self, queries: np.ndarray, eps: Optional[float] = None):
        return self.prepared.join(queries, eps=eps,
                                  return_pairs=self.return_pairs)


class ShardedJoinService(_JoinServiceBase):
    """Slab-sharded epsilon-join service (DESIGN.md S3 serving mode).

    The indexed set partitions into equal-count dim-0 slabs (the same
    partitioner as the distributed self-join); each slab holds its OWN
    grid index and ``PreparedJoin`` -- index once per slab. A request fans
    out to every slab (an external query near a slab boundary has
    neighbors on both sides), per-slab counts sum, and pair point-ids
    remap through the slab's global-id table, so the answer is identical
    to the single-index service (asserted in tests/test_query_join.py).
    No ownership rule is needed: every indexed point lives in exactly one
    slab, so no pair can be found twice.

    Warmup compiles every slab's executables off the request path; the
    no-retrace gate is inherited unchanged (the executable caches are
    module-level, shared across slabs -- a steady-state request may not
    grow them no matter which slab it lands on).
    """

    def __init__(self, points: np.ndarray, eps: float, n_slabs: int, *,
                 return_pairs: bool = False,
                 merge_last_dim: Optional[bool] = None,
                 metric: str = "l2", vocab: Optional[int] = None):
        from repro.core import metric as metric_lib
        from repro.core.distributed import partition_points_host
        from repro.core.grid import build_grid_host
        from repro.core.query_join import prepare

        super().__init__(return_pairs)
        metric_lib.check_metric(metric)
        self.metric = metric
        self.eps = float(eps)          # METRIC-units threshold
        # canonicalize ONCE over the full set (slab grids partition the
        # canonical geometry; queries canonicalize against this form)
        self._query_canon = None
        if metric != "l2":
            self._query_canon = metric_lib.canonicalize(
                points, eps, metric=metric, vocab=vocab)
            pts = np.asarray(self._query_canon.geom)
        else:
            pts = np.asarray(points)
        t0 = time.perf_counter()
        coords, gids, _ = partition_points_host(pts, n_slabs)
        self.n_slabs = n_slabs
        self.slab_gids: list[np.ndarray] = []
        self.prepared: list = []
        self.indexes: list = []
        for k in range(n_slabs):
            own = gids[k] >= 0
            if not own.any():
                continue                      # empty slab: nothing to index
            sg = gids[k][own]
            self.slab_gids.append(sg)
            canon_k = None
            if self._query_canon is not None:
                qc = self._query_canon
                canon_k = metric_lib.Canonical(
                    qc.metric, coords[k][own],
                    None if qc.feats is None else qc.feats[sg],
                    qc.n_feat, qc.eps, qc.eps_geom, qc.vocab)
            idx = build_grid_host(coords[k][own],
                                  float(self._query_canon.eps_geom
                                        if canon_k else eps))
            self.indexes.append(idx)
            self.prepared.append(prepare(idx, merge_last_dim=merge_last_dim,
                                         canon=canon_k))
        self.build_s = time.perf_counter() - t0

    def warmup(self, batch_size: int) -> int:
        from repro.core.query_join import bucket_rows

        qp = bucket_rows(batch_size)
        if qp not in self._warm_buckets:
            for pj in self.prepared:
                pj.warm(batch_size, return_pairs=self.return_pairs)
            self._warm_buckets.add(qp)
        self._auto_steady()
        return qp

    def _answer(self, queries: np.ndarray, eps: Optional[float] = None):
        # canonicalize raw metric queries ONCE (the pre-canonicalized
        # tuple path in join_async), not once per slab
        if self._query_canon is not None:
            from repro.core import metric as metric_lib
            queries = metric_lib.canonicalize_queries(self._query_canon,
                                                      queries)
        # dispatch EVERY slab before resolving ANY: the k-th slab's fused
        # sweep executes on device while the (k+1)-th is still being set
        # up on the host (join_async seam, DESIGN.md S8)
        pendings = [pj.join_async(queries, eps=eps,
                                  return_pairs=self.return_pairs,
                                  sort_pairs=False)
                    for pj in self.prepared]
        return _merge_slab_results([p.result() for p in pendings],
                                   self.slab_gids, self.return_pairs)


def _merge_slab_results(results, slab_gids, return_pairs: bool):
    """Scatter-gather merge of per-slab join results into the single-index
    answer: counts sum, pair point-ids remap through each slab's global-id
    table, merged pairs lexsort to the canonical order."""
    from repro.core.query_join import QueryJoinResult

    counts = None
    chunks = []
    bucket = 0
    n_off = 0
    emit = None
    for res, sg in zip(results, slab_gids):
        counts = res.counts if counts is None else counts + res.counts
        bucket, n_off, emit = res.bucket_rows, res.n_offsets, res.emit
        if return_pairs and res.pairs.shape[0]:
            p = res.pairs.copy()
            p[:, 1] = sg[p[:, 1]]             # slab point id -> global id
            chunks.append(p)
    pairs = None
    if return_pairs:
        pairs = (np.concatenate(chunks, axis=0) if chunks
                 else np.empty((0, 2), np.int32))
        pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
    return QueryJoinResult(
        counts=counts, pairs=pairs, n_offsets=n_off,
        bucket_rows=bucket, emit=emit,
        candidates_checked=None)


class BatchTicket:
    """Handle for one submitted request: completes when every part of the
    request (a request wider than ``max_batch`` is split) has been sliced
    out of its coalesced launch."""

    def __init__(self, n_parts: int, n_queries: int):
        self.n_parts = n_parts
        self.n_queries = n_queries
        self.t_submit = time.perf_counter()
        self.t_done: Optional[float] = None
        self._parts: dict = {}

    def done(self) -> bool:
        return len(self._parts) == self.n_parts

    def _add_part(self, part: int, res) -> None:
        self._parts[part] = res
        if self.done() and self.t_done is None:
            self.t_done = time.perf_counter()

    def result(self):
        """The request's QueryJoinResult, identical to serving it alone
        (parts concatenate back in submission order; pair query-rows of
        part k rebase by the rows of parts < k)."""
        from repro.core.query_join import QueryJoinResult

        if not self.done():
            raise RuntimeError(
                f"ticket incomplete: {len(self._parts)}/{self.n_parts} "
                f"parts resolved (call service.drain() first)")
        parts = [self._parts[i] for i in range(self.n_parts)]
        if len(parts) == 1:
            return parts[0]
        counts = np.concatenate([p.counts for p in parts])
        pairs = None
        if parts[0].pairs is not None:
            chunks = []
            row0 = 0
            for p in parts:
                q = p.pairs.copy()
                q[:, 0] += row0
                chunks.append(q)
                row0 += p.counts.shape[0]
            pairs = np.concatenate(chunks, axis=0)
        return QueryJoinResult(
            counts=counts, pairs=pairs, n_offsets=parts[0].n_offsets,
            bucket_rows=parts[0].bucket_rows, emit=parts[0].emit,
            candidates_checked=None)

    def latency_ms(self) -> float:
        if self.t_done is None:
            raise RuntimeError("ticket not complete")
        return 1000 * (self.t_done - self.t_submit)


class _Sub:
    """One admission-queue entry: a request part awaiting coalescing."""

    __slots__ = ("queries", "eps_key", "ticket", "part", "t_arrival")

    def __init__(self, queries, eps_key, ticket, part):
        self.queries = queries
        self.eps_key = eps_key
        self.ticket = ticket
        self.part = part
        self.t_arrival = time.perf_counter()


class _Inflight:
    """A launched coalesced batch whose device results are outstanding."""

    __slots__ = ("pendings", "subs", "bounds")

    def __init__(self, pendings, subs, bounds):
        self.pendings = pendings      # one PendingJoin per slab
        self.subs = subs
        self.bounds = bounds


class BatchingJoinService(_JoinServiceBase):
    """Continuous-batching epsilon-join service (DESIGN.md S8).

    Requests from independent callers enter an admission queue
    (``submit``) and are coalesced -- FIFO, same epsilon -- into single
    fused launches of up to ``max_batch`` queries, so the per-launch
    dispatch overhead that dominates small requests amortizes across
    callers and the kernel runs at the occupancy the paper's batching
    scheme targets. Coalesced batch sizes land on the same pow2 bucket
    ladder as direct requests (``bucket_rows``), and ``warmup`` compiles
    EVERY rung up to ``max_batch``, so ``PreparedJoin.warm``'s no-retrace
    contract holds over arbitrary coalescing patterns. A flushed batch
    dispatches through ``join_async`` and resolves lazily: up to two
    batches stay in flight, so host-side assembly (queue scan, request
    concatenation, descriptor setup) of batch k+1 overlaps device
    execution of batch k (double buffering). Per-request results slice
    back out of the coalesced ``QueryJoinResult`` by query-row range
    (``slice_result``) -- bitwise identical to serving the request alone
    (tests/test_serve_batching.py property-tests arbitrary partitions).

    A request wider than ``max_batch`` splits into parts that ride
    separate launches and concatenate on completion; an empty request
    completes immediately. With ``n_slabs > 1`` each coalesced batch
    scatter-gathers across the slab-sharded indexes exactly like
    ``ShardedJoinService``.
    """

    def __init__(self, points: np.ndarray, eps: float, *,
                 index=None, n_slabs: int = 1, return_pairs: bool = False,
                 merge_last_dim: Optional[bool] = None,
                 max_batch: int = 1024, max_wait_ms: float = 2.0,
                 metric: str = "l2", vocab: Optional[int] = None):
        from repro.core import metric as metric_lib
        from repro.core.grid import build_grid_host
        from repro.core.query_join import prepare

        super().__init__(return_pairs)
        metric_lib.check_metric(metric)
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.metric = metric
        self.eps = float(eps)          # METRIC-units threshold
        # full-set canonical form: admission-time query canonicalization
        # (slab grids partition the canonical geometry)
        self._query_canon = None
        if metric != "l2":
            if index is not None:
                raise ValueError(
                    "BatchingJoinService: non-L2 metrics build their own "
                    "index over the canonical geometry; pass raw points")
            self._query_canon = metric_lib.canonicalize(
                points, eps, metric=metric, vocab=vocab)
        t0 = time.perf_counter()
        if n_slabs > 1:
            from repro.core.distributed import partition_points_host

            qc = self._query_canon
            pts = np.asarray(points if qc is None else qc.geom)
            eps_geom = float(eps if qc is None else qc.eps_geom)
            coords, gids, _ = partition_points_host(pts, n_slabs)
            self.slab_gids = []
            self.indexes = []
            self.prepared = []
            for k in range(n_slabs):
                own = gids[k] >= 0
                if not own.any():
                    continue
                sg = gids[k][own]
                self.slab_gids.append(sg)
                canon_k = None
                if qc is not None:
                    canon_k = metric_lib.Canonical(
                        qc.metric, coords[k][own],
                        None if qc.feats is None else qc.feats[sg],
                        qc.n_feat, qc.eps, qc.eps_geom, qc.vocab)
                idx = build_grid_host(coords[k][own], eps_geom)
                self.indexes.append(idx)
                self.prepared.append(
                    prepare(idx, merge_last_dim=merge_last_dim,
                            canon=canon_k))
        else:
            qc = self._query_canon
            if index is not None:
                idx = index
            elif qc is not None:
                idx = build_grid_host(np.asarray(qc.geom),
                                      float(qc.eps_geom))
            else:
                idx = build_grid_host(np.asarray(points), float(eps))
            self.slab_gids = None
            self.indexes = [idx]
            self.prepared = [prepare(idx, merge_last_dim=merge_last_dim,
                                     canon=qc)]
        self.n_slabs = len(self.prepared)
        self.build_s = time.perf_counter() - t0
        self._queue: deque[_Sub] = deque()
        self._queued_rows = 0
        self._inflight: deque[_Inflight] = deque()
        self.n_launches = 0
        self.n_coalesced = 0
        self.rows_launched = 0

    # -- admission ---------------------------------------------------------

    def submit(self, queries: np.ndarray, *,
               eps: Optional[float] = None) -> BatchTicket:
        """Enqueue one request; returns a ticket that completes once every
        part has been served from a coalesced launch (``pump``/``drain``
        advance the pipeline). Does not block."""
        from repro.core.query_join import QueryJoinResult, note_metric_peak

        pj0 = self.prepared[0]
        if self.metric != "l2":
            # canonicalize at ADMISSION (once per request, not per launch/
            # slab): geometry + feature lanes coalesce as one 2-D array
            # and split back at launch into join_async's tuple path
            from repro.core import metric as metric_lib

            qg, qf = metric_lib.canonicalize_queries(self._query_canon,
                                                     queries)
            q = np.asarray(qg, pj0.dtype)
            if qf is not None:
                q = np.concatenate([q, np.asarray(qf, pj0.dtype)], axis=1)
        else:
            q = np.asarray(queries, pj0.dtype)
            if q.ndim != 2 or q.shape[1] != pj0.n_dims:
                raise ValueError(f"queries must be (Q, {pj0.n_dims}), "
                                 f"got {q.shape}")
        eps_key = float(self.eps if eps is None else eps)
        n = q.shape[0]
        if n == 0:
            t = BatchTicket(1, 0)
            t._add_part(0, QueryJoinResult(
                counts=np.zeros(0, np.int32),
                pairs=(np.empty((0, 2), np.int32) if self.return_pairs
                       else None),
                n_offsets=pj0.n_offsets, bucket_rows=0, emit=None,
                candidates_checked=None))
            return t
        parts = [q[i:i + self.max_batch]
                 for i in range(0, n, self.max_batch)]
        ticket = BatchTicket(len(parts), n)
        for i, p in enumerate(parts):
            self._queue.append(_Sub(p, eps_key, ticket, i))
            self._queued_rows += p.shape[0]
        note_metric_peak("batch.queue_depth_peak", len(self._queue))
        return ticket

    # -- pipeline ----------------------------------------------------------

    def _flush_due(self, now: float) -> bool:
        if not self._queue:
            return False
        if self._queued_rows >= self.max_batch:
            return True
        return 1000 * (now - self._queue[0].t_arrival) >= self.max_wait_ms

    def _form_group(self) -> list[_Sub]:
        """Pop the next coalesced batch off the queue: FIFO from the head,
        same epsilon (the threshold is one traced scalar per launch), up
        to ``max_batch`` rows. Skipped entries (different eps, or too wide
        to fit the remaining budget) keep their queue position."""
        head_eps = self._queue[0].eps_key
        group: list[_Sub] = []
        rows = 0
        keep: list[_Sub] = []
        while self._queue:
            sub = self._queue.popleft()
            if (sub.eps_key == head_eps
                    and rows + sub.queries.shape[0] <= self.max_batch):
                group.append(sub)
                rows += sub.queries.shape[0]
            else:
                keep.append(sub)
        self._queue.extendleft(reversed(keep))
        self._queued_rows -= rows
        return group

    def _launch(self, group: list[_Sub]) -> None:
        from repro.core.query_join import coalesce_requests, note_metric

        qcat, bounds = coalesce_requests([s.queries for s in group])
        eps = group[0].eps_key
        single = self.slab_gids is None
        pj0 = self.prepared[0]
        if self.metric != "l2":
            # split the admission-time concatenation back into the
            # (geometry, features) pair join_async consumes directly
            qsend = (qcat[:, :pj0.n_dims],
                     qcat[:, pj0.n_dims:] if pj0.n_feat else None)
        else:
            qsend = qcat
        pendings = [pj.join_async(qsend, eps=eps,
                                  return_pairs=self.return_pairs,
                                  sort_pairs=single)
                    for pj in self.prepared]
        self._inflight.append(_Inflight(pendings, group, bounds))
        self.n_launches += 1
        self.n_coalesced += len(group)
        self.rows_launched += qcat.shape[0]
        note_metric("batch.launches")
        note_metric("batch.coalesced_requests", len(group))
        note_metric("batch.rows", qcat.shape[0])

    def _resolve_oldest(self) -> None:
        from repro.core.query_join import slice_result

        infl = self._inflight.popleft()
        if self.slab_gids is None:
            res = infl.pendings[0].result()
        else:
            res = _merge_slab_results(
                [p.result() for p in infl.pendings],
                self.slab_gids, self.return_pairs)
        for k, sub in enumerate(infl.subs):
            part = slice_result(res, int(infl.bounds[k]),
                                int(infl.bounds[k + 1]))
            sub.ticket._add_part(sub.part, part)
            self.total_neighbors += part.total

    def pump(self) -> None:
        """Advance the pipeline without blocking on admission: launch
        every due batch (oldest waiter past ``max_wait_ms``, or a full
        ``max_batch`` of rows queued), then resolve inflight batches --
        eagerly while their device values are already down (free), and
        forcibly past the double-buffer depth of two, so the NEXT ``pump``
        assembles batch k+1 on the host while batch k still executes."""
        now = time.perf_counter()
        while self._flush_due(now):
            self._launch(self._form_group())
        while self._inflight and (len(self._inflight) > 2
                                  or all(p.ready() for p
                                         in self._inflight[0].pendings)):
            self._resolve_oldest()

    def drain(self) -> None:
        """Flush and resolve everything: queued requests launch regardless
        of due time, all inflight batches resolve. Every ticket issued
        before the call is complete afterwards."""
        while self._queue:
            self._launch(self._form_group())
        while self._inflight:
            self._resolve_oldest()

    # -- service interface -------------------------------------------------

    @property
    def coalesce_factor(self) -> float:
        """Mean requests per fused launch (1.0 = batching is a no-op)."""
        return self.n_coalesced / self.n_launches if self.n_launches else 0.0

    def warmup(self, batch_size: Optional[int] = None) -> int:
        """Compile every executable a steady-state coalescing pattern can
        reach, off the request path: coalesced batches land on ANY pow2
        rung up to ``max_batch`` rows (not just the one bucket a fixed
        request size would hit), so the whole ladder warms -- for every
        slab. ``batch_size`` is accepted for interface parity with the
        other services but deliberately IGNORED for the ladder top: the
        coalescer is free to fill any group to ``max_batch`` rows no
        matter how small individual requests are (and wider requests
        split into ``max_batch``-row parts), so warming less than the
        full ladder would retrace in steady state. Returns the top
        rung's padded row count."""
        from repro.core.query_join import bucket_rows

        top = bucket_rows(self.max_batch)
        s = bucket_rows(1)
        while s <= top:
            if s not in self._warm_buckets:
                for pj in self.prepared:
                    pj.warm(s, return_pairs=self.return_pairs)
                self._warm_buckets.add(s)
            s *= 2
        self._auto_steady()
        return top

    def _answer(self, queries: np.ndarray, eps: Optional[float] = None):
        # synchronous convenience path: admit, drain, slice. Throughput
        # callers should submit()/pump() concurrently instead.
        ticket = self.submit(queries, eps=eps)
        self.drain()
        return ticket.result()


def _metric_workload(args, rng):
    """(points, eps, make_queries) for the service smoke, per metric.

    l2 keeps the uniform box; cosine serves random embeddings at a
    similarity floor; jaccard serves random binary token matrices at a
    Jaccard floor ((Q, V) matrix form, 2-D so the batching coalescer
    accepts it)."""
    if args.metric == "cosine":
        eps = args.eps if -1.0 <= args.eps < 1.0 else 0.9
        if eps != args.eps:
            print(f"[serve] --eps {args.eps} is not a cosine similarity; "
                  f"using {eps}")
        pts = rng.normal(size=(args.points, args.dims))
        return pts, eps, lambda n: rng.normal(size=(n, args.dims))
    if args.metric == "jaccard":
        eps = args.eps if 0.0 < args.eps <= 1.0 else 0.5
        if eps != args.eps:
            print(f"[serve] --eps {args.eps} is not a jaccard threshold; "
                  f"using {eps}")
        vocab = 64
        pts = (rng.random((args.points, vocab)) < 0.1).astype(np.float32)
        return pts, eps, lambda n: (
            rng.random((n, vocab)) < 0.1).astype(np.float32)
    pts = rng.uniform(0, 100, size=(args.points, args.dims))
    return pts, args.eps, lambda n: rng.uniform(0, 100,
                                                size=(n, args.dims))


def serve_selfjoin(args):
    rng = np.random.default_rng(args.seed)
    pts, eps, make_queries = _metric_workload(args, rng)
    if args.batching:
        svc = BatchingJoinService(
            pts, eps, n_slabs=args.slabs,
            return_pairs=args.return_pairs,
            merge_last_dim=not args.no_merge,
            max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
            metric=args.metric)
        print(f"[serve] batching service: {args.points} pts, "
              f"{svc.n_slabs} slab(s), max_batch={svc.max_batch}, "
              f"max_wait={svc.max_wait_ms}ms "
              f"(indexed in {svc.build_s:.3f}s)")
    elif args.slabs > 1:
        svc = ShardedJoinService(pts, eps, args.slabs,
                                 return_pairs=args.return_pairs,
                                 merge_last_dim=not args.no_merge,
                                 metric=args.metric)
        sweep = ("merged-range" if svc.prepared[0].merged else "per-cell")
        cells = sum(int(i.num_cells) for i in svc.indexes)
        print(f"[serve] indexed {args.points} pts across "
              f"{len(svc.prepared)} slabs in {svc.build_s:.3f}s "
              f"(|G|={cells} non-empty cells total, {sweep} sweep)")
    else:
        svc = JoinService(pts, eps, return_pairs=args.return_pairs,
                          merge_last_dim=not args.no_merge,
                          metric=args.metric)
        sweep = "merged-range" if svc.prepared.merged else "per-cell"
        print(f"[serve] indexed {args.points} pts in {svc.build_s:.3f}s "
              f"(metric={args.metric}, |G|={int(svc.index.num_cells)} "
              f"non-empty cells, C={svc.prepared.c}, "
              f"{svc.prepared.n_offsets} {sweep} stencil offsets)")
    t0 = time.perf_counter()
    qp = svc.warmup(args.request_batch)   # auto-marks steady (warns)
    print(f"[serve] warmed bucket {qp} rows in "
          f"{time.perf_counter()-t0:.3f}s (compile, off the request path)")
    if args.batching:
        # throughput path: admit everything through the queue, pump, drain
        tickets = [svc.submit(make_queries(args.request_batch))
                   for _ in range(args.requests)]
        t0 = time.perf_counter()
        svc.pump()
        svc.drain()
        wall = time.perf_counter() - t0
        svc.latencies_ms = [t.latency_ms() for t in tickets]
        svc.requests = len(tickets)
        p50, p99 = svc.percentiles()
        print(f"[serve] {args.requests} requests x {args.request_batch} "
              f"queries coalesced into {svc.n_launches} launches "
              f"(coalesce factor {svc.coalesce_factor:.1f}): "
              f"p50 {p50:.1f}ms p99 {p99:.1f}ms "
              f"{len(tickets) / wall:.1f} req/s")
    else:
        if args.reindex and not type(svc) is JoinService:
            raise SystemExit("--reindex needs the single-index service "
                             "(no --slabs/--batching)")
        for r in range(args.requests):
            if args.reindex and r == args.requests // 2:
                # mid-load re-index: background device build + plan, then
                # one atomic snapshot swap. Same point set (permuted), so
                # bucket classes match and every warmed executable is
                # reused -- the no-retrace gate below must stay green.
                svc.reindex(rng.permutation(pts), wait=True)
                t = svc.reindex_timings
                print(f"[serve] reindexed {args.points} pts mid-load: "
                      f"build {t['build_s']*1000:.1f}ms "
                      f"plan {t['plan_s']*1000:.1f}ms "
                      f"warm {t['warm_s']*1000:.1f}ms "
                      f"swap {t['swap_s']*1e6:.0f}us "
                      f"(snapshot swaps: {svc.swaps})")
            q = make_queries(args.request_batch)
            svc.query(q)
        p50, p99 = svc.percentiles()
        print(f"[serve] {args.requests} requests x {args.request_batch} "
              f"queries{' (+pairs)' if args.return_pairs else ''}: "
              f"p50 {p50:.1f}ms p99 {p99:.1f}ms "
              f"{svc.requests_per_sec():.1f} req/s "
              f"({svc.total_neighbors} neighbors found)")
    svc.assert_no_retrace()   # regression gate: steady state never compiles
    print("[serve] no-retrace check passed: steady-state requests hit "
          "cached executables only")
    return p50


def serve_lm(args):
    cfg = get_config(args.arch, reduced=args.reduced)
    model = LMModel(cfg, None)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    B, S = args.request_batch, args.prompt_len
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    caches = model.init_caches(B, S + args.tokens)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(2,))
    t0 = time.time()
    logits, caches = prefill(params, batch, caches)
    logits.block_until_ready()
    print(f"[serve] prefill {B}x{S} in {time.time()-t0:.3f}s")
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lat = []
    out = [tok]
    for _ in range(args.tokens):
        t0 = time.time()
        logits, caches = decode(params, tok, caches)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        tok.block_until_ready()
        lat.append(time.time() - t0)
        out.append(tok)
    lat_ms = 1000 * np.asarray(lat[1:])  # drop compile step
    print(f"[serve] decoded {args.tokens} tokens: "
          f"p50 {np.percentile(lat_ms, 50):.1f}ms/token")
    return float(np.median(lat_ms))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="selfjoin")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # selfjoin service
    ap.add_argument("--points", type=int, default=20000)
    ap.add_argument("--dims", type=int, default=4)
    ap.add_argument("--eps", type=float, default=2.0)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--request-batch", type=int, default=256)
    ap.add_argument("--return-pairs", action="store_true",
                    help="materialize neighbor pairs per request, not "
                         "just counts")
    ap.add_argument("--metric", default="l2",
                    choices=("l2", "cosine", "jaccard"),
                    help="similarity metric for the join service "
                         "(DESIGN.md S12); --eps is then the metric-units "
                         "threshold (minimum cosine / Jaccard similarity)")
    ap.add_argument("--no-merge", action="store_true",
                    help="serve through the per-cell 3^n stencil instead "
                         "of the merged-range 3^(n-1) sweep (parity "
                         "oracle, DESIGN.md S7)")
    ap.add_argument("--slabs", type=int, default=1,
                    help="shard the index into N dim-0 slabs and serve "
                         "requests scatter-gather across them "
                         "(ShardedJoinService, DESIGN.md S3)")
    ap.add_argument("--reindex", action="store_true",
                    help="re-index a permutation of the point set halfway "
                         "through the request loop (background device "
                         "build + atomic snapshot swap; the no-retrace "
                         "gate must stay green across it)")
    ap.add_argument("--batching", action="store_true",
                    help="serve through the continuous-batching admission "
                         "queue (BatchingJoinService, DESIGN.md S8); "
                         "composes with --slabs")
    ap.add_argument("--max-batch", type=int, default=1024,
                    help="coalesced launch budget in query rows")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="admission-queue flush deadline for the oldest "
                         "waiting request")
    # lm service
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)
    if args.arch == "selfjoin":
        return serve_selfjoin(args)
    return serve_lm(args)


if __name__ == "__main__":
    main()
