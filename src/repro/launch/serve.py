"""Serving driver: a persistent external-query epsilon-join service over a
grid-indexed set, or LM token decoding -- selected by --arch.

Epsilon-join service (the paper's operator in the index-once/query-many
regime, DESIGN.md S5):
    python -m repro.launch.serve --arch selfjoin --points 20000 --dims 4 \
        --eps 1.0 --requests 8 --request-batch 256
``JoinService`` builds the grid index ONCE (paper SIV) and prepares the
fused external-query join path (core/query_join.py): offset tables and the
padded points copy are computed at startup, request batches are padded to
static bucket shapes, and every per-request computation dispatches into
module-level jitted functions whose XLA executables are cached per bucket --
so steady-state requests pay pure execution, never trace/compile (the bug
the original ``range_query``-per-request loop had). The driver warms the
request bucket, then reports p50/p99 latency and requests/sec over the
steady-state window, and FAILS (exit code) if any steady-state request
grew a compilation cache -- the no-retrace regression gate `make verify`
runs.

LM decode service:
    python -m repro.launch.serve --arch smoke-lm --reduced --tokens 32
Prefills a prompt batch and decodes tokens autoregressively with the KV
cache, reporting per-token latency.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.models.lm import LMModel


class _JoinServiceBase:
    """Serving-side bookkeeping shared by the single-index and the
    slab-sharded services: steady-state latency percentiles that reflect
    execution rather than trace time, and a compilation-cache watchdog
    (``assert_no_retrace``) so a regression back to per-request tracing
    can never pass silently."""

    def __init__(self, return_pairs: bool = False):
        self.return_pairs = return_pairs
        self.latencies_ms: list[float] = []   # steady-state only
        self.total_neighbors = 0
        self.requests = 0
        self._warm_buckets: set[int] = set()
        self._cache_mark: Optional[dict] = None

    def _answer(self, queries: np.ndarray):
        raise NotImplementedError

    def mark_steady(self) -> None:
        """Snapshot compilation caches; later requests must not grow them."""
        from repro.core.query_join import executable_cache_stats

        self._cache_mark = executable_cache_stats()

    def query(self, queries: np.ndarray):
        """Answer one request; records steady-state latency."""
        t0 = time.perf_counter()
        res = self._answer(queries)
        self.latencies_ms.append(1000 * (time.perf_counter() - t0))
        self.requests += 1
        self.total_neighbors += res.total
        return res

    def percentiles(self) -> tuple[float, float]:
        lat = np.asarray(self.latencies_ms)
        return (float(np.percentile(lat, 50)), float(np.percentile(lat, 99)))

    def requests_per_sec(self) -> float:
        total_s = sum(self.latencies_ms) / 1000
        return self.requests / total_s if total_s > 0 else float("inf")

    def assert_no_retrace(self) -> None:
        """Raise if any request since ``mark_steady`` traced or compiled.

        The device-emit scatter is exempt: its result-buffer capacity is a
        static shape bucketed to powers of two (with a floor), so a
        pair-serving service legitimately compiles O(log max_result) emit
        executables on demand as larger results first appear -- warmup
        cannot know result sizes in advance. The request-path functions
        (window descriptors, fused sweep) must stay frozen; those are
        what the per-request re-tracing bug burned."""
        from repro.core.query_join import executable_cache_stats

        def freeze(stats: dict) -> dict:
            out = {k: v for k, v in stats.items()
                   if k not in ("emit_pairs_device", "trace_events")}
            out["trace_events"] = {
                k: v for k, v in stats["trace_events"].items()
                if k != "emit_pairs_device"}
            return out

        now = executable_cache_stats()
        if (self._cache_mark is not None
                and freeze(now) != freeze(self._cache_mark)):
            raise RuntimeError(
                "serve path recompiled during steady state: "
                f"{freeze(self._cache_mark)} -> {freeze(now)}")


class JoinService(_JoinServiceBase):
    """Persistent epsilon-join service: index once, answer many requests.

    Wraps ``core.query_join.prepare`` with the serving-side bookkeeping of
    ``_JoinServiceBase`` plus bucket warmup (compile off the request
    path).
    """

    def __init__(self, points: np.ndarray, eps: float, *,
                 index=None, return_pairs: bool = False,
                 merge_last_dim: Optional[bool] = None):
        from repro.core.grid import build_grid_host
        from repro.core.query_join import prepare

        super().__init__(return_pairs)
        t0 = time.perf_counter()
        self.index = index if index is not None else build_grid_host(
            np.asarray(points), float(eps))
        self.prepared = prepare(self.index, merge_last_dim=merge_last_dim)
        self.build_s = time.perf_counter() - t0

    def warmup(self, batch_size: int) -> int:
        """Compile the executables serving ``batch_size``-query requests
        (off the request path): the request bucket AND, on a skewed index,
        every (capacity class, bucket size) launch a steady-state request
        mix can need (``PreparedJoin.warm``). Returns the request bucket's
        padded row count."""
        from repro.core.query_join import bucket_rows

        qp = bucket_rows(batch_size)
        if qp not in self._warm_buckets:
            self.prepared.warm(batch_size, return_pairs=self.return_pairs)
            self._warm_buckets.add(qp)
        return qp

    def _answer(self, queries: np.ndarray):
        return self.prepared.join(queries, return_pairs=self.return_pairs)


class ShardedJoinService(_JoinServiceBase):
    """Slab-sharded epsilon-join service (DESIGN.md S3 serving mode).

    The indexed set partitions into equal-count dim-0 slabs (the same
    partitioner as the distributed self-join); each slab holds its OWN
    grid index and ``PreparedJoin`` -- index once per slab. A request fans
    out to every slab (an external query near a slab boundary has
    neighbors on both sides), per-slab counts sum, and pair point-ids
    remap through the slab's global-id table, so the answer is identical
    to the single-index service (asserted in tests/test_query_join.py).
    No ownership rule is needed: every indexed point lives in exactly one
    slab, so no pair can be found twice.

    Warmup compiles every slab's executables off the request path; the
    no-retrace gate is inherited unchanged (the executable caches are
    module-level, shared across slabs -- a steady-state request may not
    grow them no matter which slab it lands on).
    """

    def __init__(self, points: np.ndarray, eps: float, n_slabs: int, *,
                 return_pairs: bool = False,
                 merge_last_dim: Optional[bool] = None):
        from repro.core.distributed import partition_points_host
        from repro.core.grid import build_grid_host
        from repro.core.query_join import prepare

        super().__init__(return_pairs)
        pts = np.asarray(points)
        t0 = time.perf_counter()
        coords, gids, _ = partition_points_host(pts, n_slabs)
        self.n_slabs = n_slabs
        self.eps = float(eps)
        self.slab_gids: list[np.ndarray] = []
        self.prepared: list = []
        self.indexes: list = []
        for k in range(n_slabs):
            own = gids[k] >= 0
            if not own.any():
                continue                      # empty slab: nothing to index
            self.slab_gids.append(gids[k][own])
            idx = build_grid_host(coords[k][own], float(eps))
            self.indexes.append(idx)
            self.prepared.append(prepare(idx, merge_last_dim=merge_last_dim))
        self.build_s = time.perf_counter() - t0

    def warmup(self, batch_size: int) -> int:
        from repro.core.query_join import bucket_rows

        qp = bucket_rows(batch_size)
        if qp not in self._warm_buckets:
            for pj in self.prepared:
                pj.warm(batch_size, return_pairs=self.return_pairs)
            self._warm_buckets.add(qp)
        return qp

    def _answer(self, queries: np.ndarray):
        from repro.core.query_join import QueryJoinResult

        counts = None
        chunks = []
        bucket = 0
        n_off = 0
        emit = None
        for pj, sg in zip(self.prepared, self.slab_gids):
            res = pj.join(queries, return_pairs=self.return_pairs,
                          sort_pairs=False)
            counts = res.counts if counts is None else counts + res.counts
            bucket, n_off, emit = res.bucket_rows, res.n_offsets, res.emit
            if self.return_pairs and res.pairs.shape[0]:
                p = res.pairs.copy()
                p[:, 1] = sg[p[:, 1]]         # slab point id -> global id
                chunks.append(p)
        pairs = None
        if self.return_pairs:
            pairs = (np.concatenate(chunks, axis=0) if chunks
                     else np.empty((0, 2), np.int32))
            pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
        return QueryJoinResult(
            counts=counts, pairs=pairs, n_offsets=n_off,
            bucket_rows=bucket, emit=emit,
            candidates_checked=None)


def serve_selfjoin(args):
    rng = np.random.default_rng(args.seed)
    pts = rng.uniform(0, 100, size=(args.points, args.dims))
    if args.slabs > 1:
        svc = ShardedJoinService(pts, args.eps, args.slabs,
                                 return_pairs=args.return_pairs,
                                 merge_last_dim=not args.no_merge)
        sweep = ("merged-range" if svc.prepared[0].merged else "per-cell")
        cells = sum(int(i.num_cells) for i in svc.indexes)
        print(f"[serve] indexed {args.points} pts across "
              f"{len(svc.prepared)} slabs in {svc.build_s:.3f}s "
              f"(|G|={cells} non-empty cells total, {sweep} sweep)")
    else:
        svc = JoinService(pts, args.eps, return_pairs=args.return_pairs,
                          merge_last_dim=not args.no_merge)
        sweep = "merged-range" if svc.prepared.merged else "per-cell"
        print(f"[serve] indexed {args.points} pts in {svc.build_s:.3f}s "
              f"(|G|={int(svc.index.num_cells)} non-empty cells, "
              f"C={svc.prepared.c}, {svc.prepared.n_offsets} {sweep} "
              f"stencil offsets)")
    t0 = time.perf_counter()
    qp = svc.warmup(args.request_batch)
    print(f"[serve] warmed bucket {qp} rows in "
          f"{time.perf_counter()-t0:.3f}s (compile, off the request path)")
    svc.mark_steady()
    for r in range(args.requests):
        q = rng.uniform(0, 100, size=(args.request_batch, args.dims))
        svc.query(q)
    p50, p99 = svc.percentiles()
    print(f"[serve] {args.requests} requests x {args.request_batch} queries"
          f"{' (+pairs)' if args.return_pairs else ''}: "
          f"p50 {p50:.1f}ms p99 {p99:.1f}ms "
          f"{svc.requests_per_sec():.1f} req/s "
          f"({svc.total_neighbors} neighbors found)")
    svc.assert_no_retrace()   # regression gate: steady state never compiles
    print("[serve] no-retrace check passed: steady-state requests hit "
          "cached executables only")
    return p50


def serve_lm(args):
    cfg = get_config(args.arch, reduced=args.reduced)
    model = LMModel(cfg, None)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    B, S = args.request_batch, args.prompt_len
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    caches = model.init_caches(B, S + args.tokens)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(2,))
    t0 = time.time()
    logits, caches = prefill(params, batch, caches)
    logits.block_until_ready()
    print(f"[serve] prefill {B}x{S} in {time.time()-t0:.3f}s")
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lat = []
    out = [tok]
    for _ in range(args.tokens):
        t0 = time.time()
        logits, caches = decode(params, tok, caches)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        tok.block_until_ready()
        lat.append(time.time() - t0)
        out.append(tok)
    lat_ms = 1000 * np.asarray(lat[1:])  # drop compile step
    print(f"[serve] decoded {args.tokens} tokens: "
          f"p50 {np.percentile(lat_ms, 50):.1f}ms/token")
    return float(np.median(lat_ms))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="selfjoin")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # selfjoin service
    ap.add_argument("--points", type=int, default=20000)
    ap.add_argument("--dims", type=int, default=4)
    ap.add_argument("--eps", type=float, default=2.0)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--request-batch", type=int, default=256)
    ap.add_argument("--return-pairs", action="store_true",
                    help="materialize neighbor pairs per request, not "
                         "just counts")
    ap.add_argument("--no-merge", action="store_true",
                    help="serve through the per-cell 3^n stencil instead "
                         "of the merged-range 3^(n-1) sweep (parity "
                         "oracle, DESIGN.md S7)")
    ap.add_argument("--slabs", type=int, default=1,
                    help="shard the index into N dim-0 slabs and serve "
                         "requests scatter-gather across them "
                         "(ShardedJoinService, DESIGN.md S3)")
    # lm service
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)
    if args.arch == "selfjoin":
        return serve_selfjoin(args)
    return serve_lm(args)


if __name__ == "__main__":
    main()
