"""Load generator for the epsilon-join serving path (DESIGN.md S8).

Drives a join service -- per-request ``JoinService`` or continuous-batching
``BatchingJoinService``, single-index or slab-sharded -- with a synthetic
request stream and measures the latency/throughput behaviour that a single
fixed-size request loop cannot see:

- **Open loop** (``run_open_loop``): requests arrive on a Poisson process
  at a target offered rate, independent of service completion. Latency is
  measured from the SCHEDULED arrival time, not the submit call, so queue
  delay under overload is charged to the service (coordinated-omission
  safe: a generator that waits for the service before "arriving" hides
  exactly the latencies that matter). Sweeping the offered rate maps the
  latency/throughput frontier recorded in BENCH_selfjoin.json's "load"
  section.
- **Closed loop** (``run_closed_loop``): a fixed window of outstanding
  requests, next admitted when one completes -- measures service capacity
  (max sustained req/s) without an arrival model.

The request mix (``RequestMix``) draws per-request sizes and epsilon
thresholds from weighted sets, exercising the pow2 bucket ladder and the
traced-eps path exactly as a population of independent callers would.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class RequestMix:
    """Weighted request-size / epsilon population for a synthetic load.

    ``eps_values`` must all be <= the service's build epsilon (the stencil
    only covers the build radius); sizes may exceed the batching service's
    ``max_batch`` (such requests split into parts on admission).
    """

    sizes: tuple = (32, 64, 256)
    size_weights: Optional[tuple] = None
    eps_values: tuple = ()         # empty: always the service build eps
    eps_weights: Optional[tuple] = None
    lo: float = 0.0
    hi: float = 100.0

    def draw(self, rng: np.random.Generator, dims: int):
        n = int(rng.choice(self.sizes, p=self.size_weights))
        eps = (float(rng.choice(self.eps_values, p=self.eps_weights))
               if self.eps_values else None)
        q = rng.uniform(self.lo, self.hi, size=(n, dims))
        return q, eps


def make_request_stream(n_requests: int, mix: RequestMix, dims: int,
                        seed: int = 0) -> list:
    """Pre-draw the whole request stream so generation cost never sits on
    the measured path. Returns [(queries, eps_or_None), ...]."""
    rng = np.random.default_rng(seed)
    return [mix.draw(rng, dims) for _ in range(n_requests)]


@dataclass
class LoadReport:
    """One point on the latency/throughput frontier."""

    mode: str
    offered_rps: Optional[float]
    achieved_rps: float
    p50_ms: float
    p99_ms: float
    mean_ms: float
    n_requests: int
    total_queries: int
    wall_s: float
    coalesce_factor: Optional[float] = None
    latencies_ms: list = field(default_factory=list, repr=False)

    def to_dict(self) -> dict:
        return {
            "mode": self.mode,
            "offered_rps": self.offered_rps,
            "achieved_rps": round(self.achieved_rps, 2),
            "p50_ms": round(self.p50_ms, 3),
            "p99_ms": round(self.p99_ms, 3),
            "mean_ms": round(self.mean_ms, 3),
            "n_requests": self.n_requests,
            "total_queries": self.total_queries,
            "wall_s": round(self.wall_s, 3),
            "coalesce_factor": (None if self.coalesce_factor is None
                                else round(self.coalesce_factor, 2)),
        }


def _report(mode, offered, lat_ms, wall_s, stream, svc) -> LoadReport:
    lat = np.asarray(lat_ms)
    return LoadReport(
        mode=mode, offered_rps=offered,
        achieved_rps=len(lat) / wall_s if wall_s > 0 else float("inf"),
        p50_ms=float(np.percentile(lat, 50)),
        p99_ms=float(np.percentile(lat, 99)),
        mean_ms=float(lat.mean()),
        n_requests=len(lat),
        total_queries=sum(q.shape[0] for q, _ in stream),
        wall_s=wall_s,
        coalesce_factor=getattr(svc, "coalesce_factor", None),
        latencies_ms=[float(x) for x in lat])


def poisson_schedule(n_requests: int, rate_rps: float,
                     seed: int = 0) -> np.ndarray:
    """Scheduled arrival offsets (seconds from start) of a Poisson process
    at ``rate_rps``: i.i.d. exponential inter-arrival gaps."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_rps, size=n_requests))


def run_open_loop(svc, stream: list, rate_rps: float, *,
                  seed: int = 0) -> LoadReport:
    """Offer ``stream`` at ``rate_rps`` on a Poisson arrival process.

    A batching service (anything with ``submit``) is driven
    asynchronously: arrivals enter the admission queue the moment they are
    due and ``pump`` advances the launch/resolve pipeline between
    arrivals. A synchronous service serves arrivals in order; if it falls
    behind schedule the backlog delay is charged to every queued request
    (latency counts from the scheduled arrival either way).
    """
    sched = poisson_schedule(len(stream), rate_rps, seed)
    if hasattr(svc, "submit"):
        t0 = time.perf_counter()
        tickets = []
        i = 0
        while i < len(stream):
            now = time.perf_counter() - t0
            while i < len(stream) and sched[i] <= now:
                q, eps = stream[i]
                tickets.append((svc.submit(q, eps=eps), sched[i]))
                i += 1
            svc.pump()
            if i < len(stream):
                now = time.perf_counter() - t0
                if sched[i] > now:
                    time.sleep(min(sched[i] - now, 5e-4))
        svc.drain()
        wall = time.perf_counter() - t0
        lat = [1000 * ((t.t_done - t0) - s) for t, s in tickets]
    else:
        t0 = time.perf_counter()
        lat = []
        for (q, eps), s in zip(stream, sched):
            now = time.perf_counter() - t0
            if now < s:
                time.sleep(s - now)
            svc.query(q, eps=eps)
            lat.append(1000 * ((time.perf_counter() - t0) - s))
        wall = time.perf_counter() - t0
    return _report("open", rate_rps, lat, wall, stream, svc)


def run_closed_loop(svc, stream: list, *,
                    concurrency: int = 1) -> LoadReport:
    """Serve ``stream`` with a fixed window of ``concurrency`` outstanding
    requests -- the service's capacity measurement (no arrival model, so
    no queue delay: latency is pure service time at this concurrency)."""
    if hasattr(svc, "submit"):
        t0 = time.perf_counter()
        tickets = []
        for base in range(0, len(stream), concurrency):
            window = stream[base:base + concurrency]
            ts = [svc.submit(q, eps=eps) for q, eps in window]
            svc.pump()
            svc.drain()
            tickets.extend(ts)
        wall = time.perf_counter() - t0
        lat = [t.latency_ms() for t in tickets]
    else:
        t0 = time.perf_counter()
        lat = []
        for q, eps in stream:
            s0 = time.perf_counter()
            svc.query(q, eps=eps)
            lat.append(1000 * (time.perf_counter() - s0))
        wall = time.perf_counter() - t0
    return _report("closed", None, lat, wall, stream, svc)


def frontier_sweep(svc, stream: list, rates: list, *,
                   seed: int = 0) -> list:
    """Open-loop sweep over offered rates: one LoadReport per rate (the
    latency/throughput frontier). The same stream replays at every rate so
    points differ only in arrival schedule."""
    return [run_open_loop(svc, stream, r, seed=seed) for r in rates]


def main(argv=None):
    from repro.launch.serve import (BatchingJoinService, JoinService,
                                    ShardedJoinService)

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--points", type=int, default=20000)
    ap.add_argument("--dims", type=int, default=4)
    ap.add_argument("--eps", type=float, default=2.0)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rate", type=float, default=None,
                    help="open-loop offered req/s (omit for closed loop)")
    ap.add_argument("--conc", type=int, default=1,
                    help="closed-loop outstanding-request window")
    ap.add_argument("--sizes", type=int, nargs="+", default=[32, 64, 256])
    ap.add_argument("--eps-mix", type=float, nargs="+", default=[],
                    help="request eps values drawn uniformly (all <= "
                         "--eps); empty serves every request at --eps")
    ap.add_argument("--batching", action="store_true")
    ap.add_argument("--max-batch", type=int, default=1024)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--slabs", type=int, default=1)
    ap.add_argument("--return-pairs", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(args.seed)
    pts = rng.uniform(0, 100, size=(args.points, args.dims))
    if args.batching:
        svc = BatchingJoinService(
            pts, args.eps, n_slabs=args.slabs,
            return_pairs=args.return_pairs,
            max_batch=args.max_batch, max_wait_ms=args.max_wait_ms)
        svc.warmup()
    elif args.slabs > 1:
        svc = ShardedJoinService(pts, args.eps, args.slabs,
                                 return_pairs=args.return_pairs)
        svc.warmup(max(args.sizes))
    else:
        svc = JoinService(pts, args.eps, return_pairs=args.return_pairs)
        svc.warmup(max(args.sizes))
    mix = RequestMix(sizes=tuple(args.sizes),
                     eps_values=tuple(args.eps_mix))
    stream = make_request_stream(args.requests, mix, args.dims,
                                 seed=args.seed + 1)
    if args.rate is not None:
        rep = run_open_loop(svc, stream, args.rate, seed=args.seed + 2)
    else:
        rep = run_closed_loop(svc, stream, concurrency=args.conc)
    svc.assert_no_retrace()
    d = rep.to_dict()
    print("[loadgen] " + " ".join(f"{k}={v}" for k, v in d.items()))
    return rep


if __name__ == "__main__":
    main()
