"""Production mesh construction.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and dryrun.py
must set XLA_FLAGS before that happens).

LM meshes:    (16, 16) -> ('data', 'model');  multi-pod (2, 16, 16) ->
              ('pod', 'data', 'model'). Batch shards over ('pod','data'),
              FSDP over 'data', tensor/expert parallelism over 'model'
              (per-arch fallbacks in models/lm.py choose_layout).
Self-join:    the paper's workload wants a 1-D spatial slab axis x an
              offset-parallel axis, so its mesh flattens pod x data into
              'slab': (16, 16) single-pod, (32, 16) multi-pod.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types (Auto/Explicit sharding modes)
    from jax.sharding import AxisType
except ImportError:  # older jax (e.g. 0.4.x): every axis is Auto already
    AxisType = None


def _mk(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh_compat(shape, axes):
    """Version-portable mesh constructor (tests and subprocess drivers use
    this instead of touching jax.sharding.AxisType directly)."""
    return _mk(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_selfjoin_mesh(*, multi_pod: bool = False):
    shape = (32, 16) if multi_pod else (16, 16)
    return _mk(shape, ("slab", "model"))


def make_slab_mesh(n_slabs: int):
    """1-D slab mesh over the first ``n_slabs`` local devices -- the mesh
    shape of the distributed self-join (core/distributed.py) and the
    distributed bench/CI smokes. Unlike ``jax.make_mesh`` this accepts a
    strict subset of the devices, so a 2-slab smoke runs on any host with
    ``--xla_force_host_platform_device_count=2`` or more."""
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    if n_slabs > len(devs):
        raise ValueError(
            f"make_slab_mesh({n_slabs}) needs {n_slabs} devices, have "
            f"{len(devs)} (set --xla_force_host_platform_device_count)")
    return Mesh(np.asarray(devs[:n_slabs]), ("slab",))


def make_smoke_mesh(n_devices: int = 1):
    """Tiny mesh over whatever devices exist (tests / CPU examples)."""
    n = min(n_devices, len(jax.devices()))
    model = 2 if n % 2 == 0 else 1
    return _mk((n // model, model), ("data", "model"))
