import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init). 512 host-platform placeholder devices back both the
single-pod (16x16=256) and multi-pod (2x16x16=512) production meshes.

Per cell:
    lowered  = jit(step, in_shardings, out_shardings).lower(*ShapeDtypeStructs)
    compiled = lowered.compile()
    record memory_analysis(), cost_analysis(), collective schedule (parsed
    from optimized HLO) -> roofline terms (launch/roofline.py).

Usage:
    python -m repro.launch.dryrun --arch smoke-lm --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json
    python -m repro.launch.dryrun --arch selfjoin --shape syn6d2m --mesh single
"""
import argparse
import dataclasses
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ShapeCell, all_cells, cell_plan, get_config
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh, make_selfjoin_mesh
from repro.models.lm import LMModel, choose_layout
from repro.train.optimizer import AdamWConfig, adamw_init, opt_state_specs
from repro.train.steps import make_train_step


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def batch_struct(cfg, cell: ShapeCell):
    B, S = cell.global_batch, cell.seq_len
    if cfg.input_kind == "embeddings":
        return {
            "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }


def batch_specs(cfg, layout):
    b = layout.batch_axes
    if cfg.input_kind == "embeddings":
        return {"embeds": P(b, None, None), "labels": P(b, None)}
    return {"tokens": P(b, None), "labels": P(b, None)}


def opt_config_for(cfg) -> AdamWConfig:
    """Factored v + bf16 m for the 300B+ MoEs (state compression); plain
    AdamW elsewhere. Recorded per arch in EXPERIMENTS.md SDry-run."""
    if cfg.param_count() > 100e9:
        return AdamWConfig(factored=True, m_dtype="bfloat16")
    return AdamWConfig()


def lower_lm_cell(arch: str, cell: ShapeCell, mesh, cfg=None):
    cfg = cfg if cfg is not None else get_config(arch)
    model = LMModel(cfg, mesh)
    pshapes, pspecs = model.abstract_params()
    layout = choose_layout(cfg, mesh, cell.global_batch, cell.seq_len)
    bstruct = batch_struct(cfg, cell)
    bspecs = batch_specs(cfg, layout)

    with mesh:
        if cell.kind == "train":
            ocfg = opt_config_for(cfg)
            oshapes = jax.eval_shape(partial(adamw_init, cfg=ocfg), pshapes)
            ospecs = opt_state_specs(pspecs, ocfg, pshapes)
            step = make_train_step(model, ocfg, param_specs=pspecs)
            fn = jax.jit(
                step,
                in_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs),
                              _ns(mesh, bspecs)),
                out_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs), None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(pshapes, oshapes, bstruct)
        elif cell.kind == "prefill":
            if cfg.encoder_only:
                fn = jax.jit(
                    lambda p, b: model.encode(p, b, layout),
                    in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)),
                )
                lowered = fn.lower(pshapes, bstruct)
            else:
                cshapes = jax.eval_shape(
                    lambda: model.init_caches(cell.global_batch, cell.seq_len))
                cspecs = model.cache_specs(layout)
                fn = jax.jit(
                    lambda p, b, c: model.prefill(p, b, c, layout),
                    in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs),
                                  _ns(mesh, cspecs)),
                    out_shardings=(None, _ns(mesh, cspecs)),
                    donate_argnums=(2,),
                )
                lowered = fn.lower(pshapes, bstruct, cshapes)
        elif cell.kind == "decode":
            cshapes = jax.eval_shape(
                lambda: model.init_caches(cell.global_batch, cell.seq_len))
            cspecs = model.cache_specs(layout)
            tshape = jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32)
            fn = jax.jit(
                lambda p, t, c: model.decode_step(p, t, c, layout),
                in_shardings=(_ns(mesh, pspecs),
                              NamedSharding(mesh, P(layout.batch_axes)),
                              _ns(mesh, cspecs)),
                out_shardings=(None, _ns(mesh, cspecs)),
                donate_argnums=(2,),
            )
            lowered = fn.lower(pshapes, tshape, cshapes)
        else:
            raise ValueError(cell.kind)
    return cfg, layout, lowered


# ---------------------------------------------------------------------------
# Cost probes.
#
# XLA's HloCostAnalysis counts while-loop bodies ONCE (verified on this
# container: a 24-layer scan reports the same flops as its body). Exact
# FLOP/byte totals therefore come from loop-free lowerings: the same cell is
# lowered UNROLLED (cfg.unroll_scans) at L1 = pattern and L2 = 2 x pattern
# layers (pattern = lcm of slstm_every / shared_attn_every so heterogeneous
# stacks stay self-similar), which is exact at those sizes, and extended to
# the full depth with the exactly-linear-in-layers model
#     total(L) = base + (L / pattern) * per_pattern.
# No compile is needed -- lowered.cost_analysis() suffices -- and no mesh:
# FLOPs/bytes are partition-independent (reported per-chip by dividing).
#
# Collectives only exist post-SPMD, so they are extrapolated the same way
# from two COMPILED small-depth lowerings on the real mesh (cheap at L<=16),
# keyed by (kind, bytes, group): count(L) = base + (L/pattern) * per_pattern.
# The full-depth compile (stage A) stays as the shardability/memory proof.
# ---------------------------------------------------------------------------

def _cost_dict(cost) -> dict:
    """Normalize cost_analysis(): dict on current jax, [dict] on 0.4.x."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}


def _pattern_len(cfg):
    pat = 1
    if cfg.slstm_every:
        pat = max(pat, cfg.slstm_every)
    if cfg.shared_attn_every:
        pat = max(pat, cfg.shared_attn_every)
    return pat


def _probe_cfg(cfg, n_layers, unroll):
    return dataclasses.replace(cfg, n_layers=n_layers, unroll_scans=unroll)


def _lower_probe(cfg, cell: ShapeCell):
    """Mesh-free lowering of one cell at reduced depth; returns cost dict."""
    model = LMModel(cfg, mesh=None)
    pshapes, _ = model.abstract_params()
    bstruct = batch_struct(cfg, cell)
    if cell.kind == "train":
        ocfg = opt_config_for(cfg)
        oshapes = jax.eval_shape(partial(adamw_init, cfg=ocfg), pshapes)
        step = make_train_step(model, ocfg)
        lowered = jax.jit(step).lower(pshapes, oshapes, bstruct)
    elif cell.kind == "prefill":
        if cfg.encoder_only:
            lowered = jax.jit(model.encode).lower(pshapes, bstruct)
        else:
            cshapes = jax.eval_shape(
                lambda: model.init_caches(cell.global_batch, cell.seq_len))
            lowered = jax.jit(model.prefill).lower(pshapes, bstruct, cshapes)
    else:
        cshapes = jax.eval_shape(
            lambda: model.init_caches(cell.global_batch, cell.seq_len))
        tshape = jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32)
        lowered = jax.jit(model.decode_step).lower(pshapes, tshape, cshapes)
    cost = _cost_dict(lowered.cost_analysis())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0))}


def cost_probe(arch: str, cell: ShapeCell) -> dict:
    """Exact unrolled two-point probe -> whole-program flops/bytes."""
    cfg = get_config(arch)
    pat = _pattern_len(cfg)
    l1, l2 = pat, 2 * pat
    c1 = _lower_probe(_probe_cfg(cfg, l1, True), cell)
    c2 = _lower_probe(_probe_cfg(cfg, l2, True), cell)
    k = (cfg.n_layers - l1) / pat
    out = {}
    for key in ("flops", "bytes"):
        per_pat = c2[key] - c1[key]
        out[key + "_total"] = c1[key] + k * per_pat
        out[key + "_probe"] = (c1[key], c2[key])
    out["probe_layers"] = (l1, l2)
    return out


def _coll_key(c):
    return (c["kind"], c["bytes_result"], c["group_size"], c["cross_pod"])


def _coll_counts(lowered):
    compiled = lowered.compile()
    colls = roofline.parse_collectives(compiled.as_text())
    counts = {}
    for c in colls:
        counts[_coll_key(dataclasses.asdict(c))] = counts.get(
            _coll_key(dataclasses.asdict(c)), 0) + 1
    cost = _cost_dict(compiled.cost_analysis())
    fused = {"flops": float(cost.get("flops", 0.0)),
             "bytes": float(cost.get("bytes accessed", 0.0))}
    return counts, fused


def collective_probe(arch: str, cell: ShapeCell, mesh) -> dict:
    """Two-point compiled probe -> extrapolated collective schedule."""
    cfg = get_config(arch)
    pat = _pattern_len(cfg)
    l1, l2 = pat, 2 * pat
    counts = []
    fused = []
    for lk in (l1, l2):
        cfgk = _probe_cfg(cfg, lk, False)
        _, _, lowered = lower_lm_cell(arch, cell, mesh, cfg=cfgk)
        c, f = _coll_counts(lowered)
        counts.append(c)
        fused.append(f)
    keys = set(counts[0]) | set(counts[1])
    k = (cfg.n_layers - l1) / pat
    # post-fusion per-device bytes/flops, loop-corrected the same way.
    # NOTE: compiled probes keep real chunk sizes, so their while bodies
    # (attn/CE chunk loops) are still counted once -> scale the fused-bytes
    # per-layer delta by the chunk trip count is NOT needed for the linear
    # layer term (each layer body is one loop iteration here at L=1,2 the
    # scan is typically unrolled by XLA); treat as lower-bound companion to
    # the pre-fusion upper bound.
    fused_bytes = fused[0]["bytes"] + k * (fused[1]["bytes"] - fused[0]["bytes"])
    fused_flops = fused[0]["flops"] + k * (fused[1]["flops"] - fused[0]["flops"])
    total_s = 0.0
    wire_total = 0.0
    schedule = []
    for key in sorted(keys, key=str):
        c1, c2 = counts[0].get(key, 0), counts[1].get(key, 0)
        n = max(round(c1 + k * (c2 - c1)), 0)
        kind, bytes_result, g, cross = key
        if kind == "all-reduce":
            wire = 2.0 * bytes_result * (g - 1) / g
        elif kind == "all-gather":
            wire = bytes_result * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = bytes_result * (g - 1)
        elif kind == "all-to-all":
            wire = bytes_result * (g - 1) / g
        else:
            wire = float(bytes_result)
        bw = roofline.DCN_BW if cross else roofline.ICI_BW
        total_s += n * wire / bw
        wire_total += n * wire
        schedule.append({"kind": kind, "bytes": bytes_result, "group": g,
                         "cross_pod": cross, "count": int(n)})
    return {"collective_s": total_s, "wire_bytes_per_device": wire_total,
            "schedule": schedule, "probe_layers": (l1, l2),
            "fused_bytes_per_device": max(fused_bytes, 0.0),
            "fused_flops_per_device": max(fused_flops, 0.0)}


def selfjoin_analytic_cost(cfg, npts, ndims, eps, n_slab, n_model):
    """Analytic per-device flops/bytes for the distributed count step.

    Work model (uniform data in [0,100]^n, the paper's Syn- datasets):
    offsets ~ (3^n+1)/2 (UNICOMP), candidate window C per cell, candidates
    per device per offset = P_cand = P_loc + 2H. Each candidate slot costs
    ~3n flops (sub, mul, add) + compare; gathers dominate bytes.
    """
    p_loc = -(-npts // n_slab)
    halo = max(64, int(p_loc * 0.25))
    p_cand = p_loc + 2 * halo
    n_off = (3 ** ndims + 1) // 2 if cfg.unicomp else 3 ** ndims
    n_off_local = -(-n_off // n_model)
    C = cfg.max_per_cell
    per_slot_flops = 3 * ndims + 2
    flops = p_cand * C * n_off_local * per_slot_flops
    bytes_per_slot = 8 * ndims + 8        # f64 coords + ids/masks
    bytes_ = p_cand * C * n_off_local * bytes_per_slot
    return {"flops_total": flops * n_slab * n_model,
            "bytes_total": bytes_ * n_slab * n_model,
            "flops_per_device": flops, "bytes_per_device": bytes_}


def lower_selfjoin_cell(shape_name: str, mesh):
    from repro.configs.selfjoin import CONFIG, SHAPES as SJ_SHAPES
    from repro.core.distributed import DistJoinConfig, make_distributed_count_step

    by_name = {s[0]: s for s in SJ_SHAPES}
    _, npts, ndims, eps = by_name[shape_name]
    n_slab = mesh.shape["slab"]
    pts_per_dev = -(-npts // n_slab)
    cfg = DistJoinConfig(
        pts_per_device=pts_per_dev,
        n_dims=ndims,
        halo_capacity=max(64, int(pts_per_dev * CONFIG.halo_frac)),
        max_per_cell=CONFIG.max_per_cell,
        unicomp=CONFIG.unicomp,
        model_axis="model",
    )
    step, in_sh = make_distributed_count_step(mesh, cfg)
    coords = jax.ShapeDtypeStruct((n_slab * pts_per_dev, ndims), jnp.float64)
    gids = jax.ShapeDtypeStruct((n_slab * pts_per_dev,), jnp.int32)
    with mesh:
        lowered = step.lower(coords, gids,
                             jax.ShapeDtypeStruct((), jnp.float64))
    return cfg, lowered


def analyze(lowered, cfg, cell, mesh, *, compile_s):
    compiled = lowered.compile()
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}
    try:
        cost = _cost_dict(compiled.cost_analysis())
    except Exception as e:
        cost = {"error": str(e)}
    chips = mesh.devices.size
    hlo = compiled.as_text()
    summary = roofline.summarize(cost, hlo, chips)
    result = {
        "chips": int(chips),
        "mesh": dict(zip(mesh.axis_names,
                         [int(mesh.shape[a]) for a in mesh.axis_names])),
        "memory_analysis": mem_info,
        "compile_seconds": compile_s,
        "roofline": summary,
    }
    if cell is not None and hasattr(cfg, "active_param_count"):
        result["model_check"] = roofline.model_flops_check(
            cfg, cell, summary["flops_per_device"], chips)
    return result, compiled


def run_cell(arch: str, shape: str, mesh_kind: str, probe_cache: dict):
    """Full dry-run for one cell: stage A (full-depth lower+compile =
    shardability + memory proof), stage B (unrolled cost probe, cached per
    arch|shape), stage C (collective extrapolation probe)."""
    multi = mesh_kind == "multi"
    t0 = time.time()
    if arch == "selfjoin":
        mesh = make_selfjoin_mesh(multi_pod=multi)
        sj_cfg, lowered = lower_selfjoin_cell(shape, mesh)
        cell = None
    else:
        mesh = make_production_mesh(multi_pod=multi)
        cells = {c.name: c for c in SHAPES}
        cell = cells[shape]
        cfg, layout, lowered = lower_lm_cell(arch, cell, mesh)
    lower_s = time.time() - t0
    t0 = time.time()
    result, compiled = analyze(lowered, None if arch == "selfjoin" else cfg,
                               cell, mesh, compile_s=None)
    result["compile_seconds"] = time.time() - t0
    result["lower_seconds"] = lower_s
    chips = mesh.devices.size

    if arch == "selfjoin":
        from repro.configs.selfjoin import SHAPES as SJ_SHAPES
        by_name = {s[0]: s for s in SJ_SHAPES}
        _, npts, ndims, eps = by_name[shape]
        ana = selfjoin_analytic_cost(sj_cfg, npts, ndims, eps,
                                     mesh.shape["slab"], mesh.shape["model"])
        # the step body has no collectives inside its offset scan; the
        # stage-A parse (halo exchange + final psums) is already complete.
        result["roofline"].update(
            flops_per_device=ana["flops_per_device"],
            bytes_per_device=ana["bytes_per_device"],
            compute_s=ana["flops_per_device"] / roofline.PEAK_FLOPS,
            memory_s=ana["bytes_per_device"] / roofline.HBM_BW,
            cost_source="analytic (paper work model); HLO parse for colls",
        )
    else:
        probe_key = f"{arch}|{shape}"
        if probe_key not in probe_cache:
            probe_cache[probe_key] = cost_probe(arch, cell)
        probe = probe_cache[probe_key]
        colls = collective_probe(arch, cell, mesh)
        flops_dev = probe["flops_total"] / chips
        bytes_logical_dev = probe["bytes_total"] / chips   # pre-fusion: upper
        bytes_fused_dev = colls["fused_bytes_per_device"]  # post-fusion: lower
        floor = roofline.traffic_floor(cfg, cell, chips)   # analytic floor
        if cell.kind == "decode":
            # dynamic-update-slice on the KV cache makes HLO byte counts
            # charge the full cache per layer; the analytic model (params +
            # one full cache read + tiny writes) is the faithful estimate.
            bytes_dev = floor
        else:
            bytes_dev = max(bytes_fused_dev, floor)
        r = result["roofline"]
        r.update(
            flops_per_device=flops_dev,
            bytes_per_device=bytes_dev,
            bytes_logical_per_device=bytes_logical_dev,
            bytes_fused_per_device=bytes_fused_dev,
            bytes_floor_per_device=floor,
            compute_s=flops_dev / roofline.PEAK_FLOPS,
            memory_s=bytes_dev / roofline.HBM_BW,
            memory_s_upper=bytes_logical_dev / roofline.HBM_BW,
            collective_s=colls["collective_s"],
            wire_bytes_per_device=colls["wire_bytes_per_device"],
            collective_schedule=colls["schedule"],
            cost_source="flops: unrolled two-point probe (exact at probe "
                         "depths, linear-in-layers); bytes: max(post-fusion "
                         "two-point probe, analytic traffic floor), "
                         "pre-fusion logical bytes kept as upper bound; "
                         "collectives: compiled two-point probe",
            probe=probe,
        )
        r["bottleneck"] = max(
            [("compute", r["compute_s"]), ("memory", r["memory_s"]),
             ("collective", r["collective_s"])], key=lambda kv: kv[1])[0]
        result["model_check"] = roofline.model_flops_check(
            cfg, cell, flops_dev, chips)
        result["layout"] = {
            "batch_axes": str(layout.batch_axes),
            "head_tp": str(layout.head_tp),
            "cache_seq": str(layout.cache_seq),
        }
    # recompute bottleneck for selfjoin too
    r = result["roofline"]
    r["bottleneck"] = max(
        [("compute", r["compute_s"]), ("memory", r["memory_s"]),
         ("collective", r["collective_s"])], key=lambda kv: kv[1])[0]
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    jobs = []
    if args.all:
        for arch, cell, skip in all_cells():
            for mk in meshes:
                jobs.append((arch, cell.name, mk, skip))
        from repro.configs.selfjoin import SHAPES as SJ_SHAPES
        for s in SJ_SHAPES:
            for mk in meshes:
                jobs.append(("selfjoin", s[0], mk, None))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mk in meshes:
            jobs.append((args.arch, args.shape, mk, None))

    results = {}
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)  # resume support
    probe_cache = results.setdefault("_probe_cache", {})
    for arch, shape, mk, skip in jobs:
        key = f"{arch}|{shape}|{mk}"
        if key in results and "error" not in results[key]:
            print(f"[dryrun] {key}: cached", flush=True)
            continue
        if skip is not None:
            results[key] = {"skipped": skip}
            print(f"[dryrun] {key}: SKIP ({skip})", flush=True)
            continue
        print(f"[dryrun] {key}: lowering...", flush=True)
        t0 = time.time()
        try:
            res = run_cell(arch, shape, mk, probe_cache)
            results[key] = res
            r = res["roofline"]
            print(f"[dryrun] {key}: OK in {time.time()-t0:.1f}s "
                  f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                  f"collective={r['collective_s']:.3e}s "
                  f"bottleneck={r['bottleneck']}", flush=True)
        except Exception as e:
            results[key] = {"error": f"{type(e).__name__}: {e}",
                            "traceback": traceback.format_exc()[-2000:]}
            print(f"[dryrun] {key}: FAIL {type(e).__name__}: {e}", flush=True)
        if args.out:
            tmp = args.out + ".tmp"
            with open(tmp, "w") as f:
                json.dump(results, f, indent=1)
            os.replace(tmp, args.out)
    n_ok = sum(1 for v in results.values() if "roofline" in v)
    n_skip = sum(1 for v in results.values() if "skipped" in v)
    n_err = sum(1 for v in results.values() if "error" in v)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} failed",
          flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
