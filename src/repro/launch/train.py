"""Training driver: elastic, fault-tolerant, with the paper's dedup pipeline.

    python -m repro.launch.train --arch smoke-lm --reduced \
        --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Wires together: configs registry -> LMModel -> AdamW -> jitted step with
shardings -> TokenPipeline (optional self-join dedup) -> CheckpointManager
(async, atomic, keep-last-k) -> StragglerMonitor -> elastic restore (a
restart on a different device count resumes from the same step).

On this CPU container use --reduced and a smoke mesh; on TPU pods the same
driver takes --mesh single|multi for the production meshes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import CheckpointManager, latest_step, restore_checkpoint
from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.models.lm import LMModel, choose_layout
from repro.train.optimizer import AdamWConfig, adamw_init, opt_state_specs
from repro.train.steps import make_train_step
from repro.train.straggler import StragglerMonitor


def _ns(mesh, spec_tree):
    if mesh is None:
        return None
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build(args):
    cfg = get_config(args.arch, reduced=args.reduced)
    if args.mesh == "single":
        mesh = make_production_mesh(multi_pod=False)
    elif args.mesh == "multi":
        mesh = make_production_mesh(multi_pod=True)
    elif args.mesh == "smoke":
        mesh = make_smoke_mesh(len(jax.devices()))
    else:
        mesh = None
    model = LMModel(cfg, mesh)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=args.warmup)
    return cfg, mesh, model, ocfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smoke-lm")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "smoke", "single", "multi"],
                    default="none")
    ap.add_argument("--dedup", action="store_true",
                    help="self-join near-duplicate filter in the pipeline")
    ap.add_argument("--compress-pods", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, mesh, model, ocfg = build(args)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                         seed=args.seed, dedup=args.dedup,
                         input_kind=cfg.input_kind, d_model=cfg.d_model)

    params, specs = model.init(jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params, ocfg)
    ospecs = opt_state_specs(specs, ocfg, params)
    if args.compress_pods:
        from repro.train.compression import init_error_state

        opt_state["grad_error"] = init_error_state(params)
        ospecs = dict(ospecs)
        ospecs["grad_error"] = specs
    if mesh is not None:
        params = jax.device_put(params, _ns(mesh, specs))
        opt_state = jax.device_put(opt_state, _ns(mesh, ospecs))

    start = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr is not None:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            tree = {"params": params, "opt": opt_state}
            tree = restore_checkpoint(
                args.ckpt_dir, last, tree, mesh=mesh,
                specs={"params": specs, "opt": ospecs} if mesh else None)
            params, opt_state = tree["params"], tree["opt"]
            start = last
            print(f"[train] elastic restore from step {last} onto "
                  f"{len(jax.devices())} device(s)")

    step_fn = make_train_step(model, ocfg, compress_pods=args.compress_pods,
                              param_specs=specs if mesh is not None else None)
    if mesh is not None:
        step_fn = jax.jit(
            step_fn,
            in_shardings=(_ns(mesh, specs), _ns(mesh, ospecs), None),
            out_shardings=(_ns(mesh, specs), _ns(mesh, ospecs), None),
            donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    mon = StragglerMonitor()
    ctx = mesh if mesh is not None else _NullCtx()
    with ctx:
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     pipe.batch_at(step).items()}
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])  # sync point
            dt = time.time() - t0
            slow = mon.record(dt)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"{dt*1000:.0f}ms gnorm {float(metrics['grad_norm']):.3f}"
                      + (" SLOW" if slow else ""), flush=True)
            if mon.should_rebalance():
                print("[train] straggler threshold exceeded -> checkpoint + "
                      "rebalance requested", flush=True)
                mon.reset()
                if mgr is not None:
                    mgr.save_async(step + 1,
                                   {"params": params, "opt": opt_state})
            if mgr is not None and (step + 1) % args.ckpt_every == 0:
                mgr.save_async(step + 1, {"params": params, "opt": opt_state})
    if mgr is not None:
        mgr.save_async(args.steps, {"params": params, "opt": opt_state})
        mgr.wait()
    print(f"[train] done at step {args.steps}, final loss {loss:.4f}")
    return loss


class _NullCtx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
