"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms, in seconds per step per chip (TPU v5e constants as assigned):

    compute    = HLO_FLOPs / (chips * 197e12)          [bf16 peak]
    memory     = HLO_bytes / (chips * 819e9)           [HBM]
    collective = sum(bytes_on_wire_per_device) / link_bw per collective

FLOPs/bytes come from ``compiled.cost_analysis()`` (totals for the whole
SPMD program: already per-device in XLA's SPMD view -- see note below).
Collective traffic is NOT in cost_analysis, so we parse the optimized HLO
(``compiled.as_text()``) and apply ring-model byte counts:

    all-reduce          2 * S * (g-1)/g      (S = result bytes per device)
    all-gather          S_out * (g-1)/g      (receives everyone else's shard)
    reduce-scatter      S_in * (g-1)/g
    all-to-all          S * (g-1)/g
    collective-permute  S                    (single hop)

Cross-pod groups (device ids spanning >1 block of 256) ride DCN
(25 GB/s assumed) instead of ICI (50 GB/s per the assignment).

NOTE on cost_analysis semantics: for an SPMD-partitioned program, XLA reports
the per-partition (per-device) op set, so flops/bytes are per device; we
multiply by ``chips`` only where a global number is reported (detected via
the program's num_partitions).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link
DCN_BW = 25e9              # bytes/s cross-pod (assumed)
POD_SIZE = 256
VMEM_BYTES = 128 * 2 ** 20  # v5e VMEM per core; the fused kernel's budget


def fused_join_vmem_bytes(*, c: int, tq: int, np_pad: int = 8,
                          dtype_bytes: int = 4,
                          run_loop: bool = False) -> int:
    """Static VMEM footprint of one fused-join grid step (bytes).

    Mirrors the block/scratch shapes of ``kernels.fused_join
    ._fused_join_hits_pallas``: the pipelined blocks -- query tile
    (tq, np_pad), hits (1, tq, c) int8, counts + slot_base (tq, 1) int32,
    the eps scalar -- are counted TWICE (Pallas double-buffers revolving
    in/out blocks across grid steps), plus the explicitly double-buffered
    (2, c, np_pad) window scratch. Scalar-prefetch descriptors live in
    SMEM and are excluded. The contract prover (analysis/contracts.py C6)
    checks every (class, tile) the occupancy plan can launch against
    ``VMEM_BYTES``.

    ``run_loop`` (the cell-run DMA dedup, DESIGN.md S11) does NOT change
    the footprint: the run plan's ``run_ord`` descriptor rides the
    scalar-prefetch path (SMEM) like win_start/win_count, and the kernel
    keeps the same two (c, np_pad) window slots -- only the start/wait
    SCHEDULE changes (per run instead of per row). The parameter exists
    so provers state the mode they checked.
    """
    del run_loop   # same slots, same blocks; see docstring
    blocks = (tq * np_pad * dtype_bytes   # query tile
              + tq * c                    # int8 hits block
              + 2 * tq * 4                # counts + slot_base
              + dtype_bytes)              # eps2
    scratch = 2 * c * np_pad * dtype_bytes
    return 2 * blocks + scratch

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.:  %all-gather.7 = bf16[16,4096,1024]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
# tuple-result collectives: (bf16[...], bf16[...]) all-reduce(...)
_TUPLE_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{\{")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class Collective:
    kind: str
    bytes_result: int
    group_size: int
    cross_pod: bool
    wire_bytes: float      # per device
    seconds: float


def _group_info(line: str):
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        n_groups, group_size, total = map(int, m.groups())
        # iota groups [G,S]<=[N]: contiguity depends on the transpose spec;
        # conservatively flag cross-pod when a group must span >1 pod block.
        cross = group_size > POD_SIZE or (
            "T(" in line and total > POD_SIZE)
        return group_size, cross
    m = _GROUPS_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        cross = len({i // POD_SIZE for i in ids}) > 1
        return len(ids), cross
    return 1, False


def parse_collectives(hlo_text: str):
    """Collective ops with ring-model per-device wire bytes and time."""
    out = []
    for line in hlo_text.splitlines():
        line = line.strip()
        mt = _TUPLE_RE.search(line)
        mo = _OP_RE.search(line) if mt is None else None
        if mt is None and mo is None:
            continue
        if "-done" in line:
            continue
        if mt is not None:
            kind = mt.group(2)
            bytes_result = sum(_shape_bytes(d, s)
                               for d, s in _SHAPE_RE.findall(mt.group(1)))
        else:
            kind = mo.group(3)
            bytes_result = _shape_bytes(mo.group(1), mo.group(2))
        kind = kind.replace("-start", "")
        g, cross = _group_info(line)
        if g <= 1 and kind != "collective-permute":
            continue
        if kind == "all-reduce":
            wire = 2.0 * bytes_result * (g - 1) / g
        elif kind == "all-gather":
            wire = bytes_result * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = bytes_result * (g - 1)       # result is the scattered shard
        elif kind == "all-to-all":
            wire = bytes_result * (g - 1) / g
        else:  # collective-permute
            wire = float(bytes_result)
        bw = DCN_BW if cross else ICI_BW
        out.append(Collective(kind, bytes_result, g, cross, wire, wire / bw))
    return out


def _loop_trip_counts(hlo_text: str) -> float:
    """Best-effort: collectives inside while loops execute trip_count times.

    XLA CPU emits scan as while; cost_analysis already multiplies flops by
    trip counts, but our HLO text parse sees the loop body once. We extract
    known trip counts and scale collectives found inside loop bodies.
    (Approximation: a single dominant scan-over-layers loop.)
    """
    m = re.findall(r"trip_count=(\d+)", hlo_text)
    return max((int(x) for x in m), default=1)


def summarize(cost: dict, hlo_text: str, chips: int, *,
              scale_loop_collectives: bool = True) -> dict:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(hlo_text)
    trip = _loop_trip_counts(hlo_text) if scale_loop_collectives else 1

    # Group collectives by whether they appear before or inside loops is
    # brittle from text; we scale all by the dominant trip count when the
    # program has a scan (documented approximation, see module docstring).
    wire = sum(c.wire_bytes for c in colls)
    coll_s = sum(c.seconds for c in colls)
    body_count = len(colls)

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collectives": [dataclasses.asdict(c) for c in colls],
        "n_collectives": body_count,
        "loop_trip_count": trip,
        "wire_bytes_per_device": wire,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "bottleneck": max(
            [("compute", compute_s), ("memory", memory_s),
             ("collective", coll_s)], key=lambda kv: kv[1])[0],
    }


def traffic_floor(cfg, cell, chips: int) -> float:
    """Analytic lower bound on HBM bytes/device/step.

    Used to floor the post-fusion HLO byte estimate (whose while-loop bodies
    are counted once). Terms: parameter reads (3x for train: fwd, remat-fwd,
    bwd), gradient + optimizer-state traffic (train), KV/SSM cache traffic
    (decode/prefill), boundary activations (train, remat).
    """
    P = cfg.param_count()
    PA = cfg.active_param_count()
    bf16 = 2
    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        act = cfg.n_layers * B * S * cfg.d_model * bf16 * 2   # save + reload
        opt = 2 * (4 + 4 + 4) * P                             # m/v/master r+w
        total = (3 * bf16 + 2 * bf16) * P + opt + act
    elif cell.kind == "prefill":
        cache = 2 * B * S * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers * bf16
        act = cfg.n_layers * B * S * cfg.d_model * bf16
        total = bf16 * P + cache + act
    else:  # decode
        touched = min(1.0, B * max(cfg.top_k, 1) / max(cfg.n_experts, 1)) \
            if cfg.n_experts else 1.0
        params = bf16 * (PA + touched * (P - PA))
        cache = 0.0
        if cfg.family in ("dense", "moe", "vlm"):
            cache = 2 * B * S * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers * bf16
        elif cfg.family == "hybrid":
            n_inv = -(-cfg.n_layers // cfg.shared_attn_every) \
                if cfg.shared_attn_every else 0
            cache = 2 * B * S * cfg.n_kv_heads * cfg.head_dim * n_inv * bf16
            H = cfg.d_inner // cfg.ssm_head_dim
            cache += 2 * B * H * cfg.ssm_state * cfg.ssm_head_dim * 4 * cfg.n_layers
        elif cfg.family == "ssm":
            dh = cfg.d_inner // cfg.n_heads
            cache = 2 * B * cfg.n_heads * dh * dh * 4 * cfg.n_layers
        total = params + cache
    return total / chips


def model_flops_check(cfg, cell, hlo_flops_per_device: float, chips: int):
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D; ratio vs compiled FLOPs."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        model_flops = 6.0 * n * tokens
    elif cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        model_flops = 2.0 * n * tokens
    else:  # decode: one token per sequence
        tokens = cell.global_batch
        model_flops = 2.0 * n * tokens
    hlo_total = hlo_flops_per_device * chips
    return {
        "model_flops": model_flops,
        "hlo_flops_total": hlo_total,
        "useful_fraction": model_flops / hlo_total if hlo_total else 0.0,
    }
