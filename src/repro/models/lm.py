"""LMModel: init / train_loss / prefill / decode for every architecture.

Layout selection happens here: given the mesh axes (ShardCtx) and the shape
cell, pick batch axes, head TP, and cache sequence sharding, falling back to
replication whenever a dimension does not divide the axis (recorded by
``layout_report`` and surfaced in the dry-run output).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import transformer as tf
from repro.models import xlstm as xlstm_lib
from repro.models.attention import KVCache
from repro.models.config import ModelConfig
from repro.models.layers import (
    ShardCtx,
    cross_entropy,
    embed_param,
    norm_param,
    rms_norm,
    shard,
)


@dataclasses.dataclass(frozen=True)
class Layout:
    batch_axes: Any          # axis (or tuple) for the batch dim, or None
    head_tp: Optional[str]   # 'model' when n_heads divides the TP axis
    cache_seq: Any           # axes for the KV-cache sequence dim


def make_shard_ctx(mesh=None) -> ShardCtx:
    if mesh is None:
        return ShardCtx(fsdp_axis=None, tp_axis=None, fsdp_size=1, tp_size=1)
    names = mesh.axis_names
    fsdp = "data" if "data" in names else None
    tp = "model" if "model" in names else None
    return ShardCtx(
        fsdp_axis=fsdp,
        tp_axis=tp,
        fsdp_size=mesh.shape[fsdp] if fsdp else 1,
        tp_size=mesh.shape[tp] if tp else 1,
    )


def choose_layout(cfg: ModelConfig, mesh, batch: int, seq: int) -> Layout:
    if mesh is None:
        return Layout(batch_axes=None, head_tp=None, cache_seq=None)
    names = mesh.axis_names
    sizes = dict(zip(names, tuple(mesh.shape[n] for n in names)))
    dp_candidates = []
    if "pod" in names and "data" in names:
        dp_candidates.append(("pod", "data"))
    if "data" in names:
        dp_candidates.append(("data",))
    batch_axes = None
    for cand in dp_candidates:
        n = 1
        for a in cand:
            n *= sizes[a]
        if batch % n == 0:
            batch_axes = cand if len(cand) > 1 else cand[0]
            break
    tp = sizes.get("model", 1)
    head_tp = "model" if ("model" in names and cfg.n_heads % tp == 0) else None
    cache_seq = None
    if "model" in names and seq % tp == 0:
        cache_seq = "model"
        if batch_axes is None and "data" in names and seq % (tp * sizes["data"]) == 0:
            cache_seq = ("data", "model")
    return Layout(batch_axes=batch_axes, head_tp=head_tp, cache_seq=cache_seq)


class LMModel:
    def __init__(self, cfg: ModelConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.ctx = make_shard_ctx(mesh)
        self._specs_cache = None

    @property
    def param_specs(self):
        """Spec pytree (cached; derived abstractly, no allocation)."""
        if self._specs_cache is None:
            _, self._specs_cache = self.abstract_params()
        return self._specs_cache

    def _stack_kwargs(self):
        if self.mesh is None:
            return {}
        s = self.param_specs
        return {"block_specs": s.get("blocks"),
                "shared_specs": s.get("shared_attn")}

    # -- parameters ---------------------------------------------------------

    def init(self, rng) -> Tuple[Any, Any]:
        """Returns (params, specs) parallel pytrees."""
        cfg, ctx = self.cfg, self.ctx
        dt = jnp.dtype(cfg.dtype)
        keys = jax.random.split(rng, 5)
        p, s = {}, {}
        if cfg.input_kind == "tokens" or cfg.has_decode:
            p["embed"], s["embed"] = embed_param(keys[0], cfg.vocab,
                                                 cfg.d_model, ctx, dt)
        p["blocks"], s["blocks"] = tf.init_stack(keys[1], cfg, ctx)
        sp, ss = tf.init_shared_attn(keys[2], cfg, ctx)
        if sp is not None:
            p["shared_attn"], s["shared_attn"] = sp, ss
        p["final_norm"], s["final_norm"] = norm_param(cfg.d_model, dt)
        p["head"], s["head"] = embed_param(keys[3], cfg.vocab, cfg.d_model, ctx, dt)
        s["head"] = P(ctx.axis("tp", cfg.vocab), None)
        return p, s

    def abstract_params(self, rng=None):
        """(ShapeDtypeStruct pytree, specs) without allocating -- dry-run.

        init() is traced abstractly (eval_shape); the specs -- plain static
        PartitionSpec objects, value-independent -- are captured through a
        side box during the trace.
        """
        rng = jax.random.PRNGKey(0) if rng is None else rng
        box = {}

        def capture(k):
            p, s = self.init(k)
            box["specs"] = s
            return p

        shapes = jax.eval_shape(capture, rng)
        return shapes, box["specs"]

    # -- embedding / head ---------------------------------------------------

    def _embed_in(self, p, batch, layout):
        cfg = self.cfg
        if cfg.input_kind == "tokens":
            x = p["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype))
        else:
            x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        return shard(x, layout.batch_axes, None, None)

    def _loss_from_hidden(self, p, x, labels, layout):
        """Sequence-chunked CE against the TP-sharded head (memory-bounded)."""
        cfg = self.cfg
        B, S, _ = x.shape
        chunk = min(cfg.loss_chunk, S)
        if S % chunk:
            chunk = S
        nc = S // chunk
        xs = jnp.moveaxis(x.reshape(B, nc, chunk, cfg.d_model), 1, 0)
        ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

        def body(carry, xs_):
            xc, lc = xs_
            logits = xc @ p["head"].T.astype(xc.dtype)
            logits = shard(logits, layout.batch_axes, None,
                           self.ctx.axis("tp", cfg.vocab))
            lsum, cnt = carry
            mask = lc >= 0
            lo = logits.astype(jnp.float32)
            lse = jax.scipy.special.logsumexp(lo, axis=-1)
            ll = jnp.take_along_axis(lo, jnp.maximum(lc, 0)[..., None],
                                     axis=-1)[..., 0]
            loss = (lse - ll) * mask
            if cfg.z_loss:
                loss = loss + cfg.z_loss * (lse * mask) ** 2
            return (lsum + loss.sum(), cnt + mask.sum(dtype=jnp.int32)), None

        body = jax.checkpoint(body)
        (lsum, cnt), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
            (xs, ls), unroll=nc if cfg.unroll_scans else 1)
        return lsum / jnp.maximum(cnt, 1)

    # -- training -----------------------------------------------------------

    def train_loss(self, p, batch, layout: Optional[Layout] = None):
        cfg = self.cfg
        layout = layout or self._default_layout(batch)
        x = self._embed_in(p, batch, layout)
        x, _, aux = tf.stack_forward(
            p["blocks"], p.get("shared_attn"), x, cfg, self.ctx, mode="train",
            head_tp=layout.head_tp, seq_axes=layout.cache_seq,
            dp_spec=layout.batch_axes, caches=None,
            **self._stack_kwargs())
        x = rms_norm(x, p["final_norm"])
        loss = self._loss_from_hidden(p, x, batch["labels"], layout)
        return loss, aux

    def _default_layout(self, batch):
        leaf = batch["tokens"] if "tokens" in batch else batch["embeds"]
        return choose_layout(self.cfg, self.mesh, leaf.shape[0], leaf.shape[1])

    def encode(self, p, batch, layout: Optional[Layout] = None):
        """Encoder-only forward -> (B, S, vocab) logits (hubert's 'prefill')."""
        cfg = self.cfg
        layout = layout or self._default_layout(batch)
        x = self._embed_in(p, batch, layout)
        x, _, _ = tf.stack_forward(
            p["blocks"], p.get("shared_attn"), x, cfg, self.ctx, mode="train",
            head_tp=layout.head_tp, seq_axes=layout.cache_seq,
            dp_spec=layout.batch_axes, caches=None,
            **self._stack_kwargs())
        x = rms_norm(x, p["final_norm"])
        return x @ p["head"].T.astype(x.dtype)

    # -- serving ------------------------------------------------------------

    def init_caches(self, batch: int, max_len: int) -> tf.StackCaches:
        cfg = self.cfg
        L = cfg.n_layers
        dt = jnp.dtype(cfg.dtype)

        def stack_kv(n):
            return KVCache(
                k=jnp.zeros((n, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
                v=jnp.zeros((n, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
                length=jnp.zeros((n,), jnp.int32),
            )

        if cfg.family in ("dense", "moe", "audio", "vlm"):
            return tf.StackCaches(kv=stack_kv(L))
        if cfg.family == "ssm":
            H = cfg.n_heads
            dh = cfg.d_inner // H
            ml = jnp.zeros((L, batch, H, dh, dh), jnp.float32)
            sl = (jnp.zeros((L, batch, cfg.d_model), jnp.float32),
                  jnp.zeros((L, batch, cfg.d_model), jnp.float32))
            return tf.StackCaches(mlstm=ml, slstm=sl)
        if cfg.family == "hybrid":
            st = mamba_lib.mamba2_state(cfg, batch)
            mamba = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), st)
            n_inv = tf._shared_invocations(cfg)
            kv = KVCache(
                k=jnp.zeros((n_inv, batch, max_len, cfg.n_kv_heads,
                             cfg.head_dim), dt),
                v=jnp.zeros((n_inv, batch, max_len, cfg.n_kv_heads,
                             cfg.head_dim), dt),
                length=jnp.zeros((), jnp.int32),
            )
            return tf.StackCaches(mamba=mamba, shared_kv=kv)
        raise ValueError(cfg.family)

    def cache_specs(self, layout: Layout) -> tf.StackCaches:
        cfg = self.cfg
        b, s_ = layout.batch_axes, layout.cache_seq
        kvspec = KVCache(k=P(None, b, s_, None, None),
                         v=P(None, b, s_, None, None), length=P(None))
        if cfg.family in ("dense", "moe", "audio", "vlm"):
            return tf.StackCaches(kv=kvspec)
        if cfg.family == "ssm":
            return tf.StackCaches(
                mlstm=P(None, b, None, None, None),
                slstm=(P(None, b, None), P(None, b, None)))
        if cfg.family == "hybrid":
            return tf.StackCaches(
                mamba=mamba_lib.Mamba2State(
                    conv=P(None, b, None, None),
                    ssm=P(None, b, None, None, None)),
                shared_kv=KVCache(k=P(None, b, s_, None, None),
                                  v=P(None, b, s_, None, None), length=P()))
        raise ValueError(cfg.family)

    def prefill(self, p, batch, caches: tf.StackCaches,
                layout: Optional[Layout] = None):
        """Process a prompt; returns (last-position logits, filled caches)."""
        cfg = self.cfg
        layout = layout or self._default_layout(batch)
        x = self._embed_in(p, batch, layout)
        x, caches, _ = tf.stack_forward(
            p["blocks"], p.get("shared_attn"), x, cfg, self.ctx,
            mode="prefill", head_tp=layout.head_tp, seq_axes=layout.cache_seq,
            dp_spec=layout.batch_axes, caches=caches,
            **self._stack_kwargs())
        x = rms_norm(x, p["final_norm"])
        logits = x[:, -1, :] @ p["head"].T.astype(x.dtype)
        if cfg.family == "hybrid":
            caches = caches._replace(shared_kv=caches.shared_kv._replace(
                length=jnp.asarray(x.shape[1], jnp.int32)))
        return logits, caches

    def decode_step(self, p, tokens, caches: tf.StackCaches,
                    layout: Optional[Layout] = None):
        """One token for every sequence. tokens: (B,) int32."""
        cfg = self.cfg
        if layout is None:
            b = tokens.shape[0]
            s = self._cache_len(caches)
            layout = choose_layout(cfg, self.mesh, b, s)
        x = p["embed"][tokens][:, None, :].astype(jnp.dtype(cfg.dtype))
        x = shard(x, layout.batch_axes, None, None)
        x, caches, _ = tf.stack_forward(
            p["blocks"], p.get("shared_attn"), x, cfg, self.ctx, mode="decode",
            head_tp=layout.head_tp, seq_axes=layout.cache_seq,
            dp_spec=layout.batch_axes, caches=caches,
            **self._stack_kwargs())
        x = rms_norm(x, p["final_norm"])
        logits = x[:, 0, :] @ p["head"].T.astype(x.dtype)
        if cfg.family == "hybrid":
            caches = caches._replace(shared_kv=caches.shared_kv._replace(
                length=caches.shared_kv.length + 1))
        return logits, caches

    def _cache_len(self, caches):
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "audio", "vlm"):
            return caches.kv.k.shape[2]
        if cfg.family == "hybrid" and cfg.shared_attn_every:
            return caches.shared_kv.k.shape[2]
        return 0
