"""Model zoo: one implementation spine for the 10 assigned architectures.

layers.py      -- norms, dense/embed params with sharding specs, RoPE, losses
attention.py   -- GQA attention: chunked train/prefill + KV-cache decode
moe.py         -- top-k router with capacity + sort-based dispatch (EP)
xlstm.py       -- mLSTM (chunkwise-parallel) and sLSTM (recurrent) blocks
mamba2.py      -- Mamba2 SSD (chunked scan) block
transformer.py -- per-family block assembly, lax.scan + remat layer stack
lm.py          -- LMModel facade: init / train_loss / prefill / decode
"""
from repro.models.lm import LMModel

__all__ = ["LMModel"]
