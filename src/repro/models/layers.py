"""Shared layers: parameters carry sharding specs as a parallel pytree.

Every parameter-creating helper returns ``(array, spec)`` where spec is a
``jax.sharding.PartitionSpec``; model init assembles parallel (params, specs)
trees. The convention for 2-D weights is P(fsdp, tp): the input dimension is
sharded over the FSDP ('data') axis, the output over the tensor ('model')
axis, unless a dimension is not divisible -- then that dim is replicated
(recorded by the config's layout report, see launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def _divisible(dim: int, axis_size: int) -> bool:
    return axis_size > 0 and dim % axis_size == 0


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh axis names/sizes the init code uses to pick legal specs."""

    fsdp_axis: Optional[str]   # usually 'data' (+'pod' folded by the mesh)
    tp_axis: Optional[str]     # usually 'model'
    fsdp_size: int
    tp_size: int

    def axis(self, kind: str, dim: int):
        """Return the axis name for ``kind`` if ``dim`` divides, else None."""
        if kind == "tp" and self.tp_axis and _divisible(dim, self.tp_size):
            return self.tp_axis
        if kind == "fsdp" and self.fsdp_axis and _divisible(dim, self.fsdp_size):
            return self.fsdp_axis
        return None


def dense_param(key, d_in: int, d_out: int, ctx: ShardCtx, dtype,
                *, tp_dim: str = "out", scale: Optional[float] = None):
    """Weight (d_in, d_out); TP on ``tp_dim``, FSDP on the other dim."""
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype) * jnp.asarray(scale, dtype)
    if tp_dim == "out":
        spec = P(ctx.axis("fsdp", d_in), ctx.axis("tp", d_out))
    else:
        spec = P(ctx.axis("tp", d_in), ctx.axis("fsdp", d_out))
    return w, spec


def bias_param(d: int, ctx: ShardCtx, dtype, *, tp: bool):
    b = jnp.zeros((d,), dtype)
    return b, P(ctx.axis("tp", d) if tp else None)


def embed_param(key, vocab: int, d_model: int, ctx: ShardCtx, dtype):
    w = jax.random.normal(key, (vocab, d_model), dtype) * jnp.asarray(0.02, dtype)
    return w, P(ctx.axis("tp", vocab), None)


def norm_param(d: int, dtype):
    return jnp.ones((d,), dtype), P(None)


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope_frequencies(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits, labels, *, z_loss: float = 0.0):
    """Mean token CE in f32; labels < 0 are masked out."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse**2
    mask = labels >= 0
    return jnp.sum(loss * mask) / jnp.maximum(mask.sum(), 1)


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def shard(x, *spec):
    """with_sharding_constraint that tolerates running outside a mesh (and
    inside a 0.4.x fully-manual shard_map body, where compat strips the
    promoted axes from the spec)."""
    from repro.compat import sharding_constraint

    try:
        return sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x
