"""Per-family block assembly and the scanned, remat'd layer stack.

All 10 architectures share this spine:

  * params are initialized per layer with jax.vmap over layer keys, giving
    every leaf a leading L dimension; the forward pass is one lax.scan over
    that stack (small HLO, fast SPMD partitioning, flat live memory);
  * jax.checkpoint on the scan body implements activation rematerialization;
  * heterogeneous stacks (xlstm's mLSTM/sLSTM pattern) carry a static
    per-layer kind vector and lax.cond inside the body; zamba2's shared
    attention block lives outside the scanned stack (one param set) and is
    applied statically between scanned groups of ``shared_attn_every``
    Mamba2 layers (a per-layer lax.cond costs 4.4x, EXPERIMENTS.md SPerf);
  * sharding is injected through ShardCtx (which axes exist and their sizes)
    -- every weight picks a legal spec at init, and activations get
    with_sharding_constraint at family-specific cut points.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.models.attention import KVCache
from repro.models.config import ModelConfig
from repro.models.layers import (
    ShardCtx,
    cross_entropy,
    dense_param,
    embed_param,
    norm_param,
    rms_norm,
    shard,
)

KIND_IDS = {"attn": 0, "mlstm": 1, "slstm": 2, "mamba2": 3}


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _init_ffn(key, cfg, ctx):
    d, ff = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["w_gate"], s["w_gate"] = dense_param(ks[0], d, ff, ctx, dt)
    p["w_up"], s["w_up"] = dense_param(ks[1], d, ff, ctx, dt)
    p["w_down"], s["w_down"] = dense_param(ks[2], ff, d, ctx, dt, tp_dim="in")
    return p, s


def _init_layer(key, cfg: ModelConfig, ctx: ShardCtx):
    """One layer's params for the union of block kinds this family needs."""
    p, s = {}, {}
    ks = jax.random.split(key, 8)
    p["ln1"], s["ln1"] = norm_param(cfg.d_model, jnp.dtype(cfg.dtype))
    kinds = set(cfg.layer_kinds())
    if "attn" in kinds:
        p["attn"], s["attn"] = attn_lib.init_attention(ks[0], cfg, ctx)
        p["ln2"], s["ln2"] = norm_param(cfg.d_model, jnp.dtype(cfg.dtype))
        if cfg.n_experts:
            p["moe"], s["moe"] = moe_lib.init_moe(ks[1], cfg, ctx)
            if cfg.moe_dense_residual:
                p["ffn"], s["ffn"] = _init_ffn(ks[2], cfg, ctx)
        else:
            p["ffn"], s["ffn"] = _init_ffn(ks[2], cfg, ctx)
    if "mlstm" in kinds:
        p["mlstm"], s["mlstm"] = xlstm_lib.init_mlstm(ks[3], cfg, ctx)
    if "slstm" in kinds:
        p["slstm"], s["slstm"] = xlstm_lib.init_slstm(ks[4], cfg, ctx)
    if "mamba2" in kinds:
        p["mamba"], s["mamba"] = mamba_lib.init_mamba2(ks[5], cfg, ctx)
    return p, s


def init_stack(key, cfg: ModelConfig, ctx: ShardCtx):
    """All layers, vmapped init -> every leaf has leading dim L."""
    keys = jax.random.split(key, cfg.n_layers)
    p0, s0 = _init_layer(keys[0], cfg, ctx)  # structure + specs template
    stacked = jax.vmap(lambda k: _init_layer(k, cfg, ctx)[0])(keys)
    specs = jax.tree.map(
        lambda sp: P(*((None,) + tuple(sp))), s0,
        is_leaf=lambda x: isinstance(x, P),
    )
    return stacked, specs


# ---------------------------------------------------------------------------
# per-layer apply
# ---------------------------------------------------------------------------

def _apply_attn_layer(bp, x, cfg, *, mode, head_tp, seq_axes, dp_spec,
                      ep_axis=None, cache=None):
    h = rms_norm(x, bp["ln1"])
    new_cache = None
    if mode == "decode":
        a, new_cache = attn_lib.attention_decode(
            bp["attn"], h, cache, cfg, head_tp=head_tp, seq_axes=seq_axes,
            dp_spec=dp_spec)
    elif mode == "prefill":
        a, new_cache = attn_lib.prefill_cache(
            bp["attn"], h, cfg, head_tp=head_tp, seq_axes=seq_axes,
            dp_spec=dp_spec, max_len=cache.k.shape[1] if cache else None)
    else:
        a = attn_lib.attention_forward(
            bp["attn"], h, cfg, causal=not cfg.encoder_only,
            head_tp=head_tp, dp_spec=dp_spec)
    x = x + a
    h = rms_norm(x, bp["ln2"])
    aux = {}
    if cfg.n_experts:
        cap_axis = None if ep_axis is not None else "data"
        m, aux = moe_lib.moe_ffn(bp["moe"], h, cfg, ep_axis=ep_axis,
                                 cap_axis=cap_axis, dp_spec=dp_spec)
        if cfg.moe_dense_residual:
            m = m + _ffn(bp["ffn"], h)
        x = x + m
    else:
        x = x + _ffn(bp["ffn"], h)
    return x, new_cache, aux


def _ffn(fp, h):
    return (jax.nn.silu(h @ fp["w_gate"]) * (h @ fp["w_up"])) @ fp["w_down"]


# ---------------------------------------------------------------------------
# stack forward (train / prefill / decode)
# ---------------------------------------------------------------------------

class StackCaches(NamedTuple):
    """Union cache pytree; unused slots are () for a given family."""
    kv: Any = ()          # attn: KVCache with (L, ...) leaves
    mlstm: Any = ()       # (L, B, H, dh, dh)
    slstm: Any = ()       # ((L,B,d), (L,B,d))
    mamba: Any = ()       # Mamba2State with (L, ...) leaves
    shared_kv: Any = ()   # zamba2: KVCache with (n_inv, ...) leaves


def _layer_kind_array(cfg):
    return jnp.asarray([KIND_IDS[k] for k in cfg.layer_kinds()], jnp.int32)


def _constrain_tree(params, specs):
    """with_sharding_constraint over a (params, specs) pair of pytrees.

    Applied to the per-layer parameter slice INSIDE the scan body: the
    constraint's transpose applies the same sharding to the parameter
    cotangent, which is what keeps per-layer gradients in their FSDP shards
    (reduce-scatter) instead of replicated f32 all-reduces -- measured 80s ->
    sub-second on grok-1-314b train_4k (EXPERIMENTS.md SPerf).
    """
    if specs is None:
        return params

    def one(sp, p):
        from repro.compat import sharding_constraint

        try:
            return sharding_constraint(p, sp)
        except (ValueError, RuntimeError):
            return p

    return jax.tree.map(one, specs, params,
                        is_leaf=lambda x: isinstance(x, P))


def _strip_layer_dim(specs):
    if specs is None:
        return None
    return jax.tree.map(lambda sp: P(*tuple(sp)[1:]), specs,
                        is_leaf=lambda x: isinstance(x, P))


def stack_forward(stacked, shared_attn, x, cfg: ModelConfig, ctx, *,
                  mode: str, head_tp, seq_axes, dp_spec,
                  caches: Optional[StackCaches] = None, block_specs=None,
                  shared_specs=None):
    """Run all layers. mode: 'train' | 'prefill' | 'decode'.

    Returns (x, new_caches, aux). Caches are scanned alongside the layer
    params; zamba2's shared-attention KV cache rides in the scan carry. In
    'train' mode no caches are produced (dummy pass-throughs keep the scan
    signature static).
    """
    kinds = _layer_kind_array(cfg)
    layer_idx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    has_shared = cfg.shared_attn_every > 0 and shared_attn is not None
    ep_axis = ctx.axis("fsdp", cfg.n_experts) if cfg.n_experts else None
    per_layer_specs = _strip_layer_dim(block_specs)

    def body(carry, xs):
        x, shared_cache = carry
        bp, kind, li, layer_cache = xs
        bp = _constrain_tree(bp, per_layer_specs)
        new_cache = layer_cache
        dropped = jnp.zeros((), jnp.float32)

        if cfg.family in ("dense", "moe", "audio", "vlm"):
            x, kv, a = _apply_attn_layer(
                bp, x, cfg, mode=mode, head_tp=head_tp, seq_axes=seq_axes,
                dp_spec=dp_spec, ep_axis=ep_axis, cache=layer_cache)
            if kv is not None:
                new_cache = kv
            if "dropped_frac" in a:
                dropped = a["dropped_frac"].astype(jnp.float32)

        elif cfg.family == "ssm":
            h = rms_norm(x, bp["ln1"])
            if mode == "train":
                o = jax.lax.cond(
                    kind == KIND_IDS["slstm"],
                    lambda h: xlstm_lib.slstm_forward(bp["slstm"], h, cfg)[0],
                    lambda h: xlstm_lib.mlstm_forward(bp["mlstm"], h, cfg)[0],
                    h)
            else:
                ml_state, sl_state = layer_cache
                use = mode == "decode"

                def do_m(h):
                    o, st = xlstm_lib.mlstm_forward(
                        bp["mlstm"], h, cfg, state=ml_state if use else None)
                    return o, (st, sl_state)

                def do_s(h):
                    o, st = xlstm_lib.slstm_forward(
                        bp["slstm"], h, cfg, state=sl_state if use else None)
                    return o, (ml_state, st)

                o, new_cache = jax.lax.cond(
                    kind == KIND_IDS["slstm"], do_s, do_m, h)
            x = x + o

        elif cfg.family == "hybrid":
            h = rms_norm(x, bp["ln1"])
            o, st = mamba_lib.mamba2_forward(
                bp["mamba"], h, cfg,
                state=layer_cache if mode == "decode" else None)
            x = x + o
            if mode != "train":
                new_cache = st
        else:
            raise ValueError(cfg.family)
        return (x, shared_cache), (new_cache, dropped)

    if cfg.remat:
        body = jax.checkpoint(body)

    layer_caches = _scan_caches(caches, cfg)
    shared0 = caches.shared_kv if (caches is not None and has_shared) else ()

    if cfg.family == "hybrid" and has_shared:
        # Grouped execution: scan each run of ``shared_attn_every`` Mamba2
        # layers, then apply the shared attention block ONCE, statically.
        # (The earlier per-layer lax.cond formulation made the attention
        # branch part of every scanned layer: 4.4x the per-layer FLOPs on
        # zamba2 train_4k -- EXPERIMENTS.md SPerf iteration log.)
        L, k = cfg.n_layers, cfg.shared_attn_every
        bounds = list(range(0, L, k))
        new_layer_list, dropped_all = [], []
        shared_cache = shared0
        for g, lo in enumerate(bounds):
            hi = min(lo + k, L)
            sl = lambda a: a[lo:hi]
            grp_stack = jax.tree.map(sl, stacked)
            grp_caches = jax.tree.map(sl, layer_caches)
            # shared attention first (zamba2 places it at layers 0, k, 2k..)
            if mode == "train":
                x, _ = _apply_shared(shared_attn, x, cfg, mode, head_tp,
                                     seq_axes, dp_spec, None)
            else:
                this = KVCache(k=shared_cache.k[g], v=shared_cache.v[g],
                               length=shared_cache.length)
                x, nc = _apply_shared(shared_attn, x, cfg, mode, head_tp,
                                      seq_axes, dp_spec, this)
                shared_cache = KVCache(
                    k=_set(shared_cache.k, g, nc.k),
                    v=_set(shared_cache.v, g, nc.v),
                    length=shared_cache.length)
            (x, _), (grp_new, grp_drop) = jax.lax.scan(
                body, (x, ()), (grp_stack, kinds[lo:hi], layer_idx[lo:hi],
                                grp_caches),
                unroll=(hi - lo) if cfg.unroll_scans else 1)
            new_layer_list.append(grp_new)
            dropped_all.append(grp_drop)
        new_layer_caches = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_layer_list)
        dropped = jnp.concatenate(dropped_all)
        new_caches = _pack_caches(new_layer_caches, shared_cache, cfg)
        return x, new_caches, {"dropped_frac": dropped.mean()}

    (x, shared_cache), (new_layer_caches, dropped) = jax.lax.scan(
        body, (x, shared0), (stacked, kinds, layer_idx, layer_caches),
        unroll=cfg.n_layers if cfg.unroll_scans else 1)
    new_caches = _pack_caches(new_layer_caches, shared_cache, cfg)
    return x, new_caches, {"dropped_frac": dropped.mean()}


def _is_arr(x):
    return isinstance(x, jax.Array) or hasattr(x, "shape")


def _set(arr, i, val):
    return jax.lax.dynamic_update_index_in_dim(arr, val, i, axis=0)


def _apply_shared(sp, x, cfg, mode, head_tp, seq_axes, dp_spec, cache):
    h = rms_norm(x, sp["ln1"])
    if mode == "decode":
        a, nc = attn_lib.attention_decode(sp["attn"], h, cache, cfg,
                                          head_tp=head_tp, seq_axes=seq_axes,
                                          dp_spec=dp_spec)
    elif mode == "prefill":
        a, nc = attn_lib.prefill_cache(sp["attn"], h, cfg, head_tp=head_tp,
                                       seq_axes=seq_axes, dp_spec=dp_spec,
                                       max_len=cache.k.shape[1])
    else:
        a, nc = attn_lib.attention_forward(sp["attn"], h, cfg, causal=True,
                                           head_tp=head_tp, dp_spec=dp_spec), None
    x = x + a
    h2 = rms_norm(x, sp["ln2"])
    return x + _ffn(sp["ffn"], h2), nc


def _shared_invocations(cfg):
    if cfg.shared_attn_every <= 0:
        return 0
    return -(-cfg.n_layers // cfg.shared_attn_every)


def _scan_caches(caches: Optional[StackCaches], cfg):
    """Layer-cache pytree handed to scan as xs (leading dim L)."""
    if caches is None:
        # train mode: dummy per-layer zeros so the scan xs structure is fixed
        L = cfg.n_layers
        if cfg.family == "ssm":
            return (jnp.zeros((L, 1)), (jnp.zeros((L, 1)), jnp.zeros((L, 1))))
        return jnp.zeros((L, 1))
    if cfg.family == "ssm":
        return (caches.mlstm, caches.slstm)
    if cfg.family == "hybrid":
        return caches.mamba
    return caches.kv


def _pack_caches(new_layer_caches, shared_cache, cfg) -> StackCaches:
    if cfg.family == "ssm":
        ml, sl = new_layer_caches
        return StackCaches(mlstm=ml, slstm=sl)
    if cfg.family == "hybrid":
        return StackCaches(mamba=new_layer_caches, shared_kv=shared_cache)
    return StackCaches(kv=new_layer_caches)


def init_shared_attn(key, cfg, ctx):
    """zamba2's shared attention+FFN block (single param set)."""
    if cfg.shared_attn_every <= 0:
        return None, None
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["ln1"], s["ln1"] = norm_param(cfg.d_model, jnp.dtype(cfg.dtype))
    p["ln2"], s["ln2"] = norm_param(cfg.d_model, jnp.dtype(cfg.dtype))
    p["attn"], s["attn"] = attn_lib.init_attention(ks[0], cfg, ctx)
    p["ffn"], s["ffn"] = _init_ffn(ks[1], cfg, ctx)
    return p, s
