"""Architecture configuration shared by models/, configs/ and launch/."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    encoder_only: bool = False
    input_kind: str = "tokens"   # tokens | embeddings (audio/vlm stub frontends)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 2
    moe_dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0           # mamba2 N
    ssm_head_dim: int = 64       # mamba2 P
    ssm_expand: int = 2
    conv_width: int = 4
    slstm_every: int = 0         # xlstm: every k-th layer is sLSTM (0 = none)
    shared_attn_every: int = 0   # zamba2: shared attn block every k layers
    # --- numerics / scheduling ---
    dtype: str = "bfloat16"
    attn_chunk: int = 512        # query chunk for memory-efficient attention
    ssm_chunk: int = 256         # chunk for mLSTM / SSD scan
    loss_chunk: int = 2048       # sequence chunk for the CE loss
    remat: bool = True
    z_loss: float = 0.0
    # Fully unroll every lax.scan. Never for real execution -- this exists
    # for the dry-run cost probe: XLA's HloCostAnalysis counts while bodies
    # once, so exact FLOP/byte counts require a loop-free lowering
    # (launch/dryrun.py probes small layer counts unrolled and extrapolates).
    unroll_scans: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (no full-attention over the whole context).

        zamba2 qualifies: its Mamba2 backbone is linear; the single shared
        attention block holds the only full KV cache, which is O(S) memory
        and O(S) work per decoded token.
        """
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, e.g. ('attn',)*L or mLSTM/sLSTM pattern."""
        kinds = []
        for l in range(self.n_layers):
            if self.family == "ssm" and self.slstm_every:
                kinds.append("slstm" if (l % self.slstm_every == self.slstm_every - 1)
                             else "mlstm")
            elif self.family == "ssm":
                kinds.append("mlstm")
            elif self.family == "hybrid":
                kinds.append("mamba2")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def param_count(self) -> int:
        """Allocated parameter count (embedding + blocks + head).

        For mixed-kind SSM stacks (xlstm), every scanned layer carries the
        UNION of block parameter sets (the stack is one homogeneous lax.scan;
        the per-layer kind flag selects the live branch). The dead branch's
        weights are allocated but untrained -- counted here, excluded from
        ``active_param_count`` (which feeds MODEL_FLOPS). Recorded in
        DESIGN.md as a deliberate scan-homogeneity trade-off.
        """
        d, V, L = self.d_model, self.vocab, self.n_layers
        emb = V * d if (self.input_kind == "tokens" or self.has_decode) else 0
        head = d * V
        total = emb + head + d  # + final norm
        kinds_per_layer = self.layer_kinds()
        union = sorted(set(kinds_per_layer))
        effective = (union * L if len(union) > 1 else list(kinds_per_layer))
        total += sum(self._block_params(k) for k in effective)
        if self.family == "hybrid" and self.shared_attn_every:
            H, KV, hd = self.n_heads, self.n_kv_heads, self.head_dim
            total += (2 * d + d * H * hd + 2 * d * KV * hd + H * hd * d
                      + 3 * d * self.d_ff)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts; mixed SSM
        stacks: only each layer's live branch)."""
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        total = self.param_count()
        if self.n_experts:
            total -= L * (self.n_experts - self.top_k) * 3 * d * ff
        kinds = self.layer_kinds()
        union = sorted(set(kinds))
        if len(union) > 1:  # subtract each layer's dead branch
            sizes = {k: self._block_params(k) for k in union}
            for k in kinds:
                for other in union:
                    if other != k:
                        total -= sizes[other]
        return total

    def _block_params(self, kind: str) -> int:
        """Exact per-layer parameter count of one block kind (matches
        models/transformer._init_layer)."""
        d, ff = self.d_model, self.d_ff
        H, KV, hd = self.n_heads, self.n_kv_heads, self.head_dim
        if kind == "attn":
            blk = d + d  # ln1, ln2
            blk += d * H * hd + 2 * d * KV * hd + H * hd * d
            if self.qkv_bias:
                blk += H * hd + 2 * KV * hd
            if self.n_experts:
                blk += d * self.n_experts + self.n_experts * 3 * d * ff
                if self.moe_dense_residual:
                    blk += 3 * d * ff
            else:
                blk += 3 * d * ff
            return blk
        if kind == "mlstm":
            di = self.d_inner
            return d + d * 3 * di + d * 2 * self.n_heads + di * d
        if kind == "slstm":
            return d + 8 * d * d
        if kind == "mamba2":
            di = self.d_inner
            nheads = di // self.ssm_head_dim
            blk = d + d * (2 * di + 2 * self.ssm_state + nheads) + di * d
            return blk + self.conv_width * (di + 2 * self.ssm_state) + 3 * nheads
        raise ValueError(kind)
