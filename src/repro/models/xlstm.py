"""xLSTM blocks: chunkwise-parallel mLSTM and recurrent sLSTM.

mLSTM (matrix memory, the parallelizable block): per head h with dim dh,

    C_t = f_t C_{t-1} + i_t k_t v_t^T        (dh x dh matrix memory)
    y_t = C_t^T q_t

computed in a chunkwise-parallel form (intra-chunk quadratic with a decay
matrix, inter-chunk state carry via lax.scan over chunks) -- the same
machinery Mamba2's SSD uses, shared via ``chunked_gated_linear``. Training
cost is O(S * dh^2 / chunk + S * chunk * dh): sub-quadratic in S, which is
what qualifies xlstm-1.3b for the long_500k cell.

Simplifications vs. the paper (xLSTM, arXiv:2405.04517, 'unverified' tier):
sigmoid input gate instead of exponential-with-stabilizer, and the
key-normalizer n_t is dropped (sigmoid gates keep the state bounded; the
1/sqrt(dh) key scaling plays the stabilizing role). No separate output gate
projection on sLSTM. Recorded in DESIGN.md SArch-applicability.

sLSTM (scalar memory, strictly recurrent): lax.scan over time. Kept for
block-pattern fidelity; xlstm-1.3b uses 1 sLSTM per ``slstm_every`` layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_param, shard


# ---------------------------------------------------------------------------
# Shared chunked gated linear attention (used by mLSTM and Mamba2 SSD)
# ---------------------------------------------------------------------------

def chunked_gated_linear(q, k, v, log_f, i_gate, chunk: int, unroll: bool = False,
                         shared_qk: bool = False):
    """y_t = sum_{j<=t} (prod_{r=j+1..t} f_r) i_j (q_t . k_j) v_j, chunked.

    q, k: (B, S, H, dk); v: (B, S, H, dv); log_f, i_gate: (B, S, H).
    Returns (y (B,S,H,dv), final_state (B,H,dk,dv)).

    ``shared_qk``: Mamba2's B/C projections are shared across heads (q/k
    arrive head-broadcast); the intra-chunk score matmul is then computed
    ONCE per chunk instead of per head -- an H-fold FLOP cut on that term
    (measured on zamba2 train_4k, EXPERIMENTS.md SPerf).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, S)
    if S % c:
        c = S
    nc = S // c

    def resh(x):
        return jnp.moveaxis(x.reshape(B, nc, c, *x.shape[2:]), 1, 0)

    qs, ks, vs = resh(q), resh(k), resh(v)
    fs, is_ = resh(log_f), resh(i_gate)

    def body(state, xs):
        qc, kc, vc, fc, ic = xs                     # (B, c, H, *)
        F = jnp.cumsum(fc, axis=1)                  # (B, c, H) log decay
        # intra-chunk: D[t, j] = exp(F_t - F_j) * i_j  for j <= t
        dmat = F[:, :, None, :] - F[:, None, :, :]  # (B, c, c, H)
        tri = jnp.tril(jnp.ones((c, c), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        gates = jnp.exp(dmat) * ic[:, None, :, :]   # (B, c(t), c(j), H)
        if shared_qk:
            scores1 = jnp.einsum("btd,bjd->btj", qc[:, :, 0].astype(jnp.float32),
                                 kc[:, :, 0].astype(jnp.float32))
            scores = scores1[..., None]             # (B, c, c, 1) -> bcast H
        else:
            scores = jnp.einsum("bthd,bjhd->btjh", qc.astype(jnp.float32),
                                kc.astype(jnp.float32))
        intra = jnp.einsum("btjh,bjhv->bthv", scores * gates,
                           vc.astype(jnp.float32))
        # inter-chunk: y += exp(F_t) * q_t . state
        inter = jnp.einsum("bthd,bhdv->bthv", qc.astype(jnp.float32)
                           * jnp.exp(F)[..., None], state)
        # state' = exp(F_c) * state + sum_j exp(F_c - F_j) i_j k_j v_j^T
        last = F[:, -1:, :]                         # (B, 1, H)
        carry_gate = jnp.exp(last - F) * ic         # (B, c, H)
        upd = jnp.einsum("bjh,bjhd,bjhv->bhdv", carry_gate,
                         kc.astype(jnp.float32), vc.astype(jnp.float32))
        state = jnp.exp(last[:, 0, :])[..., None, None] * state + upd
        return state, intra + inter

    state0 = jnp.zeros((B, H, dk, dv), jnp.float32)
    state, ys = jax.lax.scan(body, state0, (qs, ks, vs, fs, is_),
                             unroll=nc if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, dv)
    return y.astype(v.dtype), state


def gated_linear_step(state, q, k, v, log_f, i_gate):
    """Single-token recurrent step. state: (B,H,dk,dv); q/k/v: (B,H,d*)."""
    f = jnp.exp(log_f)[..., None, None].astype(jnp.float32)
    upd = jnp.einsum("bhd,bhv->bhdv", k.astype(jnp.float32),
                     v.astype(jnp.float32)) * i_gate[..., None, None]
    state = f * state + upd
    y = jnp.einsum("bhdv,bhd->bhv", state, q.astype(jnp.float32))
    return state, y.astype(v.dtype)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg, ctx):
    d, di, H = cfg.d_model, cfg.d_inner, cfg.n_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["wqkv"], s["wqkv"] = dense_param(ks[0], d, 3 * di, ctx, dt)
    p["wgate"], s["wgate"] = dense_param(ks[1], d, 2 * H, ctx, dt, scale=0.02)
    p["wout"], s["wout"] = dense_param(ks[2], di, d, ctx, dt, tp_dim="in")
    return p, s


def mlstm_forward(p, x, cfg, state=None):
    """x: (B, S, d). state None -> chunked parallel; else one-step decode."""
    B, S, d = x.shape
    H = cfg.n_heads
    di = cfg.d_inner
    dh = di // H
    qkv = x @ p["wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, H, dh) / jnp.sqrt(dh).astype(x.dtype)
    v = v.reshape(B, S, H, dh)
    gates = (x @ p["wgate"]).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(gates[..., :H])
    i_gate = jax.nn.sigmoid(gates[..., H:])
    if state is None:
        y, fin = chunked_gated_linear(q, k, v, log_f, i_gate, cfg.ssm_chunk,
                                      unroll=cfg.unroll_scans)
    else:
        fin, y1 = gated_linear_step(state, q[:, 0], k[:, 0], v[:, 0],
                                    log_f[:, 0], i_gate[:, 0])
        y = y1[:, None]
    out = y.reshape(B, S, di) @ p["wout"]
    return out, fin


def mlstm_state(cfg, batch: int):
    H = cfg.n_heads
    dh = cfg.d_inner // H
    return jnp.zeros((batch, H, dh, dh), jnp.float32)


# ---------------------------------------------------------------------------
# sLSTM block (recurrent scalar memory)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, ctx):
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["wx"], s["wx"] = dense_param(ks[0], d, 4 * d, ctx, dt)
    p["wh"], s["wh"] = dense_param(ks[1], d, 4 * d, ctx, dt, scale=0.02)
    return p, s


def slstm_forward(p, x, cfg, state=None):
    """x: (B, S, d); recurrent over S. state = (c, h) each (B, d)."""
    B, S, d = x.shape
    xg = x @ p["wx"]                       # (B, S, 4d)

    def step(carry, xt):
        c, h = carry
        g = (xt + h.astype(xt.dtype) @ p["wh"]).astype(jnp.float32)
        i, f, z, o = jnp.split(g, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(z)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (c, h), h

    if state is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        h0 = jnp.zeros((B, d), jnp.float32)
    else:
        c0, h0 = state
    (c, h), ys = jax.lax.scan(step, (c0, h0), jnp.moveaxis(xg, 1, 0),
                              unroll=S if cfg.unroll_scans else 1)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)   # (B, S, d)
    return y, (c, h)


def slstm_state(cfg, batch: int):
    return (jnp.zeros((batch, cfg.d_model), jnp.float32),
            jnp.zeros((batch, cfg.d_model), jnp.float32))
