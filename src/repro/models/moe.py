"""Mixture-of-Experts FFN: top-k router, capacity, sort-based dispatch.

Dispatch is scatter-based (argsort by expert id + per-expert cumulative
slots) rather than one-hot einsum: O(T x d) memory instead of O(T x E x cap).
Tokens over capacity are dropped (standard capacity-factor semantics) and the
drop fraction is returned for logging.

Routing is ROW-LOCAL (vmapped per batch row, capacity per row) for training
so dispatch indices shard with the batch, and batch-global at decode (S=1)
where per-row capacity would reserve slots in every expert per sequence.
Sharding: experts are expert-parallel over the FSDP axis when E divides it
(arctic: 128 over 16; the (B,E,cap,d) dispatched tensor is resharded
B->'data' to E->'data', the canonical MoE all-to-all); otherwise (grok: 8
experts) storage stays 256-way FSDP with compute-time weight gathers. The
full derivation of this layout is the EXPERIMENTS.md SPerf hillclimb log
(79.9 s -> 4.8 s of per-step collectives on grok-1-314b train_4k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_param, shard


def init_moe(key, cfg, ctx):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p, s = {}, {}
    p["router"], s["router"] = dense_param(ks[0], d, E, ctx, jnp.float32,
                                           tp_dim="out", scale=0.02)
    s["router"] = P(None, None)  # tiny; keep replicated
    ep_axis = ctx.axis("fsdp", E)
    scale = 1.0 / jnp.sqrt(d)
    shape_in = (E, d, ff)
    shape_out = (E, ff, d)
    p["w_gate"] = jax.random.normal(ks[1], shape_in, dt) * scale
    p["w_up"] = jax.random.normal(ks[2], shape_in, dt) * scale
    p["w_down"] = jax.random.normal(ks[3], shape_out, dt) / jnp.sqrt(ff)
    if ep_axis:
        s["w_gate"] = s["w_up"] = P(ep_axis, None, ctx.axis("tp", ff))
        s["w_down"] = P(ep_axis, ctx.axis("tp", ff), None)
    else:
        s["w_gate"] = s["w_up"] = P(None, ctx.axis("fsdp", d), ctx.axis("tp", ff))
        s["w_down"] = P(None, ctx.axis("tp", ff), ctx.axis("fsdp", d))
    return p, s


def _route_row(tokens, tope, topw, E, k, cap):
    """Dispatch ONE batch row: (S,d),(S,k),(S,k) -> dispatched (E*cap, d),
    slot/src/wgt for the combine, keep mask. vmapped over the batch so every
    index op (sort, cumsum, scatter) is row-local -- with the batch sharded
    over 'data', GSPMD never materializes a replicated global routing chain
    (which cost 50 GB/layer f32 all-reduces in the global-sort formulation;
    EXPERIMENTS.md SPerf)."""
    S, d = tokens.shape
    eid = tope.reshape(-1)                                   # (S*k,)
    src = jnp.repeat(jnp.arange(S), k)
    wgt = topw.reshape(-1)
    order = jnp.argsort(eid)
    eid_s, src_s, wgt_s = eid[order], src[order], wgt[order]
    counts = jnp.bincount(eid, length=E)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(S * k) - offsets[eid_s]
    keep = pos_in_e < cap
    slot = jnp.where(keep, eid_s * cap + pos_in_e, E * cap)  # drop slot
    dispatched = jnp.zeros((E * cap, d), tokens.dtype).at[slot].set(
        tokens[src_s], mode="drop")
    return dispatched, slot, src_s, wgt_s, keep


def moe_ffn(p, x, cfg, *, ep_axis, cap_axis=None, dp_spec="data", rng=None):
    """x: (B, S, d) -> (B, S, d). Returns (out, aux) with load stats.

    Row-local routing + layout (measured on the dry-run, SPerf):
      * routing/dispatch is vmapped per batch row (capacity enforced per
        row, the standard per-device-capacity semantics), so the dispatch
        indices stay sharded with the batch;
      * EP case (E divides the FSDP axis; arctic): the dispatched tensor is
        resharded from (B->'data') to (E->'data'), which GSPMD implements as
        the canonical MoE all-to-all; expert compute is local;
      * non-EP case (grok, E=8 < 16): expert STORAGE stays 256-way FSDP
        (d x f over data x model) but compute uses weights gathered over
        'data' (0.6 GB/layer bf16 all-gather instead of 20-50 GB/layer f32
        activation all-reduces from a d-sharded contraction); expert FLOPs
        stay distributed over the batch shards. Weight-grad partials
        reduce-scatter back into the FSDP shards via the in-scan param
        constraint (transformer._constrain_tree).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    if S == 1 and B > 1:
        # decode: per-row capacity would reserve cap slots in EVERY expert
        # for every sequence (measured 2.4e5x the useful decode FLOPs on
        # arctic, EXPERIMENTS.md SPerf note) -- fold the batch into one
        # routing row so dispatch is global across the decode batch.
        out, aux = moe_ffn(p, x.reshape(1, B, d), cfg, ep_axis=ep_axis,
                           cap_axis=cap_axis, dp_spec=None, rng=rng)
        return out.reshape(B, S, d), aux
    x = shard(x, dp_spec, None, None)

    # router in bf16 operands / f32 accumulation (an f32 input cast would
    # drag the whole (B,S,d) cotangent to f32 on the way back)
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, k)                     # (B, S, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    cap = max(-(-int(S * k / E * cfg.capacity_factor) // 8) * 8, 8)
    dispatched, slot, src_s, wgt_s, keep = jax.vmap(
        lambda t, e, w: _route_row(t, e, w, E, k, cap))(x, tope, topw)
    dispatched = dispatched.reshape(B, E, cap, d)

    if ep_axis is None:
        # non-EP: batch-sharded expert compute with gathered weights
        dispatched = shard(dispatched, dp_spec, None, None, None)
        w_gate = shard(p["w_gate"], None, None, "model")
        w_up = shard(p["w_up"], None, None, "model")
        w_down = shard(p["w_down"], None, "model", None)
    else:
        # EP: all-to-all (B->'data')  ->  (E->'data')
        dispatched = shard(dispatched, None, ep_axis, None, None)
        w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", dispatched, w_gate))
    h = h * jnp.einsum("becd,edf->becf", dispatched, w_up)
    eo = jnp.einsum("becf,efd->becd", h, w_down)
    if ep_axis is None:
        eo = shard(eo, dp_spec, None, None, None)
    else:
        eo = shard(eo, dp_spec, None, None, None)  # reverse all-to-all
    eo = eo.reshape(B, E * cap, d)
    eo = jnp.concatenate([eo, jnp.zeros((B, 1, d), eo.dtype)], axis=1)

    def combine_row(eo_row, slot, src_s, wgt_s):
        gathered = eo_row[slot] * wgt_s[:, None].astype(eo_row.dtype)
        return jnp.zeros((S, d), eo_row.dtype).at[src_s].add(gathered)

    out = jax.vmap(combine_row)(eo, slot, src_s, wgt_s)
    out = shard(out, dp_spec, None, None)
    aux = {
        "dropped_frac": 1.0 - keep.mean(),
        "router_entropy": -(probs * jnp.log(probs + 1e-9)).sum(-1).mean(),
    }
    return out.astype(x.dtype), aux
