"""Mamba2 (SSD) block, chunked-scan form, for zamba2's backbone.

State-space duality form: per head h with head dim P and state dim N,

    S_t = exp(dt_t * A_h) S_{t-1} + dt_t * x_t B_t^T     (P x N state)
    y_t = S_t C_t + D_h x_t

which is exactly the gated-linear recurrence of xlstm.chunked_gated_linear
with q = C, k = B, v = dt * x, log_f = dt * A, i = 1 -- the two families
share one chunked kernel (DESIGN.md: one implementation spine).

Includes the causal depthwise conv (width ``conv_width``) on the x/B/C
stream, SiLU activations and the gated output projection, following the
Mamba2 block layout (arXiv:2405.21060; 'hf' tier via Zamba2 configs).
Decode keeps (conv window, SSM state) as the recurrent cache.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_param, shard
from repro.models.xlstm import chunked_gated_linear, gated_linear_step


class Mamba2State(NamedTuple):
    conv: jax.Array   # (B, W-1, conv_channels) rolling input window
    ssm: jax.Array    # (B, H, N, P) state (dk=N, dv=P in the shared kernel)


def _conv_channels(cfg):
    return cfg.d_inner + 2 * cfg.ssm_state


def init_mamba2(key, cfg, ctx):
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = di // cfg.ssm_head_dim
    dt_ = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p, s = {}, {}
    # in_proj -> [z (gate, di), x (di), B (N), C (N), dt (H)]
    p["win"], s["win"] = dense_param(ks[0], d, 2 * di + 2 * N + H, ctx, dt_)
    p["wout"], s["wout"] = dense_param(ks[1], di, d, ctx, dt_, tp_dim="in")
    p["conv_w"] = (
        jax.random.normal(ks[2], (cfg.conv_width, _conv_channels(cfg)), dt_) * 0.2
    )
    s["conv_w"] = jax.sharding.PartitionSpec(None, None)
    p["a_log"] = jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32))
    s["a_log"] = jax.sharding.PartitionSpec(None)
    p["d_skip"] = jnp.ones((H,), jnp.float32)
    s["d_skip"] = jax.sharding.PartitionSpec(None)
    p["dt_bias"] = jnp.zeros((H,), jnp.float32)
    s["dt_bias"] = jax.sharding.PartitionSpec(None)
    return p, s


def _causal_conv(u, w, prev=None):
    """Depthwise causal conv. u: (B, S, C); w: (W, C); prev: (B, W-1, C)."""
    W = w.shape[0]
    if prev is None:
        prev = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([prev, u], axis=1)          # (B, S+W-1, C)
    out = sum(ext[:, i : i + u.shape[1]] * w[i] for i in range(W))
    window = ext[:, -(W - 1):] if W > 1 else prev
    return out, window


def mamba2_forward(p, x, cfg, state: Mamba2State | None = None):
    """x: (B, S, d). state None -> chunked scan; else one-step decode."""
    B, S, d = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    H = di // cfg.ssm_head_dim
    P = cfg.ssm_head_dim

    proj = x @ p["win"]
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt_pre = jnp.split(xbc_dt, [di + 2 * N], axis=-1)
    conv_out, conv_win = _causal_conv(
        xbc, p["conv_w"], None if state is None else state.conv
    )
    conv_out = jax.nn.silu(conv_out)
    xs, Bmat, Cmat = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["a_log"])                                          # (H,)
    log_f = dt * A                                                    # (B,S,H)

    xs_h = xs.reshape(B, S, H, P)
    v = xs_h * dt[..., None].astype(xs.dtype)                         # dt * x
    k = jnp.broadcast_to(Bmat[:, :, None, :], (B, S, H, N))
    q = jnp.broadcast_to(Cmat[:, :, None, :], (B, S, H, N))
    ones = jnp.ones_like(dt)

    if state is None:
        y, ssm = chunked_gated_linear(q, k, v, log_f, ones, cfg.ssm_chunk,
                                      unroll=cfg.unroll_scans, shared_qk=True)
    else:
        ssm, y1 = gated_linear_step(state.ssm, q[:, 0], k[:, 0], v[:, 0],
                                    log_f[:, 0], ones[:, 0])
        y = y1[:, None]
    y = y + xs_h * p["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    out = y @ p["wout"]
    return out, Mamba2State(conv=conv_win, ssm=ssm)


def mamba2_state(cfg, batch: int) -> Mamba2State:
    H = cfg.d_inner // cfg.ssm_head_dim
    return Mamba2State(
        conv=jnp.zeros((batch, cfg.conv_width - 1, _conv_channels(cfg)),
                       jnp.dtype(cfg.dtype)),
        ssm=jnp.zeros((batch, H, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
    )
