"""GQA attention: chunked train/prefill path + KV-cache decode path.

Memory-efficient training attention: a lax.scan over query chunks so the
(B, chunk, H, S) score block is the only attention intermediate alive --
required for prefill_32k and compatible with remat (the block is recomputed
in the backward pass).

Sharding: heads are TP-sharded when n_heads divides the model axis
(with_sharding_constraint on q/k/v); otherwise heads stay replicated and the
KV cache's sequence dimension is model-sharded at decode (GSPMD inserts the
partial-softmax all-reduces). Decisions are made from the config by
transformer.py and threaded here as ``head_tp``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import apply_rope, dense_param, bias_param, shard


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_max, KV, hd)
    v: jax.Array          # (B, S_max, KV, hd)
    length: jax.Array     # () int32 -- tokens already in the cache


def init_attention(key, cfg, ctx):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["wq"], s["wq"] = dense_param(ks[0], d, H * hd, ctx, dt)
    p["wk"], s["wk"] = dense_param(ks[1], d, KV * hd, ctx, dt)
    p["wv"], s["wv"] = dense_param(ks[2], d, KV * hd, ctx, dt)
    p["wo"], s["wo"] = dense_param(ks[3], H * hd, d, ctx, dt, tp_dim="in")
    if cfg.qkv_bias:
        p["bq"], s["bq"] = bias_param(H * hd, ctx, dt, tp=True)
        p["bk"], s["bk"] = bias_param(KV * hd, ctx, dt, tp=True)
        p["bv"], s["bv"] = bias_param(KV * hd, ctx, dt, tp=True)
    return p, s


def _qkv(p, x, cfg):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (
        q.reshape(B, S, H, hd),
        k.reshape(B, S, KV, hd),
        v.reshape(B, S, KV, hd),
    )


def _sdpa_block(qc, k, v, mask, cfg):
    """qc: (B, c, H, hd) vs full k/v: (B, S, KV, hd); mask (c, S) or None."""
    B, c, H, hd = qc.shape
    KV = k.shape[2]
    G = H // KV
    qg = qc.reshape(B, c, KV, G, hd)
    scores = jnp.einsum(
        "bckgh,bskh->bckgs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(hd).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask[None, :, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bckgs,bskh->bckgh", w, v.astype(jnp.float32))
    return out.reshape(B, c, H, hd).astype(qc.dtype)


def attention_forward(p, x, cfg, *, causal: bool, head_tp: Optional[str],
                      dp_spec, positions=None):
    """Full-sequence attention (train / prefill). x: (B, S, d)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, dp_spec, None, head_tp, None)
    k = shard(k, dp_spec, None, head_tp if cfg.n_kv_heads == cfg.n_heads else None, None)
    v = shard(v, dp_spec, None, head_tp if cfg.n_kv_heads == cfg.n_heads else None, None)

    chunk = min(cfg.attn_chunk, S)
    if S % chunk:
        chunk = S  # fall back to unchunked for odd smoke-test lengths
    nc = S // chunk
    qs = q.reshape(B, nc, chunk, cfg.n_heads, cfg.head_dim)
    pos_k = jnp.arange(S)

    def body(_, xs):
        qc, ci = xs
        if causal:
            pos_q = ci * chunk + jnp.arange(chunk)
            mask = pos_k[None, :] <= pos_q[:, None]
        else:
            mask = None
        return None, _sdpa_block(qc, k, v, mask, cfg)

    _, outs = jax.lax.scan(
        body, None, (jnp.moveaxis(qs, 1, 0), jnp.arange(nc)),
        unroll=nc if cfg.unroll_scans else 1,
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"]


def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> KVCache:
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return KVCache(
        k=jnp.zeros((batch, max_len, KV, hd), dtype),
        v=jnp.zeros((batch, max_len, KV, hd), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def cache_spec(cfg, seq_axes) -> KVCache:
    """PartitionSpec pytree for the KV cache; sequence over ``seq_axes``."""
    s = P("data", seq_axes, None, None)
    return KVCache(k=s, v=s, length=P())


def attention_decode(p, x, cache: KVCache, cfg, *, head_tp, seq_axes, dp_spec):
    """One-token decode. x: (B, 1, d). Returns (out (B,1,d), new cache)."""
    B = x.shape[0]
    q, k, v = _qkv(p, x, cfg)
    pos = cache.length
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), pos, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), pos, axis=1)
    new_k = shard(new_k, dp_spec, seq_axes, None, None)
    new_v = shard(new_v, dp_spec, seq_axes, None, None)
    S = cache.k.shape[1]
    valid = jnp.arange(S)[None, :] <= pos          # (1, S)
    out = _sdpa_block(q, new_k, new_v, valid, cfg)  # (B, 1, H, hd)
    out = out.reshape(B, 1, cfg.n_heads * cfg.head_dim) @ p["wo"]
    return out, KVCache(k=new_k, v=new_v, length=pos + 1)


def prefill_cache(p, x, cfg, *, head_tp, seq_axes, dp_spec, max_len=None):
    """Prefill: full forward that also materializes the cache."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    positions = jnp.arange(S)[None, :]
    k_r = apply_rope(k, positions, cfg.rope_theta)
    out = attention_forward(p, x, cfg, causal=not cfg.encoder_only,
                            head_tp=head_tp, dp_spec=dp_spec)
    max_len = max_len or S
    ck = jnp.zeros((B, max_len, cfg.n_kv_heads, cfg.head_dim), k.dtype)
    cv = jnp.zeros_like(ck)
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k_r, 0, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v, 0, axis=1)
    ck = shard(ck, dp_spec, seq_axes, None, None)
    cv = shard(cv, dp_spec, seq_axes, None, None)
    return out, KVCache(k=ck, v=cv, length=jnp.asarray(S, jnp.int32))
