"""Version portability shims for jax APIs that moved between releases.

The repo targets the current jax (``jax.shard_map`` with ``check_vma`` /
``axis_names``, ``jax.sharding.AxisType``) but must also run on the 0.4.x
line this container ships, where shard_map lives in ``jax.experimental``
with the (check_rep, auto) spelling. Everything here is a thin argument
translation -- semantics are identical.

Mesh construction portability lives in ``repro.launch.mesh.make_mesh_compat``
(it is launch-flavored and must not import jax device state early).
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
                  axis_names=None):
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)

else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
                  axis_names=None):
        # new-jax axis_names lists the MANUAL axes; old-jax `auto` lists the
        # complement. check_vma maps to check_rep (default True, like both
        # jax spellings). 0.4.x raises NotImplementedError for check_rep=True
        # with a non-empty auto set, so partial-manual maps drop the check
        # there (new jax still honors it).
        auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
                if axis_names is not None else frozenset())
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs,
                          check_rep=check_vma and not auto,
                          auto=auto)
