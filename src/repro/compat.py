"""Version portability shims for jax APIs that moved between releases.

The repo targets the current jax (``jax.shard_map`` with ``check_vma`` /
``axis_names``, ``jax.sharding.AxisType``) but must also run on the 0.4.x
line this container ships, where shard_map lives in ``jax.experimental``
with the (check_rep, auto) spelling. Everything here is a thin argument
translation -- semantics are identical.

Mesh construction portability lives in ``repro.launch.mesh.make_mesh_compat``
(it is launch-flavored and must not import jax device state early).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec

# Axis names that are MANUAL in the enclosing shard_map body because the
# 0.4.x lowering below promoted a partial-manual map to fully manual. A
# with_sharding_constraint naming such an axis is legal on new jax (the
# axis is still GSPMD-auto there) but raises at lowering time on 0.4.x
# ("Axis ... is also found in manual_axes"); constraint sites consult
# ``sharding_constraint`` so those entries are dropped only where -- and
# only on the jax line where -- they became manual.
_MANUAL_AXES = threading.local()


def manual_axes_in_effect() -> frozenset:
    """Mesh axes the current trace context made manual via the 0.4.x
    fully-manual lowering (empty on new jax and outside shard_map)."""
    return getattr(_MANUAL_AXES, "axes", frozenset())


@contextlib.contextmanager
def _manual_axes_ctx(axes: frozenset):
    prev = manual_axes_in_effect()
    _MANUAL_AXES.axes = prev | axes
    try:
        yield
    finally:
        _MANUAL_AXES.axes = prev


def strip_manual_axes(spec: PartitionSpec) -> PartitionSpec:
    """Drop PartitionSpec entries that name currently-manual axes."""
    manual = manual_axes_in_effect()
    if not manual:
        return spec

    def one(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a not in manual)
            return kept if kept else None
        return None if entry in manual else entry

    return PartitionSpec(*(one(e) for e in spec))


def sharding_constraint(x, spec: PartitionSpec):
    """``with_sharding_constraint`` portable into 0.4.x fully-manual bodies.

    Entries over axes the compat lowering made manual are stripped (the
    data is already per-device there); if that leaves no named axes, the
    constraint is skipped entirely rather than lowered as an empty
    constraint inside a manual context. Outside such bodies the call
    passes through UNCHANGED -- an all-None spec still lowers an explicit
    replicate constraint, exactly as the raw jax call would.
    """
    if manual_axes_in_effect():
        spec = strip_manual_axes(spec)
        if all(e is None for e in spec):
            return x
    return jax.lax.with_sharding_constraint(x, spec)


if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
                  axis_names=None):
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)

else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
                  axis_names=None):
        # new-jax axis_names lists the MANUAL axes; old-jax `auto` lists the
        # complement. check_vma maps to check_rep (default True, like both
        # jax spellings).
        #
        # Partial-manual (axis_names a strict subset of the mesh axes) is
        # NOT forwarded as a non-empty `auto` set here: the 0.4.x SPMD
        # partitioner crashes on that composition (spmd_partitioner.cc
        # "Check failed: target.IsManualSubgroup() == sharding()
        # .IsManualSubgroup()" -- the partial-manual subgroup sharding of a
        # shard_map operand meets a non-subgroup target sharding). Instead
        # the map is lowered FULLY manual: the specs already mention only
        # the manual axes, so the unmentioned axes simply replicate their
        # block per device and every collective the body runs (psum/pmean/
        # all_gather over its explicit axis names) is unchanged. Semantics
        # are identical -- the auto axes lose compiler-chosen sharding
        # inside the body (they compute their block redundantly), which is
        # a performance trade on the 0.4.x line only; new jax keeps true
        # partial-manual above. check_rep must be off in this mode: specs
        # of a partial-manual caller make no replication claims about the
        # now-manual axes.
        partial_manual = (axis_names is not None
                          and frozenset(mesh.axis_names)
                          != frozenset(axis_names))
        if partial_manual:
            inner, all_axes = f, frozenset(mesh.axis_names)

            def f(*args):
                # announce the promoted axes so sharding_constraint can
                # strip spec entries that would now name a manual axis
                with _manual_axes_ctx(all_axes):
                    return inner(*args)

        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs,
                          check_rep=check_vma and not partial_manual,
                          auto=frozenset())
