"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 -- GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab=152064, qkv_bias=True, rope_theta=1e6,
)

REDUCED = ModelConfig(
    name="qwen2.5-32b-reduced", family="dense",
    n_layers=2, d_model=160, n_heads=5, n_kv_heads=1,
    d_ff=320, vocab=512, qkv_bias=True, attn_chunk=32, remat=False,
)
