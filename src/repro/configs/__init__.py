"""Architecture registry: the paper's own workload + a generic LM smoke arch.

The seed's 10 published-LLM configs (qwen/grok/arctic/...) were unrelated
to the self-join system and were pruned (PR 3); ``smoke_lm`` is the single
generic stand-in that keeps the LM substrate (models/, train/, launch/)
driver-testable. Each remaining ``configs/<arch>.py`` exports:

    CONFIG   -- the arch's full configuration
    REDUCED  -- a small same-family config for CPU smoke tests

``selfjoin`` (the paper's system) carries its own SHAPES/workloads; the LM
shape-cell machinery (dry-run lowering grid) is retained for the smoke
arch.
"""
from __future__ import annotations

import dataclasses
import importlib

ARCHS = [
    "smoke_lm",
]

# canonical ids -> module names
ALIASES = {
    "smoke-lm": "smoke_lm",
    "selfjoin": "selfjoin",
}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def get_config(arch: str, *, reduced: bool = False):
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.REDUCED if reduced else mod.CONFIG


def cell_plan(arch: str):
    """List of (ShapeCell, skip_reason|None) for an architecture."""
    cfg = get_config(arch)
    plan = []
    for cell in SHAPES:
        skip = None
        if cell.kind == "decode" and not cfg.has_decode:
            skip = "encoder-only: no decode step"
        elif cell.name == "long_500k" and not cfg.sub_quadratic:
            skip = ("full attention is quadratic at 500k context; "
                    "run only for SSM/hybrid (DESIGN.md)")
        elif cell.name == "prefill_32k" and not cfg.has_decode:
            skip = None  # encoder: prefill cell = encoder forward
        plan.append((cell, skip))
    return plan


def all_cells():
    """Every (arch, cell, skip) across the registry."""
    out = []
    canon = {v: k for k, v in ALIASES.items()}
    for arch in ARCHS:
        for cell, skip in cell_plan(arch):
            out.append((canon[arch], cell, skip))
    return out
