"""Architecture registry: the 10 assigned archs + the paper's own workload.

Each ``configs/<arch>.py`` exports:
    CONFIG   -- the exact published configuration (source tier in docstring)
    REDUCED  -- a small same-family config for CPU smoke tests

Shape cells (LM family): seq_len x global_batch per the assignment;
``decode_*``/``long_*`` lower ``serve_step`` (one token against a KV cache of
seq_len), not ``train_step``. Skips (encoder-only decode, full-attention
long_500k) are encoded in ``cell_plan`` and mirrored in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

ARCHS = [
    "qwen2_72b",
    "qwen1_5_0_5b",
    "qwen2_5_32b",
    "stablelm_12b",
    "arctic_480b",
    "grok_1_314b",
    "xlstm_1_3b",
    "hubert_xlarge",
    "llava_next_34b",
    "zamba2_1_2b",
]

# canonical ids from the assignment -> module names
ALIASES = {
    "qwen2-72b": "qwen2_72b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "qwen2.5-32b": "qwen2_5_32b",
    "stablelm-12b": "stablelm_12b",
    "arctic-480b": "arctic_480b",
    "grok-1-314b": "grok_1_314b",
    "xlstm-1.3b": "xlstm_1_3b",
    "hubert-xlarge": "hubert_xlarge",
    "llava-next-34b": "llava_next_34b",
    "zamba2-1.2b": "zamba2_1_2b",
    "selfjoin": "selfjoin",
}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def get_config(arch: str, *, reduced: bool = False):
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.REDUCED if reduced else mod.CONFIG


def cell_plan(arch: str):
    """List of (ShapeCell, skip_reason|None) for an architecture."""
    cfg = get_config(arch)
    plan = []
    for cell in SHAPES:
        skip = None
        if cell.kind == "decode" and not cfg.has_decode:
            skip = "encoder-only: no decode step"
        elif cell.name == "long_500k" and not cfg.sub_quadratic:
            skip = ("full attention is quadratic at 500k context; "
                    "run only for SSM/hybrid (DESIGN.md)")
        elif cell.name == "prefill_32k" and not cfg.has_decode:
            skip = None  # encoder: prefill cell = encoder forward
        plan.append((cell, skip))
    return plan


def all_cells():
    """Every (arch, cell, skip) across the assignment (40 logical cells)."""
    out = []
    for arch in ARCHS:
        a = arch.replace("_", "-")
        # restore canonical spelling
        canon = {v: k for k, v in ALIASES.items()}[arch]
        for cell, skip in cell_plan(arch):
            out.append((canon, cell, skip))
    return out
