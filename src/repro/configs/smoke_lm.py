"""smoke-lm: a tiny dense transformer config for LM-substrate smoke tests.

The seed repo carried 10 published LLM configs (qwen/grok/arctic/...)
unrelated to the paper's self-join system; they were pruned (PR 3) to cut
test collection/runtime. This single generic config keeps the LM substrate
(models/, train/, launch/train.py, launch/serve.py --arch) exercisable by
the driver and distributed tests without re-importing that registry.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smoke-lm", family="dense",
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
    d_ff=1024, vocab=8192, qkv_bias=True,
)

REDUCED = ModelConfig(
    name="smoke-lm-reduced", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=512, qkv_bias=True, attn_chunk=32, remat=False,
)
