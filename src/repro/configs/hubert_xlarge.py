"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504
-- encoder-only, same arch as w2v2. [arXiv:2106.07447; unverified]

Modality frontend (conv feature extractor) is a STUB: input_specs() provides
precomputed frame embeddings (B, S, d_model); vocab=504 is the HuBERT
cluster-target codebook. Encoder-only -> no decode shapes; prefill_32k
lowers the encoder forward.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, encoder_only=True, input_kind="embeddings",
)

REDUCED = ModelConfig(
    name="hubert-xlarge-reduced", family="audio",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=64, encoder_only=True, input_kind="embeddings",
    attn_chunk=32, remat=False,
)
