"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192
vocab=32000, ssm_state=64 -- Mamba2 + shared attn blocks.
[arXiv:2411.15242; hf]

38 Mamba2 layers; ONE shared attention+FFN block (single param set) applied
every 6 layers (7 invocations). Hybrid is long_500k-eligible: the Mamba2
backbone is linear and only the shared block holds a (per-invocation) KV
cache. Zamba2's per-invocation LoRA deltas on the shared block are omitted
(noted in DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, ssm_state=64, ssm_head_dim=64,
    shared_attn_every=6,
    # ssm_chunk=64 balances the intra-chunk quadratic term against the
    # state-passing term (hillclimb iteration 2, EXPERIMENTS.md SPerf)
    ssm_chunk=64,
)

REDUCED = ModelConfig(
    name="zamba2-1.2b-reduced", family="hybrid",
    n_layers=4, d_model=128, n_heads=8, n_kv_heads=8,
    d_ff=256, vocab=512, ssm_state=16, ssm_head_dim=16,
    shared_attn_every=2, ssm_chunk=16, attn_chunk=32, remat=False,
)
