"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2. [hf:xai-org/grok-1; unverified]

Note: 8 experts do not divide the 16-way data axis -> expert weights shard
over (d_model x d_ff) = ('data' x 'model') instead of expert-parallel.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072,
    n_experts=8, top_k=2,
)

REDUCED = ModelConfig(
    name="grok-1-314b-reduced", family="moe",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=128, vocab=512, n_experts=4, top_k=2,
    attn_chunk=32, remat=False,
)
