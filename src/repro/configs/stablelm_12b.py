"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2-1_6b; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13824, vocab=100352,
)

REDUCED = ModelConfig(
    name="stablelm-12b-reduced", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=512, attn_chunk=32, remat=False,
)
