"""xlstm-1.3b [ssm]: 48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304 --
sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

Block pattern: one sLSTM every 8 layers (6 of 48), rest mLSTM, expand=2.
mLSTM runs chunkwise-parallel (sub-quadratic -> long_500k eligible);
sLSTM is recurrent (lax.scan over time).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, slstm_every=8, ssm_expand=2,
)

REDUCED = ModelConfig(
    name="xlstm-1.3b-reduced", family="ssm",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=512, slstm_every=4, ssm_expand=2, ssm_chunk=16,
    remat=False,
)
