"""The paper's own workload as a selectable 'architecture'.

Shapes mirror the paper's datasets (Table I): |D| in {2M, 10M}, n in 2-6,
uniform [0,100]^n (the grid index's worst case, paper SVI-C). The
distributed step is core/distributed.py's slab join; the mesh's first axis
(pod x data flattened to 'slab') partitions space, 'model' parallelizes
stencil offsets.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SelfJoinConfig:
    name: str = "selfjoin"
    n_dims: int = 6
    eps: float = 2.0
    n_points: int = 2_000_000
    unicomp: bool = True
    halo_frac: float = 0.25     # halo capacity as fraction of slab size
    max_per_cell: int = 64
    dtype: str = "float64"      # the paper's precision


CONFIG = SelfJoinConfig()
REDUCED = SelfJoinConfig(name="selfjoin-reduced", n_points=4096, eps=5.0,
                         max_per_cell=32)

# dry-run cells for the self-join workload: (name, n_points, n_dims, eps)
SHAPES = (
    ("syn2d2m", 2_000_000, 2, 1.0),
    ("syn6d2m", 2_000_000, 6, 2.0),
    ("syn2d10m", 10_000_000, 2, 0.4),
    ("syn6d10m", 10_000_000, 6, 1.5),
)
