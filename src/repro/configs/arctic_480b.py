"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128e top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]

Note: n_heads=56 does not divide the 16-way model axis; attention runs with
replicated heads and the weights shard on the fused head*dim axis (448/dev).
Experts (128) are expert-parallel over the 16-way data axis.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    n_experts=128, top_k=2, moe_dense_residual=True,
)

REDUCED = ModelConfig(
    name="arctic-480b-reduced", family="moe",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=96, vocab=512, n_experts=8, top_k=2, moe_dense_residual=True,
    attn_chunk=32, remat=False,
)
