"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 -- anyres tiling. [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]

Modality frontend (ViT + anyres tile packer) is a STUB: input_specs()
provides precomputed patch+text embeddings (B, S, d_model) for train/prefill;
decode embeds generated tokens through the LM embedding table.
n_heads=56 does not divide the model axis -> replicated-head attention.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, input_kind="embeddings",
)

REDUCED = ModelConfig(
    name="llava-next-34b-reduced", family="vlm",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=512, input_kind="embeddings", attn_chunk=32, remat=False,
)
