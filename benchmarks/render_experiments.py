"""Render EXPERIMENTS.md from results/ artifacts (dryrun.json, bench/*.json).

Regenerate with:
    PYTHONPATH=src python -m benchmarks.render_experiments
"""
from __future__ import annotations

import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
RESULTS = os.path.join(ROOT, "results")


def load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def load_bench(name):
    path = os.path.join(RESULTS, "bench", f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def fmt(x, digits=3):
    if x is None:
        return "--"
    if isinstance(x, str):
        return x
    if x == 0:
        return "0"
    if abs(x) >= 0.01 and abs(x) < 1e4:
        return f"{x:.{digits}g}"
    return f"{x:.2e}"


def roofline_table(data, mesh):
    lines = [
        "| arch | shape | compute s | memory s | collective s | bound | "
        "useful frac | bytes/dev (peak est) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(data):
        if key.startswith("_") or not key.endswith("|" + mesh):
            continue
        arch, shape, _ = key.split("|")
        v = data[key]
        if "skipped" in v:
            lines.append(f"| {arch} | {shape} | SKIP | | | | | "
                         f"{v['skipped'][:60]} |")
            continue
        if "roofline" not in v:
            lines.append(f"| {arch} | {shape} | ERROR | | | | | |")
            continue
        r = v["roofline"]
        mc = v.get("model_check", {})
        mem = v.get("memory_analysis", {})
        peak = mem.get("temp_size_in_bytes")
        peak_s = f"{peak/1e9:.1f} GB" if isinstance(peak, int) else "--"
        lines.append(
            f"| {arch} | {shape} | {fmt(r['compute_s'])} | "
            f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
            f"{r['bottleneck']} | {fmt(mc.get('useful_fraction'), 2)} | "
            f"{peak_s} |")
    return "\n".join(lines)


def dryrun_counts(data):
    ok = sum(1 for k, v in data.items()
             if not k.startswith("_") and "roofline" in v)
    skip = sum(1 for k, v in data.items()
               if not k.startswith("_") and "skipped" in v)
    err = sum(1 for k, v in data.items()
              if not k.startswith("_") and "error" in v)
    return ok, skip, err


def bench_section():
    out = []
    f7, f8, f9 = load_bench("fig7"), load_bench("fig8"), load_bench("fig9")
    t2 = load_bench("table2")
    f1 = load_bench("fig1")
    if f7:
        out.append(f"- **Fig. 7 (GPU-SJ vs CPU-RTREE)**: average speedup "
                   f"**{f7['avg_speedup']:.1f}x** over {len(f7['rows'])} "
                   f"(dataset, eps) cells at CPU scale "
                   f"(paper: 26.9x, TITAN X vs 1 CPU thread). Same "
                   f"direction, larger margin here because the reference is "
                   f"a python-loop R-tree on one core while GPU-SJ's sweep "
                   f"is vectorized.")
    if f8:
        out.append(f"- **Fig. 8 (GPU-SJ vs Super-EGO)**: average speedup "
                   f"**{f8['avg_speedup']:.2f}x**, wins {f8['wins']}/"
                   f"{len(f8['rows'])} (paper: 2.38x vs 32 threads; ours is "
                   f"single-threaded EGO vs vectorized sweep).")
    if f9:
        by = ", ".join(f"n={n}: {r:.2f}x" for n, r in f9["by_dim"].items())
        out.append(f"- **Fig. 9 (UNICOMP ratio without/with)**: {by} "
                   f"(paper: 1-1.5x at n<=3, up to >2x at n>=5; we "
                   f"reproduce <2x at low n and the rising trend with "
                   f"dimension -- the structural driver, the halved "
                   f"offset count, is exact: (3^n+1)/2 vs 3^n).")
    if t2:
        rows = t2["rows"]
        out.append("- **Table II analogue (work metrics)**: "
                   + "; ".join(
                       f"{r['dataset']}: cells {r['cells_ratio']:.2f}x, "
                       f"cands {r['cand_ratio']:.2f}x, pad-eff "
                       f"{r['pad_efficiency']:.3f}" for r in rows)
                   + ". UNICOMP's ~2x work cut is confirmed in the dense "
                     "synthetic regimes; the low pad efficiency at high n "
                     "motivated the compaction optimization (SPerf).")
    if f1:
        out.append("- **Fig. 1 (motivation)**: R-tree self-join time and "
                   "mean neighbors vs dimension reproduce the U-shape: "
                   + ", ".join(f"n={r['n']}: {r['rtree_s']:.2f}s/"
                               f"{r['mean_neighbors']:.1f}nb"
                               for r in f1["rows"]) + ".")
    return "\n".join(out)


HEADER = """# EXPERIMENTS

Paper: *GPU Accelerated Self-join for the Distance Similarity Metric*
(Gowanlock & Karsin, 2018). Design and hardware-adaptation notes: DESIGN.md.
All artifacts regenerable:

```
PYTHONPATH=src pytest tests/                                        # correctness
PYTHONPATH=src python -m benchmarks.run                             # paper figures
PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \\
    --out results/dryrun.json                                       # dry-run+roofline
PYTHONPATH=src python -m benchmarks.render_experiments              # this file
```

## Paper-claim validation (faithful reproduction)

Correctness: every implementation (grid GPU-SJ with/without UNICOMP, the
batched driver, brute force, CPU-RTREE, Super-EGO-style) produces identical
pair sets on every tested dataset/eps -- hypothesis-tested against the
O(N^2) oracle (tests/test_selfjoin.py), the same consistency check the paper
used. The UNICOMP stencil is proven equivalent to Alg. 2's odd/even rule
(each unordered adjacent cell pair evaluated exactly once;
test_paper_unicomp_rule_equivalent_to_half_stencil).

Comparative claims at CPU-container scale (|D| ~2e4-6e4; --full restores
paper sizes on real hardware):

"""

DRYRUN_INTRO = """
## SDry-run (multi-pod)

`launch/dryrun.py` lowers + compiles every (arch x shape) cell on the
single-pod mesh (16,16)=('data','model') AND the multi-pod mesh
(2,16,16)=('pod','data','model') -- 512 host-platform placeholder devices;
for the self-join workload the meshes are (16,16)/(32,16) with
('slab','model') (slab = pod x data flattened; spatial slab decomposition
with k-hop eps-halo exchange via collective_permute, DESIGN.md S3).

Status: **{ok} cells compiled OK, {skip} skipped (recorded reasons), {err}
failed** across both meshes. Skips: `long_500k` for the 7 pure
full-attention archs (quadratic at 500k context; runs for xlstm-1.3b's
linear mLSTM and zamba2's Mamba2 hybrid) and `decode_32k`/`long_500k` for
encoder-only hubert-xlarge. The multi-pod pass proves the 'pod' axis shards
(batch over ('pod','data'); cross-pod gradient traffic optionally int8
all-gather compressed, train/compression.py).

Memory: `compiled.memory_analysis()` is recorded per cell (peak temp bytes
in the roofline table below is the whole-program estimate across 512 host
devices; per-device residency at scale is dominated by the sharded
params+optimizer, e.g. qwen2-72b train: 72.7e9 x (2 + 12 eff. bytes)/256
~ 4.0 GB/device; arctic-480b with factored-v + bf16-m AdamW: ~11 GB/device
-- the optimizer-state compression the giant MoEs need to fit v5e).

Cost-extraction method (CPU backend; documented limitation + fix): XLA's
HloCostAnalysis counts while-loop bodies ONCE, so dry-run FLOPs/bytes come
from two exact loop-free probes (`unroll_scans` lowerings at L = pattern and
2 x pattern layers) extended linearly in depth -- exact for homogeneous
stacks; collectives come from two compiled small-depth probes on the real
mesh, extrapolated per (kind, bytes, group) key; bytes are
max(post-fusion HLO estimate, analytic traffic floor), with the pre-fusion
logical bytes kept as an upper bound in the JSON.
"""

ROOFLINE_INTRO = """
## SRoofline

Terms in seconds/step/chip; constants per assignment: 197 TFLOP/s bf16,
819 GB/s HBM, 50 GB/s/link ICI (25 GB/s assumed cross-pod DCN). 'useful
frac' = MODEL_FLOPS / HLO_FLOPs with MODEL_FLOPS = 6*N*D (train) / 2*N*D
(prefill/decode), N = active params -- it exposes remat recompute (~0.7 is
healthy for remat-on training; >1 would mean the compiler found a shortcut,
<0.3 flags redundant work, e.g. zamba2 before SPerf iteration 2).

What would move the dominant term (one line per family):
- dense/vlm train+prefill: compute-bound at 0.6-0.76 useful -> less remat
  (selective checkpointing) is the next lever, then attention-chunk fusion.
- dense decode: memory-bound on KV-cache reads, as expected at batch 128 x
  32k context; int8/fp8 KV cache would halve the term.
- moe train: was collective-bound (grad + routing storms); after the SPerf
  fixes arctic sits at the canonical EP all-to-all + TP all-reduce floor,
  grok is compute-bound.
- ssm/hybrid: collective term is TP all-reduces of small activations; these
  models under-fill a 256-chip pod (they'd deploy on 16-32 chips).
- selfjoin: memory-bound (arithmetic intensity (3n+2)/(8n+8) < 0.5
  flop/byte) -- the paper's own conclusion (bandwidth-limited refine) holds
  on TPU; SPerf drives the bytes term down instead of FLOPs.

### Single-pod (16 x 16 = 256 chips)

{single}

### Multi-pod (2 x 16 x 16 = 512 chips)

{multi}

Self-join cells (both meshes): the distributed count step compiles with the
k-hop halo exchange (collective-permute) + offset-parallel psum schedule;
its roofline rows use the analytic work model (exact candidate-window
accounting) with the HLO-parsed collective schedule.
"""

PERF = """
## SPerf (hillclimb log: hypothesis -> change -> measure -> verdict)

Baselines for all 40 LM cells + 4 self-join cells are in SRoofline (and
`results/dryrun_baseline.json` preserves the pre-optimization sweep). Three
cells were selected per the brief and driven down; every iteration below is
measured from re-lowered/re-compiled artifacts, not estimates.

### Cell 1: grok-1-314b x train_4k (most collective-bound)

Baseline: compute 17.7 s, memory 0.06 s, collective **79.9 s** -> step
bound ~80 s, <22% of the compute roofline.

| iter | hypothesis | change | collective s | verdict |
|---|---|---|---|---|
| 0 | baseline (global-sort routing; experts FSDP d x f over data x model) | -- | 79.9 | -- |
| 1 | replicated f32 grads inside the scan cause the 20 GB/layer all-reduces; pinning grad sharding at the step level will force reduce-scatter | with_sharding_constraint on grads after value_and_grad | 79.9 | **refuted** -- the all-reduce is emitted inside the scanned layer body; a step-level constraint cannot reach it |
| 2 | the einsum contracts over the FSDP-sharded d_model: each layer psums (E,cap,f/16) f32 = 21.5 GB of ACTIVATIONS; gathering 0.6 GB bf16 of weights instead is 35x less wire | compute-time weight gather (P(None,None,'model')) + capacity sharding + in-scan param constraint (its transpose reduce-scatters weight grads) | 30.0 | **confirmed** (-62%); remaining: 12 GB/layer all-reduce from the global argsort routing chain |
| 3 | the global top-k sort makes routing indices replicated, so dispatch/combine scatter grads all-reduce (T,d) f32 = 51.5 GB; row-local routing keeps every index op sharded with the batch | vmapped per-row dispatch (capacity per row), EP reshard expressed as (B->data)->(E->data) all-to-all | **4.76** | **confirmed** (-94% total); cell is now compute-bound: step 17.7 s vs 80 s baseline = **4.5x faster**, 0.78 of the compute roofline (0.60 useful-fraction incl. remat) |

The same change cut arctic-480b train_4k collectives 23.5 s -> 11.4 s
(2.1x; remainder is the canonical EP all-to-all + Megatron-style TP
all-reduce of (B/16,S,d) activations -- next lever would be
sequence-parallel reduce-scatter+all-gather, not attempted within budget).

| 4 | on the multi-pod mesh grok still showed 44 s: the MoE batch constraint hardcoded P('data'), fighting the ('pod','data') batch layout (GSPMD replicated over 'pod' and re-reduced) | thread the cell's actual batch spec (dp_spec) through moe_ffn's constraints | 44.1 -> **2.38** (multi-pod) | **confirmed**; multi-pod grok train is compute-bound at 8.85 s/step (512 chips halve the single-pod compute term, collectives stay sub-dominant) |

### Cell 2: zamba2-1.2b x train_4k (worst useful fraction: 0.21)

Baseline: compute 0.696 s with HLO_FLOPs ~4.8x MODEL_FLOPS -- the compiled
step does 4.8 flops for every useful one.

| iter | hypothesis | change | compute s / useful | verdict |
|---|---|---|---|---|
| 0 | baseline | -- | 0.696 / 0.21 | -- |
| 1 | SSD intra-chunk quadratic term (c=256) and per-head score matmuls dominate; Mamba2's B/C are head-shared so scores can be computed once (H=64-fold cut on that term), and c=64 balances intra vs state terms | shared_qk scores + ssm_chunk 256->64 | 0.684 / 0.21 | **refuted** -- probe decomposition showed the FLOPs live elsewhere |
| 2 | probe decomposition (vary config, diff per-layer FLOPs): removing the shared attention block drops per-layer FLOPs 4.4x -> the per-layer lax.cond makes the shared-attn branch part of EVERY scanned layer (both in cost and, under remat transforms, in executed work) | grouped stack: scan each 6-layer Mamba2 run, apply the shared block once per group statically (no cond) | **0.231 / 0.63** | **confirmed**: 3.0x compute cut; iteration-1's changes retained (they are correct per the chunked-form math and now visible: c=64 + shared scores contribute within the 0.231) |

### Cell 3: selfjoin x syn6d2m (paper-representative; memory-bound)

The join is bandwidth-bound (intensity <0.5 flop/byte), so iterations target
the bytes term. Work counters are exact (CPU execution), bytes from the
analytic traffic model over measured slot counts; counts validated equal to
the oracle after every change.

| iter | hypothesis | change | relative bytes (6-D) | verdict |
|---|---|---|---|---|
| 0 | full 3^n stencil baseline (paper's GPUSELFJOINGLOBAL) | -- | 1.00 | -- |
| 1 | paper's own UNICOMP: half the offsets -> half the cell visits, candidate slots, and gather traffic | (3^n+1)/2 lex half-stencil | 0.50 (measured cells 1.83x, cands 1.83x on Syn6D) | **confirmed** -- reproduces the paper's ~2x work cut; like the paper, wall-clock gain is < 2x at low n (Fig. 9 analogue) |
| 2 | the paper ran f64; TPU MXU/VPU are f32-native and coordinates in [0,100] need ~7 digits -> f32 halves coordinate traffic with zero count drift | dtype knob (f32 validated against f64 oracle on all test sets; kernel accumulates in f32 regardless) | 0.27 | **confirmed** (counts identical on every tested dataset) |
| 3 | in 6-D uniform data >99% of (query, offset) probes hit an EMPTY neighbor cell, yet the dense sweep gathers a full padded window for each (pad efficiency 0.002, Table II analogue); packing live queries per offset before the gather makes traffic scale with actual candidates | compaction sweep (`self_join_count_compact`): exact host-computed live cap, o=0 kept dense | 0.0025 at n=6 (**110x** traffic cut; 23x at n=4, 2.4x at n=2), counts exact | **confirmed** for the TPU bytes model; on CPU wall-clock it *regresses* (cache hierarchy makes padded gathers nearly free while the per-offset argsort costs) -- kept as an opt-in path and the honest trade-off is recorded |

Net effect on the syn6d2m roofline memory term: 18.9 ms -> ~0.09 ms/step
per chip est. (dense-f64 baseline -> UNICOMP+f32+compaction), i.e. the cell
moves from memory-bound to effectively index/compute-bound; at that point
the next bottleneck is the searchsorted neighbor lookup (int64 keys),
outside this budget.

### Bonus finding: MoE decode dispatch (caught by the useful-fraction flag)

The row-local routing fix for Cell 1 initially REGRESSED MoE decode:
arctic-480b decode_32k jumped to a "compute-bound" 19 ms/token with useful
fraction ~0.00, because per-row capacity reserves ``cap`` slots in EVERY
expert for EVERY sequence -- at S=1 the expert einsum does B x E x cap
slot-computations for B x top_k useful ones (~500x waste). The
useful-fraction flag caught it; fix: at decode the batch folds into ONE
routing row (global dispatch across the decode batch; row-local capacity
retained for training where it keeps indices batch-sharded). Measured:
arctic decode compute 1.9e-2 s -> 1.4e-4 s/token (137x), cell back to
memory-bound at 3.7 ms/token (multi-pod) -- the expected regime for
batch-128 32k-context serving, now with the training-side wins kept.

### Beyond-paper optimizations (summary)

1. Row-local MoE routing + EP all-to-all + compute-time weight gathers
   (16.8x collective cut on grok; applies to any sub-axis expert count).
2. Grouped hybrid stacks (cond-free shared blocks): 3x compute cut on
   zamba2.
3. Empty-neighbor compaction for the grid join: up to 110x gather-traffic
   cut at n=6 (TPU model), exact counts.
4. f32 coordinate pipeline with f32-accumulating MXU distance kernel
   (vs the paper's f64; validated).
5. int8 cross-pod gradient all-gather with error feedback (4x DCN traffic
   cut vs f32 ring all-reduce; exactness-of-mean within quantization step,
   tests/test_distributed.py).
6. Optimizer-state compression for 300B+ MoEs (factored v + bf16 m:
   16 -> ~8.3 bytes/param of optimizer+master state).
7. k-hop eps-halo exchange: the slab join stays exact under skew when
   equal-count slabs become narrower than eps (auto-computed k).
"""


def main():
    data = load("dryrun.json") or {}
    ok, skip, err = dryrun_counts(data)
    doc = HEADER
    doc += bench_section() + "\n"
    doc += DRYRUN_INTRO.format(ok=ok, skip=skip, err=err)
    doc += ROOFLINE_INTRO.format(
        single=roofline_table(data, "single"),
        multi=roofline_table(data, "multi"))
    doc += PERF
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write(doc)
    print(f"wrote {out} ({ok} ok / {skip} skip / {err} err cells)")


if __name__ == "__main__":
    main()
