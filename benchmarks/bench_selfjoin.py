"""Self-join perf trajectory: count/fill across distance_impl variants,
plus the serving path (--mode serve) and a CI smoke (--smoke).

    PYTHONPATH=src python benchmarks/bench_selfjoin.py [--out BENCH_selfjoin.json]
    PYTHONPATH=src python benchmarks/bench_selfjoin.py --mode serve
    PYTHONPATH=src python benchmarks/bench_selfjoin.py --smoke

--mode impl (default) times ``self_join_count`` (count) and ``self_join``
(count+fill, unsorted -- the paper reports the result sort separately) for
n in {2, 3, 4, 6} on uniform, clustered, and exponentially skewed
datasets, across distance_impl in {jnp, pallas, fused}, with the grid
index prebuilt (index construction is shared by every impl and benchmarked
in benchmarks/joins.py). The fused impl sweeps the merged-range 3^(n-1)
stencil by default (--no-merge times the per-cell 3^n oracle; --smoke
asserts pair-set parity between the two on every workload -- the CI
parity gate) and runs with autotuning enabled (kernels/autotune.py
measures tiles and the count route once and persists the winners), records
the chosen route, the offsets swept (n_offsets_swept), and the per-cell +
merged window-capacity histograms that drive the occupancy buckets
(DESIGN.md S6/S7), and ASSERTS the routing floor: fused count must not
lose to jnp on any workload (the uniform-6d regression this gate pins
down; --no-assert-floor to disable). The fused entry also records the
cell-run DMA dedup trajectory (DESIGN.md S11): row-loop vs run-loop join
timings and the per-workload analytic DMA-window ledger + run-length
histogram (``dma`` section); --smoke additionally gates run-loop vs
row-loop pair-set parity and the DMA-window reduction (strict decrease
on the clustered workload, >= mean cell occupancy on the 2-D ones).

--mode serve times the external-query serving path (DESIGN.md S5) on the
default serve workload: steady-state (post-warmup) request latency
percentiles and requests/sec of launch.serve.JoinService against the
LEGACY pre-PR-2 path, kept verbatim here as ``legacy_range_query_retrace``
-- a per-request ``@jax.jit`` closure that re-traces and recompiles on
every call. The acceptance claim is steady-state p50 >= 5x better than
the legacy path.

--mode load measures the continuous-batching serving pipeline (DESIGN.md
S8): a closed-loop capacity probe of the per-request JoinService, an
open-loop Poisson frontier sweep of BatchingJoinService, and the GATE
point at --load-overload x capacity where batching must deliver
>= --load-speedup-floor x the baseline's req/s at equal-or-better p99
with coalescing active and no retrace. Records the frontier and an SLO
(2x gate p99) in the "load" section; ``--mode load --smoke`` replays the
gate workload with fewer requests and fails CI if p99 exceeds the
recorded SLO or the coalesce factor is 1.0.

--mode index measures the device-resident index lifecycle (DESIGN.md
S10): host-vs-device build and host-vs-device merged-planning latency
(compile excluded), cold JoinService construction, and a live
``reindex`` swap with its build/plan/warm/swap breakdown -- AFTER
asserting the device build is bit-identical to ``build_grid_host``
field-for-field and pair-for-pair on every workload. Records the
"index" section; ``--mode index --smoke`` is the CI parity smoke.

--mode metrics times the metric-trait join paths (DESIGN.md S12): cosine
on raw embeddings with planted scaled duplicates and jaccard on ~10%-dense
token sets, each with pair-set parity against the metric's brute-force
oracle ASSERTED before timing (smoke and full runs alike). Cosine is also
timed against the plain L2 join on its canonical geometry, pinning the
claim that the metric's steady-state overhead is canonicalization only.
Records the "metrics" section; ``--mode metrics --smoke`` is the CI gate.

--smoke shrinks the impl sweep to one tiny workload (seconds), writes to a
temp file by default, skips the floor assert (noise at this scale), and
schema-validates the payload -- wired into scripts/ci.sh so the harness
and the BENCH schema cannot rot between full runs.

On this CPU container the 'pallas' impl runs the cell_join kernel through
the interpreter and the 'fused' impl runs the reference lowering of
kernels/fused_join.py (same algorithm, same outputs as the Mosaic kernel);
absolute times are machine-local, the IMPL-vs-IMPL ratios are the claim
(interpret-mode CPU timing as proxy, ISSUE 1). The headline acceptance
number is fused-vs-jnp on the 2-D uniform 100k workload.

Writes/updates BENCH_selfjoin.json (repo root by default): each mode
rewrites its own section and preserves the other's, so the file holds the
full perf trajectory; EXPERIMENTS.md tracks the history.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from repro.core.grid import build_grid_host                     # noqa: E402
from repro.core.selfjoin import self_join, self_join_count      # noqa: E402
from benchmarks.common import syn                               # noqa: E402

IMPLS = ("jnp", "pallas", "fused")


def clustered(n_points: int, n_dims: int, seed: int = 3) -> np.ndarray:
    """Gaussian clusters in [0, 100]^n (sw_like is 2/3-D only)."""
    rng = np.random.default_rng(seed)
    k = max(n_points // 200, 4)
    centers = rng.uniform(0, 100, (k, n_dims))
    pts = centers[rng.integers(0, k, n_points)]
    return pts + rng.normal(0, 1.5, pts.shape)


def expo(n_points: int, n_dims: int, seed: int = 5,
         scale: float = 10.0) -> np.ndarray:
    """Exponentially distributed coordinates (the paper's expo datasets):
    density concentrates near the origin, producing the long-tailed
    per-cell occupancy skew that exercises the capacity classes hardest."""
    rng = np.random.default_rng(seed)
    return rng.exponential(scale, (n_points, n_dims))


def workloads(args):
    if args.smoke:
        # tiny skewed workloads: exercise the occupancy buckets, the
        # merged-vs-unmerged parity oracle, and the full payload schema in
        # seconds (CI harness-rot gate)
        yield "uniform-2d", syn(4000, 2), 0.4
        yield "clustered-2d", clustered(3000, 2), 0.4
        yield "expo-3d", expo(3000, 3), 1.2
        return
    # eps tuned per dimensionality for paper-like selectivity (a handful of
    # neighbors per point on the uniform sets; denser on the clustered sets).
    yield "uniform-2d", syn(args.points_2d, 2), 0.4
    yield "clustered-2d", clustered(args.points_2d, 2), 0.4
    yield "expo-3d", expo(args.points_3d, 3), 1.2
    yield "uniform-4d", syn(args.points_4d, 4), 6.0
    yield "clustered-4d", clustered(args.points_4d, 4), 3.0
    yield "uniform-6d", syn(args.points_6d, 6), 14.0
    yield "clustered-6d", clustered(args.points_6d, 6), 4.0


def validate_schema(payload: dict) -> None:
    """The BENCH_selfjoin.json contract consumed by EXPERIMENTS.md and the
    acceptance gates; --smoke runs this in CI so it cannot rot."""
    for key in ("bench", "backend", "jax", "results"):
        assert key in payload, key
    assert payload["headline"] is None or {
        "workload", "n_points", "fused_over_jnp_join",
        "fused_over_jnp_count"} <= set(payload["headline"])
    for e in payload["results"]:
        for key in ("workload", "n_points", "n_dims", "eps", "total_pairs",
                    "max_per_cell", "window_caps_hist",
                    "merged_window_caps_hist", "impls"):
            assert key in e, (e.get("workload"), key)
        for impl, t in e["impls"].items():
            assert {"count_s", "join_s"} <= set(t), (e["workload"], impl)
        if "fused" in e["impls"]:
            assert "route" in e["impls"]["fused"], e["workload"]
            assert "n_offsets_swept" in e["impls"]["fused"], e["workload"]
            # cell-run DMA dedup trajectory (DESIGN.md S11)
            assert {"join_row_s", "join_run_s",
                    "run_over_row_join"} <= set(e["impls"]["fused"]), (
                e["workload"])
            assert "dma" in e, e["workload"]
            assert {"dma_windows_row", "dma_windows_run", "dma_bytes_saved",
                    "reduction_factor", "mean_cell_occupancy",
                    "run_length_hist"} <= set(e["dma"]), e["workload"]
    if "load" in payload:
        validate_load_schema(payload["load"])
    if "index" in payload:
        validate_index_schema(payload["index"])
    if "metrics" in payload:
        validate_metrics_schema(payload["metrics"])


def validate_load_schema(load: dict) -> None:
    """Contract of the "load" section (EXPERIMENTS.md SLoad, the CI load
    smoke's SLO source)."""
    for key in ("workload", "knobs", "baseline_capacity", "gate",
                "frontier", "slo_p99_ms"):
        assert key in load, key
    assert {"max_batch", "max_wait_ms"} <= set(load["knobs"])
    gate = load["gate"]
    for key in ("offered_rps", "baseline", "batching",
                "speedup_req_per_sec", "p99_ratio"):
        assert key in gate, key
    for side in ("baseline", "batching"):
        assert {"achieved_rps", "p50_ms", "p99_ms"} <= set(gate[side]), side
    assert gate["batching"].get("coalesce_factor") is not None
    for pt in load["frontier"]:
        assert {"offered_rps", "achieved_rps", "p50_ms", "p99_ms",
                "coalesce_factor"} <= set(pt)


def best_of(fn, trials: int) -> float:
    fn()  # warm-up: jit compile excluded (paper excludes context setup)
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def legacy_range_query_retrace(index, queries, deltas, max_per_cell):
    """The pre-PR-2 serving path, kept VERBATIM as the regression baseline.

    The ``@jax.jit`` closure below is a new function object on every call,
    so each request pays a fresh trace + compile before executing; it also
    gathers the (Q, C, n) candidate tensor the fused path eliminates and
    can only return counts. core/query_join.py replaced it; this copy
    exists only so --mode serve can keep measuring what the fix is worth.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import grid as grid_lib
    from repro.core.grid import neighbor_rank

    queries = jnp.asarray(queries)

    @jax.jit
    def run(index, queries):
        qcoords = grid_lib.cell_coords(queries, index.grid_min, index.eps)
        qcoords = jnp.clip(qcoords, 1, index.dims - 2)
        qkeys = grid_lib.linearize(qcoords, index.dims)
        eps2 = index.eps * index.eps

        def body(counts, delta):
            nbr = neighbor_rank(index, qkeys + delta)
            nbr_c = jnp.maximum(nbr, 0)
            start = index.cell_start[nbr_c]
            count = jnp.where(nbr >= 0, index.cell_count[nbr_c], 0)
            slots = jnp.arange(max_per_cell, dtype=jnp.int32)
            pos = jnp.minimum(start[:, None] + slots[None, :],
                              index.num_points - 1)
            valid = slots[None, :] < count[:, None]
            cand = index.points_sorted[pos]
            d2 = jnp.sum((queries[:, None, :] - cand) ** 2, axis=-1)
            hits = (d2 <= eps2) & valid
            return counts + hits.sum(axis=1, dtype=jnp.int32), None

        counts0 = jnp.zeros((queries.shape[0],), jnp.int32)
        counts, _ = jax.lax.scan(body, counts0, deltas)
        return counts

    return np.asarray(run(index, queries))


def bench_serve(args):
    """Steady-state serving vs. the legacy re-tracing path."""
    from repro.core.grid import build_grid_host
    from repro.core.query_join import bucket_rows
    from repro.core.selfjoin import _offset_tables, _round_up
    from repro.launch.serve import JoinService

    rng = np.random.default_rng(args.seed)
    pts = rng.uniform(0, 100, (args.serve_points, args.serve_dims))
    eps = args.serve_eps
    B = args.serve_batch
    index = build_grid_host(pts, eps)
    deltas, _ = _offset_tables(index, unicomp=False)
    c = _round_up(max(int(index.max_per_cell), 1), 8)

    # legacy path: EVERY request re-traces (that is the point being measured)
    lat_legacy = []
    legacy_counts = legacy_q = None
    for r in range(max(args.serve_requests_legacy, 1)):
        q = rng.uniform(0, 100, (B, args.serve_dims))
        t0 = time.perf_counter()
        counts = legacy_range_query_retrace(index, q, deltas, c)
        lat_legacy.append(1000 * (time.perf_counter() - t0))
        legacy_counts, legacy_q = counts, q

    # service path: warm once, measure steady state (warmup auto-marks
    # steady, so latencies below land in the steady window)
    svc = JoinService(pts, eps, index=index)
    svc.warmup(B)
    for r in range(args.serve_requests):
        q = rng.uniform(0, 100, (B, args.serve_dims))
        svc.query(q)
    # parity gate: the service must answer the legacy path's last request
    # identically before its timings count
    parity = svc.prepared.counts(legacy_q)
    assert np.array_equal(parity, legacy_counts), "serve parity failure"
    svc.assert_no_retrace()
    p50, p99 = svc.percentiles()
    p50_legacy = float(np.percentile(lat_legacy, 50))
    entry = {
        "workload": (f"uniform-{args.serve_dims}d serve, "
                     f"{args.serve_points} pts indexed, "
                     f"batch {B} external queries/request"),
        "n_points": int(args.serve_points),
        "n_dims": int(args.serve_dims),
        "eps": float(eps),
        "request_batch": int(B),
        "legacy_retrace": {
            "requests": len(lat_legacy),
            "p50_ms": p50_legacy,
            "p99_ms": float(np.percentile(lat_legacy, 99)),
            "note": "per-request @jax.jit closure: trace+compile every call",
        },
        "service": {
            "requests": svc.requests,
            "p50_ms": p50,
            "p99_ms": p99,
            "requests_per_sec": svc.requests_per_sec(),
            "bucket_rows": int(bucket_rows(B)),
            "note": "JoinService steady state (post-warmup), counts-only "
                    "requests; no retrace (asserted)",
        },
        "speedup_service_vs_legacy_p50": p50_legacy / p50,
    }
    print(f"[bench-serve] legacy p50 {p50_legacy:9.1f} ms  "
          f"service p50 {p50:7.2f} ms  "
          f"speedup {entry['speedup_service_vs_legacy_p50']:.1f}x  "
          f"({svc.requests_per_sec():.1f} req/s steady)")
    return entry


def bench_load(args):
    """Continuous-batching throughput gate + latency/throughput frontier
    (DESIGN.md S8, EXPERIMENTS.md SLoad).

    One mixed-size mixed-eps request stream drives both services:

    1. closed-loop capacity probe of the per-request ``JoinService``
       (concurrency 1 -- its max sustained req/s),
    2. open-loop frontier sweep of ``BatchingJoinService`` at multiples
       of that capacity (Poisson arrivals, coordinated-omission-safe
       latency from the scheduled arrival),
    3. the GATE point at ``--load-overload`` x baseline capacity, where
       both services face identical offered load: the acceptance claim is
       batching req/s >= ``--load-speedup-floor`` x baseline at
       equal-or-better p99, with coalesce factor > 1 and the no-retrace
       watchdog green on both services.

    The recorded ``slo_p99_ms`` (2x the gate run's batching p99,
    headroom for machine noise) is what the CI load smoke
    (``--mode load --smoke``) replays against: same workload and knobs,
    fewer requests, FAIL if p99 exceeds the SLO or coalescing silently
    turned off.
    """
    from repro.launch.loadgen import (RequestMix, make_request_stream,
                                      run_closed_loop, run_open_loop)
    from repro.launch.serve import BatchingJoinService, JoinService

    rng = np.random.default_rng(args.seed)
    n_requests = 60 if args.smoke else args.load_requests
    pts = rng.uniform(0, 100, (args.load_points, args.load_dims))
    eps = args.load_eps
    sizes = (16, 32, 64, 128)
    eps_mix = (0.75 * eps, eps)
    mix = RequestMix(sizes=sizes, eps_values=eps_mix)
    stream = make_request_stream(n_requests, mix, args.load_dims,
                                 seed=args.seed + 1)

    # warm BOTH services before marking steady on either: the executable
    # caches are module-global, so a later warmup would trip the earlier
    # service's watchdog as a foreign compile
    baseline = JoinService(pts, eps)
    baseline.warmup(max(sizes))
    svc = BatchingJoinService(pts, eps, max_batch=args.load_max_batch,
                              max_wait_ms=args.load_max_wait_ms)
    svc.warmup()
    baseline.mark_steady()
    svc.mark_steady()

    cap = run_closed_loop(baseline, stream[: min(60, n_requests)])
    print(f"[bench-load] baseline capacity {cap.achieved_rps:8.1f} req/s "
          f"(closed loop, p50 {cap.p50_ms:.2f} ms)", flush=True)

    gate_rate = args.load_overload * cap.achieved_rps
    multiples = (0.5, 1.0, 2.0) if not args.smoke else ()
    frontier = []
    for m in multiples:
        r = run_open_loop(svc, stream, m * cap.achieved_rps,
                          seed=args.seed + 2)
        frontier.append(r)
        print(f"[bench-load] batching @ {m:3.1f}x cap "
              f"({r.offered_rps:7.1f} rps offered): "
              f"achieved {r.achieved_rps:7.1f} p50 {r.p50_ms:6.2f} ms "
              f"p99 {r.p99_ms:6.2f} ms coalesce {r.coalesce_factor:.1f}",
              flush=True)
    gate_base = run_open_loop(baseline, stream, gate_rate,
                              seed=args.seed + 2)
    gate_batch = run_open_loop(svc, stream, gate_rate, seed=args.seed + 2)
    frontier.append(gate_batch)
    baseline.assert_no_retrace()
    svc.assert_no_retrace()
    speedup = gate_batch.achieved_rps / gate_base.achieved_rps
    p99_ratio = gate_batch.p99_ms / gate_base.p99_ms
    print(f"[bench-load] GATE @ {gate_rate:7.1f} rps offered "
          f"({args.load_overload}x capacity): baseline "
          f"{gate_base.achieved_rps:7.1f} req/s p99 {gate_base.p99_ms:7.2f} "
          f"ms | batching {gate_batch.achieved_rps:7.1f} req/s p99 "
          f"{gate_batch.p99_ms:7.2f} ms | speedup {speedup:.2f}x "
          f"coalesce {gate_batch.coalesce_factor:.1f}", flush=True)

    assert gate_batch.coalesce_factor > 1.0, (
        "batching silently disabled: coalesce factor "
        f"{gate_batch.coalesce_factor} at {gate_rate:.0f} rps offered")
    if args.smoke:
        # CI load smoke: replay the gate workload (fewer requests) against
        # the SLO the last full run recorded in the repo BENCH file
        repo_bench = os.path.join(_ROOT, "BENCH_selfjoin.json")
        if os.path.exists(repo_bench):
            with open(repo_bench) as f:
                recorded = json.load(f).get("load")
            if recorded is not None:
                slo = recorded["slo_p99_ms"]
                assert gate_batch.p99_ms <= slo, (
                    f"load smoke p99 {gate_batch.p99_ms:.2f} ms exceeds "
                    f"the recorded SLO {slo:.2f} ms "
                    f"(BENCH_selfjoin.json load.slo_p99_ms)")
                print(f"[bench-load] smoke p99 {gate_batch.p99_ms:.2f} ms "
                      f"within recorded SLO {slo:.2f} ms", flush=True)
    else:
        assert speedup >= args.load_speedup_floor, (
            f"batching speedup {speedup:.2f}x under the "
            f"{args.load_speedup_floor}x floor at {gate_rate:.0f} rps")
        assert gate_batch.p99_ms <= gate_base.p99_ms, (
            f"batching p99 {gate_batch.p99_ms:.2f} ms worse than baseline "
            f"{gate_base.p99_ms:.2f} ms at equal offered load")

    return {
        "workload": {
            "n_points": int(args.load_points),
            "n_dims": int(args.load_dims),
            "eps": float(eps),
            "request_sizes": list(sizes),
            "eps_mix": [float(e) for e in eps_mix],
            "n_requests": int(n_requests),
            "arrivals": "poisson (open loop), latency from scheduled "
                        "arrival (coordinated-omission safe)",
        },
        "knobs": {"max_batch": int(svc.max_batch),
                  "max_wait_ms": float(svc.max_wait_ms)},
        "baseline_capacity": {
            "requests_per_sec": cap.achieved_rps,
            "p50_ms": cap.p50_ms,
            "p99_ms": cap.p99_ms,
            "note": "JoinService closed loop, concurrency 1",
        },
        "gate": {
            "offered_rps": gate_rate,
            "overload_factor": float(args.load_overload),
            "baseline": {k: v for k, v in gate_base.to_dict().items()
                         if k not in ("mode",)},
            "batching": {k: v for k, v in gate_batch.to_dict().items()
                         if k not in ("mode",)},
            "speedup_req_per_sec": speedup,
            "p99_ratio": p99_ratio,
            "no_retrace": True,
        },
        "frontier": [r.to_dict() for r in frontier],
        "slo_p99_ms": 2.0 * gate_batch.p99_ms,
    }


def bench_distributed(args):
    """Fused slab join (DESIGN.md S3) vs the single-device fused join.

    Asserts pair-set parity between ``distributed_self_join`` over
    ``--dist-slabs`` slabs and ``self_join(distance_impl='fused')`` on
    every workload BEFORE timing (the CI parity gate), then records both
    timings. Needs >= --dist-slabs local devices: run under
    XLA_FLAGS=--xla_force_host_platform_device_count=N (scripts/ci.sh
    does). On this container the placeholder devices share one host, so
    the distributed timing carries the partition + halo-exchange overhead
    without any real parallel speedup; the recorded claim is parity +
    overhead trajectory, not a speedup.
    """
    import jax

    from repro.core.distributed import distributed_self_join
    from repro.core.selfjoin import self_join
    from repro.launch.mesh import make_slab_mesh

    n_slabs = args.dist_slabs
    if jax.device_count() < n_slabs:
        raise SystemExit(
            f"--mode distributed needs >= {n_slabs} devices; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_slabs}")
    mesh = make_slab_mesh(n_slabs)
    npts = 4000 if args.smoke else args.dist_points
    results = []
    for name, pts, eps in (("uniform-2d", syn(npts, 2), 0.4),
                           ("clustered-2d", clustered(npts, 2), 0.4)):
        index = build_grid_host(pts, eps)
        ref = self_join(pts, eps, index=index, distance_impl="fused")
        got = distributed_self_join(pts, eps, mesh)
        assert np.array_equal(got, ref), (
            f"distributed pair-set parity failure on {name}: "
            f"{got.shape} vs {ref.shape}")
        print(f"[bench-dist] {name:14s} parity OK "
              f"({ref.shape[0]} pairs, {n_slabs} slabs)", flush=True)
        t_single = best_of(
            lambda: self_join(pts, eps, index=index, distance_impl="fused",
                              sort_result=False), args.trials)
        t_dist = best_of(
            lambda: distributed_self_join(pts, eps, mesh,
                                          sort_result=False), args.trials)
        results.append({
            "workload": name,
            "n_points": int(pts.shape[0]),
            "n_dims": int(pts.shape[1]),
            "eps": float(eps),
            "total_pairs": int(ref.shape[0]),
            "n_slabs": int(n_slabs),
            "single_fused_join_s": t_single,
            "distributed_join_s": t_dist,
            "distributed_over_single": t_dist / t_single,
            "pair_set_parity": True,
        })
        print(f"[bench-dist] {name:14s} single {t_single*1e3:9.1f} ms   "
              f"distributed({n_slabs}) {t_dist*1e3:9.1f} ms", flush=True)
    for e in results:   # schema: the keys EXPERIMENTS.md SDist reads
        assert {"workload", "n_slabs", "single_fused_join_s",
                "distributed_join_s", "pair_set_parity"} <= set(e)
    return {
        "n_slabs": int(n_slabs),
        "note": ("CPU placeholder devices share one host: the distributed "
                 "column measures partition + halo exchange + per-slab "
                 "sweep overhead, not parallel speedup; parity is the "
                 "asserted claim"),
        "results": results,
    }


_INDEX_FIELDS = ("grid_min", "eps", "dims", "order", "points_sorted",
                 "cell_keys", "cell_start", "cell_count", "point_cell_rank",
                 "num_cells", "max_per_cell")


def assert_index_parity(host_index, device_index, name: str) -> None:
    """Field-for-field bit-parity of two GridIndex builds (values AND
    dtypes) -- the --mode index acceptance gate."""
    for f in _INDEX_FIELDS:
        a = np.asarray(getattr(host_index, f))
        b = np.asarray(getattr(device_index, f))
        assert a.dtype == b.dtype, (
            f"index dtype mismatch on {name}.{f}: {a.dtype} vs {b.dtype}")
        assert np.array_equal(a, b), (
            f"index bit-parity failure on {name}.{f}")


def bench_index(args):
    """Device-resident index build + planning (DESIGN.md S10).

    Per workload: host (numpy) vs device (jitted) build time, host vs
    device merged-capacity planning time, cold prepare time, and the
    JoinService.reindex build/plan/warm/swap breakdown -- after asserting
    the device index is BIT-IDENTICAL to ``build_grid_host`` field-for-
    field and that downstream pairs match exactly (the acceptance gate).
    Times exclude compile (best_of warms first); the jitted builder is
    shared with the distributed slab join, so these executables are the
    ones a real service re-uses.
    """
    import jax

    from repro.core.grid import (build_grid, cell_window_caps,
                                 cell_window_caps_host)
    from repro.core.selfjoin import self_join
    from repro.launch.serve import JoinService

    rng = np.random.default_rng(args.seed)
    results = []
    for name, pts, eps in workloads(args):
        h_index = build_grid_host(pts, eps)
        d_index = build_grid(pts, eps)
        assert_index_parity(h_index, d_index, name)
        ref = self_join(pts, eps, index=h_index, sort_result=True)
        got = self_join(pts, eps, index=d_index, sort_result=True)
        assert np.array_equal(np.asarray(ref), np.asarray(got)), (
            f"pair-set parity failure on device-built index for {name}")
        print(f"[bench-index] {name:14s} parity OK: {len(_INDEX_FIELDS)} "
              f"fields bit-identical, {ref.shape[0]} pairs identical",
              flush=True)

        t_host = best_of(lambda: build_grid_host(pts, eps), args.trials)
        t_dev = best_of(
            lambda: jax.block_until_ready(build_grid(pts, eps)), args.trials)
        tp_host = best_of(
            lambda: cell_window_caps_host(d_index, merged=True), args.trials)
        tp_dev = best_of(
            lambda: cell_window_caps(d_index, merged=True), args.trials)
        # cold prepare on a FRESH device build: what a re-index pays
        # (per-index plan caches cannot help a new index object)
        t0 = time.perf_counter()
        svc = JoinService(pts, eps)
        prepare_cold_s = time.perf_counter() - t0
        q = pts[:min(256, pts.shape[0])]
        svc.warmup(q.shape[0])
        svc.reindex(rng.permutation(pts))
        svc.query(q)   # same bucket as warmed: swap must not retrace
        svc.assert_no_retrace()   # warmed executables survived the swap

        entry = {
            "workload": name,
            "n_points": int(pts.shape[0]),
            "n_dims": int(pts.shape[1]),
            "eps": float(eps),
            "key_dtype": str(np.asarray(d_index.cell_keys).dtype),
            "num_cells": int(d_index.num_cells),
            "build_host_s": t_host,
            "build_device_s": t_dev,
            "build_device_over_host": t_dev / t_host,
            "plan_host_s": tp_host,
            "plan_device_s": tp_dev,
            "plan_device_over_host": tp_dev / tp_host,
            "prepare_cold_s": prepare_cold_s,
            "reindex": dict(svc.reindex_timings),
            "snapshot_swaps": int(svc.swaps),
            "bit_parity": True,
            "pair_parity": True,
            "total_pairs": int(ref.shape[0]),
        }
        results.append(entry)
        rt = entry["reindex"]
        print(f"[bench-index] {name:14s} build host {t_host*1e3:8.1f} ms  "
              f"device {t_dev*1e3:8.1f} ms   plan host {tp_host*1e3:7.1f} ms"
              f"  device {tp_dev*1e3:7.1f} ms", flush=True)
        print(f"[bench-index] {name:14s} reindex build {rt['build_s']*1e3:.1f}"
              f" ms + plan {rt['plan_s']*1e3:.1f} ms + warm "
              f"{rt['warm_s']*1e3:.1f} ms + swap {rt['swap_s']*1e6:.0f} us "
              f"(no retrace across swap)", flush=True)
    return {
        "note": ("device build/plan on the shared jitted executables "
                 "(grid.build_grid_with_geometry_jit + batched searchsorted "
                 "planners); compile excluded (warmed), parity asserted "
                 "field-for-field and on downstream pairs before timing"),
        "results": results,
    }


def validate_index_schema(section: dict) -> None:
    """Contract of the "index" section (EXPERIMENTS.md SIndexBuild)."""
    assert "results" in section and section["results"], "empty index section"
    for e in section["results"]:
        for key in ("workload", "n_points", "n_dims", "eps", "key_dtype",
                    "build_host_s", "build_device_s", "plan_host_s",
                    "plan_device_s", "prepare_cold_s", "reindex",
                    "bit_parity", "pair_parity"):
            assert key in e, (e.get("workload"), key)
        assert e["bit_parity"] is True and e["pair_parity"] is True
        assert {"build_s", "plan_s", "warm_s", "swap_s"} <= set(e["reindex"])


def metric_workloads(args):
    """Per-metric bench workloads (DESIGN.md S12). Cosine: raw gaussian
    embeddings with planted scaled duplicates (the case L2 misses).
    Jaccard: ~10%-dense token sets over a 64-token vocabulary."""
    rng = np.random.default_rng(args.seed)
    n = 2500 if args.smoke else args.metrics_points
    d = args.metrics_dims
    emb = rng.normal(size=(n, d))
    emb[: n // 50] = emb[n // 2: n // 2 + n // 50] * 2.5   # scaled dups
    yield "cosine", f"cosine-{d}d", emb, 0.9
    vocab = 64
    sets = [tuple(np.flatnonzero(rng.random(vocab) < 0.1))
            for _ in range(n)]
    yield "jaccard", f"jaccard-v{vocab}", sets, 0.5


def bench_metrics(args):
    """Metric-generic join trajectory (DESIGN.md S12): per-metric fused
    join timings with PAIR-SET PARITY vs the metric's brute-force oracle
    asserted on every workload before anything is timed -- smoke and full
    runs alike (the acceptance gate: a metric path that returns L2
    answers cannot produce a plausible-but-wrong benchmark row). For
    cosine the canonical-geometry L2 join is timed too: the metric's
    steady-state overhead is canonicalization only, and the ratio records
    that claim.
    """
    from repro.core import metric as metric_lib
    from repro.core.selfjoin import self_join, self_join_count

    results = []
    for metric, name, data, eps in metric_workloads(args):
        t0 = time.perf_counter()
        canon = metric_lib.canonicalize(data, eps, metric=metric)
        canonicalize_s = time.perf_counter() - t0
        expect = metric_lib.brute_force_join_metric(canon)
        got = self_join(data, eps, metric=metric)
        assert np.array_equal(np.asarray(got), np.asarray(expect)), (
            f"{name}: fused {metric} pair set diverges from the brute "
            f"oracle ({got.shape} vs {expect.shape})")
        print(f"[bench-metrics] {name:14s} pair-set parity vs brute "
              f"oracle OK ({expect.shape[0]} pairs)", flush=True)
        count_s = best_of(
            lambda: self_join_count(data, eps, metric=metric), args.trials)
        join_s = best_of(
            lambda: self_join(data, eps, metric=metric), args.trials)
        entry = {
            "metric": metric,
            "workload": name,
            "n_points": int(canon.geom.shape[0]),
            "eps": float(eps),
            "eps_geom": float(canon.eps_geom),
            "n_feat": int(canon.n_feat),
            "total_pairs": int(expect.shape[0]),
            "pair_parity": True,
            "canonicalize_s": canonicalize_s,
            "count_s": count_s,
            "join_s": join_s,
        }
        if metric == "cosine":
            # the SAME fused machinery on the pre-canonicalized geometry:
            # the ratio isolates what the metric tag itself costs (~1.0)
            geom = np.asarray(canon.geom)
            l2_s = best_of(
                lambda: self_join(geom, float(canon.eps_geom),
                                  distance_impl="fused"), args.trials)
            entry["l2_equiv_join_s"] = l2_s
            entry["over_l2_equiv"] = join_s / l2_s
        results.append(entry)
        print(f"[bench-metrics] {name:14s} count {count_s*1e3:8.1f} ms  "
              f"join {join_s*1e3:8.1f} ms  canonicalize "
              f"{canonicalize_s*1e3:6.1f} ms", flush=True)
    return {
        "note": ("fused join per metric trait (core/metric.py): pair-set "
                 "parity vs the brute oracle asserted before timing; "
                 "cosine also timed against the plain L2 join on its "
                 "canonical geometry (steady-state metric overhead)"),
        "results": results,
    }


def validate_metrics_schema(section: dict) -> None:
    """Contract of the "metrics" section (EXPERIMENTS.md SMetrics)."""
    assert "results" in section and section["results"], "empty metrics section"
    seen = set()
    for e in section["results"]:
        for key in ("metric", "workload", "n_points", "eps", "eps_geom",
                    "n_feat", "total_pairs", "pair_parity",
                    "canonicalize_s", "count_s", "join_s"):
            assert key in e, (e.get("workload"), key)
        assert e["pair_parity"] is True, e["workload"]
        seen.add(e["metric"])
    assert {"cosine", "jaccard"} <= seen, seen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--mode", default="impl",
                    choices=("impl", "serve", "distributed", "load", "index",
                             "metrics"))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny impl sweep + schema validation (CI gate); "
                         "writes to a temp file unless --out is given")
    ap.add_argument("--assert-floor", dest="assert_floor",
                    action="store_true", default=None,
                    help="fail if routed fused count loses to jnp "
                         "(default: on for full impl runs, off for --smoke)")
    ap.add_argument("--no-assert-floor", dest="assert_floor",
                    action="store_false")
    ap.add_argument("--no-autotune", dest="autotune", action="store_false",
                    default=True,
                    help="disable measured tile/route autotuning "
                         "(kernels/autotune.py) for this run")
    ap.add_argument("--no-merge", action="store_true",
                    help="time the per-cell 3^n sweep instead of the "
                         "merged-range 3^(n-1) sweep (parity oracle, "
                         "DESIGN.md S7); --smoke asserts pair-set parity "
                         "between both regardless")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--points-2d", type=int, default=100_000)
    ap.add_argument("--points-3d", type=int, default=30_000)
    ap.add_argument("--points-4d", type=int, default=20_000)
    ap.add_argument("--points-6d", type=int, default=10_000)
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--impls", default=",".join(IMPLS),
                    help="comma-separated subset of %s" % (IMPLS,))
    # --mode serve: the default serve workload (launch/serve.py defaults)
    ap.add_argument("--serve-points", type=int, default=20_000)
    ap.add_argument("--serve-dims", type=int, default=4)
    ap.add_argument("--serve-eps", type=float, default=2.0)
    ap.add_argument("--serve-batch", type=int, default=256)
    ap.add_argument("--serve-requests", type=int, default=32)
    ap.add_argument("--serve-requests-legacy", type=int, default=6)
    # --mode distributed: fused slab join parity + overhead (DESIGN.md S3)
    ap.add_argument("--dist-slabs", type=int, default=2)
    ap.add_argument("--dist-points", type=int, default=40_000)
    # --mode metrics: per-metric trait joins, parity-gated (DESIGN.md S12)
    ap.add_argument("--metrics-points", type=int, default=20_000)
    ap.add_argument("--metrics-dims", type=int, default=4)
    # --mode load: continuous-batching frontier + SLO gate (DESIGN.md S8)
    ap.add_argument("--load-points", type=int, default=20_000)
    ap.add_argument("--load-dims", type=int, default=4)
    ap.add_argument("--load-eps", type=float, default=2.0)
    ap.add_argument("--load-requests", type=int, default=200)
    ap.add_argument("--load-max-batch", type=int, default=1024)
    ap.add_argument("--load-max-wait-ms", type=float, default=2.0)
    ap.add_argument("--load-overload", type=float, default=6.0,
                    help="gate offered load as a multiple of the measured "
                         "baseline capacity")
    ap.add_argument("--load-speedup-floor", type=float, default=3.0,
                    help="minimum batching-vs-baseline req/s ratio at the "
                         "gate point (full runs only)")
    args = ap.parse_args(argv)
    if args.assert_floor is None:
        args.assert_floor = args.mode == "impl" and not args.smoke
    if args.smoke:
        args.trials = 1
        if args.impls == ",".join(IMPLS):
            args.impls = "jnp,fused"   # interpreted pallas is minutes even
    impls = tuple(args.impls.split(","))
    if args.out is None:
        if args.smoke:
            import tempfile

            args.out = os.path.join(tempfile.gettempdir(),
                                    "bench_selfjoin_smoke.json")
        else:
            args.out = os.path.join(
                os.path.dirname(__file__), "..", "BENCH_selfjoin.json")
    if args.autotune and args.mode == "impl" and not args.smoke:
        # measured tile + route autotuning: winners persist in the cache
        # next to kernels/autotune.py (or $REPRO_AUTOTUNE_CACHE)
        os.environ.setdefault("REPRO_AUTOTUNE", "1")
    out = os.path.abspath(args.out)
    existing = {}
    if os.path.exists(out):
        with open(out) as f:
            existing = json.load(f)

    import jax

    if args.mode in ("serve", "distributed", "load", "index", "metrics"):
        payload = existing or {"bench": "selfjoin-distance-impl"}
        payload["backend"] = jax.default_backend()
        payload["jax"] = jax.__version__
        if args.mode == "serve":
            payload["serve"] = bench_serve(args)
        elif args.mode == "load":
            payload["load"] = bench_load(args)
            validate_load_schema(payload["load"])
        elif args.mode == "index":
            payload["index"] = bench_index(args)
            validate_index_schema(payload["index"])
        elif args.mode == "metrics":
            payload["metrics"] = bench_metrics(args)
            validate_metrics_schema(payload["metrics"])
        else:
            payload["distributed"] = bench_distributed(args)
        with open(out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[bench] wrote {out}")
        return payload

    from repro.core.grid import occupancy_plan

    merge = not args.no_merge
    results = []
    for name, pts, eps in workloads(args):
        index = build_grid_host(pts, eps)
        expect = self_join_count(pts, eps, index=index).total_pairs
        plan = occupancy_plan(index)
        mplan = occupancy_plan(index, merged=True)
        if args.smoke:
            # CI parity oracle (DESIGN.md S7): the merged-range sweep and
            # the per-cell sweep must emit identical sorted pair sets --
            # exercised on every build, not just under pytest. The driver
            # is called with the sweep PINNED (not through the public
            # merge_last_dim default) so a measured 'dense-flat' route
            # verdict can never silently turn this into oracle-vs-oracle.
            from repro.core.selfjoin import _self_join_fused

            pm = _self_join_fused(index, unicomp=True, sort_result=True,
                                  merged=True)
            pf = _self_join_fused(index, unicomp=True, sort_result=True,
                                  merged=False)
            assert np.array_equal(pm, pf), (
                f"merged-range sweep pair-set mismatch vs per-cell oracle "
                f"on {name}: {pm.shape} vs {pf.shape}")
            print(f"[bench] {name:14s} merged/unmerged pair-set parity OK "
                  f"({pm.shape[0]} pairs)", flush=True)
            # Run-loop parity gate (DESIGN.md S11): the cell-run DMA dedup
            # must emit the row-loop's pair set bit-for-bit, with the
            # analytic DMA ledger showing fewer window gathers -- strictly
            # fewer on the clustered workload (co-located queries are its
            # whole point), and by at least the mean cell occupancy factor
            # on the dense 2-D workloads (ISSUE 9 acceptance).
            from repro.core.selfjoin import dma_window_stats

            pr = _self_join_fused(index, unicomp=True, sort_result=True,
                                  merged=True, run_loop=True)
            assert np.array_equal(pm, pr), (
                f"run-loop pair-set mismatch vs row-loop on {name}: "
                f"{pr.shape} vs {pm.shape}")
            dma = dma_window_stats(index)
            assert dma["dma_windows_run"] <= dma["dma_windows_row"], (
                name, dma)
            if name.startswith("clustered"):
                assert dma["dma_windows_run"] < dma["dma_windows_row"], (
                    f"run-loop did not reduce DMA windows on {name}: {dma}")
            if name in ("uniform-2d", "clustered-2d"):
                assert (dma["reduction_factor"]
                        >= dma["mean_cell_occupancy"]), (
                    f"DMA window reduction {dma['reduction_factor']:.2f}x "
                    f"under the mean cell occupancy "
                    f"{dma['mean_cell_occupancy']:.2f}x on {name}")
            print(f"[bench] {name:14s} run-loop pair-set parity OK, DMA "
                  f"windows {dma['dma_windows_row']} -> "
                  f"{dma['dma_windows_run']} "
                  f"({dma['reduction_factor']:.2f}x, mean occupancy "
                  f"{dma['mean_cell_occupancy']:.2f})", flush=True)
        entry = {
            "workload": name,
            "n_points": int(pts.shape[0]),
            "n_dims": int(pts.shape[1]),
            "eps": float(eps),
            "total_pairs": int(expect),
            "max_per_cell": int(index.max_per_cell),
            # per-query candidate-capacity histogram {class: rows} -- the
            # skew that motivates the occupancy buckets (DESIGN.md S6)
            "window_caps_hist": {str(k): v for k, v in
                                 sorted(plan.hist.items())},
            # same histogram over MERGED range-window capacities: what the
            # merged sweep's buckets actually launch at (DESIGN.md S7)
            "merged_window_caps_hist": {str(k): v for k, v in
                                        sorted(mplan.hist.items())},
            "impls": {},
        }
        for impl in impls:
            stats = self_join_count(pts, eps, index=index, distance_impl=impl,
                                    merge_last_dim=merge)
            assert stats.total_pairs == expect, (name, impl, stats)
            # the interpreted cell_join kernel is ~100x slower than its
            # Mosaic build; one timed trial keeps the sweep tractable
            trials = 1 if impl == "pallas" else args.trials
            t_count = best_of(
                lambda: self_join_count(pts, eps, index=index,
                                        distance_impl=impl,
                                        merge_last_dim=merge),
                trials)
            t_join = best_of(
                lambda: self_join(pts, eps, index=index, distance_impl=impl,
                                  sort_result=False, merge_last_dim=merge),
                trials)
            entry["impls"][impl] = {"count_s": t_count, "join_s": t_join}
            if impl == "fused":
                entry["impls"][impl]["route"] = stats.route
                entry["impls"][impl]["n_offsets_swept"] = stats.n_offsets
                # Cell-run DMA dedup trajectory (DESIGN.md S11): row-loop
                # vs run-loop join through the same fused driver, plus the
                # analytic per-workload DMA-window ledger + run-length
                # histogram (the redundancy reduction as a TRACKED number)
                from repro.core.selfjoin import (_self_join_fused,
                                                 dma_window_stats)

                t_row = best_of(
                    lambda: _self_join_fused(index, unicomp=True,
                                             sort_result=False, merged=merge,
                                             run_loop=False), trials)
                t_run = best_of(
                    lambda: _self_join_fused(index, unicomp=True,
                                             sort_result=False, merged=merge,
                                             run_loop=True), trials)
                entry["impls"][impl]["join_row_s"] = t_row
                entry["impls"][impl]["join_run_s"] = t_run
                entry["impls"][impl]["run_over_row_join"] = t_row / t_run
                entry["dma"] = dma_window_stats(index, merged=merge)
                d = entry["dma"]
                print(f"[bench] {name:14s} {'dma':6s} "
                      f"row {t_row*1e3:9.1f} ms   run {t_run*1e3:9.1f} ms  "
                      f"({t_row / t_run:.2f}x)   windows "
                      f"{d['dma_windows_row']} -> {d['dma_windows_run']} "
                      f"({d['reduction_factor']:.2f}x, occ "
                      f"{d['mean_cell_occupancy']:.2f})", flush=True)
            print(f"[bench] {name:14s} {impl:6s} "
                  f"count {t_count*1e3:9.1f} ms   join {t_join*1e3:9.1f} ms"
                  + (f"   route={stats.route} n_off={stats.n_offsets}"
                     if impl == "fused" else ""),
                  flush=True)
        j = entry["impls"]
        if "jnp" in j and "fused" in j:
            entry["speedup_fused_vs_jnp"] = {
                "count": j["jnp"]["count_s"] / j["fused"]["count_s"],
                "join": j["jnp"]["join_s"] / j["fused"]["join_s"],
            }
            if args.assert_floor:
                r = entry["speedup_fused_vs_jnp"]["count"]
                assert r >= 1.0, (
                    f"routing floor violated on {name}: fused count {r:.2f}x "
                    f"vs jnp (route={j['fused']['route']}) -- the routing "
                    f"table must never pin a fused plan that loses to jnp")
        results.append(entry)

    headline = next((e for e in results
                     if e["workload"] == "uniform-2d"
                     and "speedup_fused_vs_jnp" in e), None)
    payload = {
        "bench": "selfjoin-distance-impl",
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "note": ("CPU proxy timings: 'pallas' via kernel interpreter, "
                 "'fused' via the reference lowering of the fused kernel "
                 "(bit-identical outputs to the Mosaic kernel)"),
        "headline": None if headline is None else {
            "workload": "uniform-2d",
            "n_points": headline["n_points"],
            "fused_over_jnp_join": headline["speedup_fused_vs_jnp"]["join"],
            "fused_over_jnp_count": headline["speedup_fused_vs_jnp"]["count"],
        },
        "results": results,
    }
    for section in ("serve", "distributed", "load", "index"):  # modes preserve others
        if section in existing:
            payload[section] = existing[section]
    validate_schema(payload)
    if args.smoke:
        print("[bench] smoke: schema validated "
              f"({len(results)} workloads, floor assert "
              f"{'on' if args.assert_floor else 'off'})")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    if headline is not None:
        print(f"[bench] headline: fused over jnp (uniform-2d, "
              f"{headline['n_points']} pts): "
              f"join {payload['headline']['fused_over_jnp_join']:.2f}x, "
              f"count {payload['headline']['fused_over_jnp_count']:.2f}x")
    print(f"[bench] wrote {out}")
    return payload


if __name__ == "__main__":
    main()
