"""Self-join perf trajectory: count/fill across distance_impl variants.

    PYTHONPATH=src python benchmarks/bench_selfjoin.py [--out BENCH_selfjoin.json]

Times ``self_join_count`` (count) and ``self_join`` (count+fill, unsorted --
the paper reports the result sort separately) for n in {2, 4, 6} on uniform
and clustered datasets, across distance_impl in {jnp, pallas, fused}, with
the grid index prebuilt (index construction is shared by every impl and
benchmarked in benchmarks/joins.py).

On this CPU container the 'pallas' impl runs the cell_join kernel through
the interpreter and the 'fused' impl runs the reference lowering of
kernels/fused_join.py (same algorithm, same outputs as the Mosaic kernel);
absolute times are machine-local, the IMPL-vs-IMPL ratios are the claim
(interpret-mode CPU timing as proxy, ISSUE 1). The headline acceptance
number is fused-vs-jnp on the 2-D uniform 100k workload.

Writes BENCH_selfjoin.json (repo root by default) -- the first point of the
perf trajectory; later PRs append runs, EXPERIMENTS.md tracks the history.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from repro.core.grid import build_grid_host                     # noqa: E402
from repro.core.selfjoin import self_join, self_join_count      # noqa: E402
from benchmarks.common import syn                               # noqa: E402

IMPLS = ("jnp", "pallas", "fused")


def clustered(n_points: int, n_dims: int, seed: int = 3) -> np.ndarray:
    """Gaussian clusters in [0, 100]^n (sw_like is 2/3-D only)."""
    rng = np.random.default_rng(seed)
    k = max(n_points // 200, 4)
    centers = rng.uniform(0, 100, (k, n_dims))
    pts = centers[rng.integers(0, k, n_points)]
    return pts + rng.normal(0, 1.5, pts.shape)


def workloads(args):
    # eps tuned per dimensionality for paper-like selectivity (a handful of
    # neighbors per point on the uniform sets; denser on the clustered sets).
    yield "uniform-2d", syn(args.points_2d, 2), 0.4
    yield "clustered-2d", clustered(args.points_2d, 2), 0.4
    yield "uniform-4d", syn(args.points_4d, 4), 6.0
    yield "clustered-4d", clustered(args.points_4d, 4), 3.0
    yield "uniform-6d", syn(args.points_6d, 6), 14.0
    yield "clustered-6d", clustered(args.points_6d, 6), 4.0


def best_of(fn, trials: int) -> float:
    fn()  # warm-up: jit compile excluded (paper excludes context setup)
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_selfjoin.json"))
    ap.add_argument("--points-2d", type=int, default=100_000)
    ap.add_argument("--points-4d", type=int, default=20_000)
    ap.add_argument("--points-6d", type=int, default=10_000)
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--impls", default=",".join(IMPLS),
                    help="comma-separated subset of %s" % (IMPLS,))
    args = ap.parse_args(argv)
    impls = tuple(args.impls.split(","))

    import jax

    results = []
    for name, pts, eps in workloads(args):
        index = build_grid_host(pts, eps)
        expect = self_join_count(pts, eps, index=index).total_pairs
        entry = {
            "workload": name,
            "n_points": int(pts.shape[0]),
            "n_dims": int(pts.shape[1]),
            "eps": float(eps),
            "total_pairs": int(expect),
            "max_per_cell": int(index.max_per_cell),
            "impls": {},
        }
        for impl in impls:
            stats = self_join_count(pts, eps, index=index, distance_impl=impl)
            assert stats.total_pairs == expect, (name, impl, stats)
            # the interpreted cell_join kernel is ~100x slower than its
            # Mosaic build; one timed trial keeps the sweep tractable
            trials = 1 if impl == "pallas" else args.trials
            t_count = best_of(
                lambda: self_join_count(pts, eps, index=index,
                                        distance_impl=impl),
                trials)
            t_join = best_of(
                lambda: self_join(pts, eps, index=index, distance_impl=impl,
                                  sort_result=False),
                trials)
            entry["impls"][impl] = {"count_s": t_count, "join_s": t_join}
            print(f"[bench] {name:14s} {impl:6s} "
                  f"count {t_count*1e3:9.1f} ms   join {t_join*1e3:9.1f} ms",
                  flush=True)
        j = entry["impls"]
        if "jnp" in j and "fused" in j:
            entry["speedup_fused_vs_jnp"] = {
                "count": j["jnp"]["count_s"] / j["fused"]["count_s"],
                "join": j["jnp"]["join_s"] / j["fused"]["join_s"],
            }
        results.append(entry)

    headline = next((e for e in results
                     if e["workload"] == "uniform-2d"
                     and "speedup_fused_vs_jnp" in e), None)
    payload = {
        "bench": "selfjoin-distance-impl",
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "note": ("CPU proxy timings: 'pallas' via kernel interpreter, "
                 "'fused' via the reference lowering of the fused kernel "
                 "(bit-identical outputs to the Mosaic kernel)"),
        "headline": None if headline is None else {
            "workload": "uniform-2d",
            "n_points": headline["n_points"],
            "fused_over_jnp_join": headline["speedup_fused_vs_jnp"]["join"],
            "fused_over_jnp_count": headline["speedup_fused_vs_jnp"]["count"],
        },
        "results": results,
    }
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    if headline is not None:
        print(f"[bench] headline: fused over jnp (uniform-2d, "
              f"{headline['n_points']} pts): "
              f"join {payload['headline']['fused_over_jnp_join']:.2f}x, "
              f"count {payload['headline']['fused_over_jnp_count']:.2f}x")
    print(f"[bench] wrote {out}")
    return payload


if __name__ == "__main__":
    main()
