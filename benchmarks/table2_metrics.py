"""Table II analogue: execution characteristics of UNICOMP.

The paper profiles occupancy and L1 cache utilization on the GPU to explain
why UNICOMP's ~2x work reduction does not always yield 2x time. Those
counters have no TPU meaning; the structural analogues we report are:

  work ratio        cells visited & candidate slots, without/with UNICOMP
                    (the actual work-avoidance factor)
  padding efficiency valid candidate slots / (padded) window slots -- the
                    TPU cost of regularizing ragged cells into fixed windows
                    (the analogue of occupancy loss)
  query-tile reuse  stencil offsets per query tile residency -- how many
                    times the VMEM-resident query tile is reused (the
                    analogue of the L1 temporal-locality gain, kernel
                    cell_join.py keeps the tile resident across offsets)
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.grid import build_grid_host
from repro.core.selfjoin import self_join_count


def run(scale=1.0):
    n = int(20000 * scale)
    rows = []
    for dname, pts, eps in [
        ("SW2DA", common.sw_like(n, 2), 0.4),
        ("SDSS2DA", common.sdss_like(n), 0.3),
        ("Syn5D", common.syn(n, 5), 8.0),
        ("Syn6D", common.syn(n, 6), 10.0),
    ]:
        index = build_grid_host(pts, eps)
        cmax = int(index.max_per_cell)
        cpad = -(-max(cmax, 1) // 8) * 8
        s_u = self_join_count(pts, eps, unicomp=True, index=index)
        s_f = self_join_count(pts, eps, unicomp=False, index=index)
        valid_frac_u = s_u.candidates_checked / (
            s_u.offsets * pts.shape[0] * cpad)
        rows.append({
            "dataset": dname, "eps": eps, "n": pts.shape[1],
            "cells_ratio": s_f.cells_visited / max(s_u.cells_visited, 1),
            "cand_ratio": s_f.candidates_checked / max(
                s_u.candidates_checked, 1),
            "pad_efficiency": valid_frac_u,
            "max_per_cell": cmax,
            "window": cpad,
            "query_tile_reuse": s_u.offsets,
        })
        r = rows[-1]
        print(f"[table2] {dname}: work ratio cells {r['cells_ratio']:.2f}x "
              f"cands {r['cand_ratio']:.2f}x, pad-eff "
              f"{r['pad_efficiency']:.3f}, reuse {r['query_tile_reuse']}")
    common.store("table2", {"rows": rows})
    return rows


if __name__ == "__main__":
    run()
