"""Figures 7, 8, 9 + Fig 1: derived from the response-time sweeps.

fig7  speedup of GPU-SJ (UNICOMP) over CPU-RTREE       (paper avg: 26.9x)
fig8  speedup of GPU-SJ (UNICOMP) over SUPEREGO        (paper avg: 2.38x)
fig9  UNICOMP response-time ratio (without / with)     (paper: <2 at n<=3,
                                                        >=2 possible n>=5)
fig1  motivation: R-tree self-join time + avg neighbors vs dimension
"""
from __future__ import annotations

import numpy as np

from benchmarks import common


def _ratios(num_key, den_key):
    out = []
    for fig in ("fig4", "fig5", "fig6"):
        data = common.load(fig)
        if not data:
            continue
        for row in data["rows"]:
            out.append({
                "dataset": row["dataset"], "eps": row["eps"],
                "ratio": row[num_key] / row[den_key],
            })
    return out


def fig7():
    rows = _ratios("cpurtree_s", "gpusj_s")
    avg = float(np.mean([r["ratio"] for r in rows])) if rows else 0.0
    common.store("fig7", {"rows": rows, "avg_speedup": avg,
                          "paper_avg": 26.9})
    print(f"[fig7] GPU-SJ vs CPU-RTREE: avg {avg:.1f}x over {len(rows)} "
          f"cells (paper: 26.9x on a TITAN X vs 1 CPU thread)")
    return avg


def fig8():
    rows = _ratios("superego_s", "gpusj_s")
    avg = float(np.mean([r["ratio"] for r in rows])) if rows else 0.0
    wins = sum(1 for r in rows if r["ratio"] > 1)
    common.store("fig8", {"rows": rows, "avg_speedup": avg,
                          "wins": wins, "paper_avg": 2.38})
    print(f"[fig8] GPU-SJ vs SUPEREGO: avg {avg:.2f}x, wins {wins}/"
          f"{len(rows)} (paper: 2.38x vs 32 threads)")
    return avg


def fig9():
    rows = _ratios("gpusj_nouni_s", "gpusj_s")
    by_n = {}
    for fig in ("fig4", "fig5", "fig6"):
        data = common.load(fig)
        if not data:
            continue
        for row in data["rows"]:
            by_n.setdefault(row["n"], []).append(
                row["gpusj_nouni_s"] / row["gpusj_s"])
    summary = {n: float(np.mean(v)) for n, v in sorted(by_n.items())}
    common.store("fig9", {"rows": rows, "by_dim": summary})
    print(f"[fig9] UNICOMP ratio by dim: "
          + ", ".join(f"n={n}: {r:.2f}x" for n, r in summary.items())
          + " (paper: ~1-1.5x low-D, >=2x possible at n>=5)")
    return summary


def fig1(scale=1.0, trials=2):
    """Motivation: CPU R-tree self-join time + mean neighbors vs dimension."""
    from benchmarks.joins import IMPLS
    from repro.core.selfjoin import per_point_neighbor_counts

    n = int(10000 * scale)
    rows = []
    for d in (2, 3, 4, 5, 6):
        pts = common.syn(n, d, seed=5)
        eps = 1.0 * (d / 2.0)  # keep some density as volume grows
        t, pairs = common.timeit(lambda: IMPLS["cpurtree"](pts, eps),
                                 trials=trials)
        mean_nbrs = pairs / n
        rows.append({"n": d, "eps": eps, "rtree_s": t,
                     "mean_neighbors": mean_nbrs})
        print(f"[fig1] n={d}: rtree {t:.2f}s, {mean_nbrs:.2f} avg neighbors")
    common.store("fig1", {"rows": rows})
    return rows


if __name__ == "__main__":
    fig1()
    fig7()
    fig8()
    fig9()
