"""The four implementations under test, with uniform call signatures.

GPU-SJ (+/- UNICOMP) warms up its jit cache before timing (the paper's GPU
timings exclude CUDA context setup); index build is INCLUDED in gpusj times
(grid build is part of the algorithm; the R-tree's build is excluded, as the
paper excludes it for CPU-RTREE -- making the comparison conservative for
GPU-SJ).
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines import build_rtree, ego_join, rtree_join
from repro.core.brute import brute_force_count
from repro.core.selfjoin import self_join_count


def gpusj(points, eps, *, unicomp=True):
    return self_join_count(points, eps, unicomp=unicomp).total_pairs


def gpusj_warm(points, eps, *, unicomp=True):
    """Trigger compilation once so timed runs measure execution."""
    self_join_count(points, eps, unicomp=unicomp)


def cpurtree(points, eps, *, tree=None):
    return rtree_join(points, eps)


def superego(points, eps):
    return ego_join(points, eps)


def brute(points, eps):
    return brute_force_count(points, eps)


IMPLS = {
    "gpusj": gpusj,
    "gpusj_nouni": lambda p, e: gpusj(p, e, unicomp=False),
    "cpurtree": cpurtree,
    "superego": superego,
    "brute": brute,
}
