"""Shared benchmark machinery: datasets, timing, result store.

Datasets mirror the paper's Table I, scaled to this CPU container (the
paper's |D| are 2-15M; defaults here are 2e4-1e5 -- pass --full to restore
paper sizes on real hardware). Comparative CLAIMS (GPU-SJ vs brute force vs
CPU baselines, UNICOMP work ratios, count consistency) are validated at the
scaled sizes; absolute times are machine-local.

  Syn{n}D   uniform [0,100]^n              (the grid's worst case, SVI-C)
  SW2D/3D   clustered lat/lon (+TEC)       (space-weather-like skew)
  SDSS2D    filamentary 2-D galaxy field   (survey-like skew)
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "bench")


def syn(n_points: int, n_dims: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.uniform(0, 100, size=(n_points, n_dims))


def sw_like(n_points: int, n_dims: int = 2, seed: int = 1) -> np.ndarray:
    """Clustered geo points: dense mid-latitude bands + sparse elsewhere."""
    rng = np.random.default_rng(seed)
    n_band = int(n_points * 0.8)
    lat = np.concatenate([
        rng.normal(45, 8, n_band), rng.uniform(-90, 90, n_points - n_band)])
    lon = rng.uniform(-180, 180, n_points)
    cols = [lat[:n_points], lon]
    if n_dims == 3:
        cols.append(rng.lognormal(2.0, 0.5, n_points))  # TEC-like
    return np.stack(cols, axis=1)


def sdss_like(n_points: int, seed: int = 2) -> np.ndarray:
    """Filamentary 2-D field: points along random walls + field noise."""
    rng = np.random.default_rng(seed)
    n_fil = int(n_points * 0.7)
    k = 40
    centers = rng.uniform(0, 100, (k, 2))
    angles = rng.uniform(0, np.pi, k)
    which = rng.integers(0, k, n_fil)
    t = rng.normal(0, 6, n_fil)
    fil = centers[which] + np.stack(
        [t * np.cos(angles[which]), t * np.sin(angles[which])], 1)
    fil += rng.normal(0, 0.3, fil.shape)
    field = rng.uniform(0, 100, (n_points - n_fil, 2))
    return np.clip(np.concatenate([fil, field]), 0, 100)


def timeit(fn, *, trials: int = 3):
    """Median wall time of ``trials`` runs (paper averages 3 trials)."""
    times = []
    out = None
    for _ in range(trials):
        t0 = time.perf_counter()
        out = fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), out


def store(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def load(name: str):
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)
