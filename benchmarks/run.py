"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per cell (us_per_call = the timed
implementation under test, GPU-SJ with UNICOMP; derived = the headline
derived quantity for that figure). ``--full`` restores paper-scale dataset
sizes (hours on this CPU container; sized for real accelerators).
"""
from __future__ import annotations

import argparse


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale dataset sizes")
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--only", type=str, default=None,
                    help="comma list: fig1,fig4,fig5,fig6,fig7,fig8,fig9,"
                         "table2,roofline")
    args = ap.parse_args(argv)
    scale = args.scale if args.scale else (100.0 if args.full else 1.0)
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    from benchmarks import fig_response_time, fig_speedup, table2_metrics
    from benchmarks import roofline as roofline_mod

    lines = []
    if want("fig4"):
        for r in fig_response_time.fig4(scale=scale):
            lines.append((f"fig4/{r['dataset']}/eps{r['eps']}",
                          r["gpusj_s"] * 1e6, r["pairs"]))
    if want("fig5"):
        for r in fig_response_time.fig5(scale=scale):
            lines.append((f"fig5/{r['dataset']}/eps{r['eps']}",
                          r["gpusj_s"] * 1e6, r["pairs"]))
    if want("fig6"):
        for r in fig_response_time.fig6(scale=scale):
            lines.append((f"fig6/{r['dataset']}/eps{r['eps']}",
                          r["gpusj_s"] * 1e6, r["pairs"]))
    if want("fig1"):
        for r in fig_speedup.fig1(scale=scale):
            lines.append((f"fig1/n{r['n']}", r["rtree_s"] * 1e6,
                          round(r["mean_neighbors"], 3)))
    if want("fig7"):
        avg = fig_speedup.fig7()
        lines.append(("fig7/avg_speedup_vs_rtree", 0.0, round(avg, 2)))
    if want("fig8"):
        avg = fig_speedup.fig8()
        lines.append(("fig8/avg_speedup_vs_superego", 0.0, round(avg, 2)))
    if want("fig9"):
        for n, ratio in fig_speedup.fig9().items():
            lines.append((f"fig9/unicomp_ratio_n{n}", 0.0, round(ratio, 3)))
    if want("table2"):
        for r in table2_metrics.run(scale=scale):
            lines.append((f"table2/{r['dataset']}", 0.0,
                          round(r["cand_ratio"], 3)))
    if want("roofline"):
        roofline_mod.main()

    print("\nname,us_per_call,derived")
    for name, us, derived in lines:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
