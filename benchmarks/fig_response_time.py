"""Figures 4, 5, 6: response time vs epsilon, all implementations.

fig4  real-world-like datasets (SW2D/SW3D/SDSS2D; clustered + filamentary)
fig5  synthetic uniform 2-6D at the '2M' scale point (scaled down on CPU)
fig6  synthetic uniform at the '10M' scale point (larger |D|)

Each cell times GPU-SJ (with and without UNICOMP), CPU-RTREE, SUPEREGO, and
(once per dataset; eps-independent) GPU brute force, and asserts every
implementation agrees on the pair count -- the paper's cross-validation.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.joins import IMPLS, gpusj_warm
from repro.core.selfjoin import self_join_count


def _sweep(name, datasets, eps_list, *, brute_once=True, trials=3):
    rows = []
    for dname, pts in datasets:
        bcount = None
        btime = None
        for i, eps in enumerate(eps_list[dname]):
            gpusj_warm(pts, eps, unicomp=True)
            gpusj_warm(pts, eps, unicomp=False)
            row = {"dataset": dname, "eps": eps, "n": pts.shape[1],
                   "npts": pts.shape[0]}
            counts = {}
            for impl in ("gpusj", "gpusj_nouni", "cpurtree", "superego"):
                t, c = common.timeit(lambda: IMPLS[impl](pts, eps),
                                     trials=trials)
                row[impl + "_s"] = t
                counts[impl] = int(c)
            if brute_once and i == 0:
                btime, bcount = common.timeit(
                    lambda: IMPLS["brute"](pts, eps), trials=1)
            row["brute_s"] = btime if i == 0 else None
            assert len(set(counts.values())) == 1, (dname, eps, counts)
            if i == 0 and bcount is not None:
                assert bcount == counts["gpusj"], (dname, eps)
            row["pairs"] = counts["gpusj"]
            rows.append(row)
            print(f"[{name}] {dname} eps={eps}: gpusj {row['gpusj_s']:.3f}s "
                  f"rtree {row['cpurtree_s']:.3f}s ego {row['superego_s']:.3f}s "
                  f"pairs {row['pairs']}", flush=True)
    common.store(name, {"rows": rows})
    return rows


def fig4(scale=1.0, trials=3):
    n = int(20000 * scale)
    datasets = [
        ("SW2DA", common.sw_like(n, 2)),
        ("SW3DA", common.sw_like(n, 3)),
        ("SDSS2DA", common.sdss_like(n)),
    ]
    eps = {"SW2DA": [0.4, 0.8, 1.2], "SW3DA": [0.8, 1.6, 2.4],
           "SDSS2DA": [0.3, 0.6, 0.9]}
    return _sweep("fig4", datasets, eps, trials=trials)


def fig5(scale=1.0, trials=3):
    n = int(20000 * scale)
    datasets = [(f"Syn{d}D", common.syn(n, d)) for d in (2, 3, 4, 5, 6)]
    eps = {"Syn2D": [0.4, 0.8, 1.2], "Syn3D": [1.5, 2.5, 3.5],
           "Syn4D": [3.0, 5.0, 7.0], "Syn5D": [6.0, 8.0, 10.0],
           "Syn6D": [8.0, 10.0, 12.0]}
    return _sweep("fig5", datasets, eps, trials=trials)


def fig6(scale=1.0, trials=2):
    n = int(60000 * scale)
    datasets = [(f"Syn{d}D10M", common.syn(n, d, seed=9)) for d in (2, 4, 6)]
    eps = {"Syn2D10M": [0.3, 0.6], "Syn4D10M": [2.5, 4.0],
           "Syn6D10M": [7.0, 9.0]}
    return _sweep("fig6", datasets, eps, trials=trials)


if __name__ == "__main__":
    fig4()
    fig5()
    fig6()
