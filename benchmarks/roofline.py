"""Roofline table from the dry-run artifact (results/dryrun.json).

Prints the per-(arch x shape x mesh) three-term roofline with bottleneck and
the MODEL_FLOPS/HLO_FLOPs useful fraction; the markdown form of this table
is EXPERIMENTS.md SRoofline.
"""
from __future__ import annotations

import json
import os

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results",
                      "dryrun.json")


def rows(path=DRYRUN, mesh="single"):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    out = []
    for key, val in sorted(data.items()):
        if key.startswith("_") or not key.endswith("|" + mesh):
            continue
        arch, shape, _ = key.split("|")
        if "skipped" in val:
            out.append({"arch": arch, "shape": shape, "skip": val["skipped"]})
            continue
        if "roofline" not in val:
            out.append({"arch": arch, "shape": shape,
                        "skip": "ERROR: " + val.get("error", "?")})
            continue
        r = val["roofline"]
        mc = val.get("model_check", {})
        out.append({
            "arch": arch, "shape": shape,
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bottleneck": r["bottleneck"],
            "useful": mc.get("useful_fraction"),
            "step_s": max(r["compute_s"], r["memory_s"], r["collective_s"]),
            "frac_of_roofline": r["compute_s"] / max(
                r["compute_s"], r["memory_s"], r["collective_s"]),
        })
    return out


def main(mesh="single"):
    table = rows(mesh=mesh)
    if not table:
        print("[roofline] no dryrun.json yet -- run "
              "`python -m repro.launch.dryrun --all --mesh both "
              "--out results/dryrun.json`")
        return
    print(f"{'arch':16s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
          f"{'collective':>10s} {'bound':>10s} {'useful':>7s}")
    for r in table:
        if "skip" in r:
            print(f"{r['arch']:16s} {r['shape']:12s} SKIP: {r['skip'][:48]}")
            continue
        u = f"{r['useful']:.2f}" if r.get("useful") else "--"
        print(f"{r['arch']:16s} {r['shape']:12s} {r['compute_s']:10.3e} "
              f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
              f"{r['bottleneck']:>10s} {u:>7s}")


if __name__ == "__main__":
    main()
