"""Continuous-batching serving pipeline (DESIGN.md S8).

Covers the BatchingJoinService tentpole: coalescing correctness under
ARBITRARY partitions of a query set (the per-request slice must be
bitwise identical to serving the chunk alone), the admission-queue knobs,
split/merge of oversized requests, the mixed-size mixed-eps no-retrace
contract, the sharded scatter-gather integration, the steady-state stats
fix of _JoinServiceBase, and the load generator.
"""
import numpy as np
import pytest

from repro.core.grid import build_grid_host
from repro.core.query_join import (PendingJoin, coalesce_requests, prepare,
                                   slice_result)
from repro.launch.serve import (BatchingJoinService, JoinService,
                                ShardedJoinService)


def brute_counts(queries, pts, eps):
    d2 = ((queries[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    return (d2 <= eps * eps).sum(1).astype(np.int32)


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(7)
    pts = rng.uniform(0, 100, size=(2500, 3))
    return pts, 3.0


@pytest.fixture(scope="module")
def prepared(dataset):
    pts, eps = dataset
    return prepare(build_grid_host(pts, eps))


# ---------------------------------------------------------------------------
# coalesce/slice primitives
# ---------------------------------------------------------------------------

def test_coalesce_requests_bounds():
    a = np.zeros((3, 2))
    b = np.ones((0, 2))
    c = np.full((5, 2), 2.0)
    cat, bounds = coalesce_requests([a, b, c])
    assert cat.shape == (8, 2)
    assert bounds.tolist() == [0, 3, 3, 8]


def test_coalesce_requests_rejects_empty_list():
    with pytest.raises(ValueError):
        coalesce_requests([])


def test_coalesce_requests_rejects_mixed_dims():
    with pytest.raises(ValueError):
        coalesce_requests([np.zeros((2, 2)), np.zeros((2, 3))])


def test_slice_result_matches_solo(prepared, dataset):
    pts, eps = dataset
    rng = np.random.default_rng(0)
    q = rng.uniform(0, 100, size=(90, 3))
    res = prepared.join(q, return_pairs=True)
    mid = slice_result(res, 30, 70)
    solo = prepared.join(q[30:70], return_pairs=True)
    assert np.array_equal(mid.counts, solo.counts)
    assert np.array_equal(mid.pairs, solo.pairs)
    empty = slice_result(res, 12, 12)
    assert empty.counts.shape == (0,) and empty.pairs.shape == (0, 2)


def test_join_async_matches_join(prepared):
    rng = np.random.default_rng(1)
    q = rng.uniform(0, 100, size=(150, 3))
    pending = prepared.join_async(q, return_pairs=True)
    assert isinstance(pending, PendingJoin)
    res = pending.result()
    ref = prepared.join(q, return_pairs=True)
    assert np.array_equal(res.counts, ref.counts)
    assert np.array_equal(res.pairs, ref.pairs)
    assert pending.ready()                     # resolved => trivially ready
    assert pending.result() is res             # idempotent


# ---------------------------------------------------------------------------
# BatchingJoinService: coalescing correctness (the satellite property test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk_sizes", [
    [40],                        # single request
    [0, 40, 0],                  # empty requests interleaved
    [17, 1, 63, 9],              # ragged partition
    [200],                       # larger than max_batch: split into parts
    [130, 0, 70, 200, 5],        # everything at once
])
def test_partition_property(dataset, prepared, chunk_sizes):
    """ANY partition of a query set served through BatchingJoinService
    yields per-request results identical to serving each chunk alone
    through PreparedJoin.join -- including empty requests and requests
    wider than max_batch."""
    pts, eps = dataset
    rng = np.random.default_rng(3)
    chunks = [rng.uniform(0, 100, size=(n, 3)) for n in chunk_sizes]
    solos = [prepared.join(c, return_pairs=True) if c.shape[0] else None
             for c in chunks]

    svc = BatchingJoinService(pts, eps, return_pairs=True,
                              max_batch=128, max_wait_ms=0.5)
    svc.warmup()
    tickets = [svc.submit(c) for c in chunks]
    svc.pump()
    svc.drain()
    for t, c, solo in zip(tickets, chunks, solos):
        assert t.done()
        got = t.result()
        if c.shape[0] == 0:
            assert got.counts.shape == (0,)
            assert got.pairs.shape == (0, 2)
            continue
        assert np.array_equal(got.counts, solo.counts)
        assert np.array_equal(got.pairs, solo.pairs)


def test_oversized_request_splits_and_merges(dataset, prepared):
    pts, eps = dataset
    rng = np.random.default_rng(4)
    q = rng.uniform(0, 100, size=(300, 3))
    svc = BatchingJoinService(pts, eps, return_pairs=True, max_batch=128)
    svc.warmup()
    t = svc.submit(q)
    assert t.n_parts == 3                       # 128 + 128 + 44
    svc.drain()
    got = t.result()
    ref = prepared.join(q, return_pairs=True)
    assert np.array_equal(got.counts, ref.counts)
    assert np.array_equal(got.pairs, ref.pairs)


def test_incomplete_ticket_raises(dataset):
    pts, eps = dataset
    svc = BatchingJoinService(pts, eps, max_batch=128,
                              max_wait_ms=1e6)     # never due on its own
    svc.warmup()
    t = svc.submit(np.zeros((4, 3)))
    with pytest.raises(RuntimeError, match="incomplete"):
        t.result()
    svc.drain()
    assert t.result().counts.shape == (4,)


def test_mixed_eps_never_coalesce_but_both_answer(dataset, prepared):
    pts, eps = dataset
    rng = np.random.default_rng(5)
    qa = rng.uniform(0, 100, size=(30, 3))
    qb = rng.uniform(0, 100, size=(30, 3))
    svc = BatchingJoinService(pts, eps, max_batch=256)
    svc.warmup()
    ta = svc.submit(qa, eps=eps)
    tb = svc.submit(qb, eps=0.5 * eps)          # different traced radius
    svc.drain()
    assert svc.n_launches == 2                  # eps mismatch: no coalesce
    assert np.array_equal(ta.result().counts, prepared.counts(qa))
    assert np.array_equal(tb.result().counts,
                          prepared.counts(qb, eps=0.5 * eps))


def test_no_retrace_and_coalescing_under_mixed_load(dataset):
    """Steady-state mixed-size mixed-eps load through the batching service
    must hit cached executables only, and must actually coalesce."""
    pts, eps = dataset
    rng = np.random.default_rng(6)
    svc = BatchingJoinService(pts, eps, max_batch=256, max_wait_ms=0.2)
    svc.warmup()                                # auto-marks steady
    for _ in range(30):
        n = int(rng.choice([1, 7, 32, 64, 300]))
        e = float(rng.choice([eps, 0.7 * eps]))
        svc.submit(rng.uniform(0, 100, size=(n, 3)), eps=e)
        svc.pump()
    svc.drain()
    svc.assert_no_retrace()
    assert svc.coalesce_factor > 1.0
    stats = svc.n_coalesced / max(svc.n_launches, 1)
    assert stats == pytest.approx(svc.coalesce_factor)


def test_sharded_batching_matches_single(dataset, prepared):
    pts, eps = dataset
    rng = np.random.default_rng(8)
    q = rng.uniform(0, 100, size=(120, 3))
    svc = BatchingJoinService(pts, eps, n_slabs=3, return_pairs=True,
                              max_batch=256)
    svc.warmup()
    t = svc.submit(q)
    svc.drain()
    got = t.result()
    ref = prepared.join(q, return_pairs=True)
    assert np.array_equal(got.counts, ref.counts)
    assert np.array_equal(got.pairs, ref.pairs)
    svc.assert_no_retrace()


def test_sync_query_path(dataset, prepared):
    pts, eps = dataset
    rng = np.random.default_rng(9)
    q = rng.uniform(0, 100, size=(50, 3))
    svc = BatchingJoinService(pts, eps, max_batch=128)
    svc.warmup()
    res = svc.query(q)
    assert np.array_equal(res.counts, prepared.counts(q))
    assert len(svc.latencies_ms) == 1           # steady after warmup


# ---------------------------------------------------------------------------
# _JoinServiceBase steady-state stats fix (satellite)
# ---------------------------------------------------------------------------

def test_warmup_auto_marks_steady_with_warning(dataset):
    pts, eps = dataset
    svc = JoinService(pts, eps)
    with pytest.warns(UserWarning, match="auto-marking steady"):
        svc.warmup(32)
    assert svc._steady


def test_stats_exclude_warmup_window(dataset):
    pts, eps = dataset
    rng = np.random.default_rng(10)
    svc = JoinService(pts, eps)
    q = rng.uniform(0, 100, size=(32, 3))
    svc.query(q)                                # pre-steady: warmup sample
    assert len(svc.warmup_latencies_ms) == 1
    assert len(svc.latencies_ms) == 0
    with pytest.warns(UserWarning):
        svc.warmup(32)
    for _ in range(3):
        svc.query(q)
    assert len(svc.latencies_ms) == 3           # steady window only
    p50, p99 = svc.percentiles()
    lat = np.asarray(svc.latencies_ms)
    assert p50 == pytest.approx(float(np.percentile(lat, 50)))
    # requests_per_sec counts the steady window, not the tainted sample
    assert svc.requests_per_sec() == pytest.approx(
        3 / (lat.sum() / 1000), rel=1e-6)


def test_stats_fallback_warns_when_never_steady(dataset):
    pts, eps = dataset
    rng = np.random.default_rng(11)
    svc = JoinService(pts, eps)
    svc.query(rng.uniform(0, 100, size=(32, 3)))
    with pytest.warns(UserWarning, match="falling back to the warmup"):
        p50, _ = svc.percentiles()
    assert p50 > 0


def test_explicit_mark_steady_suppresses_warning(dataset):
    pts, eps = dataset
    svc = JoinService(pts, eps)
    import warnings

    svc.prepared.warm(32)        # compile first so the mark is post-compile
    svc.mark_steady()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        svc.warmup(32)           # already steady: no warning
    assert svc._steady


# ---------------------------------------------------------------------------
# load generator
# ---------------------------------------------------------------------------

def test_poisson_schedule_shape_and_rate():
    from repro.launch.loadgen import poisson_schedule

    s = poisson_schedule(2000, 100.0, seed=0)
    assert s.shape == (2000,)
    assert np.all(np.diff(s) > 0)
    # mean inter-arrival ~ 1/rate
    assert np.mean(np.diff(s)) == pytest.approx(0.01, rel=0.15)


def test_loadgen_open_and_closed_loops(dataset):
    from repro.launch.loadgen import (RequestMix, make_request_stream,
                                      run_closed_loop, run_open_loop)

    pts, eps = dataset
    mix = RequestMix(sizes=(8, 16), eps_values=(eps, 0.5 * eps))
    stream = make_request_stream(12, mix, 3, seed=1)
    assert all(q.shape[1] == 3 for q, _ in stream)

    svc = BatchingJoinService(pts, eps, max_batch=128, max_wait_ms=0.5)
    svc.warmup()
    rep = run_open_loop(svc, stream, 300.0, seed=2)
    assert rep.n_requests == 12
    assert rep.p99_ms >= rep.p50_ms > 0
    assert rep.coalesce_factor >= 1.0
    d = rep.to_dict()
    assert {"mode", "offered_rps", "achieved_rps", "p50_ms", "p99_ms",
            "coalesce_factor"} <= set(d)

    base = JoinService(pts, eps)
    base.warmup(16)
    rep2 = run_closed_loop(base, stream)
    assert rep2.mode == "closed" and rep2.offered_rps is None
    assert rep2.n_requests == 12
    rep3 = run_open_loop(base, stream, 300.0, seed=2)
    assert rep3.coalesce_factor is None


def test_sharded_service_eps_threading(dataset, prepared):
    """ShardedJoinService must honour per-request eps (the loadgen's
    mixed-eps stream goes through query(eps=...))."""
    pts, eps = dataset
    rng = np.random.default_rng(12)
    q = rng.uniform(0, 100, size=(40, 3))
    svc = ShardedJoinService(pts, eps, 3)
    svc.warmup(40)
    got = svc.query(q, eps=0.6 * eps)
    assert np.array_equal(got.counts, prepared.counts(q, eps=0.6 * eps))
