"""repro.analysis: contract prover, retrace/dtype linter, sanitizer.

Covers ISSUE 7's tentpole and satellites 3/4: the prover passes on
healthy geometries and catches injected planner faults, the linter flags
the PR-2 per-call ``@jax.jit`` pattern while the fixed ``range_query``
and ``BatchingJoinService`` paths lint clean, the static no-retrace
model proves the warm ladder covers canned request mixes, and sanitized
kernel mode catches a corrupted window descriptor (OOB gather) and an
undersized window cap in interpreter mode.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts, lint, sanitize
from repro.analysis import findings as F
from repro.core.grid import (BucketPlan, build_grid_host, occupancy_plan,
                             sentinel_margin)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _uniform(n=300, d=2, eps=0.08, seed=0):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, 1.0, (n, d)), eps


def _clustered(n=300, d=3, eps=0.1, seed=3):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0.0, 1.0, (4, d))
    return centers[rng.integers(0, 4, n)] + rng.normal(0.0, 0.03, (n, d)), eps


# ---------------------------------------------------------------------------
# contract prover
# ---------------------------------------------------------------------------

class TestContracts:
    @pytest.mark.parametrize("mk", [_uniform, _clustered])
    def test_healthy_index_proves_clean(self, mk):
        pts, eps = mk()
        found = contracts.prove_index_contracts(build_grid_host(pts, eps))
        errors = [f for f in found if f.severity == "error"]
        assert errors == [], [f.render() for f in errors]

    def test_recomputed_caps_match_planner(self):
        """The coordinate-space re-derivation and the linear-key planner
        agree exactly on a healthy index (the planner may only overcount,
        and on interior geometries it should not even do that)."""
        from repro.core.grid import cell_window_caps

        pts, eps = _clustered()
        index = build_grid_host(pts, eps)
        for merged in (False, True):
            exact = contracts.recompute_cell_caps(index, merged)
            planner = np.asarray(cell_window_caps(index, merged=merged))
            assert np.all(planner >= exact)

    def test_tampered_plan_caught(self):
        """Mutation (a): a plan granting less than a cell's worst-case
        window must produce a cap-coverage finding."""
        pts, eps = _clustered()
        index = build_grid_host(pts, eps)
        assert contracts.recompute_cell_caps(index, merged=True).max() > 8
        plan = occupancy_plan(index, merged=True)
        tampered = BucketPlan(caps=(8,), sel=(None,),
                              cap_global=plan.cap_global,
                              hist={8: index.num_points})
        found = contracts.check_window_caps(index, merged=True,
                                            plan=tampered, tag="t")
        assert any(f.rule == "cap-coverage" for f in found)

    def test_tampered_partition_caught(self):
        """A plan that drops rows is not a partition."""
        pts, eps = _clustered()
        index = build_grid_host(pts, eps)
        plan = occupancy_plan(index, merged=True)
        half = np.arange(index.num_points // 2, dtype=np.int32)
        tampered = BucketPlan(caps=(plan.cap_global,), sel=(half,),
                              cap_global=plan.cap_global,
                              hist={plan.cap_global: half.size})
        found = contracts.check_window_caps(index, merged=True,
                                            plan=tampered, tag="t")
        assert any(f.rule == "plan-partition" for f in found)

    def test_sentinel_margin(self):
        assert sentinel_margin([10, 10]) == 2**31 - 1 - 99
        assert sentinel_margin([1 << 20, 1 << 11]) > 0       # int32 boundary
        assert sentinel_margin([1 << 32, 1 << 20]) > 0       # int64 route
        # forced-narrow dtype on a too-big volume: negative margin = alias
        assert sentinel_margin([1 << 20, 1 << 12], np.int32) <= 0

    def test_external_cap_exact(self):
        from repro.core.grid import external_range_cap

        pts, eps = _clustered()
        index = build_grid_host(pts, eps)
        assert int(external_range_cap(index)) >= \
            contracts.recompute_external_cap(index)

    def test_vmem_contract_flags_oversized_tile(self):
        from repro.launch.roofline import VMEM_BYTES, fused_join_vmem_bytes

        pts, eps = _uniform()
        index = build_grid_host(pts, eps)
        plan = occupancy_plan(index, merged=True)
        # a tile big enough to blow the budget at the plan's largest cap
        cap = int(max(plan.caps))
        huge_tq = (VMEM_BYTES // cap) + 1024
        assert fused_join_vmem_bytes(c=cap, tq=huge_tq) > VMEM_BYTES
        found = contracts.check_vmem(
            index, merged=True, plan=plan,
            tiles={int(c): huge_tq for c in plan.caps}, tag="t")
        assert any(f.rule == "vmem-budget" for f in found)

    def test_halo_contracts_healthy(self):
        pts, eps = _uniform(n=200)
        found = contracts.prove_halo_contracts(pts, eps, n_slabs=4)
        assert [f for f in found if f.severity == "error"] == []

    def test_halo_capacity_finding_names_worst_parcel(self):
        pts, eps = _uniform(n=200)
        found = contracts.prove_halo_contracts(pts, eps, n_slabs=4,
                                               halo_capacity=1)
        caps = [f for f in found if f.site.endswith(":capacity")]
        assert caps and "slab" in caps[0].message
        assert "halo_capacity >=" in caps[0].message


class TestHaloPlan:
    def test_plan_max_is_exact_capacity(self):
        from repro.core.distributed import (exact_halo_capacity,
                                            halo_capacity_plan, halo_reach,
                                            partition_points_host,
                                            slab_extents)

        pts, eps = _uniform(n=257, d=2)
        coords, gids, _ = partition_points_host(pts, 4)
        mins, maxs = slab_extents(coords, gids)
        k = halo_reach(mins, maxs, eps)
        plan = halo_capacity_plan(coords, gids, mins, maxs, eps, k)
        assert plan
        assert max(p.need for p in plan) == \
            exact_halo_capacity(coords, gids, mins, maxs, eps, k)

    def test_overflow_error_is_actionable(self):
        """Satellite 1: the under-capacity raise names the offending
        slab/parcel and the minimal sufficient capacity."""
        from repro.core.distributed import (_halo_overflow_error,
                                            halo_capacity_plan, halo_reach,
                                            partition_points_host,
                                            slab_extents)

        pts, eps = _uniform(n=200, d=2)
        coords, gids, _ = partition_points_host(pts, 4)
        mins, maxs = slab_extents(coords, gids)
        k = halo_reach(mins, maxs, eps)
        plan = halo_capacity_plan(coords, gids, mins, maxs, eps, k)
        err = _halo_overflow_error(1, plan)
        worst = max(plan, key=lambda p: p.need)
        msg = str(err)
        assert f"slab {worst.slab} -> slab {worst.dest}" in msg
        assert f"halo_capacity >= {worst.need}" in msg


# ---------------------------------------------------------------------------
# linter
# ---------------------------------------------------------------------------

_PR2_FIXTURE = '''
import jax
import numpy as np

def range_query(index, q, eps):
    """The PR-2 bug shape: a fresh jitted closure per call."""
    @jax.jit
    def _probe(q):
        return q * 2
    return _probe(q)
'''

_SYNC_FIXTURE = '''
import jax
import numpy as np

@jax.jit
def bad(x):
    v = x.sum().item()
    w = np.asarray(x)
    return v + float(x[0])
'''

_I64_FIXTURE = '''
import numpy as np

def build_table(keys):
    pad = np.iinfo(np.int64).max
    return np.where(keys == 9223372036854775807, -1, keys), pad
'''


class TestLinter:
    def test_pr2_percall_jit_flagged(self):
        found = lint.lint_source(_PR2_FIXTURE, "fixture.py")
        jit = [f for f in found if f.rule == lint.RULE_JIT]
        assert len(jit) == 1
        assert jit[0].site == "fixture.py::range_query"
        assert "_probe" in jit[0].message

    def test_module_level_jit_clean(self):
        src = ("import jax\n\n@jax.jit\ndef f(x):\n    return x\n\n"
               "g = jax.jit(lambda x: x)\n")
        found = lint.lint_source(src, "m.py")
        assert [f for f in found if f.rule == lint.RULE_JIT] == []

    def test_host_sync_in_jit_flagged(self):
        found = lint.lint_source(_SYNC_FIXTURE, "fixture.py")
        sync = [f for f in found if f.rule == lint.RULE_SYNC]
        msgs = " ".join(f.message for f in sync)
        assert ".item()" in msgs and "np.asarray" in msgs
        assert any(f.severity == "warning" for f in sync)  # float()

    def test_host_sync_outside_jit_clean(self):
        src = "def f(x):\n    return x.sum().item()\n"
        found = lint.lint_source(src, "m.py")
        assert [f for f in found if f.rule == lint.RULE_SYNC] == []

    def test_int64_literals_flagged(self):
        found = lint.lint_source(_I64_FIXTURE, "fixture.py")
        i64 = [f for f in found if f.rule == lint.RULE_I64]
        assert len(i64) == 2           # iinfo(int64) + the bare literal

    def test_fixed_paths_lint_clean(self):
        """Satellite 3: range_query / per_point_neighbor_counts
        (core/selfjoin.py) and BatchingJoinService (launch/serve.py) carry
        no retrace or dtype findings after the fixes."""
        sj = lint.lint_paths([os.path.join(SRC, "repro/core/selfjoin.py")],
                             root=os.path.dirname(SRC))
        bad = [f for f in sj
               if "range_query" in f.site
               or "per_point_neighbor_counts" in f.site
               or "neighbor_counts" in f.site]
        assert bad == [], [f.render() for f in bad]
        assert [f for f in sj if f.rule == lint.RULE_I64] == [], \
            [f.render() for f in sj if f.rule == lint.RULE_I64]
        sv = lint.lint_paths([os.path.join(SRC, "repro/launch/serve.py")],
                             root=os.path.dirname(SRC))
        bad = [f for f in sv if "BatchingJoinService" in f.site
               or "JoinService" in f.site]
        assert bad == [], [f.render() for f in bad]

    def test_query_join_lints_clean(self):
        qj = lint.lint_paths([os.path.join(SRC, "repro/core/query_join.py")],
                             root=os.path.dirname(SRC))
        assert qj == [], [f.render() for f in qj]


# ---------------------------------------------------------------------------
# static no-retrace model
# ---------------------------------------------------------------------------

class TestNoRetrace:
    def _prepared(self, mk=_clustered):
        from repro.core.query_join import prepare

        pts, eps = mk()
        return prepare(build_grid_host(pts, eps))

    def test_full_ladder_covers_mix(self):
        pj = self._prepared()
        found = lint.check_no_retrace(
            pj, max_batch=256, request_sizes=(1, 7, 32, 128, 256))
        assert found == [], [f.render() for f in found]

    def test_oversized_request_caught(self):
        pj = self._prepared()
        found = lint.check_no_retrace(
            pj, max_batch=128, request_sizes=(512,))
        assert found and all(f.rule == "static-retrace" for f in found)

    def test_single_size_warm_misses_other_sizes(self):
        """A fixed-size JoinService.warmup covers only its own request
        bucket on the non-bucketed path: the model reports the miss."""
        from repro.core.query_join import prepare

        pts, eps = _uniform(n=40, eps=0.03)      # sparse: one class
        pj = prepare(build_grid_host(pts, eps))
        assert not pj.bucketed
        found = lint.check_no_retrace(
            pj, max_batch=256, warm_sizes=(256,), request_sizes=(8,))
        assert found

    def test_lowering_count_bounded(self):
        pj = self._prepared()
        n = lint.count_distinct_lowerings(pj, sizes=(1, 32, 256))
        assert 0 < n <= 2 * len(pj.classes) * (
            1 + max(0, (256 // min(pj.tiles.values())).bit_length()))


# ---------------------------------------------------------------------------
# findings / baseline protocol
# ---------------------------------------------------------------------------

class TestBaseline:
    def test_round_trip(self, tmp_path):
        f1 = F.Finding("lint", "per-call-jit", "a.py::f", "m1", line=3)
        f2 = F.Finding("contracts", "cap-coverage", "index:t", "m2")
        path = str(tmp_path / "base.json")
        F.save_baseline([f1, f2], path)
        base = F.load_baseline(path)
        assert base == {f1.key, f2.key}
        f3 = F.Finding("lint", "per-call-jit", "b.py::g", "new")
        assert F.new_findings([f1, f2, f3], base) == [f3]

    def test_key_excludes_line_and_message(self):
        a = F.Finding("lint", "r", "s.py::f", "msg one", line=1)
        b = F.Finding("lint", "r", "s.py::f", "msg two", line=99)
        assert a.key == b.key

    def test_committed_baseline_accepts_tree(self):
        """The committed baseline accepts the current tree's lint findings
        (the full gate incl. prover runs in scripts/ci.sh)."""
        base = F.load_baseline(
            os.path.join(SRC, "..", "scripts", "analysis_baseline.json"))
        fresh = F.new_findings(lint.lint_tree(SRC), base)
        assert fresh == [], [f.render() for f in fresh]


# ---------------------------------------------------------------------------
# sanitized kernel mode (satellite 4: interpreter-mode Pallas kernel)
# ---------------------------------------------------------------------------

class TestSanitizer:
    def setup_method(self):
        sanitize.set_enabled(True)
        sanitize.clear()

    def teardown_method(self):
        sanitize.set_enabled(None)
        sanitize.clear()

    def _launch(self, ws=None, wc=None):
        from repro.kernels import ops
        from repro.kernels.fused_join import pad_points

        rng = np.random.default_rng(0)
        pts = np.sort(rng.uniform(0, 1, (64, 2)), axis=0)
        c, tq, qp, n_off = 8, 16, 16, 9
        points_pad = pad_points(jnp.asarray(pts), c)
        ws = jnp.zeros((n_off, qp), jnp.int32) if ws is None else ws
        wc = jnp.zeros((n_off, qp), jnp.int32) if wc is None else wc
        # method='kernel' exercises the Pallas kernel in interpreter mode
        return ops.fused_join_hits(
            points_pad, jnp.zeros((qp, 8)), ws, wc,
            jnp.zeros((n_off,), jnp.int32), jnp.zeros((qp,), jnp.int32),
            0.1, c=c, n_real=2, unicomp=False, external=True, tq=tq,
            method="kernel")

    def test_clean_launch_passes(self):
        self._launch()
        assert sanitize.pending() == 1
        sanitize.raise_pending()              # no raise
        assert sanitize.pending() == 0

    def test_corrupted_window_descriptor_oob_gather(self):
        ws = jnp.zeros((9, 16), jnp.int32).at[0, 0].set(1000)
        wc = jnp.zeros((9, 16), jnp.int32).at[0, 0].set(3)
        self._launch(ws=ws, wc=wc)
        with pytest.raises(sanitize.SanitizerError, match="oob-gather"):
            sanitize.raise_pending()

    def test_undersized_window_cap(self):
        wc = jnp.zeros((9, 16), jnp.int32).at[0, 0].set(13)   # > c = 8
        self._launch(wc=wc)
        with pytest.raises(sanitize.SanitizerError, match="cap-overflow"):
            sanitize.raise_pending()

    def test_driver_drains_at_result(self):
        """The count->fill drivers raise pending codes at their sync
        points: a poisoned pending queue surfaces from PendingJoin.result."""
        from repro.core.query_join import prepare

        pts, eps = _uniform(n=100)
        pj = prepare(build_grid_host(pts, eps))
        pend = pj.join_async(pts[:4])
        sanitize.record("poisoned", jnp.asarray(7, jnp.int32))
        with pytest.raises(sanitize.SanitizerError):
            pend.result()

    def test_self_join_clean_under_sanitize(self):
        from repro.core import selfjoin

        pts, eps = _uniform(n=150)
        ref = selfjoin.self_join(pts, eps, distance_impl="jnp")
        got = selfjoin.self_join(pts, eps, distance_impl="fused")
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
        assert sanitize.pending() == 0        # drained by the driver

    def test_decode(self):
        assert sanitize.decode(3) == ["oob-gather", "cap-overflow"]
        assert sanitize.decode(0) == []

    def test_env_gate(self):
        sanitize.set_enabled(None)
        old = os.environ.pop("REPRO_SANITIZE", None)
        try:
            assert not sanitize.enabled()
            os.environ["REPRO_SANITIZE"] = "1"
            assert sanitize.enabled()
            os.environ["REPRO_SANITIZE"] = "0"
            assert not sanitize.enabled()
        finally:
            if old is None:
                os.environ.pop("REPRO_SANITIZE", None)
            else:
                os.environ["REPRO_SANITIZE"] = old
