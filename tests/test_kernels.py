"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import cell_join, distance_tile, ref


DIMS = [2, 3, 4, 5, 6]
DTYPES = [np.float32, np.float64]


@pytest.mark.parametrize("n", DIMS)
@pytest.mark.parametrize("dt", DTYPES)
@pytest.mark.parametrize("nq,npts", [(1, 1), (7, 500), (256, 256), (300, 1000)])
def test_distance_tile_hits(n, dt, nq, npts):
    rng = np.random.default_rng(n * 100 + npts)
    q = rng.uniform(0, 10, (nq, n)).astype(dt)
    p = rng.uniform(0, 10, (npts, n)).astype(dt)
    eps = 1.3
    got = distance_tile.distance_tile_hits(jnp.asarray(q), jnp.asarray(p),
                                           eps, interpret=True)
    want = ref.distance_tile_hits_ref(jnp.asarray(q), jnp.asarray(p), eps)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n", [2, 4, 6])
@pytest.mark.parametrize("dt", DTYPES)
@pytest.mark.parametrize("npts", [3, 129, 700])
def test_distance_tile_counts(n, dt, npts):
    rng = np.random.default_rng(n + npts)
    p = rng.uniform(0, 5, (npts, n)).astype(dt)
    eps = 0.9
    got = distance_tile.distance_tile_counts(jnp.asarray(p), eps,
                                             interpret=True)
    want = ref.distance_tile_counts_ref(jnp.asarray(p), eps)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_distance_tile_tile_size_invariance():
    rng = np.random.default_rng(0)
    p = rng.uniform(0, 5, (400, 3))
    for tq, tc in [(64, 64), (128, 256), (512, 512)]:
        got = distance_tile.distance_tile_counts(
            jnp.asarray(p), 0.8, tq=tq, tc=tc, interpret=True)
        want = ref.distance_tile_counts_ref(jnp.asarray(p), 0.8)
        assert np.array_equal(np.asarray(got), np.asarray(want)), (tq, tc)


def test_distance_tile_bf16_close():
    """bf16 kernel vs f32 oracle: hits may differ only at the threshold."""
    rng = np.random.default_rng(1)
    q = rng.uniform(0, 4, (64, 3)).astype(np.float32)
    p = rng.uniform(0, 4, (200, 3)).astype(np.float32)
    eps = 1.0
    got = np.asarray(distance_tile.distance_tile_hits(
        jnp.asarray(q, jnp.bfloat16), jnp.asarray(p, jnp.bfloat16), eps,
        interpret=True))
    d2 = ((q[:, None, :] - p[None, :, :]) ** 2).sum(-1)
    # the MXU form qn+pn-2ab in bf16 has absolute error ~ (qn+pn) * 2^-8:
    # coords up to 4 in 3-D -> norms up to 48 -> band ~ 0.4. Exactness is
    # required outside that band; inside it bf16 legitimately flips.
    qn = (q ** 2).sum(-1)[:, None]
    pn = (p ** 2).sum(-1)[None, :]
    band = (qn + pn) * 2.0 ** -8 + 0.02
    sure = np.abs(d2 - eps * eps) > band
    want = d2 <= eps * eps
    assert np.array_equal(got[sure], want[sure])
    assert (got == want).mean() > 0.98


@pytest.mark.parametrize("n", DIMS)
@pytest.mark.parametrize("dt", DTYPES)
@pytest.mark.parametrize("b,c", [(1, 8), (57, 24), (512, 8), (600, 40)])
def test_cell_join_hits(n, dt, b, c):
    rng = np.random.default_rng(b * 7 + c)
    q = rng.uniform(0, 10, (b, n)).astype(dt)
    cand = rng.uniform(0, 10, (b, c, n)).astype(dt)
    valid = rng.random((b, c)) < 0.7
    eps = 1.1
    got = cell_join.cell_join_hits(jnp.asarray(q), jnp.asarray(cand),
                                   jnp.asarray(valid), eps, interpret=True)
    want = ref.cell_join_hits_ref(jnp.asarray(q), jnp.asarray(cand),
                                  jnp.asarray(valid), eps)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_cell_join_all_invalid():
    q = jnp.zeros((16, 3))
    cand = jnp.zeros((16, 8, 3))
    valid = jnp.zeros((16, 8), bool)
    got = cell_join.cell_join_hits(q, cand, valid, 1.0, interpret=True)
    assert not np.asarray(got).any()


def test_mxu_formulation_numerics():
    """||a-b||^2 = ||a||^2+||b||^2-2ab can go (slightly) negative for
    coincident points; the threshold compare must still classify them in."""
    pts = np.array([[1e3, 1e3], [1e3, 1e3], [1e3 + 0.5, 1e3]])
    got = np.asarray(distance_tile.distance_tile_hits(
        jnp.asarray(pts, jnp.float32), jnp.asarray(pts, jnp.float32), 0.6,
        interpret=True))
    assert got.all()  # all pairwise distances <= 0.6
