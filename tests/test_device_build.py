"""Device-resident index build & planning (core/grid.build_grid, S10).

The jitted ``build_grid_with_geometry`` is the PRIMARY build path now;
this file pins its one non-negotiable contract: the device build is
BIT-IDENTICAL to ``build_grid_host`` -- every field, every dtype --
across dimensionalities, key dtypes, degenerate point sets, and with
x64 disabled.  Planning (``cell_window_caps``) moved on-device too, so
the retired host sweep (``cell_window_caps_host``) stays behind as the
independent oracle it is checked against here.  The serve-side half:
``JoinService.reindex`` swaps a full snapshot without re-tracing any
request-path executable, and the per-index plan cache is LRU-bounded.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import repro.core.grid as grid_lib
from repro.core.grid import (build_grid, build_grid_host, cell_window_caps,
                             cell_window_caps_cached, cell_window_caps_host,
                             external_range_cap, index_cache_stats,
                             index_cached)
from repro.core.query_join import prepare
from repro.core.selfjoin import self_join

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_FIELDS = ("grid_min", "eps", "dims", "order", "points_sorted", "cell_keys",
           "cell_start", "cell_count", "point_cell_rank", "num_cells",
           "max_per_cell")


def assert_bit_identical(host_idx, dev_idx):
    for f in _FIELDS:
        a = np.asarray(getattr(host_idx, f))
        b = np.asarray(getattr(dev_idx, f))
        assert a.dtype == b.dtype, (f, a.dtype, b.dtype)
        assert np.array_equal(a, b), f


def clustered(rng, n, d, spread=0.05):
    centers = rng.uniform(0.0, 1.0, (max(2, n // 200), d))
    which = rng.integers(0, centers.shape[0], n)
    return centers[which] + rng.normal(0.0, spread, (n, d))


@pytest.mark.parametrize("d,eps", [(2, 0.04), (3, 0.1), (4, 0.25), (6, 0.5)])
def test_device_build_bit_identical_uniform(d, eps):
    rng = np.random.default_rng(d)
    pts = rng.uniform(0.0, 1.0, (1200, d))
    h = build_grid_host(pts, eps)
    g = build_grid(pts, eps)
    assert_bit_identical(h, g)
    # uniform sparse points leave empty cells: the scatter paths that
    # differ most between numpy and the jitted segment build
    vol = int(np.prod(np.asarray(h.dims, dtype=object)))
    assert int(h.num_cells) < vol


@pytest.mark.parametrize("d", [2, 3, 4])
def test_device_build_bit_identical_clustered(d):
    rng = np.random.default_rng(10 + d)
    pts = clustered(rng, 900, d)
    h = build_grid_host(pts, 0.08)
    assert_bit_identical(h, build_grid(pts, 0.08))


def test_device_build_int64_keys():
    """A 6-D grid past 2^31 cells routes to int64 keys on BOTH builders
    and stays bit-identical (the legacy key path, now jit-shared)."""
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 100, size=(400, 6))
    pts[0] = 0.0
    pts[1] = 100.0                              # pin the extent exactly
    h = build_grid_host(pts, 2.9)               # ~3.0e9 cells
    assert h.key_dtype == np.int64
    g = build_grid(pts, 2.9)
    assert_bit_identical(h, g)


def test_device_build_duplicates_and_coincident():
    rng = np.random.default_rng(4)
    base = rng.uniform(0, 10, (50, 3))
    pts = np.concatenate([
        base,
        base[rng.integers(0, 50, 300)],          # exact duplicates
        np.tile(base[:1], (64, 1)),              # 64 coincident points
    ])
    h = build_grid_host(pts, 0.7)
    assert int(h.max_per_cell) >= 64
    assert_bit_identical(h, build_grid(pts, 0.7))


def test_device_build_degenerate_sizes():
    for pts in (np.zeros((1, 2)), np.asarray([[0.0, 0.0], [5.0, 5.0]])):
        assert_bit_identical(build_grid_host(pts, 1.0), build_grid(pts, 1.0))


def test_device_build_host_flag():
    rng = np.random.default_rng(5)
    pts = rng.uniform(0, 1, (200, 2))
    idx = build_grid(pts, 0.1, device=False)
    assert_bit_identical(build_grid_host(pts, 0.1), idx)


@pytest.mark.parametrize("merged", [False, True])
def test_device_planning_matches_host_sweep(merged):
    """Batched-searchsorted planner vs the retired per-offset host sweep
    (the independent oracle) -- bit-equal caps on both stencils."""
    rng = np.random.default_rng(6)
    for pts, eps in ((rng.uniform(0, 1, (800, 3)), 0.12),
                     (clustered(rng, 700, 4), 0.1),
                     (rng.uniform(0, 1, (300, 2)), 0.07)):
        idx = build_grid(pts, eps)
        host = cell_window_caps_host(idx, merged=merged)
        dev = cell_window_caps(idx, merged=merged)
        assert host.dtype == dev.dtype
        assert np.array_equal(host, dev)


def test_external_range_cap_consistent():
    rng = np.random.default_rng(7)
    pts = clustered(rng, 600, 3)
    h = build_grid_host(pts, 0.09)
    g = build_grid(pts, 0.09)
    assert external_range_cap(h) == external_range_cap(g)


def test_serve_path_pair_parity():
    """Device-built and host-built indexes drive the SAME serve
    executables to the SAME pairs (and external counts)."""
    rng = np.random.default_rng(8)
    pts = clustered(rng, 1000, 3)
    eps = 0.1
    h = build_grid_host(pts, eps)
    g = build_grid(pts, eps)
    ph = np.asarray(self_join(pts, eps, index=h, sort_result=True))
    pg = np.asarray(self_join(pts, eps, index=g, sort_result=True))
    assert np.array_equal(ph, pg)
    q = rng.uniform(0, 1, (64, 3))
    assert np.array_equal(np.asarray(prepare(h).counts(q)),
                          np.asarray(prepare(g).counts(q)))


def test_reindex_swaps_snapshot_without_retrace():
    from repro.launch.serve import JoinService

    rng = np.random.default_rng(9)
    pts = clustered(rng, 1500, 3)
    svc = JoinService(pts, 0.1)
    svc.warmup(128)
    old_index = svc.index
    svc.query(pts[:128])
    # permutation of the same point set: same bucket classes, so every
    # warmed executable must carry over to the new snapshot
    svc.reindex(rng.permutation(pts))
    assert svc.swaps == 1
    assert svc.index is not old_index
    assert {"build_s", "plan_s", "warm_s", "swap_s"} <= set(
        svc.reindex_timings)
    res = svc.query(pts[:128])
    assert res.total > 0
    svc.assert_no_retrace()
    # the new snapshot answers identically to a cold service on the
    # permuted points (order-insensitive: totals match)
    ref = JoinService(rng.permutation(pts), 0.1)
    assert int(res.total) == int(ref.query(pts[:128]).total)


def test_reindex_background_error_surfaces():
    from repro.launch.serve import JoinService

    rng = np.random.default_rng(11)
    pts = rng.uniform(0, 1, (300, 2))
    svc = JoinService(pts, 0.1)
    svc.reindex(np.zeros(7), wait=False)         # 1-D: build must fail
    with pytest.raises(RuntimeError, match="background reindex failed"):
        svc.join_reindex()
    # the serving snapshot survived the failed swap
    assert svc.swaps == 0
    assert svc.query(pts[:32]).total >= 0


def test_index_cache_lru_bound_and_stats(monkeypatch):
    monkeypatch.setattr(grid_lib, "_INDEX_CACHE_MAX", 3)
    grid_lib._INDEX_CACHE.clear()
    before = dict(index_cache_stats())
    rng = np.random.default_rng(12)
    indexes = [build_grid_host(rng.uniform(0, 1, (60, 2)), 0.2)
               for _ in range(5)]
    calls = []
    for i, idx in enumerate(indexes):
        index_cached(idx, "t", lambda i=i: calls.append(i) or i)
    assert len(calls) == 5                        # 5 misses
    assert index_cache_stats()["size"] <= 3       # LRU bound holds
    stats = index_cache_stats()
    assert stats["misses"] - before["misses"] == 5
    assert stats["evictions"] - before["evictions"] == 2
    # most-recent entries hit without rebuilding
    assert index_cached(indexes[-1], "t", lambda: "rebuilt") == 4
    assert index_cache_stats()["hits"] - before["hits"] == 1
    # dropping the last reference finalizes its entry (the loop variable
    # above still aliases it, so rebind before popping)
    import gc

    idx = None
    indexes.pop()
    gc.collect()
    assert index_cache_stats()["finalized"] > before["finalized"]


def test_index_cache_eviction_is_recomputable():
    """Evicted values are rebuilt on demand -- eviction can never change
    answers, only cost (values are pure functions of the index)."""
    grid_lib._INDEX_CACHE.clear()
    rng = np.random.default_rng(13)
    idx = build_grid_host(rng.uniform(0, 1, (200, 3)), 0.15)
    first = cell_window_caps_cached(idx, merged=True)
    key = next(k for k in grid_lib._INDEX_CACHE if k[0] == id(idx))
    grid_lib._INDEX_CACHE.pop(key)                # force an eviction
    again = cell_window_caps_cached(idx, merged=True)
    assert np.array_equal(first, again)


@pytest.mark.slow
def test_no_x64_subprocess_device_build_parity():
    """With REPRO_NO_X64: the device build stays bit-identical to the
    host build on the int32 key route, and a build that needs int64
    keys fails BEFORE tracing with the same actionable error."""
    script = textwrap.dedent("""
        import numpy as np
        from repro.core.grid import build_grid, build_grid_host
        rng = np.random.default_rng(0)
        pts = rng.uniform(0, 30, size=(600, 3)).astype(np.float32)
        h = build_grid_host(pts, 2.0)
        g = build_grid(pts, 2.0)
        assert h.key_dtype == np.int32
        for f in ("grid_min", "eps", "dims", "order", "points_sorted",
                  "cell_keys", "cell_start", "cell_count",
                  "point_cell_rank", "num_cells", "max_per_cell"):
            a, b = np.asarray(getattr(h, f)), np.asarray(getattr(g, f))
            assert a.dtype == b.dtype and np.array_equal(a, b), f
        big = rng.uniform(0, 100, size=(64, 6)).astype(np.float32)
        big[0] = 0.0
        big[1] = 100.0
        try:
            build_grid(big, 2.9)                # ~3.0e9 cells: needs int64
        except RuntimeError as e:
            assert "x64" in str(e) or "int64" in str(e), e
            print("OK")
        else:
            raise SystemExit("int64-needing device build did not raise")
    """)
    env = dict(os.environ, REPRO_NO_X64="1",
               PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


def test_device_sentinel_contract_c9():
    """C9: an int32-keyed index whose volume leaves < 2 keys of headroom
    below the pad sentinel is rejected (device probes use key+2)."""
    import jax.numpy as jnp

    from repro.analysis.contracts import check_device_sentinel

    rng = np.random.default_rng(14)
    idx = build_grid_host(rng.uniform(0, 1, (100, 2)), 0.2)
    assert not check_device_sentinel(idx)
    forged = dataclasses.replace(
        idx, dims=jnp.asarray([2, 2**30 - 1], jnp.int64),
        cell_keys=np.asarray(idx.cell_keys).astype(np.int32))
    found = check_device_sentinel(forged, tag="forged")
    assert any(f.rule == "device-sentinel" for f in found)
