"""LM-substrate smoke tests (the generic ``smoke-lm`` arch) + family unit
tests.

The seed's 10-arch registry (and its ~40 per-arch parametrized tests) was
pruned with the unrelated LLM configs (PR 3); one train-step and one
decode-consistency smoke over ``smoke-lm`` keeps the LM stack (models/,
train/) covered, and the family-level unit tests (MoE dispatch, gated
linear scan) are registry-independent and stay.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ALIASES, get_config
from repro.models.lm import LMModel
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.steps import make_train_step

CANON = {v: k for k, v in ALIASES.items()}


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    if cfg.input_kind == "embeddings":
        return {"embeds": jnp.asarray(
                    rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16),
                "labels": jnp.asarray(labels)}
    return {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
            "labels": jnp.asarray(labels)}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(CANON[arch], reduced=True)
    model = LMModel(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1)
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(model, ocfg))
    losses = []
    for i in range(3):
        params, opt, metrics = step(params, opt, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), (arch, i)
        losses.append(loss)
    # same batch re-fed: optimization must reduce the loss
    assert losses[-1] < losses[0], (arch, losses)
    # outputs shaped and finite
    leaves = jax.tree.leaves(params)
    assert all(np.all(np.isfinite(np.asarray(l, np.float32)))
               for l in leaves), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_matches_forward(arch):
    """Prefill then decode-one vs teacher-forced forward: same logits."""
    cfg = get_config(CANON[arch], reduced=True)
    model = LMModel(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)
    if cfg.input_kind == "embeddings":
        # vlm: prefill from embeddings uses the embed table for parity
        emb = np.asarray(params["embed"])[toks]
        batch = {"embeds": jnp.asarray(emb[:, :S], jnp.bfloat16),
                 "labels": jnp.zeros((B, S), jnp.int32)}
        full_batch = {"embeds": jnp.asarray(emb[:, 1:], jnp.bfloat16),
                      "labels": jnp.zeros((B, S), jnp.int32)}
    else:
        batch = {"tokens": jnp.asarray(toks[:, :S]),
                 "labels": jnp.zeros((B, S), jnp.int32)}
    caches = model.init_caches(B, S + 4)
    logits_p, caches = jax.jit(model.prefill)(params, batch, caches)
    logits_d, _ = jax.jit(model.decode_step)(
        params, jnp.asarray(toks[:, S]), caches)
    # reference: full forward over S+1 tokens; decode logits == position S
    if cfg.input_kind == "embeddings":
        emb_all = np.asarray(params["embed"])[toks]
        ref_in = {"embeds": jnp.asarray(emb_all, jnp.bfloat16),
                  "labels": jnp.zeros((B, S + 1), jnp.int32)}
    else:
        ref_in = {"tokens": jnp.asarray(toks),
                  "labels": jnp.zeros((B, S + 1), jnp.int32)}

    def full_logits(p, b):
        from repro.models import transformer as tf
        from repro.models.layers import rms_norm
        x = model._embed_in(p, b, model._default_layout(b))
        x, _, _ = tf.stack_forward(
            p["blocks"], p.get("shared_attn"), x, cfg, model.ctx,
            mode="train", head_tp=None, seq_axes=None, dp_spec=None)
        x = rms_norm(x, p["final_norm"])
        return x[:, -1, :] @ p["head"].T.astype(x.dtype)

    ref = jax.jit(full_logits)(params, ref_in)
    a = np.asarray(logits_d, np.float32)
    b = np.asarray(ref, np.float32)
    # prefill logits (position S-1) must also match the S-token forward
    np.testing.assert_allclose(a, b, rtol=0.15, atol=0.15)
    # ranking agreement is the functional check (bf16 noise tolerated)
    assert (np.argmax(a, -1) == np.argmax(b, -1)).mean() >= 0.95, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """Abstract init (no allocation) matches the analytic parameter count
    within 3% -- guards config drift."""
    cfg = get_config(CANON[arch])
    model = LMModel(cfg)
    shapes, specs = model.abstract_params()
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    analytic = cfg.param_count()
    assert abs(total - analytic) / analytic < 0.03, (arch, total, analytic)


def test_moe_dispatch_exactness():
    """Sort-based dispatch == dense reference when capacity is unbounded."""
    from repro.models.moe import init_moe, moe_ffn
    from repro.models.config import ModelConfig
    from repro.models.layers import ShardCtx

    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=48, vocab=64,
                      n_experts=4, top_k=2, capacity_factor=100.0)
    ctx = ShardCtx(None, None, 1, 1)
    p, _ = init_moe(jax.random.PRNGKey(0), cfg, ctx)
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
    out, aux = moe_ffn(p, x, cfg, ep_axis=None)
    assert float(aux["dropped_frac"]) == 0.0

    # dense reference
    T = 16
    tokens = x.reshape(T, 32)
    logits = tokens @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    topw, tope = jax.lax.top_k(probs, 2)
    topw = topw / topw.sum(-1, keepdims=True)
    ref = np.zeros((T, 32), np.float32)
    for t in range(T):
        for j in range(2):
            e = int(tope[t, j])
            h = jax.nn.silu(tokens[t] @ p["w_gate"][e]) * (
                tokens[t] @ p["w_up"][e])
            ref[t] += float(topw[t, j]) * np.asarray(h @ p["w_down"][e])
    np.testing.assert_allclose(np.asarray(out).reshape(T, 32), ref,
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_reported():
    from repro.models.moe import init_moe, moe_ffn
    from repro.models.config import ModelConfig
    from repro.models.layers import ShardCtx

    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                      n_experts=8, top_k=2, capacity_factor=0.25)
    ctx = ShardCtx(None, None, 1, 1)
    p, _ = init_moe(jax.random.PRNGKey(0), cfg, ctx)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128, 16), jnp.bfloat16)
    out, aux = moe_ffn(p, x, cfg, ep_axis=None)
    assert float(aux["dropped_frac"]) > 0.0
    assert np.all(np.isfinite(np.asarray(out, np.float32)))


def test_gated_linear_chunked_vs_recurrent():
    """Chunkwise-parallel mLSTM/SSD kernel == step-by-step recurrence."""
    from repro.models.xlstm import chunked_gated_linear, gated_linear_step

    rng = np.random.default_rng(0)
    B, S, H, dk, dv = 2, 24, 3, 8, 5
    q = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, dv)), jnp.float32)
    log_f = jnp.asarray(np.log(rng.uniform(0.7, 1.0, (B, S, H))), jnp.float32)
    ig = jnp.asarray(rng.uniform(0.2, 1.0, (B, S, H)), jnp.float32)

    y_chunk, st_chunk = chunked_gated_linear(q, k, v, log_f, ig, chunk=8)
    st = jnp.zeros((B, H, dk, dv), jnp.float32)
    ys = []
    for t in range(S):
        st, yt = gated_linear_step(st, q[:, t], k[:, t], v[:, t],
                                   log_f[:, t], ig[:, t])
        ys.append(yt)
    y_rec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(st),
                               rtol=2e-4, atol=2e-4)
