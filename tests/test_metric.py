"""Metric trait (DESIGN.md S12): cosine + jaccard join paths end-to-end.

Four layers of coverage:

  * trait primitives -- canonicalization, threshold translation, the
    request-override rules, token bitmap packing;
  * pair-set parity of every metric's fused join against the module's own
    brute-force oracles (seed-swept always; hypothesis-driven where the
    environment has hypothesis, per-test ``importorskip`` like
    tests/test_cell_runs.py);
  * Pallas-kernel bit-parity: the interpreter-mode Mosaic kernel
    (``method='kernel'``) against the reference lowering, per metric;
  * the serving no-retrace gate with the metric warm ladder, and the
    sanitizer's E_UNNORMALIZED cosine check.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metric as metric_lib
from repro.core.selfjoin import self_join, self_join_count


def _embeddings(seed, n=120, d=4):
    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(n, d))
    # scaled copies: cosine-duplicates that L2 cannot see
    emb[n - 8: n - 4] = 3.0 * emb[:4]
    emb[n - 4:] = emb[4:8] + 0.01 * rng.normal(size=(4, d))
    return emb


def _token_sets(seed, n=80, vocab=60):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        k = int(rng.integers(0, 9))
        out.append(tuple(rng.integers(0, vocab, k)))   # dups + empties
    out[0] = ()                                        # guaranteed empty set
    out[1] = out[2]                                    # guaranteed duplicate
    return out


# ---------------------------------------------------------------------------
# trait primitives
# ---------------------------------------------------------------------------

def test_check_metric_rejects_unknown():
    with pytest.raises(ValueError):
        metric_lib.check_metric("manhattan")


def test_cosine_eps_geom_chord_translation():
    # cos 1 -> chord 0; cos -1 -> chord 2 (antipodal on the unit sphere)
    assert metric_lib.cosine_eps_geom(1.0) == pytest.approx(0.0)
    assert metric_lib.cosine_eps_geom(-1.0) == pytest.approx(2.0)
    # monotone: higher required similarity -> smaller chord radius
    grid = np.linspace(-1, 1, 21)
    chords = [metric_lib.cosine_eps_geom(c) for c in grid]
    assert all(a >= b for a, b in zip(chords, chords[1:]))
    # exact identity on a known pair: cos(60 deg) = 0.5 -> chord 1
    assert metric_lib.cosine_eps_geom(0.5) == pytest.approx(1.0)


def test_cosine_canonicalize_rejects_zero_and_nonfinite():
    with pytest.raises(ValueError):
        metric_lib.canonicalize(np.array([[1.0, 0.0], [0.0, 0.0]]), 0.9,
                                metric="cosine")
    with pytest.raises(ValueError):
        metric_lib.canonicalize(np.array([[1.0, np.nan]]), 0.9,
                                metric="cosine")


def test_cosine_canonicalize_unit_rows():
    canon = metric_lib.canonicalize(_embeddings(0), 0.9, metric="cosine")
    norms = np.linalg.norm(np.asarray(canon.geom), axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=metric_lib.NORM_TOL)
    assert canon.eps_geom == pytest.approx(np.sqrt(2 - 2 * 0.9))
    assert canon.refine == pytest.approx(canon.eps_geom)


def test_jaccard_pack_tokens_popcount_intersection():
    sets = [(1, 2, 3), (2, 3, 50), (), (1, 2, 3)]
    canon = metric_lib.canonicalize(sets, 0.5, metric="jaccard")
    feats = np.asarray(canon.feats)
    pop = metric_lib._popcount16_table()
    inter = pop[np.bitwise_and(feats[0].astype(np.int64),
                               feats[1].astype(np.int64))].sum()
    assert inter == 2                                   # {2, 3}
    sizes = np.asarray(canon.geom)[:, 0]
    np.testing.assert_array_equal(sizes, [3, 3, 0, 3])
    np.testing.assert_array_equal(feats[0], feats[3])   # dup packs equal
    assert not feats[2].any()                           # empty set: no bits


def test_request_scalar_override_rules():
    # l2: tighter radius fine, looser raises
    assert metric_lib.request_scalar(
        "l2", 0.5, index_eps=1.0, index_eps_geom=1.0) == pytest.approx(0.5)
    with pytest.raises(ValueError):
        metric_lib.request_scalar("l2", 2.0, index_eps=1.0,
                                  index_eps_geom=1.0)
    # cosine: HIGHER similarity is the tighter request
    g = metric_lib.cosine_eps_geom(0.8)
    got = metric_lib.request_scalar("cosine", 0.95, index_eps=0.8,
                                    index_eps_geom=g)
    assert got == pytest.approx(metric_lib.cosine_eps_geom(0.95))
    with pytest.raises(ValueError):
        metric_lib.request_scalar("cosine", 0.5, index_eps=0.8,
                                  index_eps_geom=g)
    # jaccard: similarity scalar passes through verbatim when tighter
    assert metric_lib.request_scalar(
        "jaccard", 0.7, index_eps=0.5,
        index_eps_geom=4.0) == pytest.approx(0.7)
    with pytest.raises(ValueError):
        metric_lib.request_scalar("jaccard", 0.3, index_eps=0.5,
                                  index_eps_geom=4.0)


# ---------------------------------------------------------------------------
# fused join vs brute oracle, per metric (seed-swept, always runs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("min_cos", [0.5, 0.9, 0.999])
def test_cosine_join_matches_brute(seed, min_cos):
    emb = _embeddings(seed)
    canon = metric_lib.canonicalize(emb, min_cos, metric="cosine")
    expect = metric_lib.brute_force_join_metric(canon)
    got = self_join(emb, min_cos, metric="cosine")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))
    stats = self_join_count(emb, min_cos, metric="cosine")
    assert stats.total_pairs == expect.shape[0]


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("t", [0.3, 0.6, 1.0])
def test_jaccard_join_matches_brute(seed, t):
    sets = _token_sets(seed)
    canon = metric_lib.canonicalize(sets, t, metric="jaccard")
    expect = metric_lib.brute_force_join_metric(canon)
    got = self_join(sets, t, metric="jaccard")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))
    assert self_join_count(sets, t,
                           metric="jaccard").total_pairs == expect.shape[0]


def test_jaccard_binary_matrix_input_equals_token_sets():
    sets = _token_sets(3, vocab=32)
    mat = np.zeros((len(sets), 32), np.float64)
    for i, s in enumerate(sets):
        mat[i, list(s)] = 1.0
    a = self_join(sets, 0.5, metric="jaccard", vocab=32)
    b = self_join(mat, 0.5, metric="jaccard")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_l2_metric_tag_is_bit_identical_to_default():
    rng = np.random.default_rng(9)
    pts = rng.uniform(0, 10, (300, 3))
    a = self_join(pts, 0.7)
    b = self_join(pts, 0.7, metric="l2")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cosine_catches_scaled_duplicates_l2_misses():
    emb = _embeddings(0)
    cos_pairs = set(map(tuple, np.asarray(
        self_join(emb, 0.9999, metric="cosine"))))
    l2_pairs = set(map(tuple, np.asarray(self_join(emb, 1e-6))))
    n = emb.shape[0]
    for k in range(4):                   # the 3x-scaled copies
        assert (k, n - 8 + k) in cos_pairs
        assert (k, n - 8 + k) not in l2_pairs


# hypothesis-driven versions (skip cleanly where hypothesis is absent)

def test_cosine_join_matches_brute_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=20, deadline=None)
    @hyp.given(seed=st.integers(0, 2**31 - 1),
               n=st.integers(2, 80), d=st.integers(2, 5),
               min_cos=st.sampled_from([-0.5, 0.0, 0.8, 0.99]))
    def run(seed, n, d, min_cos):
        rng = np.random.default_rng(seed)
        emb = rng.normal(size=(n, d))
        emb[n // 2] = emb[0] * rng.uniform(0.5, 4.0)   # scaled duplicate
        expect = metric_lib.brute_force_join_metric(
            metric_lib.canonicalize(emb, min_cos, metric="cosine"))
        got = self_join(emb, min_cos, metric="cosine")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))

    run()


def test_jaccard_join_matches_brute_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=20, deadline=None)
    @hyp.given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 60),
               vocab=st.sampled_from([8, 40, 120]),
               t=st.sampled_from([0.25, 0.5, 0.75, 1.0]))
    def run(seed, n, vocab, t):
        rng = np.random.default_rng(seed)
        sets = [tuple(rng.integers(0, vocab, int(rng.integers(0, 7))))
                for _ in range(n)]
        expect = metric_lib.brute_force_join_metric(
            metric_lib.canonicalize(sets, t, metric="jaccard"))
        got = self_join(sets, t, metric="jaccard")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))

    run()


# ---------------------------------------------------------------------------
# Pallas-kernel bit-parity (interpreter-mode Mosaic vs reference lowering)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric,data,eps", [
    ("l2", _embeddings(5) * 2.0, 1.0),
    ("cosine", _embeddings(5), 0.9),
    ("jaccard", _token_sets(5), 0.5),
])
def test_kernel_lowering_bit_parity(metric, data, eps):
    """``method='kernel'`` (the Pallas kernel, interpreter mode off-TPU)
    must produce the SAME counts and pair set as the reference lowering
    for every metric -- the trait predicate is shared code, so parity is
    structural, and this pins it."""
    from repro.core.query_join import epsilon_join

    queries = data[:40] if metric != "jaccard" else data[:40]
    ref = epsilon_join(queries, data, eps, metric=metric)
    ker = epsilon_join(queries, data, eps, metric=metric, method="kernel")
    np.testing.assert_array_equal(ref.counts, ker.counts)
    np.testing.assert_array_equal(ref.pairs, ker.pairs)


# ---------------------------------------------------------------------------
# serving: metric warm ladder keeps the no-retrace watchdog green
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["cosine", "jaccard"])
def test_join_service_no_retrace_across_metric_requests(metric):
    from repro.launch.serve import JoinService

    if metric == "cosine":
        pts = _embeddings(7, n=200)
        eps = 0.95
        make = lambda k, s: np.random.default_rng(s).normal(  # noqa: E731
            size=(k, 4))
    else:
        pts = _token_sets(7, n=200)
        eps = 0.5
        make = lambda k, s: _token_sets(s, n=k)  # noqa: E731
    svc = JoinService(pts, eps, return_pairs=True, metric=metric)
    svc.warmup(32)
    svc.mark_steady()
    for i, size in enumerate((3, 17, 32, 8)):
        res = svc.query(make(size, 20 + i))
        assert res.counts.shape == (size,)
    svc.assert_no_retrace()


def test_join_service_metric_eps_override():
    """Per-request thresholds stay in METRIC units and respect the
    tighter-only rule end-to-end through the service."""
    from repro.launch.serve import JoinService

    emb = _embeddings(11, n=150)
    svc = JoinService(emb, 0.8, return_pairs=True, metric="cosine")
    q = _embeddings(12, n=10)
    tight = svc.query(q, eps=0.99)
    base = svc.query(q)
    assert (tight.counts <= base.counts).all()
    qu = q / np.linalg.norm(q, axis=1, keepdims=True)
    eu = emb / np.linalg.norm(emb, axis=1, keepdims=True)
    chord2 = ((qu[:, None, :] - eu[None, :, :]) ** 2).sum(-1)
    thresh = metric_lib.cosine_eps_geom(0.99)
    expect = metric_lib.l2_sq_hits(chord2, thresh).sum(axis=1)
    np.testing.assert_array_equal(tight.counts, expect)
    with pytest.raises(ValueError):
        svc.query(q, eps=0.5)          # looser than the index threshold


# ---------------------------------------------------------------------------
# sanitizer: E_UNNORMALIZED (cosine) end-to-end
# ---------------------------------------------------------------------------

class TestCosineSanitizer:
    def setup_method(self):
        from repro.analysis import sanitize
        sanitize.set_enabled(True)
        sanitize.clear()

    def teardown_method(self):
        from repro.analysis import sanitize
        sanitize.set_enabled(None)
        sanitize.clear()

    def _launch(self, rows):
        from repro.kernels import ops
        from repro.kernels.fused_join import pad_points

        c, tq, qp, n_off = 8, 16, 16, 9
        points_pad = pad_points(jnp.asarray(rows), c)
        return ops.fused_join_hits(
            points_pad, points_pad[:qp],
            jnp.zeros((n_off, qp), jnp.int32),
            jnp.zeros((n_off, qp), jnp.int32),
            jnp.zeros((n_off,), jnp.int32), jnp.zeros((qp,), jnp.int32),
            0.2, c=c, n_real=2, unicomp=False, external=True, tq=tq,
            method="kernel", metric="cosine")

    def test_unit_rows_pass(self):
        from repro.analysis import sanitize

        rng = np.random.default_rng(0)
        rows = rng.normal(size=(64, 2))
        rows /= np.linalg.norm(rows, axis=1, keepdims=True)
        self._launch(rows)
        sanitize.raise_pending()              # no raise

    def test_unnormalized_rows_flagged(self):
        from repro.analysis import sanitize

        rng = np.random.default_rng(0)
        rows = rng.normal(size=(64, 2))
        rows /= np.linalg.norm(rows, axis=1, keepdims=True)
        rows[5] *= 1.5                        # bypassed canonicalize
        self._launch(rows)
        with pytest.raises(sanitize.SanitizerError,
                           match="unnormalized-cosine"):
            sanitize.raise_pending()
