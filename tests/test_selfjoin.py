"""Deterministic behaviour tests for the core self-join (the paper's system).

The oracle is the O(N^2) distance matrix; every implementation (grid join
with/without UNICOMP, batched driver, brute force, CPU R-tree, EGO) must
produce the same ordered-pair set -- the same validation the paper used
across its implementations ("we validated consistency ... by comparing the
total number of neighbors", SVI-B).

Hypothesis property tests live in test_selfjoin_properties.py (skipped when
hypothesis is absent); fused-kernel parity tests in test_fused_join.py.
"""
import numpy as np

from repro.core.baselines import ego_join, rtree_join
from repro.core.brute import brute_force_count, brute_force_join
from repro.core.grid import build_grid, build_grid_host, masks_host
from repro.core.selfjoin import (
    JoinStats,
    per_point_neighbor_counts,
    range_query,
    self_join,
    self_join_batched,
    self_join_count,
)
from repro.core.stencil import stencil_offsets, unicomp_paper_visits


def oracle_pairs(pts, eps):
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    hit = d2 <= eps * eps
    np.fill_diagonal(hit, False)
    i, j = np.nonzero(hit)
    out = np.stack([i, j], 1).astype(np.int32)
    return out[np.lexsort((out[:, 1], out[:, 0]))]


def test_join_matches_oracle_deterministic():
    rng = np.random.default_rng(2)
    for n in (2, 3, 5):
        pts = rng.uniform(0, 10, (200, n))
        eps = 1.0
        assert np.array_equal(self_join(pts, eps), oracle_pairs(pts, eps))


def test_unicomp_equals_full_stencil_deterministic():
    rng = np.random.default_rng(4)
    pts = rng.uniform(0, 10, (250, 3))
    a = self_join(pts, 0.9, unicomp=True)
    b = self_join(pts, 0.9, unicomp=False)
    assert np.array_equal(a, b)


def test_batched_invariant_to_batch_count_deterministic():
    rng = np.random.default_rng(6)
    pts = rng.uniform(0, 10, (300, 2))
    a = self_join(pts, 0.7)
    for nb in (2, 3, 5):
        assert np.array_equal(self_join_batched(pts, 0.7, n_batches=nb), a)


def test_result_symmetry_deterministic():
    """Euclidean distance is reflexive (paper SV-B): (p,q) <-> (q,p)."""
    rng = np.random.default_rng(8)
    pts = rng.uniform(0, 10, (300, 3))
    pairs = self_join(pts, 0.9)
    fwd = set(map(tuple, pairs))
    assert fwd == {(b, a) for a, b in fwd}


def test_baselines_agree():
    rng = np.random.default_rng(7)
    for n in (2, 3, 4):
        pts = rng.uniform(0, 10, (300, n))
        eps = 0.8
        expect = len(oracle_pairs(pts, eps))
        assert brute_force_count(pts, eps) == expect
        assert rtree_join(pts, eps) == expect
        assert ego_join(pts, eps) == expect
        assert self_join_count(pts, eps).total_pairs == expect
        _, rp = rtree_join(pts, eps, return_pairs=True)
        _, ep_ = ego_join(pts, eps, return_pairs=True)
        assert np.array_equal(rp, oracle_pairs(pts, eps))
        assert np.array_equal(ep_, oracle_pairs(pts, eps))
        assert np.array_equal(brute_force_join(pts, eps),
                              oracle_pairs(pts, eps))


def test_unicomp_halves_work():
    """Paper SV-B: UNICOMP reduces cells searched and distance calcs ~2x.

    Holds in the dense regime (several points per cell, most adjacent cells
    non-empty -- the paper's low-dimensionality setting); in sparse data the
    self-cell (never halved) dominates and the ratio drops below 2, which
    matches the paper's observed <2x on some datasets.
    """
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 10, (4000, 3))
    s_uni = self_join_count(pts, 1.0, unicomp=True)
    s_full = self_join_count(pts, 1.0, unicomp=False)
    assert s_uni.total_pairs == s_full.total_pairs
    # offsets: (3^n+1)/2 vs 3^n
    assert s_uni.offsets == (3**3 + 1) // 2
    assert s_full.offsets == 3**3
    ratio = s_full.candidates_checked / max(s_uni.candidates_checked, 1)
    assert 1.6 < ratio < 2.4
    cells_ratio = s_full.cells_visited / max(s_uni.cells_visited, 1)
    assert 1.6 < cells_ratio < 2.4


def test_paper_unicomp_rule_equivalent_to_half_stencil():
    """Alg. 2's odd/even rule and our lexicographic half-stencil both
    evaluate every unordered adjacent-cell pair exactly once."""
    for n in (1, 2, 3, 4):
        half = {tuple(o) for o in stencil_offsets(n, unicomp=True)}
        half.discard((0,) * n)
        # half-stencil: exactly one of {o, -o} kept
        for o in half:
            assert tuple(-np.array(o)) not in half
        full = {tuple(o) for o in stencil_offsets(n, unicomp=False)}
        assert len(half) == (len(full) - 1) // 2
        # paper rule: for every cell pair (c, c+o), exactly one endpoint
        # evaluates it
        rng = np.random.default_rng(n)
        for _ in range(20):
            c = rng.integers(0, 7, n)
            for o in full:
                if o == (0,) * n:
                    continue
                o = np.array(o)
                a_visits = tuple(o) in unicomp_paper_visits(c, n)
                b_visits = tuple(-o) in unicomp_paper_visits(c + o, n)
                assert a_visits ^ b_visits


def test_jit_grid_matches_host_grid():
    rng = np.random.default_rng(11)
    pts = rng.uniform(0, 20, (500, 3))
    h = build_grid_host(pts, 0.7)
    j = build_grid(pts, 0.7)
    nc = int(h.num_cells)
    assert int(j.num_cells) == nc
    assert np.array_equal(np.asarray(h.cell_keys[:nc]),
                          np.asarray(j.cell_keys[:nc]))
    assert np.array_equal(np.asarray(h.cell_count[:nc]),
                          np.asarray(j.cell_count[:nc]))
    assert int(h.max_per_cell) == int(j.max_per_cell)
    # points grouped identically (order within a cell may differ; compare
    # the sorted point ids per cell)
    for h_idx in (0, nc // 2, nc - 1):
        s, c = int(h.cell_start[h_idx]), int(h.cell_count[h_idx])
        a = np.sort(np.asarray(h.order[s:s + c]))
        s2, c2 = int(j.cell_start[h_idx]), int(j.cell_count[h_idx])
        b = np.sort(np.asarray(j.order[s2:s2 + c2]))
        assert np.array_equal(a, b)


def test_masks_host_prune_consistency():
    """The M_j arrays (paper SIV-C) contain exactly the non-empty per-dim
    coordinates."""
    rng = np.random.default_rng(5)
    pts = rng.uniform(0, 10, (200, 2))
    idx = build_grid_host(pts, 1.0)
    M = masks_host(idx)
    from repro.core.grid import cell_coords
    import jax.numpy as jnp

    coords = np.floor(
        (pts - (pts.min(0) - 1.0)) / 1.0).astype(np.int64)
    for j in range(2):
        assert set(M[j]) == set(np.unique(coords[:, j]))


def test_per_point_counts_and_range_query():
    rng = np.random.default_rng(13)
    pts = rng.uniform(0, 10, (400, 3))
    eps = 0.9
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    hit = d2 <= eps * eps
    np.fill_diagonal(hit, False)
    assert np.array_equal(per_point_neighbor_counts(pts, eps), hit.sum(1))
    # external queries (not in the dataset)
    q = rng.uniform(-1, 11, (50, 3))
    dq = ((q[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    expect = (dq <= eps * eps).sum(1)
    got = range_query(q, pts, eps)
    assert np.array_equal(got, expect)


def test_compact_sweep_matches_dense():
    """Empty-neighbor compaction (beyond-paper opt): identical counts,
    gather traffic bounded by the exact live-query cap."""
    from repro.core.grid import build_grid_host
    from repro.core.selfjoin import (compact_cap, self_join_count_compact)

    rng = np.random.default_rng(23)
    for n, eps in ((2, 0.5), (4, 3.0), (5, 6.0)):
        pts = rng.uniform(0, 60, (3000, n))
        dense = self_join_count(pts, eps, unicomp=True)
        comp = self_join_count_compact(pts, eps, unicomp=True)
        assert comp.total_pairs == dense.total_pairs, n
        comp_f = self_join_count_compact(pts, eps, unicomp=False)
        assert comp_f.total_pairs == dense.total_pairs, n
        idx = build_grid_host(pts, eps)
        assert compact_cap(idx, True) <= 3000


def test_per_point_counts_prebuilt_index_and_degenerates():
    """Satellite coverage: per_point_neighbor_counts against the oracle
    with a PREBUILT index, on skewed data, and in the no-neighbor case."""
    rng = np.random.default_rng(29)
    bg = rng.uniform(0, 10, (300, 2))
    cl = rng.normal(5.0, 0.1, (150, 2))
    pts = np.concatenate([bg, cl])
    eps = 0.5
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    hit = d2 <= eps * eps
    np.fill_diagonal(hit, False)
    idx = build_grid_host(pts, eps)
    got = per_point_neighbor_counts(pts, eps, index=idx)
    assert np.array_equal(got, hit.sum(1))
    assert got.sum() == self_join_count(pts, eps, index=idx).total_pairs
    # isolated points: every degree is zero
    iso = np.array([[0.0, 0.0], [5.0, 5.0], [9.0, 9.0]])
    assert np.array_equal(per_point_neighbor_counts(iso, 1.0), [0, 0, 0])
    # coincident points count each other but never themselves
    dup = np.zeros((4, 3))
    assert np.array_equal(per_point_neighbor_counts(dup, 0.1), [3, 3, 3, 3])


def test_build_grid_requires_int64_keys():
    """Regression (satellite): with jax_enable_x64 off, a grid whose key
    space exceeds 2^31 cells would silently truncate keys to int32 (6-D
    key spaces alias); the builders must refuse instead. Grids UNDER the
    boundary now take the int32 fast path (key_dtype_for) and build fine
    without x64 — see tests/test_grid_keys.py for that half."""
    import jax
    import jax.numpy as jnp
    import pytest

    from repro.core.grid import build_grid_with_geometry, grid_geometry

    rng = np.random.default_rng(7)
    pts = rng.uniform(0, 100, (64, 6))
    pts[0] = 0.0
    pts[1] = 100.0                  # pin the extent: eps 2.9 -> ~3.0e9 cells
    jax.config.update("jax_enable_x64", False)
    try:
        with pytest.raises(RuntimeError, match="int64"):
            build_grid_host(pts, 2.9)
        # small grids no longer need x64 at all: int32 fast path
        assert build_grid_host(pts, 5.0).key_dtype == np.int32
        with pytest.raises(RuntimeError, match="jax_enable_x64"):
            gmin = jnp.asarray(pts.min(0) - 5.0, jnp.float32)
            dims = jnp.full((6,), 23, jnp.int32)
            build_grid_with_geometry(jnp.asarray(pts, jnp.float32), 5.0,
                                     gmin, dims)
    finally:
        jax.config.update("jax_enable_x64", True)
    # restored: the guarded builders work again and big grids are int64
    idx = build_grid_host(pts, 2.9)
    assert np.asarray(idx.cell_keys).dtype == np.int64
    g = grid_geometry(jnp.asarray(pts), 2.9)
    assert np.asarray(g[1]).dtype == np.int64


def test_pallas_impl_through_join():
    rng = np.random.default_rng(17)
    pts = rng.uniform(0, 10, (300, 2))
    a = self_join(pts, 0.7, distance_impl="jnp")
    b = self_join(pts, 0.7, distance_impl="pallas")
    assert np.array_equal(a, b)


def test_empty_and_tiny():
    pts = np.array([[0.0, 0.0], [10.0, 10.0]])
    assert self_join_count(pts, 1.0).total_pairs == 0
    assert self_join(pts, 1.0).shape == (0, 2)
    pts = np.array([[0.0, 0.0], [0.5, 0.0], [10.0, 10.0]])
    assert self_join_count(pts, 1.0).total_pairs == 2


def test_batched_more_batches_than_points():
    """n_batches > npts: the batch count clamps to the point count, so no
    empty trailing batch ever schedules a rounded-up query slice over pure
    padding rows. Pair sets match the unbatched join for every impl."""
    rng = np.random.default_rng(23)
    for npts in (1, 2, 3, 5):
        pts = rng.uniform(0, 2, (npts, 2))
        ref = self_join(pts, 0.8, distance_impl="jnp")
        for impl in ("jnp", "fused"):
            got = self_join_batched(pts, 0.8, n_batches=npts + 4,
                                    distance_impl=impl)
            assert np.array_equal(got, ref), (npts, impl)
