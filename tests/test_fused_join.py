"""Parity tests for distance_impl='fused' (kernels/fused_join.py).

The fused gather-refine path must produce identical pair sets and counts to
the 'jnp' reference across every driver, including the degenerate grid
shapes (one point per cell, all points in one cell) and the
empty-neighbor-heavy 6-D regime where most (query, offset) probes miss.
The Pallas kernel itself (interpret mode off-TPU) is validated against the
reference lowering bit-for-bit, including the per-query counts and the
in-kernel exclusive-scan slot bases.
"""
import numpy as np
import pytest

from repro.core.grid import build_grid_host
from repro.core.selfjoin import (
    _fused_batch_run,
    _fused_pad,
    _offset_tables,
    _round_up,
    _self_join_fused,
    self_join,
    self_join_batched,
    self_join_count,
    self_join_count_compact,
)


def fused_run(index, deltas, is_zero, npts, c, unicomp, method,
              merged=False):
    points_pad, qp = _fused_pad(index, q_size=npts, c=c, merged=merged)
    return _fused_batch_run(index, points_pad, deltas, is_zero, 0, qp=qp,
                            q_size=npts, c=c, unicomp=unicomp,
                            keep_hits=True, method=method, merged=merged)


def sorted_pairs(p):
    return p[np.lexsort((p[:, 1], p[:, 0]))]


def datasets():
    rng = np.random.default_rng(99)
    yield "uniform-2d", rng.uniform(0, 10, (400, 2)), 0.6
    yield "uniform-3d", rng.uniform(0, 10, (300, 3)), 1.0
    centers = rng.uniform(0, 10, (12, 2))
    clustered = centers[rng.integers(0, 12, 350)] + rng.normal(0, 0.1, (350, 2))
    yield "clustered-2d", clustered, 0.25
    # empty-neighbor-heavy: 6-D uniform, >90% of stencil probes miss
    yield "sparse-6d", rng.uniform(0, 60, (250, 6)), 7.0
    dup = rng.integers(0, 3, (120, 3)).astype(np.float64)
    yield "degenerate-dups", dup, 0.5


@pytest.mark.parametrize("unicomp", [True, False])
def test_fused_join_matches_jnp(unicomp):
    for name, pts, eps in datasets():
        a = self_join(pts, eps, unicomp=unicomp, distance_impl="jnp")
        b = self_join(pts, eps, unicomp=unicomp, distance_impl="fused")
        assert np.array_equal(a, b), name


def test_fused_count_matches_jnp():
    for name, pts, eps in datasets():
        n = pts.shape[1]
        merged_off = {True: (3 ** (n - 1) + 1) // 2, False: 3 ** (n - 1)}
        for unicomp in (True, False):
            a = self_join_count(pts, eps, unicomp=unicomp)
            b = self_join_count(pts, eps, unicomp=unicomp,
                                distance_impl="fused")
            assert a.total_pairs == b.total_pairs, name
            if b.route == "compact":
                # compacted counter: fewer slots checked by construction,
                # no per-cell visit counter
                assert b.candidates_checked <= a.candidates_checked, name
            else:
                # 'dense'/'sparse' (merged, measured '-flat' or measured
                # '-run'), and 'jnp' all report counter-for-counter parity
                # with the reference
                assert b.route in ("dense", "sparse", "jnp", "dense-flat",
                                   "sparse-flat", "dense-run"), \
                    (name, b.route)
                assert a.cells_visited == b.cells_visited, name
                assert a.candidates_checked == b.candidates_checked, name
            # the fused sweep defaults to the merged-range stencil: 3^(n-1)
            # offsets (reduced UNICOMP half); the 'jnp' fallback and the
            # measured '-flat' routes run per cell and report 3^n
            if b.route in ("dense", "sparse"):
                assert b.n_offsets == merged_off[unicomp], (name, b.route)
            elif b.route.endswith("-flat"):
                assert b.n_offsets == a.n_offsets, (name, b.route)
            # every explicit route override agrees on the total; the
            # counter-parity routes also agree counter-for-counter
            for route in ("dense", "sparse", "jnp"):
                d = self_join_count(pts, eps, unicomp=unicomp,
                                    distance_impl="fused", route=route)
                assert d.route == route and d.total_pairs == a.total_pairs
                assert d.cells_visited == a.cells_visited, (name, route)
                assert d.candidates_checked == a.candidates_checked, \
                    (name, route)
                if route in ("dense", "sparse"):
                    assert d.n_offsets == merged_off[unicomp], (name, route)
                # the per-cell oracle sweep reports the full 3^n counts
                u = self_join_count(pts, eps, unicomp=unicomp,
                                    distance_impl="fused", route=route,
                                    merge_last_dim=False)
                assert u.total_pairs == a.total_pairs, (name, route)
                assert u.cells_visited == a.cells_visited, (name, route)
                assert u.candidates_checked == a.candidates_checked, \
                    (name, route)
                if route in ("dense", "sparse"):
                    assert u.n_offsets == a.n_offsets, (name, route)


def test_fused_batched_matches_jnp():
    for name, pts, eps in datasets():
        a = self_join(pts, eps, distance_impl="jnp")
        for nb in (2, 3, 5):
            b = self_join_batched(pts, eps, n_batches=nb,
                                  distance_impl="fused")
            assert np.array_equal(a, b), (name, nb)


def test_fused_count_compact_matches_jnp():
    for name, pts, eps in datasets():
        for unicomp in (True, False):
            a = self_join_count_compact(pts, eps, unicomp=unicomp)
            b = self_join_count_compact(pts, eps, unicomp=unicomp,
                                        distance_impl="fused")
            assert a.total_pairs == b.total_pairs, (name, unicomp)
            assert a.candidates_checked == b.candidates_checked, (name, unicomp)


def test_fused_count_query_batching():
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 10, (500, 2))
    a = self_join_count(pts, 0.7)
    for qb in (64, 130, 500):
        b = self_join_count(pts, 0.7, distance_impl="fused", query_batch=qb)
        assert a.total_pairs == b.total_pairs, qb
        assert a.candidates_checked == b.candidates_checked, qb


def test_fused_max_per_cell_one_point_per_cell():
    """Grid-aligned points, eps < spacing/2: every cell holds one point."""
    g = np.stack(np.meshgrid(np.arange(12.0), np.arange(12.0)), -1)
    pts = g.reshape(-1, 2) * 3.0
    idx = build_grid_host(pts, 1.4)
    assert int(idx.max_per_cell) == 1
    for unicomp in (True, False):
        a = self_join(pts, 1.4, unicomp=unicomp, distance_impl="jnp")
        b = self_join(pts, 1.4, unicomp=unicomp, distance_impl="fused")
        assert np.array_equal(a, b)
    # spacing 3 > eps: no pairs at all
    assert self_join_count(pts, 1.4, distance_impl="fused").total_pairs == 0
    # eps just over the spacing: 4-neighborhood pairs appear
    s = self_join_count(pts, 3.1, distance_impl="fused")
    assert s.total_pairs == self_join_count(pts, 3.1).total_pairs > 0


def test_fused_max_per_cell_single_cell():
    """All points inside one grid cell: C == |D|, window == whole dataset."""
    rng = np.random.default_rng(7)
    pts = rng.uniform(0, 0.3, (90, 2))
    idx = build_grid_host(pts, 1.0)
    assert int(idx.max_per_cell) == 90
    for unicomp in (True, False):
        a = self_join(pts, 1.0, unicomp=unicomp, distance_impl="jnp")
        b = self_join(pts, 1.0, unicomp=unicomp, distance_impl="fused")
        assert np.array_equal(a, b)
        assert a.shape == (90 * 89, 2)  # eps covers the whole cloud
    c = self_join_count_compact(pts, 1.0, distance_impl="fused")
    assert c.total_pairs == 90 * 89


def test_fused_tiny_and_empty():
    pts = np.array([[0.0, 0.0], [10.0, 10.0]])
    assert self_join_count(pts, 1.0, distance_impl="fused").total_pairs == 0
    assert self_join(pts, 1.0, distance_impl="fused").shape == (0, 2)
    pts = np.array([[0.0, 0.0], [0.5, 0.0], [10.0, 10.0]])
    assert self_join_count(pts, 1.0, distance_impl="fused").total_pairs == 2
    assert np.array_equal(self_join(pts, 1.0, distance_impl="fused"),
                          self_join(pts, 1.0, distance_impl="jnp"))


def test_fused_emit_host_equals_device():
    """Both fill backends consume the same hit set and must agree."""
    rng = np.random.default_rng(11)
    pts = rng.uniform(0, 10, (350, 3))
    index = build_grid_host(pts, 0.9)
    for unicomp in (True, False):
        h = _self_join_fused(index, unicomp=unicomp, sort_result=True,
                             emit="host")
        d = _self_join_fused(index, unicomp=unicomp, sort_result=True,
                             emit="device")
        assert np.array_equal(h, d), unicomp
        # both backends emit query-major: identical row order even UNSORTED
        hu = _self_join_fused(index, unicomp=unicomp, sort_result=False,
                              emit="host")
        du = _self_join_fused(index, unicomp=unicomp, sort_result=False,
                              emit="device")
        assert np.array_equal(hu, du), unicomp
        # multi-batch device emission exercises the pow2 capacity path
        d3 = _self_join_fused(index, unicomp=unicomp, sort_result=True,
                              emit="device", n_batches=3)
        assert np.array_equal(h, d3), unicomp


def test_pallas_kernel_matches_reference():
    """The Pallas kernel (interpret off-TPU) against the reference lowering:
    hits, per-query counts, and in-kernel exclusive-scan slot bases."""
    rng = np.random.default_rng(5)
    for n, npts, eps, unicomp in [(2, 220, 0.8, True), (2, 220, 0.8, False),
                                  (3, 150, 1.2, True)]:
        pts = rng.uniform(0, 10, (npts, n))
        index = build_grid_host(pts, eps)
        deltas, is_zero = _offset_tables(index, unicomp)
        c = _round_up(max(int(index.max_per_cell), 1), 8)
        ref = fused_run(index, deltas, is_zero, npts, c, unicomp, "reference")
        ker = fused_run(index, deltas, is_zero, npts, c, unicomp, "kernel")
        for name, a, b in zip(("ws", "wc", "wcells", "hits", "counts",
                               "slot_base"), ref, ker):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (name, n)
        # slot_base really is the per-tile exclusive scan of counts
        counts = np.asarray(ref[4])
        base = np.asarray(ref[5])
        per_tile = counts.reshape(-1, 128)
        expect = np.cumsum(per_tile, axis=1) - per_tile
        assert np.array_equal(base.reshape(-1, 128), expect)


def test_pallas_kernel_join_end_to_end():
    """Full join through the Pallas kernel path equals the jnp oracle."""
    rng = np.random.default_rng(13)
    pts = rng.uniform(0, 10, (260, 2))
    index = build_grid_host(pts, 0.8)
    a = self_join(pts, 0.8, distance_impl="jnp")
    b = _self_join_fused(index, unicomp=True, sort_result=True,
                         method="kernel")
    assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# Occupancy bucketing (DESIGN.md S6)
# ---------------------------------------------------------------------------

def skewed(seed=31, n_dims=2, n_bg=500, n_cl=260):
    """Heavy cluster + sparse background: guaranteed multi-class plan."""
    rng = np.random.default_rng(seed)
    bg = rng.uniform(0, 10, (n_bg, n_dims))
    cl = rng.normal(5.0, 0.12, (n_cl, n_dims))
    return np.concatenate([bg, cl])


def test_occupancy_plan_partitions_rows():
    from repro.core.grid import occupancy_plan

    pts = skewed()
    index = build_grid_host(pts, 0.5)
    plan = occupancy_plan(index)
    assert plan.n_buckets > 1, "workload must exercise multiple classes"
    assert plan.caps == tuple(sorted(plan.caps))
    assert plan.caps[-1] == plan.cap_global
    assert plan.cap_global == _round_up(int(index.max_per_cell), 8)
    # every sorted row in exactly one bucket, ascending within each
    allsel = np.concatenate(plan.sel)
    assert np.array_equal(np.sort(allsel), np.arange(index.num_points))
    for s in plan.sel:
        assert np.all(np.diff(s) > 0)
    assert sum(plan.hist.values()) == index.num_points
    # plan is cached per index object
    assert occupancy_plan(index) is plan
    # per-bucket capacity really bounds every member row's windows
    from repro.core.grid import cell_window_caps
    caps = cell_window_caps(index)
    rank = np.asarray(index.point_cell_rank)
    for cap, s in zip(plan.caps, plan.sel):
        assert caps[rank[s]].max() <= cap


@pytest.mark.parametrize("unicomp", [True, False])
def test_bucketed_join_bit_identical_to_single_capacity(unicomp):
    """Satellite gate: bucketed and single-capacity fused joins produce
    bit-identical sorted pair sets (and match the jnp oracle)."""
    for n_dims, eps in ((2, 0.5), (3, 0.9)):
        pts = skewed(seed=41 + n_dims, n_dims=n_dims)
        index = build_grid_host(pts, eps)
        from repro.core.grid import occupancy_plan

        assert occupancy_plan(index).n_buckets > 1
        a = self_join(pts, eps, unicomp=unicomp, distance_impl="jnp",
                      index=index)
        b = self_join(pts, eps, unicomp=unicomp, distance_impl="fused",
                      index=index)                      # bucketed (auto)
        s = self_join(pts, eps, unicomp=unicomp, distance_impl="fused",
                      index=index, bucketed=False)      # single capacity
        assert np.array_equal(b, s), (n_dims, unicomp)
        assert np.array_equal(a, b), (n_dims, unicomp)
        # counts: bucketed and single-capacity report identical work
        cb = self_join_count(pts, eps, unicomp=unicomp, index=index,
                             distance_impl="fused", route="dense")
        cs = self_join_count(pts, eps, unicomp=unicomp, index=index,
                             distance_impl="fused", route="dense",
                             bucketed=False)
        assert (cb.total_pairs, cb.cells_visited, cb.candidates_checked) \
            == (cs.total_pairs, cs.cells_visited, cs.candidates_checked)


def test_bucketed_join_batched_and_emits():
    """Bucketed launches compose with the batching scheme and both fill
    backends."""
    pts = skewed(seed=77)
    index = build_grid_host(pts, 0.5)
    a = self_join(pts, 0.5, distance_impl="jnp", index=index)
    for nb in (2, 4):
        b = self_join_batched(pts, 0.5, n_batches=nb,
                              distance_impl="fused", index=index)
        assert np.array_equal(a, b), nb
    h = _self_join_fused(index, unicomp=True, sort_result=True, emit="host")
    d = _self_join_fused(index, unicomp=True, sort_result=True,
                         emit="device")
    assert np.array_equal(h, d)
    assert np.array_equal(h, a)
    k = _self_join_fused(index, unicomp=True, sort_result=True,
                         method="kernel")
    assert np.array_equal(k, a)


def test_autotune_tile_and_route_cache(tmp_path, monkeypatch):
    """kernels/autotune.py: defaults on a cold cache, measured winners
    persisted and re-read."""
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    autotune._CACHE.reset()
    # cold cache, measurement off: deterministic default
    assert autotune.fused_tile(2, 16) == autotune.DEFAULT_TQ
    # measured: winner is a candidate, persisted, and re-read from disk
    tq = autotune.fused_tile(2, 16, measure=True)
    assert tq in autotune.TQ_CANDIDATES
    autotune._CACHE.reset()
    assert autotune.fused_tile(2, 16) == tq
    import json

    data = json.loads((tmp_path / "autotune.json").read_text())
    assert any(k.startswith("tile/") and k.endswith("/2d/c16")
               for k in data)
    # route: heuristic fallback, measured winner cached under the class key
    route, src = autotune.count_route(
        n_dims=6, n_off=365, c=3, occupancy=0.005, live_frac=0.005,
        backend="cpu")
    assert (route, src) == ("sparse", "heuristic")
    calls = []
    cands = {"dense": lambda: calls.append("dense"),
             "jnp": lambda: calls.append("jnp")}
    route, src = autotune.count_route(
        n_dims=6, n_off=365, c=3, occupancy=0.005, live_frac=0.005,
        backend="cpu", candidates=cands, measure=True)
    assert src == "measured" and route in cands and calls
    cached, src = autotune.count_route(
        n_dims=6, n_off=365, c=3, occupancy=0.005, live_frac=0.005,
        backend="cpu")
    assert (cached, src) == (route, "cache")
    autotune._CACHE.reset()


@pytest.mark.parametrize("merged", [False, True])
@pytest.mark.parametrize("unicomp", [True, False])
def test_gid_pairs_kernel_matches_reference(merged, unicomp):
    """The global-id pad lane (gid_pairs, DESIGN.md S3): the Pallas kernel
    and the reference lowering agree bit-for-bit on hits/counts/bases, and
    with ids == sorted positions the gid masks reproduce the positional
    join's pair totals exactly."""
    import jax.numpy as jnp

    from repro.core.selfjoin import _merged_offset_tables

    rng = np.random.default_rng(41)
    pts = rng.uniform(0, 8, (300, 3))
    eps = 0.9
    index = build_grid_host(pts, eps)
    npts = index.num_points
    if merged:
        deltas, is_zero = _merged_offset_tables(index, unicomp)
    else:
        deltas, is_zero = _offset_tables(index, unicomp)
    from repro.core.grid import global_window_cap

    c = global_window_cap(index, merged)
    # ids == sorted position: the gid tie-break coincides with the
    # positional triangle, so totals must match the plain sweep
    ids = np.arange(npts, dtype=np.int32)
    outs = {}
    for method in ("reference", "kernel"):
        points_pad, qp = _fused_pad(index, q_size=npts, c=c, merged=merged,
                                    gid=jnp.asarray(ids))
        outs[method] = _fused_batch_run(
            index, points_pad, deltas, is_zero, 0, qp=qp, q_size=npts,
            c=c, unicomp=unicomp, keep_hits=True, method=method,
            merged=merged, gid_pairs=True)
    for a, b in zip(outs["reference"][3:7], outs["kernel"][3:7]):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    counts = np.asarray(outs["reference"][4])
    mult = 2 if unicomp else 1
    expect = self_join_count(pts, eps, index=index, unicomp=unicomp,
                             distance_impl="jnp").total_pairs
    assert mult * int(counts.sum()) == expect
