"""Data pipeline + the paper's self-join dedup operator."""
import numpy as np
import pytest

from repro.data.dedup import (dedup_batch, dedup_embeddings, embed_ngrams,
                              guard_embeddings)
from repro.data.pipeline import TokenPipeline


def test_pipeline_deterministic_and_step_keyed():
    p1 = TokenPipeline(vocab=1000, batch=4, seq=64, seed=3)
    p2 = TokenPipeline(vocab=1000, batch=4, seq=64, seed=3)
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(17)["tokens"],
                              p1.batch_at(18)["tokens"])
    # labels are next-token with masked tail
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert (b1["labels"][:, -1] == -1).all()


def test_pipeline_restart_resumes_exactly():
    """The step index is the only state -> restart reproduces the stream."""
    p = TokenPipeline(vocab=500, batch=2, seq=32, seed=1)
    first = [p.batch_at(s)["tokens"] for s in range(5)]
    again = [TokenPipeline(vocab=500, batch=2, seq=32, seed=1).batch_at(s)["tokens"]
             for s in range(5)]
    for a, b in zip(first, again):
        assert np.array_equal(a, b)


def test_embed_ngrams_separates_duplicates():
    rng = np.random.default_rng(0)
    doc = rng.integers(0, 1000, (1, 128))
    near = doc.copy()
    near[0, ::64] += 1                        # tiny perturbation (2 tokens)
    far = rng.integers(0, 1000, (1, 128))
    emb = embed_ngrams(np.concatenate([doc, near, far]), n_dims=4)
    d_near = np.linalg.norm(emb[0] - emb[1])
    d_far = np.linalg.norm(emb[0] - emb[2])
    assert d_near < 0.25 * d_far


def test_dedup_batch_drops_planted_duplicates():
    rng = np.random.default_rng(1)
    base = rng.integers(0, 1000, (6, 128))
    batch = np.concatenate([base, base[:3]])   # plant 3 exact duplicates
    keep = dedup_batch(batch, eps=0.05)
    assert keep.sum() == 6
    # exactly one survivor per duplicate pair, and it is the earliest id
    for i in range(3):
        assert keep[i] and not keep[6 + i]
    # unrelated docs all kept
    assert keep[3:6].all()


def test_dedup_union_find_clusters():
    rng = np.random.default_rng(2)
    doc = rng.integers(0, 1000, (1, 128))
    batch = np.concatenate([doc] * 4 + [rng.integers(0, 1000, (2, 128))])
    keep = dedup_batch(batch, eps=0.05)
    assert keep.sum() == 3                     # 1 survivor + 2 unique
    assert keep[0] and not keep[1:4].any()


def test_guard_embeddings_flags_zero_and_nonfinite_rows():
    emb = np.array([[1.0, 0.0], [0.0, 0.0], [np.nan, 1.0],
                    [np.inf, 0.5], [0.3, -0.4]])
    assert np.array_equal(guard_embeddings(emb),
                          [True, False, False, False, True])


def test_dedup_embeddings_cosine_scale_invariant():
    """Cosine dedup must catch a scaled copy (same direction, different
    norm) that L2 dedup at any small radius would miss."""
    rng = np.random.default_rng(3)
    base = rng.normal(size=(6, 5))
    scaled = 7.5 * base[:3]                    # same docs, longer vectors
    emb = np.concatenate([base, scaled])
    keep, valid = dedup_embeddings(emb, min_cos=0.999)
    assert valid.all()
    assert keep[:6].all() and not keep[6:].any()


def test_dedup_embeddings_quarantines_bad_encodes():
    """Zero/NaN rows survive the guard (kept for re-encode, valid=False)
    and never reach cosine canonicalization -- which rejects them."""
    rng = np.random.default_rng(4)
    good = rng.normal(size=(5, 4))
    emb = np.concatenate([good, good[:2],       # 2 exact dups
                          np.zeros((1, 4)),     # encoder timeout
                          np.full((1, 4), np.nan)])
    keep, valid = dedup_embeddings(emb, min_cos=0.999)
    assert np.array_equal(valid, [True] * 7 + [False] * 2)
    assert keep[7:].all()                       # quarantined rows kept
    assert keep[:5].all() and not keep[5:7].any()
    # the same batch without the guard seam crashes canonicalization
    from repro.core import metric as metric_lib
    with pytest.raises(ValueError):
        metric_lib.canonicalize(emb, 0.999, metric="cosine")


def test_dedup_embeddings_matches_brute_cosine_clusters():
    """keep-mask parity with a brute-force union-find over the exact
    cosine similarity matrix."""
    rng = np.random.default_rng(5)
    emb = rng.normal(size=(40, 6))
    emb[10:14] = emb[0:4] + 0.001 * rng.normal(size=(4, 6))  # near-dups
    min_cos = 0.99
    keep, valid = dedup_embeddings(emb, min_cos=min_cos)
    assert valid.all()
    u = emb / np.linalg.norm(emb, axis=1, keepdims=True)
    sims = u @ u.T
    parent = list(range(40))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for i in range(40):
        for j in range(i + 1, 40):
            if sims[i, j] >= min_cos:
                ri, rj = find(i), find(j)
                if ri != rj:
                    parent[max(ri, rj)] = min(ri, rj)
    expect = np.array([find(i) == i for i in range(40)])
    assert np.array_equal(keep, expect)


def test_pipeline_dedup_keeps_batch_shape():
    p = TokenPipeline(vocab=50, batch=16, seq=32, seed=0, dedup=True,
                      dedup_eps=0.3)
    b = p.batch_at(0)
    assert b["tokens"].shape == (16, 32)
    assert b["labels"].shape == (16, 32)
