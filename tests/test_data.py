"""Data pipeline + the paper's self-join dedup operator."""
import numpy as np
import pytest

from repro.data.dedup import dedup_batch, embed_ngrams
from repro.data.pipeline import TokenPipeline


def test_pipeline_deterministic_and_step_keyed():
    p1 = TokenPipeline(vocab=1000, batch=4, seq=64, seed=3)
    p2 = TokenPipeline(vocab=1000, batch=4, seq=64, seed=3)
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(17)["tokens"],
                              p1.batch_at(18)["tokens"])
    # labels are next-token with masked tail
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert (b1["labels"][:, -1] == -1).all()


def test_pipeline_restart_resumes_exactly():
    """The step index is the only state -> restart reproduces the stream."""
    p = TokenPipeline(vocab=500, batch=2, seq=32, seed=1)
    first = [p.batch_at(s)["tokens"] for s in range(5)]
    again = [TokenPipeline(vocab=500, batch=2, seq=32, seed=1).batch_at(s)["tokens"]
             for s in range(5)]
    for a, b in zip(first, again):
        assert np.array_equal(a, b)


def test_embed_ngrams_separates_duplicates():
    rng = np.random.default_rng(0)
    doc = rng.integers(0, 1000, (1, 128))
    near = doc.copy()
    near[0, ::64] += 1                        # tiny perturbation (2 tokens)
    far = rng.integers(0, 1000, (1, 128))
    emb = embed_ngrams(np.concatenate([doc, near, far]), n_dims=4)
    d_near = np.linalg.norm(emb[0] - emb[1])
    d_far = np.linalg.norm(emb[0] - emb[2])
    assert d_near < 0.25 * d_far


def test_dedup_batch_drops_planted_duplicates():
    rng = np.random.default_rng(1)
    base = rng.integers(0, 1000, (6, 128))
    batch = np.concatenate([base, base[:3]])   # plant 3 exact duplicates
    keep = dedup_batch(batch, eps=0.05)
    assert keep.sum() == 6
    # exactly one survivor per duplicate pair, and it is the earliest id
    for i in range(3):
        assert keep[i] and not keep[6 + i]
    # unrelated docs all kept
    assert keep[3:6].all()


def test_dedup_union_find_clusters():
    rng = np.random.default_rng(2)
    doc = rng.integers(0, 1000, (1, 128))
    batch = np.concatenate([doc] * 4 + [rng.integers(0, 1000, (2, 128))])
    keep = dedup_batch(batch, eps=0.05)
    assert keep.sum() == 3                     # 1 survivor + 2 unique
    assert keep[0] and not keep[1:4].any()


def test_pipeline_dedup_keeps_batch_shape():
    p = TokenPipeline(vocab=50, batch=16, seq=32, seed=0, dedup=True,
                      dedup_eps=0.3)
    b = p.batch_at(0)
    assert b["tokens"].shape == (16, 32)
    assert b["labels"].shape == (16, 32)
