"""int32 grid-key fast path (core/grid.py key_dtype_for).

Small grids (prod(dims) < 2^31) build int32 cell keys and no longer
require jax_enable_x64; larger grids keep the int64 path behind the
explicit x64 guard.  The 6-D boundary regression pins the routing rule
on a grid whose key-space volume straddles 2^31.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.grid import (build_grid_host, grid_geometry, key_dtype_for,
                             pad_key_for)
from repro.core.query_join import prepare
from repro.core.selfjoin import self_join, self_join_count

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def brute_pairs(pts, eps):
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    i, j = np.nonzero(d2 <= eps * eps)
    return {(a, b) for a, b in zip(i.tolist(), j.tolist()) if a != b}


def test_key_dtype_for_boundary():
    assert key_dtype_for([46341, 46341]) == np.int64     # 46341^2 > 2^31-1
    assert key_dtype_for([46340, 46340]) == np.int32     # 46340^2 < 2^31
    # prod == 2^31-1 is still int32-safe: real keys <= prod-1 == 2^31-2,
    # so the dtype-max sentinel (2^31-1) never aliases a real cell.
    assert key_dtype_for([2**31 - 1]) == np.int32
    assert key_dtype_for([2**31]) == np.int64
    # product must be exact python-int arithmetic, no int64 overflow
    assert key_dtype_for([2**20, 2**20, 2**20]) == np.int64


def test_pad_key_for_is_dtype_max():
    assert pad_key_for(np.int32) == np.iinfo(np.int32).max
    assert pad_key_for(np.int64) == np.iinfo(np.int64).max


def test_small_grid_routes_to_int32():
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 100, size=(1500, 3))
    idx = build_grid_host(pts, 3.0)
    assert idx.key_dtype == np.int32
    assert np.asarray(idx.cell_keys).dtype == np.int32


def test_int32_selfjoin_matches_brute():
    rng = np.random.default_rng(1)
    pts = rng.uniform(0, 30, size=(400, 2))
    eps = 2.0
    idx = build_grid_host(pts, eps)
    assert idx.key_dtype == np.int32
    ref = brute_pairs(pts, eps)
    stats = self_join_count(pts, eps)
    assert int(stats.total_pairs) == len(ref)
    pairs = np.asarray(self_join(pts, eps))
    got = set(zip(pairs[:, 0].tolist(), pairs[:, 1].tolist()))
    assert got == ref


def test_int32_external_join_matches_brute():
    rng = np.random.default_rng(2)
    pts = rng.uniform(0, 50, size=(900, 3))
    eps = 3.0
    idx = build_grid_host(pts, eps)
    assert idx.key_dtype == np.int32
    pj = prepare(idx)
    q = rng.uniform(-5, 55, size=(64, 3))       # some queries off-grid
    d2 = ((q[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    ref = (d2 <= eps * eps).sum(1)
    assert np.array_equal(np.asarray(pj.counts(q)), ref)


def test_6d_boundary_grid_still_routes_to_int64():
    """Regression: a 6-D grid just past 2^31 cells must keep int64 keys.

    Uniform [0,100]^6 at eps=3.2 has prod(dims) ~ 1.79e9 (int32); the
    same extent at eps=2.9 has ~ 3.01e9 cells and MUST route to int64 --
    an int32 key there would alias distinct cells.
    """
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 100, size=(2000, 6))
    pts[0] = 0.0
    pts[1] = 100.0                              # pin the extent exactly

    _, dims_small = grid_geometry(pts, 3.2)
    _, dims_big = grid_geometry(pts, 2.9)
    vol_small = int(np.prod(np.asarray(dims_small, dtype=object)))
    vol_big = int(np.prod(np.asarray(dims_big, dtype=object)))
    assert vol_small < 2**31 <= vol_big         # straddles the boundary

    assert key_dtype_for(np.asarray(dims_small)) == np.int32
    assert key_dtype_for(np.asarray(dims_big)) == np.int64
    assert build_grid_host(pts, 3.2).key_dtype == np.int32
    idx64 = build_grid_host(pts, 2.9)
    assert idx64.key_dtype == np.int64
    # and the int64 build still answers correctly near the boundary
    eps = 2.9
    d2 = ((pts[:50, None, :] - pts[None, :, :]) ** 2).sum(-1)
    ref = (d2 <= eps * eps).sum(1)
    assert np.array_equal(np.asarray(prepare(idx64).counts(pts[:50])), ref)


@pytest.mark.slow
def test_no_x64_subprocess_int32_path_and_int64_guard():
    """With REPRO_NO_X64 set, small grids work end-to-end on int32 keys
    and a build that needs int64 keys raises instead of aliasing."""
    script = textwrap.dedent("""
        import numpy as np
        from repro.core.grid import build_grid_host
        from repro.core.query_join import prepare
        rng = np.random.default_rng(0)
        pts = rng.uniform(0, 30, size=(500, 2)).astype(np.float32)
        eps = 2.0
        idx = build_grid_host(pts, eps)
        assert idx.key_dtype == np.int32, idx.key_dtype
        q = pts[:40]
        d2 = ((q[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
        ref = (d2 <= np.float32(eps) * np.float32(eps)).sum(1)
        got = np.asarray(prepare(idx).counts(q))
        assert np.array_equal(got, ref), (got, ref)
        big = rng.uniform(0, 100, size=(64, 6))
        big[0] = 0.0
        big[1] = 100.0
        try:
            build_grid_host(big, 2.9)           # ~3.0e9 cells: needs int64
        except RuntimeError as e:
            assert "int64" in str(e) or "x64" in str(e), e
            print("OK")
        else:
            raise SystemExit("int64-needing build did not raise")
    """)
    env = dict(os.environ, REPRO_NO_X64="1",
               PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout
