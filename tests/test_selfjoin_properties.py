"""Hypothesis property tests for the self-join (oracle = O(N^2) matrix).

Separated from test_selfjoin.py so the deterministic suite still collects
when hypothesis is not installed (the seed environment); with hypothesis
present these run as before. ``pytest.importorskip`` keeps the split honest:
this module skips, nothing else does.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.selfjoin import self_join, self_join_batched  # noqa: E402


def oracle_pairs(pts, eps):
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    hit = d2 <= eps * eps
    np.fill_diagonal(hit, False)
    i, j = np.nonzero(hit)
    out = np.stack([i, j], 1).astype(np.int32)
    return out[np.lexsort((out[:, 1], out[:, 0]))]


@st.composite
def point_sets(draw):
    n = draw(st.integers(2, 5))
    npts = draw(st.integers(2, 120))
    scale = draw(st.sampled_from([1.0, 10.0, 100.0]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    kind = draw(st.sampled_from(["uniform", "clustered", "degenerate"]))
    if kind == "uniform":
        pts = rng.uniform(0, scale, (npts, n))
    elif kind == "clustered":
        centers = rng.uniform(0, scale, (max(npts // 10, 1), n))
        pts = centers[rng.integers(0, len(centers), npts)] + rng.normal(
            0, scale * 0.01, (npts, n))
    else:  # many duplicate coordinates
        pts = rng.integers(0, 3, (npts, n)).astype(np.float64) * scale * 0.1
    eps = draw(st.sampled_from([0.05, 0.2, 0.5])) * scale
    return pts, eps


@settings(max_examples=30, deadline=None)
@given(point_sets())
def test_join_matches_oracle(data):
    pts, eps = data
    expect = oracle_pairs(pts, eps)
    got = self_join(pts, eps, unicomp=True)
    assert np.array_equal(got, expect)


@settings(max_examples=15, deadline=None)
@given(point_sets())
def test_unicomp_equals_full_stencil(data):
    pts, eps = data
    a = self_join(pts, eps, unicomp=True)
    b = self_join(pts, eps, unicomp=False)
    assert np.array_equal(a, b)


@settings(max_examples=10, deadline=None)
@given(point_sets(), st.integers(2, 5))
def test_batched_invariant_to_batch_count(data, nb):
    pts, eps = data
    a = self_join_batched(pts, eps, n_batches=nb)
    b = self_join(pts, eps)
    assert np.array_equal(a, b)


@settings(max_examples=10, deadline=None)
@given(point_sets())
def test_fused_matches_oracle(data):
    """The fused gather-refine path against the O(N^2) oracle."""
    pts, eps = data
    expect = oracle_pairs(pts, eps)
    got = self_join(pts, eps, unicomp=True, distance_impl="fused")
    assert np.array_equal(got, expect)


@settings(max_examples=10, deadline=None)
@given(point_sets())
def test_result_symmetry(data):
    """Euclidean distance is reflexive (paper SV-B): (p,q) <-> (q,p)."""
    pts, eps = data
    pairs = self_join(pts, eps)
    fwd = set(map(tuple, pairs))
    assert fwd == {(b, a) for a, b in fwd}
