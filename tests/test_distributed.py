"""Distributed slab join + cross-pod compression, on 8 placeholder devices.

Runs in a subprocess-free way: conftest has NOT set a device count, so this
module re-execs itself? No -- simpler: these tests run under the 8-device
flag via the pytest-xdist-free trick of setting XLA_FLAGS in a subprocess.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# the 8-device matrix tests spawn an 8-placeholder-device subprocess and
# compile SPMD programs -- minutes of wall time; they carry the ``slow``
# marker individually. The 2-device smoke below is NOT slow-marked, so
# tier-1 always exercises the slab join end to end.
slow = pytest.mark.slow


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_smoke_two_devices():
    """Tier-1 (NOT slow): the fused slab join on 2 placeholder devices.

    Tiny workload so the subprocess stays in seconds: pair-set parity of
    ``distributed_self_join`` against the single-device fused join, the
    count contract, and the empty-slab regression (more slabs than
    points crashed the halo-reach scan: coords[i, gids[i] >= 0, 0].min()
    on a zero-point slab)."""
    out = run_sub(textwrap.dedent("""
        import numpy as np
        from repro.core.distributed import (distributed_self_join,
                                            distributed_self_join_count)
        from repro.core.selfjoin import self_join
        from repro.core.brute import brute_force_count
        from repro.launch.mesh import make_slab_mesh
        rng = np.random.default_rng(11)
        pts = rng.uniform(0, 6, size=(400, 2))
        eps = 0.5
        mesh = make_slab_mesh(2)
        ref = self_join(pts, eps, distance_impl='fused')
        got = distributed_self_join(pts, eps, mesh)
        assert np.array_equal(got, ref), (got.shape, ref.shape)
        n = distributed_self_join(pts, eps, mesh, return_pairs=False)
        assert n == ref.shape[0], (n, ref.shape)
        # empty-slab regression: 1 point, 2 slabs
        one = pts[:1]
        assert distributed_self_join(one, eps, mesh).shape == (0, 2)
        assert distributed_self_join_count(one, eps, mesh) == 0
        assert (distributed_self_join_count(pts[:3], eps, mesh)
                == brute_force_count(pts[:3], eps))
        print('OK')
    """), devices=2)
    assert "OK" in out


@slow
def test_distributed_pairs_parity_matrix():
    """Acceptance matrix: pair sets bit-identical to the single-device
    fused join at 2, 4, and 8 slabs, UNICOMP on/off, merged-range sweep
    on/off, on uniform and clustered workloads."""
    out = run_sub(textwrap.dedent("""
        import numpy as np
        from repro.core.distributed import distributed_self_join
        from repro.core.selfjoin import self_join
        from repro.launch.mesh import make_slab_mesh
        rng = np.random.default_rng(5)
        uni = rng.uniform(0, 10, size=(900, 2))
        k = rng.integers(0, 6, 900)
        centers = rng.uniform(0, 10, (6, 2))
        clus = centers[k] + rng.normal(0, 0.3, (900, 2))
        for name, pts, eps in (('uniform', uni, 0.5),
                               ('clustered', clus, 0.25)):
            for n_slabs in (2, 4, 8):
                mesh = make_slab_mesh(n_slabs)
                for unicomp in (True, False):
                    for merge in (True, False):
                        ref = self_join(pts, eps, unicomp=unicomp,
                                        distance_impl='fused',
                                        merge_last_dim=merge)
                        got = distributed_self_join(
                            pts, eps, mesh, unicomp=unicomp,
                            merge_last_dim=merge)
                        assert np.array_equal(got, ref), (
                            name, n_slabs, unicomp, merge,
                            got.shape, ref.shape)
        print('OK')
    """))
    assert "OK" in out


@slow
def test_halo_capacity_overflow_pairs():
    """An explicit too-small halo capacity raises (never silent)."""
    out = run_sub(textwrap.dedent("""
        import numpy as np
        from repro.core.distributed import distributed_self_join
        from repro.launch.mesh import make_slab_mesh
        rng = np.random.default_rng(3)
        pts = rng.uniform(0, 1.0, size=(400, 2))   # eps >> slab width
        mesh = make_slab_mesh(2)
        try:
            distributed_self_join(pts, 0.5, mesh, halo_capacity=2)
        except RuntimeError as e:
            assert 'halo capacity overflow' in str(e), e
            print('OK')
    """), devices=2)
    assert "OK" in out


@slow
def test_distributed_count_matches_brute():
    out = run_sub(textwrap.dedent("""
        import numpy as np, jax
        from repro.core.distributed import distributed_self_join_count
        from repro.core.brute import brute_force_count
        from repro.launch.mesh import make_mesh_compat
        rng = np.random.default_rng(1)
        for n, eps in ((2, 0.8), (3, 1.0)):
            pts = rng.uniform(0, 10, size=(1500, n))
            bf = brute_force_count(pts, eps)
            m1 = make_mesh_compat((8,), ('slab',))
            c1 = distributed_self_join_count(pts, eps, m1, unicomp=True)
            m2 = make_mesh_compat((4, 2), ('slab', 'model'))
            c2 = distributed_self_join_count(pts, eps, m2, unicomp=True,
                                             model_axis='model')
            c3 = distributed_self_join_count(pts, eps, m2, unicomp=False,
                                             model_axis='model')
            assert bf == c1 == c2 == c3, (n, bf, c1, c2, c3)
        print('OK')
    """))
    assert "OK" in out


@slow
def test_distributed_skewed_data_balanced():
    """Equal-count partitioner keeps slabs balanced under heavy skew."""
    out = run_sub(textwrap.dedent("""
        import numpy as np, jax
        from repro.core.distributed import (distributed_self_join_count,
                                            partition_points_host)
        from repro.core.brute import brute_force_count
        from repro.launch.mesh import make_mesh_compat
        rng = np.random.default_rng(2)
        # 90% of points clustered in 5% of the range
        a = rng.uniform(0, 0.5, size=(1800, 2))
        b = rng.uniform(0, 10, size=(200, 2))
        pts = np.concatenate([a, b])
        coords, gids, width = partition_points_host(pts, 8)
        counts = (gids >= 0).sum(axis=1)
        assert counts.max() - counts.min() <= 1, counts
        m = make_mesh_compat((8,), ('slab',))
        got = distributed_self_join_count(pts, 0.2, m)
        assert got == brute_force_count(pts, 0.2)
        print('OK')
    """))
    assert "OK" in out


@slow
def test_halo_overflow_detected():
    out = run_sub(textwrap.dedent("""
        import numpy as np, jax
        from repro.launch.mesh import make_mesh_compat
        from repro.core.distributed import (DistJoinConfig,
                                            make_distributed_count_step,
                                            partition_points_host)
        rng = np.random.default_rng(3)
        pts = rng.uniform(0, 1.0, size=(800, 2))  # eps >> slab width
        mesh = make_mesh_compat((8,), ('slab',))
        coords, gids, _ = partition_points_host(pts, 8)
        cfg = DistJoinConfig(pts_per_device=coords.shape[1], n_dims=2,
                             halo_capacity=4, max_per_cell=64,
                             model_axis=None)
        step, in_sh = make_distributed_count_step(mesh, cfg)
        import jax.numpy as jnp
        c = jax.device_put(coords.reshape(-1, 2), in_sh[0])
        g = jax.device_put(gids.reshape(-1), in_sh[1])
        total, halo_of, cell_of = step(c, g, jnp.asarray(0.5, pts.dtype))
        assert int(halo_of) == 1  # overflow detected, not silent
        print('OK')
    """))
    assert "OK" in out


@slow
def test_compressed_train_step_end_to_end():
    """Full train step with int8 cross-pod grad exchange on a (2,2,2) mesh:
    loss decreases and tracks the uncompressed step closely."""
    out = run_sub(textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh_compat
        from repro.configs import get_config
        from repro.models.lm import LMModel
        from repro.train.optimizer import AdamWConfig, adamw_init, opt_state_specs
        from repro.train.steps import make_train_step
        from repro.train.compression import init_error_state

        mesh = make_mesh_compat((2, 2, 2), ('pod', 'data', 'model'))
        cfg = get_config('smoke-lm', reduced=True)
        rng = np.random.default_rng(0)
        batch = {'tokens': jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32),
                 'labels': jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32)}
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=1)

        def run(compress):
            model = LMModel(cfg, mesh)
            params, specs = model.init(jax.random.PRNGKey(0))
            opt = adamw_init(params, ocfg)
            if compress:
                opt['grad_error'] = init_error_state(params)
            step = jax.jit(make_train_step(model, ocfg, compress_pods=compress,
                                           param_specs=specs))
            losses = []
            with mesh:
                for _ in range(4):
                    params, opt, m = step(params, opt, batch)
                    losses.append(float(m['loss']))
            return losses

        plain = run(False)
        comp = run(True)
        assert comp[-1] < comp[0], comp
        assert abs(comp[0] - plain[0]) < 1e-2, (comp[0], plain[0])
        assert abs(comp[-1] - plain[-1]) < 0.1, (comp, plain)
        print('OK')
    """))
    assert "OK" in out


@slow
def test_compressed_crosspod_grads():
    """int8 all-gather grad exchange: mean error small, error feedback
    carries the residual; exact for pod-identical gradients."""
    out = run_sub(textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh_compat
        from repro.train.compression import compressed_psum_mean
        mesh = make_mesh_compat((2, 4), ('pod', 'data'))
        rng = np.random.default_rng(0)
        g_global = rng.normal(size=(2, 64)).astype(np.float32)  # per-pod rows

        def f(g, e):
            m, ne = compressed_psum_mean({'w': g}, {'w': e}, 'pod', 2)
            return m['w'], ne['w']

        from repro.compat import shard_map
        sm = shard_map(f, mesh=mesh,
                       in_specs=(P('pod'), P('pod')),
                       out_specs=(P(), P('pod')),
                       axis_names={'pod'}, check_vma=False)
        g = jax.device_put(g_global.reshape(-1),
                           NamedSharding(mesh, P('pod')))
        e = jnp.zeros_like(g)
        mean, err = jax.jit(sm)(g, e)
        true_mean = g_global.mean(axis=0)
        got = np.asarray(mean)
        scale = np.abs(g_global).max() / 127.0
        assert got.shape == (64,)
        assert np.max(np.abs(got - true_mean)) <= scale + 1e-6
        # error feedback holds the quantization residual per pod
        err = np.asarray(err).reshape(2, 64)
        q = np.clip(np.round(g_global / scale), -127, 127)
        resid = g_global - q * scale
        assert np.allclose(err, resid, atol=1e-6)
        print('OK')
    """))
    assert "OK" in out
