"""End-to-end behaviour: drivers, examples, dry-run plumbing, registry."""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import main

    loss = main(["--arch", "smoke-lm", "--reduced", "--steps", "6",
                 "--batch", "4", "--seq", "32", "--log-every", "3",
                 "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"])
    assert np.isfinite(loss)
    from repro.ckpt import latest_step

    assert latest_step(str(tmp_path)) == 6
    # restart resumes from the checkpoint and continues
    loss2 = main(["--arch", "smoke-lm", "--reduced", "--steps", "8",
                  "--batch", "4", "--seq", "32", "--log-every", "3",
                  "--ckpt-dir", str(tmp_path)])
    assert np.isfinite(loss2)


@pytest.mark.slow
def test_train_driver_with_dedup():
    from repro.launch.train import main

    loss = main(["--arch", "smoke-lm", "--reduced", "--steps", "3",
                 "--batch", "4", "--seq", "32", "--dedup"])
    assert np.isfinite(loss)


def test_serve_selfjoin_driver():
    from repro.launch.serve import main

    lat = main(["--arch", "selfjoin", "--points", "2000", "--dims", "3",
                "--eps", "2.0", "--requests", "3", "--request-batch", "32"])
    assert lat > 0


def test_serve_lm_driver():
    from repro.launch.serve import main

    lat = main(["--arch", "smoke-lm", "--reduced",
                "--request-batch", "2", "--prompt-len", "16",
                "--tokens", "4"])
    assert lat > 0


def test_registry_after_prune():
    """The LM config registry holds only the generic smoke arch (the
    seed's 10 published-LLM configs were unrelated to the paper and were
    pruned, PR 3); selfjoin resolves through the alias table."""
    import pytest
    from repro.configs import ARCHS, ALIASES, all_cells, get_config

    assert ARCHS == ["smoke_lm"]
    assert set(ALIASES) == {"smoke-lm", "selfjoin"}
    cells = all_cells()
    assert len(cells) == 4  # 1 arch x 4 shapes
    # dense transformer: long_500k is skipped, the rest runnable
    assert [c[2] is None for c in cells] == [True, True, True, False]
    r = get_config("smoke-lm", reduced=True)
    f = get_config("smoke-lm")
    assert r.family == f.family == "dense"
    assert r.param_count() < f.param_count()
    from repro.configs.selfjoin import CONFIG as SJ  # noqa: F401  (kept)
    with pytest.raises(ModuleNotFoundError):
        get_config("qwen1.5-0.5b")  # pruned arch stays pruned


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """One real dry-run cell end to end (lower+compile on a 512-device
    placeholder topology + probes) in a fresh interpreter."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "smoke-lm", "--shape", "decode_32k", "--mesh", "single"],
        env=env, capture_output=True, text=True, timeout=560, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout and "bottleneck=" in out.stdout


@pytest.mark.slow
def test_examples_quickstart():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", "quickstart.py")],
        env=env, capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "validated" in out.stdout.lower()
