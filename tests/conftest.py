import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Drop compiled executables between test modules.

    The suite compiles hundreds of distinct programs in one process; on
    this container's jax 0.4.37 CPU backend the accumulated executable
    cache eventually segfaults a later XLA compile (reproducible at
    suite scale, never in an isolated module). Nothing in the suite
    relies on cross-module executable reuse -- no-retrace tests warm and
    assert within a single module -- so clearing per module keeps the
    process-wide cache bounded without changing any test's semantics.
    """
    yield
    import jax

    jax.clear_caches()
