"""External-query epsilon join (core/query_join.py, DESIGN.md S5).

Parity oracle is the O(Q x N) brute-force distance matrix: counts AND
sorted pairs must bit-match for queries inside the indexed volume, outside
it, duplicated, and coinciding with indexed points. The serving property
(no per-request trace/compile) is asserted through the executable-cache
stats; the tiny-grid tests are the regression for the inverted
``clip(qcoords, 1, dims - 2)`` clamp of the original ``range_query``
(coordinate-space bounds masking in ``grid.external_window_descriptors``
replaced it).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.grid import build_grid_host, build_grid_with_geometry
from repro.core.query_join import (
    PreparedJoin,
    bucket_rows,
    epsilon_join,
    executable_cache_stats,
    prepare,
)
from repro.core.selfjoin import range_query, self_join_count


def brute(queries, pts, eps):
    d2 = ((queries[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    hit = d2 <= eps * eps
    counts = hit.sum(1).astype(np.int32)
    q, p = np.nonzero(hit)
    pairs = np.stack([q, p], 1).astype(np.int32)
    return counts, pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]


def workloads():
    rng = np.random.default_rng(42)
    pts2 = rng.uniform(0, 10, (500, 2))
    yield "inside-2d", pts2, 0.6, rng.uniform(0, 10, (80, 2))
    # queries straddling and far outside the indexed volume
    yield "outside-3d", rng.uniform(0, 10, (300, 3)), 1.0, \
        rng.uniform(-8, 18, (60, 3))
    # high-dimensional sparse regime
    yield "sparse-6d", rng.uniform(0, 40, (200, 6)), 6.0, \
        rng.uniform(-5, 45, (40, 6))
    # duplicate query points (identical rows must get identical answers)
    qd = rng.uniform(0, 10, (20, 2))
    yield "dup-queries-2d", pts2, 0.6, np.repeat(qd, 3, axis=0)
    # queries that ARE indexed points: external join has no self-exclusion,
    # so each query counts its coincident point
    yield "coincident-2d", pts2, 0.6, pts2[::7].copy()


def test_epsilon_join_matches_brute_force():
    for name, pts, eps, q in workloads():
        counts, pairs = brute(q, pts, eps)
        res = epsilon_join(q, pts, eps, with_stats=True)
        assert np.array_equal(res.counts, counts), name
        assert np.array_equal(res.pairs, pairs), name
        assert res.total == counts.sum(), name
        assert res.bucket_rows == bucket_rows(q.shape[0]), name
        # counts-only path agrees without materializing the hit set
        assert np.array_equal(
            epsilon_join(q, pts, eps, return_pairs=False).counts, counts), name


def test_emit_backends_agree():
    rng = np.random.default_rng(7)
    pts = rng.uniform(0, 10, (400, 3))
    q = rng.uniform(-1, 11, (70, 3))
    index = build_grid_host(pts, 0.9)
    pj = prepare(index)
    h = pj.join(q, emit="host")
    d = pj.join(q, emit="device")
    assert np.array_equal(h.counts, d.counts)
    assert np.array_equal(h.pairs, d.pairs)
    # both emits are query-major: identical row order even unsorted
    hu = pj.join(q, emit="host", sort_pairs=False)
    du = pj.join(q, emit="device", sort_pairs=False)
    assert np.array_equal(hu.pairs, du.pairs)


def test_pallas_kernel_external_matches_reference():
    """The Pallas kernel path (interpret off-TPU) with external=True."""
    rng = np.random.default_rng(11)
    pts = rng.uniform(0, 10, (300, 2))
    q = rng.uniform(-1, 11, (50, 2))
    index = build_grid_host(pts, 0.8)
    pj = prepare(index)
    ref = pj.join(q, method="reference")
    ker = pj.join(q, method="kernel")
    assert np.array_equal(ref.counts, ker.counts)
    assert np.array_equal(ref.pairs, ker.pairs)
    counts, pairs = brute(q, pts, 0.8)
    assert np.array_equal(ker.counts, counts)
    assert np.array_equal(ker.pairs, pairs)


def test_eps_override_and_validation():
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 10, (300, 2))
    q = rng.uniform(0, 10, (40, 2))
    index = build_grid_host(pts, 1.0)
    pj = prepare(index)
    # a smaller query radius than the build radius is exact
    counts, pairs = brute(q, pts, 0.5)
    res = pj.join(q, eps=0.5)
    assert np.array_equal(res.counts, counts)
    assert np.array_equal(res.pairs, pairs)
    # a larger radius cannot be served by the +/-1-cell stencil
    with pytest.raises(ValueError):
        pj.join(q, eps=1.5)
    with pytest.raises(ValueError):
        pj.join(q[:, :1])  # wrong dimensionality


def test_tiny_grid_clip_regression():
    """Grids with < 3 cells per dimension (regression for the inverted
    ``clip(qcoords, 1, dims - 2)``: with dims=2 the bounds invert and,
    key-space probing aside, offset deltas alias (radix-2 linearization),
    double-counting adjacent-cell neighbors)."""
    pts = np.array([[0.2, 0.2], [1.8, 0.3], [1.7, 1.6], [0.1, 1.9],
                    [1.0, 1.0], [0.2, 1.6]])
    for dims in ([2, 2], [2, 4], [4, 2]):
        eps = 1.5
        gmin = jnp.zeros(2, dtype=jnp.float64 if pts.dtype == np.float64
                         else jnp.float32)
        index = build_grid_with_geometry(
            jnp.asarray(pts), eps, gmin, jnp.asarray(dims, jnp.int64))
        q = np.array([[0.2, 1.2], [0.3, 0.3], [1.9, 1.9], [-0.5, 0.5],
                      [2.4, 0.1], [5.0, 5.0], [1.0, 2.9]])
        counts, pairs = brute(q, pts, eps)
        res = prepare(index).join(q)
        assert np.array_equal(res.counts, counts), dims
        assert np.array_equal(res.pairs, pairs), dims
        got = range_query(q, pts, eps, index=index)
        assert np.array_equal(got, counts), dims


def test_range_query_wrapper():
    rng = np.random.default_rng(13)
    pts = rng.uniform(0, 10, (400, 3))
    eps = 0.9
    q = rng.uniform(-1, 11, (50, 3))
    counts, pairs = brute(q, pts, eps)
    assert np.array_equal(range_query(q, pts, eps), counts)
    got_counts, got_pairs = range_query(q, pts, eps, return_pairs=True)
    assert np.array_equal(got_counts, counts)
    assert np.array_equal(got_pairs, pairs)


def test_bucket_rows():
    assert bucket_rows(0) == 128
    assert bucket_rows(1) == 128
    assert bucket_rows(128) == 128
    assert bucket_rows(129) == 256
    assert bucket_rows(300) == 512
    assert bucket_rows(512) == 512
    assert bucket_rows(513) == 1024


def test_no_retrace_across_requests():
    """The serve-path regression gate: once a bucket shape is warm, further
    requests (any size within the bucket, any query values, any eps <=
    build eps) must hit cached executables only."""
    rng = np.random.default_rng(5)
    pts = rng.uniform(0, 10, (600, 2))
    index = build_grid_host(pts, 0.7)
    pj = prepare(index)
    pj.join(rng.uniform(0, 10, (100, 2)))          # warm the 128-row bucket
    pj.join(rng.uniform(0, 10, (100, 2)), emit="device")
    pj.join(rng.uniform(0, 10, (100, 2)), return_pairs=False)
    mark = executable_cache_stats()
    # the default serve path runs the merged-range descriptors (S7)
    assert mark["external_range_windows"] >= 1
    for k in range(6):
        q = rng.uniform(-2, 12, (17 + 13 * k, 2))  # all inside the bucket
        pj.join(q)
        pj.join(q, emit="device")
        pj.join(q, return_pairs=False, eps=0.3 + 0.05 * k)
    assert executable_cache_stats() == mark
    # a NEW bucket shape compiles exactly once...
    pj.join(rng.uniform(0, 10, (200, 2)))
    grown = executable_cache_stats()
    assert (grown["external_range_windows"]
            == mark["external_range_windows"] + 1)
    # ...and is itself steady afterwards
    pj.join(rng.uniform(0, 10, (150, 2)))
    assert executable_cache_stats() == grown


def test_join_service_steady_state():
    from repro.launch.serve import JoinService

    rng = np.random.default_rng(9)
    pts = rng.uniform(0, 10, (800, 3))
    svc = JoinService(pts, 0.8)
    svc.warmup(64)
    svc.mark_steady()
    expect_total = 0
    for _ in range(5):
        q = rng.uniform(0, 10, (64, 3))
        res = svc.query(q)
        b, _ = brute(q, pts, 0.8)
        assert np.array_equal(res.counts, b)
        expect_total += int(b.sum())
    svc.assert_no_retrace()   # raises on any steady-state compile
    assert svc.total_neighbors == expect_total
    p50, p99 = svc.percentiles()
    assert 0 < p50 <= p99
    assert svc.requests == 5


def test_fused_count_auto_route(tmp_path, monkeypatch):
    """Satellite: self_join_count(distance_impl='fused') routes through
    the autotune table (measured winner when cached, occupancy heuristic
    otherwise), logging the choice in JoinStats.route.

    The repo ships a measured cache (kernels/autotune_cache.json); this
    test pins the HEURISTIC tier, so it isolates itself from any cache.

    The heuristic regimes: TPU routes the empty-neighbor regime to the
    compacted counter (window-DMA traffic binds); off-TPU that regime goes
    to the probe-compacted 'sparse' counter (the per-offset packing sort
    of 'compact' measured slower everywhere off-TPU, EXPERIMENTS.md),
    while dense neighborhoods stay on the bucketed dense sweep."""
    from repro.core.selfjoin import _fused_count_route
    from repro.core.stencil import stencil_offsets
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "empty.json"))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    autotune._CACHE.reset()
    rng = np.random.default_rng(21)
    dense_pts = rng.uniform(0, 10, (400, 2))
    sparse_pts = rng.uniform(0, 60, (250, 6))
    dense_idx = build_grid_host(dense_pts, 0.6)
    sparse_idx = build_grid_host(sparse_pts, 7.0)
    n_off2 = stencil_offsets(2, True).shape[0]
    n_off6 = stencil_offsets(6, True).shape[0]
    # the regime detection (forced onto the TPU branch)
    assert _fused_count_route(sparse_idx, n_off6, backend="tpu") == "compact"
    assert _fused_count_route(dense_idx, n_off2, backend="tpu") == "dense"
    # off-TPU: the empty-neighbor regime routes to the flat probe
    # compaction once the dense slot volume is large enough (full stencil
    # guarantees it here), never to the per-offset packing sort
    assert _fused_count_route(dense_idx, n_off2, backend="cpu") == "dense"
    assert _fused_count_route(
        sparse_idx, 3 ** 6, backend="cpu", unicomp=False) == "sparse"
    a = self_join_count(dense_pts, 0.6, distance_impl="fused")
    assert a.route == "dense"
    expect = self_join_count(sparse_pts, 7.0)
    assert expect.route == "dense"   # non-fused impls never reroute
    # explicit overrides run the named counter and log it
    for route in ("compact", "dense", "sparse", "jnp"):
        b = self_join_count(sparse_pts, 7.0, distance_impl="fused",
                            route=route)
        assert b.route == route
        assert b.total_pairs == expect.total_pairs, route
    with pytest.raises(ValueError):
        self_join_count(sparse_pts, 7.0, distance_impl="fused",
                        route="nope")


def test_epsilon_join_empty_query_batch():
    rng = np.random.default_rng(2)
    pts = rng.uniform(0, 10, (100, 2))
    res = epsilon_join(np.zeros((0, 2)), pts, 0.5)
    assert res.counts.shape == (0,)
    assert res.pairs.shape == (0, 2)


# ---------------------------------------------------------------------------
# Occupancy-bucketed serving (DESIGN.md S6): a skewed index routes request
# batches through per-capacity-class launches; answers must stay
# bit-identical to brute force and the steady state must stay retrace-free
# across arbitrary class mixes.
# ---------------------------------------------------------------------------

def skewed_index(seed=3, n_dims=2, eps=0.5):
    rng = np.random.default_rng(seed)
    bg = rng.uniform(0, 10, (500, n_dims))
    cl = rng.normal(5.0, 0.12, (260, n_dims))
    pts = np.concatenate([bg, cl])
    return pts, build_grid_host(pts, eps)


def test_bucketed_serving_matches_brute_force():
    pts, index = skewed_index()
    pj = prepare(index)
    assert pj.bucketed and len(pj.classes) > 1
    rng = np.random.default_rng(8)
    # mixes: inside the cluster (big class), background, outside the volume
    q = np.concatenate([rng.normal(5.0, 0.2, (30, 2)),
                        rng.uniform(-1, 11, (40, 2))])
    counts, pairs = brute(q, pts, 0.5)
    for kwargs in ({}, {"emit": "device"}, {"method": "kernel"}):
        res = pj.join(q, **kwargs)
        assert np.array_equal(res.counts, counts), kwargs
        assert np.array_equal(res.pairs, pairs), kwargs
    assert np.array_equal(pj.join(q, return_pairs=False).counts, counts)
    # host and device emits agree per class (sorted output is canonical)
    h = pj.join(q, emit="host")
    d = pj.join(q, emit="device")
    assert np.array_equal(h.pairs, d.pairs)
    # smaller query eps flows through the bucketed launches
    c2, p2 = brute(q, pts, 0.3)
    r2 = pj.join(q, eps=0.3)
    assert np.array_equal(r2.counts, c2)
    assert np.array_equal(r2.pairs, p2)


def test_bucketed_serving_no_retrace():
    """Once warmed, steady-state requests must not compile regardless of
    which capacity classes each request happens to populate. The device-
    emit scatter is exempt (result-size-bucketed, same rule as
    JoinService.assert_no_retrace)."""

    def freeze(stats):
        out = {k: v for k, v in stats.items()
               if k not in ("emit_pairs_device", "trace_events")}
        out["trace_events"] = {k: v for k, v in stats["trace_events"].items()
                               if k != "emit_pairs_device"}
        return out

    pts, index = skewed_index(seed=11)
    pj = prepare(index)
    assert pj.bucketed
    pj.warm(128)
    mark = executable_cache_stats()
    assert mark["window_caps"] >= 1
    rng = np.random.default_rng(5)
    for k in range(6):
        # different sizes, different class mixes (cluster-only,
        # background-only, mixed, all-miss)
        qs = [rng.normal(5.0, 0.1, (9 + 11 * k, 2)),
              rng.uniform(0, 10, (17 + 13 * k, 2)),
              rng.uniform(20, 30, (5, 2))]
        for q in qs:
            pj.join(q)
            pj.join(q, return_pairs=False, eps=0.3 + 0.02 * k)
            pj.join(q, emit="device")
    assert freeze(executable_cache_stats()) == freeze(mark)


def test_warm_covers_full_request_bucket():
    """Regression: warm(n) must cover EVERY request that lands in the same
    request bucket as n -- including one whose rows all fall in a single
    capacity class, which needs a class launch at the full bucket size
    (larger than any class launch a size-n request can need)."""

    def freeze(stats):
        out = {k: v for k, v in stats.items()
               if k not in ("emit_pairs_device", "trace_events")}
        out["trace_events"] = {k: v for k, v in stats["trace_events"].items()
                               if k != "emit_pairs_device"}
        return out

    pts, index = skewed_index(seed=23)
    pj = prepare(index)
    assert pj.bucketed
    pj.warm(64)                      # request bucket: 128 rows
    mark = executable_cache_stats()
    rng = np.random.default_rng(7)
    # 128 queries, every one inside the cluster -> one class at qp_b=128
    q = rng.normal(5.0, 0.1, (128, 2))
    pj.join(q)
    pj.join(q, return_pairs=False)
    assert freeze(executable_cache_stats()) == freeze(mark)


def test_bucketed_join_service_steady_state():
    from repro.launch.serve import JoinService

    pts, index = skewed_index(seed=17)
    svc = JoinService(pts, 0.5, index=index)
    assert svc.prepared.bucketed
    svc.warmup(64)
    svc.mark_steady()
    rng = np.random.default_rng(19)
    for _ in range(4):
        q = np.concatenate([rng.normal(5.0, 0.15, (20, 2)),
                            rng.uniform(0, 10, (44, 2))])
        res = svc.query(q)
        b, _ = brute(q, pts, 0.5)
        assert np.array_equal(res.counts, b)
    svc.assert_no_retrace()


def test_sharded_service_matches_single_index():
    """ShardedJoinService (DESIGN.md S3 serving mode): scatter-gather over
    per-slab indexes answers exactly like the single-index service --
    counts elementwise, pairs as the same sorted set with global point
    ids -- and the steady state never retraces."""
    from repro.launch.serve import JoinService, ShardedJoinService

    rng = np.random.default_rng(31)
    pts = rng.uniform(0, 40, (2500, 3))
    eps = 1.5
    single = JoinService(pts, eps, return_pairs=True)
    sharded = ShardedJoinService(pts, eps, 3, return_pairs=True)
    qs = [np.random.default_rng(seed).uniform(-2, 42, (100, 3))
          for seed in (0, 1)]
    # the executable caches are module-level and shared across services:
    # answer the single-index reference BEFORE marking steady state, or its
    # compilations would trip the sharded service's no-retrace gate
    refs = [single.query(q) for q in qs]
    sharded.warmup(128)
    sharded.mark_steady()
    for q, r1 in zip(qs, refs):
        r2 = sharded.query(q)
        assert np.array_equal(r1.counts, r2.counts)
        p1 = r1.pairs[np.lexsort((r1.pairs[:, 1], r1.pairs[:, 0]))]
        assert np.array_equal(p1, r2.pairs)
    sharded.assert_no_retrace()
    # more slabs than points: empty slabs are skipped, answers unchanged
    tiny = ShardedJoinService(pts[:2], eps, 5, return_pairs=True)
    ref = JoinService(pts[:2], eps, return_pairs=True).query(q[:16])
    got = tiny.query(q[:16])
    assert np.array_equal(ref.counts, got.counts)
