"""Checkpoint: roundtrip, atomicity, retention, async, elastic restore."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (CheckpointManager, latest_step, restore_checkpoint,
                        save_checkpoint)


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                   "c": (jnp.zeros((), jnp.int32), jnp.full((2, 2), 7.0))},
    }


def test_roundtrip_exact(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), 3, t)
    assert latest_step(str(tmp_path)) == 3
    got = restore_checkpoint(str(tmp_path), 3, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))


def test_atomicity_incomplete_ignored(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a crash mid-save: staging dir + manifest w/o complete flag
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "manifest.json").write_text(json.dumps({"complete": False}))
    (tmp_path / "step_00000003.tmp").mkdir()
    assert latest_step(str(tmp_path)) == 1


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last_k=2)
    t = tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, t)
        mgr.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_async_overlaps_and_surfaces_errors(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(1, tree())
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1
    # an unwritable directory surfaces on wait()
    mgr2 = CheckpointManager("/proc/definitely/not/writable")
    mgr2.save_async(1, tree())
    with pytest.raises(BaseException):
        mgr2.wait()


def test_elastic_restore_subprocess(tmp_path):
    """Save on 1 device; restore onto a 4-device mesh with shardings --
    the restart-on-different-topology path."""
    import subprocess, sys, textwrap

    t = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    save_checkpoint(str(tmp_path), 7, t)
    code = textwrap.dedent(f"""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import restore_checkpoint
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4,), ('data',))
        like = {{"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}}
        got = restore_checkpoint({str(tmp_path)!r}, 7, like, mesh=mesh,
                                 specs={{"w": P('data', None)}})
        w = got['w']
        assert len(w.sharding.device_set) == 4, w.sharding
        assert np.array_equal(np.asarray(w),
                              np.arange(32, dtype=np.float32).reshape(8, 4))
        print('OK')
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_straggler_monitor():
    from repro.train.straggler import StragglerMonitor

    mon = StragglerMonitor(threshold=2.0, patience=2, warmup_steps=1)
    assert not mon.record(10.0)  # warmup (compile) step ignored
    mon.record(1.0)              # seeds the EWMA
    assert not mon.record(1.1)
    assert mon.record(5.0)       # strike 1
    assert not mon.should_rebalance()
    assert mon.record(5.0)       # strike 2
    assert mon.should_rebalance()
    mon.reset()
    assert not mon.should_rebalance()


def test_heartbeats(tmp_path):
    import time
    from repro.train.straggler import StragglerMonitor

    mon = StragglerMonitor(dead_after=60.0)
    StragglerMonitor.heartbeat(str(tmp_path), 0, step=5)
    StragglerMonitor.heartbeat(str(tmp_path), 1, step=5)
    assert mon.dead_hosts(str(tmp_path)) == []
    # host 1 goes silent; clock advances past dead_after
    assert mon.dead_hosts(str(tmp_path), now=time.time() + 120) == [0, 1]
