"""Optimizer + compression unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.compression import dequantize, quantize
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   opt_state_specs)


def _rosenbrock_ish(params):
    x = params["x"]
    return jnp.sum((x - 1.5) ** 2) + 0.1 * jnp.sum(x ** 4)


@pytest.mark.parametrize("cfg", [
    AdamWConfig(lr=0.05, warmup_steps=1, weight_decay=0.0),
    AdamWConfig(lr=0.05, warmup_steps=1, weight_decay=0.0, factored=True),
    AdamWConfig(lr=0.05, warmup_steps=1, weight_decay=0.0,
                m_dtype="bfloat16"),
])
def test_adamw_converges(cfg):
    params = {"x": jnp.zeros((4, 8), jnp.float32)}
    state = adamw_init(params, cfg)

    @jax.jit
    def step(p, s):
        loss, g = jax.value_and_grad(_rosenbrock_ish)(p)
        p, s, m = adamw_update(g, s, p, cfg)
        return p, s, loss

    losses = []
    for _ in range(200):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    # analytic minimum of sum((x-1.5)^2 + 0.1 x^4) over 32 elems is ~9.49
    assert losses[-1] < 9.6, losses[-1]
    assert int(state["step"]) == 200


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, warmup_steps=1,
                      weight_decay=0.0)
    params = {"x": jnp.zeros((8,), jnp.float32)}
    state = adamw_init(params, cfg)
    huge = {"x": jnp.full((8,), 1e9, jnp.float32)}
    _, state, metrics = adamw_update(huge, state, params, cfg)
    assert float(metrics["grad_norm"]) > 1e8
    # clipped: m holds a scaled gradient
    assert np.abs(np.asarray(state["m"]["x"])).max() < 1e-3


def test_bf16_params_fp32_master():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, weight_decay=0.0)
    params = {"x": jnp.ones((4,), jnp.bfloat16)}
    state = adamw_init(params, cfg)
    g = {"x": jnp.full((4,), 1e-3, jnp.bfloat16)}
    new_params = params
    for _ in range(10):
        new_params, state, _ = adamw_update(g, state, new_params, cfg)
    # master accumulates below bf16 resolution; params stay bf16
    assert new_params["x"].dtype == jnp.bfloat16
    assert state["master"]["x"].dtype == jnp.float32
    assert not np.array_equal(np.asarray(state["master"]["x"], np.float32),
                              np.asarray(params["x"], np.float32))


def test_factored_v_specs_and_shapes():
    cfg = AdamWConfig(factored=True)
    params = {"w": jnp.zeros((6, 8), jnp.float32),
              "b": jnp.zeros((8,), jnp.float32)}
    state = adamw_init(params, cfg)
    assert state["v"]["w"]["row"].shape == (6,)
    assert state["v"]["w"]["col"].shape == (8,)
    assert state["v"]["b"].shape == (8,)   # 1-D stays unfactored
    from jax.sharding import PartitionSpec as P
    specs = opt_state_specs({"w": P("data", "model"), "b": P(None)}, cfg,
                            params)
    assert specs["v"]["w"]["row"] == P("data")
    assert specs["v"]["w"]["col"] == P("model")


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    scale = jnp.max(jnp.abs(x)) / 127.0
    q = quantize(x, scale)
    assert q.dtype == jnp.int8
    err = np.asarray(x - dequantize(q, scale))
    assert np.abs(err).max() <= float(scale) / 2 + 1e-7
