"""Merged-range sweep (DESIGN.md S7): 3^n -> 3^(n-1) last-dimension
stencil merging.

The parity oracle is the retained per-cell sweep (``merge_last_dim=False``)
and the 'jnp' reference: pair SETS must be identical (sorted), work
counters (cells_visited / candidates_checked) must match counter-for-
counter, and only ``JoinStats.n_offsets`` may shrink. Boundary-heavy
grids -- points on the dataset edge, a collapsed (3-cell) dimension,
coincident points, and externally supplied geometry with < 3 cells in a
dimension -- exercise the row-clamp of the range probes; the kernel's
last-dimension boundary mask is unit-tested directly with a fabricated
wrapped window.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.grid import (
    build_grid_host,
    build_grid_with_geometry,
    cell_window_caps,
    global_window_cap,
    occupancy_plan,
    point_last_coords,
    range_window_descriptors_at,
    row_major_strides,
    window_descriptors_at,
)
from repro.core.selfjoin import (
    _merged_offset_tables,
    per_point_neighbor_counts,
    self_join,
    self_join_batched,
    self_join_count,
)
from repro.core.stencil import merged_stencil_offsets, stencil_offsets


def sorted_pairs(p):
    return p[np.lexsort((p[:, 1], p[:, 0]))]


def brute(queries, pts, eps):
    d2 = ((queries[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    hit = d2 <= eps * eps
    counts = hit.sum(1).astype(np.int32)
    q, p = np.nonzero(hit)
    pairs = np.stack([q, p], 1).astype(np.int32)
    return counts, sorted_pairs(pairs)


# ---------------------------------------------------------------------------
# Stencil algebra
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 3, 4, 6])
@pytest.mark.parametrize("unicomp", [True, False])
def test_merged_stencil_covers_per_cell_stencil(n, unicomp):
    """Expanding every reduced offset over its [lo, hi] last-dim span must
    reproduce the per-cell stencil exactly (no cell missed, none doubled).
    """
    reduced, lo, hi = merged_stencil_offsets(n, unicomp)
    if unicomp:
        assert reduced.shape[0] == (3 ** (n - 1) - 1) // 2 + 1
    else:
        assert reduced.shape[0] == 3 ** (n - 1)
    assert np.all(reduced[:, -1] == 0)
    assert np.all(reduced[0] == 0) and np.all(lo <= hi)
    expanded = set()
    for o, l, h in zip(reduced, lo, hi):
        for d in range(int(l), int(h) + 1):
            cell = tuple(o[:-1]) + (d,)
            assert cell not in expanded, cell
            expanded.add(cell)
    flat = {tuple(o) for o in stencil_offsets(n, unicomp)}
    assert expanded == flat


def test_merged_descriptors_equal_per_cell_union():
    """Per (reduced offset, query): the merged window must be exactly the
    concatenation of the three per-cell windows -- same total length, same
    live-cell count, same start (windows are spans of points_sorted)."""
    rng = np.random.default_rng(17)
    pts = rng.uniform(0, 10, (400, 3))
    index = build_grid_host(pts, 0.9)
    npts = index.num_points
    strides = np.asarray(row_major_strides(index.dims))
    reduced, lo, hi = merged_stencil_offsets(3, unicomp=False)
    q_pos = jnp.arange(npts, dtype=jnp.int32)
    dtab, _ = _merged_offset_tables(index, unicomp=False)
    ws, wc, wcells = range_window_descriptors_at(
        index, dtab[0], dtab[1], dtab[2], q_pos)
    for k, o in enumerate(reduced):
        parts = []
        for d in (-1, 0, 1):
            cell = np.array(o)
            cell[-1] = d
            delta = jnp.asarray([int(cell @ strides)])
            s, c = window_descriptors_at(index, delta, q_pos)
            parts.append((np.asarray(s)[0], np.asarray(c)[0]))
        total = sum(c for _, c in parts)
        ncells = sum((c > 0).astype(int) for _, c in parts)
        assert np.array_equal(np.asarray(wc)[k], total), k
        assert np.array_equal(np.asarray(wcells)[k], ncells), k
        # live merged windows start at the first live per-cell window
        live = np.asarray(wc)[k] > 0
        first = np.where(parts[0][1] > 0, parts[0][0],
                         np.where(parts[1][1] > 0, parts[1][0],
                                  parts[2][0]))
        assert np.array_equal(np.asarray(ws)[k][live], first[live])


def test_point_last_coords_matches_float_cell_coords():
    rng = np.random.default_rng(3)
    pts = rng.uniform(-5, 5, (300, 4))
    index = build_grid_host(pts, 0.8)
    lc = np.asarray(point_last_coords(index))
    ps = np.asarray(index.points_sorted)
    expect = np.floor(
        (ps[:, -1] - np.asarray(index.grid_min)[-1]) / 0.8).astype(np.int64)
    assert np.array_equal(lc, expect)


# ---------------------------------------------------------------------------
# Pair-set parity on boundary-heavy grids
# ---------------------------------------------------------------------------

def boundary_datasets():
    rng = np.random.default_rng(29)
    # points ON the dataset min/max edges: their cells sit at coordinate 1
    # and dims-2, so range probes reach grid rows 0 and dims-1
    edge = rng.uniform(0, 8, (300, 3))
    edge[:40] = np.round(edge[:40] / 8) * 8            # snap to 0 / 8
    yield "edge-3d", edge, 0.9
    # a collapsed dimension: every point shares the last coordinate, so
    # the last-dim axis has the minimum 3 cells and every query's row
    # clamp is load-bearing
    flat = rng.uniform(0, 10, (250, 3))
    flat[:, -1] = 4.0
    yield "collapsed-last-3d", flat, 0.7
    # collapsed FIRST dimension (merging acts on the last)
    flat2 = flat.copy()
    flat2[:, 0] = 2.0
    flat2[:, -1] = rng.uniform(0, 10, 250)
    yield "collapsed-first-3d", flat2, 0.7
    # coincident points: zero-distance pairs, duplicate keys
    dup = rng.integers(0, 3, (150, 3)).astype(np.float64)
    yield "coincident-3d", dup, 0.5
    # 1-D data: the reduced stencil degenerates to ONE range probe
    yield "line-1d", rng.uniform(0, 50, (400, 1)), 0.8
    # empty-neighbor-heavy 6-D
    yield "sparse-6d", rng.uniform(0, 60, (220, 6)), 7.0


@pytest.mark.parametrize("unicomp", [True, False])
def test_merged_pair_set_identical_to_oracle(unicomp):
    for name, pts, eps in boundary_datasets():
        index = build_grid_host(pts, eps)
        a = self_join(pts, eps, unicomp=unicomp, index=index,
                      distance_impl="jnp")
        m = self_join(pts, eps, unicomp=unicomp, index=index,
                      distance_impl="fused", merge_last_dim=True)
        u = self_join(pts, eps, unicomp=unicomp, index=index,
                      distance_impl="fused", merge_last_dim=False)
        assert np.array_equal(m, u), name
        assert np.array_equal(m, a), name


def test_merged_counters_and_n_offsets():
    """Acceptance gate: the merged sweep executes 3^(n-1) offsets (UNICOMP
    correspondingly reduced), asserted via JoinStats.n_offsets, with
    cells/candidates counters identical to the per-cell oracle."""
    for name, pts, eps in boundary_datasets():
        n = pts.shape[1]
        index = build_grid_host(pts, eps)
        for unicomp, n_red in ((True, (3 ** (n - 1) - 1) // 2 + 1),
                               (False, 3 ** (n - 1))):
            m = self_join_count(pts, eps, unicomp=unicomp, index=index,
                                distance_impl="fused", route="dense",
                                merge_last_dim=True)
            u = self_join_count(pts, eps, unicomp=unicomp, index=index,
                                distance_impl="fused", route="dense",
                                merge_last_dim=False)
            assert m.n_offsets == n_red, (name, unicomp)
            assert u.n_offsets == ((3 ** n + 1) // 2 if unicomp else 3 ** n)
            assert m.total_pairs == u.total_pairs, name
            assert m.cells_visited == u.cells_visited, name
            assert m.candidates_checked == u.candidates_checked, name
            s = self_join_count(pts, eps, unicomp=unicomp, index=index,
                                distance_impl="fused", route="sparse",
                                merge_last_dim=True)
            assert (s.total_pairs, s.cells_visited, s.candidates_checked,
                    s.n_offsets) == (m.total_pairs, m.cells_visited,
                                     m.candidates_checked, n_red), name


def test_merged_unicomp_equivalent_to_full():
    """UNICOMP-equivalence under merging: the reduced half-stencil with
    the merged zero-span [0, +1] emits the same pair set as the full
    merged sweep and as the unmerged UNICOMP sweep."""
    rng = np.random.default_rng(41)
    pts = rng.uniform(0, 10, (350, 3))
    index = build_grid_host(pts, 0.9)
    uni_m = self_join(pts, 0.9, unicomp=True, index=index,
                      distance_impl="fused", merge_last_dim=True)
    full_m = self_join(pts, 0.9, unicomp=False, index=index,
                       distance_impl="fused", merge_last_dim=True)
    uni_u = self_join(pts, 0.9, unicomp=True, index=index,
                      distance_impl="fused", merge_last_dim=False)
    assert np.array_equal(uni_m, full_m)
    assert np.array_equal(uni_m, uni_u)


def test_merged_batched_and_bucketed():
    rng = np.random.default_rng(31)
    bg = rng.uniform(0, 10, (500, 2))
    cl = rng.normal(5.0, 0.12, (260, 2))
    pts = np.concatenate([bg, cl])
    index = build_grid_host(pts, 0.5)
    assert occupancy_plan(index, merged=True).n_buckets > 1
    a = self_join(pts, 0.5, index=index, distance_impl="jnp")
    for nb in (2, 4):
        b = self_join_batched(pts, 0.5, n_batches=nb, index=index,
                              distance_impl="fused", merge_last_dim=True)
        assert np.array_equal(a, b), nb
    s = self_join(pts, 0.5, index=index, distance_impl="fused",
                  merge_last_dim=True, bucketed=False)
    assert np.array_equal(a, s)


def test_merged_occupancy_plan_bounds_windows():
    """Merged capacity classes really bound every member row's merged
    windows, and the merged global capacity bounds the per-cell one by at
    most the 3-cell union."""
    rng = np.random.default_rng(53)
    pts = np.concatenate([rng.uniform(0, 10, (400, 2)),
                          rng.normal(5.0, 0.15, (300, 2))])
    index = build_grid_host(pts, 0.5)
    caps = cell_window_caps(index, merged=True)
    caps_flat = cell_window_caps(index, merged=False)
    assert np.all(caps >= caps_flat)          # union >= largest member
    assert np.all(caps <= 3 * np.maximum(caps_flat, 1))
    assert global_window_cap(index, merged=True) >= int(caps.max())
    plan = occupancy_plan(index, merged=True)
    assert sum(plan.hist.values()) == index.num_points
    rank = np.asarray(index.point_cell_rank)
    if plan.sel[0] is not None:
        for cap, sel in zip(plan.caps, plan.sel):
            assert caps[rank[sel]].max() <= cap
    # merged and per-cell plans are cached independently
    assert occupancy_plan(index, merged=True) is plan
    assert occupancy_plan(index) is not plan


# ---------------------------------------------------------------------------
# Custom geometry (< 3 cells in a dimension) and the kernel boundary mask
# ---------------------------------------------------------------------------

def test_merged_external_tiny_grid_dims_under_3():
    """External-query merging on grids with < 3 cells per dimension (only
    reachable through externally supplied geometry): the last-dim span
    clamp must prevent the range probe from wrapping across grid rows --
    with dims[-1] = 2 an unclamped [base-1, base+1] span would pull an
    ADJACENT (stencil-covered) cell in twice and double-count."""
    from repro.core.query_join import prepare

    pts = np.array([[0.2, 0.2], [1.8, 0.3], [1.7, 1.6], [0.1, 1.9],
                    [1.0, 1.0], [0.2, 1.6]])
    q = np.array([[0.2, 1.2], [0.3, 0.3], [1.9, 1.9], [-0.5, 0.5],
                  [2.4, 0.1], [5.0, 5.0], [1.0, 2.9]])
    for dims in ([2, 2], [2, 4], [4, 2], [3, 2]):
        eps = 1.5
        gmin = jnp.zeros(2, dtype=jnp.float64)
        index = build_grid_with_geometry(
            jnp.asarray(pts), eps, gmin, jnp.asarray(dims, jnp.int64))
        counts, pairs = brute(q, pts, eps)
        res = prepare(index, merge_last_dim=True).join(q)
        assert np.array_equal(res.counts, counts), dims
        assert np.array_equal(res.pairs, pairs), dims
        oracle = prepare(index, merge_last_dim=False).join(q)
        assert np.array_equal(res.counts, oracle.counts), dims
        assert np.array_equal(res.pairs, oracle.pairs), dims


def test_merged_selfjoin_custom_geometry_edge_rows():
    """Self-join under externally supplied geometry whose points sit on
    grid row 0 / dims-1 (no eps margin): the descriptor row clamp is what
    keeps the merged sweep exact here."""
    rng = np.random.default_rng(61)
    pts = rng.uniform(0, 6, (300, 2))
    eps = 1.0
    gmin = jnp.zeros(2, dtype=jnp.float64)
    dims = jnp.asarray([6, 6], jnp.int64)   # coords span [0, 5]: edge rows
    index = build_grid_with_geometry(jnp.asarray(pts), eps, gmin, dims)
    for unicomp in (True, False):
        m = self_join(pts, eps, unicomp=unicomp, index=index,
                      distance_impl="fused", merge_last_dim=True)
        u = self_join(pts, eps, unicomp=unicomp, index=index,
                      distance_impl="fused", merge_last_dim=False)
        assert np.array_equal(m, u), unicomp
        _, bp = brute(pts, pts, eps)
        bp = bp[bp[:, 0] != bp[:, 1]]
        assert np.array_equal(m, bp), unicomp


@pytest.mark.parametrize("method", ["reference", "kernel"])
def test_kernel_boundary_mask_kills_wrapped_candidates(method):
    """Unit test of the kernel-side |cand_last - q_last| <= 1 mask: feed a
    fabricated window whose tail rows carry a last-dim cell coordinate 2
    rows away (the wrapped-row signature). With merged=True those rows
    must be masked even though they pass the epsilon threshold; with
    merged=False (coordinate lane absent) they count."""
    from repro.kernels import ops
    from repro.kernels.fused_join import NP_PAD

    tq = 128
    c = 8
    n = 2
    pts = np.zeros((16 + c, NP_PAD))
    pts[:, :n] = 0.05                       # all points within eps of query
    pts[:, n] = 1.0                         # last-dim cell coord lane
    pts[4:8, n] = 3.0                       # "wrapped": |3 - 1| = 2
    q = np.zeros((tq, NP_PAD))
    q[0, :n] = 0.0
    q[0, n] = 1.0                           # query's last-dim cell coord
    ws = np.zeros((1, tq), np.int32)
    wc = np.zeros((1, tq), np.int32)
    wc[0, 0] = 8                            # one live window: rows 0..7
    iz = np.zeros(1, np.int32)
    qpos = np.full(tq, 1 << 20, np.int32)   # external-style: no self mask
    kw = dict(c=c, n_real=n, unicomp=False, external=True, tq=tq,
              method=method)
    _, counts_m, _ = ops.fused_join_hits(
        jnp.asarray(pts), jnp.asarray(q), jnp.asarray(ws), jnp.asarray(wc),
        jnp.asarray(iz), jnp.asarray(qpos), 0.5, merged=True, **kw)
    _, counts_u, _ = ops.fused_join_hits(
        jnp.asarray(pts), jnp.asarray(q), jnp.asarray(ws), jnp.asarray(wc),
        jnp.asarray(iz), jnp.asarray(qpos), 0.5, merged=False, **kw)
    assert int(np.asarray(counts_m)[0]) == 4   # wrapped rows masked
    assert int(np.asarray(counts_u)[0]) == 8   # lane ignored when unmerged


# ---------------------------------------------------------------------------
# Serving path (PreparedJoin / JoinService) under merging
# ---------------------------------------------------------------------------

def test_merged_serving_parity_and_no_retrace():
    from repro.core.query_join import executable_cache_stats, prepare
    from repro.launch.serve import JoinService

    rng = np.random.default_rng(7)
    bg = rng.uniform(0, 10, (500, 2))
    cl = rng.normal(5.0, 0.12, (260, 2))
    pts = np.concatenate([bg, cl])
    index = build_grid_host(pts, 0.5)
    pj = prepare(index, merge_last_dim=True)
    po = prepare(index, merge_last_dim=False)
    assert pj.merged and not po.merged
    assert pj.n_offsets == 3 and po.n_offsets == 9
    q = np.concatenate([rng.normal(5.0, 0.2, (30, 2)),
                        rng.uniform(-1, 11, (40, 2))])
    counts, pairs = brute(q, pts, 0.5)
    rm, ro = pj.join(q), po.join(q)
    assert np.array_equal(rm.counts, counts)
    assert np.array_equal(rm.pairs, pairs)
    assert np.array_equal(ro.counts, counts)
    assert np.array_equal(ro.pairs, pairs)
    # steady state through JoinService stays retrace-free with merged
    # descriptors (the `make verify` gate's pytest twin)
    svc = JoinService(pts, 0.5, index=index)
    assert svc.prepared.merged
    svc.warmup(64)
    svc.mark_steady()
    for _ in range(4):
        qq = np.concatenate([rng.normal(5.0, 0.15, (20, 2)),
                             rng.uniform(0, 10, (44, 2))])
        res = svc.query(qq)
        b, _ = brute(qq, pts, 0.5)
        assert np.array_equal(res.counts, b)
    svc.assert_no_retrace()
    assert "external_range_windows" in executable_cache_stats()


def test_flat_route_overrides_and_join_sweep_verdict():
    """The routing table's sweep axis: '-flat' routes run the per-cell
    sweep (identical totals/counters, 3^n offsets), and the join driver
    follows a cached '-flat' verdict for its own sweep."""
    from repro.core.grid import index_cached
    from repro.core.selfjoin import _join_sweep_merged

    rng = np.random.default_rng(71)
    pts = rng.uniform(0, 10, (400, 2))
    index = build_grid_host(pts, 0.6)
    a = self_join_count(pts, 0.6, index=index, unicomp=False)
    for route, n_off in (("dense-flat", 9), ("sparse-flat", 9),
                         ("dense", 3), ("sparse", 3)):
        s = self_join_count(pts, 0.6, index=index, distance_impl="fused",
                            route=route, unicomp=False)
        assert s.route == route
        assert s.n_offsets == n_off, route
        assert (s.total_pairs, s.cells_visited, s.candidates_checked) == \
            (a.total_pairs, a.cells_visited, a.candidates_checked), route
    # no measurements cached: the heuristic tier keeps the join merged
    assert _join_sweep_merged(index, unicomp=True, bucketed=None,
                              merged=True)
    # a measured 'dense-flat' verdict flips the join's sweep (pre-seed the
    # per-index route cache the way _auto_route would after measuring);
    # 'sparse-flat' judges only the counter and leaves the join merged
    index2 = build_grid_host(pts[:300], 0.6)
    index_cached(index2, "route/True/None/True", lambda: "dense-flat")
    assert not _join_sweep_merged(index2, unicomp=True, bucketed=None,
                                  merged=True)
    assert np.array_equal(
        self_join(pts[:300], 0.6, index=index2, distance_impl="fused"),
        self_join(pts[:300], 0.6, index=index2, distance_impl="jnp"))
    index3 = build_grid_host(pts[:300], 0.6)
    index_cached(index3, "route/True/None/True", lambda: "sparse-flat")
    assert _join_sweep_merged(index3, unicomp=True, bucketed=None,
                              merged=True)


def test_merged_external_1d():
    """Regression: 1-D external queries through the merged default (the
    reduced stencil degenerates to one range probe and the row vector is
    zero-width -- the zero last-coordinate column must still appear)."""
    from repro.core.query_join import epsilon_join

    rng = np.random.default_rng(83)
    pts = rng.uniform(0, 50, (200, 1))
    q = rng.uniform(-2, 52, (17, 1))
    counts, pairs = brute(q, pts, 0.5)
    res = epsilon_join(q, pts, 0.5)
    assert res.n_offsets == 1
    assert np.array_equal(res.counts, counts)
    assert np.array_equal(res.pairs, pairs)
    oracle = epsilon_join(q, pts, 0.5, merge_last_dim=False)
    assert np.array_equal(oracle.counts, counts)


def test_per_point_counts_merged_matches_oracle():
    for name, pts, eps in boundary_datasets():
        index = build_grid_host(pts, eps)
        m = per_point_neighbor_counts(pts, eps, index=index,
                                      merge_last_dim=True)
        u = per_point_neighbor_counts(pts, eps, index=index,
                                      merge_last_dim=False)
        assert np.array_equal(m, u), name
